//! Bench: Fig 12 — normalized GPU execution time (decode, batch 8).
//! Run: `cargo bench --bench fig12_gpu_exec`

use halo::gpu::{GpuConfig, GpuSim};
use halo::workload::{ModelShapes, Phase};

fn main() {
    let sim = GpuSim::new(GpuConfig::default());
    let methods = ["fp16", "w8a8", "w4a8", "w3a8", "halo-perf", "halo-acc", "halo-bal"];
    println!("=== Fig 12: normalized GPU execution time (W8A8 = 1.0) ===");
    for model in ModelShapes::paper_models() {
        let base = sim.run_method(&model, Phase::decode(8), "w8a8", 128, 8).time_s;
        print!("{:<12}", model.name);
        for m in &methods {
            let r = sim.run_method(&model, Phase::decode(8), m, 128, 8);
            print!(" {:>9.3}", r.time_s / base);
        }
        println!();
    }
    println!("              {}", methods.map(|m| format!("{m:>9}")).join(" "));

    // DVFS governor decisions for the 7B model.
    let model = ModelShapes::llama2_7b();
    println!("\n=== DVFS level selection (llama2-7b) ===");
    for m in &methods {
        let r = sim.run_method(&model, Phase::decode(8), m, 128, 8);
        println!("{:<10} class clocks {:?} GHz, transitions {}", m, r.class_ghz, r.transitions);
    }
}
