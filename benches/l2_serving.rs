//! Bench: sharded serving throughput (PR 3) — the router → per-shard
//! batcher → executor path under synthetic CPU-bound load, 1 shard vs N.
//!
//! Run: `cargo bench --bench l2_serving [-- --smoke] [-- --json FILE]
//!       [-- --shards N] [-- --requests M]`
//!
//! `--smoke` shrinks the workload to a CI-sized run; `--json FILE` writes
//! the measured numbers (used by `make bench-json`, which produces
//! `BENCH_PR3.json` so the perf trajectory accumulates). The per-sequence
//! busywork is single-threaded (naive kernels), so shard scaling measures
//! the serving architecture, not the matmul pool. On a 4-core runner the
//! multi-shard run is expected to clear 1.5× single-shard throughput.

use std::time::Duration;

use halo::coordinator::loadgen::{run, LoadgenConfig};
use halo::util::Json;

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let smoke = args.iter().any(|a| a == "--smoke");
    let flag = |name: &str| {
        args.iter()
            .position(|a| a == name)
            .and_then(|i| args.get(i + 1))
            .cloned()
    };
    let json_path = flag("--json");
    let shards: usize = flag("--shards")
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(|| {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(4)
        })
        .max(2);
    let requests: usize = flag("--requests")
        .and_then(|s| s.parse().ok())
        .unwrap_or(if smoke { 96 } else { 768 });

    let base = LoadgenConfig {
        shards: 1,
        batch_size: 8,
        batch_timeout: Duration::from_millis(1),
        queue_cap: 0,
        deadline: None,
        requests,
        rps: 0.0, // closed firehose: measure the ceiling
        max_new_tokens: if smoke { 2 } else { 4 },
        prefix_len: 12,
        // Same busywork dose in smoke mode: the per-batch cost must stay
        // comfortably above timer noise or the scaling ratio is mush.
        work_dim: 48,
        seed: 0x10AD,
    };

    let mut report = Json::obj();
    report.set("bench", "l2_serving").set("smoke", smoke);
    let mut j_cfg = Json::obj();
    j_cfg
        .set("requests", base.requests)
        .set("max_new_tokens", base.max_new_tokens)
        .set("work_dim", base.work_dim)
        .set("multi_shards", shards);
    report.set("config", j_cfg);

    println!("=== sharded serving throughput (synthetic executor) ===");
    let one = run(&base).expect("single-shard run");
    println!("shards=1: {}", one.summary());
    assert_eq!(one.verified_ok, requests, "single-shard decode verification failed");

    let multi_cfg = LoadgenConfig { shards, ..base.clone() };
    let multi = run(&multi_cfg).expect("multi-shard run");
    println!("shards={shards}: {}", multi.summary());
    assert_eq!(multi.verified_ok, requests, "multi-shard decode verification failed");

    let scaling = multi.throughput_rps() / one.throughput_rps().max(1e-12);
    println!(
        "scaling: {:.0} → {:.0} req/s = {scaling:.2}x with {shards} shards",
        one.throughput_rps(),
        multi.throughput_rps()
    );

    report.set("single_shard", one.to_json());
    report.set("multi_shard", multi.to_json());
    report.set("scaling_throughput", scaling);

    if let Some(path) = json_path {
        std::fs::write(&path, report.to_string_pretty()).expect("write bench json");
        println!("\nwrote {path}");
    }
}
