//! Bench: paged KV pool memory — shared-prefix reuse + block packing (PR 8).
//!
//! Run: `cargo bench --bench l6_kvcache [-- --smoke] [-- --json FILE]`
//!
//! The acceptance workload: 32 requests that share a 64-token common
//! header (a system prompt) followed by per-request suffixes, prefilled
//! through `forward_incremental` into caches carved from a per-shard
//! `BlockPool`. Run twice — once against a sharing-enabled pool (the
//! serving default) and once with sharing disabled — with every cache
//! held live, so the pools' peak block counts are the real steady-state
//! footprints of the two policies.
//!
//! Gated ratio keys (see `tools/bench_check.rs` + the bench-smoke CI job):
//!
//! - `shared_prefix_saving` — no-sharing pool peak bytes over sharing
//!   pool peak bytes for the acceptance workload. At block size 16 the
//!   64-token header freezes into 4 blocks referenced by all 32 block
//!   tables instead of duplicated into each, so the analytic value is
//!   `32*ceil(72/16) / (4 + 32*ceil(8/16))` ≈ **4.4x**; the CI floor is
//!   the ISSUE's **1.5x** (`--min shared_prefix_saving=1.5`), leaving
//!   room for block-geometry tuning.
//! - `kv_bytes_per_token_ratio` — bytes the retired contiguous cache
//!   (geometric doubling from 16 rows, PR 5) would allocate for the same
//!   windows, over the paged no-sharing pool's actual bytes. Pure block
//!   packing, orthogonal to sharing: doubling rounds a 72-row window up
//!   to 128 rows where 16-row blocks round to 80 (≈ 1.6x).
//!
//! Both are deterministic geometry, not timings, so the 0.3 CI tolerance
//! is generous. `--smoke` shrinks suffix length/reps; `--json FILE`
//! writes the measured numbers (`make bench-json` -> BENCH_PR8.json).

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant;

use halo::runtime::sim::{forward_incremental, DenseParams, ModelSpec};
use halo::runtime::{argmax_slice, BlockPool, KvCache, DEFAULT_BLOCK_ROWS};
use halo::util::{Json, Rng};

/// Acceptance-workload shape (ISSUE: 32 requests, 64-token header).
const N_REQUESTS: usize = 32;
const HEADER_LEN: usize = 64;
/// The retired contiguous cache's initial capacity (PR 5
/// `INITIAL_CAP_ROWS`), the seed of its geometric doubling.
const OLD_INITIAL_CAP_ROWS: usize = 16;

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let smoke = args.iter().any(|a| a == "--smoke");
    let json_path = args
        .iter()
        .position(|a| a == "--json")
        .and_then(|i| args.get(i + 1))
        .cloned();

    let mut report = Json::obj();
    report.set("bench", "l6_kvcache").set("smoke", smoke);

    println!(
        "=== paged KV pool: {N_REQUESTS} requests x {HEADER_LEN}-token shared header ==="
    );
    let (saving, ratio) = bench_pool(smoke, &mut report);
    println!(
        "\nsummary: shared_prefix_saving {saving:.2}x, kv_bytes_per_token_ratio {ratio:.2}x"
    );

    if let Some(path) = json_path {
        std::fs::write(&path, report.to_string_pretty()).expect("write bench json");
        println!("wrote {path}");
    }
}

/// Small dense model whose context window holds header + suffix.
fn bench_model(window: usize) -> (ModelSpec, DenseParams) {
    let spec = ModelSpec::synthetic(64, 32, 2, 4, 64, window + 8);
    let mut rng = Rng::seed_from_u64(0xB10C5);
    let mut params: Vec<(String, Vec<usize>, Vec<f32>)> = Vec::new();
    for (name, shape) in spec.names.iter().zip(&spec.shapes) {
        let numel: usize = shape.iter().product();
        let data: Vec<f32> = if name.ends_with(".scale") {
            vec![1.0; numel]
        } else if name.ends_with(".bias") || name.ends_with(".b1") || name.ends_with(".b2") {
            vec![0.0; numel]
        } else {
            let std = 1.0 / (shape[0] as f32).sqrt();
            (0..numel).map(|_| rng.gen_normal() as f32 * std).collect()
        };
        params.push((name.clone(), shape.clone(), data));
    }
    let p = DenseParams::from_params(
        &spec,
        params.iter().map(|(n, s, d)| (n.as_str(), s.as_slice(), d.as_slice())),
    )
    .expect("bench model params");
    (spec, p)
}

/// Prefill `window` into a cache carved from `pool`; returns the cache
/// (held live by the caller) and the greedy next token for the sanity
/// check between the seeded and cold paths.
fn prefill(
    spec: &ModelSpec,
    p: &DenseParams,
    pool: &Arc<BlockPool>,
    window: &[i32],
) -> (KvCache, i32) {
    let mut cache = pool.new_cache(window);
    let cached = cache.len();
    let logits = forward_incremental(spec, p, &window[cached..], cached, &mut cache, false)
        .expect("prefill");
    (cache, argmax_slice(logits.row(window.len() - cached - 1)) as i32)
}

/// One full pass of the acceptance workload against `pool`: prefill all
/// requests, hold every cache live, return (peak bytes, wall seconds,
/// per-request next tokens).
fn run_workload(
    spec: &ModelSpec,
    p: &DenseParams,
    pool: &Arc<BlockPool>,
    windows: &[Vec<i32>],
) -> (usize, f64, Vec<i32>) {
    let t0 = Instant::now();
    let mut caches = Vec::with_capacity(windows.len());
    let mut toks = Vec::with_capacity(windows.len());
    for w in windows {
        let (c, t) = prefill(spec, p, pool, w);
        caches.push(c);
        toks.push(t);
    }
    let wall = t0.elapsed().as_secs_f64();
    let s = pool.stats();
    let peak_bytes = s.blocks_peak * block_bytes(spec, s.block_rows);
    drop(caches);
    (peak_bytes, wall, toks)
}

/// Bytes one K+V block holds across all layers (f32 rows).
fn block_bytes(spec: &ModelSpec, block_rows: usize) -> usize {
    block_rows * spec.d_model * 2 * spec.n_layers * 4
}

/// Rows the PR 5 contiguous cache would reserve for an `n`-row window:
/// geometric doubling from [`OLD_INITIAL_CAP_ROWS`].
fn doubled_rows(n: usize) -> usize {
    let mut cap = OLD_INITIAL_CAP_ROWS;
    while cap < n {
        cap *= 2;
    }
    cap
}

fn bench_pool(smoke: bool, report: &mut Json) -> (f64, f64) {
    let suffix_len = if smoke { 8 } else { 16 };
    let reps = if smoke { 1 } else { 3 };
    let window = HEADER_LEN + suffix_len;
    let (spec, p) = bench_model(window);
    let bs = DEFAULT_BLOCK_ROWS;

    let mut rng = Rng::seed_from_u64(0x5EED8);
    let header: Vec<i32> =
        (0..HEADER_LEN).map(|_| rng.gen_usize(spec.vocab) as i32).collect();
    let windows: Vec<Vec<i32>> = (0..N_REQUESTS)
        .map(|_| {
            let mut w = header.clone();
            w.extend((0..suffix_len).map(|_| rng.gen_usize(spec.vocab) as i32));
            w
        })
        .collect();

    let (mut shared_bytes, mut noshare_bytes) = (0usize, 0usize);
    let (mut t_shared, mut t_noshare) = (0.0f64, 0.0f64);
    let mut stats = BTreeMap::new();
    for _ in 0..reps {
        // Fresh pools per rep: peak counts measure one cold pass each.
        let shared = Arc::new(
            BlockPool::new(spec.n_layers, spec.d_model, bs, 0).with_sharing(64),
        );
        let noshare = Arc::new(BlockPool::new(spec.n_layers, spec.d_model, bs, 0));
        let (sb, st, stoks) = run_workload(&spec, &p, &shared, &windows);
        let (nb, nt, ntoks) = run_workload(&spec, &p, &noshare, &windows);
        // Seeded prefills must predict exactly what cold prefills predict.
        assert_eq!(stoks, ntoks, "shared-prefix seeding changed a next token");
        shared_bytes = sb;
        noshare_bytes = nb;
        t_shared += st;
        t_noshare += nt;
        let s = shared.stats();
        assert!(s.shared_hits > 0, "sharing pool never seeded a cache");
        stats.insert("shared_hits", s.shared_hits);
        stats.insert("prefix_lookups", s.prefix_lookups);
        stats.insert("registry_entries", s.registry_entries as u64);
    }

    let saving = noshare_bytes as f64 / shared_bytes.max(1) as f64;
    // Modeled footprint of the retired contiguous cache on this workload.
    let row = spec.d_model * 2 * spec.n_layers * 4;
    let old_bytes: usize = windows.iter().map(|w| doubled_rows(w.len()) * row).sum();
    let ratio = old_bytes as f64 / noshare_bytes.max(1) as f64;

    let total_rows = (N_REQUESTS * window) as f64;
    println!(
        "pool bs={bs}: sharing {shared_bytes} B peak, no-sharing {noshare_bytes} B peak \
         -> shared_prefix_saving {saving:.2}x"
    );
    println!(
        "contiguous(modeled) {old_bytes} B vs paged {noshare_bytes} B \
         -> kv_bytes_per_token_ratio {ratio:.2}x"
    );
    println!(
        "prefill: sharing {:.0} tok/s, no-sharing {:.0} tok/s ({} reps; {:?})",
        reps as f64 * total_rows / t_shared.max(1e-12),
        reps as f64 * total_rows / t_noshare.max(1e-12),
        reps,
        stats
    );

    report
        .set("n_requests", N_REQUESTS)
        .set("header_len", HEADER_LEN)
        .set("suffix_len", suffix_len)
        .set("block_rows", bs)
        .set("shared_pool_peak_bytes", shared_bytes as f64)
        .set("noshare_pool_peak_bytes", noshare_bytes as f64)
        .set("contiguous_modeled_bytes", old_bytes as f64)
        .set("shared_prefix_saving", saving)
        .set("kv_bytes_per_token_ratio", ratio);
    (saving, ratio)
}
