//! Bench: L3 hot paths (§Perf) — coordinator routing/batching throughput,
//! the quantization pipeline, the MAC profile build, and (when artifacts
//! exist) the PJRT kernel execution path.
//! Run: `cargo bench --bench l3_coordinator`

use std::time::Duration;

use halo::coordinator::{BatchExecutor, BatcherConfig, Coordinator, CoordinatorConfig, Request};
use halo::mac::MacProfile;
use halo::quant::baselines::by_name;
use halo::quant::{LayerCtx, Matrix};
use halo::util::bench::{bench, bench_n};
use halo::util::Rng;

struct Noop;

impl BatchExecutor for Noop {
    fn batch_capacity(&self) -> usize {
        8
    }
    fn seq_len(&self) -> usize {
        128
    }
    fn run(&mut self, prefixes: &[Vec<i32>]) -> anyhow::Result<Vec<i32>> {
        Ok(prefixes.iter().map(|p| p.len() as i32).collect())
    }
}

fn main() {
    // 1. Coordinator routing throughput (no model): requests/s ceiling.
    let coord = Coordinator::start(
        CoordinatorConfig {
            batcher: BatcherConfig { batch_size: 8, timeout: Duration::from_micros(200) },
            ..CoordinatorConfig::default()
        },
        |_shard| Ok(Box::new(Noop) as Box<dyn BatchExecutor>),
    );
    let n = 20_000;
    let t0 = std::time::Instant::now();
    let rxs: Vec<_> =
        (0..n).map(|i| coord.submit_or_shed(Request::new(vec![i as i32; 16]))).collect();
    for rx in rxs {
        rx.recv().unwrap();
    }
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "coordinator_routing: {n} reqs in {dt:.3}s = {:.0} req/s (occupancy {:.2})",
        n as f64 / dt,
        coord.metrics.mean_batch_occupancy()
    );
    coord.shutdown().unwrap();

    // 2. Quantization pipeline on a 1024x1024 layer.
    let profile = MacProfile::cached();
    let mut rng = Rng::seed_from_u64(1);
    let w = Matrix::random_normal(1024, 1024, 0.02, &mut rng);
    let g = Matrix::random_normal(1024, 1024, 1.0, &mut rng);
    for method in ["rtn-w4", "zq-local", "halo-bal"] {
        let q = by_name(method, profile, 128).unwrap();
        let s = bench(&format!("quantize_1024x1024/{method}"), Duration::from_secs(2), || {
            std::hint::black_box(q.quantize(&w, &LayerCtx::with_grad("b", &g)));
        });
        println!("{}", s.report());
    }
    // GPTQ is heavier (Cholesky + error propagation) — fixed iterations.
    let q = by_name("gptq", profile, 128).unwrap();
    let s = bench_n("quantize_1024x1024/gptq", 3, || {
        std::hint::black_box(q.quantize(&w, &LayerCtx::with_grad("b", &g)));
    });
    println!("{}", s.report());

    // 3. MAC profile build (STA + dynamic sampling over 256 weights).
    let s = bench_n("mac_profile_compute(256 samples)", 3, || {
        std::hint::black_box(MacProfile::compute(256, 1));
    });
    println!("{}", s.report());

    // 4. PJRT kernel microbench (needs artifacts).
    if let Ok(store) = halo::runtime::Store::open_default() {
        if let Ok(rt) = halo::runtime::Runtime::cpu() {
            let path = store.kernel_path("halo_matmul");
            if let Ok(exe) = rt.load(&path) {
                let mut rng = Rng::seed_from_u64(2);
                let x: Vec<f32> = (0..128 * 256).map(|_| rng.gen_normal() as f32).collect();
                let idx: Vec<i8> = (0..256 * 1024).map(|_| rng.gen_usize(16) as i8).collect();
                let cb: Vec<f32> = (0..16).map(|_| rng.gen_normal() as f32).collect();
                let sc: Vec<f32> = (0..2 * 8).map(|_| 1.0).collect();
                let inputs = vec![
                    halo::runtime::literal_f32(&x, &[128, 256]).unwrap(),
                    halo::runtime::literal_i8(&idx, &[256, 1024]).unwrap(),
                    halo::runtime::literal_f32(&cb, &[16]).unwrap(),
                    halo::runtime::literal_f32(&sc, &[2, 8]).unwrap(),
                ];
                let s = bench("pjrt_halo_matmul_128x256x1024", Duration::from_secs(2), || {
                    std::hint::black_box(exe.run(&inputs).unwrap());
                });
                println!("{}", s.report());
                let flops = 2.0 * 128.0 * 256.0 * 1024.0;
                println!(
                    "  ≈ {:.2} GFLOP/s through the L1 Pallas kernel (interpret-mode HLO)",
                    flops / s.mean_s() / 1e9
                );
            }
        }
    } else {
        println!("(artifacts missing — skipping PJRT kernel microbench)");
    }
}
