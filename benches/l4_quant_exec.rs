//! Bench: native quantized execution (PR 4, rebuilt integer-first in
//! PR 10) — the W4A8 panel kernel (i8 weight panels × per-row-quantized
//! i8 activations, i32 accumulation, one f32 rescale per tile, fused
//! hypersparse SpMV) vs the dequantize-then-dense path, at the layer
//! level and through the full decode loop, plus the deterministic
//! bytes-touched and modeled-DVFS ratios from the per-tile cost model.
//!
//! Run: `cargo bench --bench l4_quant_exec [-- --smoke] [-- --json FILE]`
//!
//! `--smoke` shrinks shapes/reps to a CI-sized run; `--json FILE` writes
//! the measured numbers (`make bench-json` → `BENCH_PR10.json`). Gated
//! ratio keys (see `tools/bench_check.rs` + the bench-smoke CI job):
//!
//! - `layer.throughput_ratio`   — qmatmul wall-clock vs blocked dense matmul
//! - `decode.throughput_ratio`  — packed decode tokens/s vs dense decode
//! - `quant_vs_dense_throughput` — top-level alias of the decode ratio,
//!   gated at `--min 1.0`: packed decode must BEAT dense, not merely
//!   hold a fraction of it
//! - `memory.bytes_saving`      — dense f32 bytes / packed bytes (deterministic)
//! - `model_cost.modeled_speedup` — DVFS class clocks vs all-base (deterministic)
//!
//! The PR 4 LUT kernel expanded every tile's codes through an f32 table
//! on each call and held ~25 % of dense throughput (the old floor). The
//! integer panels stream 1 byte/weight with no per-call expansion — a 4×
//! weight-traffic drop on the memory-bound decode shapes — so the floor
//! flips to `quant_vs_dense_throughput >= 1.0`.

use std::collections::BTreeMap;
use std::time::Instant;

use halo::coordinator::{BatchExecutor, QuantExecutor};
use halo::dvfs::Ladder;
use halo::mac::MacProfile;
use halo::quant::packed::PackedLayer;
use halo::quant::{HaloConfig, HaloQuantizer, LayerCtx, Matrix, Variant};
use halo::runtime::sim::{model_forward, ModelSpec};
use halo::runtime::{argmax_slice, kernels, qmatmul, Literal, PackedModel};
use halo::util::{Json, Rng};

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let smoke = args.iter().any(|a| a == "--smoke");
    let json_path = args
        .iter()
        .position(|a| a == "--json")
        .and_then(|i| args.get(i + 1))
        .cloned();

    let mut report = Json::obj();
    report.set("bench", "l4_quant_exec").set("smoke", smoke);

    println!("=== quantized execution vs dequantize-then-dense ===");
    let layer_ratio = bench_layer(smoke, &mut report);
    let (decode_ratio, bytes_saving, modeled) = bench_decode(smoke, &mut report);
    // The headline gate: packed decode throughput as a multiple of dense.
    report.set("quant_vs_dense_throughput", decode_ratio);

    println!(
        "\nsummary: layer ratio {layer_ratio:.2}, decode ratio {decode_ratio:.2}, \
         bytes saving {bytes_saving:.2}x, modeled speedup {modeled:.2}x"
    );

    if let Some(path) = json_path {
        std::fs::write(&path, report.to_string_pretty()).expect("write bench json");
        println!("wrote {path}");
    }
}

/// Layer-level: y = x @ W on one packed layer vs the blocked dense kernel
/// fed the dequantized weights.
fn bench_layer(smoke: bool, report: &mut Json) -> f64 {
    let (k, n, m) = if smoke { (256, 256, 64) } else { (768, 768, 128) };
    let reps = if smoke { 5 } else { 20 };
    let profile = MacProfile::cached();
    let mut rng = Rng::seed_from_u64(0x9A10);
    let w = Matrix::random_normal(k, n, 0.02, &mut rng);
    let g = Matrix::random_normal(k, n, 1.0, &mut rng);
    let q = HaloQuantizer::new(HaloConfig::new(64, Variant::Bal), profile);
    let (res, pay) = q.quantize_full(&w, &LayerCtx::with_grad("bench", &g));
    let layer = PackedLayer::pack("bench", &res, &pay, profile);
    let dense = layer.dequantize();
    let x = Matrix::random_normal(m, k, 1.0, &mut rng);

    // Warm both paths once, then alternate to cancel drift.
    let mut acc = 0.0f32;
    acc += qmatmul(&x, &layer).data[0];
    acc += kernels::matmul(&x, &dense).data[0];
    let (mut t_quant, mut t_dense) = (0.0f64, 0.0f64);
    for _ in 0..reps {
        let t0 = Instant::now();
        acc += qmatmul(&x, &layer).data[0];
        t_quant += t0.elapsed().as_secs_f64();
        let t0 = Instant::now();
        acc += kernels::matmul(&x, &dense).data[0];
        t_dense += t0.elapsed().as_secs_f64();
    }
    std::hint::black_box(acc);

    let ratio = t_dense / t_quant.max(1e-12);
    println!(
        "layer {k}x{n} (m={m}, tile 64): quant {:.2}ms dense {:.2}ms → ratio {ratio:.2}",
        t_quant / reps as f64 * 1e3,
        t_dense / reps as f64 * 1e3
    );
    let mut j = Json::obj();
    j.set("k", k)
        .set("n", n)
        .set("m", m)
        .set("quant_ms", t_quant / reps as f64 * 1e3)
        .set("dense_ms", t_dense / reps as f64 * 1e3)
        .set("throughput_ratio", ratio);
    report.set("layer", j);
    ratio
}

/// Dense oracle executor: the dequantize-then-dense serving path this PR
/// retires, kept as the bench baseline (same interpreter, dense weights
/// substituted as literals).
struct DenseExec {
    spec: ModelSpec,
    params: Vec<Literal>,
    batch: usize,
}

impl BatchExecutor for DenseExec {
    fn batch_capacity(&self) -> usize {
        self.batch
    }

    fn seq_len(&self) -> usize {
        self.spec.seq_len
    }

    fn run(&mut self, prefixes: &[Vec<i32>]) -> anyhow::Result<Vec<i32>> {
        let (b, s) = (prefixes.len(), self.spec.seq_len);
        let mut tokens = vec![0i32; b * s];
        for (i, p) in prefixes.iter().enumerate() {
            let np = p.len().min(s);
            tokens[i * s..i * s + np].copy_from_slice(&p[p.len() - np..]);
        }
        let mut inputs: Vec<&Literal> = self.params.iter().collect();
        let tok = Literal::i32(&tokens, &[b, s])?;
        inputs.push(&tok);
        let (logits, _, _) = model_forward(&self.spec, &inputs)?;
        Ok(prefixes
            .iter()
            .enumerate()
            .map(|(i, p)| {
                let pos = p.len().clamp(1, s) - 1;
                argmax_slice(logits.row(i * s + pos)) as i32
            })
            .collect())
    }
}

/// Bench model off the shared canonical layout ([`ModelSpec::synthetic`]),
/// so the bench and the `tests/qexec.rs` oracle exercise the same contract.
fn bench_spec(smoke: bool) -> ModelSpec {
    if smoke {
        ModelSpec::synthetic(64, 48, 2, 4, 96, 16)
    } else {
        ModelSpec::synthetic(128, 96, 2, 4, 192, 32)
    }
}

/// Full decode loop: packed executor vs the dense oracle on the same
/// synthetic model, same prefixes, same decode length.
fn bench_decode(smoke: bool, report: &mut Json) -> (f64, f64, f64) {
    let spec = bench_spec(smoke);
    let mut rng = Rng::seed_from_u64(0xDEC0);
    let mut params = Vec::new();
    let mut grads = BTreeMap::new();
    for (i, (name, shape)) in spec.names.iter().zip(&spec.shapes).enumerate() {
        let numel: usize = shape.iter().product();
        let data: Vec<f32> = if name.ends_with(".scale") {
            vec![1.0; numel]
        } else if name.ends_with(".bias") || name.ends_with(".b1") || name.ends_with(".b2") {
            vec![0.0; numel]
        } else {
            let std = 1.0 / (shape[0] as f32).sqrt();
            (0..numel).map(|_| rng.gen_normal() as f32 * std).collect()
        };
        if spec.linear[i] {
            grads.insert(
                name.clone(),
                Matrix::from_fn(shape[0], shape[1], |_, _| rng.gen_normal() as f32),
            );
        }
        params.push((name.clone(), shape.clone(), data));
    }
    let profile = MacProfile::cached();
    let views = params.iter().map(|(n, s, d)| (n.as_str(), s.as_slice(), d.as_slice()));
    let pm = PackedModel::pack_from(spec.clone(), views, Variant::Bal, 32, &grads, profile)
        .expect("pack");

    let cost = pm.cost(&Ladder::paper_systolic());
    let bytes_saving = cost.bytes_saving();
    let modeled = cost.modeled_speedup();
    println!("cost model: {}", cost.summary());

    // Dense oracle literals: the packed model's own dequantized weights.
    let dense_params: Vec<Literal> = spec
        .names
        .iter()
        .enumerate()
        .map(|(i, name)| {
            if spec.linear[i] {
                let dq = pm.layer(name).expect("packed").dequantize();
                Literal::f32(&dq.data, &spec.shapes[i]).unwrap()
            } else {
                Literal::f32(&params[i].2, &spec.shapes[i]).unwrap()
            }
        })
        .collect();

    let batch = 8usize;
    let max_new = if smoke { 2 } else { 4 };
    let reps = if smoke { 3 } else { 8 };
    let prefixes: Vec<Vec<i32>> = (0..batch)
        .map(|_| (0..8).map(|_| rng.gen_usize(spec.vocab) as i32).collect())
        .collect();
    let new_lens = vec![max_new; batch];

    // KV caching off: this bench isolates the packed-vs-dense *execution
    // format* (LUT matmul + fused SpMV vs dense f32), so both sides must
    // run the same full-recompute decode algorithm. The caching win is
    // measured separately in benches/l5_decode.rs.
    let mut quant_exec = QuantExecutor::new(std::sync::Arc::new(pm), batch).with_kv_cache(false);
    let mut dense_exec = DenseExec { spec: spec.clone(), params: dense_params, batch };

    // Warm-up + verification: both paths produce in-vocab tokens.
    let gq = quant_exec.generate(&prefixes, &new_lens).expect("quant decode");
    let gd = dense_exec.generate(&prefixes, &new_lens).expect("dense decode");
    for g in gq.iter().chain(gd.iter()) {
        assert_eq!(g.len(), max_new);
        assert!(g.iter().all(|&t| (0..spec.vocab as i32).contains(&t)));
    }

    let (mut t_quant, mut t_dense) = (0.0f64, 0.0f64);
    let mut tokens_out = 0usize;
    for _ in 0..reps {
        let t0 = Instant::now();
        let g = quant_exec.generate(&prefixes, &new_lens).expect("quant decode");
        t_quant += t0.elapsed().as_secs_f64();
        tokens_out += g.iter().map(|v| v.len()).sum::<usize>();
        let t0 = Instant::now();
        std::hint::black_box(dense_exec.generate(&prefixes, &new_lens).expect("dense decode"));
        t_dense += t0.elapsed().as_secs_f64();
    }
    let quant_tps = tokens_out as f64 / t_quant.max(1e-12);
    let dense_tps = tokens_out as f64 / t_dense.max(1e-12);
    let ratio = quant_tps / dense_tps.max(1e-12);
    println!(
        "decode (b={batch}, max_new={max_new}, {} layers d={}): quant {quant_tps:.0} tok/s, \
         dense {dense_tps:.0} tok/s → ratio {ratio:.2}",
        spec.n_layers, spec.d_model
    );

    let mut j = Json::obj();
    j.set("batch", batch)
        .set("max_new", max_new)
        .set("quant_tokens_per_sec", quant_tps)
        .set("dense_tokens_per_sec", dense_tps)
        .set("throughput_ratio", ratio);
    report.set("decode", j);
    let mut jm = Json::obj();
    jm.set("packed_bytes", cost.packed_bytes)
        .set("dense_bytes", cost.dense_bytes)
        .set("bytes_saving", bytes_saving);
    report.set("memory", jm);
    let mut jc = Json::obj();
    jc.set("modeled_speedup", modeled).set("sparse_nnz", cost.sparse_nnz);
    report.set("model_cost", jc);
    (ratio, bytes_saving, modeled)
}
