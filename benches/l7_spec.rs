//! Bench: speculative decoding on the variant ladder (PR 9).
//!
//! Run: `cargo bench --bench l7_spec [-- --smoke] [-- --json FILE]`
//!
//! The acceptance workload: one long greedy decode (S=256 tokens, the
//! PR 5 decode-bench shape) against a packed halo-acc verifier, run two
//! ways — verifier-only (`PackedModel::decode_greedy`, the solo cached
//! oracle) and speculatively through `SpecExecutor` with a k=4 drafter.
//! The speculative chain is asserted BIT-IDENTICAL to the verifier-only
//! chain before any timing is trusted, so the speedup below can never be
//! bought with a wrong token.
//!
//! Gated ratio keys (see `tools/bench_check.rs` + the bench-smoke CI job):
//!
//! - `spec_decode_speedup` — verifier-only wall-clock over speculative
//!   wall-clock for the **self-pair** (drafter = the verifier's own
//!   packed model, drafting natively on its integer W4A8 tiles since
//!   PR 10, so proposals are bit-identical and acceptance is 1). The
//!   PR 10 kernel rebuild moved the economics honestly: the old LUT
//!   kernel paid a per-pass panel expansion that the k+1-position verify
//!   pass amortized, while the drafter ran cheaper dense kernels —
//!   that asymmetry was the 1.2x self-pair win. The integer panels have
//!   no per-pass expansion and the packed drafter now costs exactly as
//!   much per token as the verifier, so the self-pair is bounded near
//!   break-even (each round spends k drafter passes + 1 verify pass for
//!   k+1 tokens; the verify pass re-does the k positions the drafter
//!   already computed). It is kept measured and gated as a regression
//!   alarm — CI floor: **0.7x** (`--min spec_decode_speedup=0.7`,
//!   tol 0.3) — and the real speedup headroom is a smaller-capacity
//!   drafter (see ROADMAP: distilled/truncated drafter rung).
//! - `acceptance_rate` — accepted/drafted for the self-pair. Drafter
//!   and verifier now run the SAME integer kernels on the same tiles,
//!   so every draft argmax-matches and the rate is exactly 1.0; gated
//!   at tol 0.3 as a drift alarm (a drop means the pairing silently
//!   degraded).
//!
//! A cross-variant pair (halo-perf drafting for halo-acc, the `--spec
//! drafter=halo-perf` serving default) is measured informationally:
//! its acceptance — and therefore its speedup — depends on how often two
//! quantization variants argmax-agree, which is workload physics, not a
//! regression axis.

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant;

use halo::coordinator::{BatchExecutor, SpecExecutor, SpecVerifier};
use halo::mac::MacProfile;
use halo::quant::{Matrix, Variant};
use halo::runtime::sim::ModelSpec;
use halo::runtime::PackedModel;
use halo::util::{Json, Rng};

/// Draft depth for every measured pair (the serving default `k=4`).
const K: usize = 4;

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let smoke = args.iter().any(|a| a == "--smoke");
    let json_path = args
        .iter()
        .position(|a| a == "--json")
        .and_then(|i| args.get(i + 1))
        .cloned();

    let mut report = Json::obj();
    report.set("bench", "l7_spec").set("smoke", smoke).set("k", K);

    let s_tokens = if smoke { 48 } else { 256 };
    let reps = if smoke { 1 } else { 3 };
    println!("=== speculative decode: S={s_tokens} tokens, k={K}, {reps} reps ===");

    let (speedup, acceptance) = bench_spec(s_tokens, reps, &mut report);
    println!(
        "\nsummary: spec_decode_speedup {speedup:.2}x, acceptance_rate {acceptance:.3}"
    );

    if let Some(path) = json_path {
        std::fs::write(&path, report.to_string_pretty()).expect("write bench json");
        println!("wrote {path}");
    }
}

type ParamList = Vec<(String, Vec<usize>, Vec<f32>)>;

/// Small model whose context holds prefix + the whole decode, so the
/// window never slides and speculation stays active for all S tokens
/// (at the cap the headroom clamp turns rounds into plain verifier
/// steps, which would just dilute the measurement).
fn bench_model(s_tokens: usize, prefix_len: usize) -> (ModelSpec, ParamList, BTreeMap<String, Matrix>) {
    let spec = ModelSpec::synthetic(64, 32, 2, 4, 64, prefix_len + s_tokens + 8);
    let mut rng = Rng::seed_from_u64(0x59EC);
    let mut params: ParamList = Vec::new();
    let mut grads = BTreeMap::new();
    for (i, (name, shape)) in spec.names.iter().zip(&spec.shapes).enumerate() {
        let numel: usize = shape.iter().product();
        let data: Vec<f32> = if name.ends_with(".scale") {
            vec![1.0; numel]
        } else if name.ends_with(".bias") || name.ends_with(".b1") || name.ends_with(".b2") {
            vec![0.0; numel]
        } else {
            let std = 1.0 / (shape[0] as f32).sqrt();
            (0..numel).map(|_| rng.gen_normal() as f32 * std).collect()
        };
        if spec.linear[i] {
            let g = Matrix::from_fn(shape[0], shape[1], |r, _| {
                let base = rng.gen_normal() as f32;
                if r < shape[0] / 2 {
                    base * 5.0
                } else {
                    base * 0.1
                }
            });
            grads.insert(name.clone(), g);
        }
        params.push((name.clone(), shape.clone(), data));
    }
    (spec, params, grads)
}

fn pack(
    spec: &ModelSpec,
    params: &ParamList,
    grads: &BTreeMap<String, Matrix>,
    variant: Variant,
) -> Arc<PackedModel> {
    let views = params.iter().map(|(n, s, d)| (n.as_str(), s.as_slice(), d.as_slice()));
    Arc::new(
        PackedModel::pack_from(spec.clone(), views, variant, 16, grads, MacProfile::cached())
            .expect("pack bench model"),
    )
}

/// Time one full speculative decode; returns (seconds, chain, stats).
fn run_spec(
    drafter: &Arc<PackedModel>,
    verifier: &Arc<PackedModel>,
    prefix: &[i32],
    s_tokens: usize,
) -> (f64, Vec<i32>, halo::coordinator::SpecDecodeStats) {
    let mut ex = SpecExecutor::from_packed(
        drafter.clone(),
        SpecVerifier::Packed(verifier.clone()),
        K,
        1,
    )
    .expect("pair speculative executor");
    let t0 = Instant::now();
    let out = ex.generate(&[prefix.to_vec()], &[s_tokens]).expect("speculative decode");
    (t0.elapsed().as_secs_f64(), out.into_iter().next().unwrap_or_default(), ex.stats())
}

fn bench_spec(s_tokens: usize, reps: usize, report: &mut Json) -> (f64, f64) {
    let prefix_len = 16usize;
    let (spec, params, grads) = bench_model(s_tokens, prefix_len);
    let acc = pack(&spec, &params, &grads, Variant::AccOpt);
    let perf = pack(&spec, &params, &grads, Variant::PerfOpt);

    let mut rng = Rng::seed_from_u64(0x5EED9);
    let prefix: Vec<i32> = (0..prefix_len).map(|_| rng.gen_usize(spec.vocab) as i32).collect();

    // Correctness first: both pairings must emit exactly the verifier's
    // own greedy chain. Only then do the timings below mean anything.
    let want = acc.decode_greedy(&prefix, s_tokens).expect("verifier-only oracle");
    let (_, self_chain, _) = run_spec(&acc, &acc, &prefix, s_tokens);
    assert_eq!(self_chain, want, "self-pair speculative chain diverged from verifier-only");
    let (_, cross_chain, _) = run_spec(&perf, &acc, &prefix, s_tokens);
    assert_eq!(cross_chain, want, "cross-pair speculative chain diverged from verifier-only");

    let (mut t_base, mut t_self, mut t_cross) = (0.0f64, 0.0f64, 0.0f64);
    let mut self_stats = halo::coordinator::SpecDecodeStats::default();
    let mut cross_stats = halo::coordinator::SpecDecodeStats::default();
    for _ in 0..reps {
        let t0 = Instant::now();
        let base = acc.decode_greedy(&prefix, s_tokens).expect("verifier-only decode");
        t_base += t0.elapsed().as_secs_f64();
        assert_eq!(base, want);

        let (ts, chain, st) = run_spec(&acc, &acc, &prefix, s_tokens);
        assert_eq!(chain, want);
        t_self += ts;
        self_stats = st;

        let (tc, chain, st) = run_spec(&perf, &acc, &prefix, s_tokens);
        assert_eq!(chain, want);
        t_cross += tc;
        cross_stats = st;
    }

    let speedup = t_base / t_self.max(1e-12);
    let acceptance = self_stats.acceptance_rate();
    let cross_speedup = t_base / t_cross.max(1e-12);
    let cross_acceptance = cross_stats.acceptance_rate();

    let tok_s = |t: f64| reps as f64 * s_tokens as f64 / t.max(1e-12);
    println!(
        "verifier-only (halo-acc packed): {:.0} tok/s over {reps} reps",
        tok_s(t_base)
    );
    println!(
        "self-pair   acc->acc  k={K}: {:.0} tok/s, accept {acceptance:.3}, \
         rounds {} ({} drafted / {} verify positions)",
        tok_s(t_self),
        self_stats.verify_rounds,
        self_stats.drafted_tokens,
        self_stats.verify_positions
    );
    println!(
        "cross-pair perf->acc  k={K}: {:.0} tok/s, accept {cross_acceptance:.3}, \
         rounds {} (informational)",
        tok_s(t_cross),
        cross_stats.verify_rounds
    );

    report
        .set("s_tokens", s_tokens)
        .set("prefix_len", prefix_len)
        .set("verifier_only_s", t_base)
        .set("spec_self_s", t_self)
        .set("spec_cross_s", t_cross)
        .set("spec_decode_speedup", speedup)
        .set("acceptance_rate", acceptance)
        .set("cross_speedup", cross_speedup)
        .set("cross_acceptance_rate", cross_acceptance)
        .set("self_verify_rounds", self_stats.verify_rounds as f64)
        .set("self_draft_positions", self_stats.draft_positions as f64)
        .set("self_verify_positions", self_stats.verify_positions as f64);
    (speedup, acceptance)
}
