//! Bench: KV-cached incremental decode vs full-prefix recompute (PR 5).
//!
//! Run: `cargo bench --bench l5_decode [-- --smoke] [-- --json FILE]`
//!
//! Decodes from a 256-token prefix through the packed serving executor
//! (`QuantExecutor`) twice — once KV-cached (the serving fast path: each
//! step evaluates only the uncached window suffix) and once with the
//! cache disabled (`--no-kv-cache` semantics: every step re-runs the
//! whole prefix, O(S) positions per token). Both paths produce identical
//! greedy chains (verified in-bench; pinned by `tests/decode_equiv.rs`),
//! so the ratio is a pure execution-cost comparison.
//!
//! Gated ratio key (see `tools/bench_check.rs` + the bench-smoke CI job):
//!
//! - `decode_cached_speedup` — cached tokens/s over recompute tokens/s at
//!   prefix length 256, *including* the cached path's one-time prefill.
//!
//! Documented floor: cached decode must hold at least **2x** recompute
//! throughput at S=256 (enforced twice in CI: baseline x (1 - tol) with
//! the committed BENCH_PR5.json, and an absolute `--min
//! decode_cached_speedup=2.0`). The analytic expectation is
//! `max_new x S / (S + max_new - 1)` ≈ 5.9x for the smoke shape (6
//! tokens), plus the O(S²)->O(S) attention saving on top, so 2x leaves
//! generous headroom for runner noise.
//!
//! `--smoke` shrinks decode length/reps to a CI-sized run; `--json FILE`
//! writes the measured numbers (`make bench-json` -> BENCH_PR5.json).

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant;

use halo::coordinator::{BatchExecutor, QuantExecutor};
use halo::mac::MacProfile;
use halo::quant::{Matrix, Variant};
use halo::runtime::sim::ModelSpec;
use halo::runtime::PackedModel;
use halo::util::{Json, Rng};

/// Prefix length the ISSUE's acceptance bar is stated at.
const PREFIX_LEN: usize = 256;

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let smoke = args.iter().any(|a| a == "--smoke");
    let json_path = args
        .iter()
        .position(|a| a == "--json")
        .and_then(|i| args.get(i + 1))
        .cloned();

    let mut report = Json::obj();
    report.set("bench", "l5_decode").set("smoke", smoke);

    println!("=== KV-cached decode vs full-prefix recompute (S={PREFIX_LEN}) ===");
    let speedup = bench_decode(smoke, &mut report);
    println!("\nsummary: decode_cached_speedup {speedup:.2}x");

    if let Some(path) = json_path {
        std::fs::write(&path, report.to_string_pretty()).expect("write bench json");
        println!("wrote {path}");
    }
}

/// Pack a small transformer whose context window fits the 256-token
/// prefix plus the decode budget.
fn bench_model(max_new: usize) -> (ModelSpec, Arc<PackedModel>) {
    let seq = PREFIX_LEN + max_new + 8;
    let spec = ModelSpec::synthetic(96, 48, 2, 4, 96, seq);
    let mut rng = Rng::seed_from_u64(0xDECA);
    let mut params: Vec<(String, Vec<usize>, Vec<f32>)> = Vec::new();
    let mut grads = BTreeMap::new();
    for (i, (name, shape)) in spec.names.iter().zip(&spec.shapes).enumerate() {
        let numel: usize = shape.iter().product();
        let data: Vec<f32> = if name.ends_with(".scale") {
            vec![1.0; numel]
        } else if name.ends_with(".bias") || name.ends_with(".b1") || name.ends_with(".b2") {
            vec![0.0; numel]
        } else {
            let std = 1.0 / (shape[0] as f32).sqrt();
            (0..numel).map(|_| rng.gen_normal() as f32 * std).collect()
        };
        if spec.linear[i] {
            grads.insert(
                name.clone(),
                Matrix::from_fn(shape[0], shape[1], |_, _| rng.gen_normal() as f32),
            );
        }
        params.push((name.clone(), shape.clone(), data));
    }
    let views = params.iter().map(|(n, s, d)| (n.as_str(), s.as_slice(), d.as_slice()));
    let profile = MacProfile::cached();
    let pm = PackedModel::pack_from(spec.clone(), views, Variant::Bal, 32, &grads, profile)
        .expect("pack bench model");
    (spec, Arc::new(pm))
}

fn bench_decode(smoke: bool, report: &mut Json) -> f64 {
    let max_new = if smoke { 6 } else { 8 };
    let reps = if smoke { 2 } else { 5 };
    let (spec, pm) = bench_model(max_new);
    let mut rng = Rng::seed_from_u64(0x5EED);
    let prefix: Vec<i32> =
        (0..PREFIX_LEN).map(|_| rng.gen_usize(spec.vocab) as i32).collect();
    let prefixes = vec![prefix];
    let new_lens = vec![max_new];

    // Correctness first: both paths must emit the same greedy chain.
    let mut cached = QuantExecutor::new(pm.clone(), 1);
    let mut recompute = QuantExecutor::new(pm.clone(), 1).with_kv_cache(false);
    let warm_c = cached.generate(&prefixes, &new_lens).expect("cached decode");
    let warm_r = recompute.generate(&prefixes, &new_lens).expect("recompute decode");
    assert_eq!(warm_c, warm_r, "cached and recompute chains diverged");
    assert_eq!(warm_c[0].len(), max_new);

    let (mut t_cached, mut t_recompute) = (0.0f64, 0.0f64);
    let mut tokens_out = 0usize;
    for _ in 0..reps {
        // Fresh executors per rep: the cached path pays its prefill every
        // time, so the measured ratio is end-to-end honest.
        let mut cached = QuantExecutor::new(pm.clone(), 1);
        let t0 = Instant::now();
        let g = cached.generate(&prefixes, &new_lens).expect("cached decode");
        t_cached += t0.elapsed().as_secs_f64();
        tokens_out += g[0].len();

        let mut recompute = QuantExecutor::new(pm.clone(), 1).with_kv_cache(false);
        let t0 = Instant::now();
        std::hint::black_box(recompute.generate(&prefixes, &new_lens).expect("recompute"));
        t_recompute += t0.elapsed().as_secs_f64();
    }
    let cached_tps = tokens_out as f64 / t_cached.max(1e-12);
    let recompute_tps = tokens_out as f64 / t_recompute.max(1e-12);
    let speedup = cached_tps / recompute_tps.max(1e-12);
    println!(
        "decode S={PREFIX_LEN} max_new={max_new} ({} layers, d={}): cached {cached_tps:.0} tok/s, \
         recompute {recompute_tps:.0} tok/s -> speedup {speedup:.2}x",
        spec.n_layers, spec.d_model
    );

    report
        .set("prefix_len", PREFIX_LEN)
        .set("max_new", max_new)
        .set("cached_tokens_per_sec", cached_tps)
        .set("recompute_tokens_per_sec", recompute_tps)
        .set("decode_cached_speedup", speedup);
    speedup
}
