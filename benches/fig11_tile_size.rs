//! Bench: Fig 11 — HALO-bal execution time vs tile size (128/64/32).
//! Run: `cargo bench --bench fig11_tile_size`

use halo::systolic::{SimConfig, Simulator};
use halo::workload::{ModelShapes, Phase};

fn main() {
    let sim = Simulator::new(SimConfig::default());
    println!("=== Fig 11: HALO-bal normalized time vs tile size (tile=128 → 1.0) ===");
    let mut geo = [0.0f64; 3];
    let models = ModelShapes::paper_models();
    for model in &models {
        let t128 = sim.run_method(model, Phase::prefill(), "halo-bal", 128, 9).time_s;
        print!("{:<12}", model.name);
        for (i, tile) in [128usize, 64, 32].into_iter().enumerate() {
            let t = sim.run_method(model, Phase::prefill(), "halo-bal", tile, 9).time_s;
            geo[i] += (t / t128).ln();
            print!("  tile{tile:<4} {:>6.3}", t / t128);
        }
        println!();
    }
    println!(
        "\ngeomean: t128 {:.3}, t64 {:.3}, t32 {:.3} (paper: 32x32 ≈ 15% faster than 128)",
        (geo[0] / models.len() as f64).exp(),
        (geo[1] / models.len() as f64).exp(),
        (geo[2] / models.len() as f64).exp()
    );
}
