//! Bench: Fig 8 — normalized systolic execution time per method.
//!
//! Times the simulator itself (the L3 hot path, §Perf) and prints the
//! figure's normalized rows. Run: `cargo bench --bench fig8_exec_time`

use std::time::Duration;

use halo::systolic::{SimConfig, Simulator};
use halo::util::bench::bench;
use halo::workload::{ModelShapes, Phase};

fn main() {
    let sim = Simulator::new(SimConfig::default());
    let models = ModelShapes::paper_models();
    let methods = ["fp16", "w8a8", "w4a8", "w3a8", "halo-perf", "halo-acc", "halo-bal"];

    println!("=== Fig 8: normalized execution time (FP16 = 1.0) ===");
    for model in &models {
        let fp16 = sim.run_method(model, Phase::prefill(), "fp16", 128, 8).time_s;
        print!("{:<12}", model.name);
        for m in &methods {
            let r = sim.run_method(model, Phase::prefill(), m, 128, 8);
            print!(" {:>9.3}", r.time_s / fp16);
        }
        println!();
    }
    println!("              {}", methods.map(|m| format!("{m:>9}")).join(" "));

    println!("\n=== simulator hot-path timing ===");
    let model = ModelShapes::llama2_7b();
    for m in ["w8a8", "halo-bal"] {
        let s = bench(&format!("systolic_sim/llama2-7b/{m}"), Duration::from_secs(2), || {
            std::hint::black_box(sim.run_method(&model, Phase::prefill(), m, 128, 8));
        });
        println!("{}", s.report());
    }
}
