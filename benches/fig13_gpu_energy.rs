//! Bench: Fig 13 — normalized GPU energy (constant/static/dynamic).
//! Run: `cargo bench --bench fig13_gpu_energy`

use halo::gpu::{GpuConfig, GpuSim};
use halo::workload::{ModelShapes, Phase};

fn main() {
    let sim = GpuSim::new(GpuConfig::default());
    let methods = ["fp16", "w8a8", "w4a8", "w3a8", "halo-perf", "halo-acc", "halo-bal"];
    println!("=== Fig 13: normalized GPU energy (W8A8 = 1.0) ===");
    for model in ModelShapes::paper_models() {
        let base = sim
            .run_method(&model, Phase::decode(8), "w8a8", 128, 8)
            .energy_total();
        print!("{:<12}", model.name);
        for m in &methods {
            let r = sim.run_method(&model, Phase::decode(8), m, 128, 8);
            print!(" {:>9.3}", r.energy_total() / base);
        }
        println!();
    }
    println!("              {}", methods.map(|m| format!("{m:>9}")).join(" "));

    println!("\n=== decomposition (opt-30b, joules) ===");
    let model = ModelShapes::opt_30b();
    println!("{:<10} {:>10} {:>10} {:>10}", "method", "constant", "static", "dynamic");
    for m in &methods {
        let r = sim.run_method(&model, Phase::decode(8), m, 128, 8);
        println!(
            "{:<10} {:>10.3} {:>10.3} {:>10.3}",
            m, r.energy_constant, r.energy_static, r.energy_dynamic
        );
    }
}
