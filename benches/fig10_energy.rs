//! Bench: Fig 10 — normalized systolic energy per method, with the
//! static/dynamic × core/buffer/memory decomposition.
//! Run: `cargo bench --bench fig10_energy`

use halo::systolic::{SimConfig, Simulator};
use halo::workload::{ModelShapes, Phase};

fn main() {
    let sim = Simulator::new(SimConfig::default());
    let methods = ["fp16", "w8a8", "w4a8", "w3a8", "halo-perf", "halo-acc", "halo-bal"];

    println!("=== Fig 10: normalized energy (FP16 = 1.0) ===");
    for model in ModelShapes::paper_models() {
        let fp16 = sim
            .run_method(&model, Phase::prefill(), "fp16", 128, 8)
            .energy
            .total();
        print!("{:<12}", model.name);
        for m in &methods {
            let e = sim.run_method(&model, Phase::prefill(), m, 128, 8).energy.total();
            print!(" {:>9.3}", e / fp16);
        }
        println!();
    }
    println!("              {}", methods.map(|m| format!("{m:>9}")).join(" "));

    println!("\n=== decomposition (llama2-7b, joules) ===");
    let model = ModelShapes::llama2_7b();
    println!(
        "{:<10} {:>9} {:>9} {:>9} {:>9} {:>9} {:>9}",
        "method", "core_dyn", "core_st", "buf_dyn", "buf_st", "mem_dyn", "mem_st"
    );
    for m in &methods {
        let e = sim.run_method(&model, Phase::prefill(), m, 128, 8).energy;
        println!(
            "{:<10} {:>9.2} {:>9.2} {:>9.2} {:>9.2} {:>9.2} {:>9.2}",
            m, e.core_dynamic, e.core_static, e.buffer_dynamic, e.buffer_static,
            e.mem_dynamic, e.mem_static
        );
    }
}
