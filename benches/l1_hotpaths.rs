//! Bench: the two L1/L3 compute hot paths rebuilt in PR 2 — gate-level
//! MAC profiling (bit-sliced + parallel vs the seed scalar loop) and the
//! `SimBackend` matmul/forward kernels (blocked + parallel vs naive).
//!
//! Run: `cargo bench --bench l1_hotpaths [-- --smoke] [-- --json FILE]`
//!
//! `--smoke` shrinks every workload to a CI-sized single iteration;
//! `--json FILE` writes the measured numbers (used by `make bench-json`,
//! which produces `BENCH_PR2.json` so the perf trajectory accumulates).

use std::time::{Duration, Instant};

use halo::mac::profile::{MacProfile, DEFAULT_SAMPLES};
use halo::mac::{dynsim, mac8, sta};
use halo::quant::Matrix;
use halo::runtime::backend::Literal;
use halo::runtime::kernels::{self, naive};
use halo::runtime::sim::{model_loss, ModelSpec};
use halo::util::bench::{bench_n, fmt_dur};
use halo::util::{parallel, Json, Rng};

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let smoke = args.iter().any(|a| a == "--smoke");
    let json_path = args
        .iter()
        .position(|a| a == "--json")
        .and_then(|i| args.get(i + 1))
        .cloned();
    let mut report = Json::obj();
    report.set("bench", "l1_hotpaths").set("smoke", smoke);

    bench_profile(smoke, &mut report);
    bench_netlist_eval(smoke, &mut report);
    bench_matmul(smoke, &mut report);
    bench_forward(smoke, &mut report);

    if let Some(path) = json_path {
        std::fs::write(&path, report.to_string_pretty()).expect("write bench json");
        println!("\nwrote {path}");
    }
}

/// MacProfile::compute: pre-PR serial scalar loop vs the bit-sliced +
/// parallel rebuild (plus the disk-cache hit path).
fn bench_profile(smoke: bool, report: &mut Json) {
    println!("=== MacProfile::compute (cold) ===");
    let samples = if smoke { 32 } else { DEFAULT_SAMPLES };
    let seed = 0x4A10u64;
    let (net, ports) = mac8::build();

    // Pre-PR baseline: the seed implementation was a serial scalar loop
    // over all 256 weights. Measure a subset and scale linearly (per-weight
    // cost is near-uniform).
    let scalar_weights: Vec<i8> = if smoke {
        vec![0, 64, -127]
    } else {
        (i8::MIN..=i8::MAX).step_by(8).collect() // 32 of 256
    };
    let t0 = Instant::now();
    for &w in &scalar_weights {
        std::hint::black_box(dynsim::weight_stats_scalar(&net, &ports, w, samples, seed));
        std::hint::black_box(sta::weight_delay(&net, &ports, w));
    }
    let scalar_est = t0.elapsed().as_secs_f64() * (256.0 / scalar_weights.len() as f64);

    let t0 = Instant::now();
    let prof = MacProfile::compute(samples, seed);
    let new_s = t0.elapsed().as_secs_f64();
    std::hint::black_box(&prof);

    // Disk-cache round trip (hit path = load + validate only).
    let dir = std::env::temp_dir().join(format!("halo_bench_profile_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    MacProfile::cached_or_compute_in(&dir, samples, seed);
    let t0 = Instant::now();
    MacProfile::cached_or_compute_in(&dir, samples, seed);
    let hit_s = t0.elapsed().as_secs_f64();
    std::fs::remove_dir_all(&dir).ok();

    let speedup = scalar_est / new_s.max(1e-12);
    println!(
        "profile/compute ({samples} samples/weight): {} \
         (pre-PR scalar est {}, speedup {speedup:.1}x; cache hit {})",
        fmt_dur(Duration::from_secs_f64(new_s)),
        fmt_dur(Duration::from_secs_f64(scalar_est)),
        fmt_dur(Duration::from_secs_f64(hit_s)),
    );
    let mut j = Json::obj();
    j.set("samples", samples)
        .set("scalar_est_s", scalar_est)
        .set("bitsliced_parallel_s", new_s)
        .set("speedup", speedup)
        .set("cache_hit_s", hit_s);
    report.set("mac_profile_compute", j);
}

/// Raw netlist evaluation throughput: 64 scalar passes vs one bit-sliced
/// pass over the same 64 assignments.
fn bench_netlist_eval(smoke: bool, report: &mut Json) {
    println!("\n=== netlist eval: 64 scalar passes vs one 64-lane pass ===");
    let (net, ports) = mac8::build();
    let mut rng = Rng::seed_from_u64(1);
    let xs: Vec<(i8, i32)> = (0..64)
        .map(|_| (rng.gen_i8(), rng.gen_range_i64(-0x400000, 0x400000) as i32))
        .collect();
    let w = -77i8;
    let iters = if smoke { 1 } else { 400 };

    let mut vals = vec![false; net.len()];
    let scalar = bench_n("netlist_eval/scalar_x64", iters, || {
        for &(a, acc) in &xs {
            mac8::set_inputs(&ports, &mut vals, w, a, acc);
            net.eval_into(&mut vals);
            std::hint::black_box(net.read_outputs(&vals));
        }
    });
    println!("{}", scalar.report());

    let mut words = vec![0u64; net.len()];
    let sliced = bench_n("netlist_eval/bitsliced_x64", iters, || {
        mac8::set_inputs64(&ports, &mut words, w, &xs);
        net.eval64_into(&mut words);
        std::hint::black_box(net.read_outputs_lane(&words, 63));
    });
    println!("{}", sliced.report());

    let speedup = scalar.mean_s() / sliced.mean_s().max(1e-12);
    println!("speedup: {speedup:.1}x");
    let mut j = Json::obj();
    j.set("scalar_x64_s", scalar.mean_s())
        .set("bitsliced_s", sliced.mean_s())
        .set("speedup", speedup);
    report.set("netlist_eval", j);
}

/// Blocked matmul kernels vs the seed naive implementations.
fn bench_matmul(smoke: bool, report: &mut Json) {
    println!("\n=== matmul kernels: blocked+parallel vs naive ===");
    let (m, k, n) = if smoke { (16, 24, 20) } else { (256, 512, 512) };
    let iters = if smoke { 1 } else { 8 };
    let mut rng = Rng::seed_from_u64(2);
    let a = Matrix::random_normal(m, k, 1.0, &mut rng);
    let b = Matrix::random_normal(k, n, 1.0, &mut rng);
    let at = Matrix::random_normal(k, m, 1.0, &mut rng);
    let bt = Matrix::random_normal(n, k, 1.0, &mut rng);

    let mut j = Json::obj();
    j.set("shape_mkn", Json::Arr(vec![(m as f64).into(), (k as f64).into(), (n as f64).into()]));
    let run = |label: &str, f_new: &dyn Fn() -> Matrix, f_old: &dyn Fn() -> Matrix| {
        let old = bench_n(&format!("matmul/{label}/naive"), iters, || {
            std::hint::black_box(f_old());
        });
        let new = bench_n(&format!("matmul/{label}/blocked"), iters, || {
            std::hint::black_box(f_new());
        });
        println!("{}", old.report());
        println!("{}", new.report());
        let speedup = old.mean_s() / new.mean_s().max(1e-12);
        println!("speedup: {speedup:.1}x");
        let mut e = Json::obj();
        e.set("naive_s", old.mean_s())
            .set("blocked_s", new.mean_s())
            .set("speedup", speedup);
        e
    };
    let nn = run("nn", &|| kernels::matmul(&a, &b), &|| naive::matmul(&a, &b));
    j.set("nn", nn);
    let tn = run("tn", &|| kernels::matmul_tn(&at, &b), &|| naive::matmul_tn(&at, &b));
    j.set("tn", tn);
    let nt = run("nt", &|| kernels::matmul_nt(&a, &bt), &|| naive::matmul_nt(&a, &bt));
    j.set("nt", nt);
    report.set("matmul", j);
}

/// End-to-end `SimBackend` forward pass (NLL graph) — pre-PR configuration
/// (naive kernels, single thread) vs the rebuilt path.
fn bench_forward(smoke: bool, report: &mut Json) {
    println!("\n=== SimBackend forward pass (nll graph) ===");
    let spec = bench_spec(smoke);
    let inputs = bench_inputs(&spec, 3);
    let refs: Vec<&Literal> = inputs.iter().collect();
    let iters = if smoke { 1 } else { 5 };

    kernels::set_force_naive(true);
    parallel::set_max_threads(1);
    let old = bench_n("forward/pre_pr(naive,1thread)", iters, || {
        std::hint::black_box(model_loss(&spec, &refs, false).unwrap());
    });
    kernels::set_force_naive(false);
    parallel::set_max_threads(0);
    println!("{}", old.report());

    let new = bench_n("forward/blocked_parallel", iters, || {
        std::hint::black_box(model_loss(&spec, &refs, false).unwrap());
    });
    println!("{}", new.report());

    let speedup = old.mean_s() / new.mean_s().max(1e-12);
    println!("speedup: {speedup:.1}x");
    let mut j = Json::obj();
    j.set("d_model", spec.d_model)
        .set("n_layers", spec.n_layers)
        .set("seq_len", spec.seq_len)
        .set("naive_serial_s", old.mean_s())
        .set("blocked_parallel_s", new.mean_s())
        .set("speedup", speedup);
    report.set("forward_pass", j);
}

/// Synthetic model spec for the forward bench (bigger than the unit-test
/// tiny model so the kernels see realistic GEMM shapes).
fn bench_spec(smoke: bool) -> ModelSpec {
    let (v, d, ff, s, layers, heads) = if smoke {
        (64usize, 32usize, 64usize, 8usize, 1usize, 2usize)
    } else {
        (512, 256, 1024, 64, 2, 4)
    };
    let mut names = Vec::new();
    let mut shapes = Vec::new();
    let mut linear = Vec::new();
    let mut push = |n: String, sh: Vec<usize>, lin: bool| {
        names.push(n);
        shapes.push(sh);
        linear.push(lin);
    };
    push("embed".into(), vec![v, d], false);
    push("pos_embed".into(), vec![s, d], false);
    for l in 0..layers {
        push(format!("layer{l}.ln1.scale"), vec![d], false);
        push(format!("layer{l}.ln1.bias"), vec![d], false);
        push(format!("layer{l}.attn.wq"), vec![d, d], true);
        push(format!("layer{l}.attn.wk"), vec![d, d], true);
        push(format!("layer{l}.attn.wv"), vec![d, d], true);
        push(format!("layer{l}.attn.wo"), vec![d, d], true);
        push(format!("layer{l}.ln2.scale"), vec![d], false);
        push(format!("layer{l}.ln2.bias"), vec![d], false);
        push(format!("layer{l}.mlp.w1"), vec![d, ff], true);
        push(format!("layer{l}.mlp.b1"), vec![ff], false);
        push(format!("layer{l}.mlp.w2"), vec![ff, d], true);
        push(format!("layer{l}.mlp.b2"), vec![d], false);
    }
    push("ln_f.scale".into(), vec![d], false);
    push("ln_f.bias".into(), vec![d], false);
    push("head".into(), vec![d, v], true);
    ModelSpec {
        vocab: v,
        d_model: d,
        n_layers: layers,
        n_heads: heads,
        d_ff: ff,
        seq_len: s,
        names,
        shapes,
        linear,
    }
}

fn bench_inputs(spec: &ModelSpec, seed: u64) -> Vec<Literal> {
    let mut rng = Rng::seed_from_u64(seed);
    let mut out = Vec::new();
    for (name, shape) in spec.names.iter().zip(&spec.shapes) {
        let numel: usize = shape.iter().product();
        let data: Vec<f32> = if name.ends_with(".scale") {
            vec![1.0; numel]
        } else if name.ends_with(".bias") || name.ends_with(".b1") || name.ends_with(".b2") {
            vec![0.0; numel]
        } else {
            let std = 1.0 / (shape[0] as f32).sqrt();
            (0..numel).map(|_| rng.gen_normal() as f32 * std).collect()
        };
        out.push(Literal::f32(&data, shape).unwrap());
    }
    let (b, s) = (2usize, spec.seq_len);
    let toks: Vec<i32> = (0..b * (s + 1))
        .map(|_| rng.gen_usize(spec.vocab) as i32)
        .collect();
    out.push(Literal::i32(&toks, &[b, s + 1]).unwrap());
    out
}
