//! Tile-size ablation (paper §IV-D, Fig 11 + Table II tile rows).
//!
//! Sweeps HALO-bal over 128/64/32 tiles on (a) the systolic simulator at
//! paper scale and (b) the real trained model's accuracy when artifacts
//! exist.
//!
//! Run: `cargo run --release --example tile_sweep -- [--model small]`
//!
//! Expected output: per-model speedup ratios where smaller tiles are
//! faster (t32 > t64 > t128 ≡ 1.0x, Fig 11's shape), then — artifacts
//! permitting — a perplexity-vs-tile table where smaller tiles cost a bit
//! of accuracy at lower B_eff; otherwise a clean skip message pointing at
//! `make artifacts`.

use std::collections::BTreeMap;

use halo::mac::MacProfile;
use halo::model::{calibrate_fisher, Evaluator};
use halo::quant::{HaloConfig, HaloQuantizer, LayerCtx, Quantizer, Variant};
use halo::runtime::{Runtime, Store};
use halo::systolic::{SimConfig, Simulator};
use halo::util::cli::Args;
use halo::workload::{ModelShapes, Phase};

fn main() -> halo::Result<()> {
    let args = Args::from_env();
    let profile = MacProfile::cached();

    println!("== systolic performance vs tile size (Fig 11, HALO-bal) ==");
    let sim = Simulator::new(SimConfig::default());
    for model in ModelShapes::paper_models() {
        let t128 = sim.run_method(&model, Phase::prefill(), "halo-bal", 128, 9).time_s;
        print!("{:<12}", model.name);
        for tile in [128usize, 64, 32] {
            let t = sim.run_method(&model, Phase::prefill(), "halo-bal", tile, 9).time_s;
            print!("  t{tile}: {:.3}x", t128 / t);
        }
        println!();
    }

    // Accuracy sweep on a real model (when artifacts are present).
    let Ok(store) = Store::open_default() else {
        println!("\n(no artifacts — skipping accuracy sweep; run `make artifacts`)");
        return Ok(());
    };
    let model_name = args.str_or("model", "small").to_string();
    println!("\n== accuracy vs tile size on `{model_name}` (Table II bottom rows) ==");
    let rt = Runtime::cpu()?;
    let model = store.model(&model_name)?;
    let calib = store.corpus_calib()?;
    let grads = calibrate_fisher(&rt, &model, &calib, 3)?;
    let ev = Evaluator::new(&rt, &model)?;
    let stream = store.corpus_eval("wikisyn")?;

    let (nll_fp, _) = ev.mean_nll(&BTreeMap::new(), &stream, false, 8)?;
    println!("fp16 ppl: {:.2}", nll_fp.exp());
    for tile in [128usize, 64, 32] {
        let q = HaloQuantizer::new(HaloConfig::new(tile, Variant::Bal), profile);
        let mut replace = BTreeMap::new();
        let mut bits = 0.0;
        let mut total = 0.0;
        for p in model.linear_params() {
            let w = p.as_matrix()?;
            let ctx = match grads.get(&p.name) {
                Some(g) => LayerCtx::with_grad(&p.name, g),
                None => LayerCtx::new(&p.name),
            };
            let res = q.quantize(&w, &ctx);
            bits += res.bits_eff * w.numel() as f64;
            total += w.numel() as f64;
            replace.insert(p.name.clone(), res.dequant);
        }
        let (nll, _) = ev.mean_nll(&replace, &stream, true, 8)?;
        println!(
            "halo-bal tile={tile:<4} ppl: {:.2} (Δ {:+.2}), B_eff {:.2}",
            nll.exp(),
            nll.exp() - nll_fp.exp(),
            bits / total
        );
    }
    Ok(())
}
