//! MAC circuit exploration: regenerates the data behind Figs 3, 4 and 5.
//!
//! Prints (a) the full 256-entry per-weight frequency/power profile with an
//! ASCII rendering of Fig 4's peaks, and (b) Fig 3's settle-time histograms
//! for the paper's example pair (w = 64 vs w = −127).
//!
//! Run: `cargo run --release --example mac_explorer [-- --samples 4096]`
//!
//! Expected output: an ASCII bar chart of achievable GHz across all 256
//! weight values (Booth-sparse values like 0/±64 peak, dense values like
//! −127 trough), a power-ordering sample (toggles/energy grow with Booth
//! digits), per-weight settle histograms for w=64 vs w=−127 (the latter
//! wider and slower), and the derived fast/med/base class summary line.

use halo::mac::{booth, profile::delay_histogram_ps, MacProfile};
use halo::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let samples = args.usize_or("samples", 2048).unwrap();
    let profile = MacProfile::cached();

    println!("== Fig 4: achievable frequency per weight value ==");
    let fmax = profile
        .freq_ghz
        .iter()
        .cloned()
        .filter(|f| f.is_finite())
        .fold(0.0, f64::max);
    for w in (i8::MIN..=i8::MAX).step_by(4) {
        let f = profile.freq_of(w).min(fmax);
        let bar = "#".repeat((f / fmax * 50.0) as usize);
        println!(
            "{w:>5} | {bar:<50} {f:.2} GHz ({} booth digits)",
            booth::nonzero_digits(w)
        );
    }

    println!("\n== Fig 5: power ordering (sample) ==");
    for w in [0i8, 64, 16, -16, 1, -1, 2, 85, -86, -127, 127] {
        println!(
            "w={w:>5}: mean toggles {:>6.1}, dyn energy {:.3} pJ/op",
            profile.toggles_of(w),
            profile.energy_of(w)
        );
    }

    println!("\n== Fig 3: delay histograms across activation transitions ==");
    for w in [64i8, -127] {
        println!("-- weight {w} --");
        let hist = delay_histogram_ps(w, samples, 3);
        let max_count = hist.iter().map(|&(_, c)| c).max().unwrap_or(1);
        for (ps, count) in hist {
            let bar = "*".repeat((count as f64 / max_count as f64 * 40.0) as usize);
            println!("{ps:7.0} ps | {bar:<40} {count}");
        }
        println!(
            "max delay {:.0} ps -> achievable {:.2} GHz\n",
            profile.delay_of(w),
            profile.freq_of(w)
        );
    }

    println!(
        "derived classes: fast {:?} @ {:.2} GHz | med (16) @ {:.2} GHz | base @ {:.2} GHz",
        profile.codebook_fast, profile.f_fast_ghz, profile.f_med_ghz, profile.f_base_ghz
    );
    println!("(paper Table I clocks these classes at 3.7 / 2.4 / 1.9 GHz — see DESIGN.md)");
}
