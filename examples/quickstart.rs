//! Quickstart: the whole HALO idea in one file, no artifacts needed.
//!
//! 1. Build the MAC circuit profile (gate-level Booth–Wallace model).
//! 2. Quantize a synthetic weight matrix with HALO and every baseline.
//! 3. Compare reconstruction error, effective bits, achievable clocks.
//! 4. Simulate a LLaMA2-7B prefill on the systolic array per method.
//!
//! Run: `cargo run --release --example quickstart`
//!
//! Expected output: four sections — the derived 9/16-value codebooks with
//! their GHz classes, a per-method table (bits / rel-err / fast-med-base
//! tile counts / sparse nnz) where HALO variants land between W8 and W3
//! error at < 5 effective bits, and a Fig 8-shaped simulation table where
//! `halo-*` beats every uniform baseline vs fp16 (≈3–5x). Exits 0; first
//! run computes the MAC profile (~seconds), repeats hit the disk cache.

use halo::mac::MacProfile;
use halo::quant::baselines::by_name;
use halo::quant::{LayerCtx, Matrix};
use halo::systolic::{SimConfig, Simulator};
use halo::util::Rng;
use halo::workload::{ModelShapes, Phase};

fn main() -> halo::Result<()> {
    println!("== 1. MAC circuit profile (paper §II) ==");
    let profile = MacProfile::cached();
    println!(
        "fast codebook (9 values, ≤{:.0} ps): {:?}",
        1000.0 / profile.f_fast_ghz,
        profile.codebook_fast
    );
    println!(
        "med codebook (16 values, ≤{:.0} ps): {:?}",
        1000.0 / profile.f_med_ghz,
        profile.codebook_med
    );
    println!(
        "full int8 range worst case: {:.0} ps → {:.1} GHz (Table I base)\n",
        1000.0 / profile.f_base_ghz,
        profile.f_base_ghz
    );

    println!("== 2+3. quantize one 256x256 layer with every method ==");
    let mut rng = Rng::seed_from_u64(1);
    let w = Matrix::random_normal(256, 256, 0.02, &mut rng);
    // A gradient field with one very sensitive tile-row band.
    let g = Matrix::from_fn(256, 256, |r, _| {
        let x = rng.gen_normal() as f32;
        if r < 64 { x } else { x * 0.05 }
    });
    println!(
        "{:<18} {:>8} {:>8} {:>22} {:>8}",
        "method", "bits", "rel-err", "tiles fast/med/base", "sparse"
    );
    for method in ["fp16", "w8a8", "w4a8", "w3a8", "gptq", "zq-local",
                   "halo-perf", "halo-acc", "halo-bal"] {
        let q = by_name(method, profile, 64).unwrap();
        let res = q.quantize(&w, &LayerCtx::with_grad("demo", &g));
        let (f, m, b) = res.class_counts(profile);
        println!(
            "{:<18} {:>8.2} {:>8.4} {:>22} {:>8}",
            res.method,
            res.bits_eff,
            res.dequant.mse(&w).sqrt() / w.std(),
            format!("{f}/{m}/{b}"),
            res.sparse_nnz
        );
    }

    println!("\n== 4. systolic-array simulation: LLaMA2-7B prefill (Fig 8) ==");
    let sim = Simulator::new(SimConfig::default());
    let model = ModelShapes::llama2_7b();
    let fp16 = sim.run_method(&model, Phase::prefill(), "fp16", 128, 7).time_s;
    println!("{:<12} {:>10} {:>10} {:>12}", "method", "time", "vs fp16", "energy (J)");
    for method in ["fp16", "w8a8", "w4a8", "w3a8", "halo-perf", "halo-acc", "halo-bal"] {
        let r = sim.run_method(&model, Phase::prefill(), method, 128, 7);
        println!(
            "{:<12} {:>8.1}ms {:>9.2}x {:>12.1}",
            method,
            r.time_s * 1e3,
            fp16 / r.time_s,
            r.energy.total()
        );
    }
    Ok(())
}
