//! Fig 9 — normalized performance vs perplexity knee + the Pareto-front
//! optimizer (paper Fig 1: "a set of Pareto-optimal quantized models").
//!
//! Enumerates (variant × tile) candidates, scores latency/energy with the
//! systolic simulator and accuracy with the real trained model (when
//! artifacts exist; otherwise weight-MSE on synthetic layers), then prints
//! the Pareto front and marks the knee.
//!
//! Run: `cargo run --release --example pareto_sweep -- [--model small]`
//!
//! Expected output: a 9-row candidate table (3 variants × tiles 128/64/32
//! with time/energy/accuracy columns), the surviving Pareto front (typically
//! 3–6 rows; perf-opt fastest, acc-opt most accurate), and a final
//! `knee (balanced goals): bal tile ...` line. Runs without artifacts
//! (falls back to weight-MSE as the accuracy proxy).

use std::collections::BTreeMap;

use halo::dvfs::optimizer::{pareto_front, select, Candidate};
use halo::mac::MacProfile;
use halo::model::{calibrate_fisher, Evaluator};
use halo::quant::{HaloConfig, HaloQuantizer, LayerCtx, Quantizer, Variant};
use halo::runtime::{Runtime, Store};
use halo::systolic::{SimConfig, Simulator};
use halo::util::cli::Args;
use halo::workload::{ModelShapes, Phase};

fn main() -> halo::Result<()> {
    let args = Args::from_env();
    let profile = MacProfile::cached();
    let sim = Simulator::new(SimConfig::default());
    let shapes = ModelShapes::llama2_7b();

    // Accuracy scorer: real perplexity if artifacts exist.
    let real = Store::open_default().ok().and_then(|store| {
        let model_name = args.str_or("model", "small").to_string();
        let rt = Runtime::cpu().ok()?;
        let model = store.model(&model_name).ok()?;
        let calib = store.corpus_calib().ok()?;
        let grads = calibrate_fisher(&rt, &model, &calib, 3).ok()?;
        let stream = store.corpus_eval("wikisyn").ok()?;
        Some((store, rt, model, grads, stream, model_name))
    });

    let mut candidates = Vec::new();
    for variant in [Variant::PerfOpt, Variant::Bal, Variant::AccOpt] {
        for tile in [128usize, 64, 32] {
            let method = match variant {
                Variant::PerfOpt => "halo-perf",
                Variant::Bal => "halo-bal",
                Variant::AccOpt => "halo-acc",
            };
            let r = sim.run_method(&shapes, Phase::prefill(), method, tile, 11);

            let accuracy_cost = match &real {
                Some((_, rt, model, grads, stream, _)) => {
                    let ev = Evaluator::new(rt, model)?;
                    let q = HaloQuantizer::new(HaloConfig::new(tile, variant), profile);
                    let mut replace = BTreeMap::new();
                    for p in model.linear_params() {
                        let w = p.as_matrix()?;
                        let ctx = match grads.get(&p.name) {
                            Some(g) => LayerCtx::with_grad(&p.name, g),
                            None => LayerCtx::new(&p.name),
                        };
                        replace.insert(p.name.clone(), q.quantize(&w, &ctx).dequant);
                    }
                    let (nll, _) = ev.mean_nll(&replace, stream, true, 6)?;
                    nll.exp()
                }
                None => {
                    // Synthetic fallback: weight reconstruction MSE.
                    let mut rng = halo::util::Rng::seed_from_u64(5);
                    let w = halo::quant::Matrix::random_normal(256, 256, 0.02, &mut rng);
                    let g = halo::quant::Matrix::random_normal(256, 256, 1.0, &mut rng);
                    let q = HaloQuantizer::new(HaloConfig::new(tile, variant), profile);
                    q.quantize(&w, &LayerCtx::with_grad("syn", &g)).dequant.mse(&w)
                }
            };
            candidates.push(Candidate {
                variant,
                tile,
                time_s: r.time_s,
                energy_j: r.energy.total(),
                accuracy_cost,
            });
        }
    }

    println!("== all candidates (Fig 9 scatter) ==");
    println!(
        "{:<10} {:>5} {:>10} {:>10} {:>10}",
        "variant", "tile", "time", "energy", "ppl/mse"
    );
    for c in &candidates {
        println!(
            "{:<10} {:>5} {:>8.1}ms {:>9.1}J {:>10.3}",
            c.variant.name(),
            c.tile,
            c.time_s * 1e3,
            c.energy_j,
            c.accuracy_cost
        );
    }

    let front = pareto_front(&candidates);
    println!("\n== Pareto front ({} of {}) ==", front.len(), candidates.len());
    for c in &front {
        println!("{:<10} tile {:<4} — kept", c.variant.name(), c.tile);
    }

    let knee = select(&front, 1.0, 0.5, 1.0).expect("non-empty front");
    println!(
        "\nknee (balanced goals): {} tile {} — the paper's `bal` recommendation",
        knee.variant.name(),
        knee.tile
    );
    Ok(())
}
