//! END-TO-END driver (DESIGN.md §End-to-end validation): load a real
//! trained model from `artifacts/`, HALO-quantize it with Fisher
//! calibration through the PJRT grad graph, measure perplexity before and
//! after on both corpora, then serve batched next-token requests through
//! the L3 coordinator and report latency/throughput.
//!
//! Requires `make artifacts`. Run:
//!   cargo run --release --example serve_llm -- [--model base] [--requests 128]
//!
//! Expected output: model stats, Fisher-calibration timing, a quantization
//! summary (B_eff ≈ 3.5–4.5 bits, ≤ 3 DVFS transitions/pass), per-corpus
//! perplexity before/after (small Δ for halo-bal), then
//! `served N requests in X.XXs = Y req/s` and a final `serve_llm OK`.
//! Errors out with a `make artifacts` hint when the store is missing.

use std::collections::BTreeMap;
use std::time::Instant;

use halo::coordinator::server::GraphExecutor;
use halo::coordinator::{Coordinator, CoordinatorConfig, Request};
use halo::dvfs::Schedule;
use halo::mac::MacProfile;
use halo::model::{calibrate_fisher, Evaluator};
use halo::quant::{HaloConfig, HaloQuantizer, LayerCtx, Quantizer, Variant};
use halo::runtime::{Runtime, Store};
use halo::util::cli::Args;

fn main() -> halo::Result<()> {
    let args = Args::from_env();
    let model_name = args.str_or("model", "base").to_string();
    let n_requests = args.usize_or("requests", 128)?;
    let max_batches = args.usize_or("max-batches", 12)?;

    let store = Store::open_default()?;
    let rt = Runtime::cpu()?;
    let model = store.model(&model_name)?;
    println!(
        "model {model_name}: {} params, vocab {}, seq {}",
        model.n_weights(),
        model.vocab,
        model.seq_len
    );

    // --- Fisher calibration (paper Eq. 1) through the grad graph ---
    let t0 = Instant::now();
    let calib = store.corpus_calib()?;
    let grads = calibrate_fisher(&rt, &model, &calib, 4)?;
    println!("fisher calibration: {:.1}s ({} tensors)", t0.elapsed().as_secs_f64(), grads.len());

    // --- quantize (HALO-bal, tile 128) ---
    let profile = MacProfile::cached();
    let q = HaloQuantizer::new(HaloConfig::new(128, Variant::Bal), profile);
    let t0 = Instant::now();
    let mut replace = BTreeMap::new();
    let mut classes = Vec::new();
    let mut bits = 0.0;
    let mut total = 0.0;
    for p in model.linear_params() {
        let w = p.as_matrix()?;
        let ctx = match grads.get(&p.name) {
            Some(g) => LayerCtx::with_grad(&p.name, g),
            None => LayerCtx::new(&p.name),
        };
        let res = q.quantize(&w, &ctx);
        for &f in &res.tile_freq_ghz {
            classes.push(halo::dvfs::classify(f, profile));
        }
        bits += res.bits_eff * w.numel() as f64;
        total += w.numel() as f64;
        replace.insert(p.name.clone(), res.dequant);
    }
    let schedule = Schedule::cluster(&classes);
    println!(
        "quantized in {:.1}s: B_eff {:.2} bits, {} tiles, {} DVFS transitions/pass",
        t0.elapsed().as_secs_f64(),
        bits / total,
        classes.len(),
        schedule.transitions()
    );

    // --- accuracy before/after (Table II cells for this model) ---
    let ev = Evaluator::new(&rt, &model)?;
    for corpus in ["wikisyn", "c4syn"] {
        let stream = store.corpus_eval(corpus)?;
        let (nll_fp, _) = ev.mean_nll(&BTreeMap::new(), &stream, false, max_batches)?;
        let (nll_halo, n) = ev.mean_nll(&replace, &stream, true, max_batches)?;
        println!(
            "{corpus}: ppl fp16 {:.2} → halo-bal {:.2} (Δ {:+.2}, {} batches)",
            nll_fp.exp(),
            nll_halo.exp(),
            nll_halo.exp() - nll_fp.exp(),
            n
        );
    }

    // --- serve batched requests through the coordinator ---
    let root = store.root.clone();
    let model_name2 = model_name.clone();
    let replace2 = replace.clone();
    let schedule2 = schedule.clone();
    let coord = Coordinator::start(CoordinatorConfig::default(), move |_shard| {
        let rt = Runtime::cpu()?;
        let store = Store::open(root.clone())?;
        let model = store.model(&model_name2)?;
        let exec = GraphExecutor::new(rt, &model, &replace2, schedule2.clone())?;
        Ok(Box::new(exec) as Box<dyn halo::coordinator::BatchExecutor>)
    });

    let stream = store.corpus_eval("wikisyn")?;
    let t0 = Instant::now();
    let mut rxs = Vec::new();
    for i in 0..n_requests {
        let start = (i * 61) % (stream.len() - 64);
        let prefix: Vec<i32> =
            stream[start..start + 48].iter().map(|&t| t as i32).collect();
        rxs.push(coord.submit_or_shed(Request::new(prefix)));
    }
    for rx in rxs {
        let r = rx.recv()?;
        assert!((0..model.vocab as i32).contains(&r.next_token));
    }
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "served {n_requests} requests in {wall:.2}s = {:.1} req/s; {}",
        n_requests as f64 / wall,
        coord.metrics.summary()
    );
    coord.shutdown()?;
    println!("serve_llm OK");
    Ok(())
}
