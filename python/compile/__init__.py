"""Build-time Python for HALO: L1 Pallas kernels, L2 JAX model, AOT lowering.

Never imported at runtime — the Rust binary consumes only ``artifacts/``.
"""
