"""Synthetic corpora standing in for WikiText-2 and C4 (DESIGN.md §Subst.).

Each corpus is a Zipfian-bigram Markov chain over a 512-token vocabulary:
row t of the transition matrix is a Zipf(s) distribution over a
seed-deterministic permutation of the vocabulary. The two corpora differ in
skew (entropy): ``wikisyn`` is peakier (curated text), ``c4syn`` flatter
(noisy web crawl), giving the same "C4 perplexity > WikiText perplexity"
ordering the paper's Table II shows for every model.

Everything is deterministic in (name, seed) so `make artifacts` is
reproducible and Rust-side evaluation sees the exact same token streams.
"""

from __future__ import annotations

import numpy as np

VOCAB = 256

# name -> (zipf skew, permutation seed)
SPECS = {
    "wikisyn": (1.45, 101),
    "c4syn": (1.15, 202),
}

# Mixture weight of the global (unigram) component: every next-token
# distribution is  (1-MIX)·bigram_row + MIX·unigram. The unigram part is
# learnable within a few hundred steps (like natural-language frequency
# structure); the bigram part rewards model capacity (like syntax).
UNIGRAM_MIX = 0.45


def transition_matrix(name: str) -> np.ndarray:
    """(VOCAB, VOCAB) row-stochastic next-token matrix (bigram + unigram)."""
    skew, seed = SPECS[name]
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, VOCAB + 1, dtype=np.float64)
    base = ranks ** (-skew)
    base /= base.sum()
    unigram = base[rng.permutation(VOCAB)]
    mat = np.empty((VOCAB, VOCAB), np.float64)
    for t in range(VOCAB):
        perm = rng.permutation(VOCAB)
        mat[t, perm] = base
    return (1.0 - UNIGRAM_MIX) * mat + UNIGRAM_MIX * unigram[None, :]


def generate(name: str, n_tokens: int, seed: int) -> np.ndarray:
    """Sample a (n_tokens,) uint16 stream from the corpus chain."""
    mat = transition_matrix(name)
    cum = np.cumsum(mat, axis=1)
    rng = np.random.default_rng(seed)
    u = rng.random(n_tokens)
    out = np.empty(n_tokens, np.uint16)
    t = int(rng.integers(VOCAB))
    for i in range(n_tokens):
        t = int(np.searchsorted(cum[t], u[i]))
        if t >= VOCAB:  # guard fp edge
            t = VOCAB - 1
        out[i] = t
    return out


def entropy_bits(name: str) -> float:
    """Per-token conditional entropy (bits) — the perplexity floor is 2^H."""
    mat = transition_matrix(name)
    # Stationary distribution ~ uniform by symmetry of the construction.
    h = -(mat * np.log2(np.maximum(mat, 1e-300))).sum(axis=1)
    return float(h.mean())


def batches(stream: np.ndarray, batch: int, seq: int) -> np.ndarray:
    """Reshape a token stream into (n_batches, batch, seq) dropping the tail."""
    per = batch * seq
    n = len(stream) // per
    return stream[: n * per].reshape(n, batch, seq).astype(np.int32)
