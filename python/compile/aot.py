"""AOT pipeline: train the tiny LMs, lower every graph to HLO *text*.

Run via ``make artifacts`` (``python -m compile.aot --out ../artifacts``).
Python appears ONLY here; after this runs, the Rust binary is self-contained.

Interchange format is HLO **text**, not serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids that the `xla` crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Artifacts layout (consumed by rust/src/runtime/artifacts.rs):

    artifacts/
      manifest.json                  global index
      corpora/<name>_{eval,calib}.u16.bin
      models/<name>/
        config.json                  model + param table (name/shape/offset)
        params.f32.bin               trained weights, canonical order
        train_log.json
        nll_fp.hlo.txt               (params..., tokens(B,S+1)) -> mean NLL
        nll_a8.hlo.txt               same, A8 fake-quant activations
        fwd_fp.hlo.txt               (params..., tokens(B,S)) -> logits
        grad.hlo.txt                 (params..., tokens) -> (loss, dW_linear...)
      models/base/fwd_halo.hlo.txt   true HALO path (L1 Pallas kernels inside)
      kernels/halo_matmul.hlo.txt    standalone kernel for runtime microbench
      kernels/spmv.hlo.txt
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import time
from pathlib import Path
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import corpus, model, train
from .kernels import halo_matmul as hm
from .kernels import spmv as sp

EVAL_BATCH = 8
EVAL_TOKENS = 96_000  # per corpus; ~ 93 batches of 8x129
CALIB_TOKENS = 16_000
HALO_TILE = 128
SPARSE_FRAC = 0.005  # 0.5% outliers+salient, padded up (paper §III-A)
SPARSE_PAD = 256

# steps per model (HALO_FAST=1 cuts everything down for CI)
TRAIN_STEPS = {"tiny": 400, "small": 400, "base": 450, "large": 400}


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _cfg_digest(cfg: model.Config, steps: int) -> str:
    blob = json.dumps({**cfg.__dict__, "steps": steps}, sort_keys=True)
    return hashlib.sha256(blob.encode()).hexdigest()[:16]


def dump_params(cfg: model.Config, params: Dict[str, jnp.ndarray], mdir: Path,
                steps: int) -> List[dict]:
    """Write params.f32.bin + the param table; returns the table."""
    table, off = [], 0
    with open(mdir / "params.f32.bin", "wb") as f:
        for name, shape, is_lin in model.param_specs(cfg):
            arr = np.asarray(params[name], np.float32)
            assert arr.shape == tuple(shape), (name, arr.shape, shape)
            f.write(arr.tobytes())
            table.append(
                {
                    "name": name,
                    "shape": list(shape),
                    "offset": off,
                    "numel": int(arr.size),
                    "linear": is_lin,
                }
            )
            off += arr.size
    (mdir / "config.json").write_text(
        json.dumps(
            {
                "config": cfg.__dict__,
                "digest": _cfg_digest(cfg, steps),
                "n_params": int(off),
                "eval_batch": EVAL_BATCH,
                "params": table,
            },
            indent=1,
        )
    )
    return table


def load_cached(cfg: model.Config, mdir: Path, steps: int):
    """Reload trained params if config.json digest matches (skip training)."""
    cj = mdir / "config.json"
    pb = mdir / "params.f32.bin"
    if not (cj.exists() and pb.exists()):
        return None
    meta = json.loads(cj.read_text())
    if meta.get("digest") != _cfg_digest(cfg, steps):
        return None
    flat = np.fromfile(pb, np.float32)
    out = {}
    for e in meta["params"]:
        out[e["name"]] = jnp.asarray(
            flat[e["offset"] : e["offset"] + e["numel"]].reshape(e["shape"])
        )
    return out


def lower_model_graphs(cfg: model.Config, mdir: Path) -> None:
    names = model.param_names(cfg)
    b, s = EVAL_BATCH, cfg.seq_len

    def as_dict(ptuple):
        return dict(zip(names, ptuple))

    def nll_fp(ptuple, tokens):
        return (model.loss_fn(cfg, as_dict(ptuple), tokens),)

    def nll_a8(ptuple, tokens):
        return (model.loss_fn(cfg, as_dict(ptuple), tokens, fwd=model.forward_a8),)

    def fwd_fp(ptuple, tokens):
        return (model.forward_fp(cfg, as_dict(ptuple), tokens),)

    def grad(ptuple, tokens):
        loss, grads = model.grad_linear_fn(cfg, as_dict(ptuple), tokens)
        return (loss,) + tuple(grads)

    pspecs = tuple(
        jax.ShapeDtypeStruct(shape, jnp.float32)
        for _, shape, _ in model.param_specs(cfg)
    )
    tok_nll = jax.ShapeDtypeStruct((b, s + 1), jnp.int32)
    tok_fwd = jax.ShapeDtypeStruct((b, s), jnp.int32)

    for fname, fn, tok in [
        ("nll_fp", nll_fp, tok_nll),
        ("nll_a8", nll_a8, tok_nll),
        ("fwd_fp", fwd_fp, tok_fwd),
        ("grad", grad, tok_nll),
    ]:
        t0 = time.time()
        text = to_hlo_text(jax.jit(fn).lower(pspecs, tok))
        (mdir / f"{fname}.hlo.txt").write_text(text)
        print(f"  lowered {cfg.name}/{fname}: {len(text)/1e6:.2f} MB "
              f"({time.time()-t0:.1f}s)", flush=True)


def sparse_pad_len(k: int, n: int) -> int:
    raw = int(np.ceil(k * n * SPARSE_FRAC))
    return int(np.ceil(raw / SPARSE_PAD) * SPARSE_PAD)


def lower_halo_graph(cfg: model.Config, mdir: Path) -> None:
    """Lower the true-HALO forward (L1 Pallas kernels inside the graph)."""
    names = model.param_names(cfg)
    lin = set(model.linear_weight_names(cfg))
    b, s, t = EVAL_BATCH, cfg.seq_len, HALO_TILE

    # HLO parameter layout: non-linear params (canonical order), then per
    # linear weight (canonical order): idx, codebook, scales, sp_val, sp_pos,
    # then tokens. Recorded in manifest for the Rust side.
    rest_names = [n for n in names if n not in lin]
    lin_names = [n for n in names if n in lin]

    spec_by_name = {n: shp for n, shp, _ in model.param_specs(cfg)}
    rest_specs = tuple(
        jax.ShapeDtypeStruct(spec_by_name[n], jnp.float32) for n in rest_names
    )
    qspecs = []
    qlayout = []
    for n in lin_names:
        k, nn = spec_by_name[n]
        nnz = sparse_pad_len(k, nn)
        qspecs.append(
            dict(
                idx=jax.ShapeDtypeStruct((k, nn), jnp.int8),
                codebook=jax.ShapeDtypeStruct((16,), jnp.float32),
                scales=jax.ShapeDtypeStruct((k // t, nn // t), jnp.float32),
                sp_val=jax.ShapeDtypeStruct((nnz,), jnp.float32),
                sp_pos=jax.ShapeDtypeStruct((nnz,), jnp.int32),
            )
        )
        qlayout.append({"name": n, "k": k, "n": nn, "nnz": nnz})

    def fwd_halo(rest_tuple, qtuple, tokens):
        params = dict(zip(rest_names, rest_tuple))
        qparams = dict(zip(lin_names, qtuple))
        return (model.forward_halo(cfg, params, qparams, tokens, tile=t),)

    tok = jax.ShapeDtypeStruct((b, s), jnp.int32)
    t0 = time.time()
    text = to_hlo_text(jax.jit(fwd_halo).lower(rest_specs, tuple(qspecs), tok))
    (mdir / "fwd_halo.hlo.txt").write_text(text)
    (mdir / "fwd_halo.json").write_text(
        json.dumps(
            {"tile": t, "rest": rest_names, "linear": qlayout,
             "qfields": ["idx", "codebook", "scales", "sp_val", "sp_pos"]},
            indent=1,
        )
    )
    print(f"  lowered {cfg.name}/fwd_halo: {len(text)/1e6:.2f} MB "
          f"({time.time()-t0:.1f}s)", flush=True)


def lower_kernel_graphs(kdir: Path) -> None:
    """Standalone L1 kernels for the Rust runtime microbenches."""
    m, k, n, t = 128, 256, 1024, 128

    def hm_fn(x, idx, cb, sc):
        return (hm.halo_matmul(x, idx, cb, sc, tile=t, block_m=m),)

    text = to_hlo_text(
        jax.jit(hm_fn).lower(
            jax.ShapeDtypeStruct((m, k), jnp.float32),
            jax.ShapeDtypeStruct((k, n), jnp.int8),
            jax.ShapeDtypeStruct((16,), jnp.float32),
            jax.ShapeDtypeStruct((k // t, n // t), jnp.float32),
        )
    )
    (kdir / "halo_matmul.hlo.txt").write_text(text)

    nnz = 512

    def sp_fn(val, pos, x):
        return (sp.spmv(val, pos, x, out_dim=n),)

    text = to_hlo_text(
        jax.jit(sp_fn).lower(
            jax.ShapeDtypeStruct((nnz,), jnp.float32),
            jax.ShapeDtypeStruct((nnz,), jnp.int32),
            jax.ShapeDtypeStruct((m, k), jnp.float32),
        )
    )
    (kdir / "spmv.hlo.txt").write_text(text)
    (kdir / "kernels.json").write_text(
        json.dumps({"halo_matmul": {"m": m, "k": k, "n": n, "tile": t},
                    "spmv": {"m": m, "k": k, "n": n, "nnz": nnz}}, indent=1)
    )
    print("  lowered standalone kernels", flush=True)


def write_corpora(cdir: Path) -> dict:
    meta = {}
    for i, name in enumerate(corpus.SPECS):
        ev = corpus.generate(name, EVAL_TOKENS, seed=9000 + i)
        (cdir / f"{name}_eval.u16.bin").write_bytes(ev.tobytes())
        meta[name] = {
            "eval_tokens": int(len(ev)),
            "entropy_bits": corpus.entropy_bits(name),
        }
    # Calibration stream: the paper samples from the C4 *training* set.
    cal = corpus.generate("c4syn", CALIB_TOKENS, seed=7777)
    (cdir / "calib.u16.bin").write_bytes(cal.tobytes())
    meta["calib"] = {"tokens": int(len(cal)), "source": "c4syn"}
    return meta


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--fast", action="store_true",
                    help="tiny-only, few steps (CI smoke)")
    ap.add_argument("--models", nargs="*", default=None)
    args = ap.parse_args()
    fast = args.fast or os.environ.get("HALO_FAST") == "1"

    out = Path(args.out)
    (out / "corpora").mkdir(parents=True, exist_ok=True)
    (out / "kernels").mkdir(parents=True, exist_ok=True)

    model_names = args.models or (["tiny"] if fast else list(model.CONFIGS))
    steps = {k: (20 if fast else v) for k, v in TRAIN_STEPS.items()}

    corpora_meta = write_corpora(out / "corpora")
    print("corpora written", flush=True)

    models_meta = {}
    for name in model_names:
        cfg = model.CONFIGS[name]
        mdir = out / "models" / name
        mdir.mkdir(parents=True, exist_ok=True)
        params = load_cached(cfg, mdir, steps[name])
        if params is None:
            print(f"training {name} ({model.count_params(cfg)/1e6:.1f}M params, "
                  f"{steps[name]} steps)", flush=True)
            params, log = train.train(cfg, steps=steps[name])
            dump_params(cfg, params, mdir, steps[name])
            (mdir / "train_log.json").write_text(json.dumps(log))
        else:
            print(f"{name}: cached params reused", flush=True)
        lower_model_graphs(cfg, mdir)
        if name == "base" or (fast and name == "tiny"):
            lower_halo_graph(cfg, mdir)
        models_meta[name] = {
            "n_params": model.count_params(cfg),
            "config": cfg.__dict__,
            "train_steps": steps[name],
        }

    lower_kernel_graphs(out / "kernels")

    (out / "manifest.json").write_text(
        json.dumps(
            {
                "halo_tile": HALO_TILE,
                "sparse_frac": SPARSE_FRAC,
                "sparse_pad": SPARSE_PAD,
                "eval_batch": EVAL_BATCH,
                "vocab": corpus.VOCAB,
                "corpora": corpora_meta,
                "models": models_meta,
                "fast": fast,
            },
            indent=1,
        )
    )
    print("manifest written; artifacts complete", flush=True)


if __name__ == "__main__":
    main()
