"""Build-time training loop (Adam) for the synthetic-corpus LMs.

Runs once inside `make artifacts`; never on the request path. Checkpoints
land in artifacts/models/<name>/params.f32.bin and are reused on rebuild.
"""

from __future__ import annotations

import time
from typing import Dict, Iterator, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import corpus, model


def adam_init(params: Dict[str, jnp.ndarray]):
    zeros = {k: jnp.zeros_like(v) for k, v in params.items()}
    return {"m": zeros, "v": {k: jnp.zeros_like(v) for k, v in params.items()}, "t": jnp.zeros((), jnp.int32)}


def lr_schedule(step, total, peak=6e-3, floor=1e-3, warmup=20):
    """Linear warmup to `peak`, cosine decay to `floor`."""
    import numpy as np

    if step < warmup:
        return peak * (step + 1) / warmup
    frac = (step - warmup) / max(total - warmup, 1)
    return floor + 0.5 * (peak - floor) * (1 + np.cos(np.pi * frac))


def adam_update(params, grads, state, lr=3e-3, b1=0.9, b2=0.98, eps=1e-9):
    t = state["t"] + 1
    m = {k: b1 * state["m"][k] + (1 - b1) * grads[k] for k in params}
    v = {k: b2 * state["v"][k] + (1 - b2) * grads[k] ** 2 for k in params}
    tf = t.astype(jnp.float32)
    lr_t = lr * jnp.sqrt(1 - b2**tf) / (1 - b1**tf)
    new = {k: params[k] - lr_t * m[k] / (jnp.sqrt(v[k]) + eps) for k in params}
    return new, {"m": m, "v": v, "t": t}


def make_step(cfg: model.Config):
    @jax.jit
    def step(params, opt, tokens, lr):
        loss, grads = jax.value_and_grad(lambda p: model.loss_fn(cfg, p, tokens))(params)
        params, opt = adam_update(params, grads, opt, lr=lr)
        return params, opt, loss

    return step


def data_iter(batch: int, seq: int, seed: int) -> Iterator[np.ndarray]:
    """Alternate batches from both corpora (the 'mixed web data' trainset)."""
    streams = {n: corpus.generate(n, 600_000, seed=seed + i) for i, n in enumerate(corpus.SPECS)}
    bat = {n: corpus.batches(s, batch, seq + 1) for n, s in streams.items()}
    names = list(corpus.SPECS)
    i = 0
    while True:
        for n in names:
            yield bat[n][i % len(bat[n])]
        i += 1


def train(
    cfg: model.Config,
    steps: int,
    batch: int = 8,
    seed: int = 0,
    log_every: int = 50,
) -> Tuple[Dict[str, jnp.ndarray], list]:
    """Train ``cfg`` for ``steps`` Adam steps; returns (params, loss log)."""
    params = model.init_params(cfg, seed=seed)
    opt = adam_init(params)
    step = make_step(cfg)
    it = data_iter(batch, cfg.seq_len, seed=1234)
    log = []
    t0 = time.time()
    for s in range(steps):
        tokens = jnp.asarray(next(it))
        params, opt, loss = step(params, opt, tokens, lr_schedule(s, steps))
        if s % log_every == 0 or s == steps - 1:
            l = float(loss)
            log.append((s, l))
            print(
                f"  [{cfg.name}] step {s:4d} loss {l:6.3f} ppl {np.exp(l):8.2f} "
                f"({time.time() - t0:5.1f}s)",
                flush=True,
            )
    return params, log
