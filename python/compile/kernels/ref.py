"""Pure-jnp reference oracles for the Pallas kernels.

These are the CORE correctness signal for Layer 1: every Pallas kernel in
this package must match its oracle here to float tolerance (pytest +
hypothesis sweeps in ``python/tests/``). They are also the semantic spec the
Rust quantizer re-implements (``rust/src/quant``), so the three layers agree
on what "HALO quantized matmul" means.
"""

from __future__ import annotations

import jax.numpy as jnp


def dequantize(idx, codebook, scales, tile: int):
    """Expand HALO codebook-index weights back to dense f32.

    Args:
      idx:      (K, N) int8/int32 — per-weight index into ``codebook``.
      codebook: (C,) f32 — low critical-path-delay weight values (9 or 16
                entries, padded to a power of two for the kernel).
      scales:   (K // tile, N // tile) f32 — per-tile dequant scale.
      tile:     tile edge length (paper default 128).

    Returns:
      (K, N) f32 dense weights ``codebook[idx] * scale_of_tile``.
    """
    k, n = idx.shape
    assert k % tile == 0 and n % tile == 0, (idx.shape, tile)
    w = codebook[idx.astype(jnp.int32)]
    s = jnp.repeat(jnp.repeat(scales, tile, axis=0), tile, axis=1)
    return w * s


def halo_matmul(x, idx, codebook, scales, tile: int):
    """Oracle for the HALO codebook-dequant matmul kernel.

    y = x @ (codebook[idx] * per_tile_scale)

    Args:
      x: (M, K) f32 activations.
      idx/codebook/scales/tile: see :func:`dequantize`.

    Returns:
      (M, N) f32.
    """
    return x @ dequantize(idx, codebook, scales, tile)


def spmv(val, pos, x, out_dim: int):
    """Oracle for the hypersparse outlier/salient SpMV (paper §III-C1).

    The sparse matrix W_s (K, N) is stored as ``val[i]`` at flattened
    position ``pos[i]`` (row-major: pos = row * N + col). Padding entries
    use val == 0 (pos arbitrary but in range). Computes  y = x @ W_s.

    Args:
      val: (nnz,) f32 non-zero weight values (zero-padded).
      pos: (nnz,) int32 flattened positions into the (K, N) matrix.
      x:   (M, K) f32 dense activations.
      out_dim: N.

    Returns:
      (M, N) f32.
    """
    k = x.shape[-1]
    rows = pos // out_dim
    cols = pos % out_dim
    dense = jnp.zeros((k, out_dim), x.dtype).at[rows, cols].add(val)
    return x @ dense


def fake_quant_act(x, bits: int = 8):
    """Per-token symmetric fake quantization of activations (paper: A8).

    Each token (row) gets its own scale max|x| / qmax; zeros stay zero.
    """
    qmax = 2.0 ** (bits - 1) - 1.0
    s = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / qmax
    s = jnp.where(s == 0.0, 1.0, s)
    return jnp.round(x / s).clip(-qmax - 1, qmax) * s


def tile_sensitivity(g, tile: int):
    """Oracle for the per-tile Fisher sensitivity reduction (paper Eq. 2).

    Lambda_Tk = sum_ij g_{k,i,j}^2 / (tile_rows * tile_cols)

    Args:
      g: (K, N) f32 gradient of the loss w.r.t. the weight matrix.
      tile: tile edge length.

    Returns:
      (K // tile, N // tile) f32 per-tile sensitivity scores.
    """
    k, n = g.shape
    assert k % tile == 0 and n % tile == 0, (g.shape, tile)
    g2 = (g * g).reshape(k // tile, tile, n // tile, tile)
    return g2.sum(axis=(1, 3)) / float(tile * tile)
