"""Layer-1 Pallas kernel: hypersparse SpMV for outlier/salient weights.

Paper §III-C1: salient + outlier weights (< 0.5% of all weights) are kept in
high precision and packaged as ``(val, idx)`` vectors for a dedicated SpMV
engine:  res[i] = val[i] * b[idx[i]]  scattered into the output.

We compute  y = x @ W_s  where W_s is the (K, N) hypersparse matrix stored
COO-style as ``val[i]`` at flattened row-major position ``pos[i]``. The
kernel blocks over the nnz vector; each grid step gathers the activation
columns its values need and scatter-adds partial products into the output,
which stays resident across the (sequential) grid — the Pallas analogue of
the paper's streaming SpMV unit.

interpret=True only (CPU PJRT); see halo_matmul.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(val_ref, pos_ref, x_ref, o_ref, *, n: int):
    """Process one block of nnz entries against the full x / y panels."""
    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    val = val_ref[...]  # (bnnz,)
    pos = pos_ref[...].astype(jnp.int32)  # (bnnz,)
    rows = pos // n
    cols = pos % n
    # (M, bnnz): activation column for each nnz entry, times its value.
    contrib = x_ref[...][:, rows] * val[None, :]
    # Scatter-add into the output columns.
    o_ref[...] = o_ref[...].at[:, cols].add(contrib)


@functools.partial(jax.jit, static_argnames=("out_dim", "block_nnz", "interpret"))
def spmv(val, pos, x, *, out_dim: int, block_nnz: int = 256, interpret: bool = True):
    """y = x @ scatter(val at pos) for hypersparse (val, pos).

    Args:
      val: (nnz,) f32 values, zero-padded to a multiple of ``block_nnz``.
      pos: (nnz,) int32 flattened row-major positions into (K, N).
      x:   (M, K) f32 activations.
      out_dim: N.
      block_nnz: nnz entries per grid step.

    Returns:
      (M, N) f32.
    """
    (nnz,) = val.shape
    assert pos.shape == (nnz,)
    block_nnz = min(block_nnz, nnz)  # small layers: single block
    assert nnz % block_nnz == 0, (nnz, block_nnz)
    m, k = x.shape

    grid = (nnz // block_nnz,)
    return pl.pallas_call(
        functools.partial(_kernel, n=out_dim),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_nnz,), lambda i: (i,)),
            pl.BlockSpec((block_nnz,), lambda i: (i,)),
            pl.BlockSpec((m, k), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((m, out_dim), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((m, out_dim), jnp.float32),
        interpret=interpret,
    )(val, pos, x)
