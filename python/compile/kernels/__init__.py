"""Layer-1 Pallas kernels for HALO (build-time only; lowered into L2 HLO).

- :mod:`halo_matmul` — codebook-dequant tiled matmul (the paper's quantized
  GEMM on the systolic array, re-thought for TPU VMEM/MXU).
- :mod:`spmv` — hypersparse SpMV for outlier/salient weights (§III-C1).
- :mod:`tile_stats` — per-tile Fisher sensitivity reduction (Eq. 2).
- :mod:`ref` — pure-jnp oracles; the correctness contract for all of the
  above and for the Rust re-implementation.
"""

from . import halo_matmul, ref, spmv, tile_stats  # noqa: F401
