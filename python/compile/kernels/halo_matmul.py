"""Layer-1 Pallas kernel: HALO codebook-dequant tiled matmul.

The paper executes non-uniformly quantized weights on a weight-stationary
systolic array whose PEs hold int8 weights drawn from a small codebook of
low critical-path-delay values (9 values for low-sensitivity tiles, 16 for
high-sensitivity tiles), with one dequant scale per 128x128 tile.

TPU re-think (DESIGN.md §Hardware adaptation): the 128x128 *tile* becomes the
Pallas block. HBM holds only the int8 *indices* (3-4 effective bits of
entropy, 1 byte stored); the codebook and the per-tile scale ride along as
tiny operands; dequantization (gather + scale) happens in VMEM immediately
before the MXU ``dot``. VMEM plays the role of the PE weight registers and
the BlockSpec index maps play the role of the paper's tile scheduler.

Lowered with ``interpret=True`` — CPU PJRT cannot execute Mosaic
custom-calls; real-TPU utilization is estimated in DESIGN.md §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_TILE = 128


def _kernel(x_ref, idx_ref, cb_ref, scale_ref, o_ref, *, nk: int):
    """One (bm x tile) @ (tile x tile) step of the dequant matmul.

    Grid is (M/bm, N/tile, K/tile); K is the reduction (innermost) axis.
    The output block mapping is independent of the K index, so ``o_ref``
    persists across the reduction — the classic Pallas accumulate-in-place
    pattern; partial sums never round-trip through HBM.
    """
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # Dequant in VMEM: gather from the (tiny) codebook, apply per-tile scale.
    w = cb_ref[idx_ref[...].astype(jnp.int32)] * scale_ref[0, 0]
    o_ref[...] += jnp.dot(x_ref[...], w, preferred_element_type=jnp.float32)


@functools.partial(
    jax.jit, static_argnames=("tile", "block_m", "interpret")
)
def halo_matmul(
    x,
    idx,
    codebook,
    scales,
    *,
    tile: int = DEFAULT_TILE,
    block_m: int = 128,
    interpret: bool = True,
):
    """y = x @ (codebook[idx] * per_tile_scale) as a Pallas kernel.

    Args:
      x:        (M, K) f32 activations, M % block_m == 0.
      idx:      (K, N) int8 codebook indices, K/N % tile == 0.
      codebook: (C,) f32 codebook (9 or 16 live entries; may be padded).
      scales:   (K//tile, N//tile) f32 per-tile scales.
      tile:     tile edge (paper default 128).
      block_m:  rows of x per grid step.

    Returns:
      (M, N) f32.
    """
    m, k = x.shape
    k2, n = idx.shape
    assert k == k2, (x.shape, idx.shape)
    assert m % block_m == 0, (m, block_m)
    assert k % tile == 0 and n % tile == 0, (idx.shape, tile)
    nk = k // tile

    grid = (m // block_m, n // tile, nk)
    return pl.pallas_call(
        functools.partial(_kernel, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, tile), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((tile, tile), lambda i, j, kk: (kk, j)),
            # Whole codebook visible to every block.
            pl.BlockSpec(codebook.shape, lambda i, j, kk: (0,)),
            pl.BlockSpec((1, 1), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((block_m, tile), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, idx, codebook, scales)


def vmem_bytes(tile: int, block_m: int, codebook_len: int = 16) -> int:
    """Estimated VMEM working set per grid step (DESIGN.md §Perf, L1).

    x block + idx block + dequantized w + output accumulator + codebook
    + scale. Used by the perf pass to keep the footprint under ~16 MB.
    """
    f32 = 4
    return (
        block_m * tile * f32  # x block
        + tile * tile * 1  # idx block (int8)
        + tile * tile * f32  # dequantized weights
        + block_m * tile * f32  # output accumulator
        + codebook_len * f32
        + f32
    )


def mxu_utilization_estimate(tile: int, block_m: int) -> float:
    """Crude MXU utilization estimate for DESIGN.md §Perf.

    The MXU is a 128x128 systolic array fed 8 lanes deep; a (bm, t) @ (t, t)
    dot achieves full utilization when all dims are multiples of 128 and the
    gather+scale dequant overlaps with the previous dot. We charge the
    dequant as a VPU pass over the weight block: t*t elements at 8 elem/cycle
    vs the dot's bm*t*t / (128*128) MXU cycles.
    """
    mxu_cycles = block_m * tile * tile / (128.0 * 128.0)
    vpu_cycles = tile * tile / 8.0
    dim_eff = min(tile / 128.0, 1.0) * min(block_m / 128.0, 1.0)
    overlap_eff = mxu_cycles / (mxu_cycles + max(vpu_cycles - mxu_cycles, 0.0))
    return dim_eff * overlap_eff
