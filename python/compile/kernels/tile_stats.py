"""Layer-1 Pallas kernel: per-tile Fisher sensitivity reduction (Eq. 2).

Lambda_Tk = sum_{i,j in tile k} g_{i,j}^2 / (tile_rows * tile_cols)

Used at calibration time over the gradient tensors produced by the L2 grad
graph; one grid step per 128x128 tile. Trivial compute, but it is the third
distinct dataflow in the paper (dense GEMM, SpMV, tile reduction), so it
gets the same Pallas + oracle treatment.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(g_ref, o_ref, *, tile: int):
    g = g_ref[...]
    o_ref[0, 0] = jnp.sum(g * g) / float(tile * tile)


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def tile_sensitivity(g, *, tile: int = 128, interpret: bool = True):
    """Per-tile mean squared gradient (diagonal Fisher, paper Eq. 2).

    Args:
      g: (K, N) f32 gradients, K/N % tile == 0.
      tile: tile edge length.

    Returns:
      (K//tile, N//tile) f32 sensitivities.
    """
    k, n = g.shape
    assert k % tile == 0 and n % tile == 0, (g.shape, tile)
    grid = (k // tile, n // tile)
    return pl.pallas_call(
        functools.partial(_kernel, tile=tile),
        grid=grid,
        in_specs=[pl.BlockSpec((tile, tile), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((1, 1), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((k // tile, n // tile), jnp.float32),
        interpret=interpret,
    )(g)
