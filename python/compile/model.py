"""Layer-2 JAX model: decoder-only transformer LM (the LLM under test).

Three lowered variants per model (see aot.py):

- ``fwd_fp``   — plain f32 forward (Table II "FP16/Ideal" row).
- ``fwd_a8``   — forward with per-token 8-bit fake-quantized activations;
                 weights are ordinary f32 parameters, so Rust substitutes any
                 fake-quantized weight tensor (RTN / SmoothQuant / GPTQ / ZQ /
                 HALO) into the same graph. This is the Table II workhorse.
- ``fwd_halo`` — the true HALO execution path: every linear layer runs the
                 L1 Pallas codebook-dequant matmul on int8 *indices* plus the
                 hypersparse SpMV correction (outliers + salient weights),
                 exactly the dataflow of Fig. 6(b). Used by the Rust serving
                 coordinator.

Weights are HLO *parameters*, never constants: one lowered graph serves
every quantization method (DESIGN.md, key decision 2). Parameter order is
the order of :func:`param_names`, followed by the token batch.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import halo_matmul as hm
from .kernels import ref as kref
from .kernels import spmv as sp


@dataclasses.dataclass(frozen=True)
class Config:
    """Transformer hyper-parameters.

    All matrix dims are multiples of 128 so that every linear weight tiles
    exactly at the paper's 128/64/32 tile sweep sizes.
    """

    name: str
    vocab: int = 256
    d_model: int = 128
    n_layers: int = 2
    n_heads: int = 2
    d_ff: int = 512
    seq_len: int = 128

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads


# The four model sizes standing in for LLaMA2-7B/13B and OPT-1.3B/30B
# (DESIGN.md §Substitutions): same architecture family, graded capacity.
CONFIGS: Dict[str, Config] = {
    c.name: c
    for c in [
        Config(name="tiny", d_model=128, n_layers=2, n_heads=2, d_ff=512),
        Config(name="small", d_model=256, n_layers=4, n_heads=4, d_ff=1024),
        Config(name="base", d_model=256, n_layers=6, n_heads=4, d_ff=1024),
        Config(name="large", d_model=384, n_layers=8, n_heads=6, d_ff=1536),
    ]
}


def param_specs(cfg: Config) -> List[Tuple[str, Tuple[int, ...], bool]]:
    """Canonical (name, shape, is_linear_weight) list.

    ``is_linear_weight`` marks the GEMM weights the paper quantizes
    ("computationally intensive operators such as attention and linear
    layers"); embeddings / norms / biases stay f32.
    """
    d, ff, v, s = cfg.d_model, cfg.d_ff, cfg.vocab, cfg.seq_len
    specs: List[Tuple[str, Tuple[int, ...], bool]] = [
        ("embed", (v, d), False),
        ("pos_embed", (s, d), False),
    ]
    for i in range(cfg.n_layers):
        p = f"layer{i}."
        specs += [
            (p + "ln1.scale", (d,), False),
            (p + "ln1.bias", (d,), False),
            (p + "attn.wq", (d, d), True),
            (p + "attn.wk", (d, d), True),
            (p + "attn.wv", (d, d), True),
            (p + "attn.wo", (d, d), True),
            (p + "ln2.scale", (d,), False),
            (p + "ln2.bias", (d,), False),
            (p + "mlp.w1", (d, ff), True),
            (p + "mlp.b1", (ff,), False),
            (p + "mlp.w2", (ff, d), True),
            (p + "mlp.b2", (d,), False),
        ]
    specs += [
        ("ln_f.scale", (d,), False),
        ("ln_f.bias", (d,), False),
        ("head", (d, v), True),
    ]
    return specs


def param_names(cfg: Config) -> List[str]:
    return [n for n, _, _ in param_specs(cfg)]


def linear_weight_names(cfg: Config) -> List[str]:
    return [n for n, _, lin in param_specs(cfg) if lin]


def init_params(cfg: Config, seed: int = 0) -> Dict[str, jnp.ndarray]:
    """Scaled-normal init (GPT-2 style: residual projections down-scaled)."""
    rng = np.random.default_rng(seed)
    out = {}
    resid_scale = 1.0 / math.sqrt(2 * cfg.n_layers)
    for name, shape, is_lin in param_specs(cfg):
        if name.endswith((".scale",)):
            arr = np.ones(shape, np.float32)
        elif name.endswith((".bias", ".b1", ".b2")):
            arr = np.zeros(shape, np.float32)
        elif is_lin or name in ("embed", "pos_embed"):
            std = 0.02 if len(shape) < 2 else 1.0 / math.sqrt(shape[0])
            if name.endswith((".wo", ".w2")):
                std *= resid_scale
            arr = rng.normal(0.0, std, shape).astype(np.float32)
        else:
            arr = rng.normal(0.0, 0.02, shape).astype(np.float32)
        out[name] = jnp.asarray(arr)
    return out


def _layer_norm(x, scale, bias, eps: float = 1e-5):
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * scale + bias


def _causal_mask(s: int):
    return jnp.tril(jnp.ones((s, s), jnp.bool_))


def _attention(cfg: Config, x, q, k, v):
    """(B, S, D) multi-head causal attention given projected q/k/v."""
    b, s, d = x.shape
    h, hd = cfg.n_heads, cfg.head_dim

    def split(t):
        return t.reshape(b, s, h, hd).transpose(0, 2, 1, 3)

    qh, kh, vh = split(q), split(k), split(v)
    logits = jnp.einsum("bhqd,bhkd->bhqk", qh, kh) / math.sqrt(hd)
    logits = jnp.where(_causal_mask(s)[None, None], logits, -1e30)
    att = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", att, vh)
    return out.transpose(0, 2, 1, 3).reshape(b, s, d)


def _forward(cfg: Config, params: Dict[str, jnp.ndarray], tokens, matmul):
    """Shared forward; ``matmul(name, x2d, default_w)`` performs the GEMM for
    the linear weight called ``name`` on flattened (B*S, in) activations."""
    b, s = tokens.shape
    x = params["embed"][tokens] + params["pos_embed"][None, :s]

    def lin(name, t):
        t2 = t.reshape(b * s, t.shape[-1])
        return matmul(name, t2, params.get(name)).reshape(b, s, -1)

    for i in range(cfg.n_layers):
        p = f"layer{i}."
        hn = _layer_norm(x, params[p + "ln1.scale"], params[p + "ln1.bias"])
        q, k, v = lin(p + "attn.wq", hn), lin(p + "attn.wk", hn), lin(p + "attn.wv", hn)
        x = x + lin(p + "attn.wo", _attention(cfg, hn, q, k, v))
        hn = _layer_norm(x, params[p + "ln2.scale"], params[p + "ln2.bias"])
        h1 = jax.nn.gelu(lin(p + "mlp.w1", hn) + params[p + "mlp.b1"])
        x = x + lin(p + "mlp.w2", h1) + params[p + "mlp.b2"]

    x = _layer_norm(x, params["ln_f.scale"], params["ln_f.bias"])
    return lin("head", x)


def forward_fp(cfg: Config, params, tokens):
    """Plain f32 forward → logits (B, S, vocab)."""
    return _forward(cfg, params, tokens, lambda _n, x, w: x @ w)


def forward_a8(cfg: Config, params, tokens):
    """Forward with per-token A8 fake-quantized activations at every GEMM."""
    return _forward(
        cfg, params, tokens, lambda _n, x, w: kref.fake_quant_act(x) @ w
    )


def forward_halo(cfg: Config, params, qparams, tokens, tile: int = 128):
    """True HALO path: L1 Pallas codebook matmul + SpMV correction per GEMM.

    ``qparams[name]`` is a dict with keys ``idx`` (K,N i8), ``codebook``
    (C,), ``scales`` (K//tile, N//tile), ``sp_val`` (nnz,), ``sp_pos``
    (nnz, i32). Non-linear params come from ``params`` as usual.
    """

    def mm(name, x, _w):
        q = qparams[name]
        xq = kref.fake_quant_act(x)
        y = hm.halo_matmul(xq, q["idx"], q["codebook"], q["scales"], tile=tile,
                           block_m=min(128, x.shape[0]))
        n = q["idx"].shape[1]
        return y + sp.spmv(q["sp_val"], q["sp_pos"], xq, out_dim=n)

    return _forward(cfg, params, tokens, mm)


def loss_fn(cfg: Config, params, tokens, fwd=None):
    """Next-token mean cross-entropy over (B, S+1) token batch.

    ``fwd`` selects the forward variant (default :func:`forward_fp`;
    :func:`forward_a8` gives the quantized-activation loss used by the
    Table II evaluation graphs).
    """
    fwd = fwd or forward_fp
    logits = fwd(cfg, params, tokens[:, :-1])
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return nll.mean()


def grad_linear_fn(cfg: Config, params, tokens):
    """(loss, grads-for-linear-weights) — the Fisher inputs (paper Eq. 1).

    Only the quantizable GEMM weights get gradients in the lowered artifact,
    keeping the output tuple small.
    """
    lin_names = linear_weight_names(cfg)

    def f(lin_weights, rest, toks):
        p = dict(rest)
        p.update(dict(zip(lin_names, lin_weights)))
        return loss_fn(cfg, p, toks)

    lin = tuple(params[n] for n in lin_names)
    rest = {k: v for k, v in params.items() if k not in lin_names}
    loss, grads = jax.value_and_grad(f)(lin, rest, tokens)
    return loss, grads


def count_params(cfg: Config) -> int:
    return sum(int(np.prod(s)) for _, s, _ in param_specs(cfg))
