"""L2 correctness: transformer shapes, gradients, quantized-forward paths."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import corpus, model, train
from compile.kernels import ref

MICRO = model.Config(name="micro", vocab=64, d_model=32, n_layers=2,
                     n_heads=2, d_ff=64, seq_len=16)


def _params(cfg=MICRO, seed=0):
    return model.init_params(cfg, seed=seed)


def _tokens(cfg=MICRO, batch=2, seed=0, extra=0):
    r = np.random.default_rng(seed)
    return jnp.asarray(r.integers(0, cfg.vocab, size=(batch, cfg.seq_len + extra)),
                       jnp.int32)


class TestForward:
    def test_logits_shape(self):
        p = _params()
        out = model.forward_fp(MICRO, p, _tokens())
        assert out.shape == (2, MICRO.seq_len, MICRO.vocab)

    def test_causality(self):
        """Changing a future token must not affect earlier logits."""
        p = _params()
        t1 = _tokens(seed=1)
        t2 = t1.at[:, -1].set((t1[:, -1] + 1) % MICRO.vocab)
        l1 = model.forward_fp(MICRO, p, t1)
        l2 = model.forward_fp(MICRO, p, t2)
        np.testing.assert_allclose(l1[:, :-1], l2[:, :-1], rtol=1e-5, atol=1e-5)
        assert float(jnp.abs(l1[:, -1] - l2[:, -1]).max()) > 0

    def test_a8_close_to_fp(self):
        p = _params()
        t = _tokens()
        lf = model.forward_fp(MICRO, p, t)
        la = model.forward_a8(MICRO, p, t)
        # 8-bit per-token activation quant is a small perturbation.
        rel = float(jnp.abs(la - lf).mean() / (jnp.abs(lf).mean() + 1e-9))
        assert rel < 0.15, rel

    def test_loss_finite_and_near_uniform_at_init(self):
        p = _params()
        l = float(model.loss_fn(MICRO, p, _tokens(extra=1)))
        assert np.isfinite(l)
        assert abs(l - np.log(MICRO.vocab)) < 1.0


class TestGrad:
    def test_grad_matches_finite_difference(self):
        cfg = MICRO
        p = _params(cfg)
        t = _tokens(cfg, extra=1)
        loss, grads = model.grad_linear_fn(cfg, p, t)
        name = model.linear_weight_names(cfg)[0]
        g = grads[0]
        # Probe one coordinate with central differences.
        eps = 1e-3
        w = p[name]
        for (i, j) in [(0, 0), (3, 5)]:
            pp = dict(p); pp[name] = w.at[i, j].add(eps)
            pm = dict(p); pm[name] = w.at[i, j].add(-eps)
            fd = (model.loss_fn(cfg, pp, t) - model.loss_fn(cfg, pm, t)) / (2 * eps)
            assert abs(float(fd) - float(g[i, j])) < 5e-3, (i, j)

    def test_grad_count_matches_linear_weights(self):
        p = _params()
        _, grads = model.grad_linear_fn(MICRO, p, _tokens(extra=1))
        names = model.linear_weight_names(MICRO)
        assert len(grads) == len(names)
        for g, n in zip(grads, names):
            assert g.shape == p[n].shape


class TestHaloForward:
    def test_matches_dequant_reference(self):
        """forward_halo(idx form) == forward_a8 with explicitly dequantized
        dense weights + sparse correction — the L1/L2 agreement contract."""
        cfg = MICRO
        tile = 16
        p = _params(cfg)
        t = _tokens(cfg)
        r = np.random.default_rng(42)

        qparams, dense = {}, {}
        for n in model.linear_weight_names(cfg):
            k, nn = p[n].shape
            idx = jnp.asarray(r.integers(0, 16, size=(k, nn)), jnp.int8)
            cb = jnp.asarray(r.normal(size=(16,)) * 0.05, jnp.float32)
            sc = jnp.asarray(r.uniform(0.5, 1.5, size=(k // tile, nn // tile)),
                             jnp.float32)
            nnz = 32
            val = jnp.asarray(r.normal(size=(nnz,)) * 0.05, jnp.float32)
            pos = jnp.asarray(
                r.choice(k * nn, size=nnz, replace=False), jnp.int32)
            qparams[n] = dict(idx=idx, codebook=cb, scales=sc,
                              sp_val=val, sp_pos=pos)
            w = ref.dequantize(idx, cb, sc, tile)
            rows, cols = pos // nn, pos % nn
            dense[n] = w + jnp.zeros_like(w).at[rows, cols].add(val)

        got = model.forward_halo(cfg, p, qparams, t, tile=tile)
        pd = dict(p); pd.update(dense)
        want = model.forward_a8(cfg, pd, t)
        # Tiled (Pallas) vs dense accumulation order drifts a few ulp per
        # GEMM; two decoder layers + layernorm amplify to ~1e-2 absolute on
        # logits of magnitude ~10. Structural equivalence is what we assert.
        np.testing.assert_allclose(got, want, rtol=5e-3, atol=2e-2)


class TestTraining:
    def test_loss_decreases(self):
        # vocab must match the corpus vocabulary (train.data_iter streams
        # real corpus tokens).
        cfg = model.Config(name="trainmicro", vocab=256, d_model=32,
                           n_layers=1, n_heads=2, d_ff=64, seq_len=16)
        params, log = train.train(cfg, steps=30, batch=4, log_every=29)
        assert log[-1][1] < log[0][1] - 0.1, log


class TestCorpus:
    def test_deterministic(self):
        a = corpus.generate("wikisyn", 1000, seed=1)
        b = corpus.generate("wikisyn", 1000, seed=1)
        np.testing.assert_array_equal(a, b)

    def test_vocab_range(self):
        s = corpus.generate("c4syn", 5000, seed=2)
        assert s.min() >= 0 and s.max() < corpus.VOCAB

    def test_entropy_ordering(self):
        # c4syn (web crawl analog) must be harder than wikisyn.
        assert corpus.entropy_bits("c4syn") > corpus.entropy_bits("wikisyn") + 0.5

    def test_batches_shape(self):
        s = corpus.generate("wikisyn", 10_000, seed=3)
        b = corpus.batches(s, 4, 33)
        assert b.shape[1:] == (4, 33)

    def test_transitions_match_matrix(self):
        """Empirical bigram frequencies approximate the transition matrix."""
        mat = corpus.transition_matrix("wikisyn")
        s = corpus.generate("wikisyn", 200_000, seed=4)
        # For the most common successor of token 0, empirical freq ~ matrix.
        idx0 = np.where(s[:-1] == 0)[0]
        if len(idx0) > 100:
            succ = s[idx0 + 1]
            top = int(np.argmax(mat[0]))
            emp = float((succ == top).mean())
            assert abs(emp - mat[0, top]) < 0.1


class TestParamSpecs:
    @pytest.mark.parametrize("name", list(model.CONFIGS))
    def test_dims_tile_divisible(self, name):
        """Every linear weight must tile exactly at 128/64/32 (paper sweep)."""
        cfg = model.CONFIGS[name]
        for n, shape, lin in model.param_specs(cfg):
            if lin:
                for d in shape:
                    assert d % 128 == 0, (n, shape)

    def test_param_count_monotone(self):
        counts = [model.count_params(model.CONFIGS[n])
                  for n in ["tiny", "small", "base", "large"]]
        assert counts == sorted(counts)
