"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps shapes/dtypes (the instruction from DESIGN.md: the kernel
contract is what the Rust quantizer re-implements, so these tests are the
three-layer agreement point).
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import halo_matmul as hm
from compile.kernels import ref
from compile.kernels import spmv as sp
from compile.kernels import tile_stats as ts

SETTINGS = dict(max_examples=15, deadline=None)


def _rng(seed):
    return np.random.default_rng(seed)


def make_case(seed, tile, mt, kt, nt, cb_len):
    r = _rng(seed)
    m, k, n = mt * tile, kt * tile, nt * tile
    x = jnp.asarray(r.normal(size=(m, k)), jnp.float32)
    idx = jnp.asarray(r.integers(0, cb_len, size=(k, n)), jnp.int8)
    cb = jnp.asarray(r.normal(size=(cb_len,)), jnp.float32)
    sc = jnp.asarray(r.uniform(0.25, 4.0, size=(k // tile, n // tile)), jnp.float32)
    return x, idx, cb, sc


class TestHaloMatmul:
    @settings(**SETTINGS)
    @given(
        seed=st.integers(0, 2**31 - 1),
        tile=st.sampled_from([8, 16, 32]),
        mt=st.integers(1, 3),
        kt=st.integers(1, 3),
        nt=st.integers(1, 3),
        cb_len=st.sampled_from([9, 16]),
    )
    def test_matches_ref(self, seed, tile, mt, kt, nt, cb_len):
        x, idx, cb, sc = make_case(seed, tile, mt, kt, nt, cb_len)
        got = hm.halo_matmul(x, idx, cb, sc, tile=tile, block_m=tile)
        want = ref.halo_matmul(x, idx, cb, sc, tile)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_paper_tile_128(self):
        x, idx, cb, sc = make_case(7, 128, 1, 2, 2, 16)
        got = hm.halo_matmul(x, idx, cb, sc, tile=128, block_m=128)
        want = ref.halo_matmul(x, idx, cb, sc, 128)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_block_m_smaller_than_tile(self):
        x, idx, cb, sc = make_case(3, 32, 2, 2, 2, 9)
        got = hm.halo_matmul(x, idx, cb, sc, tile=32, block_m=16)
        want = ref.halo_matmul(x, idx, cb, sc, 32)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_zero_scale_tile_zeroes_block(self):
        x, idx, cb, _ = make_case(11, 16, 1, 1, 2, 16)
        sc = jnp.asarray([[0.0, 1.0]], jnp.float32)
        got = hm.halo_matmul(x, idx, cb, sc, tile=16, block_m=16)
        assert float(jnp.abs(got[:, :16]).max()) == 0.0
        assert float(jnp.abs(got[:, 16:]).max()) > 0.0

    def test_rejects_ragged(self):
        x, idx, cb, sc = make_case(0, 16, 1, 1, 1, 16)
        with pytest.raises(AssertionError):
            hm.halo_matmul(x[:, :-1], idx[:-1], cb, sc, tile=16, block_m=16)

    def test_vmem_budget(self):
        # DESIGN.md §Perf L1: default block shapes stay far under 16 MB VMEM.
        assert hm.vmem_bytes(128, 128) < 16 * 2**20
        assert 0.0 < hm.mxu_utilization_estimate(128, 128) <= 1.0


class TestSpmv:
    @settings(**SETTINGS)
    @given(
        seed=st.integers(0, 2**31 - 1),
        m=st.sampled_from([4, 8, 16]),
        k=st.sampled_from([16, 64]),
        n=st.sampled_from([16, 32, 128]),
        blocks=st.integers(1, 4),
    )
    def test_matches_ref(self, seed, m, k, n, blocks):
        r = _rng(seed)
        nnz = 64 * blocks
        val = jnp.asarray(r.normal(size=(nnz,)), jnp.float32)
        pos = jnp.asarray(r.integers(0, k * n, size=(nnz,)), jnp.int32)
        x = jnp.asarray(r.normal(size=(m, k)), jnp.float32)
        got = sp.spmv(val, pos, x, out_dim=n, block_nnz=64)
        want = ref.spmv(val, pos, x, n)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_duplicate_positions_accumulate(self):
        # Paper packaging never duplicates, but the kernel must still be a
        # well-defined scatter-add (Rust property tests rely on it).
        val = jnp.asarray([1.0, 2.0, 0.0, 0.0], jnp.float32)
        pos = jnp.asarray([5, 5, 0, 0], jnp.int32)
        x = jnp.eye(4, dtype=jnp.float32)
        got = sp.spmv(val, pos, x, out_dim=4, block_nnz=4)
        want = ref.spmv(val, pos, x, 4)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)

    def test_zero_padding_is_noop(self):
        r = _rng(0)
        val = jnp.concatenate(
            [jnp.asarray(r.normal(size=(32,)), jnp.float32), jnp.zeros(32)]
        )
        pos = jnp.asarray(r.integers(0, 64, size=(64,)), jnp.int32)
        x = jnp.asarray(r.normal(size=(4, 8)), jnp.float32)
        got = sp.spmv(val, pos, x, out_dim=8, block_nnz=32)
        want = ref.spmv(val[:32], pos[:32], x, 8)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


class TestTileStats:
    @settings(**SETTINGS)
    @given(
        seed=st.integers(0, 2**31 - 1),
        tile=st.sampled_from([8, 32]),
        kt=st.integers(1, 4),
        nt=st.integers(1, 4),
    )
    def test_matches_ref(self, seed, tile, kt, nt):
        r = _rng(seed)
        g = jnp.asarray(r.normal(size=(kt * tile, nt * tile)), jnp.float32)
        got = ts.tile_sensitivity(g, tile=tile)
        want = ref.tile_sensitivity(g, tile)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-6)

    def test_constant_tile_value(self):
        g = jnp.full((16, 16), 2.0, jnp.float32)
        got = ts.tile_sensitivity(g, tile=8)
        np.testing.assert_allclose(got, jnp.full((2, 2), 4.0), rtol=1e-6)


class TestFakeQuantAct:
    @settings(**SETTINGS)
    @given(seed=st.integers(0, 2**31 - 1), bits=st.sampled_from([4, 8]))
    def test_bounded_error(self, seed, bits):
        r = _rng(seed)
        x = jnp.asarray(r.normal(size=(8, 32)) * 10, jnp.float32)
        xq = ref.fake_quant_act(x, bits=bits)
        # Per-token scale bounds the max error to scale/2.
        s = np.abs(np.asarray(x)).max(axis=1, keepdims=True) / (2 ** (bits - 1) - 1)
        assert np.all(np.abs(np.asarray(xq - x)) <= s / 2 + 1e-6)

    def test_zero_rows_stay_zero(self):
        x = jnp.zeros((2, 8), jnp.float32)
        assert float(jnp.abs(ref.fake_quant_act(x)).max()) == 0.0

    def test_idempotent(self):
        r = _rng(1)
        x = jnp.asarray(r.normal(size=(4, 16)), jnp.float32)
        xq = ref.fake_quant_act(x)
        np.testing.assert_allclose(ref.fake_quant_act(xq), xq, rtol=1e-5, atol=1e-6)
