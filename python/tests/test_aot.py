"""AOT artifact sanity: manifest, param tables, HLO text well-formedness.

Runs against whatever ``artifacts/`` the Makefile produced (fast or full).
Skips if artifacts have not been built yet.
"""

from __future__ import annotations

import json
from pathlib import Path

import numpy as np
import pytest

from compile import aot, model

ART = Path(__file__).resolve().parents[2] / "artifacts"

pytestmark = pytest.mark.skipif(
    not (ART / "manifest.json").exists(), reason="run `make artifacts` first"
)


def _manifest():
    return json.loads((ART / "manifest.json").read_text())


def test_manifest_fields():
    m = _manifest()
    assert m["halo_tile"] == 128
    assert 0 < m["sparse_frac"] <= 0.01
    assert set(m["corpora"]) >= {"wikisyn", "c4syn", "calib"}
    assert m["models"]


@pytest.mark.parametrize("corp", ["wikisyn", "c4syn"])
def test_corpus_files(corp):
    m = _manifest()
    data = np.fromfile(ART / "corpora" / f"{corp}_eval.u16.bin", np.uint16)
    assert len(data) == m["corpora"][corp]["eval_tokens"]
    assert data.max() < m["vocab"]


def test_param_bin_matches_table():
    m = _manifest()
    for name in m["models"]:
        meta = json.loads((ART / "models" / name / "config.json").read_text())
        flat = np.fromfile(ART / "models" / name / "params.f32.bin", np.float32)
        assert len(flat) == meta["n_params"]
        last = meta["params"][-1]
        assert last["offset"] + last["numel"] == meta["n_params"]
        # Table order matches the model's canonical param order.
        cfg = model.CONFIGS[name]
        assert [e["name"] for e in meta["params"]] == model.param_names(cfg)
        assert np.isfinite(flat).all()


@pytest.mark.parametrize("g", ["nll_fp", "nll_a8", "fwd_fp", "grad"])
def test_hlo_text_wellformed(g):
    m = _manifest()
    for name in m["models"]:
        text = (ART / "models" / name / f"{g}.hlo.txt").read_text()
        assert "ENTRY" in text and "ROOT" in text
        cfg = model.CONFIGS[name]
        # params + tokens all appear as HLO parameters. Subcomputations
        # (reduces etc.) declare their own parameter() instructions, so the
        # total count is a lower bound check.
        n_params = len(model.param_names(cfg)) + 1
        assert text.count("parameter(") >= n_params, (name, g)
        # the token batch parameter is the (B, S(+1)) s32 operand
        assert "s32[" in text


def test_halo_graph_layout():
    m = _manifest()
    name = "base" if "base" in m["models"] else next(iter(m["models"]))
    meta_p = ART / "models" / name / "fwd_halo.json"
    if not meta_p.exists():
        pytest.skip("fwd_halo only lowered for base model")
    meta = json.loads(meta_p.read_text())
    cfg = model.CONFIGS[name]
    assert meta["tile"] == 128
    assert [e["name"] for e in meta["linear"]] == model.linear_weight_names(cfg)
    for e in meta["linear"]:
        assert e["nnz"] % aot.SPARSE_PAD == 0
        assert e["nnz"] >= e["k"] * e["n"] * aot.SPARSE_FRAC
    text = (ART / "models" / name / "fwd_halo.hlo.txt").read_text()
    n_hlo_params = (len(meta["rest"]) + 5 * len(meta["linear"])) + 1
    assert text.count("parameter(") >= n_hlo_params
    assert "s8[" in text  # codebook index operands reached the graph


def test_kernel_artifacts():
    kj = json.loads((ART / "kernels" / "kernels.json").read_text())
    for k in ["halo_matmul", "spmv"]:
        text = (ART / "kernels" / f"{k}.hlo.txt").read_text()
        assert "ENTRY" in text
        assert kj[k]["m"] > 0


def test_sparse_pad_len():
    assert aot.sparse_pad_len(128, 128) == 256  # ceil(82) -> 256
    assert aot.sparse_pad_len(1024, 1024) % aot.SPARSE_PAD == 0
    assert aot.sparse_pad_len(1024, 1024) >= 1024 * 1024 * aot.SPARSE_FRAC
