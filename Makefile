# HALO reproduction — top-level targets.
#
#   make artifacts       train the tiny LMs + lower every graph (needs JAX)
#   make artifacts-fast  tiny-only, few steps (CI smoke / quick iteration)
#   make test            tier-1 verify: cargo build --release && cargo test -q
#   make bench           run every harness-free benchmark
#   make bench-json      JSON benches → BENCH_PR2..PR10.json (perf trajectory)
#   make docs            rustdoc with -D warnings + build all examples (same as CI)
#   make fmt             rustfmt check (same as CI)
#   make lint            halo-lint: panic-safety / sync-shim / retry-bound / unsafe-docs
#   make loom            exhaustive coordinator model checks (plain + --cfg loom)
#   make chaos           seeded fault-injection soak (failpoints + shard recovery)
#   make spec            speculative-decoding exactness suite + the l7 bench smoke
#   make quant           integer-vs-LUT-oracle equivalence suite (W4A8 kernels)

ARTIFACTS ?= artifacts
PYTHON ?= python3

.PHONY: artifacts artifacts-fast build test bench bench-json bench-check docs fmt lint loom chaos spec quant clean

artifacts:
	cd python && $(PYTHON) -m compile.aot --out ../$(ARTIFACTS)

artifacts-fast:
	cd python && HALO_FAST=1 $(PYTHON) -m compile.aot --out ../$(ARTIFACTS) --fast

build:
	cargo build --release

test:
	cargo build --release && cargo test -q

bench:
	cargo bench --bench l1_hotpaths
	cargo bench --bench l2_serving
	cargo bench --bench l4_quant_exec
	cargo bench --bench l5_decode
	cargo bench --bench l6_kvcache
	cargo bench --bench l7_spec
	cargo bench --bench fig8_exec_time
	cargo bench --bench fig10_energy
	cargo bench --bench fig11_tile_size
	cargo bench --bench fig12_gpu_exec
	cargo bench --bench fig13_gpu_energy
	cargo bench --bench l3_coordinator

# Machine-readable perf-trajectory numbers: hot paths (MacProfile::compute,
# 64-lane vs scalar netlist eval, blocked vs naive matmul, SimBackend
# forward), sharded serving throughput (1 shard vs N), quantized vs
# dense execution (integer W4A8 panel kernels + fused SpMV vs
# dequantize-then-dense — PR 10 re-baselined BENCH_PR4 → BENCH_PR10),
# KV-cached decode vs full-prefix recompute at S=256, the paged KV
# pool's shared-prefix/block-packing memory savings, and speculative
# decode vs verifier-only decode (exactness-asserted speedup).
bench-json:
	cargo bench --bench l1_hotpaths -- --smoke --json BENCH_PR2.json
	cargo bench --bench l2_serving -- --smoke --json BENCH_PR3.json
	cargo bench --bench l4_quant_exec -- --smoke --json BENCH_PR10.json
	cargo bench --bench l5_decode -- --smoke --json BENCH_PR5.json
	cargo bench --bench l6_kvcache -- --smoke --json BENCH_PR8.json
	cargo bench --bench l7_spec -- --smoke --json BENCH_PR9.json

# The CI regression gate, runnable locally: fresh smoke JSONs compared
# against the committed baselines (ratio keys only, see tools/bench_check.rs).
bench-check:
	cargo bench --bench l1_hotpaths -- --smoke --json /tmp/halo_l1_smoke.json
	cargo bench --bench l2_serving -- --smoke --json /tmp/halo_l2_smoke.json
	cargo bench --bench l4_quant_exec -- --smoke --json /tmp/halo_l4_smoke.json
	cargo bench --bench l5_decode -- --smoke --json /tmp/halo_l5_smoke.json
	cargo bench --bench l6_kvcache -- --smoke --json /tmp/halo_l6_smoke.json
	cargo run --release --bin bench_check -- --baseline BENCH_PR2.json \
	  --current /tmp/halo_l1_smoke.json --tol 0.5 \
	  --keys mac_profile_compute.speedup,netlist_eval.speedup,forward_pass.speedup
	cargo run --release --bin bench_check -- --baseline BENCH_PR3.json \
	  --current /tmp/halo_l2_smoke.json --tol 0.3 --keys scaling_throughput
	cargo run --release --bin bench_check -- --baseline BENCH_PR10.json \
	  --current /tmp/halo_l4_smoke.json --tol 0.3 \
	  --keys layer.throughput_ratio,decode.throughput_ratio,quant_vs_dense_throughput \
	  --min quant_vs_dense_throughput=1.0
	cargo run --release --bin bench_check -- --baseline BENCH_PR10.json \
	  --current /tmp/halo_l4_smoke.json --tol 0.3 \
	  --keys memory.bytes_saving,model_cost.modeled_speedup
	cargo run --release --bin bench_check -- --baseline BENCH_PR5.json \
	  --current /tmp/halo_l5_smoke.json --tol 0.5 \
	  --keys decode_cached_speedup --min decode_cached_speedup=2.0
	cargo run --release --bin bench_check -- --baseline BENCH_PR8.json \
	  --current /tmp/halo_l6_smoke.json --tol 0.3 \
	  --keys shared_prefix_saving,kv_bytes_per_token_ratio \
	  --min shared_prefix_saving=1.5
	cargo bench --bench l7_spec -- --smoke --json /tmp/halo_l7_smoke.json
	cargo run --release --bin bench_check -- --baseline BENCH_PR9.json \
	  --current /tmp/halo_l7_smoke.json --tol 0.3 \
	  --keys spec_decode_speedup,acceptance_rate \
	  --min spec_decode_speedup=0.7

# Documentation gate: rustdoc is warning-clean (missing_docs + intra-doc
# links) and every example builds.
docs:
	RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --lib
	cargo build --examples

fmt:
	cargo fmt --check

# Repo lint (CI `analysis` job): no-panic-serving-path, sync-via-shim,
# no-unbounded-retry, no-undocumented-unsafe, missing-docs inventory.
# Audited exceptions live in lint_allow.toml; the lint's own rule
# fixtures run first.
lint:
	cargo test --bin halo-lint -q
	cargo run --release --bin halo-lint

# Loom-style exhaustive model checks over the coordinator, twice: plain
# (shim passthrough outside model()) and strict (--cfg loom: shim use
# outside model() panics, proving the suite only exercises modeled code).
loom:
	cargo test --release --test loom_coordinator -- --nocapture
	RUSTFLAGS="--cfg loom" CARGO_TARGET_DIR=target/loom \
	  cargo test --release --test loom_coordinator

# Chaos soak (CI `analysis` job): deterministic seeded failpoint schedules
# driving shard kills, transient errors and delays through the supervised
# coordinator; pins exactly-one-response, bit-identical retried decodes
# and the metrics conservation law. See DESIGN.md Â§Fault model & recovery.
chaos:
	cargo test --release --test chaos -- --nocapture

# Speculative decoding (PR 9): the exactness matrix + sampling/rollback
# properties that pin `coordinator::spec`, then the l7 bench in smoke
# mode (which asserts bit-identical chains before timing anything).
spec:
	cargo test --release --test decode_equiv speculative -- --nocapture
	cargo test --release --test proptests prop_seeded_sampling -- --nocapture
	cargo test --release --test proptests prop_rollback -- --nocapture
	cargo bench --bench l7_spec -- --smoke

# Integer W4A8 kernels (PR 10): the i8-vs-LUT-oracle equivalence suite —
# bit-identical layer outputs across every tile geometry, the MAX_TILE
# overflow/exactness property, the lib-level kernel pins, and the
# force_lut greedy-chain pin in decode_equiv.
quant:
	cargo test --release --test qexec -- --nocapture
	cargo test --release --lib runtime::qkernels -- --nocapture
	cargo test --release --test decode_equiv greedy_chains_identical_under_integer_and_lut_oracle_kernels -- --nocapture

clean:
	cargo clean
	rm -rf results
