# HALO reproduction — top-level targets.
#
#   make artifacts       train the tiny LMs + lower every graph (needs JAX)
#   make artifacts-fast  tiny-only, few steps (CI smoke / quick iteration)
#   make test            tier-1 verify: cargo build --release && cargo test -q
#   make bench           run every harness-free benchmark
#   make bench-json      hot-path bench → BENCH_PR2.json (perf trajectory)
#   make fmt             rustfmt check (same as CI)

ARTIFACTS ?= artifacts
PYTHON ?= python3

.PHONY: artifacts artifacts-fast build test bench bench-json fmt clean

artifacts:
	cd python && $(PYTHON) -m compile.aot --out ../$(ARTIFACTS)

artifacts-fast:
	cd python && HALO_FAST=1 $(PYTHON) -m compile.aot --out ../$(ARTIFACTS) --fast

build:
	cargo build --release

test:
	cargo build --release && cargo test -q

bench:
	cargo bench --bench l1_hotpaths
	cargo bench --bench fig8_exec_time
	cargo bench --bench fig10_energy
	cargo bench --bench fig11_tile_size
	cargo bench --bench fig12_gpu_exec
	cargo bench --bench fig13_gpu_energy
	cargo bench --bench l3_coordinator

# Machine-readable hot-path numbers (MacProfile::compute, 64-lane vs
# scalar netlist eval, blocked vs naive matmul, SimBackend forward).
bench-json:
	cargo bench --bench l1_hotpaths -- --json BENCH_PR2.json

fmt:
	cargo fmt --check

clean:
	cargo clean
	rm -rf results
