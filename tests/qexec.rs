//! Equivalence suite for the native quantized execution engine (PR 4,
//! rebuilt integer-first in PR 10): the W4A8 panel kernel — i8 weight
//! panels × per-row-quantized i8 activations, i32 accumulation, one f32
//! rescale per tile, fused hypersparse SpMV — is pinned two ways:
//!
//! - **bit-exactly** against the f32 LUT oracle behind
//!   [`halo::runtime::qkernels::set_force_lut`]: per-tile partial sums
//!   stay under 2^24 (see `quant::packed::MAX_TILE`), so both paths
//!   compute the same exactly-representable integers and must agree to
//!   the last bit, across all three HALO variants and every
//!   tile-geometry edge case (ragged, all-sparse, empty-outlier);
//! - **approximately** against the dequantize-then-dense oracle, where
//!   the tolerance budgets the deliberate A8 activation-quantization
//!   and integer-codebook rounding error (≲1% relative each).
//!
//! A hand-rolled property test sweeps random and adversarial tiles at
//! `MAX_TILE` to prove the i32 accumulator never overflows and every
//! partial sum survives the `as f32` cast exactly. The end-to-end
//! serving contract (decode through the coordinator on a `PackedModel`
//! store that holds packed tiles and never a dense f32 linear weight)
//! rides on top.
//!
//! No artifacts needed: models are synthesized in-memory from a tiny
//! `ModelSpec`, exactly like the sim backend's own validation tests.

use std::collections::BTreeMap;
use std::sync::Arc;

use halo::coordinator::{BatcherConfig, Coordinator, CoordinatorConfig, QuantExecutor, Request};
use halo::dvfs::Ladder;
use halo::mac::MacProfile;
use halo::quant::packed::PackedLayer;
use halo::quant::{HaloConfig, HaloQuantizer, LayerCtx, Matrix, Variant};
use halo::runtime::sim::{model_forward, ModelSpec};
use halo::runtime::{argmax_slice, kernels, qmatmul, Literal, PackedModel};
use halo::util::Rng;

fn pack_one(w: &Matrix, grad: Option<&Matrix>, tile: usize, variant: Variant) -> PackedLayer {
    let profile = MacProfile::cached();
    let q = HaloQuantizer::new(HaloConfig::new(tile, variant), profile);
    let ctx = match grad {
        Some(g) => LayerCtx::with_grad("t", g),
        None => LayerCtx::new("t"),
    };
    let (res, pay) = q.quantize_full(w, &ctx);
    PackedLayer::pack("t", &res, &pay, profile)
}

fn assert_close(got: &Matrix, want: &Matrix, what: &str, tol: f32) {
    assert_eq!((got.rows, got.cols), (want.rows, want.cols), "{what}: shape");
    for (i, (a, b)) in got.data.iter().zip(&want.data).enumerate() {
        assert!(
            (a - b).abs() <= tol * (1.0 + b.abs()),
            "{what}[{i}]: {a} vs {b}"
        );
    }
}

/// Tolerance vs the dequantize-then-dense oracle: the integer path
/// deliberately quantizes activations to i8 (≤ ~0.4% relative per value)
/// and snaps the codebook to i8 steps (≤ qstep/2 per weight), so the
/// bound budgets both — bit-exactness is pinned against the LUT oracle
/// below, not against this f32 oracle.
const A8_TOL: f32 = 5e-2;

#[test]
fn packed_matmul_matches_oracle_all_variants() {
    let mut rng = Rng::seed_from_u64(1);
    for variant in [Variant::PerfOpt, Variant::Bal, Variant::AccOpt] {
        let w = Matrix::random_normal(96, 64, 0.02, &mut rng);
        let g = Matrix::random_normal(96, 64, 1.0, &mut rng);
        let layer = pack_one(&w, Some(&g), 32, variant);
        let x = Matrix::random_normal(9, 96, 1.0, &mut rng);
        let want = kernels::matmul(&x, &layer.dequantize());
        let got = qmatmul(&x, &layer);
        assert_close(&got, &want, variant.name(), A8_TOL);
    }
}

#[test]
fn packed_matmul_ragged_last_tiles() {
    // 100x70 with tile 32: ragged tiles on both edges (last is 4x6).
    let mut rng = Rng::seed_from_u64(2);
    let w = Matrix::random_normal(100, 70, 0.02, &mut rng);
    let g = Matrix::random_normal(100, 70, 1.0, &mut rng);
    let layer = pack_one(&w, Some(&g), 32, Variant::Bal);
    assert_eq!(layer.tiles.last().unwrap().codes.len(), 4 * 6);
    for m in [1usize, 3, 8] {
        let x = Matrix::random_normal(m, 100, 1.0, &mut rng);
        let want = kernels::matmul(&x, &layer.dequantize());
        assert_close(&qmatmul(&x, &layer), &want, &format!("ragged m={m}"), A8_TOL);
    }
}

#[test]
fn packed_matmul_all_sparse_tile() {
    // Plant a tile whose every entry is an extreme outlier: the 3σ cut
    // routes the whole tile to the SpMV side and the dense tile quantizes
    // pure zeros. The fused epilogue must reproduce it exactly.
    let mut rng = Rng::seed_from_u64(3);
    let mut w = Matrix::random_normal(64, 64, 0.02, &mut rng);
    for r in 0..16 {
        for c in 0..16 {
            w.set(r, c, 1.5 * if (r + c) % 2 == 0 { 1.0 } else { -1.0 });
        }
    }
    let layer = pack_one(&w, None, 16, Variant::Bal);
    assert!(
        layer.sparse.nnz >= 16 * 16,
        "planted tile not extracted: nnz={}",
        layer.sparse.nnz
    );
    let x = Matrix::random_normal(5, 64, 1.0, &mut rng);
    let want = kernels::matmul(&x, &layer.dequantize());
    assert_close(&qmatmul(&x, &layer), &want, "all-sparse tile", A8_TOL);
}

#[test]
fn packed_matmul_empty_outlier_set() {
    // Bounded values, no gradients: nothing is salient and nothing crosses
    // 3σ, so the sparse side is empty and the epilogue must be a no-op.
    let w = Matrix::from_fn(48, 32, |r, c| ((r + 2 * c) % 5) as f32 * 0.01 - 0.02);
    let layer = pack_one(&w, None, 16, Variant::Bal);
    assert_eq!(layer.sparse.nnz, 0, "expected an empty outlier set");
    let mut rng = Rng::seed_from_u64(4);
    let x = Matrix::random_normal(6, 48, 1.0, &mut rng);
    let want = kernels::matmul(&x, &layer.dequantize());
    assert_close(&qmatmul(&x, &layer), &want, "empty outliers", A8_TOL);
}

// ------------------------------------------------------- LUT-oracle pins

/// Every layer construction used above, replayed under the i8-vs-LUT
/// microscope: the integer kernel and the f32 LUT oracle must agree to
/// the LAST BIT (`assert_eq!` on the raw f32 payloads) — all three
/// variants, ragged edges, an all-sparse tile, and an empty outlier set.
/// Serialized via `LUT_TEST_LOCK` so a concurrent toggle elsewhere in
/// the binary cannot make the comparison vacuous.
#[test]
fn integer_kernel_bit_identical_to_lut_oracle_every_tile_geometry() {
    use halo::runtime::qkernels::{set_force_lut, LUT_TEST_LOCK};
    let _guard = LUT_TEST_LOCK.lock().unwrap();
    let mut rng = Rng::seed_from_u64(21);

    let mut cases: Vec<(String, PackedLayer, Matrix)> = Vec::new();
    for variant in [Variant::PerfOpt, Variant::Bal, Variant::AccOpt] {
        let w = Matrix::random_normal(96, 64, 0.02, &mut rng);
        let g = Matrix::random_normal(96, 64, 1.0, &mut rng);
        let layer = pack_one(&w, Some(&g), 32, variant);
        let x = Matrix::random_normal(7, 96, 1.0, &mut rng);
        cases.push((format!("variant {}", variant.name()), layer, x));
    }
    {
        // Ragged tiles on both edges (last is 4x6).
        let w = Matrix::random_normal(100, 70, 0.02, &mut rng);
        let g = Matrix::random_normal(100, 70, 1.0, &mut rng);
        let layer = pack_one(&w, Some(&g), 32, Variant::Bal);
        let x = Matrix::random_normal(3, 100, 1.0, &mut rng);
        cases.push(("ragged".into(), layer, x));
    }
    {
        // All-sparse tile: dense side quantizes zeros, SpMV carries it.
        let mut w = Matrix::random_normal(64, 64, 0.02, &mut rng);
        for r in 0..16 {
            for c in 0..16 {
                w.set(r, c, 1.5 * if (r + c) % 2 == 0 { 1.0 } else { -1.0 });
            }
        }
        let layer = pack_one(&w, None, 16, Variant::Bal);
        assert!(layer.sparse.nnz >= 16 * 16);
        let x = Matrix::random_normal(5, 64, 1.0, &mut rng);
        cases.push(("all-sparse".into(), layer, x));
    }
    {
        // Empty outlier set: the SpMV epilogue is a no-op.
        let w = Matrix::from_fn(48, 32, |r, c| ((r + 2 * c) % 5) as f32 * 0.01 - 0.02);
        let layer = pack_one(&w, None, 16, Variant::Bal);
        assert_eq!(layer.sparse.nnz, 0);
        let x = Matrix::random_normal(6, 48, 1.0, &mut rng);
        cases.push(("empty-outlier".into(), layer, x));
    }

    for (what, layer, x) in &cases {
        set_force_lut(false);
        let int_path = qmatmul(x, layer);
        set_force_lut(true);
        let oracle = qmatmul(x, layer);
        set_force_lut(false);
        assert_eq!(
            int_path.data, oracle.data,
            "{what}: integer path is not bit-identical to the LUT oracle"
        );
    }
}

/// Hand-rolled property test (no external proptest crate): per-tile i32
/// accumulation can NEVER overflow at the maximum tile size, and every
/// partial sum is exactly representable in f32 — the invariant the
/// bit-exact LUT oracle rests on. Sweeps seeded-random i8 panels and
/// activations at `MAX_TILE` depth plus the adversarial corners
/// (all-extreme same-sign and alternating-sign columns), checking
/// `|acc| <= MAX_TILE * 127 * 128 = 16_646_144 < 2^24` with checked
/// arithmetic so an overflow fails loudly instead of wrapping.
#[test]
fn i32_accumulation_never_overflows_at_max_tile() {
    use halo::quant::packed::MAX_TILE;
    const BOUND: i64 = (MAX_TILE as i64) * 127 * 128;
    assert!(BOUND < 1 << 24, "exactness budget violated: {BOUND} >= 2^24");

    let mut rng = Rng::seed_from_u64(22);
    let check = |wq: &[i8], xq: &[i8], what: &str| {
        let mut acc: i32 = 0;
        for (&w, &a) in wq.iter().zip(xq) {
            acc = acc
                .checked_add(a as i32 * w as i32)
                .unwrap_or_else(|| panic!("{what}: i32 accumulator overflowed"));
        }
        assert!(
            (acc as i64).abs() <= BOUND,
            "{what}: |{acc}| exceeds the 2^24 exactness budget"
        );
        // Round-trip through f32: the rescale epilogue casts `acc as f32`,
        // which must be lossless for the LUT oracle to match bit-for-bit.
        assert_eq!(acc as f32 as i32, acc, "{what}: {acc} not exact in f32");
    };

    // Adversarial corners: extreme codebook (|wq| = 127) against extreme
    // activations (xq = -128 is the widest i8 the A8 clamp admits).
    let corners: [(i8, i8); 4] = [(127, -128), (-127, -128), (127, 127), (-127, 127)];
    for (w, a) in corners {
        check(&vec![w; MAX_TILE], &vec![a; MAX_TILE], &format!("corner ({w}, {a})"));
    }
    // Alternating signs: cancellation must not trick checked_add either.
    let wq: Vec<i8> = (0..MAX_TILE).map(|i| if i % 2 == 0 { 127 } else { -127 }).collect();
    check(&wq, &vec![-128i8; MAX_TILE], "alternating");

    // Seeded random sweep across depths up to MAX_TILE.
    for trial in 0..64 {
        let kh = 1 + rng.gen_usize(MAX_TILE);
        let wq: Vec<i8> = (0..kh).map(|_| (rng.gen_usize(255) as i32 - 127) as i8).collect();
        let xq: Vec<i8> = (0..kh).map(|_| (rng.gen_usize(256) as i32 - 128) as i8).collect();
        check(&wq, &xq, &format!("trial {trial} kh={kh}"));
    }
}

/// The kernel itself at the maximum tile size: a single `MAX_TILE`-deep
/// panel packed from extreme weights, driven by extreme activations,
/// must still match the LUT oracle bit-for-bit (the in-situ form of the
/// overflow property above).
#[test]
fn max_tile_kernel_is_bit_identical_to_lut_oracle() {
    use halo::quant::packed::MAX_TILE;
    use halo::runtime::qkernels::{set_force_lut, LUT_TEST_LOCK};
    let _guard = LUT_TEST_LOCK.lock().unwrap();
    let mut rng = Rng::seed_from_u64(23);
    // Two-level alternating weights: codes snap to the table extremes.
    let w = Matrix::from_fn(MAX_TILE, 32, |r, c| {
        0.02 * if (r + c) % 2 == 0 { 1.0 } else { -1.0 }
    });
    let layer = pack_one(&w, None, MAX_TILE, Variant::Bal);
    assert_eq!(layer.tiles.len(), 1, "expected a single MAX_TILE panel");
    let mut x = Matrix::random_normal(3, MAX_TILE, 1.0, &mut rng);
    for v in &mut x.data {
        *v = v.signum() * 8.0; // saturate the A8 grid: |xq| = 127 everywhere
    }
    set_force_lut(false);
    let int_path = qmatmul(&x, &layer);
    set_force_lut(true);
    let oracle = qmatmul(&x, &layer);
    set_force_lut(false);
    assert_eq!(int_path.data, oracle.data, "MAX_TILE panel diverged from LUT oracle");
}

// ---------------------------------------------------------------- model path

/// 1-layer toy config off the shared canonical layout
/// ([`ModelSpec::synthetic`] mirrors model.py::param_specs).
fn tiny_spec() -> ModelSpec {
    ModelSpec::synthetic(13, 8, 1, 2, 16, 8)
}

/// Owned parameter list the helpers build; borrowed into
/// `PackedModel::pack_from` as `(name, shape, data)` views.
type ParamList = Vec<(String, Vec<usize>, Vec<f32>)>;

/// Synthesize parameters + per-layer gradients for `spec`.
fn tiny_params(spec: &ModelSpec, seed: u64) -> (ParamList, BTreeMap<String, Matrix>) {
    let mut rng = Rng::seed_from_u64(seed);
    let mut params = Vec::new();
    let mut grads = BTreeMap::new();
    for (i, (name, shape)) in spec.names.iter().zip(&spec.shapes).enumerate() {
        let n: usize = shape.iter().product();
        let data: Vec<f32> = if name.ends_with(".scale") {
            vec![1.0; n]
        } else if name.ends_with(".bias") || name.ends_with(".b1") || name.ends_with(".b2") {
            vec![0.0; n]
        } else {
            let std = 1.0 / (shape[0] as f32).sqrt();
            (0..n).map(|_| rng.gen_normal() as f32 * std).collect()
        };
        if spec.linear[i] {
            let g = Matrix::from_fn(shape[0], shape[1], |r, _| {
                let base = rng.gen_normal() as f32;
                if r < shape[0] / 2 {
                    base * 5.0
                } else {
                    base * 0.1
                }
            });
            grads.insert(name.clone(), g);
        }
        params.push((name.clone(), shape.clone(), data));
    }
    (params, grads)
}

fn pack_tiny(seed: u64, variant: Variant) -> (ModelSpec, PackedModel) {
    let spec = tiny_spec();
    let (params, grads) = tiny_params(&spec, seed);
    let views = params.iter().map(|(n, s, d)| (n.as_str(), s.as_slice(), d.as_slice()));
    let profile = MacProfile::cached();
    let pm = PackedModel::pack_from(spec.clone(), views, variant, 4, &grads, profile).unwrap();
    (spec, pm)
}

/// Literal inputs for the dense oracle: the packed model's own dequantized
/// weights (the dequantize-then-dense path this PR retires) + dense
/// params, in canonical order, followed by the (b, s) token batch.
fn oracle_inputs(
    spec: &ModelSpec,
    pm: &PackedModel,
    tokens: &[i32],
    b: usize,
    s: usize,
) -> Vec<Literal> {
    let mut out = Vec::new();
    for (i, name) in spec.names.iter().enumerate() {
        if spec.linear[i] {
            let dq = pm.layer(name).expect("linear layer packed").dequantize();
            out.push(Literal::f32(&dq.data, &spec.shapes[i]).unwrap());
        } else {
            let data = pm.dense_param(name).expect("dense param present");
            out.push(Literal::f32(data, &spec.shapes[i]).unwrap());
        }
    }
    out.push(Literal::i32(tokens, &[b, s]).unwrap());
    out
}

#[test]
fn packed_forward_matches_dense_oracle() {
    let (spec, pm) = pack_tiny(10, Variant::Bal);
    let (b, s) = (2usize, spec.seq_len);
    let mut rng = Rng::seed_from_u64(11);
    let tokens: Vec<i32> = (0..b * s).map(|_| rng.gen_usize(spec.vocab) as i32).collect();

    let got = pm.forward(&tokens, b, s).unwrap();
    let inputs = oracle_inputs(&spec, &pm, &tokens, b, s);
    let refs: Vec<&Literal> = inputs.iter().collect();
    let (want, ob, os) = model_forward(&spec, &refs).unwrap();
    assert_eq!((ob, os), (b, s));
    // Per-layer A8 + codebook rounding compounds through the residual
    // stream, so the full-model bound is looser than the single-layer one.
    assert_close(&got, &want, "packed forward", 8e-2);
}

#[test]
fn store_holds_packed_tiles_never_dense_linear() {
    // The acceptance-criterion test: the serving store keeps every linear
    // weight ONLY as packed codebook tiles.
    let (spec, pm) = pack_tiny(12, Variant::Bal);
    assert_eq!(pm.dense_linear_count(), 0, "a linear weight is stored dense");
    let mut n_linear = 0;
    for (i, name) in spec.names.iter().enumerate() {
        if spec.linear[i] {
            n_linear += 1;
            let layer = pm.layer(name).unwrap_or_else(|| panic!("{name} not packed"));
            assert!(!layer.tiles.is_empty(), "{name} has no packed tiles");
            assert!(
                layer.tiles.iter().all(|t| !t.codes.is_empty()),
                "{name} has an empty code tile"
            );
            assert!(pm.dense_param(name).is_none(), "{name} also stored dense");
        } else {
            assert!(pm.layer(name).is_none());
            assert!(pm.dense_param(name).is_some(), "{name} missing from dense store");
        }
    }
    assert_eq!(pm.n_packed(), n_linear);
    // The cost model sees every tile and prices the packed form smaller.
    let cost = pm.cost(&Ladder::paper_systolic());
    assert!(cost.modeled_speedup() > 1.0);
    assert!(cost.bytes_saving() > 3.0, "bytes saving {}", cost.bytes_saving());
}

#[test]
fn quant_executor_serves_decode_end_to_end() {
    let (spec, pm) = pack_tiny(13, Variant::Bal);
    let pm = Arc::new(pm);
    let max_new = 4usize;

    // Expected decode chains straight off the packed forward (sliding
    // window at the context cap), computed in-test.
    let chain = |prefix: &[i32]| -> Vec<i32> {
        let cap = spec.seq_len;
        let mut seq: Vec<i32> = prefix[prefix.len().saturating_sub(cap)..].to_vec();
        let mut out = Vec::new();
        for _ in 0..max_new {
            let s = cap;
            let mut tokens = vec![0i32; s];
            let n = seq.len().min(s);
            tokens[..n].copy_from_slice(&seq[seq.len() - n..]);
            let logits = pm.forward(&tokens, 1, s).unwrap();
            let t = argmax_slice(logits.row(n.max(1) - 1)) as i32;
            out.push(t);
            if seq.len() >= cap {
                seq.remove(0);
            }
            seq.push(t);
        }
        out
    };

    let pm2 = pm.clone();
    let coord = Coordinator::start(
        CoordinatorConfig {
            batcher: BatcherConfig {
                batch_size: 4,
                timeout: std::time::Duration::from_millis(2),
            },
            ..CoordinatorConfig::default()
        },
        move |_shard| {
            Ok(Box::new(QuantExecutor::new(pm2.clone(), 4))
                as Box<dyn halo::coordinator::BatchExecutor>)
        },
    );
    let mut rng = Rng::seed_from_u64(14);
    let prefixes: Vec<Vec<i32>> = (0..12)
        .map(|i| {
            (0..2 + (i % 9)).map(|_| rng.gen_usize(spec.vocab) as i32).collect()
        })
        .collect();
    let rxs: Vec<_> = prefixes
        .iter()
        .map(|p| coord.submit_or_shed(Request::new(p.clone()).max_new(max_new)))
        .collect();
    for (rx, p) in rxs.into_iter().zip(&prefixes) {
        let r = rx.recv_timeout(std::time::Duration::from_secs(60)).unwrap();
        assert!(!r.shed, "request shed");
        assert_eq!(r.tokens.len(), max_new);
        assert!(r.tokens.iter().all(|&t| (0..spec.vocab as i32).contains(&t)));
        assert_eq!(r.tokens, chain(p), "decode chain mismatch for prefix {p:?}");
    }
    coord.shutdown().unwrap();
}

#[test]
fn packed_decode_agrees_with_dense_oracle_decode() {
    // Walk both decode chains in lockstep. If they ever pick different
    // tokens, the dense logits at the two candidates must be within the
    // A8 + codebook noise floor of a tie (the integer path deliberately
    // quantizes activations, so small tie-breaks can flip); a gap beyond
    // that floor is a real divergence. Bit-level pins live in the
    // LUT-oracle tests above and in tests/decode_equiv.rs.
    let (spec, pm) = pack_tiny(15, Variant::AccOpt);
    let s = spec.seq_len;
    let mut seq: Vec<i32> = vec![1, 5, 2];
    for _ in 0..5 {
        let mut tokens = vec![0i32; s];
        let n = seq.len().min(s);
        tokens[..n].copy_from_slice(&seq[seq.len() - n..]);
        let pos = n.max(1) - 1;

        let packed_logits = pm.forward(&tokens, 1, s).unwrap();
        let inputs = oracle_inputs(&spec, &pm, &tokens, 1, s);
        let refs: Vec<&Literal> = inputs.iter().collect();
        let (dense_logits, _, _) = model_forward(&spec, &refs).unwrap();

        let tp = argmax_slice(packed_logits.row(pos));
        let td = argmax_slice(dense_logits.row(pos));
        if tp != td {
            let row = dense_logits.row(pos);
            let gap = (row[tp] - row[td]).abs();
            let floor = 8e-2 * (1.0 + row[td].abs());
            assert!(gap < floor, "decode diverged beyond the A8 noise floor: gap {gap}");
            break;
        }
        if seq.len() >= s {
            seq.remove(0);
        }
        seq.push(tp as i32);
    }
}
