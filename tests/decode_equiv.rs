//! Differential decode-equivalence suite (PR 5): KV-cached incremental
//! decode must produce BIT-IDENTICAL greedy token chains to full-prefix
//! recompute — for the dense parameter path and all three packed HALO
//! variants, through ragged continuous-batching joins/retires, across a
//! KV-cache growth boundary, and past the context-window slide.
//!
//! These tests pin the serving fast path to the oracle: any numerical
//! drift between `forward_incremental` and the full `forward` (summation
//! order, softmax precision, position handling) breaks an exact token
//! comparison here, not a tolerance.
//!
//! No artifacts needed: models are synthesized in-memory from a tiny
//! `ModelSpec`, exactly like `tests/qexec.rs`.

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Duration;

use halo::coordinator::{
    BatchExecutor, BatcherConfig, Coordinator, CoordinatorConfig, QuantExecutor, SubmitSpec,
};
use halo::mac::MacProfile;
use halo::quant::{Matrix, Variant};
use halo::runtime::kvcache::INITIAL_CAP_ROWS;
use halo::runtime::sim::{forward_incremental, forward_logits, DenseParams, ModelSpec, ParamSource};
use halo::runtime::{argmax_slice, DecodeState, KvCache, PackedModel};
use halo::util::Rng;

/// Tiny 2-layer model whose context window (24) exceeds the KV cache's
/// initial capacity (16), so in-window decode crosses a growth boundary.
fn tiny_spec() -> ModelSpec {
    ModelSpec::synthetic(13, 8, 2, 2, 16, 24)
}

type ParamList = Vec<(String, Vec<usize>, Vec<f32>)>;

/// Synthesize parameters + per-layer gradients for `spec`.
fn tiny_params(spec: &ModelSpec, seed: u64) -> (ParamList, BTreeMap<String, Matrix>) {
    let mut rng = Rng::seed_from_u64(seed);
    let mut params = Vec::new();
    let mut grads = BTreeMap::new();
    for (i, (name, shape)) in spec.names.iter().zip(&spec.shapes).enumerate() {
        let n: usize = shape.iter().product();
        let data: Vec<f32> = if name.ends_with(".scale") {
            vec![1.0; n]
        } else if name.ends_with(".bias") || name.ends_with(".b1") || name.ends_with(".b2") {
            vec![0.0; n]
        } else {
            let std = 1.0 / (shape[0] as f32).sqrt();
            (0..n).map(|_| rng.gen_normal() as f32 * std).collect()
        };
        if spec.linear[i] {
            let g = Matrix::from_fn(shape[0], shape[1], |r, _| {
                let base = rng.gen_normal() as f32;
                if r < shape[0] / 2 {
                    base * 5.0
                } else {
                    base * 0.1
                }
            });
            grads.insert(name.clone(), g);
        }
        params.push((name.clone(), shape.clone(), data));
    }
    (params, grads)
}

fn dense_source(spec: &ModelSpec, params: &ParamList) -> DenseParams {
    DenseParams::from_params(
        spec,
        params.iter().map(|(n, s, d)| (n.as_str(), s.as_slice(), d.as_slice())),
    )
    .unwrap()
}

fn pack_tiny(seed: u64, variant: Variant) -> (ModelSpec, PackedModel) {
    let spec = tiny_spec();
    let (params, grads) = tiny_params(&spec, seed);
    let views = params.iter().map(|(n, s, d)| (n.as_str(), s.as_slice(), d.as_slice()));
    let profile = MacProfile::cached();
    let pm = PackedModel::pack_from(spec.clone(), views, variant, 4, &grads, profile).unwrap();
    (spec, pm)
}

fn random_prefix(rng: &mut Rng, vocab: usize, len: usize) -> Vec<i32> {
    (0..len).map(|_| rng.gen_usize(vocab) as i32).collect()
}

/// The recompute oracle: greedy decode where every step re-runs the whole
/// window through the full-prefix forward pass (window slides at the
/// context cap, identical to the serving decode contract).
fn greedy_recompute(
    spec: &ModelSpec,
    p: &dyn ParamSource,
    prefix: &[i32],
    max_new: usize,
) -> Vec<i32> {
    let cap = spec.seq_len;
    let mut window: Vec<i32> = prefix[prefix.len().saturating_sub(cap)..].to_vec();
    let mut out = Vec::new();
    for _ in 0..max_new {
        let tok = if window.is_empty() {
            let logits = forward_logits(spec, p, &[0], 1, 1).unwrap();
            argmax_slice(logits.row(0)) as i32
        } else {
            let n = window.len();
            let logits = forward_logits(spec, p, &window, 1, n).unwrap();
            argmax_slice(logits.row(n - 1)) as i32
        };
        out.push(tok);
        if window.len() >= cap {
            window.remove(0);
        }
        window.push(tok);
    }
    out
}

/// The KV-cached fast path: greedy decode through `forward_incremental`,
/// evaluating only the uncached window suffix each step and re-prefilling
/// after a slide (the `DecodeState` contract, spelled out so the test is
/// an independent mirror of the executor logic). Also returns the peak
/// per-layer cache capacity observed, so growth tests can assert a
/// boundary was actually crossed.
fn greedy_cached(
    spec: &ModelSpec,
    p: &dyn ParamSource,
    prefix: &[i32],
    max_new: usize,
) -> (Vec<i32>, usize) {
    let cap = spec.seq_len;
    let mut window: Vec<i32> = prefix[prefix.len().saturating_sub(cap)..].to_vec();
    let mut cache = KvCache::new(spec.n_layers, spec.d_model);
    let mut out = Vec::new();
    let mut peak_cap = 0usize;
    for _ in 0..max_new {
        let tok = if window.is_empty() {
            let mut scratch = KvCache::new(spec.n_layers, spec.d_model);
            let logits = forward_incremental(spec, p, &[0], 0, &mut scratch, false).unwrap();
            argmax_slice(logits.row(0)) as i32
        } else {
            let cached = cache.len();
            let new = window[cached..].to_vec();
            let logits = forward_incremental(spec, p, &new, cached, &mut cache, false).unwrap();
            argmax_slice(logits.row(logits.rows - 1)) as i32
        };
        peak_cap = peak_cap.max(cache.capacity_rows());
        out.push(tok);
        if window.len() >= cap {
            window.remove(0);
            cache.clear(); // the slide shifts every position
        }
        window.push(tok);
    }
    (out, peak_cap)
}

// ------------------------------------------------------------- dense path

#[test]
fn dense_cached_decode_is_bit_identical_to_recompute() {
    let spec = tiny_spec();
    let (params, _) = tiny_params(&spec, 40);
    let p = dense_source(&spec, &params);
    let mut rng = Rng::seed_from_u64(41);
    // Prefix lengths: empty, short, across the cache-growth boundary
    // (20 > INITIAL_CAP_ROWS), at the context cap, and beyond it.
    for plen in [0usize, 1, 5, 20, 24, 30] {
        let prefix = random_prefix(&mut rng, spec.vocab, plen);
        let want = greedy_recompute(&spec, &p, &prefix, 6);
        let (got, _) = greedy_cached(&spec, &p, &prefix, 6);
        assert_eq!(got, want, "dense decode diverged for prefix length {plen}");
    }
}

#[test]
fn dense_decode_across_cache_growth_boundary() {
    // A 20-token prefix prefills past the cache's initial 16-row
    // capacity: the growth (16 -> 32) must be observed AND change nothing.
    let spec = tiny_spec();
    let (params, _) = tiny_params(&spec, 42);
    let p = dense_source(&spec, &params);
    let mut rng = Rng::seed_from_u64(43);
    let prefix = random_prefix(&mut rng, spec.vocab, 20);
    let (got, peak_cap) = greedy_cached(&spec, &p, &prefix, 3);
    assert!(
        peak_cap > INITIAL_CAP_ROWS,
        "prefix 20 never crossed the {INITIAL_CAP_ROWS}-row boundary (peak {peak_cap})"
    );
    assert_eq!(got, greedy_recompute(&spec, &p, &prefix, 3));

    // And the exact-boundary case: prefill 16, then step across it.
    let prefix16 = random_prefix(&mut rng, spec.vocab, INITIAL_CAP_ROWS);
    let (got16, _) = greedy_cached(&spec, &p, &prefix16, 4);
    assert_eq!(got16, greedy_recompute(&spec, &p, &prefix16, 4));
}

#[test]
fn dense_decode_past_the_context_slide() {
    // Prefix at the cap + enough new tokens that the window slides every
    // step: the cached path re-prefills after each slide and must still
    // match the recompute oracle token for token.
    let spec = tiny_spec();
    let (params, _) = tiny_params(&spec, 44);
    let p = dense_source(&spec, &params);
    let mut rng = Rng::seed_from_u64(45);
    let prefix = random_prefix(&mut rng, spec.vocab, spec.seq_len);
    let want = greedy_recompute(&spec, &p, &prefix, 8);
    let (got, _) = greedy_cached(&spec, &p, &prefix, 8);
    assert_eq!(got, want);
}

// ------------------------------------------------------------ packed paths

#[test]
fn packed_cached_decode_matches_oracle_all_variants() {
    // All three HALO variants, executor-level: the KV-cached QuantExecutor
    // vs the same executor with the cache disabled (the recompute oracle),
    // over a ragged batch. Chains must be identical token for token.
    for (vi, variant) in [Variant::PerfOpt, Variant::Bal, Variant::AccOpt]
        .into_iter()
        .enumerate()
    {
        let (spec, pm) = pack_tiny(50 + vi as u64, variant);
        let pm = Arc::new(pm);
        let mut rng = Rng::seed_from_u64(60 + vi as u64);
        let prefixes: Vec<Vec<i32>> = [0usize, 3, 20, 24, 30]
            .iter()
            .map(|&l| random_prefix(&mut rng, spec.vocab, l))
            .collect();
        let max_new = vec![5usize, 1, 4, 2, 6];

        let mut cached = QuantExecutor::new(pm.clone(), prefixes.len());
        let mut oracle = QuantExecutor::new(pm.clone(), prefixes.len()).with_kv_cache(false);
        let got = cached.generate(&prefixes, &max_new).unwrap();
        let want = oracle.generate(&prefixes, &max_new).unwrap();
        assert_eq!(got, want, "variant {} cached decode diverged", variant.name());
        // And against the pre-PR-5 packed greedy oracle, per request.
        for (p, (&m, chain)) in prefixes.iter().zip(max_new.iter().zip(&got)) {
            if !p.is_empty() {
                assert_eq!(
                    chain,
                    &pm.decode_greedy(p, m).unwrap(),
                    "variant {} diverged from decode_greedy",
                    variant.name()
                );
            }
        }
    }
}

#[test]
fn continuous_batching_join_and_retire_preserve_chains() {
    // Drive begin/step directly with mid-flight joins and retires: two
    // requests decode, a third joins two steps in, finished requests
    // retire immediately. Every chain must equal the solo oracle — the
    // continuous batch never cross-pollutes requests.
    let (spec, pm) = pack_tiny(70, Variant::Bal);
    let pm = Arc::new(pm);
    let mut rng = Rng::seed_from_u64(71);
    let p1 = random_prefix(&mut rng, spec.vocab, 7);
    let p2 = random_prefix(&mut rng, spec.vocab, 19);
    let p3 = random_prefix(&mut rng, spec.vocab, 2);

    let mut exec = QuantExecutor::new(pm.clone(), 4);
    let mut s1 = exec.begin(&p1, 5).unwrap();
    let mut s2 = exec.begin(&p2, 2).unwrap();
    // Two steps with requests 1+2 live.
    for _ in 0..2 {
        let mut active: Vec<&mut DecodeState> = vec![&mut s1, &mut s2];
        exec.step(&mut active).unwrap();
    }
    assert!(s2.done(), "request 2 (max_new 2) retires after 2 steps");
    // Request 3 joins mid-flight; request 2 has retired.
    let mut s3 = exec.begin(&p3, 3).unwrap();
    while !(s1.done() && s3.done()) {
        let mut active: Vec<&mut DecodeState> = Vec::new();
        if !s1.done() {
            active.push(&mut s1);
        }
        if !s3.done() {
            active.push(&mut s3);
        }
        exec.step(&mut active).unwrap();
    }
    assert_eq!(s1.into_generated(), pm.decode_greedy(&p1, 5).unwrap());
    assert_eq!(s2.into_generated(), pm.decode_greedy(&p2, 2).unwrap());
    assert_eq!(s3.into_generated(), pm.decode_greedy(&p3, 3).unwrap());
}

#[test]
fn coordinator_staggered_submissions_decode_correctly() {
    // End to end through the sharded coordinator: requests submitted in
    // waves (so later ones join mid-decode) all come back with chains
    // identical to the solo packed oracle.
    let (spec, pm) = pack_tiny(80, Variant::Bal);
    let pm = Arc::new(pm);
    let pm2 = pm.clone();
    let coord = Coordinator::start_sharded(
        CoordinatorConfig {
            batcher: BatcherConfig { batch_size: 4, timeout: Duration::from_millis(2) },
            shards: 2,
            ..CoordinatorConfig::default()
        },
        move |_shard| {
            Ok(Box::new(QuantExecutor::new(pm2.clone(), 4)) as Box<dyn BatchExecutor>)
        },
    );
    let mut rng = Rng::seed_from_u64(81);
    let mut rxs = Vec::new();
    let mut want = Vec::new();
    for wave in 0..3 {
        for i in 0..4 {
            let prefix = random_prefix(&mut rng, spec.vocab, 1 + (wave * 4 + i) % 22);
            let max_new = 1 + (i + wave) % 4;
            want.push(pm.decode_greedy(&prefix, max_new).unwrap());
            rxs.push(coord.submit_spec(SubmitSpec::generate(prefix, max_new)));
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    for (rx, want) in rxs.into_iter().zip(want) {
        let r = rx.recv_timeout(Duration::from_secs(60)).unwrap();
        assert!(!r.shed);
        assert_eq!(r.tokens, want, "staggered coordinator decode diverged");
    }
    coord.shutdown().unwrap();
}

// --------------------------------------------- work accounting (no padding)

#[test]
fn ragged_batch_work_stays_within_ideal() {
    // The pre-PR-5 decode padded every live request to the batch's
    // longest prefix: a ragged batch paid batch x longest work per step.
    // With KV-cached continuous batching, total positions evaluated must
    // stay within 1.1x of the sum of per-request ideal work
    // (prefill + one position per extra token).
    let (spec, pm) = pack_tiny(90, Variant::Bal);
    let pm = Arc::new(pm);
    let prefixes: Vec<Vec<i32>> = [1usize, 5, 9, 14]
        .iter()
        .map(|&l| (0..l).map(|t| (t % spec.vocab) as i32).collect())
        .collect();
    let max_new = vec![6usize, 4, 2, 1];
    // No slides: longest window stays within the context cap.
    assert!(14 + 6 <= spec.seq_len);

    let mut exec = QuantExecutor::new(pm, prefixes.len());
    exec.generate(&prefixes, &max_new).unwrap();

    let ideal: u64 = prefixes
        .iter()
        .zip(&max_new)
        .map(|(p, &m)| (p.len() + m - 1) as u64)
        .sum();
    let work = exec.work_positions();
    assert!(work >= ideal, "work {work} below ideal {ideal}? counter is broken");
    assert!(
        (work as f64) <= 1.1 * ideal as f64,
        "ragged batch executed {work} positions vs ideal {ideal} — longest-prefix blowup is back"
    );

    // The padded oracle pays strictly more on the same workload.
    let (_, pm_oracle) = pack_tiny(90, Variant::Bal);
    let mut oracle = QuantExecutor::new(Arc::new(pm_oracle), prefixes.len()).with_kv_cache(false);
    oracle.generate(&prefixes, &max_new).unwrap();
    assert!(
        oracle.work_positions() > work,
        "recompute oracle ({}) should exceed cached work ({work})",
        oracle.work_positions()
    );
}

// ------------------------------------------------------- dense + packed mix

#[test]
fn packed_forward_incremental_prefill_matches_packed_forward() {
    // Direct PackedModel surface: prefill logits rows == full forward
    // rows, bit for bit, for every variant.
    for variant in [Variant::PerfOpt, Variant::Bal, Variant::AccOpt] {
        let (spec, pm) = pack_tiny(95, variant);
        let toks: Vec<i32> = (0..spec.seq_len as i32).map(|t| t % spec.vocab as i32).collect();
        let full = pm.forward(&toks, 1, spec.seq_len).unwrap();
        let mut cache = pm.new_cache();
        let inc = pm.forward_incremental(&toks, 0, &mut cache).unwrap();
        assert_eq!(inc.data, full.data, "{} prefill diverged", variant.name());
        assert_eq!(cache.len(), spec.seq_len);
    }
}
