//! Differential decode-equivalence suite (PR 5, reworked for the PR 8
//! paged KV cache): KV-cached incremental decode must produce
//! BIT-IDENTICAL greedy token chains to full-prefix recompute — for the
//! dense parameter path and all three packed HALO variants, through
//! ragged continuous-batching joins/retires, and across paged-block
//! boundaries — and context-window slides must *stream* (re-base the
//! cache, evaluate exactly one token, never re-prefill) with chains that
//! are invariant to the pool's block size.
//!
//! These tests pin the serving fast path to its oracles: any numerical
//! drift between `forward_incremental` and the full `forward` (summation
//! order, softmax precision, position handling) — or any paging bug that
//! reads a stale/mis-indexed block row — breaks an exact token
//! comparison here, not a tolerance.
//!
//! Two oracles since PR 8 (ring positional embedding):
//! - chains that never slide are bit-identical to full-prefix recompute;
//! - chains that slide are pinned by *block-size invariance* (the paged
//!   layout at any block size, including one block spanning the whole
//!   context, must produce identical chains) plus no-re-prefill
//!   assertions, and the packed executor path must equal the solo
//!   `PackedModel::decode_greedy` cached oracle.
//!
//! No artifacts needed: models are synthesized in-memory from a tiny
//! `ModelSpec`, exactly like `tests/qexec.rs`.

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Duration;

use halo::coordinator::{
    BatchExecutor, BatcherConfig, Coordinator, CoordinatorConfig, QuantExecutor, Request,
};
use halo::mac::MacProfile;
use halo::quant::{Matrix, Variant};
use halo::runtime::sim::{forward_incremental, forward_logits, DenseParams, ModelSpec, ParamSource};
use halo::runtime::{argmax_slice, BlockPool, DecodeState, KvCache, PackedModel, DEFAULT_BLOCK_ROWS};
use halo::util::Rng;

/// Tiny 2-layer model whose context window (24) exceeds the default
/// block size (16), so in-window decode crosses a paged-block boundary.
fn tiny_spec() -> ModelSpec {
    ModelSpec::synthetic(13, 8, 2, 2, 16, 24)
}

type ParamList = Vec<(String, Vec<usize>, Vec<f32>)>;

/// Synthesize parameters + per-layer gradients for `spec`.
fn tiny_params(spec: &ModelSpec, seed: u64) -> (ParamList, BTreeMap<String, Matrix>) {
    let mut rng = Rng::seed_from_u64(seed);
    let mut params = Vec::new();
    let mut grads = BTreeMap::new();
    for (i, (name, shape)) in spec.names.iter().zip(&spec.shapes).enumerate() {
        let n: usize = shape.iter().product();
        let data: Vec<f32> = if name.ends_with(".scale") {
            vec![1.0; n]
        } else if name.ends_with(".bias") || name.ends_with(".b1") || name.ends_with(".b2") {
            vec![0.0; n]
        } else {
            let std = 1.0 / (shape[0] as f32).sqrt();
            (0..n).map(|_| rng.gen_normal() as f32 * std).collect()
        };
        if spec.linear[i] {
            let g = Matrix::from_fn(shape[0], shape[1], |r, _| {
                let base = rng.gen_normal() as f32;
                if r < shape[0] / 2 {
                    base * 5.0
                } else {
                    base * 0.1
                }
            });
            grads.insert(name.clone(), g);
        }
        params.push((name.clone(), shape.clone(), data));
    }
    (params, grads)
}

fn dense_source(spec: &ModelSpec, params: &ParamList) -> DenseParams {
    DenseParams::from_params(
        spec,
        params.iter().map(|(n, s, d)| (n.as_str(), s.as_slice(), d.as_slice())),
    )
    .unwrap()
}

fn pack_tiny(seed: u64, variant: Variant) -> (ModelSpec, PackedModel) {
    let spec = tiny_spec();
    let (params, grads) = tiny_params(&spec, seed);
    let views = params.iter().map(|(n, s, d)| (n.as_str(), s.as_slice(), d.as_slice()));
    let profile = MacProfile::cached();
    let pm = PackedModel::pack_from(spec.clone(), views, variant, 4, &grads, profile).unwrap();
    (spec, pm)
}

fn random_prefix(rng: &mut Rng, vocab: usize, len: usize) -> Vec<i32> {
    (0..len).map(|_| rng.gen_usize(vocab) as i32).collect()
}

/// The recompute oracle: greedy decode where every step re-runs the whole
/// window through the full-prefix forward pass. Valid for cached chains
/// that never slide; a slid cached chain intentionally diverges (ring
/// positions stream instead of re-embedding the shifted window).
fn greedy_recompute(
    spec: &ModelSpec,
    p: &dyn ParamSource,
    prefix: &[i32],
    max_new: usize,
) -> Vec<i32> {
    let cap = spec.seq_len;
    let mut window: Vec<i32> = prefix[prefix.len().saturating_sub(cap)..].to_vec();
    let mut out = Vec::new();
    for _ in 0..max_new {
        let tok = if window.is_empty() {
            let logits = forward_logits(spec, p, &[0], 1, 1).unwrap();
            argmax_slice(logits.row(0)) as i32
        } else {
            let n = window.len();
            let logits = forward_logits(spec, p, &window, 1, n).unwrap();
            argmax_slice(logits.row(n - 1)) as i32
        };
        out.push(tok);
        if window.len() >= cap {
            window.remove(0);
        }
        window.push(tok);
    }
    out
}

/// Telemetry from one cached decode: enough to prove the paged contract
/// (streaming slides, bounded blocks, shared seeding) structurally.
#[derive(Debug, Default, Clone, Copy)]
struct CacheTrace {
    /// Longest uncached suffix evaluated on any step AFTER the first
    /// (post-prefill). Streaming decode keeps this at exactly 1 — a
    /// re-prefill would spike it to the window length.
    max_suffix_after_prefill: usize,
    /// Most blocks the request's table ever referenced.
    peak_blocks: usize,
    /// Rows seeded from the pool's shared-prefix registry at creation.
    seeded_rows: usize,
}

/// The KV-cached fast path: greedy decode through `forward_incremental`
/// over a cache carved from `pool`, evaluating only the uncached window
/// suffix each step and RE-BASING the cache at a context slide
/// (`pop_front` — the `DecodeState` contract, spelled out so the test is
/// an independent mirror of the executor logic).
fn greedy_cached(
    spec: &ModelSpec,
    p: &dyn ParamSource,
    prefix: &[i32],
    max_new: usize,
    pool: &Arc<BlockPool>,
) -> (Vec<i32>, CacheTrace) {
    let cap = spec.seq_len;
    let mut window: Vec<i32> = prefix[prefix.len().saturating_sub(cap)..].to_vec();
    let mut cache = pool.new_cache(&window);
    let mut trace = CacheTrace { seeded_rows: cache.shared_rows(), ..CacheTrace::default() };
    let mut out = Vec::new();
    for step in 0..max_new {
        let tok = if window.is_empty() {
            let mut scratch = KvCache::new(spec.n_layers, spec.d_model);
            let logits = forward_incremental(spec, p, &[0], 0, &mut scratch, false).unwrap();
            argmax_slice(logits.row(0)) as i32
        } else {
            let cached = cache.len();
            let new = window[cached..].to_vec();
            if step > 0 {
                trace.max_suffix_after_prefill = trace.max_suffix_after_prefill.max(new.len());
            }
            let logits = forward_incremental(spec, p, &new, cached, &mut cache, false).unwrap();
            argmax_slice(logits.row(logits.rows - 1)) as i32
        };
        trace.peak_blocks = trace.peak_blocks.max(cache.blocks_in_table());
        out.push(tok);
        if window.len() >= cap {
            window.remove(0);
            cache.pop_front(); // the slide re-bases; no clear, no re-prefill
        }
        window.push(tok);
    }
    (out, trace)
}

fn plain_pool(spec: &ModelSpec, block_rows: usize) -> Arc<BlockPool> {
    Arc::new(BlockPool::new(spec.n_layers, spec.d_model, block_rows, 0))
}

// ------------------------------------------------------------- dense path

#[test]
fn dense_cached_decode_is_bit_identical_to_recompute() {
    let spec = tiny_spec();
    let (params, _) = tiny_params(&spec, 40);
    let p = dense_source(&spec, &params);
    let mut rng = Rng::seed_from_u64(41);
    // Prefix lengths: empty, short, across the default 16-row block
    // boundary, at the context cap, and beyond it. Budgets shrink near
    // the cap so no decoded token lands after a slide (slid chains get
    // their own oracle below).
    for plen in [0usize, 1, 5, 20, 24, 30] {
        let prefix = random_prefix(&mut rng, spec.vocab, plen);
        let max_new = (spec.seq_len - plen.min(spec.seq_len) + 1).min(6);
        let want = greedy_recompute(&spec, &p, &prefix, max_new);
        let (got, _) = greedy_cached(&spec, &p, &prefix, max_new, &plain_pool(&spec, 16));
        assert_eq!(got, want, "dense decode diverged for prefix length {plen}");
    }
}

#[test]
fn dense_decode_across_block_boundaries() {
    // A 20-token prefix prefills past the default 16-row block: the
    // table must span blocks AND change nothing numerically; same for
    // the exact-boundary case (prefill 16, then step across the edge).
    let spec = tiny_spec();
    let (params, _) = tiny_params(&spec, 42);
    let p = dense_source(&spec, &params);
    let mut rng = Rng::seed_from_u64(43);
    let prefix = random_prefix(&mut rng, spec.vocab, 20);
    let (got, trace) = greedy_cached(&spec, &p, &prefix, 3, &plain_pool(&spec, DEFAULT_BLOCK_ROWS));
    assert!(
        trace.peak_blocks > 1,
        "prefix 20 never crossed the {DEFAULT_BLOCK_ROWS}-row block boundary ({trace:?})"
    );
    assert_eq!(got, greedy_recompute(&spec, &p, &prefix, 3));

    let prefix16 = random_prefix(&mut rng, spec.vocab, DEFAULT_BLOCK_ROWS);
    let (got16, _) =
        greedy_cached(&spec, &p, &prefix16, 4, &plain_pool(&spec, DEFAULT_BLOCK_ROWS));
    assert_eq!(got16, greedy_recompute(&spec, &p, &prefix16, 4));
}

#[test]
fn dense_slide_streams_without_reprefill_and_is_block_size_invariant() {
    // Prefix at the cap + enough new tokens that the window slides every
    // step. The paged cache must RE-BASE at each slide: every post-
    // prefill step evaluates exactly one token (streaming attention, no
    // re-prefill), the block table stays bounded by the context window,
    // and the chain is identical at every block size — including one
    // block spanning the whole context, where paging degenerates to the
    // contiguous layout.
    let spec = tiny_spec();
    let (params, _) = tiny_params(&spec, 44);
    let p = dense_source(&spec, &params);
    let mut rng = Rng::seed_from_u64(45);
    let prefix = random_prefix(&mut rng, spec.vocab, spec.seq_len);
    let max_new = 8;

    let mut chains = Vec::new();
    for bs in [4usize, DEFAULT_BLOCK_ROWS, spec.seq_len] {
        let pool = plain_pool(&spec, bs);
        let (got, trace) = greedy_cached(&spec, &p, &prefix, max_new, &pool);
        assert_eq!(
            trace.max_suffix_after_prefill, 1,
            "block size {bs}: a slide re-prefilled instead of streaming ({trace:?})"
        );
        let cap_blocks = (spec.seq_len + bs - 1) / bs;
        assert!(
            trace.peak_blocks <= cap_blocks + 1,
            "block size {bs}: table grew unboundedly across slides ({trace:?})"
        );
        assert_eq!(
            pool.stats().blocks_in_use,
            0,
            "block size {bs}: slid-off blocks leaked after the cache dropped"
        );
        chains.push((bs, got));
    }
    for w in chains.windows(2) {
        assert_eq!(
            w[0].1, w[1].1,
            "slide chain differs between block sizes {} and {}",
            w[0].0, w[1].0
        );
    }
    // The first decoded token precedes any slide, so it still matches
    // full-window recompute bit for bit.
    assert_eq!(chains[0].1[0], greedy_recompute(&spec, &p, &prefix, 1)[0]);
}

#[test]
fn dense_shared_prefix_seeding_is_bit_identical() {
    // Two requests share an 8-token header over a sharing pool with
    // 4-row blocks: the first publishes frozen header blocks, the second
    // is seeded from the registry and must decode the exact chain a
    // cold (non-sharing) cache produces — shared blocks are the same
    // rows, not approximately the same.
    let spec = tiny_spec();
    let (params, _) = tiny_params(&spec, 46);
    let p = dense_source(&spec, &params);
    let mut rng = Rng::seed_from_u64(47);
    let header = random_prefix(&mut rng, spec.vocab, 8);
    let suffix = random_prefix(&mut rng, spec.vocab, 5);

    let pool = Arc::new(BlockPool::new(spec.n_layers, spec.d_model, 4, 0).with_sharing(64));
    let (first, t_first) = greedy_cached(&spec, &p, &header, 3, &pool);
    assert_eq!(t_first.seeded_rows, 0, "empty registry must seed nothing");
    assert!(pool.stats().registry_entries >= 2, "header prefill published no blocks");

    let mut full = header.clone();
    full.extend_from_slice(&suffix);
    let (seeded, t_seeded) = greedy_cached(&spec, &p, &full, 4, &pool);
    assert_eq!(t_seeded.seeded_rows, 8, "second request not seeded from the registry");
    let (cold, _) = greedy_cached(&spec, &p, &full, 4, &plain_pool(&spec, 4));
    assert_eq!(seeded, cold, "shared-prefix seeding changed the decoded chain");
    assert_eq!(cold, greedy_recompute(&spec, &p, &full, 4));
    // And the header-only chain was itself correct.
    assert_eq!(first, greedy_recompute(&spec, &p, &header, 3));
}

// ------------------------------------------------------------ packed paths

#[test]
fn packed_cached_decode_matches_oracle_all_variants() {
    // All three HALO variants, executor-level, over a ragged batch that
    // includes sliding chains. The KV-cached QuantExecutor must equal
    // the solo cached oracle (`decode_greedy`) on every request, and
    // equal the cache-disabled recompute executor on every request that
    // never slides.
    for (vi, variant) in [Variant::PerfOpt, Variant::Bal, Variant::AccOpt]
        .into_iter()
        .enumerate()
    {
        let (spec, pm) = pack_tiny(50 + vi as u64, variant);
        let pm = Arc::new(pm);
        let mut rng = Rng::seed_from_u64(60 + vi as u64);
        let plens = [0usize, 3, 20, 24, 30];
        let prefixes: Vec<Vec<i32>> =
            plens.iter().map(|&l| random_prefix(&mut rng, spec.vocab, l)).collect();
        let max_new = vec![5usize, 1, 4, 2, 6];

        let mut cached = QuantExecutor::new(pm.clone(), prefixes.len());
        let mut oracle = QuantExecutor::new(pm.clone(), prefixes.len()).with_kv_cache(false);
        let got = cached.generate(&prefixes, &max_new).unwrap();
        let want = oracle.generate(&prefixes, &max_new).unwrap();
        for (i, (p, (&m, chain))) in
            prefixes.iter().zip(max_new.iter().zip(&got)).enumerate()
        {
            // The solo cached oracle covers every chain, slid or not.
            if !p.is_empty() {
                assert_eq!(
                    chain,
                    &pm.decode_greedy(p, m).unwrap(),
                    "variant {} diverged from decode_greedy",
                    variant.name()
                );
            }
            // The recompute executor is the oracle only while no slide
            // happened (window start + decoded < cap).
            if plens[i].min(spec.seq_len) + m - 1 < spec.seq_len {
                assert_eq!(
                    chain, &want[i],
                    "variant {} cached decode diverged pre-slide",
                    variant.name()
                );
            }
        }
    }
}

#[test]
fn continuous_batching_join_and_retire_preserve_chains() {
    // Drive begin/step directly with mid-flight joins and retires: two
    // requests decode, a third joins two steps in, finished requests
    // retire immediately. Every chain must equal the solo oracle — the
    // continuous batch never cross-pollutes requests.
    let (spec, pm) = pack_tiny(70, Variant::Bal);
    let pm = Arc::new(pm);
    let mut rng = Rng::seed_from_u64(71);
    let p1 = random_prefix(&mut rng, spec.vocab, 7);
    let p2 = random_prefix(&mut rng, spec.vocab, 19);
    let p3 = random_prefix(&mut rng, spec.vocab, 2);

    let mut exec = QuantExecutor::new(pm.clone(), 4);
    let mut s1 = exec.begin(&p1, 5).unwrap();
    let mut s2 = exec.begin(&p2, 2).unwrap();
    // Two steps with requests 1+2 live.
    for _ in 0..2 {
        let mut active: Vec<&mut DecodeState> = vec![&mut s1, &mut s2];
        exec.step(&mut active).unwrap();
    }
    assert!(s2.done(), "request 2 (max_new 2) retires after 2 steps");
    // Request 3 joins mid-flight; request 2 has retired.
    let mut s3 = exec.begin(&p3, 3).unwrap();
    while !(s1.done() && s3.done()) {
        let mut active: Vec<&mut DecodeState> = Vec::new();
        if !s1.done() {
            active.push(&mut s1);
        }
        if !s3.done() {
            active.push(&mut s3);
        }
        exec.step(&mut active).unwrap();
    }
    assert_eq!(s1.into_generated(), pm.decode_greedy(&p1, 5).unwrap());
    assert_eq!(s2.into_generated(), pm.decode_greedy(&p2, 2).unwrap());
    assert_eq!(s3.into_generated(), pm.decode_greedy(&p3, 3).unwrap());
}

#[test]
fn coordinator_staggered_submissions_decode_correctly() {
    // End to end through the sharded coordinator — with per-shard paged
    // BlockPools (sharing on) serving every request cache, exactly the
    // `halo serve` wiring: requests submitted in waves (so later ones
    // join mid-decode) all come back with chains identical to the solo
    // packed oracle, whether their cache was pool-seeded or cold.
    let (spec, pm) = pack_tiny(80, Variant::Bal);
    let pm = Arc::new(pm);
    let pm2 = pm.clone();
    let pools: Vec<Arc<BlockPool>> = (0..2)
        .map(|_| {
            Arc::new(
                BlockPool::new(spec.n_layers, spec.d_model, DEFAULT_BLOCK_ROWS, 0)
                    .with_sharing(64),
            )
        })
        .collect();
    let pools2 = pools.clone();
    let coord = Coordinator::start(
        CoordinatorConfig {
            batcher: BatcherConfig { batch_size: 4, timeout: Duration::from_millis(2) },
            shards: 2,
            ..CoordinatorConfig::default()
        },
        move |shard| {
            let exec = QuantExecutor::new(pm2.clone(), 4).with_kv_pool(pools2[shard].clone());
            Ok(Box::new(exec) as Box<dyn BatchExecutor>)
        },
    );
    let mut rng = Rng::seed_from_u64(81);
    let mut rxs = Vec::new();
    let mut want = Vec::new();
    for wave in 0..3 {
        for i in 0..4 {
            let prefix = random_prefix(&mut rng, spec.vocab, 1 + (wave * 4 + i) % 22);
            let max_new = 1 + (i + wave) % 4;
            want.push(pm.decode_greedy(&prefix, max_new).unwrap());
            rxs.push(coord.submit_or_shed(Request::new(prefix).max_new(max_new)));
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    for (rx, want) in rxs.into_iter().zip(want) {
        let r = rx.recv_timeout(Duration::from_secs(60)).unwrap();
        assert!(!r.shed);
        assert_eq!(r.tokens, want, "staggered coordinator decode diverged");
    }
    coord.shutdown().unwrap();
    // Live caches all dropped at retirement: only frozen registry
    // entries may still hold pool blocks.
    for pool in &pools {
        let s = pool.stats();
        assert!(
            s.blocks_in_use <= s.registry_entries,
            "retired requests leaked pool blocks: {s:?}"
        );
    }
}

// --------------------------------------------- work accounting (no padding)

#[test]
fn ragged_batch_work_stays_within_ideal() {
    // The pre-PR-5 decode padded every live request to the batch's
    // longest prefix: a ragged batch paid batch x longest work per step.
    // With KV-cached continuous batching, total positions evaluated must
    // stay within 1.1x of the sum of per-request ideal work
    // (prefill + one position per extra token).
    let (spec, pm) = pack_tiny(90, Variant::Bal);
    let pm = Arc::new(pm);
    let prefixes: Vec<Vec<i32>> = [1usize, 5, 9, 14]
        .iter()
        .map(|&l| (0..l).map(|t| (t % spec.vocab) as i32).collect())
        .collect();
    let max_new = vec![6usize, 4, 2, 1];
    // No slides: longest window stays within the context cap.
    assert!(14 + 6 <= spec.seq_len);

    let mut exec = QuantExecutor::new(pm, prefixes.len());
    exec.generate(&prefixes, &max_new).unwrap();

    let ideal: u64 = prefixes
        .iter()
        .zip(&max_new)
        .map(|(p, &m)| (p.len() + m - 1) as u64)
        .sum();
    let work = exec.work_positions();
    assert!(work >= ideal, "work {work} below ideal {ideal}? counter is broken");
    assert!(
        (work as f64) <= 1.1 * ideal as f64,
        "ragged batch executed {work} positions vs ideal {ideal} — longest-prefix blowup is back"
    );

    // The padded oracle pays strictly more on the same workload.
    let (_, pm_oracle) = pack_tiny(90, Variant::Bal);
    let mut oracle = QuantExecutor::new(Arc::new(pm_oracle), prefixes.len()).with_kv_cache(false);
    oracle.generate(&prefixes, &max_new).unwrap();
    assert!(
        oracle.work_positions() > work,
        "recompute oracle ({}) should exceed cached work ({work})",
        oracle.work_positions()
    );
}

// ------------------------------------------------------- dense + packed mix

// ------------------------------------------------------ speculative decoding
//
// PR 9: the speculative pipeline (drafter proposes k, verifier scores all
// k+1 positions in one batched incremental pass, longest agreeing prefix
// accepted, both KV block tables rolled back to the accept point) must be
// BIT-IDENTICAL to verifier-only decode for every (drafter, verifier)
// pairing on the ladder, every draft depth, mid-flight join/retire,
// context slides interleaved with rollbacks, and shared-prefix-seeded
// drafter caches. Acceptance-rate physics may change with the pairing —
// tokens may not.

/// Verifier-only KV-cached chain through the real `DecodeState` contract
/// — the oracle every speculative configuration must reproduce exactly
/// (matches the executor's ring re-basing across slides, which
/// `greedy_recompute` intentionally does not).
fn dense_verifier_chain(
    spec: &ModelSpec,
    p: &DenseParams,
    prefix: &[i32],
    max_new: usize,
) -> Vec<i32> {
    let mut s = DecodeState::with_cache(
        prefix,
        max_new,
        spec.seq_len,
        KvCache::new(spec.n_layers, spec.d_model),
    );
    while !s.done() {
        let (new, cached) = s.uncached_suffix().unwrap();
        let logits =
            forward_incremental(spec, p, &new, cached, s.cache_mut().unwrap(), false).unwrap();
        let t = argmax_slice(logits.row(new.len() - 1)) as i32;
        s.push_token(t);
    }
    s.into_generated()
}

#[test]
fn speculative_chains_are_bit_identical_across_the_pairing_matrix() {
    use halo::coordinator::{SpecExecutor, SpecVerifier};
    // {halo-perf, halo-bal} drafters x {dense, halo-acc} verifiers,
    // k in {1, 4, 16}, all packed from the SAME synthesized parameters
    // (the genuine ladder: one model, four rungs). Prefix lengths cover
    // short, block-crossing, and sliding chains (20 + 8 - 1 > cap 24).
    let spec = tiny_spec();
    let (params, grads) = tiny_params(&spec, 110);
    let dense = Arc::new(dense_source(&spec, &params));
    let views = params.iter().map(|(n, s, d)| (n.as_str(), s.as_slice(), d.as_slice()));
    let apm = Arc::new(
        PackedModel::pack_from(spec.clone(), views, Variant::AccOpt, 4, &grads, MacProfile::cached())
            .unwrap(),
    );
    let mut rng = Rng::seed_from_u64(111);
    let plens = [1usize, 5, 20];
    let prefixes: Vec<Vec<i32>> =
        plens.iter().map(|&l| random_prefix(&mut rng, spec.vocab, l)).collect();
    let max_new = vec![6usize, 4, 8];

    let dense_want: Vec<Vec<i32>> = prefixes
        .iter()
        .zip(&max_new)
        .map(|(p, &m)| dense_verifier_chain(&spec, &dense, p, m))
        .collect();
    let packed_want: Vec<Vec<i32>> = prefixes
        .iter()
        .zip(&max_new)
        .map(|(p, &m)| apm.decode_greedy(p, m).unwrap())
        .collect();

    for drafter_variant in [Variant::PerfOpt, Variant::Bal] {
        let views = params.iter().map(|(n, s, d)| (n.as_str(), s.as_slice(), d.as_slice()));
        let dpm = Arc::new(
            PackedModel::pack_from(
                spec.clone(),
                views,
                drafter_variant,
                4,
                &grads,
                MacProfile::cached(),
            )
            .unwrap(),
        );
        for k in [1usize, 4, 16] {
            let mut ex = SpecExecutor::from_packed(
                dpm.clone(),
                SpecVerifier::Dense { spec: spec.clone(), params: dense.clone() },
                k,
                prefixes.len(),
            )
            .unwrap();
            let got = ex.generate(&prefixes, &max_new).unwrap();
            assert_eq!(
                got,
                dense_want,
                "drafter halo-{} k={k} vs dense verifier diverged",
                drafter_variant.name()
            );
            assert!(
                ex.stats().drafted_tokens > 0,
                "drafter halo-{} k={k} never drafted against the dense verifier",
                drafter_variant.name()
            );

            let mut ex = SpecExecutor::from_packed(
                dpm.clone(),
                SpecVerifier::Packed(apm.clone()),
                k,
                prefixes.len(),
            )
            .unwrap();
            let got = ex.generate(&prefixes, &max_new).unwrap();
            assert_eq!(
                got,
                packed_want,
                "drafter halo-{} k={k} vs packed halo-acc verifier diverged",
                drafter_variant.name()
            );
            let st = ex.stats();
            assert!(st.drafted_tokens > 0);
            assert!(st.accepted_tokens <= st.drafted_tokens);
        }
    }
}

#[test]
fn speculative_join_and_retire_mid_flight_preserve_chains() {
    use halo::coordinator::{SpecExecutor, SpecVerifier};
    // Continuous-batching seam: requests join and retire mid-speculation
    // (a speculative step may retire several tokens at once, so retire
    // points land mid-round). Every chain must equal the solo verifier
    // oracle; the drafter's aux state must follow each request through
    // join/retire without cross-pollination.
    let spec = tiny_spec();
    let (params, grads) = tiny_params(&spec, 120);
    let views = params.iter().map(|(n, s, d)| (n.as_str(), s.as_slice(), d.as_slice()));
    let apm = Arc::new(
        PackedModel::pack_from(spec.clone(), views, Variant::AccOpt, 4, &grads, MacProfile::cached())
            .unwrap(),
    );
    let views = params.iter().map(|(n, s, d)| (n.as_str(), s.as_slice(), d.as_slice()));
    let dpm = Arc::new(
        PackedModel::pack_from(
            spec.clone(),
            views,
            Variant::PerfOpt,
            4,
            &grads,
            MacProfile::cached(),
        )
        .unwrap(),
    );
    let mut rng = Rng::seed_from_u64(121);
    let p1 = random_prefix(&mut rng, spec.vocab, 7);
    let p2 = random_prefix(&mut rng, spec.vocab, 19);
    let p3 = random_prefix(&mut rng, spec.vocab, 2);

    let mut exec =
        SpecExecutor::from_packed(dpm.clone(), SpecVerifier::Packed(apm.clone()), 4, 4).unwrap();
    let mut s1 = exec.begin(&p1, 9).unwrap();
    let mut s2 = exec.begin(&p2, 2).unwrap();
    // One round with requests 1+2 live; request 2 (max_new 2) may retire
    // inside it (k_eff is clamped to its remaining budget).
    while !s2.done() {
        let mut active: Vec<&mut DecodeState> = vec![&mut s1, &mut s2];
        exec.step(&mut active).unwrap();
    }
    // Request 3 joins mid-flight; request 2 has retired.
    let mut s3 = exec.begin(&p3, 5).unwrap();
    while !(s1.done() && s3.done()) {
        let mut active: Vec<&mut DecodeState> = Vec::new();
        if !s1.done() {
            active.push(&mut s1);
        }
        if !s3.done() {
            active.push(&mut s3);
        }
        exec.step(&mut active).unwrap();
    }
    assert_eq!(s1.into_generated(), apm.decode_greedy(&p1, 9).unwrap());
    assert_eq!(s2.into_generated(), apm.decode_greedy(&p2, 2).unwrap());
    assert_eq!(s3.into_generated(), apm.decode_greedy(&p3, 5).unwrap());
}

#[test]
fn speculative_context_slides_across_a_rollback_stay_exact() {
    use halo::coordinator::{SpecExecutor, SpecVerifier};
    // Start 6 tokens under the cap with k=16: early rounds draft (and
    // roll back) multi-token batches, the headroom clamp then shrinks
    // k_eff to 0 as the window hits the cap, and the tail of the decode
    // slides every step. The full chain — rollbacks, then slides — must
    // match the verifier-only ring decode bit for bit.
    let spec = tiny_spec();
    let (params, _) = tiny_params(&spec, 130);
    let dense = Arc::new(dense_source(&spec, &params));
    let views = params.iter().map(|(n, s, d)| (n.as_str(), s.as_slice(), d.as_slice()));
    let dpm = Arc::new(
        PackedModel::pack_from(
            spec.clone(),
            views,
            Variant::Bal,
            4,
            &BTreeMap::new(),
            MacProfile::cached(),
        )
        .unwrap(),
    );
    let mut rng = Rng::seed_from_u64(131);
    let prefix = random_prefix(&mut rng, spec.vocab, 18);
    let max_new = 12; // 18 + 12 - 1 = 29 > cap 24: the window slides

    let mut ex = SpecExecutor::from_packed(
        dpm.clone(),
        SpecVerifier::Dense { spec: spec.clone(), params: dense.clone() },
        16,
        1,
    )
    .unwrap();
    let got = ex.generate(&[prefix.clone()], &[max_new]).unwrap();
    assert_eq!(got[0], dense_verifier_chain(&spec, &dense, &prefix, max_new));
    let st = ex.stats();
    assert!(st.drafted_tokens > 0, "no speculation happened before the cap");
    // Every round emits at least one token, and any accepted draft means
    // some round emitted more than one.
    assert!(st.verify_rounds as usize <= max_new);
    if st.accepted_tokens > 0 {
        assert!((st.verify_rounds as usize) < max_new, "accepted drafts saved no rounds");
    }
}

#[test]
fn speculative_shared_prefix_seeded_drafter_is_bit_identical() {
    use halo::coordinator::{SpecExecutor, SpecVerifier};
    // Both sides of the pipeline draw from sharing pools (two pools —
    // each registry must only seed caches with its own K/V numerics).
    // A second request sharing the first's header must be seeded on BOTH
    // the verifier and drafter sides and still decode the exact cold
    // chain.
    let spec = tiny_spec();
    let (params, grads) = tiny_params(&spec, 140);
    let views = params.iter().map(|(n, s, d)| (n.as_str(), s.as_slice(), d.as_slice()));
    let apm = Arc::new(
        PackedModel::pack_from(spec.clone(), views, Variant::AccOpt, 4, &grads, MacProfile::cached())
            .unwrap(),
    );
    let views = params.iter().map(|(n, s, d)| (n.as_str(), s.as_slice(), d.as_slice()));
    let dpm = Arc::new(
        PackedModel::pack_from(
            spec.clone(),
            views,
            Variant::PerfOpt,
            4,
            &grads,
            MacProfile::cached(),
        )
        .unwrap(),
    );
    let mut rng = Rng::seed_from_u64(141);
    let header = random_prefix(&mut rng, spec.vocab, 8);
    let suffix = random_prefix(&mut rng, spec.vocab, 5);
    let mut full = header.clone();
    full.extend_from_slice(&suffix);

    let vpool = Arc::new(BlockPool::new(spec.n_layers, spec.d_model, 4, 0).with_sharing(64));
    let dpool = Arc::new(BlockPool::new(spec.n_layers, spec.d_model, 4, 0).with_sharing(64));
    let mut ex = SpecExecutor::from_packed(dpm.clone(), SpecVerifier::Packed(apm.clone()), 4, 2)
        .unwrap()
        .with_kv_pools(vpool.clone(), dpool.clone());

    // First request publishes frozen header blocks into both registries.
    let first = ex.generate(&[header.clone()], &[3]).unwrap();
    assert_eq!(first[0], apm.decode_greedy(&header, 3).unwrap());
    assert!(vpool.stats().registry_entries >= 1, "verifier registry never populated");
    assert!(dpool.stats().registry_entries >= 1, "drafter registry never populated");

    // Second request is seeded from both registries.
    let seeded = ex.generate(&[full.clone()], &[4]).unwrap();
    assert!(
        dpool.stats().shared_hits >= 1,
        "drafter cache was never seeded from its registry: {:?}",
        dpool.stats()
    );
    assert!(vpool.stats().shared_hits >= 1, "verifier cache was never seeded");

    // Cold oracle: same pairing, no pools at all.
    let mut cold =
        SpecExecutor::from_packed(dpm.clone(), SpecVerifier::Packed(apm.clone()), 4, 2).unwrap();
    let want = cold.generate(&[full.clone()], &[4]).unwrap();
    assert_eq!(seeded, want, "shared-prefix seeding changed a speculative chain");
    assert_eq!(want[0], apm.decode_greedy(&full, 4).unwrap());
}

#[test]
fn packed_forward_incremental_prefill_matches_packed_forward() {
    // Direct PackedModel surface: prefill logits rows == full forward
    // rows, bit for bit, for every variant.
    for variant in [Variant::PerfOpt, Variant::Bal, Variant::AccOpt] {
        let (spec, pm) = pack_tiny(95, variant);
        let toks: Vec<i32> = (0..spec.seq_len as i32).map(|t| t % spec.vocab as i32).collect();
        let full = pm.forward(&toks, 1, spec.seq_len).unwrap();
        let mut cache = pm.new_cache();
        let inc = pm.forward_incremental(&toks, 0, &mut cache).unwrap();
        assert_eq!(inc.data, full.data, "{} prefill diverged", variant.name());
        assert_eq!(cache.len(), spec.seq_len);
    }
}

#[test]
fn greedy_chains_identical_under_integer_and_lut_oracle_kernels() {
    // The ISSUE 10 acceptance pin: the integer W4A8 panel path and the
    // f32 LUT oracle behind `set_force_lut` must produce IDENTICAL
    // greedy token chains (not merely close logits) for every packed
    // variant. Per-tile partial sums fit in 2^24 (see
    // `quant::packed::MAX_TILE`), so both paths compute the same
    // real-number results and any divergence here is a kernel bug.
    // Serialized via LUT_TEST_LOCK so a concurrent toggle cannot make
    // the comparison vacuous.
    use halo::runtime::qkernels::{set_force_lut, LUT_TEST_LOCK};
    let _guard = LUT_TEST_LOCK.lock().unwrap();
    for variant in [Variant::PerfOpt, Variant::Bal, Variant::AccOpt] {
        let (spec, pm) = pack_tiny(151, variant);
        let mut rng = Rng::seed_from_u64(152);
        for (plen, max_new) in [(1usize, 6usize), (9, 5), (20, 8)] {
            let prefix = random_prefix(&mut rng, spec.vocab, plen);
            set_force_lut(false);
            let int_chain = pm.decode_greedy(&prefix, max_new).unwrap();
            set_force_lut(true);
            let lut_chain = pm.decode_greedy(&prefix, max_new).unwrap();
            set_force_lut(false);
            assert_eq!(
                int_chain,
                lut_chain,
                "variant {} plen {plen}: integer path diverged from LUT oracle",
                variant.name()
            );
            assert_eq!(int_chain.len(), max_new);
        }
    }
}
