//! Property-based tests (hand-rolled generators — no proptest crate in the
//! offline build) over the coordinator and quantizer invariants that the
//! paper's correctness argument rests on (§III-C3: scheduling transparency;
//! Algorithm 1: losslessness of the sparse split; DVFS schedule validity).

use halo::coordinator::{BatchExecutor, BatcherConfig, Coordinator};
use halo::dvfs::{FreqClass, Schedule};
use halo::mac::MacProfile;
use halo::quant::baselines::by_name;
use halo::quant::outliers::extract_outliers;
use halo::quant::saliency::extract_salient;
use halo::quant::sparse::SparseMatrix;
use halo::quant::{LayerCtx, Matrix};
use halo::util::Rng;

const CASES: usize = 25;

#[test]
fn prop_schedule_partitions_tiles() {
    // For any class assignment: the clustered schedule executes every tile
    // exactly once, in class-homogeneous groups, with ≤ 3 transitions.
    let mut rng = Rng::seed_from_u64(100);
    for case in 0..CASES {
        let n = 1 + rng.gen_usize(400);
        let classes: Vec<FreqClass> =
            (0..n).map(|_| *rng.choose(&FreqClass::ALL)).collect();
        let s = Schedule::cluster(&classes);
        assert!(s.validate(n, &classes), "case {case}");
        assert!(s.transitions() <= 3);
        assert_eq!(s.n_tiles(), n);
    }
}

#[test]
fn prop_sparse_split_is_lossless() {
    // outliers + salient extraction followed by scatter-back reconstructs
    // the original matrix exactly, for any weights/gradients.
    let mut rng = Rng::seed_from_u64(200);
    for case in 0..CASES {
        let r = 8 + rng.gen_usize(60);
        let c = 8 + rng.gen_usize(60);
        let scale = 10f32.powi(rng.gen_range_i64(-3, 2) as i32);
        let w = Matrix::random_normal(r, c, scale, &mut rng);
        let g = Matrix::random_normal(r, c, 1.0, &mut rng);

        let (w1, salient) = extract_salient(&w, &g, 0.001);
        let ex = extract_outliers(&w1, 3.0);
        let mut coords = salient.clone();
        coords.extend(ex.coords.iter().copied());
        let sp = SparseMatrix::from_coords(r, c, &coords);
        let mut rec = ex.cleaned.clone();
        sp.scatter_into(&mut rec);
        assert_eq!(rec, w, "case {case} ({r}x{c})");
    }
}

#[test]
fn prop_spmv_equals_dense_matmul() {
    let mut rng = Rng::seed_from_u64(300);
    for case in 0..CASES {
        let k = 4 + rng.gen_usize(40);
        let n = 4 + rng.gen_usize(40);
        let m = 1 + rng.gen_usize(6);
        let nnz = rng.gen_usize(k * n / 2);
        let mut used = std::collections::HashSet::new();
        let coords: Vec<_> = (0..nnz)
            .filter_map(|_| {
                let r = rng.gen_usize(k);
                let c = rng.gen_usize(n);
                used.insert((r, c)).then(|| (r, c, rng.gen_normal() as f32))
            })
            .collect();
        let sp = SparseMatrix::from_coords(k, n, &coords);
        let x = Matrix::random_normal(m, k, 1.0, &mut rng);
        let got = sp.spmv(&x);
        let want = x.matmul(&sp.to_dense());
        for (a, b) in got.data.iter().zip(&want.data) {
            assert!((a - b).abs() < 1e-3, "case {case}: {a} vs {b}");
        }
    }
}

#[test]
fn prop_every_quantizer_preserves_shape_and_clock_floor() {
    // Any method on any shape: dequant has the input shape, per-tile
    // frequencies are >= the base class, bits are positive.
    let profile = MacProfile::cached();
    let methods = ["rtn-w8", "rtn-w4", "rtn-w3", "smoothquant-w4", "zq-local",
                   "zq-global", "halo-perf", "halo-bal", "halo-acc"];
    let mut rng = Rng::seed_from_u64(400);
    for case in 0..12 {
        let r = 16 + rng.gen_usize(100);
        let c = 16 + rng.gen_usize(100);
        let tile = *rng.choose(&[16usize, 32, 64]);
        let w = Matrix::random_normal(r, c, 0.05, &mut rng);
        let g = Matrix::random_normal(r, c, 1.0, &mut rng);
        let method = methods[case % methods.len()];
        let q = by_name(method, profile, tile).unwrap();
        let res = q.quantize(&w, &LayerCtx::with_grad("p", &g));
        assert_eq!((res.dequant.rows, res.dequant.cols), (r, c), "{method}");
        assert_eq!(res.tile_freq_ghz.len(), res.grid.n_tiles());
        assert!(res.bits_eff > 0.0);
        for &f in &res.tile_freq_ghz {
            assert!(f >= profile.f_base_ghz - 1e-9, "{method}: {f}");
        }
    }
}

#[test]
fn prop_coordinator_conserves_requests_under_random_load() {
    // Deterministic executor; random request sizes/counts; every request
    // answered once with the right payload.
    struct Sum;
    impl BatchExecutor for Sum {
        fn batch_capacity(&self) -> usize {
            4
        }
        fn seq_len(&self) -> usize {
            64
        }
        fn run(&mut self, p: &[Vec<i32>]) -> anyhow::Result<Vec<i32>> {
            Ok(p.iter().map(|t| t.iter().sum()).collect())
        }
    }
    let mut rng = Rng::seed_from_u64(500);
    for _case in 0..8 {
        let coord = Coordinator::start(
            BatcherConfig { batch_size: 4, timeout: std::time::Duration::from_millis(1) },
            || Ok(Box::new(Sum) as Box<dyn BatchExecutor>),
        );
        let n = 1 + rng.gen_usize(60);
        let mut expected = Vec::new();
        let mut rxs = Vec::new();
        for _ in 0..n {
            let toks: Vec<i32> =
                (0..1 + rng.gen_usize(16)).map(|_| rng.gen_usize(100) as i32).collect();
            expected.push(toks.iter().sum::<i32>());
            rxs.push(coord.submit(toks));
        }
        for (rx, want) in rxs.into_iter().zip(expected) {
            assert_eq!(rx.recv().unwrap().next_token, want);
        }
        coord.shutdown().unwrap();
    }
}

#[test]
fn prop_halo_monotone_accuracy_vs_variant() {
    // For random layers: acc-opt reconstruction error <= perf-opt error
    // (more med-codebook tiles can only help).
    use halo::quant::{HaloConfig, HaloQuantizer, Quantizer, Variant};
    let profile = MacProfile::cached();
    let mut rng = Rng::seed_from_u64(600);
    let mut acc_wins = 0;
    for _ in 0..10 {
        let w = Matrix::random_normal(96, 96, 0.03, &mut rng);
        let g = Matrix::from_fn(96, 96, |r, _| {
            rng.gen_normal() as f32 * if r < 32 { 3.0 } else { 0.1 }
        });
        let ctx = LayerCtx::with_grad("p", &g);
        let e_acc = HaloQuantizer::new(HaloConfig::new(32, Variant::AccOpt), profile)
            .quantize(&w, &ctx)
            .dequant
            .mse(&w);
        let e_perf = HaloQuantizer::new(HaloConfig::new(32, Variant::PerfOpt), profile)
            .quantize(&w, &ctx)
            .dequant
            .mse(&w);
        if e_acc <= e_perf + 1e-12 {
            acc_wins += 1;
        }
    }
    assert!(acc_wins >= 9, "acc-opt lost too often: {acc_wins}/10");
}
