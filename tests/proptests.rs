//! Property-based tests (hand-rolled generators — no proptest crate in the
//! offline build) over the coordinator and quantizer invariants that the
//! paper's correctness argument rests on (§III-C3: scheduling transparency;
//! Algorithm 1: losslessness of the sparse split; DVFS schedule validity).

use std::collections::BTreeMap;
use std::sync::Arc;

use halo::coordinator::{
    BatchExecutor, BatcherConfig, Coordinator, CoordinatorConfig, Metrics, QuantExecutor,
    Request, ShedReason, SupervisorConfig,
};
use halo::dvfs::{FreqClass, Schedule};
use halo::mac::MacProfile;
use halo::quant::baselines::by_name;
use halo::quant::outliers::extract_outliers;
use halo::quant::saliency::extract_salient;
use halo::quant::sparse::SparseMatrix;
use halo::quant::{LayerCtx, Matrix, Variant};
use halo::runtime::sim::{forward_incremental, forward_logits, DenseParams, ModelSpec};
use halo::runtime::{BlockPool, KvCache, PackedModel, PoolExhausted};
use halo::util::Rng;

const CASES: usize = 25;

#[test]
fn prop_schedule_partitions_tiles() {
    // For any class assignment: the clustered schedule executes every tile
    // exactly once, in class-homogeneous groups, with ≤ 3 transitions.
    let mut rng = Rng::seed_from_u64(100);
    for case in 0..CASES {
        let n = 1 + rng.gen_usize(400);
        let classes: Vec<FreqClass> =
            (0..n).map(|_| *rng.choose(&FreqClass::ALL)).collect();
        let s = Schedule::cluster(&classes);
        assert!(s.validate(n, &classes), "case {case}");
        assert!(s.transitions() <= 3);
        assert_eq!(s.n_tiles(), n);
    }
}

#[test]
fn prop_sparse_split_is_lossless() {
    // outliers + salient extraction followed by scatter-back reconstructs
    // the original matrix exactly, for any weights/gradients.
    let mut rng = Rng::seed_from_u64(200);
    for case in 0..CASES {
        let r = 8 + rng.gen_usize(60);
        let c = 8 + rng.gen_usize(60);
        let scale = 10f32.powi(rng.gen_range_i64(-3, 2) as i32);
        let w = Matrix::random_normal(r, c, scale, &mut rng);
        let g = Matrix::random_normal(r, c, 1.0, &mut rng);

        let (w1, salient) = extract_salient(&w, &g, 0.001);
        let ex = extract_outliers(&w1, 3.0);
        let mut coords = salient.clone();
        coords.extend(ex.coords.iter().copied());
        let sp = SparseMatrix::from_coords(r, c, &coords);
        let mut rec = ex.cleaned.clone();
        sp.scatter_into(&mut rec);
        assert_eq!(rec, w, "case {case} ({r}x{c})");
    }
}

#[test]
fn prop_spmv_equals_dense_matmul() {
    let mut rng = Rng::seed_from_u64(300);
    for case in 0..CASES {
        let k = 4 + rng.gen_usize(40);
        let n = 4 + rng.gen_usize(40);
        let m = 1 + rng.gen_usize(6);
        let nnz = rng.gen_usize(k * n / 2);
        let mut used = std::collections::HashSet::new();
        let coords: Vec<_> = (0..nnz)
            .filter_map(|_| {
                let r = rng.gen_usize(k);
                let c = rng.gen_usize(n);
                used.insert((r, c)).then(|| (r, c, rng.gen_normal() as f32))
            })
            .collect();
        let sp = SparseMatrix::from_coords(k, n, &coords);
        let x = Matrix::random_normal(m, k, 1.0, &mut rng);
        let got = sp.spmv(&x);
        let want = x.matmul(&sp.to_dense());
        for (a, b) in got.data.iter().zip(&want.data) {
            assert!((a - b).abs() < 1e-3, "case {case}: {a} vs {b}");
        }
    }
}

#[test]
fn prop_every_quantizer_preserves_shape_and_clock_floor() {
    // Any method on any shape: dequant has the input shape, per-tile
    // frequencies are >= the base class, bits are positive.
    let profile = MacProfile::cached();
    let methods = ["rtn-w8", "rtn-w4", "rtn-w3", "smoothquant-w4", "zq-local",
                   "zq-global", "halo-perf", "halo-bal", "halo-acc"];
    let mut rng = Rng::seed_from_u64(400);
    for case in 0..12 {
        let r = 16 + rng.gen_usize(100);
        let c = 16 + rng.gen_usize(100);
        let tile = *rng.choose(&[16usize, 32, 64]);
        let w = Matrix::random_normal(r, c, 0.05, &mut rng);
        let g = Matrix::random_normal(r, c, 1.0, &mut rng);
        let method = methods[case % methods.len()];
        let q = by_name(method, profile, tile).unwrap();
        let res = q.quantize(&w, &LayerCtx::with_grad("p", &g));
        assert_eq!((res.dequant.rows, res.dequant.cols), (r, c), "{method}");
        assert_eq!(res.tile_freq_ghz.len(), res.grid.n_tiles());
        assert!(res.bits_eff > 0.0);
        for &f in &res.tile_freq_ghz {
            assert!(f >= profile.f_base_ghz - 1e-9, "{method}: {f}");
        }
    }
}

#[test]
fn prop_coordinator_conserves_requests_under_random_load() {
    // Deterministic executor; random request sizes/counts; every request
    // answered once with the right payload.
    struct Sum;
    impl BatchExecutor for Sum {
        fn batch_capacity(&self) -> usize {
            4
        }
        fn seq_len(&self) -> usize {
            64
        }
        fn run(&mut self, p: &[Vec<i32>]) -> anyhow::Result<Vec<i32>> {
            Ok(p.iter().map(|t| t.iter().sum()).collect())
        }
    }
    let mut rng = Rng::seed_from_u64(500);
    for _case in 0..8 {
        let coord = Coordinator::start(
            CoordinatorConfig {
                batcher: BatcherConfig {
                    batch_size: 4,
                    timeout: std::time::Duration::from_millis(1),
                },
                ..CoordinatorConfig::default()
            },
            |_shard| Ok(Box::new(Sum) as Box<dyn BatchExecutor>),
        );
        let n = 1 + rng.gen_usize(60);
        let mut expected = Vec::new();
        let mut rxs = Vec::new();
        for _ in 0..n {
            let toks: Vec<i32> =
                (0..1 + rng.gen_usize(16)).map(|_| rng.gen_usize(100) as i32).collect();
            expected.push(toks.iter().sum::<i32>());
            rxs.push(coord.submit_or_shed(Request::new(toks)));
        }
        for (rx, want) in rxs.into_iter().zip(expected) {
            assert_eq!(rx.recv().unwrap().next_token, want);
        }
        coord.shutdown().unwrap();
    }
}

// ------------------------------------------------ PR 5: KV-cache properties

/// Owned `(name, shape, data)` parameter triples.
type ParamList = Vec<(String, Vec<usize>, Vec<f32>)>;

/// Tiny model + synthesized parameters shared by the KV-cache properties
/// (context 24 > the default 16-row block, so long prefixes span paged
/// block boundaries).
fn kv_model(seed: u64) -> (ModelSpec, ParamList) {
    let spec = ModelSpec::synthetic(13, 8, 2, 2, 16, 24);
    let mut rng = Rng::seed_from_u64(seed);
    let params = spec
        .names
        .iter()
        .zip(&spec.shapes)
        .map(|(name, shape)| {
            let n: usize = shape.iter().product();
            let data: Vec<f32> = if name.ends_with(".scale") {
                vec![1.0; n]
            } else {
                (0..n).map(|_| rng.gen_normal() as f32 * 0.1).collect()
            };
            (name.clone(), shape.clone(), data)
        })
        .collect();
    (spec, params)
}

fn kv_packed(seed: u64) -> (ModelSpec, Arc<PackedModel>) {
    let (spec, params) = kv_model(seed);
    let views = params.iter().map(|(n, s, d)| (n.as_str(), s.as_slice(), d.as_slice()));
    let pm = PackedModel::pack_from(
        spec.clone(),
        views,
        Variant::Bal,
        4,
        &BTreeMap::new(),
        MacProfile::cached(),
    )
    .unwrap();
    (spec, Arc::new(pm))
}

#[test]
fn prop_kv_cached_decode_matches_oracle_for_random_schedules() {
    // Arbitrary seeded prompt lengths (0..=2x context) and max-new
    // schedules (including 0): the KV-cached executor must never panic,
    // must match the solo cached oracle (`decode_greedy`) on every chain
    // — slid or not — and must match the full-window recompute executor
    // on every chain that never slides (ring positions diverge from
    // recompute after a slide by design; see `tests/decode_equiv.rs`).
    let (spec, pm) = kv_packed(700);
    let mut rng = Rng::seed_from_u64(701);
    for case in 0..8 {
        let nreq = 1 + rng.gen_usize(4);
        let prefixes: Vec<Vec<i32>> = (0..nreq)
            .map(|_| {
                let l = rng.gen_usize(2 * spec.seq_len + 1);
                (0..l).map(|_| rng.gen_usize(spec.vocab) as i32).collect()
            })
            .collect();
        let max_new: Vec<usize> = (0..nreq).map(|_| rng.gen_usize(6)).collect();
        let mut cached = QuantExecutor::new(pm.clone(), nreq);
        let mut oracle = QuantExecutor::new(pm.clone(), nreq).with_kv_cache(false);
        let got = cached.generate(&prefixes, &max_new).unwrap();
        let want = oracle.generate(&prefixes, &max_new).unwrap();
        for i in 0..nreq {
            assert_eq!(got[i].len(), max_new[i], "case {case}: wrong decode length");
            if !prefixes[i].is_empty() {
                assert_eq!(
                    got[i],
                    pm.decode_greedy(&prefixes[i], max_new[i]).unwrap(),
                    "case {case}: cached chain diverged from decode_greedy"
                );
            }
            let slides = max_new[i] >= 1
                && prefixes[i].len().min(spec.seq_len) + max_new[i] - 1 > spec.seq_len;
            if !slides {
                assert_eq!(
                    got[i], want[i],
                    "case {case}: no-slide chain diverged from recompute"
                );
            }
        }
    }
}

#[test]
fn prop_incremental_logits_bitexact_at_random_splits() {
    // For any prefill/step split of any window: the incremental logits
    // rows equal the full-prefix rows to 0 ulps (assert_eq on f32 bits),
    // so the final argmax can never drift.
    let (spec, params) = kv_model(710);
    let p = DenseParams::from_params(
        &spec,
        params.iter().map(|(n, s, d)| (n.as_str(), s.as_slice(), d.as_slice())),
    )
    .unwrap();
    let mut rng = Rng::seed_from_u64(711);
    for case in 0..8 {
        let s = 1 + rng.gen_usize(spec.seq_len);
        let toks: Vec<i32> = (0..s).map(|_| rng.gen_usize(spec.vocab) as i32).collect();
        let full = forward_logits(&spec, &p, &toks, 1, s).unwrap();
        let split = 1 + rng.gen_usize(s); // prefill 1..=s positions
        let mut cache = KvCache::new(spec.n_layers, spec.d_model);
        let pre = forward_incremental(&spec, &p, &toks[..split], 0, &mut cache, false).unwrap();
        for r in 0..split {
            assert_eq!(pre.row(r), full.row(r), "case {case}: prefill row {r}");
        }
        for i in split..s {
            let one =
                forward_incremental(&spec, &p, &toks[i..i + 1], i, &mut cache, false).unwrap();
            assert_eq!(one.row(0), full.row(i), "case {case}: step row {i}");
        }
        assert_eq!(cache.len(), s);
        assert!(cache.is_consistent());
    }
}

#[test]
fn prop_paged_cache_blocks_track_length_and_rows_read_back() {
    // Arbitrary append/commit schedules over random block sizes: the
    // block table holds exactly ceil(rows / block_rows) blocks, the
    // pool's occupancy matches the table, committed length tracks
    // appends, and every row reads back exactly what was appended
    // (paging never moves or aliases data).
    let mut rng = Rng::seed_from_u64(720);
    for case in 0..CASES {
        let d = 1 + rng.gen_usize(8);
        let layers = 1 + rng.gen_usize(3);
        let bs = 1 + rng.gen_usize(8);
        let pool = Arc::new(BlockPool::new(layers, d, bs, 0));
        let mut c = pool.new_cache(&[]);
        let mut mirror: Vec<Vec<f32>> = vec![Vec::new(); layers];
        let mut total = 0usize;
        for _ in 0..1 + rng.gen_usize(6) {
            let n = 1 + rng.gen_usize(12);
            for l in 0..layers {
                let k = Matrix::from_fn(n, d, |_, _| rng.gen_normal() as f32);
                let v = Matrix::from_fn(n, d, |_, _| rng.gen_normal() as f32);
                mirror[l].extend_from_slice(&k.data);
                c.append(l, &k, &v).unwrap();
            }
            let toks: Vec<i32> = (0..n as i32).collect();
            c.commit(&toks).unwrap();
            total += n;
            assert_eq!(c.len(), total, "case {case}");
            assert!(c.is_consistent());
            let want_blocks = (total + bs - 1) / bs;
            assert_eq!(c.blocks_in_table(), want_blocks, "case {case} (bs {bs})");
            assert_eq!(pool.stats().blocks_in_use, want_blocks, "case {case}");
            assert!(c.capacity_rows() >= total, "case {case}");
        }
        // Every K row reads back exactly (paging never moved data).
        for (l, m) in mirror.iter().enumerate() {
            for r in 0..total {
                assert_eq!(c.layer(l).k_row(r), &m[r * d..(r + 1) * d], "case {case}");
            }
        }
        drop(c);
        assert_eq!(pool.stats().blocks_in_use, 0, "case {case}: drop must release all");
    }
}

#[test]
fn prop_pool_block_conservation_under_random_fork_release() {
    // PR 8 leak/double-free property: random interleavings of cache
    // creation (acquire, possibly seeded from shared prefixes — the
    // copy-on-write fork), appends, slides, clears, and drops (release)
    // over a BOUNDED sharing pool. Invariants at every step: occupancy
    // never exceeds the bound, every allocated block is reachable from a
    // live table or the registry, exhaustion surfaces as a typed
    // `PoolExhausted` (never a panic or a wedged pool), and when the
    // last cache drops, occupancy drains to exactly the registry's
    // entries — no leaks, and (via the RAII permits' saturating
    // accounting) no double-frees.
    let mut rng = Rng::seed_from_u64(740);
    for case in 0..CASES {
        let bs = 1 + rng.gen_usize(4);
        let max_blocks = 8 + rng.gen_usize(24);
        let pool = Arc::new(BlockPool::new(1, 2, bs, max_blocks).with_sharing(8));
        // All-same-token windows make prefix collisions (and thus shared
        // seeding) the common case rather than the lucky one.
        let mut caches: Vec<KvCache> = Vec::new();
        for step in 0..60 {
            match rng.gen_usize(5) {
                0 => {
                    let window = vec![7i32; 1 + rng.gen_usize(3 * bs)];
                    caches.push(pool.new_cache(&window));
                }
                1 if !caches.is_empty() => {
                    let i = rng.gen_usize(caches.len());
                    let n = 1 + rng.gen_usize(2 * bs);
                    let k = Matrix::from_fn(n, 2, |_, _| 1.0);
                    let toks = vec![7i32; n];
                    match caches[i].append(0, &k, &k) {
                        Ok(()) => caches[i].commit(&toks).unwrap(),
                        Err(e) => {
                            assert!(
                                e.downcast_ref::<PoolExhausted>().is_some(),
                                "case {case} step {step}: non-exhaustion append error {e}"
                            );
                            // The coordinator contract: a failed step
                            // clears (releases) and the request re-prefills
                            // or sheds.
                            caches[i].clear();
                        }
                    }
                }
                2 if !caches.is_empty() => {
                    let i = rng.gen_usize(caches.len());
                    caches[i].pop_front();
                }
                3 if !caches.is_empty() => {
                    let i = rng.gen_usize(caches.len());
                    caches.swap_remove(i);
                }
                4 if !caches.is_empty() => {
                    let i = rng.gen_usize(caches.len());
                    caches[i].clear();
                }
                _ => {}
            }
            let s = pool.stats();
            assert!(
                s.blocks_in_use <= max_blocks,
                "case {case} step {step}: bound violated ({s:?})"
            );
            let reachable: usize =
                caches.iter().map(|c| c.blocks_in_table()).sum::<usize>() + s.registry_entries;
            assert!(
                s.blocks_in_use <= reachable,
                "case {case} step {step}: leaked blocks ({} in use, {} reachable)",
                s.blocks_in_use,
                reachable
            );
        }
        caches.clear();
        let s = pool.stats();
        assert_eq!(
            s.blocks_in_use, s.registry_entries,
            "case {case}: after dropping every cache only registry blocks may remain ({s:?})"
        );
    }
}

#[test]
fn prop_kv_coordinator_answers_everything_without_shedding() {
    // Random staggered load through a KV-cached coordinator with
    // unbounded queues and no deadlines: every request must be answered
    // exactly once, never shed, with the oracle's exact chain.
    let (spec, pm) = kv_packed(730);
    let mut rng = Rng::seed_from_u64(731);
    for _case in 0..3 {
        let pm2 = pm.clone();
        let coord = Coordinator::start(
            CoordinatorConfig {
                batcher: BatcherConfig {
                    batch_size: 4,
                    timeout: std::time::Duration::from_millis(1),
                },
                ..CoordinatorConfig::default()
            },
            move |_shard| Ok(Box::new(QuantExecutor::new(pm2.clone(), 4)) as Box<dyn BatchExecutor>),
        );
        let n = 3 + rng.gen_usize(10);
        let mut rxs = Vec::new();
        let mut want = Vec::new();
        for _ in 0..n {
            let l = 1 + rng.gen_usize(spec.seq_len);
            let prefix: Vec<i32> = (0..l).map(|_| rng.gen_usize(spec.vocab) as i32).collect();
            let m = 1 + rng.gen_usize(3);
            want.push(pm.decode_greedy(&prefix, m).unwrap());
            rxs.push(coord.submit_or_shed(Request::new(prefix).max_new(m)));
        }
        for (rx, want) in rxs.into_iter().zip(want) {
            let r = rx.recv_timeout(std::time::Duration::from_secs(60)).unwrap();
            assert!(!r.shed, "shed without queue pressure or deadlines");
            assert_eq!(r.tokens, want);
            assert!(rx.recv_timeout(std::time::Duration::from_millis(1)).is_err());
        }
        coord.shutdown().unwrap();
    }
}

#[test]
fn prop_merged_percentiles_equal_union_recompute() {
    // For any shard count and any per-shard sample sizes (including empty
    // shards): Metrics::merged reports exactly the percentiles of the
    // union of all per-shard latency samples, and counters sum exactly.
    use halo::util::sync::atomic::Ordering;
    use std::time::Duration;
    let mut rng = Rng::seed_from_u64(800);
    for case in 0..CASES {
        let nshards = 1 + rng.gen_usize(6);
        let shards: Vec<Metrics> = (0..nshards).map(|_| Metrics::default()).collect();
        let mut union: Vec<u64> = Vec::new();
        // Recovery-side counters (PR 7): per-shard restart/retry/brown-out
        // tallies and per-reason shed counts must sum exactly, element-wise
        // for the reason vector.
        let (mut restarts, mut retries, mut brownouts) = (0u64, 0u64, 0u64);
        let mut reasons = [0u64; 5];
        for m in &shards {
            for _ in 0..rng.gen_usize(40) {
                let us = rng.gen_usize(1_000_000) as u64;
                union.push(us);
                m.record_latency(Duration::from_micros(us));
                m.responses.fetch_add(1, Ordering::Relaxed);
            }
            let (r, t, b) =
                (rng.gen_usize(4) as u64, rng.gen_usize(9) as u64, rng.gen_usize(3) as u64);
            m.shard_restarts.fetch_add(r, Ordering::Relaxed);
            m.retries.fetch_add(t, Ordering::Relaxed);
            m.brownout_steps.fetch_add(b, Ordering::Relaxed);
            restarts += r;
            retries += t;
            brownouts += b;
            for (i, reason) in ShedReason::ALL.into_iter().enumerate() {
                let k = rng.gen_usize(5) as u64;
                m.shed_reason_counter(reason).fetch_add(k, Ordering::Relaxed);
                reasons[i] += k;
            }
        }
        let views: Vec<&Metrics> = shards.iter().collect();
        let merged = Metrics::merged(&views);
        union.sort_unstable();
        assert_eq!(merged.latencies_us, union, "case {case}: union mismatch");
        assert_eq!(merged.responses, union.len() as u64, "case {case}");
        assert_eq!(
            (merged.shard_restarts, merged.retries, merged.brownout_steps),
            (restarts, retries, brownouts),
            "case {case}: recovery counters must sum across shards"
        );
        assert_eq!(merged.shed_reasons, reasons, "case {case}: reason vector must sum");
        assert_eq!(merged.shed_reason_total(), reasons.iter().sum::<u64>(), "case {case}");
        for p in [0.0, 0.5, 0.95, 0.99, 1.0] {
            let want = (!union.is_empty())
                .then(|| Duration::from_micros(union[((union.len() - 1) as f64 * p) as usize]));
            assert_eq!(merged.percentile_latency(p), want, "case {case} p={p}");
        }
    }
}

#[test]
fn prop_random_executor_faults_never_panic_and_answer_exactly_once() {
    // PR 7 robustness property: an executor that randomly panics and
    // errors (seeded, per-shard streams — faults injected at the executor
    // boundary rather than through the process-global failpoint registry,
    // which `tests/chaos.rs` owns and which would leak across the tests
    // running concurrently in this binary) must never panic the
    // coordinator: every request is answered exactly once (served with
    // the oracle chain or shed with a reason), the books balance, and
    // shutdown joins every supervised shard cleanly.
    use halo::util::sync::atomic::{AtomicU64, Ordering};
    use std::time::Duration;

    struct ChaosExec {
        rng: Rng,
        panic_prob: f64,
        err_prob: f64,
    }
    impl BatchExecutor for ChaosExec {
        fn batch_capacity(&self) -> usize {
            4
        }
        fn seq_len(&self) -> usize {
            32
        }
        fn run(&mut self, p: &[Vec<i32>]) -> anyhow::Result<Vec<i32>> {
            let roll = self.rng.gen_f64();
            if roll < self.panic_prob {
                panic!("chaos executor: injected panic");
            }
            anyhow::ensure!(roll >= self.panic_prob + self.err_prob, "chaos: injected error");
            Ok(p.iter().map(|t| t.iter().sum::<i32>() % 89).collect())
        }
    }
    // The un-faulted greedy chain (prefix + generated stay under seq_len
    // 32 here, so the window never slides).
    fn sum_chain(prefix: &[i32], steps: usize) -> Vec<i32> {
        let mut seq = prefix.to_vec();
        let mut out = Vec::new();
        for _ in 0..steps {
            let t = seq.iter().sum::<i32>() % 89;
            out.push(t);
            seq.push(t);
        }
        out
    }

    let mut rng = Rng::seed_from_u64(900);
    for case in 0..6u64 {
        let cfg = CoordinatorConfig {
            batcher: BatcherConfig { batch_size: 4, timeout: Duration::from_millis(1) },
            shards: 1 + rng.gen_usize(3),
            queue_cap: 0,
            default_deadline: None,
            supervisor: SupervisorConfig {
                backoff_base: Duration::from_millis(1),
                backoff_cap: Duration::from_millis(4),
                ..SupervisorConfig::default()
            },
        };
        // Every respawn gets a fresh, distinct fault stream.
        let spawn_ctr = Arc::new(AtomicU64::new(0));
        let coord = Coordinator::start(cfg, move |shard| {
            let k = spawn_ctr.fetch_add(1, Ordering::Relaxed);
            Ok(Box::new(ChaosExec {
                rng: Rng::seed_from_u64(0x5eed ^ (case << 24) ^ ((shard as u64) << 16) ^ k),
                panic_prob: 0.05,
                err_prob: 0.10,
            }) as Box<dyn BatchExecutor>)
        });

        let n = 20 + rng.gen_usize(30);
        let mut rxs = Vec::with_capacity(n);
        let mut prefixes = Vec::with_capacity(n);
        for _ in 0..n {
            let prefix: Vec<i32> =
                (0..1 + rng.gen_usize(8)).map(|_| rng.gen_usize(89) as i32).collect();
            rxs.push(
                coord.submit_or_shed(Request::new(prefix.clone()).max_new(1 + rng.gen_usize(3))),
            );
            prefixes.push(prefix);
        }
        let (mut served, mut shed) = (0u64, 0u64);
        for (rx, prefix) in rxs.iter().zip(&prefixes) {
            let r = rx
                .recv_timeout(Duration::from_secs(30))
                .unwrap_or_else(|e| panic!("case {case}: request unanswered: {e}"));
            if r.shed {
                assert!(r.reason.is_some(), "case {case}: shed without a reason");
                shed += 1;
            } else {
                assert_eq!(
                    r.tokens,
                    sum_chain(prefix, r.tokens.len()),
                    "case {case}: served chain diverged from the oracle"
                );
                served += 1;
            }
            assert!(
                rx.recv_timeout(Duration::from_millis(2)).is_err(),
                "case {case}: a request answered twice"
            );
        }
        let snap = coord.merged_snapshot();
        assert_eq!(snap.requests, n as u64, "case {case}");
        assert_eq!(snap.requests, snap.responses + snap.shed + snap.rejected, "case {case}");
        assert_eq!(snap.shed_reason_total(), snap.shed + snap.rejected, "case {case}");
        assert_eq!((snap.responses, snap.shed + snap.rejected), (served, shed), "case {case}");
        coord.shutdown().unwrap_or_else(|e| panic!("case {case}: panic escaped supervisor: {e}"));
    }
}

// --------------------------------------------- PR 9: sampling + rollback

#[test]
fn prop_seeded_sampling_is_deterministic_across_runs_and_shard_counts() {
    // Same per-request seed + params => bit-identical sampled chains,
    // run twice on one shard and once across four (the sampler RNG is
    // per-request state, so shard placement must be unobservable).
    use halo::runtime::SamplingParams;
    let (spec, pm) = kv_packed(750);
    let mut rng = Rng::seed_from_u64(751);
    let reqs: Vec<(Vec<i32>, usize, SamplingParams)> = (0..10)
        .map(|i| {
            let l = 1 + rng.gen_usize(spec.seq_len);
            let prefix: Vec<i32> = (0..l).map(|_| rng.gen_usize(spec.vocab) as i32).collect();
            let m = 2 + rng.gen_usize(4);
            let sp = SamplingParams::new(0xA0 + i as u64)
                .temperature(0.6 + 0.15 * (i % 3) as f64)
                .top_k(4 + i % 5);
            (prefix, m, sp)
        })
        .collect();

    let run = |shards: usize| -> Vec<Vec<i32>> {
        let pm2 = pm.clone();
        let coord = Coordinator::start(
            CoordinatorConfig {
                batcher: BatcherConfig {
                    batch_size: 4,
                    timeout: std::time::Duration::from_millis(1),
                },
                shards,
                ..CoordinatorConfig::default()
            },
            move |_shard| Ok(Box::new(QuantExecutor::new(pm2.clone(), 4)) as Box<dyn BatchExecutor>),
        );
        let rxs: Vec<_> = reqs
            .iter()
            .map(|(p, m, sp)| {
                coord.submit_or_shed(Request::new(p.clone()).max_new(*m).sampling(*sp))
            })
            .collect();
        let out: Vec<Vec<i32>> = rxs
            .into_iter()
            .map(|rx| {
                let r = rx.recv_timeout(std::time::Duration::from_secs(60)).unwrap();
                assert!(!r.shed, "sampled request shed without pressure");
                r.tokens
            })
            .collect();
        coord.shutdown().unwrap();
        out
    };

    let a = run(1);
    let b = run(1);
    let c = run(4);
    assert_eq!(a, b, "same seed, same shard count: chains must replay exactly");
    assert_eq!(a, c, "shard placement leaked into a sampled chain");
    for ((_, m, _), chain) in reqs.iter().zip(&a) {
        assert_eq!(chain.len(), *m, "short sampled decode");
        assert!(chain.iter().all(|&t| (0..spec.vocab as i32).contains(&t)));
    }
    // The sampler must actually sample: across ~40 tempered draws over a
    // 13-token vocab, at least one token deviates from the greedy chain.
    let greedy: Vec<Vec<i32>> =
        reqs.iter().map(|(p, m, _)| pm.decode_greedy(p, *m).unwrap()).collect();
    assert_ne!(a, greedy, "seeded sampling never left the greedy chain — sampler inert?");
}

#[test]
fn prop_rollback_schedules_conserve_pool_blocks() {
    // PR 9 speculative-rollback property: random interleavings of cache
    // creation (possibly seeded from shared prefixes), append+commit,
    // truncate_to (the accept/reject rollback — to ANY point, including
    // 0 and the current length), slides, clears and drops over a BOUNDED
    // sharing pool. The PR 8 conservation law must keep holding: the
    // bound is never exceeded, every live block is reachable, rollback
    // never leaks a released tail block and never double-frees a shared
    // one, and draining every cache leaves exactly the registry behind.
    let mut rng = Rng::seed_from_u64(760);
    for case in 0..CASES {
        let bs = 1 + rng.gen_usize(4);
        let max_blocks = 8 + rng.gen_usize(24);
        let pool = Arc::new(BlockPool::new(1, 2, bs, max_blocks).with_sharing(8));
        let mut caches: Vec<KvCache> = Vec::new();
        for step in 0..60 {
            match rng.gen_usize(6) {
                0 => {
                    let window = vec![7i32; 1 + rng.gen_usize(3 * bs)];
                    caches.push(pool.new_cache(&window));
                }
                1 if !caches.is_empty() => {
                    let i = rng.gen_usize(caches.len());
                    let n = 1 + rng.gen_usize(2 * bs);
                    let k = Matrix::from_fn(n, 2, |_, _| 1.0);
                    let toks = vec![7i32; n];
                    match caches[i].append(0, &k, &k) {
                        Ok(()) => caches[i].commit(&toks).unwrap(),
                        Err(e) => {
                            assert!(
                                e.downcast_ref::<PoolExhausted>().is_some(),
                                "case {case} step {step}: non-exhaustion append error {e}"
                            );
                            caches[i].clear();
                        }
                    }
                }
                2 if !caches.is_empty() => {
                    // The speculative rollback: rewind to a random accept
                    // point. May itself hit the bound (re-opening a frozen
                    // shared tail forks a block) — that must surface as
                    // PoolExhausted, after which clear() recovers.
                    let i = rng.gen_usize(caches.len());
                    let len = caches[i].len();
                    let keep = rng.gen_usize(len + 1);
                    match caches[i].truncate_to(keep) {
                        Ok(()) => assert_eq!(caches[i].len(), keep, "case {case} step {step}"),
                        Err(e) => {
                            assert!(
                                e.downcast_ref::<PoolExhausted>().is_some(),
                                "case {case} step {step}: non-exhaustion rollback error {e}"
                            );
                            caches[i].clear();
                        }
                    }
                }
                3 if !caches.is_empty() => {
                    let i = rng.gen_usize(caches.len());
                    caches[i].pop_front();
                }
                4 if !caches.is_empty() => {
                    let i = rng.gen_usize(caches.len());
                    caches.swap_remove(i);
                }
                5 if !caches.is_empty() => {
                    let i = rng.gen_usize(caches.len());
                    caches[i].clear();
                }
                _ => {}
            }
            let s = pool.stats();
            assert!(
                s.blocks_in_use <= max_blocks,
                "case {case} step {step}: bound violated ({s:?})"
            );
            let reachable: usize =
                caches.iter().map(|c| c.blocks_in_table()).sum::<usize>() + s.registry_entries;
            assert!(
                s.blocks_in_use <= reachable,
                "case {case} step {step}: leaked blocks ({} in use, {} reachable)",
                s.blocks_in_use,
                reachable
            );
        }
        caches.clear();
        let s = pool.stats();
        assert_eq!(
            s.blocks_in_use, s.registry_entries,
            "case {case}: after dropping every cache only registry blocks may remain ({s:?})"
        );
    }
}

#[test]
fn prop_halo_monotone_accuracy_vs_variant() {
    // For random layers: acc-opt reconstruction error <= perf-opt error
    // (more med-codebook tiles can only help).
    use halo::quant::{HaloConfig, HaloQuantizer, Quantizer, Variant};
    let profile = MacProfile::cached();
    let mut rng = Rng::seed_from_u64(600);
    let mut acc_wins = 0;
    for _ in 0..10 {
        let w = Matrix::random_normal(96, 96, 0.03, &mut rng);
        let g = Matrix::from_fn(96, 96, |r, _| {
            rng.gen_normal() as f32 * if r < 32 { 3.0 } else { 0.1 }
        });
        let ctx = LayerCtx::with_grad("p", &g);
        let e_acc = HaloQuantizer::new(HaloConfig::new(32, Variant::AccOpt), profile)
            .quantize(&w, &ctx)
            .dequant
            .mse(&w);
        let e_perf = HaloQuantizer::new(HaloConfig::new(32, Variant::PerfOpt), profile)
            .quantize(&w, &ctx)
            .dequant
            .mse(&w);
        if e_acc <= e_perf + 1e-12 {
            acc_wins += 1;
        }
    }
    assert!(acc_wins >= 9, "acc-opt lost too often: {acc_wins}/10");
}
