//! Integration tests across the three layers: artifacts → runtime →
//! quantizers → evaluation → coordinator. All tests that need artifacts
//! skip cleanly when `make artifacts` has not run; they execute through
//! whichever runtime backend the build selected (sim by default).

use std::collections::BTreeMap;

use halo::coordinator::server::GraphExecutor;
use halo::coordinator::{Coordinator, CoordinatorConfig, Request};
use halo::dvfs::Schedule;
use halo::mac::MacProfile;
use halo::model::{calibrate_fisher, Evaluator};
use halo::quant::baselines::by_name;
use halo::quant::nonuniform::{dequantize_tile, quantize_tile, Codebook};
use halo::quant::{LayerCtx, Matrix, TileGrid};
use halo::runtime::{literal_f32, literal_i8, Runtime, Store};
use halo::util::Rng;

macro_rules! need_artifacts {
    () => {
        match Store::open_default() {
            Ok(s) => s,
            Err(_) => {
                eprintln!("skipping: run `make artifacts` first");
                return;
            }
        }
    };
}

#[test]
fn store_loads_models_and_corpora() {
    let store = need_artifacts!();
    let names = store.model_names().unwrap();
    assert!(names.contains(&"tiny".to_string()));
    let model = store.model("tiny").unwrap();
    assert!(model.n_weights() > 100_000);
    assert!(model.linear_params().count() >= 9);
    for corpus in ["wikisyn", "c4syn"] {
        let s = store.corpus_eval(corpus).unwrap();
        assert!(s.len() > 10_000);
        assert!(s.iter().all(|&t| (t as usize) < model.vocab));
    }
}

#[test]
fn fp16_perplexity_sane() {
    let store = need_artifacts!();
    let rt = Runtime::cpu().unwrap();
    let model = store.model("tiny").unwrap();
    let ev = Evaluator::new(&rt, &model).unwrap();
    let stream = store.corpus_eval("wikisyn").unwrap();
    let (nll, n) = ev.mean_nll(&BTreeMap::new(), &stream, false, 3).unwrap();
    assert!(n >= 1);
    let ppl = nll.exp();
    // Trained: far below uniform (vocab=256); above the corpus entropy floor.
    assert!(ppl < 150.0, "ppl {ppl}");
    assert!(ppl > 5.0, "ppl {ppl}");
}

#[test]
fn w8_quantization_is_nearly_free_and_w3_hurts() {
    let store = need_artifacts!();
    let rt = Runtime::cpu().unwrap();
    let model = store.model("tiny").unwrap();
    let ev = Evaluator::new(&rt, &model).unwrap();
    let stream = store.corpus_eval("wikisyn").unwrap();
    let profile = MacProfile::cached();
    let grads = BTreeMap::new();

    let ppl = |method: &str| {
        let q = by_name(method, profile, 128).unwrap();
        ev.eval_quantizer(q.as_ref(), &grads, &stream, "wikisyn", 3, true)
            .unwrap()
            .ppl
    };
    let (fp, _) = ev.mean_nll(&BTreeMap::new(), &stream, false, 3).unwrap();
    let fp = fp.exp();
    let w8 = ppl("rtn-w8");
    let w3 = ppl("rtn-w3");
    assert!((w8 - fp).abs() / fp < 0.05, "w8 {w8} vs fp {fp}");
    assert!(w3 > w8, "w3 {w3} !> w8 {w8}");
}

#[test]
fn halo_beats_rtn_w3_with_calibration() {
    let store = need_artifacts!();
    let rt = Runtime::cpu().unwrap();
    let model = store.model("tiny").unwrap();
    let calib = store.corpus_calib().unwrap();
    let grads = calibrate_fisher(&rt, &model, &calib, 2).unwrap();
    // Fisher gradients exist for every linear weight and are non-trivial.
    assert_eq!(grads.len(), model.linear_params().count());
    for (name, g) in &grads {
        assert!(g.data.iter().any(|&x| x != 0.0), "{name} all-zero grads");
    }

    let ev = Evaluator::new(&rt, &model).unwrap();
    let stream = store.corpus_eval("wikisyn").unwrap();
    let profile = MacProfile::cached();
    let halo = ev
        .eval_quantizer(
            by_name("halo-bal", profile, 128).unwrap().as_ref(),
            &grads,
            &stream,
            "wikisyn",
            3,
            true,
        )
        .unwrap();
    let w3 = ev
        .eval_quantizer(
            by_name("rtn-w3", profile, 128).unwrap().as_ref(),
            &grads,
            &stream,
            "wikisyn",
            3,
            true,
        )
        .unwrap();
    assert!(halo.ppl < w3.ppl, "halo {} !< w3 {}", halo.ppl, w3.ppl);
    assert!(halo.bits_eff < 4.5, "bits {}", halo.bits_eff);
}

#[test]
fn l1_kernel_matches_rust_oracle_through_runtime() {
    // The three-layer agreement: the Pallas halo_matmul kernel (lowered to
    // HLO, executed via the runtime backend) must equal the Rust dequant +
    // matmul oracle.
    let store = need_artifacts!();
    let rt = Runtime::cpu().unwrap();
    let exe = match rt.load(&store.kernel_path("halo_matmul")) {
        Ok(e) => e,
        Err(e) => panic!("kernel artifact missing: {e}"),
    };
    let (m, k, n, tile) = (128usize, 256, 1024, 128);
    let mut rng = Rng::seed_from_u64(77);
    let x: Vec<f32> = (0..m * k).map(|_| rng.gen_normal() as f32).collect();
    let idx: Vec<i8> = (0..k * n).map(|_| rng.gen_usize(16) as i8).collect();
    let cb: Vec<f32> = (0..16).map(|_| rng.gen_normal() as f32).collect();
    let sc: Vec<f32> = (0..(k / tile) * (n / tile))
        .map(|_| 0.5 + rng.gen_f64() as f32)
        .collect();

    let out = exe
        .run(&[
            literal_f32(&x, &[m, k]).unwrap(),
            literal_i8(&idx, &[k, n]).unwrap(),
            literal_f32(&cb, &[16]).unwrap(),
            literal_f32(&sc, &[k / tile, n / tile]).unwrap(),
        ])
        .unwrap();
    let y: Vec<f32> = out[0].to_vec().unwrap();

    // Rust oracle: dense dequant then matmul.
    let mut wd = Matrix::zeros(k, n);
    for r in 0..k {
        for c in 0..n {
            let t = (r / tile) * (n / tile) + c / tile;
            wd.set(r, c, cb[idx[r * n + c] as usize] * sc[t]);
        }
    }
    let want = Matrix::from_vec(m, k, x).matmul(&wd);
    assert_eq!(y.len(), want.data.len());
    let mut max_err = 0.0f32;
    for (a, b) in y.iter().zip(&want.data) {
        max_err = max_err.max((a - b).abs() / (1.0 + b.abs()));
    }
    assert!(max_err < 1e-3, "max rel err {max_err}");
}

#[test]
fn codebook_quantizer_consistent_with_kernel_layout() {
    // quantize_tile indices must decode identically via the shared table.
    let profile = MacProfile::cached();
    let cb = Codebook::new(profile.codebook_med.clone());
    let mut rng = Rng::seed_from_u64(5);
    let w = Matrix::random_normal(64, 64, 0.02, &mut rng);
    let grid = TileGrid::new(64, 64, 32);
    for t in 0..grid.n_tiles() {
        let tq = quantize_tile(&w, &grid, t, &cb);
        let mut out = Matrix::zeros(64, 64);
        dequantize_tile(&mut out, &grid, t, &cb, &tq);
        let mut i = 0;
        grid.for_each(t, |r, c| {
            let decoded = cb.values[tq.idx[i] as usize] as f32 * tq.scale;
            assert_eq!(out.get(r, c), decoded);
            i += 1;
        });
    }
}

#[test]
fn coordinator_serves_real_model_end_to_end() {
    let store = need_artifacts!();
    let root = store.root.clone();
    let coord = Coordinator::start(CoordinatorConfig::default(), move |_shard| {
        let rt = Runtime::cpu()?;
        let store = Store::open(root.clone())?;
        let model = store.model("tiny")?;
        let exec = GraphExecutor::new(rt, &model, &BTreeMap::new(), Schedule::default())?;
        Ok(Box::new(exec) as Box<dyn halo::coordinator::BatchExecutor>)
    });
    let stream = store.corpus_eval("wikisyn").unwrap();
    let rxs: Vec<_> = (0..20)
        .map(|i| {
            let s = (i * 101) % (stream.len() - 40);
            let toks: Vec<i32> = stream[s..s + 24].iter().map(|&t| t as i32).collect();
            coord.submit_or_shed(Request::new(toks))
        })
        .collect();
    for rx in rxs {
        let r = rx.recv().unwrap();
        assert!((0..256).contains(&r.next_token));
    }
    assert_eq!(coord.metrics.responses.load(std::sync::atomic::Ordering::Relaxed), 20);
    coord.shutdown().unwrap();
}

#[test]
fn sharded_coordinator_decodes_real_model() {
    // PR 3: multi-shard serving with autoregressive decode over real
    // artifacts. Shard executors must agree with a reference single
    // executor's decode chain (the model is deterministic).
    use halo::coordinator::BatchExecutor;
    use std::sync::Arc;

    let store = need_artifacts!();
    let model = Arc::new(store.model("tiny").unwrap());
    let max_new = 3usize;

    let m = model.clone();
    let coord = Coordinator::start(CoordinatorConfig::sharded(2), move |_shard| {
        let rt = Runtime::cpu()?;
        let exec = GraphExecutor::new(rt, &m, &BTreeMap::new(), Schedule::default())?;
        Ok(Box::new(exec) as Box<dyn halo::coordinator::BatchExecutor>)
    });

    let stream = store.corpus_eval("wikisyn").unwrap();
    let prefixes: Vec<Vec<i32>> = (0..6)
        .map(|i| {
            let s = (i * 211) % (stream.len() - 40);
            stream[s..s + 16].iter().map(|&t| t as i32).collect()
        })
        .collect();
    let rxs: Vec<_> = prefixes
        .iter()
        .map(|p| coord.submit_or_shed(Request::new(p.clone()).max_new(max_new)))
        .collect();

    // Reference decode on a private executor, one sequence at a time (row
    // independence makes batch composition irrelevant to the argmax).
    let rt = Runtime::cpu().unwrap();
    let mut reference =
        GraphExecutor::new(rt, &model, &BTreeMap::new(), Schedule::default()).unwrap();
    let want: Vec<Vec<i32>> = prefixes
        .iter()
        .map(|p| {
            let mut out =
                reference.generate(std::slice::from_ref(p), &[max_new]).unwrap();
            out.remove(0)
        })
        .collect();

    for (rx, want) in rxs.into_iter().zip(want) {
        let r = rx.recv().unwrap();
        assert!(!r.shed);
        assert_eq!(r.tokens, want, "shard decode diverged from reference");
    }
    assert_eq!(coord.merged_snapshot().generated_tokens, (6 * max_new) as u64);
    coord.shutdown().unwrap();
}

#[test]
fn quantized_serving_prediction_quality_preserved() {
    // Next-token agreement between FP16 and HALO-quantized serving should
    // be high (they share most of the distribution mass).
    let store = need_artifacts!();
    let rt = Runtime::cpu().unwrap();
    let model = store.model("tiny").unwrap();
    let calib = store.corpus_calib().unwrap();
    let grads = calibrate_fisher(&rt, &model, &calib, 2).unwrap();
    let profile = MacProfile::cached();
    let q = by_name("halo-acc", profile, 128).unwrap();
    let mut replace = BTreeMap::new();
    for p in model.linear_params() {
        let w = p.as_matrix().unwrap();
        let ctx = match grads.get(&p.name) {
            Some(g) => LayerCtx::with_grad(&p.name, g),
            None => LayerCtx::new(&p.name),
        };
        replace.insert(p.name.clone(), q.quantize(&w, &ctx).dequant);
    }

    use halo::coordinator::BatchExecutor;
    let rt2 = Runtime::cpu().unwrap();
    let mut fp = GraphExecutor::new(rt, &model, &BTreeMap::new(), Schedule::default()).unwrap();
    let mut hq = GraphExecutor::new(rt2, &model, &replace, Schedule::default()).unwrap();
    let stream = store.corpus_eval("wikisyn").unwrap();
    let prefixes: Vec<Vec<i32>> = (0..8)
        .map(|i| {
            let s = (i * 313) % (stream.len() - 40);
            stream[s..s + 32].iter().map(|&t| t as i32).collect()
        })
        .collect();
    let a = fp.run(&prefixes).unwrap();
    let b = hq.run(&prefixes).unwrap();
    let agree = a.iter().zip(&b).filter(|(x, y)| x == y).count();
    assert!(agree >= 5, "only {agree}/8 next-token agreement");
}
