//! Chaos soak suite — pins the PR 7 fault-injection + supervised-recovery
//! layer end to end.
//!
//! Every test installs a schedule in the process-global failpoint registry
//! (`halo::util::failpoint`), so the whole binary serializes behind
//! `TEST_LOCK` and uses `install_guarded` so a panicking test cannot leak
//! its schedule into the next one. The invariants pinned here:
//!
//! - **Exactly one response per request**, served or shed, under any mix
//!   of injected panics, errors and delays — nothing hangs, nothing is
//!   silently dropped, nothing answers twice.
//! - **Bit-identical retried completions**: a request re-homed after a
//!   shard kill restarts from its original prefix and produces the same
//!   greedy chain the un-faulted executor would (brown-out may clamp the
//!   decode budget, yielding a *prefix* of that chain — still bit-exact
//!   per position).
//! - **Metrics conservation**: `requests == responses + shed + rejected`
//!   and `Σ shed_reasons == shed + rejected` at quiesce.
//! - **No panic escapes the supervisor**: `shutdown()` joins every shard
//!   thread cleanly even after injected shard deaths.

use std::time::{Duration, Instant};

use anyhow::Result;
use halo::coordinator::{
    BatchExecutor, BatcherConfig, Coordinator, CoordinatorConfig, QuantExecutor, Request,
    ShedReason, SupervisorConfig,
};
use halo::util::failpoint::{self, sites, FailPlan, Fault};
use halo::util::sync::Mutex;

/// Serializes every test in this binary: the failpoint registry is
/// process-global, so concurrent schedules would contaminate each other.
static TEST_LOCK: Mutex<()> = Mutex::new(());

/// Deterministic toy model (mirrors the in-crate coordinator test
/// executor): next token = sum(window) % 97 over a 16-token context
/// window. Cheap enough that the soak is fault-dominated, not
/// compute-dominated.
struct Echo {
    cap: usize,
}

impl BatchExecutor for Echo {
    fn batch_capacity(&self) -> usize {
        self.cap
    }
    fn seq_len(&self) -> usize {
        16
    }
    fn run(&mut self, prefixes: &[Vec<i32>]) -> Result<Vec<i32>> {
        Ok(prefixes.iter().map(|p| p.iter().sum::<i32>() % 97).collect())
    }
}

/// The greedy chain `Echo` produces for `prefix` under a sliding window of
/// `cap` tokens — the oracle every served completion must match exactly.
fn echo_chain(prefix: &[i32], cap: usize, steps: usize) -> Vec<i32> {
    let mut seq: Vec<i32> = prefix[prefix.len().saturating_sub(cap)..].to_vec();
    let mut want = Vec::new();
    for _ in 0..steps {
        let t = seq.iter().sum::<i32>() % 97;
        want.push(t);
        if seq.len() >= cap {
            seq.remove(0);
        }
        seq.push(t);
    }
    want
}

/// Coordinator config tuned for chaos runs: tight batching windows and
/// millisecond-scale respawn backoffs so dozens of kill/respawn cycles
/// fit in a fast test.
fn chaos_cfg(shards: usize) -> CoordinatorConfig {
    CoordinatorConfig {
        batcher: BatcherConfig { batch_size: 4, timeout: Duration::from_millis(1) },
        shards,
        queue_cap: 0,
        default_deadline: None,
        supervisor: SupervisorConfig {
            backoff_base: Duration::from_millis(1),
            backoff_cap: Duration::from_millis(8),
            ..SupervisorConfig::default()
        },
    }
}

fn echo_factory(cap: usize) -> impl Fn(usize) -> Result<Box<dyn BatchExecutor>> + Send + Sync {
    move |_shard| Ok(Box::new(Echo { cap }) as Box<dyn BatchExecutor>)
}

/// The headline soak: three fault classes live at once (step panics kill
/// shards, begin errors force retries, push delays jitter submission),
/// guaranteed to fire at three distinct sites, with every request
/// answered exactly once and the books balancing afterwards.
#[test]
fn chaos_soak_survives_mixed_faults_with_exactly_one_response_each() {
    let _l = TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let t0 = Instant::now();
    // Probabilistic background chaos plus one deterministic fire per site
    // (prob 1.0, after N, max_fires 1) so the "≥ 3 distinct sites fired"
    // and "≥ 1 shard killed" assertions never depend on seed luck.
    let _g = failpoint::install_guarded(
        vec![
            FailPlan::always(sites::SHARD_STEP, Fault::Panic).with_prob(0.05),
            FailPlan::always(sites::SHARD_STEP, Fault::Panic).with_after(10).with_max_fires(1),
            FailPlan::always(sites::SHARD_BEGIN, Fault::Error).with_prob(0.10),
            FailPlan::always(sites::SHARD_BEGIN, Fault::Error).with_after(5).with_max_fires(1),
            FailPlan::always(sites::QUEUE_PUSH, Fault::Delay(Duration::from_micros(200)))
                .with_prob(0.05),
            FailPlan::always(sites::QUEUE_PUSH, Fault::Delay(Duration::from_micros(200)))
                .with_after(3)
                .with_max_fires(1),
        ],
        0xC0FF_EE00,
    );
    let coord = Coordinator::start(chaos_cfg(3), echo_factory(4));

    let n = 120usize;
    let mut specs = Vec::with_capacity(n);
    let mut rxs = Vec::with_capacity(n);
    for i in 0..n {
        let prefix: Vec<i32> = (0..1 + i % 6).map(|j| ((i * 7 + j * 3) % 89) as i32).collect();
        let max_new = 1 + i % 4;
        rxs.push(coord.submit_or_shed(Request::new(prefix.clone()).max_new(max_new)));
        specs.push((prefix, max_new));
    }

    let mut served = 0u64;
    let mut shed = 0u64;
    for (rx, (prefix, max_new)) in rxs.iter().zip(&specs) {
        let r = rx
            .recv_timeout(Duration::from_secs(30))
            .expect("every request answers: served or shed, never dropped");
        if r.shed {
            assert!(r.reason.is_some(), "shed response must carry a ShedReason");
            shed += 1;
        } else {
            assert!(
                !r.tokens.is_empty() && r.tokens.len() <= *max_new,
                "served length in [1, {max_new}], got {}",
                r.tokens.len()
            );
            // Retried (and possibly brown-out-clamped) completions are a
            // bit-exact prefix of the un-faulted greedy chain.
            assert_eq!(
                r.tokens,
                echo_chain(prefix, 16, r.tokens.len()),
                "served chain diverged from the decode oracle"
            );
            served += 1;
        }
        assert!(
            rx.recv_timeout(Duration::from_millis(5)).is_err(),
            "a request must never answer twice"
        );
    }

    // Fault observability: at least three distinct sites actually fired,
    // including at least one shard kill that forced a respawn.
    assert!(failpoint::fired(sites::SHARD_STEP) >= 1, "no shard was killed");
    assert!(failpoint::fired(sites::SHARD_BEGIN) >= 1, "no begin fault fired");
    assert!(failpoint::fired(sites::QUEUE_PUSH) >= 1, "no push delay fired");
    assert!(failpoint::total_fired() >= 3);

    let snap = coord.merged_snapshot();
    assert_eq!(snap.requests, n as u64);
    assert_eq!(
        snap.requests,
        snap.responses + snap.shed + snap.rejected,
        "conservation: every arrival is served, shed or rejected"
    );
    assert_eq!(
        snap.shed_reason_total(),
        snap.shed + snap.rejected,
        "every shed/reject carries exactly one reason"
    );
    assert_eq!(snap.responses, served);
    assert_eq!(snap.shed + snap.rejected, shed);
    assert!(snap.shard_restarts >= 1, "the killed shard must have respawned");

    coord.shutdown().expect("no injected panic may escape the supervisor fences");
    assert!(t0.elapsed() < Duration::from_secs(60), "soak wall-clock guard");
}

/// Fully deterministic kill: the third decode step panics (once), the
/// supervisor respawns the shard, and the re-homed request re-decodes
/// from its original prefix to the exact chain a fault-free run produces.
#[test]
fn killed_shard_respawns_and_retried_decode_is_bit_identical() {
    let _l = TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let _g = failpoint::install_guarded(
        vec![FailPlan::always(sites::SHARD_STEP, Fault::Panic).with_after(2).with_max_fires(1)],
        7,
    );
    let coord = Coordinator::start(chaos_cfg(1), echo_factory(4));

    let prefix = vec![5, 11, 2];
    let rx = coord.submit_or_shed(Request::new(prefix.clone()).max_new(6));
    let r = rx.recv_timeout(Duration::from_secs(20)).expect("retried request still answers");
    assert!(!r.shed, "one kill within the retry budget must not shed");
    assert_eq!(
        r.tokens,
        echo_chain(&prefix, 16, 6),
        "post-respawn completion must be bit-identical to a fault-free run"
    );
    assert!(rx.recv_timeout(Duration::from_millis(5)).is_err(), "exactly one response");

    assert_eq!(failpoint::fired(sites::SHARD_STEP), 1);
    let snap = coord.merged_snapshot();
    assert_eq!(snap.shard_restarts, 1, "exactly one supervised respawn");
    assert!(snap.retries >= 1, "the orphan was re-enqueued, not re-run in place");
    assert_eq!(
        (snap.requests, snap.responses, snap.shed, snap.rejected),
        (1, 1, 0, 0),
        "books balance: one arrival, one served response"
    );
    coord.shutdown().expect("respawned shard joins cleanly");
}

/// Kill storm: every admission attempt panics, so each shard burns
/// through its restart budget and dies permanently. Every request must
/// still be answered — shed with a recovery-side reason — and shutdown
/// must join the permanently-dead shard threads cleanly.
#[test]
fn total_shard_loss_sheds_everything_with_reasons_and_no_hang() {
    let _l = TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let _g = failpoint::install_guarded(
        vec![FailPlan::always(sites::SHARD_BEGIN, Fault::Panic)],
        3,
    );
    let coord = Coordinator::start(chaos_cfg(2), echo_factory(4));

    let n = 24usize;
    let rxs: Vec<_> = (0..n)
        .map(|i| coord.submit_or_shed(Request::new(vec![i as i32 % 89]).max_new(3)))
        .collect();
    for rx in &rxs {
        let r = rx.recv_timeout(Duration::from_secs(20)).expect("total loss must not hang");
        assert!(r.shed, "nothing can be served when every begin panics");
        assert!(
            matches!(r.reason, Some(ShedReason::ShardDeath | ShedReason::RetryExhausted)),
            "total-loss sheds carry a recovery-side reason, got {:?}",
            r.reason
        );
        assert!(rx.recv_timeout(Duration::from_millis(5)).is_err(), "exactly one response");
    }

    let snap = coord.merged_snapshot();
    assert_eq!(snap.requests, n as u64);
    assert_eq!(snap.responses, 0);
    assert_eq!(snap.shed + snap.rejected, n as u64);
    assert_eq!(snap.shed_reason_total(), snap.shed + snap.rejected);
    coord.shutdown().expect("permanently-dead shards exit their threads cleanly");
}

/// Seed sweep: four different seeds over the same probabilistic schedule.
/// Whatever the fault pattern, the coordinator never panics outward,
/// answers every request exactly once, serves only oracle-exact chains,
/// and balances its books.
#[test]
fn random_schedules_across_seeds_never_drop_or_double_answer() {
    let _l = TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    for seed in [1u64, 2, 3, 4] {
        let _g = failpoint::install_guarded(
            vec![
                FailPlan::always(sites::SHARD_STEP, Fault::Panic).with_prob(0.10),
                FailPlan::always(sites::SHARD_BEGIN, Fault::Error).with_prob(0.20),
                FailPlan::always(sites::QUEUE_PUSH, Fault::Delay(Duration::from_micros(100)))
                    .with_prob(0.10),
            ],
            seed,
        );
        let coord = Coordinator::start(chaos_cfg(2), echo_factory(4));
        let n = 30usize;
        let mut rxs = Vec::with_capacity(n);
        let mut specs = Vec::with_capacity(n);
        for i in 0..n {
            let prefix: Vec<i32> = (0..1 + i % 4).map(|j| ((i * 13 + j) % 89) as i32).collect();
            rxs.push(coord.submit_or_shed(Request::new(prefix.clone()).max_new(3)));
            specs.push(prefix);
        }
        let mut served = 0u64;
        let mut shed = 0u64;
        for (rx, prefix) in rxs.iter().zip(&specs) {
            let r = rx
                .recv_timeout(Duration::from_secs(30))
                .unwrap_or_else(|e| panic!("seed {seed}: request went unanswered: {e}"));
            if r.shed {
                assert!(r.reason.is_some(), "seed {seed}: shed without a reason");
                shed += 1;
            } else {
                assert_eq!(
                    r.tokens,
                    echo_chain(prefix, 16, r.tokens.len()),
                    "seed {seed}: served chain diverged from the oracle"
                );
                served += 1;
            }
            assert!(rx.recv_timeout(Duration::from_millis(5)).is_err(), "seed {seed}: double answer");
        }
        let snap = coord.merged_snapshot();
        assert_eq!(snap.requests, n as u64, "seed {seed}");
        assert_eq!(snap.requests, snap.responses + snap.shed + snap.rejected, "seed {seed}");
        assert_eq!(snap.shed_reason_total(), snap.shed + snap.rejected, "seed {seed}");
        assert_eq!((snap.responses, snap.shed + snap.rejected), (served, shed), "seed {seed}");
        coord.shutdown().unwrap_or_else(|e| panic!("seed {seed}: shard thread crashed: {e}"));
    }
    assert!(!failpoint::enabled(), "guards must clear the registry between seeds");
}

/// The CLI path: a schedule installed from `HALO_FAILPOINTS` (exactly what
/// `halo serve` / `halo loadgen` do at startup) fires on the serving path,
/// and the delayed request is still served correctly.
#[test]
fn env_installed_schedule_drives_the_serving_path() {
    let _l = TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    std::env::set_var(failpoint::ENV_PLANS, "queue.push=delay:1,1.0,0,2");
    std::env::set_var(failpoint::ENV_SEED, "9");
    let installed = failpoint::install_from_env().expect("valid env spec");
    std::env::remove_var(failpoint::ENV_PLANS);
    std::env::remove_var(failpoint::ENV_SEED);
    assert!(installed, "HALO_FAILPOINTS must install a schedule");

    let coord = Coordinator::start(chaos_cfg(1), echo_factory(4));
    let prefix = vec![4, 9];
    let rx = coord.submit_or_shed(Request::new(prefix.clone()).max_new(2));
    let r = rx.recv_timeout(Duration::from_secs(10)).expect("delayed push still answers");
    assert!(!r.shed);
    assert_eq!(r.tokens, echo_chain(&prefix, 16, 2));
    assert!(failpoint::fired(sites::QUEUE_PUSH) >= 1, "env schedule never fired");
    coord.shutdown().expect("clean shutdown");
    failpoint::clear();
    assert!(!failpoint::enabled());
}

/// PR 9: a shard dies MID-SPECULATION (step panic while the speculative
/// executor is between drafting and verifying). The supervisor re-homes
/// the orphan onto the survivor, whose `begin` rebuilds BOTH the
/// verifier cache and the drafter's aux state fresh from the original
/// prefix — so the retried completion is bit-identical to the un-faulted
/// verifier-only oracle, never a half-verified draft.
#[test]
fn spec_shard_death_mid_speculation_rehomes_bit_identically() {
    use std::collections::BTreeMap;
    use std::sync::Arc;

    use halo::coordinator::{SpecExecutor, SpecVerifier};
    use halo::mac::MacProfile;
    use halo::quant::Variant;
    use halo::runtime::sim::ModelSpec;
    use halo::runtime::PackedModel;
    use halo::util::Rng;

    let _l = TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let spec = ModelSpec::synthetic(13, 8, 2, 2, 16, 24);
    let mut rng = Rng::seed_from_u64(0x59EC);
    let params: Vec<(String, Vec<usize>, Vec<f32>)> = spec
        .names
        .iter()
        .zip(&spec.shapes)
        .map(|(name, shape)| {
            let n: usize = shape.iter().product();
            let data: Vec<f32> = if name.ends_with(".scale") {
                vec![1.0; n]
            } else {
                (0..n).map(|_| rng.gen_normal() as f32 * 0.1).collect()
            };
            (name.clone(), shape.clone(), data)
        })
        .collect();
    let pack = |variant: Variant| {
        let views = params.iter().map(|(n, s, d)| (n.as_str(), s.as_slice(), d.as_slice()));
        Arc::new(
            PackedModel::pack_from(
                spec.clone(),
                views,
                variant,
                4,
                &BTreeMap::new(),
                MacProfile::cached(),
            )
            .unwrap(),
        )
    };
    let verifier = pack(Variant::AccOpt);
    let drafter = pack(Variant::PerfOpt);

    // First step survives (one speculative round lands some tokens),
    // the second panics the shard mid-flight — exactly once.
    let _g = failpoint::install_guarded(
        vec![FailPlan::always(sites::SHARD_STEP, Fault::Panic).with_after(1).with_max_fires(1)],
        11,
    );
    let (v2, d2) = (verifier.clone(), drafter.clone());
    let coord = Coordinator::start(chaos_cfg(2), move |_shard| {
        let exec = SpecExecutor::from_packed(d2.clone(), SpecVerifier::Packed(v2.clone()), 4, 4)?;
        Ok(Box::new(exec) as Box<dyn BatchExecutor>)
    });

    let prefix = vec![5i32, 11, 2, 7];
    let max_new = 10usize;
    let rx = coord.submit_or_shed(Request::new(prefix.clone()).max_new(max_new));
    let r = rx.recv_timeout(Duration::from_secs(30)).expect("re-homed request still answers");
    assert!(!r.shed, "one kill within the retry budget must not shed");
    assert_eq!(
        r.tokens,
        verifier.decode_greedy(&prefix, max_new).unwrap(),
        "post-re-home speculative completion must equal the verifier-only oracle"
    );
    assert!(rx.recv_timeout(Duration::from_millis(5)).is_err(), "exactly one response");

    assert_eq!(failpoint::fired(sites::SHARD_STEP), 1);
    let snap = coord.merged_snapshot();
    assert!(snap.shard_restarts >= 1, "the killed shard must have respawned");
    assert!(snap.retries >= 1, "the orphan was re-homed, not re-run in place");
    assert!(
        snap.spec.verify_rounds >= 1,
        "speculative rounds never reached the metrics gauges: {snap:?}"
    );
    assert_eq!(
        (snap.requests, snap.responses, snap.shed, snap.rejected),
        (1, 1, 0, 0),
        "books balance: one arrival, one served response"
    );
    coord.shutdown().expect("respawned speculative shard joins cleanly");
}

/// PR 8: KV block-pool exhaustion is load, not a fault. A pool too small
/// for even one prefill sheds every request with `ShedReason::Brownout`
/// — no panic, no shard restart, no retry-budget burn — and the same
/// workload over an adequate pool serves bit-identically to the solo
/// cached oracle.
#[test]
fn pool_exhaustion_sheds_as_brownout_and_kills_no_shard() {
    use std::collections::BTreeMap;
    use std::sync::Arc;

    use halo::mac::MacProfile;
    use halo::quant::Variant;
    use halo::runtime::sim::ModelSpec;
    use halo::runtime::{BlockPool, PackedModel};
    use halo::util::Rng;

    let _l = TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    // No failpoint schedule: the pressure comes from the pool bound alone.
    let spec = ModelSpec::synthetic(13, 8, 2, 2, 16, 24);
    let mut rng = Rng::seed_from_u64(0xB10C);
    let params: Vec<(String, Vec<usize>, Vec<f32>)> = spec
        .names
        .iter()
        .zip(&spec.shapes)
        .map(|(name, shape)| {
            let n: usize = shape.iter().product();
            let data: Vec<f32> = if name.ends_with(".scale") {
                vec![1.0; n]
            } else {
                (0..n).map(|_| rng.gen_normal() as f32 * 0.1).collect()
            };
            (name.clone(), shape.clone(), data)
        })
        .collect();
    let views = params.iter().map(|(n, s, d)| (n.as_str(), s.as_slice(), d.as_slice()));
    let pm = Arc::new(
        PackedModel::pack_from(
            spec.clone(),
            views,
            Variant::Bal,
            4,
            &BTreeMap::new(),
            MacProfile::cached(),
        )
        .unwrap(),
    );

    // Phase 1: one 4-row block total — an 8-token prefill can never fit.
    let starved = Arc::new(BlockPool::new(spec.n_layers, spec.d_model, 4, 1));
    let (pm2, pool2) = (pm.clone(), starved.clone());
    let coord = Coordinator::start(chaos_cfg(1), move |_shard| {
        let exec = QuantExecutor::new(pm2.clone(), 4).with_kv_pool(pool2.clone());
        Ok(Box::new(exec) as Box<dyn BatchExecutor>)
    });
    let prefix: Vec<i32> = (0..8).map(|i| (i * 3 % spec.vocab as i32)).collect();
    let rx = coord.submit_or_shed(Request::new(prefix.clone()).max_new(2));
    let r = rx.recv_timeout(Duration::from_secs(20)).expect("exhaustion must answer, not hang");
    assert!(r.shed, "an impossible allocation must shed");
    assert_eq!(r.reason, Some(ShedReason::Brownout), "exhaustion sheds as brown-out");
    let snap = coord.merged_snapshot();
    assert_eq!(snap.shard_restarts, 0, "pool pressure must not look like a shard fault");
    assert!(starved.stats().refusals >= 1, "the pool recorded no refusal");
    assert!(
        snap.kv_pool_refusals >= 1,
        "pool refusals must surface in serving metrics, got {snap:?}"
    );
    coord.shutdown().expect("starved coordinator shuts down cleanly");

    // Phase 2: same request, adequate pool — served, bit-identical.
    let roomy = Arc::new(BlockPool::new(spec.n_layers, spec.d_model, 4, 0).with_sharing(16));
    let (pm3, pool3) = (pm.clone(), roomy);
    let coord = Coordinator::start(chaos_cfg(1), move |_shard| {
        let exec = QuantExecutor::new(pm3.clone(), 4).with_kv_pool(pool3.clone());
        Ok(Box::new(exec) as Box<dyn BatchExecutor>)
    });
    let rx = coord.submit_or_shed(Request::new(prefix.clone()).max_new(2));
    let r = rx.recv_timeout(Duration::from_secs(20)).expect("roomy pool serves");
    assert!(!r.shed, "an adequate pool must serve the identical request");
    assert_eq!(r.tokens, pm.decode_greedy(&prefix, 2).unwrap());
    coord.shutdown().expect("clean shutdown");
}
