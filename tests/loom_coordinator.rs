//! Loom-style exhaustive model checks over the coordinator's concurrency
//! protocols, driven by the in-tree CHESS-style explorer in
//! `halo::util::sync` (offline build: no `loom` crate).
//!
//! Every `model(..)` body below is re-run once per distinct interleaving
//! of its scheduling points (shim lock/unlock, condvar wait/notify, atomic
//! ops, spawn/join), so the asserts hold under *every* schedule, not just
//! the ones a timing-dependent stress test happens to hit. The suite runs
//! under plain `cargo test`; the CI `analysis` job additionally builds it
//! with `--cfg loom`, which makes any shim use *outside* a model panic and
//! thereby proves these tests exercise only modeled code.
//!
//! Model-safety rules (see `util::sync` docs): models only call untimed
//! queue ops (`push`/`pop`/`try_pop`/`try_fill`/`close` — `pop_deadline`
//! and `next_batch` branch on wall-clock time and are documented not
//! model-safe), and every model keeps its scheduling-point count small:
//! the DFS explores roughly C(total points, per-thread points)
//! interleavings and must finish within the execution budget.

use std::time::Duration;

use halo::coordinator::{Batcher, BatcherConfig, Metrics, PushError, RequestQueue};
use halo::quant::Matrix;
use halo::runtime::{BlockPool, KvCache};
use halo::util::sync::atomic::Ordering;
use halo::util::sync::{explore, model, thread, Arc, Mutex};

/// Admission control vs shed vs shutdown on a cap-1 queue: two producers
/// race a `close()`, and under every interleaving the queue accepts at
/// most `cap` items, refuses the rest with the right error (item returned
/// intact), and drains exactly what it accepted.
#[test]
fn model_bounded_admission_vs_shed_vs_shutdown() {
    let ex = explore(|| {
        let q = Arc::new(RequestQueue::bounded(1));
        let (q1, q2) = (q.clone(), q.clone());
        let p1 = thread::spawn(move || q1.push(1u32));
        let p2 = thread::spawn(move || q2.push(2u32));
        q.close();
        let r1 = p1.join().unwrap();
        let r2 = p2.join().unwrap();

        let (mut accepted, mut full, mut closed) = (0, 0, 0);
        for r in [r1, r2] {
            match r {
                Ok(()) => accepted += 1,
                Err(PushError::Full(v)) => {
                    assert!(v == 1 || v == 2, "rejected item mangled: {v}");
                    full += 1;
                }
                Err(PushError::Closed(v)) => {
                    assert!(v == 1 || v == 2, "rejected item mangled: {v}");
                    closed += 1;
                }
            }
        }
        assert!(accepted <= 1, "cap-1 queue admitted {accepted}");
        assert_eq!(accepted + full + closed, 2);
        // `Full` means the other producer's item occupied the only slot
        // (nothing pops concurrently, so the slot can't have been freed).
        if full > 0 {
            assert_eq!(accepted, 1, "shed as Full with an empty queue");
        }
        assert!(q.is_closed());

        // Drain: exactly the accepted items, then a sticky closed state.
        let mut drained = 0;
        while q.try_pop().is_some() {
            drained += 1;
        }
        assert_eq!(drained, accepted, "accepted {accepted} but drained {drained}");
        assert!(matches!(q.push(9), Err(PushError::Closed(9))));
        assert_eq!(q.pop(), None, "closed+drained pop must not block");
    });
    assert!(ex.executions > 1, "racing producers must branch the search");
}

/// Continuous-batching top-up vs retire: a producer pushes two requests
/// and closes while the consumer takes one blocking `pop` (the in-flight
/// decode picking up work) and then a `Batcher::try_fill` top-up. Under
/// every interleaving the consumer observes each item exactly once, in
/// FIFO order, no matter how the top-up splits against the pushes.
#[test]
fn model_try_fill_topup_vs_producer_close() {
    let ex = explore(|| {
        let q = Arc::new(RequestQueue::bounded(0));
        let batcher = Batcher::new(
            BatcherConfig { batch_size: 4, timeout: Duration::from_millis(5) },
            q.clone(),
        );
        let qp = q.clone();
        let producer = thread::spawn(move || {
            qp.push(1u32).unwrap();
            qp.push(2).unwrap();
            qp.close();
        });

        // Blocking pop: both pushes precede the close, so the first item
        // is always delivered (close never swallows queued work).
        let mut got = vec![q.pop().expect("pop lost an item queued before close")];
        // Racy top-up: may see zero or more of the remaining items.
        let topup = batcher.try_fill(4);
        assert!(topup.len() <= 1, "only one item can remain for the top-up");
        got.extend(topup);
        producer.join().unwrap();
        // Single-threaded drain of whatever the top-up missed.
        while let Some(v) = q.try_pop() {
            got.push(v);
        }
        assert_eq!(got, vec![1, 2], "items lost, duplicated or reordered");
        assert!(q.is_closed());
    });
    assert!(ex.executions > 1, "producer/consumer race must branch the search");
}

/// Two shards recording into one `Metrics` concurrently: the latency
/// reservoir (mutex) and the counters (atomics) lose no updates under any
/// interleaving of the two recorders.
#[test]
fn model_concurrent_recording_loses_no_updates() {
    model(|| {
        let m = Arc::new(Metrics::default());
        let (a, b) = (m.clone(), m.clone());
        let t1 = thread::spawn(move || a.record_latency(Duration::from_micros(10)));
        let t2 = thread::spawn(move || {
            b.record_latency(Duration::from_micros(20));
            b.generated_tokens.fetch_add(3, Ordering::Relaxed);
        });
        t1.join().unwrap();
        t2.join().unwrap();
        let s = m.snapshot();
        assert_eq!(s.latencies_us, vec![10, 20], "reservoir lost or reordered a sample");
        assert_eq!(s.generated_tokens, 3);
    });
}

/// `Metrics::merged` taken mid-flight against a concurrent recorder: the
/// snapshot is always internally consistent (sorted, only ever-recorded
/// values, bounded counters) even though it races the recording, and the
/// post-join merge is exact.
#[test]
fn model_merged_snapshot_vs_concurrent_recording() {
    model(|| {
        let m1 = Arc::new(Metrics::default());
        let m2 = Arc::new(Metrics::default());
        // Shard 2's history predates the race (single-threaded prelude).
        m2.record_latency(Duration::from_micros(5));
        m2.responses.fetch_add(1, Ordering::Relaxed);

        let r = m1.clone();
        let recorder = thread::spawn(move || {
            r.record_latency(Duration::from_micros(10));
            r.responses.fetch_add(1, Ordering::Relaxed);
        });

        // Mid-flight merge across both shards, racing the recorder.
        let mid = Metrics::merged(&[&*m1, &*m2]);
        assert!(!mid.latencies_us.is_empty() && mid.latencies_us.len() <= 2);
        assert!(mid.latencies_us.contains(&5), "pre-race sample vanished");
        assert!(mid.latencies_us.iter().all(|&v| v == 5 || v == 10));
        assert!(mid.latencies_us.windows(2).all(|w| w[0] <= w[1]), "merge unsorted");
        assert!(mid.responses >= 1 && mid.responses <= 2);

        recorder.join().unwrap();
        let fin = Metrics::merged(&[&*m1, &*m2]);
        assert_eq!(fin.latencies_us, vec![5, 10]);
        assert_eq!(fin.responses, 2);
        assert_eq!(fin.percentile_latency(0.5), Some(Duration::from_micros(5)));
        assert_eq!(fin.percentile_latency(1.0), Some(Duration::from_micros(10)));
    });
}

/// The PR 7 supervisor-vs-shard-death race: a dying shard's supervisor
/// re-homes an orphaned request onto a survivor's queue while that
/// survivor concurrently closes (its own permanent death / shutdown).
/// Under every interleaving the orphan lands in exactly one place — the
/// survivor's queue (drained later by whoever owns the backlog) or back
/// in the supervisor's hands via `PushError::Closed` (the shed path) —
/// never both, never lost. This is exactly why `redistribute` treats a
/// failed push as "try the next shard / shed with a reason" rather than
/// assuming placement succeeded.
#[test]
fn model_supervisor_reenqueue_vs_survivor_close() {
    let ex = explore(|| {
        let survivor = Arc::new(RequestQueue::bounded(0));
        let qs = survivor.clone();
        // Supervisor thread: re-homes orphan `7`, reporting placement.
        let sup = thread::spawn(move || match qs.push(7u32) {
            Ok(()) => true,
            Err(PushError::Closed(v)) => {
                assert_eq!(v, 7, "refused orphan mangled");
                false
            }
            Err(PushError::Full(v)) => panic!("unbounded queue reported Full({v})"),
        });
        // Survivor dies / shuts down concurrently with the re-enqueue.
        survivor.close();
        let requeued = sup.join().unwrap();

        // Perm-death drain: the backlog owner sees the orphan iff the
        // push won the race; the shed path owns it otherwise.
        let mut drained = 0;
        while survivor.try_pop().is_some() {
            drained += 1;
        }
        assert_eq!(
            drained,
            usize::from(requeued),
            "orphan must be owned exactly once (requeued={requeued}, drained={drained})"
        );
        assert!(survivor.is_closed());
        assert_eq!(survivor.pop(), None, "drained+closed pop must not block");
    });
    assert!(ex.executions > 1, "push/close race must branch the search");
}

/// Two dying shards race `take_retry_token` on the last token of the
/// global retry budget (a shim-mutex pool, as in the supervisor): under
/// every interleaving exactly one wins, the pool never underflows, and
/// the loser takes the shed path.
#[test]
fn model_retry_budget_last_token_has_a_single_winner() {
    fn take(pool: &Mutex<u64>) -> bool {
        let mut g = pool.lock().unwrap_or_else(|e| e.into_inner());
        if *g == 0 {
            return false;
        }
        *g -= 1;
        true
    }
    let ex = explore(|| {
        let pool = Arc::new(Mutex::new(1u64));
        let (p1, p2) = (pool.clone(), pool.clone());
        let t1 = thread::spawn(move || take(&p1));
        let t2 = thread::spawn(move || take(&p2));
        let (w1, w2) = (t1.join().unwrap(), t2.join().unwrap());
        assert!(w1 ^ w2, "exactly one shard may spend the last retry token");
        assert_eq!(*pool.lock().unwrap_or_else(|e| e.into_inner()), 0, "pool must end drained");
    });
    assert!(ex.executions > 1, "token race must branch the search");
}

/// Try to stage one row into a fresh cache off `pool` (the block
/// acquisition path a decode step takes). Returns whether the single
/// permit was won; a refusal leaves no staged residue behind.
fn try_acquire(pool: &Arc<BlockPool>) -> Option<KvCache> {
    let mut c = pool.new_cache(&[]);
    let row = Matrix::from_fn(1, 2, |_, _| 1.0);
    match c.append(0, &row, &row) {
        Ok(()) => {
            c.commit(&[7]).unwrap();
            Some(c)
        }
        Err(e) => {
            assert!(
                e.downcast_ref::<halo::runtime::PoolExhausted>().is_some(),
                "cap-1 pool refused with a non-pool error: {e:#}"
            );
            c.clear();
            None
        }
    }
}

/// The PR 8 block-permit race, acquire vs acquire: two decodes race for
/// the last block of a cap-1 [`BlockPool`]. Under every interleaving
/// exactly one wins, the pool never over-allocates, and after both
/// caches drop the pool is fully drained (no leaked permits from the
/// refusal path).
#[test]
fn model_block_pool_last_block_has_a_single_winner() {
    let ex = explore(|| {
        let pool = Arc::new(BlockPool::new(1, 2, 1, 1));
        let (p1, p2) = (pool.clone(), pool.clone());
        let t1 = thread::spawn(move || try_acquire(&p1));
        let t2 = thread::spawn(move || try_acquire(&p2));
        let (c1, c2) = (t1.join().unwrap(), t2.join().unwrap());
        assert!(
            c1.is_some() ^ c2.is_some(),
            "exactly one decode may own the last block"
        );
        let s = pool.stats();
        assert_eq!(s.blocks_in_use, 1, "winner must hold exactly one block");
        assert!(s.refusals >= 1, "loser's refusal must be counted");
        drop((c1, c2));
        assert_eq!(pool.stats().blocks_in_use, 0, "release leaked a permit");
    });
    assert!(ex.executions > 1, "permit race must branch the search");
}

/// The PR 9 speculative seam: a verify round REJECTING drafted tokens
/// rolls its cache back (`truncate_to` — releasing the rejected tail
/// block) while another request's decode concurrently acquires from the
/// same bounded pool. Under every interleaving the acquirer either wins
/// the block the rollback freed or is cleanly refused, the roller always
/// keeps exactly its accepted row, and afterwards permits are conserved
/// — rollback is a release, never a double-free, never a leak.
#[test]
fn model_spec_rollback_release_vs_acquire_single_winner() {
    let ex = explore(|| {
        let pool = Arc::new(BlockPool::new(1, 2, 1, 2));
        // Prelude (single-threaded): the speculating request owns both
        // blocks — one accepted row, one drafted-and-about-to-be-rejected
        // row (block size 1: one block each).
        let mut spec_cache = pool.new_cache(&[]);
        let row = Matrix::from_fn(1, 2, |_, _| 1.0);
        spec_cache.append(0, &row, &row).unwrap();
        spec_cache.commit(&[7]).unwrap();
        spec_cache.append(0, &row, &row).unwrap();
        spec_cache.commit(&[8]).unwrap();
        assert_eq!(pool.stats().blocks_in_use, 2, "prelude must fill the pool");

        let pr = pool.clone();
        // The verify round rejects the draft: roll back to the accept point.
        let roller = thread::spawn(move || {
            spec_cache.truncate_to(1).expect("rollback needs no new blocks here");
            spec_cache
        });
        // A second request races for the block the rollback frees.
        let acquired = try_acquire(&pool);
        let spec_cache = roller.join().unwrap();

        assert_eq!(spec_cache.len(), 1, "rollback must keep exactly the accepted row");
        let s = pool.stats();
        assert_eq!(
            s.blocks_in_use,
            1 + usize::from(acquired.is_some()),
            "permit count diverged from cache ownership"
        );
        drop((spec_cache, acquired));
        assert_eq!(pr.stats().blocks_in_use, 0, "rollback or release leaked a permit");
        // Conservation: both blocks are grantable again afterwards.
        let again = try_acquire(&pr).expect("drained pool must grant a block again");
        drop(again);
        assert_eq!(pr.stats().blocks_in_use, 0);
    });
    assert!(ex.executions > 1, "rollback/acquire race must branch the search");
}

/// The PR 7 × PR 8 seam: supervisor re-homing releases a dying shard's
/// cache (RAII drop) while a survivor concurrently acquires from the
/// same bounded pool. Under every interleaving the acquirer either wins
/// the freed block or is cleanly refused — and afterwards the block is
/// provably re-acquirable, so release-then-acquire conserves permits
/// exactly once per block (no double-free, no leak).
#[test]
fn model_block_pool_release_vs_acquire_conserves_permits() {
    let ex = explore(|| {
        let pool = Arc::new(BlockPool::new(1, 2, 1, 1));
        // Prelude (single-threaded): the dying shard's decode owns the block.
        let dying = try_acquire(&pool).expect("empty pool must grant the first block");
        let pr = pool.clone();
        let releaser = thread::spawn(move || drop(dying));
        let acquired = try_acquire(&pool);
        releaser.join().unwrap();

        // The racy acquire saw either the pre-release pool (refused) or
        // the post-release pool (won) — both leave the counts coherent.
        let s = pool.stats();
        assert_eq!(
            s.blocks_in_use,
            usize::from(acquired.is_some()),
            "permit count diverged from cache ownership"
        );
        drop(acquired);
        assert_eq!(pr.stats().blocks_in_use, 0, "release leaked a permit");
        // Conservation: after every cache is gone the block is grantable
        // again — a double-free would have pushed `allocated` negative or
        // tripped the permit bound here.
        let again = try_acquire(&pr).expect("drained pool must grant the block again");
        drop(again);
        assert_eq!(pr.stats().blocks_in_use, 0);
    });
    assert!(ex.executions > 1, "release/acquire race must branch the search");
}
