//! Property/equivalence tests for the PR-2 hot-path rebuild: the 64-lane
//! bit-sliced netlist evaluator, the bit-sliced dynamic simulator, and the
//! blocked matmul kernels must be indistinguishable (bit-identical for the
//! gate sim, within tight FP tolerance for the kernels) from the seed
//! scalar implementations they replaced.

use std::sync::Mutex;

use halo::mac::dynsim::{self, DynSim, DynSim64, Transition};
use halo::mac::gate::{Gate, Netlist};
use halo::mac::mac8;
use halo::quant::Matrix;
use halo::runtime::backend::Literal;
use halo::runtime::kernels::{self, naive};
use halo::runtime::sim::{model_loss, ModelSpec};
use halo::util::Rng;

/// Serializes the tests that flip the global `force_naive` kernel switch.
static KERNEL_FLAG: Mutex<()> = Mutex::new(());

// ------------------------------------------------------------ gate eval

/// Random topologically-ordered DAG netlist (raw `Netlist` construction —
/// deliberately bypasses the builder's constant folding so Const gates
/// survive into the evaluator).
fn random_netlist(rng: &mut Rng, n_inputs: usize, n_gates: usize) -> Netlist {
    let mut gates = vec![Gate::Input; n_inputs];
    gates.push(Gate::Const(false));
    gates.push(Gate::Const(true));
    while gates.len() < n_inputs + 2 + n_gates {
        let a = rng.gen_usize(gates.len()) as u32;
        let b = rng.gen_usize(gates.len()) as u32;
        gates.push(match rng.gen_usize(4) {
            0 => Gate::Not(a),
            1 => Gate::And(a, b),
            2 => Gate::Or(a, b),
            _ => Gate::Xor(a, b),
        });
    }
    let len = gates.len();
    let outputs: Vec<u32> = (0..8).map(|_| rng.gen_usize(len) as u32).collect();
    Netlist { gates, outputs }
}

#[test]
fn prop_eval64_equals_64_scalar_evals() {
    let mut rng = Rng::seed_from_u64(0xE64);
    for case in 0..20 {
        let n_inputs = 1 + rng.gen_usize(24);
        let net = random_netlist(&mut rng, n_inputs, 5 + rng.gen_usize(200));

        // 64 random input assignments, packed one per lane.
        let assignments: Vec<Vec<bool>> = (0..64)
            .map(|_| (0..n_inputs).map(|_| rng.gen_bool()).collect())
            .collect();
        let mut words = vec![0u64; net.len()];
        for (lane, bits) in assignments.iter().enumerate() {
            for (i, &bit) in bits.iter().enumerate() {
                words[i] |= (bit as u64) << lane;
            }
        }
        net.eval64_into(&mut words);

        for (lane, bits) in assignments.iter().enumerate() {
            let mut vals = vec![false; net.len()];
            vals[..n_inputs].copy_from_slice(bits);
            net.eval_into(&mut vals);
            for i in 0..net.len() {
                assert_eq!(
                    (words[i] >> lane) & 1 != 0,
                    vals[i],
                    "case {case} lane {lane} node {i}"
                );
            }
            assert_eq!(
                net.read_outputs_lane(&words, lane),
                net.read_outputs(&vals),
                "case {case} lane {lane} outputs"
            );
        }
    }
}

// ------------------------------------------------------------ dynamic sim

#[test]
fn prop_bitsliced_dynsim_equals_scalar_chain() {
    // Toggle counts and settle times of every transition in a random chain
    // must match the scalar simulator bit-for-bit, at every batch split.
    let (net, ports) = mac8::build();
    let mut rng = Rng::seed_from_u64(0xD5);
    for case in 0..6 {
        let w = rng.gen_i8();
        let len = 2 + rng.gen_usize(150);
        let states: Vec<(i8, i32)> = (0..len)
            .map(|_| (rng.gen_i8(), rng.gen_range_i64(-0x400000, 0x400000) as i32))
            .collect();

        let mut scalar = DynSim::new(&net, &ports, w, states[0].0, states[0].1);
        let want: Vec<Transition> =
            states[1..].iter().map(|&(a, acc)| scalar.step(a, acc)).collect();

        let samples = len - 1;
        let mut sim = DynSim64::new(&net, &ports, w);
        let mut got = vec![Transition::default(); samples];
        let mut t = 0usize;
        while t < samples {
            // Random batch sizes exercise every lane-count path.
            let n = (1 + rng.gen_usize(64)).min(samples - t);
            sim.run_batch(&states[t..t + n], &states[t + 1..t + 1 + n], &mut got[t..t + n]);
            t += n;
        }
        assert_eq!(got, want, "case {case} w={w}");
    }
}

#[test]
fn prop_weight_stats_bitsliced_equals_scalar() {
    let (net, ports) = mac8::build();
    let mut rng = Rng::seed_from_u64(0x57A7);
    for _ in 0..8 {
        let w = rng.gen_i8();
        let samples = 1 + rng.gen_usize(200);
        let seed = rng.next_u64();
        assert_eq!(
            dynsim::weight_stats(&net, &ports, w, samples, seed),
            dynsim::weight_stats_scalar(&net, &ports, w, samples, seed),
            "w={w} samples={samples} seed={seed:#x}"
        );
    }
}

#[test]
fn settle_histogram_matches_scalar_replay() {
    // The bit-sliced histogram must reproduce the seed implementation:
    // scalar DynSim over the same RNG stream (initial acc pinned to 0).
    let (net, ports) = mac8::build();
    for &(w, samples, seed) in &[(64i8, 100usize, 1u64), (-127, 130, 9), (5, 64, 3)] {
        let got = dynsim::settle_histogram(&net, &ports, w, samples, seed);

        let mut rng = Rng::seed_from_u64(seed ^ ((w as u8 as u64) << 32));
        let mut sim = DynSim::new(&net, &ports, w, rng.gen_i8(), 0);
        let mut counts = std::collections::BTreeMap::new();
        for _ in 0..samples {
            let t = sim.step(rng.gen_i8(), rng.gen_range_i64(-0x400000, 0x400000) as i32);
            *counts.entry(t.settle).or_insert(0u32) += 1;
        }
        let want: Vec<(u32, u32)> = counts.into_iter().collect();
        assert_eq!(got, want, "w={w} samples={samples}");
    }
}

// ------------------------------------------------------------ matmul kernels

fn assert_close(got: &Matrix, want: &Matrix, what: &str) {
    assert_eq!((got.rows, got.cols), (want.rows, want.cols), "{what}: shape");
    for (i, (a, b)) in got.data.iter().zip(&want.data).enumerate() {
        assert!(
            (a - b).abs() <= 1e-4 * (1.0 + b.abs()),
            "{what}[{i}]: {a} vs {b}"
        );
    }
}

#[test]
fn prop_blocked_matmul_equals_naive_on_random_shapes() {
    let _guard = KERNEL_FLAG.lock().unwrap();
    let mut rng = Rng::seed_from_u64(0xB10C);
    for case in 0..16 {
        // Ragged shapes: nothing divisible by the register block.
        let m = 1 + rng.gen_usize(70);
        let k = 1 + rng.gen_usize(90);
        let n = 1 + rng.gen_usize(80);
        let a = Matrix::random_normal(m, k, 1.0, &mut rng);
        let b = Matrix::random_normal(k, n, 1.0, &mut rng);
        assert_close(
            &kernels::matmul(&a, &b),
            &naive::matmul(&a, &b),
            &format!("matmul case {case} ({m}x{k}x{n})"),
        );

        let at = Matrix::random_normal(k, m, 1.0, &mut rng);
        assert_close(
            &kernels::matmul_tn(&at, &b),
            &naive::matmul_tn(&at, &b),
            &format!("matmul_tn case {case}"),
        );

        let bt = Matrix::random_normal(n, k, 1.0, &mut rng);
        assert_close(
            &kernels::matmul_nt(&a, &bt),
            &naive::matmul_nt(&a, &bt),
            &format!("matmul_nt case {case}"),
        );
    }
}

// ------------------------------------------------------------ full model

fn tiny_spec() -> ModelSpec {
    let (v, d, ff, s) = (13usize, 16usize, 32usize, 9usize);
    let mut names = Vec::new();
    let mut shapes = Vec::new();
    let mut linear = Vec::new();
    let mut push = |n: &str, sh: Vec<usize>, lin: bool| {
        names.push(n.to_string());
        shapes.push(sh);
        linear.push(lin);
    };
    push("embed", vec![v, d], false);
    push("pos_embed", vec![s, d], false);
    for l in 0..2 {
        push(&format!("layer{l}.ln1.scale"), vec![d], false);
        push(&format!("layer{l}.ln1.bias"), vec![d], false);
        push(&format!("layer{l}.attn.wq"), vec![d, d], true);
        push(&format!("layer{l}.attn.wk"), vec![d, d], true);
        push(&format!("layer{l}.attn.wv"), vec![d, d], true);
        push(&format!("layer{l}.attn.wo"), vec![d, d], true);
        push(&format!("layer{l}.ln2.scale"), vec![d], false);
        push(&format!("layer{l}.ln2.bias"), vec![d], false);
        push(&format!("layer{l}.mlp.w1"), vec![d, ff], true);
        push(&format!("layer{l}.mlp.b1"), vec![ff], false);
        push(&format!("layer{l}.mlp.w2"), vec![ff, d], true);
        push(&format!("layer{l}.mlp.b2"), vec![d], false);
    }
    push("ln_f.scale", vec![d], false);
    push("ln_f.bias", vec![d], false);
    push("head", vec![d, v], true);
    ModelSpec {
        vocab: v,
        d_model: d,
        n_layers: 2,
        n_heads: 4,
        d_ff: ff,
        seq_len: s,
        names,
        shapes,
        linear,
    }
}

fn tiny_inputs(spec: &ModelSpec, seed: u64) -> Vec<Literal> {
    let mut rng = Rng::seed_from_u64(seed);
    let mut out = Vec::new();
    for (name, shape) in spec.names.iter().zip(&spec.shapes) {
        let n: usize = shape.iter().product();
        let data: Vec<f32> = if name.ends_with(".scale") {
            vec![1.0; n]
        } else if name.ends_with(".bias") || name.ends_with(".b1") || name.ends_with(".b2") {
            vec![0.0; n]
        } else {
            let std = 1.0 / (shape[0] as f32).sqrt();
            (0..n).map(|_| rng.gen_normal() as f32 * std).collect()
        };
        out.push(Literal::f32(&data, shape).unwrap());
    }
    let (b, s) = (2usize, spec.seq_len);
    let toks: Vec<i32> = (0..b * (s + 1))
        .map(|_| rng.gen_usize(spec.vocab) as i32)
        .collect();
    out.push(Literal::i32(&toks, &[b, s + 1]).unwrap());
    out
}

#[test]
fn model_loss_blocked_matches_naive_kernels() {
    let _guard = KERNEL_FLAG.lock().unwrap();
    let spec = tiny_spec();
    let inputs = tiny_inputs(&spec, 11);
    let refs: Vec<&Literal> = inputs.iter().collect();

    kernels::set_force_naive(true);
    let naive_fp = model_loss(&spec, &refs, false).unwrap();
    let naive_a8 = model_loss(&spec, &refs, true).unwrap();
    kernels::set_force_naive(false);
    let blocked_fp = model_loss(&spec, &refs, false).unwrap();
    let blocked_a8 = model_loss(&spec, &refs, true).unwrap();

    assert!(
        (naive_fp - blocked_fp).abs() <= 1e-4 * (1.0 + naive_fp.abs()),
        "fp loss: naive {naive_fp} vs blocked {blocked_fp}"
    );
    assert!(
        (naive_a8 - blocked_a8).abs() <= 1e-4 * (1.0 + naive_a8.abs()),
        "a8 loss: naive {naive_a8} vs blocked {blocked_a8}"
    );
}
