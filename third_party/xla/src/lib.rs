//! API stub of the `xla` (xla_extension / PJRT bindings) crate.
//!
//! The offline build environment cannot fetch or build the real bindings,
//! so this crate exposes exactly the API surface `halo`'s PJRT backend
//! (`rust/src/runtime/xla.rs`) compiles against. The only reachable entry
//! point, [`PjRtClient::cpu`], returns an error directing the user to
//! vendor the real crate; every other body is therefore unreachable and
//! panics if called directly.
//!
//! To enable real PJRT execution, replace this directory with the actual
//! `xla` crate (elixir-nx xla_extension bindings) — the `halo` side needs
//! no code changes beyond what its `xla` feature already gates.

use std::fmt;
use std::path::Path;

const STUB_MSG: &str =
    "the bundled `xla` crate is an API stub; vendor the real xla/PJRT bindings at \
     third_party/xla to enable the PJRT backend (see README.md)";

#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types of the XLA type system (subset used by halo).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
    S8,
}

/// Native Rust types a literal can be built from / read into.
pub trait NativeType: Copy {}

impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i8 {}
impl NativeType for i32 {}
impl NativeType for i64 {}

pub struct Literal(());

impl Literal {
    pub fn vec1<T: NativeType>(_data: &[T]) -> Literal {
        unreachable!("{STUB_MSG}")
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        unreachable!("{STUB_MSG}")
    }

    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        _dims: &[usize],
        _data: &[u8],
    ) -> Result<Literal> {
        unreachable!("{STUB_MSG}")
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        unreachable!("{STUB_MSG}")
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        unreachable!("{STUB_MSG}")
    }

    pub fn get_first_element<T: NativeType>(&self) -> Result<T> {
        unreachable!("{STUB_MSG}")
    }
}

pub struct PjRtDevice(());

pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unreachable!("{STUB_MSG}")
    }
}

pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<HloModuleProto> {
        unreachable!("{STUB_MSG}")
    }
}

pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        unreachable!("{STUB_MSG}")
    }
}

pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        unreachable!("{STUB_MSG}")
    }

    pub fn execute_b<B: std::borrow::Borrow<PjRtBuffer>>(
        &self,
        _args: &[B],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        unreachable!("{STUB_MSG}")
    }
}

pub struct PjRtClient(());

impl PjRtClient {
    /// The single reachable stub entry point: always errors, so no other
    /// stub body can ever execute.
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error(STUB_MSG.to_string()))
    }

    pub fn platform_name(&self) -> String {
        unreachable!("{STUB_MSG}")
    }

    pub fn addressable_devices(&self) -> Vec<PjRtDevice> {
        unreachable!("{STUB_MSG}")
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<&PjRtDevice>,
        _lit: &Literal,
    ) -> Result<PjRtBuffer> {
        unreachable!("{STUB_MSG}")
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unreachable!("{STUB_MSG}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_client_reports_stub() {
        let err = PjRtClient::cpu().err().expect("stub must not create a client");
        assert!(err.to_string().contains("stub"));
    }
}
