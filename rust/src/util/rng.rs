//! Deterministic RNG (xoshiro256++ seeded by SplitMix64).
//!
//! The offline build environment has no `rand` crate; this is a faithful
//! implementation of the standard generators, used everywhere randomness is
//! needed (circuit transition sampling, synthetic workloads, property
//! tests) so every experiment is reproducible from a seed.

/// xoshiro256++ PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// cached second normal from Box–Muller
    spare_normal: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Deterministic generator from a 64-bit seed (state expanded via
    /// SplitMix64, per the xoshiro authors' recommendation).
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Self { s, spare_normal: None }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Next 32 bits (the generator's high half, per the reference).
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, n) — Lemire's multiply-shift rejection method.
    #[inline]
    pub fn gen_range_u64(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        loop {
            let x = self.next_u64();
            let (hi, lo) = {
                let m = (x as u128) * (n as u128);
                ((m >> 64) as u64, m as u64)
            };
            if lo >= n || lo >= n.wrapping_neg() % n {
                return hi;
            }
        }
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn gen_range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo < hi);
        lo + self.gen_range_u64((hi - lo) as u64) as i64
    }

    /// Uniform index in `[0, n)`.
    pub fn gen_usize(&mut self, n: usize) -> usize {
        self.gen_range_u64(n as u64) as usize
    }

    /// Uniform `i8` over the full range.
    #[inline]
    pub fn gen_i8(&mut self) -> i8 {
        (self.next_u64() >> 56) as u8 as i8
    }

    /// Fair coin flip.
    pub fn gen_bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Standard normal via Box–Muller.
    pub fn gen_normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        let (mut u1, u2) = (self.gen_f64(), self.gen_f64());
        if u1 < 1e-300 {
            u1 = 1e-300;
        }
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.spare_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_usize(i + 1);
            xs.swap(i, j);
        }
    }

    /// Uniformly chosen element (panics on an empty slice).
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.gen_usize(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::seed_from_u64(42);
        let mut b = Rng::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn range_bounds() {
        let mut r = Rng::seed_from_u64(1);
        for _ in 0..10_000 {
            let x = r.gen_range_i64(-5, 7);
            assert!((-5..7).contains(&x));
        }
    }

    #[test]
    fn uniform_mean() {
        let mut r = Rng::seed_from_u64(2);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.gen_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seed_from_u64(3);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gen_normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn gen_usize_bounds_and_coverage() {
        let mut r = Rng::seed_from_u64(7);
        for n in [1usize, 2, 7, 100] {
            let mut seen = vec![false; n];
            for _ in 0..5_000 {
                let x = r.gen_usize(n);
                assert!(x < n, "gen_usize({n}) produced {x}");
                seen[x] = true;
            }
            assert!(seen.iter().all(|&s| s), "gen_usize({n}) missed a residue");
        }
        // n = 1 is always 0.
        for _ in 0..100 {
            assert_eq!(r.gen_usize(1), 0);
        }
    }

    #[test]
    fn seeded_determinism_across_all_generators() {
        // Same seed → identical stream across every generator method;
        // different seeds diverge immediately.
        let mut a = Rng::seed_from_u64(99);
        let mut b = Rng::seed_from_u64(99);
        for _ in 0..200 {
            assert_eq!(a.gen_usize(1000), b.gen_usize(1000));
            assert_eq!(a.gen_f64(), b.gen_f64());
            assert_eq!(a.gen_normal(), b.gen_normal());
            assert_eq!(a.gen_bool(), b.gen_bool());
            assert_eq!(a.gen_i8(), b.gen_i8());
        }
        let mut c = Rng::seed_from_u64(100);
        let first: Vec<u64> = (0..4).map(|_| a.next_u64()).collect();
        let other: Vec<u64> = (0..4).map(|_| c.next_u64()).collect();
        assert_ne!(first, other);
    }

    #[test]
    fn choose_returns_member() {
        let xs = [10, 20, 30];
        let mut r = Rng::seed_from_u64(8);
        for _ in 0..100 {
            assert!(xs.contains(r.choose(&xs)));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seed_from_u64(4);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
