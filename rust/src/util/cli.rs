//! Tiny CLI argument parser (offline build: no clap).
//!
//! Supports `cmd subcmd --flag value --switch positional` with typed
//! accessors and repeated flags.

use anyhow::{anyhow, Context, Result};

/// Parsed command line: positionals in order plus `--flag [value]` pairs.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// Non-flag tokens, in the order given.
    pub positional: Vec<String>,
    /// `(name, value)` per `--name [value]` occurrence, in order; `None`
    /// for bare switches.
    pub flags: Vec<(String, Option<String>)>,
}

impl Args {
    /// Parse everything after the program name. A token `--name` consumes
    /// the following token as its value unless that token is itself a flag.
    pub fn parse(argv: impl IntoIterator<Item = String>) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                let val = match it.peek() {
                    Some(v) if !v.starts_with("--") => Some(it.next().unwrap()),
                    _ => None,
                };
                out.flags.push((name.to_string(), val));
            } else {
                out.positional.push(tok);
            }
        }
        out
    }

    /// Parse the process's own arguments (skipping the program name).
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    /// Whether `--name` appeared (with or without a value).
    pub fn has(&self, name: &str) -> bool {
        self.flags.iter().any(|(n, _)| n == name)
    }

    /// Value of the *last* `--name value` occurrence, if any.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags
            .iter()
            .rev()
            .find(|(n, _)| n == name)
            .and_then(|(_, v)| v.as_deref())
    }

    /// All values of a repeated flag, e.g. `-w 64 -w -127`.
    pub fn get_all(&self, name: &str) -> Vec<&str> {
        self.flags
            .iter()
            .filter(|(n, _)| n == name)
            .filter_map(|(_, v)| v.as_deref())
            .collect()
    }

    /// `--name`'s value, or `default` when absent.
    pub fn str_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    /// `--name` parsed as `usize`, or `default` when absent. Malformed
    /// values are usage errors naming the offending token, never panics.
    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .with_context(|| format!("--{name} must be an integer, got {v:?}")),
        }
    }

    /// `--name` parsed as `u64`, or `default` when absent. Malformed
    /// values are usage errors naming the offending token, never panics.
    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .with_context(|| format!("--{name} must be an integer, got {v:?}")),
        }
    }

    /// `--name` parsed as `f64`, or `default` when absent. Malformed
    /// values are usage errors naming the offending token, never panics.
    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .with_context(|| format!("--{name} must be a number, got {v:?}")),
        }
    }

    /// `--name` parsed as `i64` when present (`None` when absent).
    /// Malformed values are usage errors naming the offending token.
    pub fn i64_of(&self, name: &str) -> Result<Option<i64>> {
        self.get(name)
            .map(|v| {
                v.parse()
                    .with_context(|| format!("--{name} must be an integer, got {v:?}"))
            })
            .transpose()
    }

    /// First positional (the subcommand by convention).
    pub fn subcommand(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }

    /// These args with the first positional stripped (descend one
    /// subcommand level; flags carry through).
    pub fn rest(&self) -> Args {
        Args {
            positional: self.positional.iter().skip(1).cloned().collect(),
            flags: self.flags.clone(),
        }
    }

    /// `--name`'s value, or an error naming the missing flag.
    pub fn require(&self, name: &str) -> Result<&str> {
        self.get(name).ok_or_else(|| anyhow!("missing required flag --{name}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn flags_and_positionals() {
        let a = parse("sim systolic --model llama2-7b --method halo-bal --verbose");
        assert_eq!(a.subcommand(), Some("sim"));
        assert_eq!(a.positional, vec!["sim", "systolic"]);
        assert_eq!(a.get("model"), Some("llama2-7b"));
        assert!(a.has("verbose"));
        assert_eq!(a.get("verbose"), None);
    }

    #[test]
    fn repeated_and_negative_values() {
        // Negative numbers are values, not flags.
        let a = parse("mac histogram --w 64 --w -127");
        assert_eq!(a.get_all("w"), vec!["64", "-127"]);
    }

    #[test]
    fn typed_accessors() {
        let a = parse("x --n 42 --frac 0.5");
        assert_eq!(a.usize_or("n", 0).unwrap(), 42);
        assert_eq!(a.u64_or("n", 0).unwrap(), 42);
        assert_eq!(a.f64_or("frac", 0.0).unwrap(), 0.5);
        assert_eq!(a.usize_or("missing", 7).unwrap(), 7);
        assert_eq!(a.u64_or("missing", 9).unwrap(), 9);
        assert!(a.usize_or("frac", 0).is_err());
    }

    #[test]
    fn malformed_values_error_naming_the_token() {
        // Usage errors, not panics — and the message carries the
        // offending token so `--widths 4,x,8`-style typos are findable.
        let a = parse("x --n 4x --frac abc");
        for (err, tok) in [
            (format!("{:#}", a.usize_or("n", 0).unwrap_err()), "4x"),
            (format!("{:#}", a.u64_or("n", 0).unwrap_err()), "4x"),
            (format!("{:#}", a.i64_of("n").unwrap_err()), "4x"),
            (format!("{:#}", a.f64_or("frac", 0.0).unwrap_err()), "abc"),
        ] {
            assert!(err.contains(tok), "error {err:?} does not name the token {tok:?}");
        }
    }
}
