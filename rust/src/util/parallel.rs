//! Scoped-thread data parallelism (offline build: no rayon).
//!
//! Two primitives cover every compute hot path in the crate:
//! [`par_map`] (index-ordered fan-out over a work list with dynamic load
//! balancing — the MAC profile's 256 weight values, attention's
//! batch × head tasks) and [`par_chunks_mut`] (static partition of a
//! mutable buffer into fixed-size chunks — the matmul kernels' output row
//! blocks). Both degrade to plain serial loops when one thread is
//! available, and every index/chunk runs the same code path regardless of
//! the thread count, so results are deterministic by construction.
//!
//! Thread count: `HALO_THREADS` env override, else the machine's available
//! parallelism, optionally capped by [`set_max_threads`] (benches use the
//! cap to measure serial baselines).

use crate::util::sync::atomic::{AtomicUsize, Ordering};

/// 0 = auto (env / available parallelism); anything else caps the pool.
static MAX_THREADS: AtomicUsize = AtomicUsize::new(0);

/// Serializes tests that toggle the process-global thread cap (they would
/// otherwise race and silently weaken each other's serial leg).
#[cfg(test)]
pub(crate) static THREAD_CAP_TEST_LOCK: crate::util::sync::Mutex<()> =
    crate::util::sync::Mutex::new(());

/// Cap the number of worker threads (0 restores the default). Intended for
/// benchmarks and tests that need a serial baseline; normal code never
/// calls this.
pub fn set_max_threads(n: usize) {
    MAX_THREADS.store(n, Ordering::Relaxed);
}

/// Worker threads to use right now.
pub fn available_threads() -> usize {
    let cap = MAX_THREADS.load(Ordering::Relaxed);
    if cap == 1 {
        return 1;
    }
    let n = std::env::var("HALO_THREADS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .filter(|&n| n > 0)
        .unwrap_or_else(|| std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1));
    if cap == 0 {
        n
    } else {
        n.min(cap)
    }
}

/// Map `f` over `0..n` on scoped threads; results returned in index order.
/// Indices are claimed dynamically through an atomic counter so uneven
/// per-item cost still balances.
pub fn par_map<T, F>(n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let threads = available_threads().min(n.max(1));
    if threads <= 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    let next = AtomicUsize::new(0);
    let mut pairs: Vec<(usize, T)> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..threads)
            .map(|_| {
                let (f, next) = (&f, &next);
                s.spawn(move || {
                    let mut out = Vec::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        out.push((i, f(i)));
                    }
                    out
                })
            })
            .collect();
        handles
            .into_iter()
            .flat_map(|h| h.join().expect("parallel worker panicked"))
            .collect()
    });
    pairs.sort_unstable_by_key(|p| p.0);
    pairs.into_iter().map(|p| p.1).collect()
}

/// Split `data` into `chunk_len`-sized chunks (the last may be short) and
/// process them on scoped threads. `f` receives `(chunk_index, chunk)`
/// exactly once per chunk; each thread owns a contiguous run of chunks, so
/// the partition is static and deterministic.
pub fn par_chunks_mut<T, F>(data: &mut [T], chunk_len: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    assert!(chunk_len > 0, "chunk_len must be positive");
    let n_chunks = data.len().div_ceil(chunk_len);
    let threads = available_threads().min(n_chunks.max(1));
    if threads <= 1 {
        for (i, chunk) in data.chunks_mut(chunk_len).enumerate() {
            f(i, chunk);
        }
        return;
    }
    // Chunks per thread, rounded up: at most `threads` spawns.
    let per = n_chunks.div_ceil(threads);
    std::thread::scope(|s| {
        for (t, run) in data.chunks_mut(per * chunk_len).enumerate() {
            let f = &f;
            s.spawn(move || {
                for (k, chunk) in run.chunks_mut(chunk_len).enumerate() {
                    f(t * per + k, chunk);
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_map_preserves_index_order() {
        let got = par_map(100, |i| i * i);
        let want: Vec<usize> = (0..100).map(|i| i * i).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn par_map_empty_and_single() {
        assert_eq!(par_map(0, |i| i), Vec::<usize>::new());
        assert_eq!(par_map(1, |i| i + 7), vec![7]);
    }

    #[test]
    fn par_chunks_mut_covers_every_chunk_once() {
        let mut data = vec![0u32; 103]; // ragged: 103 = 25 chunks of 4 + 3
        par_chunks_mut(&mut data, 4, |idx, chunk| {
            for v in chunk.iter_mut() {
                *v += 1 + idx as u32;
            }
        });
        for (i, &v) in data.iter().enumerate() {
            assert_eq!(v, 1 + (i / 4) as u32, "element {i}");
        }
    }

    #[test]
    fn results_identical_serial_vs_parallel() {
        let _guard = THREAD_CAP_TEST_LOCK.lock().unwrap();
        let parallel: Vec<u64> = par_map(64, |i| (i as u64).wrapping_mul(0x9E37));
        set_max_threads(1);
        let serial: Vec<u64> = par_map(64, |i| (i as u64).wrapping_mul(0x9E37));
        set_max_threads(0);
        assert_eq!(parallel, serial);
    }
}
