//! Micro-benchmark harness (offline build: no criterion).
//!
//! Criterion-style protocol: warm-up, then timed batches until a target
//! wall time, reporting mean / p50 / p95 per iteration. Used by the
//! `benches/` targets (declared `harness = false`) and the §Perf pass.

use std::time::{Duration, Instant};

/// Per-iteration timing statistics from one [`bench`]/[`bench_n`] run.
#[derive(Debug, Clone)]
pub struct BenchStats {
    /// Benchmark label (as printed by [`report`](Self::report)).
    pub name: String,
    /// Timed iterations collected.
    pub iters: u64,
    /// Mean per-iteration wall time.
    pub mean: Duration,
    /// Median per-iteration wall time.
    pub p50: Duration,
    /// 95th-percentile per-iteration wall time.
    pub p95: Duration,
}

impl BenchStats {
    /// Mean per-iteration wall time in seconds.
    pub fn mean_s(&self) -> f64 {
        self.mean.as_secs_f64()
    }

    /// criterion-like one-liner.
    pub fn report(&self) -> String {
        format!(
            "{:<44} time: [{} {} {}]  ({} iters)",
            self.name,
            fmt_dur(self.p50),
            fmt_dur(self.mean),
            fmt_dur(self.p95),
            self.iters
        )
    }
}

/// Human-readable duration with an auto-selected unit (ns/µs/ms/s).
pub fn fmt_dur(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Run `f` repeatedly for ~`target` seconds (after warm-up) and collect stats.
pub fn bench<F: FnMut()>(name: &str, target: Duration, mut f: F) -> BenchStats {
    // Warm-up: run until 10% of target or at least once.
    let warm_end = Instant::now() + target / 10;
    f();
    while Instant::now() < warm_end {
        f();
    }

    let mut samples: Vec<Duration> = Vec::new();
    let end = Instant::now() + target;
    while Instant::now() < end {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
        if samples.len() >= 100_000 {
            break;
        }
    }
    stats_from(name, samples)
}

/// Fixed iteration count variant (for slow end-to-end cases).
pub fn bench_n<F: FnMut()>(name: &str, iters: u64, mut f: F) -> BenchStats {
    f(); // warm-up
    let mut samples = Vec::with_capacity(iters as usize);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
    }
    stats_from(name, samples)
}

fn stats_from(name: &str, mut samples: Vec<Duration>) -> BenchStats {
    assert!(!samples.is_empty());
    samples.sort_unstable();
    let iters = samples.len() as u64;
    let total: Duration = samples.iter().sum();
    let pct = |p: f64| samples[(((samples.len() - 1) as f64) * p) as usize];
    BenchStats {
        name: name.to_string(),
        iters,
        mean: total / iters as u32,
        p50: pct(0.50),
        p95: pct(0.95),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reports_sane_stats() {
        let s = bench_n("noop", 50, || {
            std::hint::black_box(1 + 1);
        });
        assert_eq!(s.iters, 50);
        assert!(s.p50 <= s.p95);
        assert!(s.report().contains("noop"));
    }

    #[test]
    fn time_budget_respected() {
        let t0 = Instant::now();
        bench("sleepless", Duration::from_millis(50), || {
            std::hint::black_box((0..100).sum::<u64>());
        });
        assert!(t0.elapsed() < Duration::from_secs(2));
    }

    #[test]
    fn duration_formatting() {
        assert!(fmt_dur(Duration::from_nanos(500)).contains("ns"));
        assert!(fmt_dur(Duration::from_micros(50)).contains("µs"));
        assert!(fmt_dur(Duration::from_millis(5)).contains("ms"));
        assert!(fmt_dur(Duration::from_secs(2)).contains(" s"));
    }
}
