//! In-crate substitutes for unavailable third-party crates (offline build):
//! RNG, JSON, CLI parsing, bench harness, and a loom-style sync shim with
//! a built-in model checker. See DESIGN.md §Key decisions.

pub mod bench;
pub mod cli;
pub mod failpoint;
pub mod json;
pub mod parallel;
pub mod rng;
pub mod sync;

pub use json::Json;
pub use rng::Rng;
