//! In-crate substitutes for unavailable third-party crates (offline build):
//! RNG, JSON, CLI parsing, bench harness. See DESIGN.md §Key decisions.

pub mod bench;
pub mod cli;
pub mod json;
pub mod parallel;
pub mod rng;

pub use json::Json;
pub use rng::Rng;
