//! Minimal JSON parser/emitter (offline build: no serde facade available).
//!
//! Handles the full JSON grammar we exchange with the Python AOT pipeline
//! (manifests, param tables) and our own saved profiles/reports. Numbers
//! are f64 — every integer we serialize fits exactly (< 2^53).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// Any number (stored as f64; integers < 2^53 are exact).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object (key-sorted for deterministic emission).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---- constructors ----
    /// Empty object, ready for chained [`set`](Self::set) calls.
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Insert/overwrite `key` on an object (panics on non-objects —
    /// builder misuse, not a data error).
    pub fn set(&mut self, key: &str, v: impl Into<Json>) -> &mut Self {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), v.into());
        } else {
            panic!("set() on non-object");
        }
        self
    }

    // ---- accessors ----
    /// Object member lookup (`None` on missing key or non-object).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Like [`get`](Self::get) but a missing key is an error naming it.
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing key `{key}`"))
    }

    /// The number, or an error for any other variant.
    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(x) => Ok(*x),
            _ => bail!("not a number: {self:?}"),
        }
    }

    /// The number truncated to `usize` (counts/dims in manifests).
    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_f64()? as usize)
    }

    /// The bool, or an error for any other variant.
    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("not a bool"),
        }
    }

    /// The string, or an error for any other variant.
    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    /// The array elements, or an error for any other variant.
    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("not an array"),
        }
    }

    /// The object map, or an error for any other variant.
    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("not an object"),
        }
    }

    /// Convenience: `j.req("a")?.req("b")?...`
    pub fn path(&self, keys: &[&str]) -> Result<&Json> {
        let mut cur = self;
        for k in keys {
            cur = cur.req(k)?;
        }
        Ok(cur)
    }

    // ---- emit ----
    /// Multi-line emission (objects indented; arrays stay on one line).
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, true);
        s
    }

    /// Single-line emission with no whitespace.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, false);
        s
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |out: &mut String, n: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..n {
                    out.push(' ');
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                        if pretty {
                            out.push(' ');
                        }
                    }
                    x.write(out, indent, false); // arrays stay on one line
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                pad(out, indent);
                out.push('}');
            }
        }
    }

    // ---- parse ----
    /// Parse a complete JSON document (trailing garbage is an error).
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing characters at offset {}", p.i);
        }
        Ok(v)
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Json {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Json {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Json {
        Json::Str(x)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(xs: Vec<T>) -> Json {
        Json::Arr(xs.into_iter().map(Into::into).collect())
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of JSON"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected `{}` at offset {}, found `{}`", c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("invalid literal at offset {}", self.i)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            m.insert(k, self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected `,` or `}}`, found `{}`", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected `,` or `]`, found `{}`", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("bad \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape \\{}", e as char),
                    }
                }
                c => {
                    // Collect the full UTF-8 sequence.
                    let start = self.i - 1;
                    let len = match c {
                        0x00..=0x7f => 1,
                        0xc0..=0xdf => 2,
                        0xe0..=0xef => 3,
                        _ => 4,
                    };
                    self.i = start + len;
                    s.push_str(std::str::from_utf8(&self.b[start..self.i])?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>().map_err(|e| anyhow!("bad number `{s}`: {e}"))?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_nested() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": {"c": null, "d": true}, "s": "x\n\"y\""}"#;
        let v = Json::parse(src).unwrap();
        let re = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, re);
        assert_eq!(v.path(&["b", "d"]).unwrap().as_bool().unwrap(), true);
        assert_eq!(v.req("a").unwrap().as_arr().unwrap()[2].as_f64().unwrap(), -300.0);
    }

    #[test]
    fn parses_python_style_manifest() {
        let src = "{\n \"halo_tile\": 128,\n \"models\": {\"tiny\": {\"n_params\": 558464}}\n}";
        let v = Json::parse(src).unwrap();
        assert_eq!(v.req("halo_tile").unwrap().as_usize().unwrap(), 128);
        assert_eq!(
            v.path(&["models", "tiny", "n_params"]).unwrap().as_usize().unwrap(),
            558464
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("{}x").is_err());
    }

    #[test]
    fn unicode_strings() {
        let v = Json::parse(r#""héllo é""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo é");
    }

    #[test]
    fn integers_emit_without_decimal() {
        let mut o = Json::obj();
        o.set("n", 42usize);
        assert_eq!(o.to_string_compact(), r#"{"n":42}"#);
    }

    #[test]
    fn string_escaping_roundtrip() {
        // Every escape class the emitter produces must parse back exactly:
        // quotes, backslashes, the named controls, and \uXXXX controls.
        let nasty = "a\"b\\c\nd\re\tf\u{1}g\u{1f}h/ü—é";
        let mut o = Json::obj();
        o.set("s", nasty);
        let emitted = o.to_string_compact();
        assert!(emitted.contains("\\\"") && emitted.contains("\\\\"));
        assert!(emitted.contains("\\n") && emitted.contains("\\u0001"));
        let back = Json::parse(&emitted).unwrap();
        assert_eq!(back.req("s").unwrap().as_str().unwrap(), nasty);
        // Explicit \u escapes on the way in, too.
        let v = Json::parse("\"x\\u0041\\u00e9y\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "xA\u{e9}y");
    }

    #[test]
    fn nested_emit_pretty_and_compact() {
        let mut inner = Json::obj();
        inner
            .set("freqs", vec![1.9f64, 2.4, 3.7])
            .set("name", "ladder")
            .set("derived", false);
        let mut o = Json::obj();
        o.set("meta", inner).set("count", 3usize).set("none", Json::Null);

        let compact = o.to_string_compact();
        assert!(!compact.contains('\n'));
        let pretty = o.to_string_pretty();
        assert!(pretty.lines().count() > 3, "pretty output should be multi-line");
        // Both forms parse back to the same structure.
        let a = Json::parse(&compact).unwrap();
        let b = Json::parse(&pretty).unwrap();
        assert_eq!(a, b);
        assert_eq!(a.path(&["meta", "name"]).unwrap().as_str().unwrap(), "ladder");
        assert_eq!(a.path(&["meta", "freqs"]).unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(a.req("none").unwrap(), &Json::Null);
    }
}
