//! Engine self-tests: the explorer must prove correct protocols correct,
//! and *find* planted deadlocks, lost wakeups and lost updates.

use std::collections::HashSet;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::time::Duration;

use super::atomic::{AtomicUsize, Ordering};
use super::{explore, model, thread, Arc, Condvar, Mutex};

#[test]
fn single_threaded_model_needs_exactly_one_execution() {
    let ex = explore(|| {
        let m = Mutex::new(0);
        *m.lock().unwrap() += 1;
        assert_eq!(*m.lock().unwrap(), 1);
    });
    assert_eq!(ex.executions, 1, "no concurrency → no alternatives to explore");
}

#[test]
fn mutex_increments_never_lose_updates() {
    let ex = explore(|| {
        let m = Arc::new(Mutex::new(0u32));
        let handles: Vec<_> = (0..2)
            .map(|_| {
                let m = m.clone();
                thread::spawn(move || *m.lock().unwrap() += 1)
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(*m.lock().unwrap(), 2);
    });
    assert!(ex.executions > 1, "two racing threads must yield multiple interleavings");
}

#[test]
fn check_then_act_lost_update_is_found() {
    // Non-atomic increment (load; store) from two threads: the exhaustive
    // search must witness BOTH the correct outcome (2) and the lost
    // update (1). This is the canonical race the shim exists to catch.
    let finals = Arc::new(std::sync::Mutex::new(HashSet::new()));
    let sink = finals.clone();
    explore(move || {
        let a = Arc::new(AtomicUsize::new(0));
        let handles: Vec<_> = (0..2)
            .map(|_| {
                let a = a.clone();
                thread::spawn(move || {
                    let v = a.load(Ordering::SeqCst);
                    a.store(v + 1, Ordering::SeqCst);
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        sink.lock().unwrap().insert(a.load(Ordering::SeqCst));
    });
    let finals = finals.lock().unwrap();
    assert!(finals.contains(&2), "missed the race-free interleaving: {finals:?}");
    assert!(finals.contains(&1), "missed the lost-update interleaving: {finals:?}");
}

#[test]
fn lock_order_inversion_deadlock_is_detected() {
    let r = catch_unwind(AssertUnwindSafe(|| {
        model(|| {
            let a = Arc::new(Mutex::new(()));
            let b = Arc::new(Mutex::new(()));
            let (a2, b2) = (a.clone(), b.clone());
            let t = thread::spawn(move || {
                let _ga = a2.lock().unwrap();
                let _gb = b2.lock().unwrap();
            });
            {
                let _gb = b.lock().unwrap();
                let _ga = a.lock().unwrap();
            }
            t.join().unwrap();
        });
    }));
    let msg = format!("{:?}", r.expect_err("AB/BA lock order must deadlock"));
    assert!(msg.contains("deadlock"), "wrong failure: {msg}");
}

#[test]
fn lost_wakeup_without_predicate_loop_is_detected() {
    // The waiter waits unconditionally; if the notify lands first it is
    // lost and the waiter parks forever. The search must find that
    // schedule and report the deadlock.
    let r = catch_unwind(AssertUnwindSafe(|| {
        model(|| {
            let pair = Arc::new((Mutex::new(false), Condvar::new()));
            let p2 = pair.clone();
            let waiter = thread::spawn(move || {
                let (m, cv) = &*p2;
                let g = m.lock().unwrap();
                let _g = cv.wait(g).unwrap(); // BUG under test: no predicate re-check
            });
            let (m, cv) = &*pair;
            *m.lock().unwrap() = true;
            cv.notify_one();
            waiter.join().unwrap();
        });
    }));
    let msg = format!("{:?}", r.expect_err("unconditional wait must lose a wakeup"));
    assert!(msg.contains("deadlock"), "wrong failure: {msg}");
}

#[test]
fn predicate_loop_condvar_protocol_is_race_free() {
    // The same handoff with the canonical while-loop protocol passes
    // under every interleaving.
    let ex = explore(|| {
        let pair = Arc::new((Mutex::new(false), Condvar::new()));
        let p2 = pair.clone();
        let waiter = thread::spawn(move || {
            let (m, cv) = &*p2;
            let mut g = m.lock().unwrap();
            while !*g {
                g = cv.wait(g).unwrap();
            }
        });
        let (m, cv) = &*pair;
        *m.lock().unwrap() = true;
        cv.notify_one();
        waiter.join().unwrap();
    });
    assert!(ex.executions > 1);
}

#[test]
fn wait_timeout_fires_when_nothing_notifies() {
    let saw_timeout = Arc::new(std::sync::Mutex::new(false));
    let sink = saw_timeout.clone();
    explore(move || {
        let m = Mutex::new(false);
        let cv = Condvar::new();
        let mut g = m.lock().unwrap();
        while !*g {
            let (g2, r) = cv.wait_timeout(g, Duration::from_millis(1)).unwrap();
            g = g2;
            if r.timed_out() {
                *sink.lock().unwrap() = true;
                return; // drop the guard; nothing will ever set the flag
            }
        }
    });
    assert!(*saw_timeout.lock().unwrap(), "timeout path never explored");
}

#[test]
fn passthrough_mode_works_like_std() {
    // Outside model(): shim types are plain std wrappers.
    let m = Arc::new(Mutex::new(0u32));
    let a = Arc::new(AtomicUsize::new(0));
    let (m2, a2) = (m.clone(), a.clone());
    let h = thread::spawn(move || {
        *m2.lock().unwrap() += 5;
        a2.fetch_add(1, Ordering::SeqCst);
        7u32
    });
    assert_eq!(h.join().unwrap(), 7);
    assert_eq!(*m.lock().unwrap(), 5);
    assert_eq!(a.load(Ordering::SeqCst), 1);

    // A passthrough timed wait actually times out.
    let g = m.lock().unwrap();
    let cv = Condvar::new();
    let (_g, r) = cv.wait_timeout(g, Duration::from_millis(5)).unwrap();
    assert!(r.timed_out());
}
