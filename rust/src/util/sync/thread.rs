//! Shim `thread::spawn`/`JoinHandle`: std threads outside a model,
//! scheduler-managed threads inside one.

use std::sync::Arc;

use super::engine::{ctx, Scheduler};

enum Inner<T> {
    Std(std::thread::JoinHandle<T>),
    Model {
        sched: Arc<Scheduler>,
        tid: usize,
        result: Arc<std::sync::Mutex<Option<T>>>,
    },
}

/// Handle to a spawned shim thread; join to collect its result.
pub struct JoinHandle<T>(Inner<T>);

impl<T> JoinHandle<T> {
    /// Wait for the thread to finish and return its result. Mirrors
    /// `std::thread::JoinHandle::join` (an `Err` carries the panic
    /// payload; in a model, a panicked thread fails the whole model
    /// before `join` can observe it).
    pub fn join(self) -> std::thread::Result<T> {
        match self.0 {
            Inner::Std(h) => h.join(),
            Inner::Model { sched, tid, result } => {
                let (_, caller) = ctx().expect("model JoinHandle joined outside its model");
                sched.join(caller, tid);
                let v = result
                    .lock()
                    .unwrap_or_else(|e| e.into_inner())
                    .take()
                    .expect("model thread finished without storing a result");
                Ok(v)
            }
        }
    }
}

/// Spawn a thread running `f`. Inside [`super::model`] the thread is
/// scheduler-managed (its operations become scheduling points); outside,
/// this is `std::thread::spawn`.
pub fn spawn<F, T>(f: F) -> JoinHandle<T>
where
    F: FnOnce() -> T + Send + 'static,
    T: Send + 'static,
{
    match ctx() {
        None => JoinHandle(Inner::Std(std::thread::spawn(f))),
        Some((sched, my_tid)) => {
            let result: Arc<std::sync::Mutex<Option<T>>> =
                Arc::new(std::sync::Mutex::new(None));
            let slot = result.clone();
            let tid = sched.spawn(
                my_tid,
                Box::new(move || {
                    let v = f();
                    *slot.lock().unwrap_or_else(|e| e.into_inner()) = Some(v);
                }),
            );
            JoinHandle(Inner::Model { sched, tid, result })
        }
    }
}
