//! [`Mutex`] and [`Condvar`]: `std::sync` wrappers that become
//! model-checked primitives inside [`super::model`].
//!
//! Outside a model they delegate to the wrapped std types (the std mutex
//! provides the real exclusion). Inside a model, *logical* ownership is
//! granted by the scheduler — which is what makes every acquire/wait a
//! scheduling point — and the std types underneath never contend, because
//! only the logically-owning thread touches them.

use std::ops::{Deref, DerefMut};
use std::sync::{LockResult, PoisonError};
use std::time::Duration;

use super::engine::ctx;

/// Panics when a shim primitive is used outside [`super::model`] in the
/// strict build (`--cfg loom`) — the CI leg that proves the loom-style
/// suite exercises only modeled code.
#[inline]
fn strict_passthrough_check() {
    #[cfg(loom)]
    panic!("sync shim used outside model() under --cfg loom");
}

/// Drop-in `std::sync::Mutex` replacement with a model-checked mode.
///
/// `const`-constructible, so process-global statics (e.g. the workload
/// trace cache) keep working.
#[derive(Debug, Default)]
pub struct Mutex<T> {
    inner: std::sync::Mutex<T>,
}

impl<T> Mutex<T> {
    /// Create a new mutex guarding `value`.
    pub const fn new(value: T) -> Self {
        Self { inner: std::sync::Mutex::new(value) }
    }

    /// The lock's model identity (its address; stable for the `Arc`- or
    /// static-held mutexes a model can express).
    fn id(&self) -> usize {
        self as *const Self as *const () as usize
    }

    /// Acquire the lock, blocking until available. Mirrors
    /// `std::sync::Mutex::lock`, poison semantics included.
    pub fn lock(&self) -> LockResult<MutexGuard<'_, T>> {
        match ctx() {
            Some((sched, tid)) => sched.lock_acquire(tid, self.id()),
            None => strict_passthrough_check(),
        }
        match self.inner.lock() {
            Ok(g) => Ok(MutexGuard { lock: self, inner: Some(g) }),
            Err(p) => Err(PoisonError::new(MutexGuard {
                lock: self,
                inner: Some(p.into_inner()),
            })),
        }
    }

    /// Consume the mutex, returning the inner value (poison reported as in
    /// `std::sync::Mutex::into_inner`).
    pub fn into_inner(self) -> LockResult<T> {
        self.inner.into_inner()
    }
}

/// RAII guard for [`Mutex`]; releases logical (model) ownership after the
/// physical std unlock.
#[derive(Debug)]
pub struct MutexGuard<'a, T> {
    lock: &'a Mutex<T>,
    /// `Some` until dropped or handed to a condvar wait.
    inner: Option<std::sync::MutexGuard<'a, T>>,
}

impl<'a, T> MutexGuard<'a, T> {
    /// Take the std guard out without running our Drop (condvar
    /// passthrough hands the guard to `std::sync::Condvar`).
    fn into_std(mut self) -> std::sync::MutexGuard<'a, T> {
        let g = self.inner.take().expect("guard invariant: inner present until drop");
        std::mem::forget(self);
        g
    }
}

impl<T> Deref for MutexGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        self.inner.as_ref().expect("guard invariant: inner present until drop")
    }
}

impl<T> DerefMut for MutexGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        self.inner.as_mut().expect("guard invariant: inner present until drop")
    }
}

impl<T> Drop for MutexGuard<'_, T> {
    fn drop(&mut self) {
        // Physical unlock first, then logical release: by the time another
        // thread can be granted logical ownership, the std lock is free.
        drop(self.inner.take());
        if let Some((sched, tid)) = ctx() {
            sched.lock_release(tid, self.lock.id());
        }
    }
}

/// Result of a [`Condvar::wait_timeout`]; mirrors
/// `std::sync::WaitTimeoutResult`.
#[derive(Debug, Clone, Copy)]
pub struct WaitTimeoutResult {
    timed_out: bool,
}

impl WaitTimeoutResult {
    /// True when the wait returned because the timeout elapsed (in a
    /// model: because the scheduler chose to fire the timeout).
    pub fn timed_out(&self) -> bool {
        self.timed_out
    }
}

/// Drop-in `std::sync::Condvar` replacement with a model-checked mode.
///
/// In a model, `notify_one`'s choice of waiter and a timed wait's
/// timeout-vs-notify outcome are scheduling choices, so the search covers
/// lost-wakeup and timeout races. Untimed waits wake only on notify
/// (spurious wakeups are not modeled; all in-crate wait loops re-check
/// their predicate regardless).
#[derive(Debug, Default)]
pub struct Condvar {
    inner: std::sync::Condvar,
}

impl Condvar {
    /// Create a new condition variable.
    pub const fn new() -> Self {
        Self { inner: std::sync::Condvar::new() }
    }

    fn id(&self) -> usize {
        self as *const Self as *const () as usize
    }

    /// Release `guard` and park until notified; reacquires before
    /// returning. Mirrors `std::sync::Condvar::wait`.
    pub fn wait<'a, T>(&self, guard: MutexGuard<'a, T>) -> LockResult<MutexGuard<'a, T>> {
        match ctx() {
            None => {
                strict_passthrough_check();
                let lock = guard.lock;
                match self.inner.wait(guard.into_std()) {
                    Ok(g) => Ok(MutexGuard { lock, inner: Some(g) }),
                    Err(p) => Err(PoisonError::new(MutexGuard {
                        lock,
                        inner: Some(p.into_inner()),
                    })),
                }
            }
            Some((sched, tid)) => {
                let lock = guard.lock;
                sched.cv_register(tid, self.id(), false);
                drop(guard);
                sched.cv_park(tid);
                lock.lock()
            }
        }
    }

    /// Like [`wait`](Self::wait) but also wakes when `dur` elapses. In a
    /// model the duration is ignored: whether the timeout fires is a
    /// scheduling choice, so both outcomes are explored.
    pub fn wait_timeout<'a, T>(
        &self,
        guard: MutexGuard<'a, T>,
        dur: Duration,
    ) -> LockResult<(MutexGuard<'a, T>, WaitTimeoutResult)> {
        match ctx() {
            None => {
                strict_passthrough_check();
                let lock = guard.lock;
                match self.inner.wait_timeout(guard.into_std(), dur) {
                    Ok((g, r)) => Ok((
                        MutexGuard { lock, inner: Some(g) },
                        WaitTimeoutResult { timed_out: r.timed_out() },
                    )),
                    Err(p) => {
                        let (g, r) = p.into_inner();
                        Err(PoisonError::new((
                            MutexGuard { lock, inner: Some(g) },
                            WaitTimeoutResult { timed_out: r.timed_out() },
                        )))
                    }
                }
            }
            Some((sched, tid)) => {
                let lock = guard.lock;
                sched.cv_register(tid, self.id(), true);
                drop(guard);
                let timed_out = sched.cv_park(tid);
                match lock.lock() {
                    Ok(g) => Ok((g, WaitTimeoutResult { timed_out })),
                    Err(p) => Err(PoisonError::new((
                        p.into_inner(),
                        WaitTimeoutResult { timed_out },
                    ))),
                }
            }
        }
    }

    /// Wake one waiter (which one is a scheduling choice in a model).
    pub fn notify_one(&self) {
        match ctx() {
            Some((sched, tid)) => sched.cv_notify_one(tid, self.id()),
            None => {
                strict_passthrough_check();
                self.inner.notify_one();
            }
        }
    }

    /// Wake every waiter.
    pub fn notify_all(&self) {
        match ctx() {
            Some((sched, tid)) => sched.cv_notify_all(tid, self.id()),
            None => {
                strict_passthrough_check();
                self.inner.notify_all();
            }
        }
    }
}
