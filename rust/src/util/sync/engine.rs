//! The deterministic interleaving explorer behind [`model`].
//!
//! One *execution* runs the model body on real OS threads, but exactly one
//! thread is ever runnable: every shim operation is a scheduling point
//! where the [`Scheduler`] picks which thread advances next. The sequence
//! of picks is recorded; after an execution finishes, the deepest choice
//! with an unexplored alternative becomes the replay prefix of the next
//! execution. The search therefore enumerates every interleaving of
//! scheduling points exactly once (depth-first, no randomness, no
//! wall-clock dependence).

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar as StdCondvar, Mutex as StdMutex};

/// Scheduling-point budget per execution; exceeding it means the model is
/// far too large to check exhaustively (a test-design bug, not a race).
const MAX_STEPS: usize = 100_000;

/// Default execution budget; override with `HALO_MODEL_MAX_EXECS`.
const MAX_EXECS: usize = 50_000;

thread_local! {
    /// Set on threads spawned by the scheduler: (engine, my thread id).
    static CTX: RefCell<Option<(Arc<Scheduler>, usize)>> = const { RefCell::new(None) };
}

/// The current thread's model context, if it runs under [`model`].
pub(super) fn ctx() -> Option<(Arc<Scheduler>, usize)> {
    CTX.with(|c| c.borrow().clone())
}

/// Unwind payload used to tear threads down when an execution aborts; the
/// thread wrapper swallows it so it never surfaces as a test panic.
struct Abort;

/// Why a blocked condvar waiter became runnable again.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub(super) enum Wake {
    /// `notify_one` / `notify_all` picked this waiter.
    Notify,
    /// The scheduler fired the waiter's timeout (`wait_timeout` only).
    Timeout,
}

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum Status {
    Runnable,
    /// Waiting to acquire the lock with this identity.
    BlockedLock(usize),
    /// Parked on a condvar; `can_timeout` waiters stay schedulable (the
    /// scheduler picking one = its timeout fires).
    BlockedCv { cv: usize, can_timeout: bool },
    /// Waiting for the thread with this id to finish.
    BlockedJoin(usize),
    Finished,
}

struct ThreadState {
    status: Status,
    woke: Option<Wake>,
}

struct SchedState {
    threads: Vec<ThreadState>,
    /// The one thread allowed to run user code right now.
    current: usize,
    /// Choice indices replayed from the previous execution.
    prefix: Vec<usize>,
    /// `(picked, options)` per scheduling decision this execution.
    choices: Vec<(usize, usize)>,
    /// Lock identity → owning thread id (absent = free).
    locks: BTreeMap<usize, usize>,
    steps: usize,
    aborting: bool,
    failure: Option<String>,
}

impl SchedState {
    fn fail(&mut self, msg: String) {
        if self.failure.is_none() {
            self.failure = Some(msg);
        }
        self.aborting = true;
    }

    fn all_finished(&self) -> bool {
        self.threads.iter().all(|t| t.status == Status::Finished)
    }

    fn schedulable(&self, tid: usize) -> bool {
        matches!(
            self.threads[tid].status,
            Status::Runnable | Status::BlockedCv { can_timeout: true, .. }
        )
    }
}

/// One model-checking engine instance (one call to [`explore`]); reused
/// across nothing — each execution builds a fresh `Scheduler`.
pub(super) struct Scheduler {
    state: StdMutex<SchedState>,
    cv: StdCondvar,
    handles: StdMutex<Vec<std::thread::JoinHandle<()>>>,
}

impl Scheduler {
    fn new(prefix: Vec<usize>) -> Self {
        Self {
            state: StdMutex::new(SchedState {
                threads: Vec::new(),
                current: 0,
                prefix,
                choices: Vec::new(),
                locks: BTreeMap::new(),
                steps: 0,
                aborting: false,
                failure: None,
            }),
            cv: StdCondvar::new(),
            handles: StdMutex::new(Vec::new()),
        }
    }

    /// Lock the scheduler state from a *model* thread: if the execution is
    /// aborting, unwind instead of proceeding.
    fn lock_model(&self) -> std::sync::MutexGuard<'_, SchedState> {
        let st = self.state.lock().unwrap_or_else(|e| e.into_inner());
        if st.aborting {
            drop(st);
            std::panic::panic_any(Abort);
        }
        st
    }

    /// Record one scheduling decision over `n` options (replaying the
    /// prefix first, then defaulting to option 0 for DFS).
    fn choose(st: &mut SchedState, n: usize) -> usize {
        let depth = st.choices.len();
        let k = if depth < st.prefix.len() {
            let k = st.prefix[depth];
            if k >= n {
                st.fail(format!(
                    "nondeterministic replay: choice {depth} had {n} options, prefix wanted {k} \
                     (does the model branch on wall-clock time or an unmodeled input?)"
                ));
                0
            } else {
                k
            }
        } else {
            0
        };
        st.choices.push((k, n));
        k
    }

    /// Pick the next thread to run among the schedulable set and make it
    /// current. No schedulable thread + unfinished threads = deadlock.
    fn pick_next(&self, st: &mut SchedState) {
        if st.aborting {
            self.cv.notify_all();
            return;
        }
        st.steps += 1;
        if st.steps > MAX_STEPS {
            st.fail(format!(
                "model exceeded {MAX_STEPS} scheduling points in one execution — shrink the model"
            ));
            self.cv.notify_all();
            return;
        }
        let options: Vec<usize> =
            (0..st.threads.len()).filter(|&t| st.schedulable(t)).collect();
        if options.is_empty() {
            if !st.all_finished() {
                let stuck: Vec<String> = st
                    .threads
                    .iter()
                    .enumerate()
                    .filter(|(_, t)| t.status != Status::Finished)
                    .map(|(i, t)| format!("thread {i}: {:?}", t.status))
                    .collect();
                st.fail(format!("deadlock: no schedulable thread [{}]", stuck.join(", ")));
            }
            self.cv.notify_all();
            return;
        }
        let k = Self::choose(st, options.len());
        let tid = options[k];
        // Scheduling a timeout-able condvar waiter = its timeout fires.
        if let Status::BlockedCv { can_timeout: true, .. } = st.threads[tid].status {
            st.threads[tid].status = Status::Runnable;
            st.threads[tid].woke = Some(Wake::Timeout);
        }
        st.current = tid;
        self.cv.notify_all();
    }

    /// Park until this thread is current and runnable; returns the state
    /// guard so callers can keep mutating under the same lock hold.
    fn wait_turn<'a>(
        &'a self,
        mut st: std::sync::MutexGuard<'a, SchedState>,
        tid: usize,
    ) -> std::sync::MutexGuard<'a, SchedState> {
        loop {
            if st.aborting {
                drop(st);
                std::panic::panic_any(Abort);
            }
            if st.current == tid && st.threads[tid].status == Status::Runnable {
                return st;
            }
            st = self.cv.wait(st).unwrap_or_else(|e| e.into_inner());
        }
    }

    /// Plain scheduling point: any schedulable thread (including the
    /// caller) may run next.
    pub(super) fn op_yield(&self, tid: usize) {
        let mut st = self.lock_model();
        self.pick_next(&mut st);
        let _st = self.wait_turn(st, tid);
    }

    /// Acquire the lock with identity `id` (a scheduling point; blocks —
    /// in scheduler terms — while another thread owns it).
    pub(super) fn lock_acquire(&self, tid: usize, id: usize) {
        self.op_yield(tid);
        let mut st = self.lock_model();
        loop {
            match st.locks.get(&id) {
                None => {
                    st.locks.insert(id, tid);
                    return;
                }
                Some(&owner) if owner == tid => {
                    st.fail(format!("thread {tid} re-locked a mutex it already holds"));
                    drop(st);
                    std::panic::panic_any(Abort);
                }
                Some(_) => {
                    st.threads[tid].status = Status::BlockedLock(id);
                    self.pick_next(&mut st);
                    st = self.wait_turn(st, tid);
                }
            }
        }
    }

    /// Release the lock with identity `id` and make its waiters runnable
    /// (they re-contend when next scheduled). Not a scheduling point.
    pub(super) fn lock_release(&self, tid: usize, id: usize) {
        let mut st = match self.state.lock() {
            Ok(st) => st,
            Err(e) => e.into_inner(),
        };
        if st.aborting {
            return; // teardown: the execution is being torn down anyway
        }
        if st.locks.remove(&id).is_none() {
            st.fail(format!("thread {tid} released a mutex it does not hold"));
            return;
        }
        for t in st.threads.iter_mut() {
            if t.status == Status::BlockedLock(id) {
                t.status = Status::Runnable;
            }
        }
    }

    /// Register the caller as a waiter on condvar `cv`. Must be called
    /// *before* the associated lock is released; no scheduling happens
    /// until [`cv_park`](Self::cv_park).
    pub(super) fn cv_register(&self, tid: usize, cv: usize, can_timeout: bool) {
        let mut st = self.lock_model();
        st.threads[tid].status = Status::BlockedCv { cv, can_timeout };
        st.threads[tid].woke = None;
    }

    /// Park on the condvar registered via [`cv_register`](Self::cv_register);
    /// returns true when the wakeup was a timeout.
    pub(super) fn cv_park(&self, tid: usize) -> bool {
        let mut st = self.lock_model();
        self.pick_next(&mut st);
        let mut st = self.wait_turn(st, tid);
        st.threads[tid].woke.take() == Some(Wake::Timeout)
    }

    /// Wake one waiter on condvar `cv` (which one is a scheduling choice —
    /// exactly the nondeterminism `notify_one` has in production).
    pub(super) fn cv_notify_one(&self, tid: usize, cv: usize) {
        self.op_yield(tid);
        let mut st = self.lock_model();
        let waiters: Vec<usize> = (0..st.threads.len())
            .filter(|&t| matches!(st.threads[t].status, Status::BlockedCv { cv: c, .. } if c == cv))
            .collect();
        if waiters.is_empty() {
            return; // a lost notify is faithfully a no-op
        }
        let k = Self::choose(&mut st, waiters.len());
        let w = waiters[k];
        st.threads[w].status = Status::Runnable;
        st.threads[w].woke = Some(Wake::Notify);
    }

    /// Wake every waiter on condvar `cv`.
    pub(super) fn cv_notify_all(&self, tid: usize, cv: usize) {
        self.op_yield(tid);
        let mut st = self.lock_model();
        for t in st.threads.iter_mut() {
            if matches!(t.status, Status::BlockedCv { cv: c, .. } if c == cv) {
                t.status = Status::Runnable;
                t.woke = Some(Wake::Notify);
            }
        }
    }

    /// Atomic-operation scheduling point (the op itself runs on the real
    /// std atomic immediately after, while the caller is still current).
    pub(super) fn op_atomic(&self, tid: usize) {
        self.op_yield(tid);
    }

    /// Register + start a new model thread running `f`; returns its id.
    pub(super) fn spawn(self: &Arc<Self>, parent: usize, f: Box<dyn FnOnce() + Send>) -> usize {
        self.op_yield(parent);
        let child = {
            let mut st = self.lock_model();
            st.threads.push(ThreadState { status: Status::Runnable, woke: None });
            st.threads.len() - 1
        };
        self.spawn_os_thread(child, f);
        child
    }

    /// Block until thread `target` finishes (a scheduling point).
    pub(super) fn join(&self, tid: usize, target: usize) {
        self.op_yield(tid);
        let mut st = self.lock_model();
        loop {
            if st.threads[target].status == Status::Finished {
                return;
            }
            st.threads[tid].status = Status::BlockedJoin(target);
            self.pick_next(&mut st);
            st = self.wait_turn(st, tid);
        }
    }

    fn spawn_os_thread(self: &Arc<Self>, tid: usize, f: Box<dyn FnOnce() + Send>) {
        let sched = self.clone();
        let h = std::thread::Builder::new()
            .name(format!("halo-model-{tid}"))
            .spawn(move || {
                CTX.with(|c| *c.borrow_mut() = Some((sched.clone(), tid)));
                let in_body = sched.clone();
                let result = catch_unwind(AssertUnwindSafe(move || {
                    // Park until first scheduled, then run the model body.
                    let st = in_body.lock_model();
                    drop(in_body.wait_turn(st, tid));
                    f();
                }));
                sched.finish_thread(tid, result);
            })
            .expect("spawning a model thread");
        self.handles
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .push(h);
    }

    fn finish_thread(&self, tid: usize, result: std::thread::Result<()>) {
        let mut st = match self.state.lock() {
            Ok(st) => st,
            Err(e) => e.into_inner(),
        };
        if let Err(payload) = result {
            if !payload.is::<Abort>() && !st.aborting {
                let msg = payload
                    .downcast_ref::<&str>()
                    .map(|s| s.to_string())
                    .or_else(|| payload.downcast_ref::<String>().cloned())
                    .unwrap_or_else(|| "non-string panic payload".to_string());
                st.fail(format!("model thread {tid} panicked: {msg}"));
            }
        }
        st.threads[tid].status = Status::Finished;
        for t in st.threads.iter_mut() {
            if t.status == Status::BlockedJoin(tid) {
                t.status = Status::Runnable;
            }
        }
        self.pick_next(&mut st);
        self.cv.notify_all();
    }

    /// Run one execution of the model body; returns the recorded choices
    /// and the failure, if any.
    fn run_one(
        self: &Arc<Self>,
        f: Arc<dyn Fn() + Send + Sync>,
    ) -> (Vec<(usize, usize)>, Option<String>) {
        {
            let mut st = self.state.lock().unwrap_or_else(|e| e.into_inner());
            st.threads.push(ThreadState { status: Status::Runnable, woke: None });
            st.current = 0;
        }
        self.spawn_os_thread(0, Box::new(move || f()));
        let (choices, failure) = {
            let mut st = self.state.lock().unwrap_or_else(|e| e.into_inner());
            while !st.all_finished() {
                st = self.cv.wait(st).unwrap_or_else(|e| e.into_inner());
            }
            (std::mem::take(&mut st.choices), st.failure.take())
        };
        let handles = std::mem::take(
            &mut *self.handles.lock().unwrap_or_else(|e| e.into_inner()),
        );
        for h in handles {
            let _ = h.join(); // panics were already captured per-thread
        }
        (choices, failure)
    }
}

/// What [`explore`] reports about a completed search.
#[derive(Debug, Clone, Copy)]
pub struct Exploration {
    /// Number of distinct interleavings executed.
    pub executions: usize,
}

/// Exhaustively explore every interleaving of `f`'s scheduling points.
///
/// `f` is re-run once per interleaving, so it must construct all of its
/// shared state fresh inside the closure. Returns how many executions the
/// search needed; panics (with the failing schedule) on deadlock, on a
/// panic inside a model thread, or when the state space exceeds the
/// execution budget (`HALO_MODEL_MAX_EXECS`, default 50 000).
pub fn explore<F: Fn() + Send + Sync + 'static>(f: F) -> Exploration {
    let f: Arc<dyn Fn() + Send + Sync> = Arc::new(f);
    let max_execs = std::env::var("HALO_MODEL_MAX_EXECS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .filter(|&n| n > 0)
        .unwrap_or(MAX_EXECS);
    let mut prefix: Vec<usize> = Vec::new();
    let mut executions = 0usize;
    loop {
        executions += 1;
        let sched = Arc::new(Scheduler::new(prefix.clone()));
        let (choices, failure) = sched.run_one(f.clone());
        if let Some(msg) = failure {
            let trace: Vec<usize> = choices.iter().map(|c| c.0).collect();
            panic!(
                "model failed on execution {executions}: {msg}\nfailing schedule: {trace:?}"
            );
        }
        // Deepest decision with an unexplored alternative → next prefix.
        let mut next = None;
        for i in (0..choices.len()).rev() {
            let (picked, options) = choices[i];
            if picked + 1 < options {
                let mut p: Vec<usize> = choices[..i].iter().map(|c| c.0).collect();
                p.push(picked + 1);
                next = Some(p);
                break;
            }
        }
        match next {
            Some(p) => prefix = p,
            None => return Exploration { executions },
        }
        if executions >= max_execs {
            panic!(
                "model state space exceeded {max_execs} executions — shrink the model or raise \
                 HALO_MODEL_MAX_EXECS"
            );
        }
    }
}

/// Model-check `f` across every interleaving of its scheduling points,
/// panicking on the first failing schedule. The loom-`model()` analogue.
pub fn model<F: Fn() + Send + Sync + 'static>(f: F) {
    explore(f);
}
