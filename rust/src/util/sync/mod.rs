//! Synchronization shim: the only sanctioned gateway to `Mutex`/`Condvar`
//! (enforced by `halo-lint`'s `sync-via-shim` rule), with a built-in
//! model-checking mode.
//!
//! The offline build has no `loom` crate, so this module carries its own
//! CHESS-style systematic concurrency tester (see [`model`]): inside
//! [`model`], every shim primitive becomes a *scheduling point* of a
//! deterministic cooperative scheduler that explores thread interleavings
//! exhaustively by depth-first search over scheduling choices. Outside
//! [`model`] the types are zero-surprise wrappers that delegate straight
//! to `std::sync` — production code pays one thread-local lookup per
//! operation and nothing else.
//!
//! Two build modes:
//!
//! - default: passthrough outside [`model`], checked inside. The loom-style
//!   suite (`tests/loom_coordinator.rs`) runs under plain `cargo test`.
//! - `--cfg loom` (the strict CI leg): using a shim primitive *outside*
//!   [`model`] panics, which proves the model-checked suite exercises only
//!   modeled code paths.
//!
//! What the checker explores and what it cannot see: interleavings are
//! enumerated at shim-operation granularity (lock/unlock, condvar
//! wait/notify, atomic ops, spawn/join) under sequentially-consistent
//! semantics. It detects deadlocks, lost wakeups, lost updates,
//! check-then-act races and invariant violations on modeled state; it does
//! *not* model weak memory orderings (loom does) nor interleave plain
//! non-atomic memory accesses between scheduling points. All shared state
//! in a model must therefore live behind these shim types — the same rule
//! loom imposes.

pub mod atomic;
mod engine;
mod primitives;
#[cfg(test)]
mod tests;
pub mod thread;

pub use engine::{explore, model, Exploration};
pub use primitives::{Condvar, Mutex, MutexGuard, WaitTimeoutResult};

/// Re-exported so call sites migrate off `std::sync` wholesale.
pub use std::sync::Arc;
/// Lock results mirror `std::sync` exactly (poison carries the guard).
pub use std::sync::{LockResult, PoisonError};
