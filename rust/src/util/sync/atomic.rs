//! Shim atomics: `std::sync::atomic` wrappers whose every operation is a
//! scheduling point inside [`super::model`].
//!
//! The model runs under sequentially-consistent semantics regardless of
//! the `Ordering` passed (the scheduler serializes operations); outside a
//! model the ordering is forwarded to std untouched.

/// Memory orderings are std's own — the shim forwards them verbatim.
pub use std::sync::atomic::Ordering;

use super::engine::ctx;

#[inline]
fn hook() {
    if let Some((sched, tid)) = ctx() {
        sched.op_atomic(tid);
    }
}

macro_rules! int_atomic {
    ($(#[$meta:meta])* $name:ident, $std:ty, $int:ty) => {
        $(#[$meta])*
        #[derive(Debug, Default)]
        pub struct $name {
            inner: $std,
        }

        impl $name {
            /// Create a new atomic with the given initial value.
            pub const fn new(v: $int) -> Self {
                Self { inner: <$std>::new(v) }
            }

            /// Load the current value.
            pub fn load(&self, order: Ordering) -> $int {
                hook();
                self.inner.load(order)
            }

            /// Store a new value.
            pub fn store(&self, v: $int, order: Ordering) {
                hook();
                self.inner.store(v, order);
            }

            /// Add `v`, returning the previous value.
            pub fn fetch_add(&self, v: $int, order: Ordering) -> $int {
                hook();
                self.inner.fetch_add(v, order)
            }

            /// Subtract `v`, returning the previous value.
            pub fn fetch_sub(&self, v: $int, order: Ordering) -> $int {
                hook();
                self.inner.fetch_sub(v, order)
            }

            /// Replace the value, returning the previous one.
            pub fn swap(&self, v: $int, order: Ordering) -> $int {
                hook();
                self.inner.swap(v, order)
            }
        }
    };
}

int_atomic!(
    /// Shim `AtomicUsize` (scheduling point per operation in a model).
    AtomicUsize,
    std::sync::atomic::AtomicUsize,
    usize
);
int_atomic!(
    /// Shim `AtomicU64` (scheduling point per operation in a model).
    AtomicU64,
    std::sync::atomic::AtomicU64,
    u64
);

/// Shim `AtomicBool` (scheduling point per operation in a model).
#[derive(Debug, Default)]
pub struct AtomicBool {
    inner: std::sync::atomic::AtomicBool,
}

impl AtomicBool {
    /// Create a new atomic flag with the given initial value.
    pub const fn new(v: bool) -> Self {
        Self { inner: std::sync::atomic::AtomicBool::new(v) }
    }

    /// Load the current value.
    pub fn load(&self, order: Ordering) -> bool {
        hook();
        self.inner.load(order)
    }

    /// Store a new value.
    pub fn store(&self, v: bool, order: Ordering) {
        hook();
        self.inner.store(v, order);
    }

    /// Replace the value, returning the previous one.
    pub fn swap(&self, v: bool, order: Ordering) -> bool {
        hook();
        self.inner.swap(v, order)
    }
}
