//! Deterministic, seeded fault injection ("failpoints") for chaos testing.
//!
//! The serving path is instrumented with a handful of *named sites* (see
//! [`sites`]) where a fault can be injected: a panic (simulating a shard
//! crash), an error (simulating a transient backend failure), or a delay
//! (simulating scheduler jitter / a slow device). Which sites fire, how
//! often, and in what order is governed entirely by a seeded schedule, so
//! every chaos run is reproducible: same plans + same seed + same thread
//! interleaving ⇒ same faults.
//!
//! # Zero cost when disabled
//!
//! [`check`] is a single relaxed atomic load when no schedule is
//! installed — no lock, no allocation, no branch misprediction of note —
//! so production binaries and benchmarks (the BENCH gates) pay nothing.
//! The subsystem is deliberately a *runtime* switch rather than a cargo
//! feature: the chaos suite must run under plain `cargo test` (tier-1)
//! against the same binary the other tests exercise.
//!
//! # Process-global registry
//!
//! The registry is process-global (faults fire on shard/worker threads
//! that know nothing about which test installed the schedule), so tests
//! that install failpoints MUST serialize with each other and clear the
//! registry when done — use [`install_guarded`] and keep all
//! registry-driven chaos tests in one binary (`tests/chaos.rs`), which
//! serializes them behind a lock.
//!
//! # Environment configuration
//!
//! `halo serve` / `halo loadgen` call [`install_from_env`]:
//!
//! ```text
//! HALO_FAILPOINTS="shard.step=panic,0.02;queue.push=delay:1,0.3"
//! HALO_FAILPOINT_SEED=7
//! ```
//!
//! Each `;`-separated entry is `site=fault[,prob[,after[,max_fires]]]`
//! where `fault` is `panic`, `error`, or `delay:<ms>`; `prob` is the
//! per-hit fire probability (default 1.0), `after` skips the first N hits
//! (default 0), and `max_fires` caps total fires (default 0 = unlimited).

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

use crate::util::sync::Mutex;
use crate::util::Rng;

/// Canonical failpoint site names wired through the serving path.
pub mod sites {
    /// Top of a shard's batching loop (`coordinator/server.rs`); a fault
    /// here kills the whole executor generation (supervisor respawns).
    pub const SHARD_LOOP: &str = "shard.loop";
    /// Admission of one request into decode (`BatchExecutor::begin`).
    pub const SHARD_BEGIN: &str = "shard.begin";
    /// One fused decode step over the live batch (`BatchExecutor::step`).
    pub const SHARD_STEP: &str = "shard.step";
    /// `RequestQueue::push` — fires on the *submitter's* thread, so panic
    /// faults are downgraded to errors here (soft site).
    pub const QUEUE_PUSH: &str = "queue.push";
    /// KV pool block acquisition (`runtime/kvcache.rs`).
    pub const KVCACHE_GROW: &str = "kvcache.grow";
    /// Backend forward entry (`runtime/sim.rs`, full and incremental).
    pub const SIM_RUN: &str = "sim.run";
}

/// Name of the env var holding the failpoint schedule.
pub const ENV_PLANS: &str = "HALO_FAILPOINTS";
/// Name of the env var holding the schedule seed (default 0).
pub const ENV_SEED: &str = "HALO_FAILPOINT_SEED";

/// What a firing failpoint does to the instrumented code path.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Fault {
    /// `panic!` at the site (a shard-thread site unwinds into the
    /// supervisor's fence and reads as a shard crash).
    Panic,
    /// Return an `Err` from the site (a transient backend failure).
    Error,
    /// Sleep for the given duration, then proceed normally.
    Delay(Duration),
}

/// One seeded injection rule: where to fire, what to inject, how often.
#[derive(Debug, Clone)]
pub struct FailPlan {
    /// Site name (one of [`sites`], or any string for tests).
    pub site: String,
    /// Fault to inject when the plan fires.
    pub fault: Fault,
    /// Per-hit fire probability in `[0, 1]`; `1.0` fires on every hit.
    pub prob: f64,
    /// Skip the first `after` hits at this site before arming.
    pub after: u64,
    /// Stop firing after this many fires (`0` = unlimited).
    pub max_fires: u64,
}

impl FailPlan {
    /// A plan that fires on every hit at `site`, forever.
    pub fn always(site: &str, fault: Fault) -> Self {
        Self { site: site.to_string(), fault, prob: 1.0, after: 0, max_fires: 0 }
    }

    /// Set the per-hit fire probability.
    #[must_use]
    pub fn with_prob(mut self, prob: f64) -> Self {
        self.prob = prob;
        self
    }

    /// Skip the first `after` hits before the plan can fire.
    #[must_use]
    pub fn with_after(mut self, after: u64) -> Self {
        self.after = after;
        self
    }

    /// Cap the total number of fires.
    #[must_use]
    pub fn with_max_fires(mut self, max_fires: u64) -> Self {
        self.max_fires = max_fires;
        self
    }
}

struct PlanState {
    plan: FailPlan,
    hits: u64,
    fires: u64,
    rng: Rng,
}

/// Fast-path gate: `false` ⇒ `check` is a single relaxed load.
static ACTIVE: AtomicBool = AtomicBool::new(false);
/// Total fires across all sites since the last `install`.
static TOTAL_FIRES: AtomicU64 = AtomicU64::new(0);
/// Installed schedule. Shim mutex (const-constructible, lint-compliant);
/// never locked while `ACTIVE` is false, so the disabled path stays free.
static REGISTRY: Mutex<Vec<PlanState>> = Mutex::new(Vec::new());

fn fnv1a(s: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in s.as_bytes() {
        h ^= u64::from(*b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Install a seeded fault schedule, replacing any previous one.
///
/// Each plan gets an independent RNG stream derived from `seed`, the site
/// name, and the plan's position, so adding a plan never perturbs the
/// firing pattern of the others.
pub fn install(plans: Vec<FailPlan>, seed: u64) {
    let states: Vec<PlanState> = plans
        .into_iter()
        .enumerate()
        .map(|(i, plan)| {
            let rng = Rng::seed_from_u64(seed ^ fnv1a(&plan.site) ^ ((i as u64) << 32));
            PlanState { plan, hits: 0, fires: 0, rng }
        })
        .collect();
    let enable = !states.is_empty();
    {
        let mut reg = REGISTRY.lock().unwrap_or_else(|e| e.into_inner());
        *reg = states;
    }
    TOTAL_FIRES.store(0, Ordering::Relaxed);
    ACTIVE.store(enable, Ordering::SeqCst);
}

/// Remove the installed schedule; [`check`] returns to its no-op fast path.
pub fn clear() {
    ACTIVE.store(false, Ordering::SeqCst);
    let mut reg = REGISTRY.lock().unwrap_or_else(|e| e.into_inner());
    reg.clear();
}

/// Whether a fault schedule is currently installed.
pub fn enabled() -> bool {
    ACTIVE.load(Ordering::Relaxed)
}

/// Total fires across all sites since the last [`install`].
pub fn total_fired() -> u64 {
    TOTAL_FIRES.load(Ordering::Relaxed)
}

/// Fires recorded at `site` since the last [`install`].
pub fn fired(site: &str) -> u64 {
    if !enabled() {
        return 0;
    }
    let reg = REGISTRY.lock().unwrap_or_else(|e| e.into_inner());
    reg.iter().filter(|s| s.plan.site == site).map(|s| s.fires).sum()
}

/// RAII guard returned by [`install_guarded`]; clears the registry on drop
/// so a panicking test cannot leak its schedule into the next one.
#[must_use = "dropping the guard immediately clears the failpoint schedule"]
pub struct FailpointsGuard {
    _priv: (),
}

impl Drop for FailpointsGuard {
    fn drop(&mut self) {
        clear();
    }
}

/// [`install`] + a guard that [`clear`]s on drop (for tests).
pub fn install_guarded(plans: Vec<FailPlan>, seed: u64) -> FailpointsGuard {
    install(plans, seed);
    FailpointsGuard { _priv: () }
}

/// Evaluate the failpoint at `site`: no-op unless a schedule is installed
/// and a matching plan fires. `Fault::Panic` panics, `Fault::Error`
/// returns `Err`, `Fault::Delay` sleeps then returns `Ok`.
#[inline]
pub fn check(site: &str) -> Result<()> {
    if !ACTIVE.load(Ordering::Relaxed) {
        return Ok(());
    }
    check_slow(site, true)
}

/// Like [`check`], but downgrades `Fault::Panic` to an error. Used at
/// sites that execute on a *caller's* thread (e.g. `queue.push`), where a
/// raw panic would unwind into client code instead of a supervisor fence.
#[inline]
pub fn check_soft(site: &str) -> Result<()> {
    if !ACTIVE.load(Ordering::Relaxed) {
        return Ok(());
    }
    check_slow(site, false)
}

#[cold]
fn check_slow(site: &str, allow_panic: bool) -> Result<()> {
    let fault = {
        let mut reg = REGISTRY.lock().unwrap_or_else(|e| e.into_inner());
        let mut hit = None;
        for st in reg.iter_mut().filter(|s| s.plan.site == site) {
            st.hits += 1;
            if st.hits <= st.plan.after {
                continue;
            }
            if st.plan.max_fires != 0 && st.fires >= st.plan.max_fires {
                continue;
            }
            if st.plan.prob < 1.0 && st.rng.gen_f64() >= st.plan.prob {
                continue;
            }
            st.fires += 1;
            hit = Some(st.plan.fault);
            break;
        }
        hit
    }; // registry lock released before sleeping/panicking
    let Some(fault) = fault else { return Ok(()) };
    TOTAL_FIRES.fetch_add(1, Ordering::Relaxed);
    match fault {
        Fault::Delay(d) => {
            std::thread::sleep(d);
            Ok(())
        }
        Fault::Error => Err(anyhow!("failpoint `{site}`: injected error")),
        Fault::Panic if allow_panic => panic!("failpoint `{site}`: injected panic"),
        Fault::Panic => Err(anyhow!("failpoint `{site}`: injected panic (soft site, downgraded)")),
    }
}

/// Parse one `site=fault[,prob[,after[,max_fires]]]` entry.
fn parse_plan(entry: &str) -> Result<FailPlan> {
    let (site, spec) = entry
        .split_once('=')
        .with_context(|| format!("failpoint entry `{entry}` missing `site=fault`"))?;
    let mut parts = spec.split(',');
    let fault_s = parts.next().unwrap_or_default().trim();
    let fault = if fault_s == "panic" {
        Fault::Panic
    } else if fault_s == "error" {
        Fault::Error
    } else if let Some(ms) = fault_s.strip_prefix("delay:") {
        let ms: u64 = ms.parse().with_context(|| format!("bad delay in `{entry}`"))?;
        Fault::Delay(Duration::from_millis(ms))
    } else {
        bail!("failpoint `{entry}`: fault must be panic | error | delay:<ms>");
    };
    let mut plan = FailPlan::always(site.trim(), fault);
    if let Some(p) = parts.next() {
        plan.prob = p.trim().parse().with_context(|| format!("bad prob in `{entry}`"))?;
    }
    if let Some(a) = parts.next() {
        plan.after = a.trim().parse().with_context(|| format!("bad after in `{entry}`"))?;
    }
    if let Some(m) = parts.next() {
        plan.max_fires = m.trim().parse().with_context(|| format!("bad max_fires in `{entry}`"))?;
    }
    Ok(plan)
}

/// Install a schedule from `HALO_FAILPOINTS` / `HALO_FAILPOINT_SEED`.
/// Returns `Ok(true)` when a schedule was installed, `Ok(false)` when the
/// env var is unset or empty, and `Err` on a malformed spec.
pub fn install_from_env() -> Result<bool> {
    let Ok(spec) = std::env::var(ENV_PLANS) else { return Ok(false) };
    if spec.trim().is_empty() {
        return Ok(false);
    }
    let plans = spec
        .split(';')
        .filter(|e| !e.trim().is_empty())
        .map(|e| parse_plan(e.trim()))
        .collect::<Result<Vec<_>>>()?;
    let seed = match std::env::var(ENV_SEED) {
        Ok(s) => s.trim().parse().with_context(|| format!("bad {ENV_SEED}"))?,
        Err(_) => 0,
    };
    install(plans, seed);
    Ok(true)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Failpoint tests share the process-global registry, so they
    /// serialize behind this lock (shim mutex per the sync-via-shim rule).
    static TEST_LOCK: Mutex<()> = Mutex::new(());

    #[test]
    fn disabled_is_a_noop_and_reports_nothing() {
        let _l = TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        clear();
        assert!(!enabled());
        assert!(check("shard.step").is_ok());
        assert_eq!(fired("shard.step"), 0);
        assert_eq!(total_fired(), 0);
    }

    #[test]
    fn error_fault_fires_after_skip_and_respects_max_fires() {
        let _l = TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let _g = install_guarded(
            vec![FailPlan::always("t.err", Fault::Error).with_after(2).with_max_fires(1)],
            1,
        );
        assert!(check("t.err").is_ok(), "hit 1 skipped");
        assert!(check("t.err").is_ok(), "hit 2 skipped");
        assert!(check("t.err").is_err(), "hit 3 fires");
        assert!(check("t.err").is_ok(), "max_fires=1 exhausted");
        assert_eq!(fired("t.err"), 1);
        assert!(check("t.other").is_ok(), "unrelated site untouched");
    }

    #[test]
    fn probabilistic_fires_are_seed_deterministic() {
        let _l = TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let run = |seed: u64| {
            let _g = install_guarded(
                vec![FailPlan::always("t.prob", Fault::Error).with_prob(0.5)],
                seed,
            );
            (0..64).map(|_| u8::from(check("t.prob").is_err())).collect::<Vec<_>>()
        };
        let a = run(7);
        assert_eq!(a, run(7), "same seed must reproduce the firing pattern");
        assert_ne!(a, run(8), "different seed must perturb the pattern");
        let fires = a.iter().map(|&b| u64::from(b)).sum::<u64>();
        assert!((8..=56).contains(&fires), "p=0.5 over 64 hits fired {fires}x");
    }

    #[test]
    fn soft_check_downgrades_panic_to_error() {
        let _l = TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let _g = install_guarded(vec![FailPlan::always("t.soft", Fault::Panic)], 1);
        assert!(check_soft("t.soft").is_err(), "soft site returns Err, not panic");
    }

    #[test]
    fn panic_fault_panics_with_site_in_message() {
        let _l = TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let _g = install_guarded(vec![FailPlan::always("t.boom", Fault::Panic)], 1);
        let r = std::panic::catch_unwind(|| check("t.boom"));
        let msg = r.expect_err("panic fault must panic");
        let msg = msg.downcast_ref::<String>().cloned().unwrap_or_default();
        assert!(msg.contains("t.boom"), "panic message names the site: {msg}");
        clear();
    }

    #[test]
    fn delay_fault_sleeps_then_proceeds() {
        let _l = TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let _g = install_guarded(
            vec![FailPlan::always("t.slow", Fault::Delay(Duration::from_millis(5)))],
            1,
        );
        let t0 = std::time::Instant::now();
        assert!(check("t.slow").is_ok());
        assert!(t0.elapsed() >= Duration::from_millis(5));
    }

    #[test]
    fn env_spec_round_trips() {
        let p = parse_plan("shard.step=panic,0.25,3,2").expect("valid spec");
        assert_eq!(p.site, "shard.step");
        assert_eq!(p.fault, Fault::Panic);
        assert!((p.prob - 0.25).abs() < 1e-12);
        assert_eq!((p.after, p.max_fires), (3, 2));
        let d = parse_plan("queue.push=delay:7").expect("valid delay spec");
        assert_eq!(d.fault, Fault::Delay(Duration::from_millis(7)));
        assert!(parse_plan("nofault").is_err());
        assert!(parse_plan("x=explode").is_err());
    }
}
