//! LLM workload library: the paper's evaluation models as GEMM traces.
//!
//! Performance/energy figures (8–13) run the *real* layer shapes of
//! LLaMA2-7B/13B and OPT-1.3B/30B without materializing their weights
//! (DESIGN.md, key decision 4): each layer carries a [`LayerQuant`]
//! describing how a quantization method distributes its tiles across
//! frequency classes — either measured from a real [`QuantResult`] (the
//! trained tiny models) or synthesized through the *same* adaptive-k code
//! path from a heavy-tailed tile-sensitivity model fitted to the trained
//! models.

use crate::dvfs::FreqClass;
use crate::mac::MacProfile;
use crate::quant::tiles::{adaptive_k, low_sensitivity_mask};
use crate::quant::{QuantResult, Variant};
use crate::util::Rng;

/// One GEMM in an inference pass: (m × k) @ (k × n), repeated `count` times.
#[derive(Debug, Clone)]
pub struct Gemm {
    pub name: &'static str,
    pub k: usize,
    pub n: usize,
    pub count: usize,
}

/// A model as a bag of weight GEMMs (per transformer block × n_layers).
#[derive(Debug, Clone)]
pub struct ModelShapes {
    pub name: &'static str,
    pub gemms: Vec<Gemm>,
    pub params: f64,
}

impl ModelShapes {
    fn new(name: &'static str, gemms: Vec<Gemm>) -> Self {
        let params = gemms
            .iter()
            .map(|g| (g.k * g.n * g.count) as f64)
            .sum();
        Self { name, gemms, params }
    }

    /// LLaMA-2 7B: d=4096, ff=11008 (SwiGLU: gate/up/down), 32 blocks.
    pub fn llama2_7b() -> Self {
        Self::llama(7, 4096, 11008, 32)
    }

    /// LLaMA-2 13B: d=5120, ff=13824, 40 blocks.
    pub fn llama2_13b() -> Self {
        Self::llama(13, 5120, 13824, 40)
    }

    fn llama(_b: usize, d: usize, ff: usize, layers: usize) -> Self {
        let name: &'static str = match d {
            4096 => "llama2-7b",
            5120 => "llama2-13b",
            _ => "llama2",
        };
        Self::new(
            name,
            vec![
                Gemm { name: "attn.qkv", k: d, n: d, count: 3 * layers },
                Gemm { name: "attn.o", k: d, n: d, count: layers },
                Gemm { name: "mlp.gate_up", k: d, n: ff, count: 2 * layers },
                Gemm { name: "mlp.down", k: ff, n: d, count: layers },
            ],
        )
    }

    /// OPT-1.3B: d=2048, ff=8192, 24 blocks.
    pub fn opt_1p3b() -> Self {
        Self::opt("opt-1.3b", 2048, 24)
    }

    /// OPT-30B: d=7168, ff=28672, 48 blocks.
    pub fn opt_30b() -> Self {
        Self::opt("opt-30b", 7168, 48)
    }

    fn opt(name: &'static str, d: usize, layers: usize) -> Self {
        Self::new(
            name,
            vec![
                Gemm { name: "attn.qkv", k: d, n: d, count: 3 * layers },
                Gemm { name: "attn.o", k: d, n: d, count: layers },
                Gemm { name: "mlp.up", k: d, n: 4 * d, count: layers },
                Gemm { name: "mlp.down", k: 4 * d, n: d, count: layers },
            ],
        )
    }

    /// The paper's four evaluation models.
    pub fn paper_models() -> Vec<ModelShapes> {
        vec![
            Self::llama2_7b(),
            Self::llama2_13b(),
            Self::opt_1p3b(),
            Self::opt_30b(),
        ]
    }
}

/// How a quantization method lays one GEMM's tiles across classes.
#[derive(Debug, Clone)]
pub struct LayerQuant {
    /// Fraction of MACs per frequency class (sums to ~1 with sparse).
    pub frac: [f64; 3],
    /// Fraction of weights routed to the SpMV engine.
    pub sparse_frac: f64,
    /// Mean dynamic MAC energy (pJ at V_NOM) per class.
    pub energy_pj: [f64; 3],
    /// Stored bits per dense weight (memory traffic).
    pub bits_eff: f64,
    /// 16 ⇒ FP16 datapath (half MAC throughput, wide ops).
    pub is_fp16: bool,
}

impl LayerQuant {
    /// Measure from a real quantization result.
    pub fn from_result(res: &QuantResult, profile: &MacProfile) -> Self {
        let mut macs = [0f64; 3];
        let mut e_sum = [0f64; 3];
        for (t, &f) in res.tile_freq_ghz.iter().enumerate() {
            let c = crate::dvfs::classify(f, profile) as usize;
            let numel = res.grid.tile_numel(t) as f64;
            macs[c] += numel;
            e_sum[c] += res.tile_energy_pj[t] * numel;
        }
        let total: f64 = macs.iter().sum::<f64>().max(1.0);
        let fallback = profile.full_range_energy_pj();
        let energy =
            std::array::from_fn(|c| if macs[c] > 0.0 { e_sum[c] / macs[c] } else { fallback });
        Self {
            frac: std::array::from_fn(|c| macs[c] / total),
            sparse_frac: res.sparse_nnz as f64 / res.dequant.numel() as f64,
            energy_pj: energy,
            bits_eff: res.bits_eff,
            is_fp16: res.method == "fp16",
        }
    }

    /// Hot-weight density of the synthetic sensitivity field: the fraction
    /// of weights carrying dominant Fisher mass (fitted so the tile-128
    /// high-sensitivity fraction matches the trained tiny models, ~40%).
    pub const HOT_WEIGHT_DENSITY: f64 = 3.1e-5;

    /// Synthesize a HALO layout at paper scale with a *spatially sparse*
    /// sensitivity field: a small density of hot weights dominates the
    /// Fisher mass (what trained LLMs show), so a tile is high-sensitivity
    /// iff it caught ≥1 hot weight — which is how smaller tiles localize
    /// sensitivity and win (paper §IV-D). Classification then runs through
    /// the *same* adaptive-k code path as the real quantizer.
    pub fn synthetic_halo(
        variant: Variant,
        n_tiles: usize,
        tile: usize,
        profile: &MacProfile,
        seed: u64,
    ) -> Self {
        let mut rng = Rng::seed_from_u64(seed);
        let lambda = Self::HOT_WEIGHT_DENSITY * (tile * tile) as f64;
        let sens: Vec<f64> = (0..n_tiles.max(1))
            .map(|_| {
                // Background tile sensitivity + Poisson(λ) hot weights, each
                // contributing ~100x the background mass.
                let mut s = 0.01 * rng.gen_normal().exp();
                let mut acc = rng.gen_f64();
                let floor = (-lambda).exp();
                while acc > floor {
                    s += 100.0 * rng.gen_normal().exp();
                    acc *= rng.gen_f64();
                }
                s
            })
            .collect();
        let k = adaptive_k(&sens, variant.keep_frac());
        let mask = low_sensitivity_mask(&sens, k);
        let frac_fast = mask.iter().filter(|&&m| m).count() as f64 / sens.len() as f64;
        let sparse = variant.salient_frac() + 0.004; // + 3σ outliers ≈ 0.4%
        let e_fast = profile.mean_energy_pj(&profile.codebook_fast);
        let e_med = profile.mean_energy_pj(&profile.codebook_med);
        let bits = frac_fast * (profile.codebook_fast.len() as f64).log2()
            + (1.0 - frac_fast) * (profile.codebook_med.len() as f64).log2()
            + sparse * 16.0;
        Self {
            frac: [0.0, 1.0 - frac_fast, frac_fast],
            sparse_frac: sparse,
            energy_pj: [profile.full_range_energy_pj(), e_med, e_fast],
            bits_eff: bits,
            is_fp16: false,
        }
    }

    /// Uniform baseline layouts at paper scale. Per-op energy is the mean
    /// MAC profile energy over the *actual* int8 PE image of the b-bit
    /// grid (MSB-aligned values toggle fewer low bits).
    pub fn uniform(method: &str, profile: &MacProfile) -> Self {
        let e_base = profile.full_range_energy_pj();
        let grid_energy = |bits: u32| {
            let m = 1i32 << (bits - 1);
            let vals: Vec<i8> = (-m..m)
                .map(|q| crate::quant::uniform::pe_image(q, bits))
                .collect();
            profile.mean_energy_pj(&vals)
        };
        match method {
            "fp16" => Self {
                frac: [1.0, 0.0, 0.0],
                sparse_frac: 0.0,
                energy_pj: [e_base * 2.0, e_base, e_base],
                bits_eff: 16.0,
                is_fp16: true,
            },
            "w8a8" => Self::uniform_bits(8, e_base),
            "w4a8" => Self::uniform_bits(4, grid_energy(4)),
            "w3a8" => Self::uniform_bits(3, grid_energy(3)),
            other => panic!("unknown uniform method {other}"),
        }
    }

    fn uniform_bits(bits: u32, energy: f64) -> Self {
        Self {
            frac: [1.0, 0.0, 0.0],
            sparse_frac: 0.0,
            energy_pj: [energy, energy, energy],
            bits_eff: bits as f64,
            is_fp16: false,
        }
    }

    /// Build the layout for any canonical method name at paper scale.
    /// Memoized by (method, n_tiles, tile, seed): the Poisson/adaptive-k
    /// sampling is deterministic in those, and re-sampling dominated the
    /// simulator hot path (§Perf: 1.08 ms → µs-scale per `run_method`).
    pub fn for_method(
        method: &str,
        n_tiles: usize,
        tile: usize,
        profile: &MacProfile,
        seed: u64,
    ) -> Self {
        use crate::util::sync::Mutex;
        use std::collections::HashMap;
        static CACHE: Mutex<Option<HashMap<(String, usize, usize, u64), LayerQuant>>> =
            Mutex::new(None);
        let key = (method.to_string(), n_tiles, tile, seed);
        if let Some(hit) = CACHE
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .get_or_insert_with(HashMap::new)
            .get(&key)
        {
            return hit.clone();
        }
        let out = Self::for_method_uncached(method, n_tiles, tile, profile, seed);
        CACHE
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .get_or_insert_with(HashMap::new)
            .insert(key, out.clone());
        out
    }

    fn for_method_uncached(
        method: &str,
        n_tiles: usize,
        tile: usize,
        profile: &MacProfile,
        seed: u64,
    ) -> Self {
        match method {
            "fp16" | "w8a8" | "w4a8" | "w3a8" => Self::uniform(method, profile),
            "halo-perf" => {
                Self::synthetic_halo(Variant::PerfOpt, n_tiles, tile, profile, seed)
            }
            "halo-acc" => Self::synthetic_halo(Variant::AccOpt, n_tiles, tile, profile, seed),
            "halo-bal" | "halo" => {
                Self::synthetic_halo(Variant::Bal, n_tiles, tile, profile, seed)
            }
            other => panic!("unknown method {other}"),
        }
    }

    pub fn class_frac(&self, c: FreqClass) -> f64 {
        self.frac[c as usize]
    }
}

/// Inference phase (paper Fig 8: full 2048-token prefill per inference).
#[derive(Debug, Clone, Copy)]
pub struct Phase {
    pub name: &'static str,
    /// Rows of every GEMM (batch × tokens).
    pub m: usize,
}

impl Phase {
    pub fn prefill() -> Self {
        Self { name: "prefill-2048", m: 2048 }
    }

    pub fn decode(batch: usize) -> Self {
        Self { name: "decode", m: batch }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_model_param_counts_roughly_match() {
        // Linear-layer params only (no embeddings), so slightly below the
        // headline sizes.
        let l7 = ModelShapes::llama2_7b();
        assert!((5.8e9..7.2e9).contains(&l7.params), "{}", l7.params);
        let l13 = ModelShapes::llama2_13b();
        assert!((11.0e9..13.5e9).contains(&l13.params), "{}", l13.params);
        let o13 = ModelShapes::opt_1p3b();
        assert!((1.0e9..1.5e9).contains(&o13.params), "{}", o13.params);
        let o30 = ModelShapes::opt_30b();
        assert!((24.0e9..32.0e9).contains(&o30.params), "{}", o30.params);
    }

    #[test]
    fn synthetic_halo_variant_ordering() {
        let p = MacProfile::cached();
        let fast_frac = |v| {
            LayerQuant::synthetic_halo(v, 2048, 128, p, 7).class_frac(FreqClass::Fast)
        };
        let pf = fast_frac(Variant::PerfOpt);
        let bl = fast_frac(Variant::Bal);
        let ac = fast_frac(Variant::AccOpt);
        assert!(pf > bl && bl > ac, "{pf} {bl} {ac}");
        assert!(pf > 0.5, "perf-opt should push most tiles fast: {pf}");
    }

    #[test]
    fn fractions_sum_to_one() {
        let p = MacProfile::cached();
        for m in ["fp16", "w8a8", "w4a8", "w3a8", "halo-bal"] {
            let lq = LayerQuant::for_method(m, 512, 128, p, 3);
            let s: f64 = lq.frac.iter().sum();
            assert!((s - 1.0).abs() < 1e-9, "{m}: {s}");
        }
    }

    #[test]
    fn halo_bits_below_uniform_w4() {
        let p = MacProfile::cached();
        let halo = LayerQuant::for_method("halo-perf", 1024, 128, p, 1);
        assert!(halo.bits_eff < 4.0, "{}", halo.bits_eff);
        assert!(halo.bits_eff > 3.0);
    }
}
