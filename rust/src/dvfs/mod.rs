//! DVFS subsystem (paper §III-C): operating points, tile classification,
//! transition scheduling, and the goal-driven variant optimizer.

pub mod levels;
pub mod optimizer;
pub mod schedule;

pub use levels::{classify, FreqClass, Ladder, Level, TRANSITION_S};
pub use schedule::{Group, Schedule};
