//! DVFS levels (paper Table I) and frequency classes.
//!
//! The paper *assumes* the Table I ladders ("deriving levels from MAC
//! characteristics"); our circuit model reproduces the class structure
//! (which weight values are fast) with a smaller frequency spread than the
//! authors' 22 nm PrimeTime numbers (DESIGN.md §Substitutions documents
//! the gap). Default simulations therefore clock classes at the paper's
//! ladder; `Ladder::derived` exposes our model's own numbers for the
//! ablation (`halo ablate derived-ladder`).

use crate::mac::MacProfile;

/// A voltage/frequency operating point.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Level {
    /// Supply voltage (V).
    pub volts: f64,
    /// Clock frequency (GHz).
    pub ghz: f64,
}

/// Which codebook class a tile's stored values belong to.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum FreqClass {
    /// Full int8 range (uniform baselines, outlier/salient SpMV).
    Base = 0,
    /// 16-value medium codebook (high-sensitivity tiles).
    Med = 1,
    /// 9-value fast codebook (low-sensitivity tiles).
    Fast = 2,
}

impl FreqClass {
    /// Every class, slow → fast (ladder/schedule iteration order).
    pub const ALL: [FreqClass; 3] = [FreqClass::Base, FreqClass::Med, FreqClass::Fast];

    /// Short class name (`base` / `med` / `fast`).
    pub fn name(self) -> &'static str {
        match self {
            FreqClass::Base => "base",
            FreqClass::Med => "med",
            FreqClass::Fast => "fast",
        }
    }
}

/// Classify a tile by its achievable frequency from the circuit model
/// (compare against the derived class frequencies, not the paper ladder).
pub fn classify(achievable_ghz: f64, profile: &MacProfile) -> FreqClass {
    if achievable_ghz >= profile.f_fast_ghz - 1e-9 {
        FreqClass::Fast
    } else if achievable_ghz >= profile.f_med_ghz - 1e-9 {
        FreqClass::Med
    } else {
        FreqClass::Base
    }
}

/// An ordered (Base → Med → Fast) set of operating points.
#[derive(Debug, Clone)]
pub struct Ladder {
    /// Ladder label (`paper-systolic` / `paper-gpu` / `derived`).
    pub name: &'static str,
    /// Operating points indexed by `FreqClass as usize`.
    pub levels: [Level; 3],
}

impl Ladder {
    /// Table I, systolic array (TPU) row.
    pub fn paper_systolic() -> Self {
        Self {
            name: "paper-systolic",
            levels: [
                Level { volts: 1.0, ghz: 1.9 },
                Level { volts: 1.1, ghz: 2.4 },
                Level { volts: 1.2, ghz: 3.7 },
            ],
        }
    }

    /// Table I, GPU row.
    pub fn paper_gpu() -> Self {
        Self {
            name: "paper-gpu",
            levels: [
                Level { volts: 0.9, ghz: 1.5 },
                Level { volts: 1.0, ghz: 2.0 },
                Level { volts: 1.1, ghz: 2.8 },
            ],
        }
    }

    /// Ladder derived from our own gate-level MAC model (ablation).
    pub fn derived(profile: &MacProfile) -> Self {
        Self {
            name: "derived",
            levels: [
                Level { volts: 1.0, ghz: profile.f_base_ghz },
                Level { volts: 1.1, ghz: profile.f_med_ghz },
                Level { volts: 1.2, ghz: profile.f_fast_ghz },
            ],
        }
    }

    /// The operating point a frequency class runs at.
    pub fn level(&self, class: FreqClass) -> Level {
        self.levels[class as usize]
    }
}

/// DVFS transition cost (paper §III-C3: "tens of nanoseconds to a few
/// microseconds"); we take the conservative end.
pub const TRANSITION_S: f64 = 2e-6;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_ladders_match_table1() {
        let s = Ladder::paper_systolic();
        assert_eq!(s.level(FreqClass::Base).ghz, 1.9);
        assert_eq!(s.level(FreqClass::Fast).ghz, 3.7);
        let g = Ladder::paper_gpu();
        assert_eq!(g.level(FreqClass::Med).volts, 1.0);
        assert_eq!(g.level(FreqClass::Fast).ghz, 2.8);
    }

    #[test]
    fn ladders_monotone() {
        for l in [
            Ladder::paper_systolic(),
            Ladder::paper_gpu(),
            Ladder::derived(MacProfile::cached()),
        ] {
            assert!(l.levels[0].ghz < l.levels[1].ghz);
            assert!(l.levels[1].ghz < l.levels[2].ghz);
            assert!(l.levels[0].volts <= l.levels[2].volts);
        }
    }

    #[test]
    fn classify_boundaries() {
        let p = MacProfile::cached();
        assert_eq!(classify(p.f_fast_ghz, p), FreqClass::Fast);
        assert_eq!(classify(p.f_med_ghz, p), FreqClass::Med);
        assert_eq!(classify(p.f_base_ghz, p), FreqClass::Base);
        assert_eq!(classify(0.5, p), FreqClass::Base);
        assert_eq!(classify(99.0, p), FreqClass::Fast);
    }
}
