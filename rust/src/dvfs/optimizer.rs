//! Goal-driven quantization/DVFS co-optimization (paper Fig 1 + §III-C):
//! enumerate (variant × tile size) candidates, predict (latency, energy,
//! weight-MSE) with the systolic simulator, and return the Pareto-optimal
//! set — the paper's "set of Pareto-optimal quantized models, each paired
//! with a corresponding DVFS schedule".

use crate::quant::Variant;

/// One candidate operating point.
#[derive(Debug, Clone)]
pub struct Candidate {
    /// HALO design-goal preset of the candidate.
    pub variant: Variant,
    /// Tile edge length.
    pub tile: usize,
    /// Predicted inference latency (s, systolic simulator).
    pub time_s: f64,
    /// Predicted inference energy (J).
    pub energy_j: f64,
    /// Accuracy proxy (weight reconstruction MSE or measured perplexity).
    pub accuracy_cost: f64,
}

impl Candidate {
    /// True iff `self` dominates `other` (no worse on all axes, strictly
    /// better on one).
    pub fn dominates(&self, other: &Candidate) -> bool {
        let le = self.time_s <= other.time_s
            && self.energy_j <= other.energy_j
            && self.accuracy_cost <= other.accuracy_cost;
        let lt = self.time_s < other.time_s
            || self.energy_j < other.energy_j
            || self.accuracy_cost < other.accuracy_cost;
        le && lt
    }
}

/// Filter to the Pareto-optimal front (order preserved).
pub fn pareto_front(candidates: &[Candidate]) -> Vec<Candidate> {
    candidates
        .iter()
        .filter(|c| !candidates.iter().any(|d| d.dominates(c)))
        .cloned()
        .collect()
}

/// Pick from the front by user goal weights (normalized scalarization).
pub fn select(front: &[Candidate], w_time: f64, w_energy: f64, w_acc: f64) -> Option<Candidate> {
    if front.is_empty() {
        return None;
    }
    let max_t = front.iter().map(|c| c.time_s).fold(f64::MIN, f64::max).max(1e-30);
    let max_e = front.iter().map(|c| c.energy_j).fold(f64::MIN, f64::max).max(1e-30);
    let max_a = front
        .iter()
        .map(|c| c.accuracy_cost)
        .fold(f64::MIN, f64::max)
        .max(1e-30);
    front
        .iter()
        .min_by(|a, b| {
            let sa = w_time * a.time_s / max_t
                + w_energy * a.energy_j / max_e
                + w_acc * a.accuracy_cost / max_a;
            let sb = w_time * b.time_s / max_t
                + w_energy * b.energy_j / max_e
                + w_acc * b.accuracy_cost / max_a;
            sa.partial_cmp(&sb).unwrap()
        })
        .cloned()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn c(v: Variant, t: f64, e: f64, a: f64) -> Candidate {
        Candidate { variant: v, tile: 128, time_s: t, energy_j: e, accuracy_cost: a }
    }

    #[test]
    fn dominated_points_removed() {
        let cands = vec![
            c(Variant::PerfOpt, 1.0, 2.0, 3.0),
            c(Variant::Bal, 1.5, 2.5, 3.5), // dominated by the first
            c(Variant::AccOpt, 2.0, 1.0, 1.0),
        ];
        let front = pareto_front(&cands);
        assert_eq!(front.len(), 2);
        assert!(front.iter().all(|x| x.variant != Variant::Bal));
    }

    #[test]
    fn incomparable_points_survive() {
        let cands = vec![
            c(Variant::PerfOpt, 1.0, 3.0, 3.0),
            c(Variant::Bal, 2.0, 2.0, 2.0),
            c(Variant::AccOpt, 3.0, 1.0, 1.0),
        ];
        assert_eq!(pareto_front(&cands).len(), 3);
    }

    #[test]
    fn goal_weights_steer_selection() {
        let cands = vec![
            c(Variant::PerfOpt, 1.0, 3.0, 3.0),
            c(Variant::AccOpt, 3.0, 1.0, 1.0),
        ];
        let front = pareto_front(&cands);
        assert_eq!(select(&front, 1.0, 0.0, 0.0).unwrap().variant, Variant::PerfOpt);
        assert_eq!(select(&front, 0.0, 0.0, 1.0).unwrap().variant, Variant::AccOpt);
    }

    #[test]
    fn empty_input() {
        assert!(pareto_front(&[]).is_empty());
        assert!(select(&[], 1.0, 1.0, 1.0).is_none());
    }
}
