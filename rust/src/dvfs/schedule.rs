//! DVFS transition scheduling (paper §III-C3).
//!
//! Tiles sharing a frequency class are clustered into contiguous execution
//! groups so each class pays for at most one voltage/frequency transition
//! per inference pass — the cost is amortized over the whole group and
//! becomes negligible against end-to-end latency.

use super::levels::{FreqClass, TRANSITION_S};

/// One contiguous execution group: every tile in it runs at `class`.
#[derive(Debug, Clone)]
pub struct Group {
    /// The frequency class the whole group clocks at.
    pub class: FreqClass,
    /// Member tile indices, input order preserved.
    pub tiles: Vec<usize>,
}

/// The per-pass schedule: groups in execution order.
#[derive(Debug, Clone, Default)]
pub struct Schedule {
    /// Execution groups, Base → Med → Fast.
    pub groups: Vec<Group>,
}

impl Schedule {
    /// Cluster tiles by class (Base first — the SpMV/uniform work — then
    /// Med, then Fast). Tile order inside a group preserves input order,
    /// which keeps activation reuse patterns intact.
    pub fn cluster(tile_classes: &[FreqClass]) -> Self {
        let mut groups = Vec::new();
        for class in FreqClass::ALL {
            let tiles: Vec<usize> = tile_classes
                .iter()
                .enumerate()
                .filter(|(_, &c)| c == class)
                .map(|(i, _)| i)
                .collect();
            if !tiles.is_empty() {
                groups.push(Group { class, tiles });
            }
        }
        Self { groups }
    }

    /// Number of DVFS transitions the pass needs (one per group boundary,
    /// plus the initial setting).
    pub fn transitions(&self) -> usize {
        self.groups.len()
    }

    /// Total transition overhead in seconds.
    pub fn transition_overhead_s(&self) -> f64 {
        self.transitions() as f64 * TRANSITION_S
    }

    /// Total tiles across all groups.
    pub fn n_tiles(&self) -> usize {
        self.groups.iter().map(|g| g.tiles.len()).sum()
    }

    /// Partition a whole-model schedule across `n` executor shards
    /// (round-robin within each class group, preserving class order), so
    /// each shard applies — and accounts — only its own slice of the DVFS
    /// plan. Class grouping is preserved per shard, so per-shard
    /// transitions never exceed the parent schedule's; the union of shard
    /// tiles is exactly the parent's tile set. `n = 1` returns a clone.
    pub fn shard(&self, n: usize) -> Vec<Schedule> {
        let n = n.max(1);
        let mut out: Vec<Schedule> = (0..n).map(|_| Schedule::default()).collect();
        for g in &self.groups {
            let mut per: Vec<Vec<usize>> = vec![Vec::new(); n];
            for (i, &t) in g.tiles.iter().enumerate() {
                per[i % n].push(t);
            }
            for (s, tiles) in per.into_iter().enumerate() {
                if !tiles.is_empty() {
                    out[s].groups.push(Group { class: g.class, tiles });
                }
            }
        }
        out
    }

    /// Invariant check: every input tile appears exactly once and groups
    /// are class-homogeneous. Used by tests and the coordinator.
    pub fn validate(&self, n_tiles: usize, classes: &[FreqClass]) -> bool {
        let mut seen = vec![false; n_tiles];
        for g in &self.groups {
            for &t in &g.tiles {
                if t >= n_tiles || seen[t] || classes[t] != g.class {
                    return false;
                }
                seen[t] = true;
            }
        }
        seen.iter().all(|&s| s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn random_classes(n: usize, seed: u64) -> Vec<FreqClass> {
        let mut rng = Rng::seed_from_u64(seed);
        (0..n)
            .map(|_| *rng.choose(&FreqClass::ALL))
            .collect()
    }

    #[test]
    fn at_most_three_groups() {
        // The paper's claim: 2-3 distinct frequency levels per model ⇒ a
        // handful of transitions regardless of tile count.
        for seed in 0..10 {
            let classes = random_classes(500, seed);
            let s = Schedule::cluster(&classes);
            assert!(s.transitions() <= 3);
            assert!(s.validate(500, &classes));
        }
    }

    #[test]
    fn overhead_negligible_vs_inference() {
        // LLaMA-13B inference ≈ 53 ms; 3 transitions at 2 µs are < 0.02 %.
        let classes = random_classes(10_000, 1);
        let s = Schedule::cluster(&classes);
        assert!(s.transition_overhead_s() / 53e-3 < 2e-4);
    }

    #[test]
    fn empty_and_uniform_inputs() {
        assert_eq!(Schedule::cluster(&[]).transitions(), 0);
        let all_fast = vec![FreqClass::Fast; 64];
        let s = Schedule::cluster(&all_fast);
        assert_eq!(s.transitions(), 1);
        assert_eq!(s.n_tiles(), 64);
    }

    #[test]
    fn shard_partitions_tiles_and_keeps_class_grouping() {
        for n in [1usize, 2, 3, 4, 7] {
            let classes = random_classes(100, 3);
            let s = Schedule::cluster(&classes);
            let shards = s.shard(n);
            assert_eq!(shards.len(), n);
            // Union of shard tiles == parent tiles, each exactly once.
            let mut seen = vec![0u32; 100];
            for sh in &shards {
                assert!(sh.transitions() <= s.transitions());
                for g in &sh.groups {
                    for &t in &g.tiles {
                        assert_eq!(classes[t], g.class, "shard group not class-homogeneous");
                        seen[t] += 1;
                    }
                }
            }
            assert!(seen.iter().all(|&c| c == 1), "tiles lost or duplicated (n={n})");
        }
    }

    #[test]
    fn shard_one_is_identity() {
        let classes = random_classes(64, 4);
        let s = Schedule::cluster(&classes);
        let one = &s.shard(1)[0];
        assert_eq!(one.transitions(), s.transitions());
        assert_eq!(one.n_tiles(), s.n_tiles());
        assert!(one.validate(64, &classes));
    }

    #[test]
    fn validate_rejects_corruption() {
        let classes = random_classes(20, 2);
        let mut s = Schedule::cluster(&classes);
        // duplicate a tile
        let t = s.groups[0].tiles[0];
        s.groups[0].tiles.push(t);
        assert!(!s.validate(20, &classes));
    }
}
