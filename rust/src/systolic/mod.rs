//! Cycle-level weight-stationary systolic-array simulator (Figs 8–11).
//!
//! Models a TPU-class 128×128 int8 PE array with a global DVFS unit
//! (the paper's custom SystemVerilog simulator, rebuilt in Rust): per-class
//! clocking from the DVFS ladder, a dedicated SpMV engine for the
//! hypersparse outlier/salient weights, double-buffered weight loads, a
//! DRAM/SRAM traffic model, and the full static/dynamic × core/buffer/
//! memory energy decomposition of Fig 10.

pub mod energy;
pub mod sim;

pub use energy::EnergyBreakdown;
pub use sim::{SimConfig, SimReport, Simulator};
