//! Systolic-array energy model: the Fig 10 decomposition
//! (core / buffer / memory) × (static / dynamic).

use crate::dvfs::{FreqClass, Ladder};
use crate::mac::power;

/// Technology/energy constants (22 nm-class, DESIGN.md §Substitutions).
#[derive(Debug, Clone)]
pub struct EnergyParams {
    /// SRAM buffer access energy per byte (pJ).
    pub sram_pj_per_byte: f64,
    /// DRAM access energy per byte (pJ).
    pub dram_pj_per_byte: f64,
    /// DRAM background (static) power (W).
    pub dram_static_w: f64,
    /// Buffer leakage power (W).
    pub buffer_static_w: f64,
    /// Activation bytes are re-read from SRAM once per resident weight
    /// block column — effective reuse multiplier for buffer traffic.
    pub buffer_reuse: f64,
}

impl Default for EnergyParams {
    fn default() -> Self {
        Self {
            sram_pj_per_byte: 0.8,
            dram_pj_per_byte: 15.0,
            dram_static_w: 1.5,
            buffer_static_w: 0.8,
            buffer_reuse: 8.0,
        }
    }
}

/// Energy report (joules).
#[derive(Debug, Clone, Copy, Default)]
pub struct EnergyBreakdown {
    /// PE switching energy (V²-scaled per class).
    pub core_dynamic: f64,
    /// PE leakage over class residency + idle tail.
    pub core_static: f64,
    /// SRAM buffer access energy.
    pub buffer_dynamic: f64,
    /// Buffer leakage over the pass.
    pub buffer_static: f64,
    /// DRAM access energy.
    pub mem_dynamic: f64,
    /// DRAM background power over the pass.
    pub mem_static: f64,
}

impl EnergyBreakdown {
    /// Sum of all six components (J).
    pub fn total(&self) -> f64 {
        self.core_dynamic
            + self.core_static
            + self.buffer_dynamic
            + self.buffer_static
            + self.mem_dynamic
            + self.mem_static
    }
}

/// Assemble the breakdown from simulator aggregates.
#[allow(clippy::too_many_arguments)]
pub fn compute(
    p: &EnergyParams,
    ladder: &Ladder,
    compute_s: &[f64; 3],
    time_s: f64,
    dyn_core_pj: f64,
    weight_bytes: f64,
    act_bytes: f64,
    pes: f64,
) -> EnergyBreakdown {
    // Core static: leakage of every PE at the voltage of whatever class is
    // active, weighted by residency (idle tail at base voltage).
    let mut core_static = 0.0f64;
    let active: f64 = compute_s.iter().sum();
    for class in FreqClass::ALL {
        let lvl = ladder.level(class);
        core_static +=
            pes * power::leakage_power_mw(lvl.volts) * 1e-3 * compute_s[class as usize];
    }
    core_static += pes
        * power::leakage_power_mw(ladder.level(FreqClass::Base).volts)
        * 1e-3
        * (time_s - active).max(0.0);

    let buffer_bytes = act_bytes * p.buffer_reuse + weight_bytes;
    EnergyBreakdown {
        core_dynamic: dyn_core_pj * 1e-12,
        core_static,
        buffer_dynamic: buffer_bytes * p.sram_pj_per_byte * 1e-12,
        buffer_static: p.buffer_static_w * time_s,
        mem_dynamic: (weight_bytes + act_bytes) * p.dram_pj_per_byte * 1e-12,
        mem_static: p.dram_static_w * time_s,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::systolic::{SimConfig, Simulator};
    use crate::workload::{ModelShapes, Phase};

    fn energy(method: &str) -> EnergyBreakdown {
        Simulator::new(SimConfig::default())
            .run_method(&ModelShapes::llama2_7b(), Phase::prefill(), method, 128, 42)
            .energy
    }

    #[test]
    fn fig10_ordering_fp16_worst() {
        let fp16 = energy("fp16").total();
        let w8 = energy("w8a8").total();
        let w3 = energy("w3a8").total();
        assert!(fp16 > w8, "fp16 {fp16} w8 {w8}");
        assert!(w8 > w3, "w8 {w8} w3 {w3}");
    }

    #[test]
    fn halo_energy_within_paper_band_of_w3() {
        // Paper: HALO within 12% of W3A8 and 10% of W4A8 while much faster.
        let halo = energy("halo-bal").total();
        let w3 = energy("w3a8").total();
        let w4 = energy("w4a8").total();
        assert!(halo / w3 < 1.35, "halo/w3 = {}", halo / w3);
        assert!(halo / w4 < 1.25, "halo/w4 = {}", halo / w4);
    }

    #[test]
    fn halo_saves_vs_w8a8_and_fp16() {
        // Headline: ~51% average energy saving over baselines.
        let halo = energy("halo-bal").total();
        let w8 = energy("w8a8").total();
        let fp16 = energy("fp16").total();
        assert!(halo < 0.9 * w8, "halo {halo} w8 {w8}");
        assert!(halo < 0.55 * fp16, "halo {halo} fp16 {fp16}");
    }

    #[test]
    fn all_components_nonnegative_and_static_tracks_time() {
        let e = energy("halo-perf");
        for v in [
            e.core_dynamic,
            e.core_static,
            e.buffer_dynamic,
            e.buffer_static,
            e.mem_dynamic,
            e.mem_static,
        ] {
            assert!(v >= 0.0);
        }
        assert!(e.total() > 0.0);
    }
}
