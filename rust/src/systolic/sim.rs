//! The systolic-array timing model.
//!
//! Weight-stationary dataflow: weight blocks stream from DRAM into the
//! 128×128 array (double-buffered, so loads hide behind compute), and each
//! resident block processes `m` activation rows at the block's frequency
//! class. Tiles are executed in class-clustered groups (one DVFS
//! transition per class, §III-C3). FP16 runs the array in two-pass mode
//! (half MAC throughput). The SpMV engine runs concurrently with the dense
//! array and is sized so the hypersparse side never dominates.

use crate::dvfs::{FreqClass, Ladder, Schedule, TRANSITION_S};
use crate::workload::{LayerQuant, ModelShapes, Phase};

use super::energy::{EnergyBreakdown, EnergyParams};

/// Hardware configuration of the simulated array.
#[derive(Debug, Clone)]
pub struct SimConfig {
    /// PE grid edge (array is `pe x pe`).
    pub pe: usize,
    /// SpMV engine lanes (MACs/cycle at base clock).
    pub spmv_lanes: usize,
    /// DRAM bandwidth (bytes/s).
    pub dram_bw: f64,
    /// Activation bit-width (paper: A8 everywhere).
    pub act_bits: u32,
    /// DVFS operating points per class (paper Table I by default).
    pub ladder: Ladder,
    /// Technology/energy constants for the Fig 10 decomposition.
    pub energy: EnergyParams,
}

impl Default for SimConfig {
    fn default() -> Self {
        Self {
            pe: 128,
            spmv_lanes: 2048,
            dram_bw: 256e9,
            act_bits: 8,
            ladder: Ladder::paper_systolic(),
            energy: EnergyParams::default(),
        }
    }
}

/// Simulation output for one inference pass.
#[derive(Debug, Clone)]
pub struct SimReport {
    /// Quantization method simulated.
    pub method: String,
    /// Model shape set simulated.
    pub model: String,
    /// End-to-end latency (s).
    pub time_s: f64,
    /// Dense compute time per class (s).
    pub compute_s: [f64; 3],
    /// SpMV engine time (s, concurrent with the dense array).
    pub spmv_s: f64,
    /// DRAM traffic time (s, overlapped by double buffering).
    pub mem_s: f64,
    /// DVFS transitions the class-clustered schedule needed.
    pub dvfs_transitions: usize,
    /// Fig 10 energy decomposition.
    pub energy: EnergyBreakdown,
    /// Total MAC operations simulated.
    pub macs: f64,
    /// Weight DRAM traffic (bytes).
    pub weight_bytes: f64,
}

impl SimReport {
    /// MACs per second achieved — the utilization headline.
    pub fn throughput(&self) -> f64 {
        self.macs / self.time_s
    }
}

/// The systolic-array simulator (see module docs).
pub struct Simulator {
    /// Hardware configuration of the simulated array.
    pub cfg: SimConfig,
}

impl Simulator {
    /// Simulator over a hardware configuration.
    pub fn new(cfg: SimConfig) -> Self {
        Self { cfg }
    }

    /// Simulate one inference (all GEMMs of `model` at phase `m`), where
    /// layer `i` is quantized per `quants[i]` (parallel to `model.gemms`).
    pub fn run(
        &self,
        model: &ModelShapes,
        phase: Phase,
        quants: &[LayerQuant],
        method: &str,
    ) -> SimReport {
        assert_eq!(quants.len(), model.gemms.len());
        let cfg = &self.cfg;
        let pes = (cfg.pe * cfg.pe) as f64;

        let mut compute_s = [0.0f64; 3];
        let mut spmv_ops = 0.0f64;
        let mut macs = 0.0f64;
        let mut weight_bytes = 0.0f64;
        let mut act_bytes = 0.0f64;
        let mut dyn_core_pj = 0.0f64;
        let mut classes_present = [false; 3];

        for (g, lq) in model.gemms.iter().zip(quants) {
            let layer_macs = (phase.m * g.k * g.n * g.count) as f64;
            macs += layer_macs;

            let throughput_scale = if lq.is_fp16 { 0.5 } else { 1.0 };
            for class in FreqClass::ALL {
                let frac = lq.class_frac(class);
                if frac <= 0.0 {
                    continue;
                }
                classes_present[class as usize] = true;
                let level = cfg.ladder.level(class);
                let class_macs = layer_macs * frac;
                compute_s[class as usize] +=
                    class_macs / (pes * throughput_scale * level.ghz * 1e9);
                // Dynamic MAC energy scales with V².
                let v2 = (level.volts / crate::mac::power::V_NOM).powi(2);
                dyn_core_pj += class_macs * lq.energy_pj[class as usize] * v2;
            }

            // SpMV side: nnz · m operations at the base level.
            let nnz = lq.sparse_frac * (g.k * g.n * g.count) as f64;
            spmv_ops += nnz * phase.m as f64;
            dyn_core_pj += nnz * phase.m as f64 * lq.energy_pj[0];

            // Traffic: weights once per pass; activations in+out per GEMM.
            weight_bytes += (g.k * g.n * g.count) as f64 * lq.bits_eff / 8.0 + nnz * 5.0;
            let act_bits = if lq.is_fp16 { 16 } else { cfg.act_bits as usize };
            act_bytes +=
                (phase.m * (g.k + g.n) * g.count) as f64 * act_bits as f64 / 8.0;
        }

        let base_ghz = cfg.ladder.level(FreqClass::Base).ghz;
        let spmv_s = spmv_ops / (cfg.spmv_lanes as f64 * base_ghz * 1e9);
        let mem_s = (weight_bytes + act_bytes) / cfg.dram_bw;

        // Class-clustered schedule: one transition per class present.
        let present: Vec<FreqClass> = FreqClass::ALL
            .into_iter()
            .filter(|&c| classes_present[c as usize])
            .collect();
        let schedule = Schedule::cluster(&present.iter().map(|&c| c).collect::<Vec<_>>());
        let transitions = schedule.transitions();

        let dense_s: f64 = compute_s.iter().sum::<f64>() + transitions as f64 * TRANSITION_S;
        // Double-buffering overlaps DRAM with compute; SpMV runs on its own
        // engine. End-to-end latency = slowest of the three streams.
        let time_s = dense_s.max(mem_s).max(spmv_s);

        let energy = super::energy::compute(
            &cfg.energy,
            &cfg.ladder,
            &compute_s,
            time_s,
            dyn_core_pj,
            weight_bytes,
            act_bytes,
            pes,
        );

        SimReport {
            method: method.to_string(),
            model: model.name.to_string(),
            time_s,
            compute_s,
            spmv_s,
            mem_s,
            dvfs_transitions: transitions,
            energy,
            macs,
            weight_bytes,
        }
    }

    /// Convenience: run a canonical method on a paper-scale model with
    /// synthetic tile layouts (same adaptive-k path as the real quantizer).
    pub fn run_method(
        &self,
        model: &ModelShapes,
        phase: Phase,
        method: &str,
        tile: usize,
        seed: u64,
    ) -> SimReport {
        let quants: Vec<LayerQuant> = model
            .gemms
            .iter()
            .enumerate()
            .map(|(i, g)| {
                let n_tiles = g.k.div_ceil(tile) * g.n.div_ceil(tile);
                LayerQuant::for_method(method, n_tiles, tile, crate::mac::MacProfile::cached(),
                                       seed ^ (i as u64) << 8)
            })
            .collect();
        self.run(model, phase, &quants, method)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sim() -> Simulator {
        Simulator::new(SimConfig::default())
    }

    fn run(method: &str) -> SimReport {
        sim().run_method(
            &ModelShapes::llama2_7b(),
            Phase::prefill(),
            method,
            128,
            42,
        )
    }

    #[test]
    fn paper_fig8_ordering() {
        // FP16 slowest; W8A8 ≈ W4A8 ≈ W3A8 (compute-bound at base clock);
        // HALO fastest.
        let fp16 = run("fp16").time_s;
        let w8 = run("w8a8").time_s;
        let w4 = run("w4a8").time_s;
        let halo = run("halo-bal").time_s;
        assert!(fp16 > w8 && w8 >= w4 && w4 > halo, "{fp16} {w8} {w4} {halo}");
    }

    #[test]
    fn halo_speedup_magnitude_matches_paper_shape() {
        // Paper: +353% vs FP16, +87% vs W8A8 (perf-opt variants near that).
        let fp16 = run("fp16").time_s;
        let w8 = run("w8a8").time_s;
        let halo = run("halo-perf").time_s;
        let vs_fp16 = fp16 / halo;
        let vs_w8 = w8 / halo;
        assert!((2.5..6.5).contains(&vs_fp16), "vs fp16: {vs_fp16}");
        assert!((1.4..2.2).contains(&vs_w8), "vs w8a8: {vs_w8}");
    }

    #[test]
    fn transitions_at_most_three() {
        for m in ["fp16", "w8a8", "halo-bal", "halo-perf"] {
            assert!(run(m).dvfs_transitions <= 3, "{m}");
        }
    }

    #[test]
    fn macs_conserved_across_methods() {
        let a = run("fp16").macs;
        let b = run("halo-bal").macs;
        assert_eq!(a, b);
        // 2048-token prefill of a ~6.6B-param linear stack: ~2048 * 6.6e9.
        assert!((a / (2048.0 * 6.6e9) - 1.0).abs() < 0.1, "{a}");
    }

    #[test]
    fn weight_traffic_scales_with_bits() {
        let w8 = run("w8a8").weight_bytes;
        let w4 = run("w4a8").weight_bytes;
        let fp16 = run("fp16").weight_bytes;
        assert!((w8 / w4 - 2.0).abs() < 0.05);
        assert!((fp16 / w8 - 2.0).abs() < 0.05);
        let halo = run("halo-bal").weight_bytes;
        assert!(halo < w4, "halo {halo} vs w4 {w4}");
    }

    #[test]
    fn larger_model_takes_longer() {
        let s = sim();
        let t7 = s
            .run_method(&ModelShapes::llama2_7b(), Phase::prefill(), "w8a8", 128, 1)
            .time_s;
        let t13 = s
            .run_method(&ModelShapes::llama2_13b(), Phase::prefill(), "w8a8", 128, 1)
            .time_s;
        assert!(t13 > t7 * 1.5);
    }

    #[test]
    fn throughput_near_roofline_for_w8a8() {
        // Compute-bound prefill at base clock: ≥ 70% of 128²·1.9 GHz.
        let r = run("w8a8");
        let roofline = 128.0 * 128.0 * 1.9e9;
        assert!(r.throughput() > 0.7 * roofline, "{}", r.throughput());
    }
}
