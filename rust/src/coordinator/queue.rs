//! Bounded MPMC request queue built on the [`crate::util::sync`] shim.
//!
//! Replaces the former `std::sync::mpsc` + side-channel depth counter in
//! the shard path. Capacity check, closed check and enqueue happen under
//! one lock, so admission control is atomic — there is no reserve-then-send
//! window in which a burst can overshoot the cap. Being built on the shim,
//! the queue is model-checkable: `tests/loom_coordinator.rs` exhaustively
//! interleaves push/shed/close against the consumer.

use std::collections::VecDeque;
use std::time::Instant;

use crate::util::sync::{Condvar, Mutex, MutexGuard};

/// Why a [`RequestQueue::push`] was refused; carries the item back.
#[derive(Debug)]
pub enum PushError<T> {
    /// The queue is at capacity (admission control: shed or retry).
    Full(T),
    /// The queue was closed; no further items are accepted.
    Closed(T),
}

impl<T> PushError<T> {
    /// Recover the rejected item.
    pub fn into_inner(self) -> T {
        match self {
            PushError::Full(v) | PushError::Closed(v) => v,
        }
    }
}

/// Outcome of a deadline-bounded pop ([`RequestQueue::pop_deadline`]).
#[derive(Debug)]
pub enum Pop<T> {
    /// An item was dequeued.
    Item(T),
    /// The queue is closed and drained; no item will ever arrive.
    Closed,
    /// The deadline passed with the queue still open and empty.
    TimedOut,
}

struct QueueState<T> {
    items: VecDeque<T>,
    closed: bool,
}

/// Bounded multi-producer multi-consumer FIFO with explicit close.
///
/// Lock-poisoning is absorbed (`into_inner`): the queue's invariants hold
/// at every await point, so state observed through a poisoned lock is
/// still consistent — a panicking shard must not take the router with it.
pub struct RequestQueue<T> {
    inner: Mutex<QueueState<T>>,
    nonempty: Condvar,
    cap: usize,
}

impl<T> RequestQueue<T> {
    /// Create a queue admitting at most `cap` queued items (`0` =
    /// unbounded).
    pub fn bounded(cap: usize) -> Self {
        Self {
            inner: Mutex::new(QueueState { items: VecDeque::new(), closed: false }),
            nonempty: Condvar::new(),
            cap,
        }
    }

    fn state(&self) -> MutexGuard<'_, QueueState<T>> {
        self.inner.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Enqueue `item`, or refuse it with [`PushError`] when the queue is
    /// full or closed. Never blocks — admission control decides to shed at
    /// the call site, not by stalling the producer.
    ///
    /// Carries the `queue.push` failpoint (soft site: it runs on the
    /// submitter's thread, so injected faults surface as a transient
    /// [`PushError::Full`] — exercising reroute/shed — never as a panic
    /// unwinding into client code). Delay faults sleep before admission.
    pub fn push(&self, item: T) -> Result<(), PushError<T>> {
        if crate::util::failpoint::check_soft(crate::util::failpoint::sites::QUEUE_PUSH).is_err() {
            return Err(PushError::Full(item));
        }
        let mut st = self.state();
        if st.closed {
            return Err(PushError::Closed(item));
        }
        if self.cap != 0 && st.items.len() >= self.cap {
            return Err(PushError::Full(item));
        }
        st.items.push_back(item);
        drop(st);
        self.nonempty.notify_one();
        Ok(())
    }

    /// Dequeue, blocking while the queue is open and empty. Returns `None`
    /// only once the queue is closed *and* drained.
    pub fn pop(&self) -> Option<T> {
        let mut st = self.state();
        loop {
            if let Some(item) = st.items.pop_front() {
                if !st.items.is_empty() {
                    // Cascade: another consumer may be parked behind us.
                    self.nonempty.notify_one();
                }
                return Some(item);
            }
            if st.closed {
                return None;
            }
            st = self.nonempty.wait(st).unwrap_or_else(|e| e.into_inner());
        }
    }

    /// Dequeue with a deadline. Blocks while open-and-empty until
    /// `deadline`; an item or a close always wins over a concurrent
    /// timeout.
    ///
    /// Not model-safe: branches on wall-clock time, so loom-style models
    /// must drive the queue through [`pop`](Self::pop) /
    /// [`try_pop`](Self::try_pop) instead.
    pub fn pop_deadline(&self, deadline: Instant) -> Pop<T> {
        let mut st = self.state();
        loop {
            if let Some(item) = st.items.pop_front() {
                if !st.items.is_empty() {
                    self.nonempty.notify_one();
                }
                return Pop::Item(item);
            }
            if st.closed {
                return Pop::Closed;
            }
            let now = Instant::now();
            if now >= deadline {
                return Pop::TimedOut;
            }
            let (g, timed_out) = self
                .nonempty
                .wait_timeout(st, deadline - now)
                .unwrap_or_else(|e| e.into_inner());
            st = g;
            if timed_out.timed_out() {
                // Re-check once: an item or close that raced the timeout
                // takes precedence over reporting TimedOut.
                if let Some(item) = st.items.pop_front() {
                    if !st.items.is_empty() {
                        self.nonempty.notify_one();
                    }
                    return Pop::Item(item);
                }
                if st.closed {
                    return Pop::Closed;
                }
                return Pop::TimedOut;
            }
        }
    }

    /// Dequeue without blocking; `None` when empty (open or closed).
    pub fn try_pop(&self) -> Option<T> {
        let mut st = self.state();
        let item = st.items.pop_front();
        if item.is_some() && !st.items.is_empty() {
            drop(st);
            self.nonempty.notify_one();
        }
        item
    }

    /// Close the queue: future pushes fail, consumers drain what remains
    /// and then observe the close. Idempotent.
    pub fn close(&self) {
        let mut st = self.state();
        st.closed = true;
        drop(st);
        self.nonempty.notify_all();
    }

    /// Queued-item count right now (racy by nature; used for least-loaded
    /// routing, where staleness only costs balance, not correctness).
    pub fn len(&self) -> usize {
        self.state().items.len()
    }

    /// Whether the queue is empty right now (racy by nature).
    pub fn is_empty(&self) -> bool {
        self.state().items.is_empty()
    }

    /// Whether the queue has been closed.
    pub fn is_closed(&self) -> bool {
        self.state().closed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::sync::{thread, Arc};
    use std::time::Duration;

    #[test]
    fn fifo_order_preserved() {
        let q = RequestQueue::bounded(0);
        for i in 0..5 {
            q.push(i).unwrap();
        }
        for i in 0..5 {
            assert_eq!(q.try_pop(), Some(i));
        }
        assert_eq!(q.try_pop(), None);
    }

    #[test]
    fn cap_is_enforced_atomically() {
        let q = RequestQueue::bounded(2);
        q.push(1).unwrap();
        q.push(2).unwrap();
        match q.push(3) {
            Err(PushError::Full(v)) => assert_eq!(v, 3),
            other => panic!("expected Full, got {other:?}"),
        }
        assert_eq!(q.len(), 2);
        q.try_pop();
        q.push(3).unwrap();
    }

    #[test]
    fn close_rejects_pushes_but_drains_pops() {
        let q = RequestQueue::bounded(0);
        q.push(1).unwrap();
        q.close();
        assert!(q.is_closed());
        match q.push(2) {
            Err(PushError::Closed(v)) => assert_eq!(v, 2),
            other => panic!("expected Closed, got {other:?}"),
        }
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn blocking_pop_wakes_on_push() {
        let q = Arc::new(RequestQueue::bounded(0));
        let q2 = q.clone();
        let h = thread::spawn(move || q2.pop());
        std::thread::sleep(Duration::from_millis(10));
        q.push(42u32).unwrap();
        assert_eq!(h.join().unwrap(), Some(42));
    }

    #[test]
    fn blocking_pop_wakes_on_close() {
        let q: Arc<RequestQueue<u32>> = Arc::new(RequestQueue::bounded(0));
        let q2 = q.clone();
        let h = thread::spawn(move || q2.pop());
        std::thread::sleep(Duration::from_millis(10));
        q.close();
        assert_eq!(h.join().unwrap(), None);
    }

    #[test]
    fn pop_deadline_times_out_on_open_empty_queue() {
        let q: RequestQueue<u32> = RequestQueue::bounded(0);
        let t0 = Instant::now();
        match q.pop_deadline(t0 + Duration::from_millis(20)) {
            Pop::TimedOut => {}
            other => panic!("expected TimedOut, got {other:?}"),
        }
        assert!(t0.elapsed() >= Duration::from_millis(20));
    }

    #[test]
    fn pop_deadline_prefers_item_and_close_over_timeout() {
        let q: RequestQueue<u32> = RequestQueue::bounded(0);
        q.push(7).unwrap();
        match q.pop_deadline(Instant::now()) {
            Pop::Item(7) => {}
            other => panic!("expected Item(7), got {other:?}"),
        }
        q.close();
        match q.pop_deadline(Instant::now()) {
            Pop::Closed => {}
            other => panic!("expected Closed, got {other:?}"),
        }
    }

    #[test]
    fn concurrent_producers_never_exceed_cap() {
        let q = Arc::new(RequestQueue::bounded(4));
        let handles: Vec<_> = (0..8)
            .map(|i| {
                let q = q.clone();
                thread::spawn(move || q.push(i).is_ok())
            })
            .collect();
        let accepted = handles
            .into_iter()
            .map(|h| h.join().unwrap())
            .filter(|&ok| ok)
            .count();
        assert!(accepted <= 4, "cap overshoot: {accepted}");
        assert!(q.len() <= 4);
        let mut drained = 0;
        while q.try_pop().is_some() {
            drained += 1;
        }
        assert_eq!(drained, accepted);
    }
}
