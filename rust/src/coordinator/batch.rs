//! Dynamic batcher: collect requests until the batch fills or the timeout
//! since the *first* pending request expires (vLLM-style continuous
//! batching, simplified to fixed-shape batches because the AOT graph has a
//! static (B, S)).

use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct BatcherConfig {
    pub batch_size: usize,
    pub timeout: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        Self { batch_size: 8, timeout: Duration::from_millis(5) }
    }
}

/// Pulls from a channel and yields batches.
pub struct Batcher<T> {
    pub cfg: BatcherConfig,
    rx: Receiver<T>,
}

impl<T> Batcher<T> {
    pub fn new(cfg: BatcherConfig, rx: Receiver<T>) -> Self {
        Self { cfg, rx }
    }

    /// Block for the next batch. Returns `None` when the channel closed and
    /// no items remain.
    pub fn next_batch(&self) -> Option<Vec<T>> {
        // Block for the first item.
        let first = match self.rx.recv() {
            Ok(x) => x,
            Err(_) => return None,
        };
        let mut batch = vec![first];
        let deadline = Instant::now() + self.cfg.timeout;
        while batch.len() < self.cfg.batch_size {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match self.rx.recv_timeout(deadline - now) {
                Ok(x) => batch.push(x),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        Some(batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    #[test]
    fn batches_up_to_size() {
        let (tx, rx) = channel();
        for i in 0..10 {
            tx.send(i).unwrap();
        }
        let b = Batcher::new(
            BatcherConfig { batch_size: 4, timeout: Duration::from_millis(1) },
            rx,
        );
        assert_eq!(b.next_batch().unwrap(), vec![0, 1, 2, 3]);
        assert_eq!(b.next_batch().unwrap(), vec![4, 5, 6, 7]);
        assert_eq!(b.next_batch().unwrap(), vec![8, 9]);
    }

    #[test]
    fn flushes_partial_batch_on_timeout() {
        let (tx, rx) = channel();
        tx.send(42).unwrap();
        let b = Batcher::new(
            BatcherConfig { batch_size: 8, timeout: Duration::from_millis(10) },
            rx,
        );
        let t0 = Instant::now();
        assert_eq!(b.next_batch().unwrap(), vec![42]);
        assert!(t0.elapsed() >= Duration::from_millis(9));
    }

    #[test]
    fn none_after_close() {
        let (tx, rx) = channel::<u32>();
        drop(tx);
        let b = Batcher::new(BatcherConfig::default(), rx);
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn drains_remaining_after_close() {
        let (tx, rx) = channel();
        tx.send(1).unwrap();
        tx.send(2).unwrap();
        drop(tx);
        let b = Batcher::new(
            BatcherConfig { batch_size: 8, timeout: Duration::from_millis(1) },
            rx,
        );
        assert_eq!(b.next_batch().unwrap(), vec![1, 2]);
        assert!(b.next_batch().is_none());
    }
}
