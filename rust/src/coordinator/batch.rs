//! Dynamic batcher: collect requests until the batch fills or the timeout
//! since the *first* pending request expires.
//!
//! Under continuous batching (PR 5) the shard loop uses the batcher in two
//! modes: [`Batcher::next_batch`] blocks for work when the shard is idle
//! (classic timeout batching), and [`Batcher::try_fill`] drains whatever
//! is already queued — without blocking — between decode steps, so queued
//! requests join the in-flight decode set as soon as a step boundary
//! passes instead of waiting for the current "batch" to finish.
//!
//! PR 6 moved the transport from `std::sync::mpsc` to the shim-backed
//! [`RequestQueue`], which makes the whole admit→batch→retire path
//! model-checkable (`tests/loom_coordinator.rs`) and folds the queue-depth
//! accounting into the queue itself.

use std::time::{Duration, Instant};

use super::queue::{Pop, RequestQueue};
use crate::util::sync::Arc;

/// Batch-forming knobs for one shard.
#[derive(Debug, Clone)]
pub struct BatcherConfig {
    /// Max requests pulled per blocking batch (clamped to the executor's
    /// batch capacity by the shard loop).
    pub batch_size: usize,
    /// Window after the first pending request in which more requests may
    /// join the batch.
    pub timeout: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        Self { batch_size: 8, timeout: Duration::from_millis(5) }
    }
}

/// Pulls from a [`RequestQueue`] and yields batches.
pub struct Batcher<T> {
    /// The batch-forming knobs this batcher was built with.
    pub cfg: BatcherConfig,
    queue: Arc<RequestQueue<T>>,
}

impl<T> Batcher<T> {
    /// Wrap a request queue with batch-forming logic.
    pub fn new(cfg: BatcherConfig, queue: Arc<RequestQueue<T>>) -> Self {
        Self { cfg, queue }
    }

    /// Block for the next batch. Returns `None` when the queue closed and
    /// no items remain.
    ///
    /// Not model-safe (the fill window branches on wall-clock time);
    /// models exercise [`try_fill`](Self::try_fill) and the queue ops
    /// directly.
    pub fn next_batch(&self) -> Option<Vec<T>> {
        // Block for the first item.
        let first = self.queue.pop()?;
        let mut batch = vec![first];
        let deadline = Instant::now() + self.cfg.timeout;
        while batch.len() < self.cfg.batch_size {
            match self.queue.pop_deadline(deadline) {
                Pop::Item(x) => batch.push(x),
                Pop::TimedOut | Pop::Closed => break,
            }
        }
        Some(batch)
    }

    /// Drain up to `max` already-queued items without blocking — the
    /// continuous-batching top-up between decode steps. Returns an empty
    /// vec when nothing is queued (or `max == 0`); never waits.
    pub fn try_fill(&self, max: usize) -> Vec<T> {
        let mut out = Vec::new();
        while out.len() < max {
            match self.queue.try_pop() {
                Some(x) => out.push(x),
                None => break,
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn queue<T>() -> Arc<RequestQueue<T>> {
        Arc::new(RequestQueue::bounded(0))
    }

    #[test]
    fn batches_up_to_size() {
        let q = queue();
        for i in 0..10 {
            q.push(i).unwrap();
        }
        let b = Batcher::new(
            BatcherConfig { batch_size: 4, timeout: Duration::from_millis(1) },
            q,
        );
        assert_eq!(b.next_batch().unwrap(), vec![0, 1, 2, 3]);
        assert_eq!(b.next_batch().unwrap(), vec![4, 5, 6, 7]);
        assert_eq!(b.next_batch().unwrap(), vec![8, 9]);
    }

    #[test]
    fn flushes_partial_batch_on_timeout() {
        let q = queue();
        q.push(42).unwrap();
        let b = Batcher::new(
            BatcherConfig { batch_size: 8, timeout: Duration::from_millis(10) },
            q,
        );
        let t0 = Instant::now();
        assert_eq!(b.next_batch().unwrap(), vec![42]);
        assert!(t0.elapsed() >= Duration::from_millis(9));
    }

    #[test]
    fn none_after_close() {
        let q = queue::<u32>();
        q.close();
        let b = Batcher::new(BatcherConfig::default(), q);
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn drains_remaining_after_close() {
        let q = queue();
        q.push(1).unwrap();
        q.push(2).unwrap();
        q.close();
        let b = Batcher::new(
            BatcherConfig { batch_size: 8, timeout: Duration::from_millis(1) },
            q,
        );
        assert_eq!(b.next_batch().unwrap(), vec![1, 2]);
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn timeout_counts_from_first_request_under_slow_trickle() {
        // Items arriving every ~8 ms must NOT keep resetting the window:
        // the batch closes one timeout after the FIRST pending item, so a
        // 25 ms window admits only ~3 trickled items, never all 10.
        let q = queue();
        let q2 = q.clone();
        let feeder = std::thread::spawn(move || {
            for i in 0..10 {
                if q2.push(i).is_err() {
                    return;
                }
                std::thread::sleep(Duration::from_millis(8));
            }
            q2.close();
        });
        let b = Batcher::new(
            BatcherConfig { batch_size: 64, timeout: Duration::from_millis(25) },
            q,
        );
        let t0 = Instant::now();
        let batch = b.next_batch().unwrap();
        let elapsed = t0.elapsed();
        assert!(batch.len() < 10, "timeout window slid with the trickle: {batch:?}");
        assert!(!batch.is_empty());
        // Closed within roughly one timeout of the first item (generous
        // upper bound for loaded CI machines).
        assert!(elapsed < Duration::from_millis(500), "took {elapsed:?}");
        // Drain the rest so the feeder thread can finish.
        while b.next_batch().is_some() {}
        feeder.join().unwrap();
    }

    #[test]
    fn close_mid_batch_drains_the_remainder() {
        // Queue closes while a batch is filling: the in-flight batch must
        // still deliver everything already queued, then end.
        let q = queue();
        let q2 = q.clone();
        let b = Batcher::new(
            BatcherConfig { batch_size: 8, timeout: Duration::from_secs(5) },
            q,
        );
        let feeder = std::thread::spawn(move || {
            q2.push(1).unwrap();
            q2.push(2).unwrap();
            q2.push(3).unwrap();
            // Queue closes here, mid-window, long before the 5 s timeout.
            q2.close();
        });
        let t0 = Instant::now();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch, vec![1, 2, 3]);
        // Returned on close, not after the full timeout.
        assert!(t0.elapsed() < Duration::from_secs(4));
        assert!(b.next_batch().is_none());
        feeder.join().unwrap();
    }

    #[test]
    fn try_fill_never_blocks_and_respects_the_cap() {
        let q = queue();
        let b = Batcher::new(BatcherConfig::default(), q.clone());
        // Empty queue: instant empty result, no waiting.
        let t0 = Instant::now();
        assert!(b.try_fill(8).is_empty());
        assert!(t0.elapsed() < Duration::from_millis(50));
        for i in 0..5 {
            q.push(i).unwrap();
        }
        assert_eq!(b.try_fill(0), Vec::<i32>::new());
        assert_eq!(b.try_fill(3), vec![0, 1, 2]);
        assert_eq!(b.try_fill(8), vec![3, 4]);
        q.close();
        assert!(b.try_fill(8).is_empty(), "closed + drained yields nothing");
    }

    #[test]
    fn burst_arrival_never_exceeds_batch_size() {
        let q = queue();
        for i in 0..1000 {
            q.push(i).unwrap();
        }
        q.close();
        let b = Batcher::new(
            BatcherConfig { batch_size: 7, timeout: Duration::from_millis(50) },
            q,
        );
        let mut total = 0;
        let mut next_expected = 0;
        while let Some(batch) = b.next_batch() {
            assert!(batch.len() <= 7, "over-full batch: {}", batch.len());
            // FIFO order is preserved across batch boundaries.
            for x in batch {
                assert_eq!(x, next_expected);
                next_expected += 1;
                total += 1;
            }
        }
        assert_eq!(total, 1000);
    }
}
