//! Serving metrics: counters + latency reservoir, lock-light.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

#[derive(Debug, Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub responses: AtomicU64,
    pub batches: AtomicU64,
    pub batch_tokens: AtomicU64,
    /// Simulated DVFS transitions accounted by the executor.
    pub dvfs_transitions: AtomicU64,
    latencies_us: Mutex<Vec<u64>>,
}

impl Metrics {
    pub fn record_latency(&self, d: Duration) {
        let mut l = self.latencies_us.lock().unwrap();
        if l.len() < 1_000_000 {
            l.push(d.as_micros() as u64);
        }
    }

    pub fn percentile_latency(&self, p: f64) -> Option<Duration> {
        let mut l = self.latencies_us.lock().unwrap().clone();
        if l.is_empty() {
            return None;
        }
        l.sort_unstable();
        let i = ((l.len() - 1) as f64 * p) as usize;
        Some(Duration::from_micros(l[i]))
    }

    pub fn mean_batch_occupancy(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            return 0.0;
        }
        self.responses.load(Ordering::Relaxed) as f64 / b as f64
    }

    pub fn summary(&self) -> String {
        format!(
            "requests={} responses={} batches={} occupancy={:.2} p50={:?} p95={:?} dvfs_transitions={}",
            self.requests.load(Ordering::Relaxed),
            self.responses.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.mean_batch_occupancy(),
            self.percentile_latency(0.5).unwrap_or_default(),
            self.percentile_latency(0.95).unwrap_or_default(),
            self.dvfs_transitions.load(Ordering::Relaxed),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles() {
        let m = Metrics::default();
        for us in [100u64, 200, 300, 400, 1000] {
            m.record_latency(Duration::from_micros(us));
        }
        assert_eq!(m.percentile_latency(0.5).unwrap(), Duration::from_micros(300));
        assert_eq!(m.percentile_latency(1.0).unwrap(), Duration::from_micros(1000));
        assert!(m.percentile_latency(0.0).unwrap() <= Duration::from_micros(100));
    }

    #[test]
    fn occupancy() {
        let m = Metrics::default();
        m.responses.store(24, Ordering::Relaxed);
        m.batches.store(4, Ordering::Relaxed);
        assert_eq!(m.mean_batch_occupancy(), 6.0);
    }
}
