//! Serving metrics: counters + latency reservoir, lock-light.
//!
//! Each shard owns a `Metrics`; the coordinator also keeps a global
//! aggregate that every shard records into, so live counters stay O(1) to
//! read. [`Metrics::merged`] folds any set of per-shard views into one
//! [`MetricsSnapshot`] (p50/p95/p99 over the union of latency samples),
//! which is what `halo loadgen` and `benches/l2_serving.rs` report.

use std::time::Duration;

use crate::runtime::PoolStats;
use crate::util::sync::atomic::{AtomicU64, Ordering};
use crate::util::sync::Mutex;
use crate::util::Json;

/// Why a request was refused or dropped instead of served. Carried on
/// every shed [`Response`](super::Response) and counted per-reason here,
/// so traces can tell overload (deadline/admission/brown-out) apart from
/// faults (shard death / retry exhaustion).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ShedReason {
    /// Deadline expired while queued or mid-decode.
    Deadline,
    /// Refused at admission: every open shard queue was at capacity.
    Admission,
    /// The owning shard died (or every shard was gone) and the request
    /// could not be re-homed.
    ShardDeath,
    /// Retried after faults until the per-request or global retry budget
    /// ran out.
    RetryExhausted,
    /// Dropped by brown-out degradation (low-priority work under
    /// sustained overload / repeated shard death).
    Brownout,
}

impl ShedReason {
    /// All reasons, in reporting order.
    pub const ALL: [ShedReason; 5] = [
        ShedReason::Deadline,
        ShedReason::Admission,
        ShedReason::ShardDeath,
        ShedReason::RetryExhausted,
        ShedReason::Brownout,
    ];

    /// Stable snake_case name used in JSON reports and summaries.
    pub fn name(self) -> &'static str {
        match self {
            ShedReason::Deadline => "deadline",
            ShedReason::Admission => "admission",
            ShedReason::ShardDeath => "shard_death",
            ShedReason::RetryExhausted => "retry_exhausted",
            ShedReason::Brownout => "brownout",
        }
    }
}

/// Monotone speculative-decode counters owned by a shard's
/// [`SpecExecutor`](super::spec::SpecExecutor) (PR 9). The executor is
/// the source of truth — the shard loop publishes a snapshot into the
/// `spec_*` gauges on [`Metrics`] after each decode step via
/// [`Metrics::store_spec`] (`store`d wholesale, never `fetch_add`ed,
/// mirroring the [`PoolStats`] pattern).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SpecDecodeStats {
    /// Tokens proposed by the drafter (k_eff summed over rounds).
    pub drafted_tokens: u64,
    /// Drafted tokens accepted by the verifier (≤ `drafted_tokens`; the
    /// bonus token emitted after each accepted prefix is not counted
    /// here, so `accepted / drafted` is the paper's acceptance rate).
    pub accepted_tokens: u64,
    /// Positions the drafter evaluated (its own incremental chain:
    /// catch-up rows + proposal rows).
    pub draft_positions: u64,
    /// Positions the verifier scored in batched verify passes.
    pub verify_positions: u64,
    /// Verifier passes executed (one per speculative round).
    pub verify_rounds: u64,
}

impl SpecDecodeStats {
    /// Fraction of drafted tokens the verifier accepted (0 when nothing
    /// was drafted yet).
    pub fn acceptance_rate(&self) -> f64 {
        if self.drafted_tokens == 0 {
            return 0.0;
        }
        self.accepted_tokens as f64 / self.drafted_tokens as f64
    }
}

/// Live serving counters + latency reservoir for one shard (or the
/// coordinator's global aggregate).
#[derive(Debug, Default)]
pub struct Metrics {
    /// Requests submitted (global view only).
    pub requests: AtomicU64,
    /// Requests answered with a served (non-shed) response.
    pub responses: AtomicU64,
    /// Decode steps executed over the live set (continuous batching: one
    /// "batch" = one step; occupancy = responses ÷ steps · decode length).
    pub batches: AtomicU64,
    /// Prompt tokens admitted into decode (prefix lengths).
    pub batch_tokens: AtomicU64,
    /// Tokens produced by autoregressive decode.
    pub generated_tokens: AtomicU64,
    /// Requests dropped after admission: deadline expired in queue, or the
    /// executor failed their batch.
    pub shed: AtomicU64,
    /// Requests refused at admission (every shard queue at capacity).
    pub rejected: AtomicU64,
    /// Batches whose executor returned an error (logged + shed).
    pub exec_errors: AtomicU64,
    /// Simulated DVFS transitions accounted by the executor: one full
    /// schedule pass per decode *step* since PR 5 (every step is a
    /// forward pass over the schedule; pre-PR-5 counted once per request
    /// batch, undercounting multi-token decode by ~max_new×).
    pub dvfs_transitions: AtomicU64,
    /// Successful shard respawns performed by the supervisor (a shard that
    /// died and came back; permanent deaths are visible as shed requests).
    pub shard_restarts: AtomicU64,
    /// Requests re-enqueued after a fault (each consumes one token of the
    /// global retry budget).
    pub retries: AtomicU64,
    /// Brown-out level transitions (each step up or down counts once).
    pub brownout_steps: AtomicU64,
    /// Sheds/rejections with [`ShedReason::Deadline`].
    pub shed_deadline: AtomicU64,
    /// Sheds/rejections with [`ShedReason::Admission`].
    pub shed_admission: AtomicU64,
    /// Sheds/rejections with [`ShedReason::ShardDeath`].
    pub shed_shard_death: AtomicU64,
    /// Sheds/rejections with [`ShedReason::RetryExhausted`].
    pub shed_retry_exhausted: AtomicU64,
    /// Sheds/rejections with [`ShedReason::Brownout`].
    pub shed_brownout: AtomicU64,
    /// KV block-pool gauges (PR 8), published by the shard loop from
    /// [`PoolStats`] after each decode step via [`Metrics::store_kv_pool`].
    /// `in_use`/`peak` are point-in-time occupancy; the rest are the
    /// pool's own monotone counters (the pool is the source of truth, so
    /// these are `store`d, never `fetch_add`ed).
    pub kv_blocks_in_use: AtomicU64,
    /// High-water mark of pool blocks allocated at once.
    pub kv_blocks_peak: AtomicU64,
    /// Frozen blocks reused from the shared-prefix registry.
    pub kv_shared_hits: AtomicU64,
    /// Shared-prefix registry lookups at cache creation.
    pub kv_prefix_lookups: AtomicU64,
    /// Idle registry blocks evicted under pool/registry pressure.
    pub kv_evictions: AtomicU64,
    /// Block acquisitions refused with `PoolExhausted` (surfaces as
    /// brown-out shed backpressure in the coordinator).
    pub kv_pool_refusals: AtomicU64,
    /// Speculative-decode gauges (PR 9), published by the shard loop from
    /// [`SpecDecodeStats`] after each decode step via
    /// [`Metrics::store_spec`]. Zero on non-speculative executors.
    pub spec_drafted_tokens: AtomicU64,
    /// Drafted tokens accepted by the verifier.
    pub spec_accepted_tokens: AtomicU64,
    /// Positions the drafter evaluated.
    pub spec_draft_positions: AtomicU64,
    /// Positions the verifier scored in batched verify passes.
    pub spec_verify_positions: AtomicU64,
    /// Verifier passes executed (one per speculative round).
    pub spec_verify_rounds: AtomicU64,
    latencies_us: Mutex<Vec<u64>>,
}

impl Metrics {
    /// Record one request's submit-to-respond latency (bounded reservoir).
    ///
    /// Poisoning is absorbed here and below: the reservoir's only
    /// invariant is "a Vec of samples", which holds at every await point,
    /// and metrics must stay readable after a recording thread panicked.
    pub fn record_latency(&self, d: Duration) {
        let mut l = self.latencies_us.lock().unwrap_or_else(|e| e.into_inner());
        if l.len() < 1_000_000 {
            l.push(d.as_micros() as u64);
        }
    }

    /// Latency percentile `p ∈ [0, 1]` over the recorded samples.
    pub fn percentile_latency(&self, p: f64) -> Option<Duration> {
        let mut l = self.latencies_us.lock().unwrap_or_else(|e| e.into_inner()).clone();
        if l.is_empty() {
            return None;
        }
        l.sort_unstable();
        let i = ((l.len() - 1) as f64 * p) as usize;
        Some(Duration::from_micros(l[i]))
    }

    /// The per-reason counter backing [`ShedReason`] accounting. Every
    /// shed *or* rejected request increments exactly one of these, so
    /// `Σ reasons == shed + rejected` at quiesce (the chaos suite pins
    /// this conservation law).
    pub fn shed_reason_counter(&self, reason: ShedReason) -> &AtomicU64 {
        match reason {
            ShedReason::Deadline => &self.shed_deadline,
            ShedReason::Admission => &self.shed_admission,
            ShedReason::ShardDeath => &self.shed_shard_death,
            ShedReason::RetryExhausted => &self.shed_retry_exhausted,
            ShedReason::Brownout => &self.shed_brownout,
        }
    }

    /// Served responses per executed decode step/batch.
    pub fn mean_batch_occupancy(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            return 0.0;
        }
        self.responses.load(Ordering::Relaxed) as f64 / b as f64
    }

    /// Publish a shard's KV block-pool stats into the gauges. The pool
    /// owns the counters, so every field is overwritten wholesale.
    pub fn store_kv_pool(&self, ps: &PoolStats) {
        self.kv_blocks_in_use.store(ps.blocks_in_use as u64, Ordering::Relaxed);
        self.kv_blocks_peak.store(ps.blocks_peak as u64, Ordering::Relaxed);
        self.kv_shared_hits.store(ps.shared_hits, Ordering::Relaxed);
        self.kv_prefix_lookups.store(ps.prefix_lookups, Ordering::Relaxed);
        self.kv_evictions.store(ps.evictions, Ordering::Relaxed);
        self.kv_pool_refusals.store(ps.refusals, Ordering::Relaxed);
    }

    /// Publish a shard's speculative-decode stats into the gauges. The
    /// executor owns the counters, so every field is overwritten
    /// wholesale (same contract as [`Metrics::store_kv_pool`]).
    pub fn store_spec(&self, ss: &SpecDecodeStats) {
        self.spec_drafted_tokens.store(ss.drafted_tokens, Ordering::Relaxed);
        self.spec_accepted_tokens.store(ss.accepted_tokens, Ordering::Relaxed);
        self.spec_draft_positions.store(ss.draft_positions, Ordering::Relaxed);
        self.spec_verify_positions.store(ss.verify_positions, Ordering::Relaxed);
        self.spec_verify_rounds.store(ss.verify_rounds, Ordering::Relaxed);
    }

    /// Point-in-time copy of everything (percentiles computed over this
    /// view's own latency samples).
    pub fn snapshot(&self) -> MetricsSnapshot {
        let mut lat = self.latencies_us.lock().unwrap_or_else(|e| e.into_inner()).clone();
        lat.sort_unstable();
        MetricsSnapshot {
            requests: self.requests.load(Ordering::Relaxed),
            responses: self.responses.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            batch_tokens: self.batch_tokens.load(Ordering::Relaxed),
            generated_tokens: self.generated_tokens.load(Ordering::Relaxed),
            shed: self.shed.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            exec_errors: self.exec_errors.load(Ordering::Relaxed),
            dvfs_transitions: self.dvfs_transitions.load(Ordering::Relaxed),
            shard_restarts: self.shard_restarts.load(Ordering::Relaxed),
            retries: self.retries.load(Ordering::Relaxed),
            brownout_steps: self.brownout_steps.load(Ordering::Relaxed),
            shed_reasons: ShedReason::ALL
                .map(|r| self.shed_reason_counter(r).load(Ordering::Relaxed)),
            kv_blocks_in_use: self.kv_blocks_in_use.load(Ordering::Relaxed),
            kv_blocks_peak: self.kv_blocks_peak.load(Ordering::Relaxed),
            kv_shared_hits: self.kv_shared_hits.load(Ordering::Relaxed),
            kv_prefix_lookups: self.kv_prefix_lookups.load(Ordering::Relaxed),
            kv_evictions: self.kv_evictions.load(Ordering::Relaxed),
            kv_pool_refusals: self.kv_pool_refusals.load(Ordering::Relaxed),
            spec: SpecDecodeStats {
                drafted_tokens: self.spec_drafted_tokens.load(Ordering::Relaxed),
                accepted_tokens: self.spec_accepted_tokens.load(Ordering::Relaxed),
                draft_positions: self.spec_draft_positions.load(Ordering::Relaxed),
                verify_positions: self.spec_verify_positions.load(Ordering::Relaxed),
                verify_rounds: self.spec_verify_rounds.load(Ordering::Relaxed),
            },
            latencies_us: lat,
        }
    }

    /// Aggregate per-shard views: counters sum, latency percentiles are
    /// computed over the union of all samples.
    pub fn merged<M: AsRef<Metrics>>(views: &[M]) -> MetricsSnapshot {
        let mut out = MetricsSnapshot::default();
        for v in views {
            let s = v.as_ref().snapshot();
            out.requests += s.requests;
            out.responses += s.responses;
            out.batches += s.batches;
            out.batch_tokens += s.batch_tokens;
            out.generated_tokens += s.generated_tokens;
            out.shed += s.shed;
            out.rejected += s.rejected;
            out.exec_errors += s.exec_errors;
            out.dvfs_transitions += s.dvfs_transitions;
            out.shard_restarts += s.shard_restarts;
            out.retries += s.retries;
            out.brownout_steps += s.brownout_steps;
            for (acc, v) in out.shed_reasons.iter_mut().zip(s.shed_reasons) {
                *acc += v;
            }
            out.kv_blocks_in_use += s.kv_blocks_in_use;
            out.kv_blocks_peak += s.kv_blocks_peak;
            out.kv_shared_hits += s.kv_shared_hits;
            out.kv_prefix_lookups += s.kv_prefix_lookups;
            out.kv_evictions += s.kv_evictions;
            out.kv_pool_refusals += s.kv_pool_refusals;
            out.spec.drafted_tokens += s.spec.drafted_tokens;
            out.spec.accepted_tokens += s.spec.accepted_tokens;
            out.spec.draft_positions += s.spec.draft_positions;
            out.spec.verify_positions += s.spec.verify_positions;
            out.spec.verify_rounds += s.spec.verify_rounds;
            out.latencies_us.extend_from_slice(&s.latencies_us);
        }
        out.latencies_us.sort_unstable();
        out
    }

    /// One-line human summary of a fresh snapshot.
    pub fn summary(&self) -> String {
        self.snapshot().summary()
    }
}

// `Arc<Metrics>` gets `AsRef<Metrics>` from std's blanket impl; this
// reflexive impl lets `merged` also take plain `&[&Metrics]` slices.
impl AsRef<Metrics> for Metrics {
    fn as_ref(&self) -> &Metrics {
        self
    }
}

/// Plain-data view of [`Metrics`] for reporting/JSON.
#[derive(Debug, Clone, Default)]
pub struct MetricsSnapshot {
    /// Requests submitted (global view only).
    pub requests: u64,
    /// Requests answered with a served (non-shed) response.
    pub responses: u64,
    /// Decode steps executed over the live set.
    pub batches: u64,
    /// Prompt tokens admitted into decode.
    pub batch_tokens: u64,
    /// Tokens produced by autoregressive decode.
    pub generated_tokens: u64,
    /// Requests dropped after admission.
    pub shed: u64,
    /// Requests refused at admission.
    pub rejected: u64,
    /// Executor step/batch errors.
    pub exec_errors: u64,
    /// Simulated DVFS transitions (one schedule pass per decode step).
    pub dvfs_transitions: u64,
    /// Successful shard respawns performed by the supervisor.
    pub shard_restarts: u64,
    /// Requests re-enqueued after a fault.
    pub retries: u64,
    /// Brown-out level transitions.
    pub brownout_steps: u64,
    /// Per-reason shed/reject counts, indexed in [`ShedReason::ALL`]
    /// order; `Σ == shed + rejected` at quiesce.
    pub shed_reasons: [u64; 5],
    /// KV pool blocks currently allocated (summed across shards when
    /// merged).
    pub kv_blocks_in_use: u64,
    /// KV pool allocation high-water mark.
    pub kv_blocks_peak: u64,
    /// Frozen blocks reused from the shared-prefix registry.
    pub kv_shared_hits: u64,
    /// Shared-prefix registry lookups at cache creation.
    pub kv_prefix_lookups: u64,
    /// Idle registry blocks evicted under pressure.
    pub kv_evictions: u64,
    /// Block acquisitions refused with `PoolExhausted`.
    pub kv_pool_refusals: u64,
    /// Speculative-decode counters (summed across shards when merged;
    /// all-zero on non-speculative executors).
    pub spec: SpecDecodeStats,
    /// Sorted ascending.
    pub latencies_us: Vec<u64>,
}

impl MetricsSnapshot {
    /// Count recorded for one [`ShedReason`].
    pub fn shed_for(&self, reason: ShedReason) -> u64 {
        let [deadline, admission, shard_death, retry_exhausted, brownout] = self.shed_reasons;
        match reason {
            ShedReason::Deadline => deadline,
            ShedReason::Admission => admission,
            ShedReason::ShardDeath => shard_death,
            ShedReason::RetryExhausted => retry_exhausted,
            ShedReason::Brownout => brownout,
        }
    }

    /// Sum over all per-reason shed counts (= `shed + rejected` at
    /// quiesce).
    pub fn shed_reason_total(&self) -> u64 {
        self.shed_reasons.iter().sum()
    }

    /// Latency percentile `p ∈ [0, 1]` over the snapshot's samples.
    pub fn percentile_latency(&self, p: f64) -> Option<Duration> {
        if self.latencies_us.is_empty() {
            return None;
        }
        let i = ((self.latencies_us.len() - 1) as f64 * p) as usize;
        Some(Duration::from_micros(self.latencies_us[i]))
    }

    /// Served responses per executed decode step/batch.
    pub fn mean_batch_occupancy(&self) -> f64 {
        if self.batches == 0 {
            return 0.0;
        }
        self.responses as f64 / self.batches as f64
    }

    /// Generated tokens per second over a measured wall-clock window.
    pub fn tokens_per_sec(&self, wall: Duration) -> f64 {
        let s = wall.as_secs_f64();
        if s <= 0.0 {
            return 0.0;
        }
        self.generated_tokens as f64 / s
    }

    /// One-line human summary (the `halo serve` / `halo loadgen` output).
    pub fn summary(&self) -> String {
        let mut s = format!(
            "requests={} responses={} shed={} rejected={} batches={} occupancy={:.2} \
             p50={:?} p95={:?} p99={:?} generated={} dvfs_transitions={} \
             restarts={} retries={} brownout_steps={}",
            self.requests,
            self.responses,
            self.shed,
            self.rejected,
            self.batches,
            self.mean_batch_occupancy(),
            self.percentile_latency(0.5).unwrap_or_default(),
            self.percentile_latency(0.95).unwrap_or_default(),
            self.percentile_latency(0.99).unwrap_or_default(),
            self.generated_tokens,
            self.dvfs_transitions,
            self.shard_restarts,
            self.retries,
            self.brownout_steps,
        );
        if self.spec.verify_rounds > 0 {
            s.push_str(&format!(
                " spec_accept={:.2} spec_drafted={} spec_rounds={}",
                self.spec.acceptance_rate(),
                self.spec.drafted_tokens,
                self.spec.verify_rounds,
            ));
        }
        s
    }

    /// JSON object for bench/loadgen reports. `wall` enables tokens/sec
    /// and requests/sec rates.
    pub fn to_json(&self, wall: Option<Duration>) -> Json {
        let us = |p: f64| {
            self.percentile_latency(p).map_or(Json::Null, |d| Json::Num(d.as_micros() as f64))
        };
        let mut j = Json::obj();
        j.set("requests", self.requests as f64)
            .set("responses", self.responses as f64)
            .set("shed", self.shed as f64)
            .set("rejected", self.rejected as f64)
            .set("exec_errors", self.exec_errors as f64)
            .set("batches", self.batches as f64)
            .set("occupancy", self.mean_batch_occupancy())
            .set("generated_tokens", self.generated_tokens as f64)
            .set("dvfs_transitions", self.dvfs_transitions as f64)
            .set("shard_restarts", self.shard_restarts as f64)
            .set("retries", self.retries as f64)
            .set("brownout_steps", self.brownout_steps as f64)
            .set("p50_us", us(0.50))
            .set("p95_us", us(0.95))
            .set("p99_us", us(0.99));
        let mut reasons = Json::obj();
        for r in ShedReason::ALL {
            reasons.set(r.name(), self.shed_for(r) as f64);
        }
        j.set("shed_reasons", reasons);
        let mut kv = Json::obj();
        kv.set("blocks_in_use", self.kv_blocks_in_use as f64)
            .set("blocks_peak", self.kv_blocks_peak as f64)
            .set("shared_hits", self.kv_shared_hits as f64)
            .set("prefix_lookups", self.kv_prefix_lookups as f64)
            .set("evictions", self.kv_evictions as f64)
            .set("pool_refusals", self.kv_pool_refusals as f64);
        j.set("kv_pool", kv);
        let mut spec = Json::obj();
        spec.set("drafted_tokens", self.spec.drafted_tokens as f64)
            .set("accepted_tokens", self.spec.accepted_tokens as f64)
            .set("draft_positions", self.spec.draft_positions as f64)
            .set("verify_positions", self.spec.verify_positions as f64)
            .set("verify_rounds", self.spec.verify_rounds as f64)
            .set("acceptance_rate", self.spec.acceptance_rate());
        j.set("spec", spec);
        if let Some(w) = wall {
            let s = w.as_secs_f64().max(1e-12);
            j.set("wall_s", s)
                .set("tokens_per_sec", self.tokens_per_sec(w))
                .set("requests_per_sec", self.responses as f64 / s);
        }
        j
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn percentiles() {
        let m = Metrics::default();
        for us in [100u64, 200, 300, 400, 1000] {
            m.record_latency(Duration::from_micros(us));
        }
        assert_eq!(m.percentile_latency(0.5).unwrap(), Duration::from_micros(300));
        assert_eq!(m.percentile_latency(1.0).unwrap(), Duration::from_micros(1000));
        assert!(m.percentile_latency(0.0).unwrap() <= Duration::from_micros(100));
        assert_eq!(
            m.snapshot().percentile_latency(0.99).unwrap(),
            Duration::from_micros(1000)
        );
    }

    #[test]
    fn occupancy() {
        let m = Metrics::default();
        m.responses.store(24, Ordering::Relaxed);
        m.batches.store(4, Ordering::Relaxed);
        assert_eq!(m.mean_batch_occupancy(), 6.0);
    }

    #[test]
    fn merged_sums_counters_and_unions_latencies() {
        let a = Arc::new(Metrics::default());
        let b = Arc::new(Metrics::default());
        a.responses.store(3, Ordering::Relaxed);
        b.responses.store(5, Ordering::Relaxed);
        a.generated_tokens.store(30, Ordering::Relaxed);
        b.generated_tokens.store(50, Ordering::Relaxed);
        a.record_latency(Duration::from_micros(100));
        b.record_latency(Duration::from_micros(900));
        let s = Metrics::merged(&[a, b]);
        assert_eq!(s.responses, 8);
        assert_eq!(s.generated_tokens, 80);
        assert_eq!(s.latencies_us, vec![100, 900]);
        assert_eq!(s.percentile_latency(1.0).unwrap(), Duration::from_micros(900));
        assert_eq!(s.tokens_per_sec(Duration::from_secs(2)), 40.0);
    }

    #[test]
    fn recovery_counters_merge_and_report() {
        let a = Arc::new(Metrics::default());
        let b = Arc::new(Metrics::default());
        a.shard_restarts.store(2, Ordering::Relaxed);
        b.shard_restarts.store(1, Ordering::Relaxed);
        a.retries.store(5, Ordering::Relaxed);
        b.brownout_steps.store(3, Ordering::Relaxed);
        a.shed_reason_counter(ShedReason::Deadline).store(4, Ordering::Relaxed);
        b.shed_reason_counter(ShedReason::Deadline).store(1, Ordering::Relaxed);
        b.shed_reason_counter(ShedReason::RetryExhausted).store(2, Ordering::Relaxed);
        let s = Metrics::merged(&[a, b]);
        assert_eq!(s.shard_restarts, 3);
        assert_eq!(s.retries, 5);
        assert_eq!(s.brownout_steps, 3);
        assert_eq!(s.shed_for(ShedReason::Deadline), 5);
        assert_eq!(s.shed_for(ShedReason::RetryExhausted), 2);
        assert_eq!(s.shed_for(ShedReason::Brownout), 0);
        assert_eq!(s.shed_reason_total(), 7);
        let j = s.to_json(None);
        assert_eq!(j.req("shard_restarts").unwrap().as_f64().unwrap(), 3.0);
        let reasons = j.req("shed_reasons").unwrap();
        assert_eq!(reasons.req("deadline").unwrap().as_f64().unwrap(), 5.0);
        assert_eq!(reasons.req("retry_exhausted").unwrap().as_f64().unwrap(), 2.0);
        assert!(s.summary().contains("retries=5"));
    }

    #[test]
    fn kv_pool_gauges_store_merge_and_report() {
        let a = Arc::new(Metrics::default());
        let b = Arc::new(Metrics::default());
        a.store_kv_pool(&PoolStats {
            blocks_in_use: 3,
            blocks_peak: 7,
            shared_hits: 10,
            prefix_lookups: 12,
            evictions: 1,
            refusals: 2,
            ..PoolStats::default()
        });
        b.store_kv_pool(&PoolStats { blocks_in_use: 5, ..PoolStats::default() });
        // Gauges overwrite wholesale: a second store replaces, not adds.
        a.store_kv_pool(&PoolStats {
            blocks_in_use: 4,
            blocks_peak: 7,
            shared_hits: 11,
            prefix_lookups: 13,
            evictions: 1,
            refusals: 2,
            ..PoolStats::default()
        });
        let s = Metrics::merged(&[a, b]);
        assert_eq!(s.kv_blocks_in_use, 9);
        assert_eq!(s.kv_blocks_peak, 7);
        assert_eq!(s.kv_shared_hits, 11);
        assert_eq!(s.kv_prefix_lookups, 13);
        assert_eq!(s.kv_evictions, 1);
        assert_eq!(s.kv_pool_refusals, 2);
        let j = s.to_json(None);
        let kv = j.req("kv_pool").unwrap();
        assert_eq!(kv.req("blocks_in_use").unwrap().as_f64().unwrap(), 9.0);
        assert_eq!(kv.req("shared_hits").unwrap().as_f64().unwrap(), 11.0);
    }

    #[test]
    fn spec_gauges_store_merge_and_report() {
        let a = Arc::new(Metrics::default());
        let b = Arc::new(Metrics::default());
        a.store_spec(&SpecDecodeStats {
            drafted_tokens: 10,
            accepted_tokens: 6,
            draft_positions: 12,
            verify_positions: 14,
            verify_rounds: 3,
        });
        // Gauges overwrite wholesale: a second store replaces, not adds.
        a.store_spec(&SpecDecodeStats {
            drafted_tokens: 12,
            accepted_tokens: 9,
            draft_positions: 15,
            verify_positions: 18,
            verify_rounds: 4,
        });
        b.store_spec(&SpecDecodeStats {
            drafted_tokens: 4,
            accepted_tokens: 3,
            draft_positions: 5,
            verify_positions: 6,
            verify_rounds: 1,
        });
        let s = Metrics::merged(&[a, b]);
        assert_eq!(s.spec.drafted_tokens, 16);
        assert_eq!(s.spec.accepted_tokens, 12);
        assert_eq!(s.spec.draft_positions, 20);
        assert_eq!(s.spec.verify_positions, 24);
        assert_eq!(s.spec.verify_rounds, 5);
        assert!((s.spec.acceptance_rate() - 0.75).abs() < 1e-12);
        assert_eq!(SpecDecodeStats::default().acceptance_rate(), 0.0);
        let j = s.to_json(None);
        let spec = j.req("spec").unwrap();
        assert_eq!(spec.req("drafted_tokens").unwrap().as_f64().unwrap(), 16.0);
        assert_eq!(spec.req("acceptance_rate").unwrap().as_f64().unwrap(), 0.75);
        assert!(s.summary().contains("spec_accept=0.75"));
        // Non-speculative snapshots keep the summary line unchanged.
        assert!(!Metrics::default().summary().contains("spec_accept"));
    }

    #[test]
    fn snapshot_json_has_percentiles_and_rates() {
        let m = Metrics::default();
        m.responses.store(10, Ordering::Relaxed);
        m.generated_tokens.store(20, Ordering::Relaxed);
        m.record_latency(Duration::from_micros(500));
        let j = m.snapshot().to_json(Some(Duration::from_secs(2)));
        assert_eq!(j.req("p50_us").unwrap().as_f64().unwrap(), 500.0);
        assert_eq!(j.req("tokens_per_sec").unwrap().as_f64().unwrap(), 10.0);
        assert_eq!(j.req("requests_per_sec").unwrap().as_f64().unwrap(), 5.0);
        // Round-trips through the in-crate JSON emitter/parser.
        let re = Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(re.req("responses").unwrap().as_f64().unwrap(), 10.0);
    }
}
