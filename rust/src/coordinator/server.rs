//! The coordinator: router → batcher → executor threads.
//!
//! The executor is abstracted behind [`BatchExecutor`] so the coordinator's
//! routing/batching invariants are testable without a model; the production
//! executor ([`GraphExecutor`]) owns the loaded `fwd` graph and the
//! quantized parameter buffers on whichever runtime backend is active
//! (PJRT handles are not `Send`, so the executor is *constructed inside*
//! its thread via a factory closure).

use std::collections::BTreeMap;
use std::sync::atomic::Ordering;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::Result;

use super::batch::{Batcher, BatcherConfig};
use super::metrics::Metrics;
use crate::dvfs::Schedule;
use crate::quant::Matrix;
use crate::runtime::{literal_i32, Buffer, ModelArtifacts, Runtime};

/// One inference request: a token prefix; the response carries the argmax
/// next token at the prefix end.
#[derive(Debug)]
pub struct Request {
    pub id: u64,
    pub tokens: Vec<i32>,
    pub respond: Sender<Response>,
    pub submitted: Instant,
}

#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub next_token: i32,
    pub latency: std::time::Duration,
}

/// What the executor thread runs per batch: padded token matrix in, one
/// next-token per request out.
pub trait BatchExecutor {
    /// Max sequences per executed batch (the AOT graph's B).
    fn batch_capacity(&self) -> usize;
    fn seq_len(&self) -> usize;
    /// `prefixes` has ≤ batch_capacity entries, each ≤ seq_len tokens.
    fn run(&mut self, prefixes: &[Vec<i32>]) -> Result<Vec<i32>>;
    /// Simulated DVFS transitions for one pass (schedule metadata).
    fn dvfs_transitions(&self) -> usize {
        0
    }
}

/// Production executor: fwd graph + (quantized) parameter buffers, on
/// whichever runtime backend is active (sim or PJRT).
pub struct GraphExecutor {
    rt: Runtime,
    exe: crate::runtime::Executable,
    /// Parameters resident on device across batches (§Perf L3).
    params: Vec<Buffer>,
    batch: usize,
    seq: usize,
    vocab: usize,
    schedule: Schedule,
}

impl GraphExecutor {
    /// Build inside the executor thread. `replace` substitutes quantized
    /// linear weights; `schedule` is the model's DVFS class schedule.
    pub fn new(
        rt: Runtime,
        model: &ModelArtifacts,
        replace: &BTreeMap<String, Matrix>,
        schedule: Schedule,
    ) -> Result<Self> {
        let exe = rt.load(&model.graph_path("fwd_fp"))?;
        let params = rt.upload_all(&model.param_literals(replace)?)?;
        Ok(Self {
            rt,
            exe,
            params,
            batch: model.eval_batch,
            seq: model.seq_len,
            vocab: model.vocab,
            schedule,
        })
    }
}

impl BatchExecutor for GraphExecutor {
    fn batch_capacity(&self) -> usize {
        self.batch
    }

    fn seq_len(&self) -> usize {
        self.seq
    }

    fn run(&mut self, prefixes: &[Vec<i32>]) -> Result<Vec<i32>> {
        anyhow::ensure!(prefixes.len() <= self.batch, "over-full batch");
        // Pad to the static (B, S) shape; causality makes right-padding safe.
        let mut tokens = vec![0i32; self.batch * self.seq];
        for (i, p) in prefixes.iter().enumerate() {
            let n = p.len().min(self.seq);
            tokens[i * self.seq..i * self.seq + n].copy_from_slice(&p[..n]);
        }
        let tok_buf = self
            .rt
            .upload(&literal_i32(&tokens, &[self.batch, self.seq])?)?;
        let mut inputs: Vec<&Buffer> = self.params.iter().collect();
        inputs.push(&tok_buf);
        let out = self.exe.run_b(&inputs)?;
        let logits: Vec<f32> = out[0].to_vec()?;
        // logits: (B, S, vocab); read the argmax at each prefix's last pos.
        let next = prefixes
            .iter()
            .enumerate()
            .map(|(i, p)| {
                // Empty prefixes read position 0 (all-padding row) instead
                // of underflowing.
                let pos = p.len().clamp(1, self.seq) - 1;
                let base = (i * self.seq + pos) * self.vocab;
                let row = &logits[base..base + self.vocab];
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(t, _)| t as i32)
                    .unwrap_or(0)
            })
            .collect();
        Ok(next)
    }

    fn dvfs_transitions(&self) -> usize {
        self.schedule.transitions()
    }
}

/// The running coordinator.
pub struct Coordinator {
    tx: Option<Sender<Request>>,
    handle: Option<JoinHandle<Result<()>>>,
    pub metrics: Arc<Metrics>,
    next_id: std::sync::atomic::AtomicU64,
}

impl Coordinator {
    /// Start with an executor factory (runs on the executor thread — PJRT
    /// handles never cross threads).
    pub fn start<F>(cfg: BatcherConfig, make_executor: F) -> Self
    where
        F: FnOnce() -> Result<Box<dyn BatchExecutor>> + Send + 'static,
    {
        let (tx, rx): (Sender<Request>, Receiver<Request>) = channel();
        let metrics = Arc::new(Metrics::default());
        let m = metrics.clone();
        let handle = std::thread::spawn(move || -> Result<()> {
            let mut exec = make_executor()?;
            let cfg = BatcherConfig {
                batch_size: cfg.batch_size.min(exec.batch_capacity()),
                ..cfg
            };
            let batcher = Batcher::new(cfg, rx);
            while let Some(batch) = batcher.next_batch() {
                let prefixes: Vec<Vec<i32>> =
                    batch.iter().map(|r| r.tokens.clone()).collect();
                let next = exec.run(&prefixes)?;
                m.batches.fetch_add(1, Ordering::Relaxed);
                m.batch_tokens
                    .fetch_add(prefixes.iter().map(|p| p.len() as u64).sum(), Ordering::Relaxed);
                m.dvfs_transitions
                    .fetch_add(exec.dvfs_transitions() as u64, Ordering::Relaxed);
                for (req, tok) in batch.into_iter().zip(next) {
                    let latency = req.submitted.elapsed();
                    m.record_latency(latency);
                    m.responses.fetch_add(1, Ordering::Relaxed);
                    // Receiver may have gone away; that's the client's loss.
                    let _ = req.respond.send(Response { id: req.id, next_token: tok, latency });
                }
            }
            Ok(())
        });
        Self {
            tx: Some(tx),
            handle: Some(handle),
            metrics,
            next_id: std::sync::atomic::AtomicU64::new(0),
        }
    }

    /// Submit a prefix; returns the response channel.
    pub fn submit(&self, tokens: Vec<i32>) -> Receiver<Response> {
        let (rtx, rrx) = channel();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.metrics.requests.fetch_add(1, Ordering::Relaxed);
        let req = Request { id, tokens, respond: rtx, submitted: Instant::now() };
        self.tx
            .as_ref()
            .expect("coordinator already shut down")
            .send(req)
            .expect("executor thread died");
        rrx
    }

    /// Drain and stop the executor thread.
    pub fn shutdown(mut self) -> Result<()> {
        drop(self.tx.take());
        if let Some(h) = self.handle.take() {
            h.join().expect("executor thread panicked")?;
        }
        Ok(())
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        drop(self.tx.take());
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;
    use std::time::Duration;

    /// Deterministic fake: next token = sum of prefix mod 97.
    struct Echo {
        cap: usize,
    }

    impl BatchExecutor for Echo {
        fn batch_capacity(&self) -> usize {
            self.cap
        }
        fn seq_len(&self) -> usize {
            16
        }
        fn run(&mut self, prefixes: &[Vec<i32>]) -> Result<Vec<i32>> {
            Ok(prefixes.iter().map(|p| p.iter().sum::<i32>() % 97).collect())
        }
        fn dvfs_transitions(&self) -> usize {
            2
        }
    }

    fn start(batch: usize) -> Coordinator {
        Coordinator::start(
            BatcherConfig { batch_size: batch, timeout: Duration::from_millis(2) },
            move || Ok(Box::new(Echo { cap: batch }) as Box<dyn BatchExecutor>),
        )
    }

    #[test]
    fn every_request_answered_exactly_once() {
        let c = start(4);
        let mut rxs = Vec::new();
        let mut want = Vec::new();
        let mut rng = Rng::seed_from_u64(1);
        for i in 0..97 {
            let tokens: Vec<i32> =
                (0..1 + rng.gen_usize(10)).map(|_| rng.gen_usize(50) as i32).collect();
            want.push((i as u64, tokens.iter().sum::<i32>() % 97));
            rxs.push(c.submit(tokens));
        }
        for (rx, (id, tok)) in rxs.into_iter().zip(want) {
            let resp = rx.recv_timeout(Duration::from_secs(5)).unwrap();
            assert_eq!(resp.id, id);
            assert_eq!(resp.next_token, tok);
            // one response only
            assert!(rx.recv_timeout(Duration::from_millis(1)).is_err());
        }
        let m = &c.metrics;
        assert_eq!(m.requests.load(Ordering::Relaxed), 97);
        assert_eq!(m.responses.load(Ordering::Relaxed), 97);
        c.shutdown().unwrap();
    }

    #[test]
    fn batching_actually_batches() {
        let c = start(8);
        let rxs: Vec<_> = (0..64).map(|i| c.submit(vec![i])).collect();
        for rx in rxs {
            rx.recv_timeout(Duration::from_secs(5)).unwrap();
        }
        let batches = c.metrics.batches.load(Ordering::Relaxed);
        assert!(batches < 64, "no batching happened: {batches}");
        assert!(c.metrics.mean_batch_occupancy() > 1.1);
        c.shutdown().unwrap();
    }

    #[test]
    fn dvfs_transitions_accounted_per_batch() {
        let c = start(4);
        let rxs: Vec<_> = (0..8).map(|i| c.submit(vec![i])).collect();
        for rx in rxs {
            rx.recv().unwrap();
        }
        let b = c.metrics.batches.load(Ordering::Relaxed);
        assert_eq!(c.metrics.dvfs_transitions.load(Ordering::Relaxed), 2 * b);
        c.shutdown().unwrap();
    }

    #[test]
    fn shutdown_drains_cleanly() {
        let c = start(2);
        let rx = c.submit(vec![1, 2, 3]);
        c.shutdown().unwrap();
        assert_eq!(rx.recv().unwrap().next_token, 6);
    }
}
