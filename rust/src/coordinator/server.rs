//! The coordinator: router → per-shard continuous-batching decode loops.
//!
//! PR 3 scaled the serving path to **N sharded executor threads**; PR 5
//! replaces each shard's batch-at-a-time decode with **KV-cached
//! continuous batching**. Each shard owns a bounded request queue, a
//! [`Batcher`], and a [`BatchExecutor`] constructed *inside* the shard
//! thread via a factory closure (PJRT handles are not `Send`). The router
//! round-robins across shards but steals toward the least-loaded queue;
//! admission control rejects new work when every queue is at capacity,
//! and requests whose deadline expired while queued are shed before
//! execution instead of burning executor time.
//!
//! The shard loop keeps a *live set* of heterogeneous-length
//! [`DecodeState`]s: every iteration admits queued requests into free
//! batch slots ([`Batcher::try_fill`] — joins happen mid-flight, no
//! request waits for the current batch to drain), advances every live
//! request by exactly one token ([`BatchExecutor::step`]), and retires
//! finished requests immediately. There is no longest-prefix padding:
//! with a KV cache each step evaluates only each request's uncached
//! window suffix, and without one each request recomputes its *own*
//! window, never its neighbors'.
//!
//! The executor is abstracted behind [`BatchExecutor`] so the
//! routing/batching/shedding invariants are testable without a model; the
//! production executors ([`GraphExecutor`], [`QuantExecutor`]) own the
//! loaded graph / packed tiles and override [`BatchExecutor::step`] with
//! the KV-cached incremental path (`--no-kv-cache` falls back to the
//! full-recompute oracle).
//!
//! **Concurrency & panic-safety (PR 6).** Requests travel through the
//! shim-backed bounded [`RequestQueue`] (admission check and enqueue are
//! one atomic operation — no reserve-then-send window), every sync
//! primitive here comes from [`crate::util::sync`] (model-checked in
//! `tests/loom_coordinator.rs`, lint-enforced by `halo-lint`), and
//! executor calls are unwind-fenced: a *panicking* executor kills only its
//! own shard. No panic propagates into a client-visible hang, and
//! shard-held locks are never poisoned across the serving path (see
//! DESIGN.md §Concurrency model).
//!
//! **Supervised recovery (PR 7).** Shard death is no longer terminal:
//! each shard thread is a *supervisor* that runs executor "generations".
//! When a generation dies (panicking executor, failed construction, or an
//! injected `util::failpoint` fault), the supervisor re-homes the orphaned
//! live set onto surviving shards (or back onto its own queue for the
//! respawned replacement), sleeps a capped exponential backoff with
//! seeded jitter, and respawns through its factory. Retries are bounded
//! twice over — per request ([`SupervisorConfig::max_request_attempts`])
//! and globally ([`SupervisorConfig::retry_budget`], a shared token pool
//! that prevents retry storms) — and a retried request restarts decode
//! from its *original prefix*, so greedy chains stay bit-identical to an
//! unfaulted run. Requests that exhaust their retries are shed with
//! [`ShedReason::RetryExhausted`] — never silently dropped. Sustained
//! overload or repeated death raises the [brown-out](SupervisorConfig)
//! level, which clamps `max_new_tokens` and sheds negative-priority work
//! at admission before anything else is sacrificed. The whole layer is
//! pinned by the chaos soak suite (`tests/chaos.rs`).

use std::collections::BTreeMap;
use std::fmt;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use super::batch::{Batcher, BatcherConfig};
use super::metrics::{Metrics, ShedReason};
use super::queue::{PushError, RequestQueue};
use crate::util::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use crate::util::sync::{Arc, Mutex};
use crate::dvfs::Schedule;
use crate::quant::Matrix;
use crate::runtime::sim::ModelSpec;
use super::metrics::SpecDecodeStats;
use crate::runtime::{
    argmax_slice, literal_i32, BlockPool, Buffer, DecodeState, KvCache, ModelArtifacts,
    PackedModel, PoolExhausted, PoolStats, Runtime, Sampler, SamplingParams,
};
use crate::util::failpoint::{self, sites};
use crate::util::{parallel, Rng};

/// One serving request, built fluently and handed to
/// [`Coordinator::submit`] (fallible) or [`Coordinator::submit_or_shed`]
/// (infallible). PR 8 collapsed the accreted `submit` / `submit_spec` /
/// `try_submit_spec` surface into this single builder:
///
/// ```ignore
/// let rx = coord.submit(
///     Request::new(tokens).max_new(16).deadline(Duration::from_millis(50)).priority(1),
/// )?;
/// ```
#[derive(Debug, Clone)]
pub struct Request {
    tokens: Vec<i32>,
    max_new: usize,
    deadline: Option<Instant>,
    priority: i8,
    sampling: Option<SamplingParams>,
}

impl Request {
    /// A request for the classic next-token serving default: decode
    /// exactly one token, no deadline, priority 0, greedy argmax decode.
    pub fn new(tokens: Vec<i32>) -> Self {
        Self { tokens, max_new: 1, deadline: None, priority: 0, sampling: None }
    }

    /// Decode `n` tokens autoregressively (clamped to ≥ 1).
    pub fn max_new(mut self, n: usize) -> Self {
        self.max_new = n.max(1);
        self
    }

    /// Attach a relative shed deadline (from now): if it passes while the
    /// request is queued, the request sheds instead of executing.
    pub fn deadline(mut self, d: Duration) -> Self {
        self.deadline = Some(Instant::now() + d);
        self
    }

    /// Attach an absolute shed deadline.
    pub fn deadline_at(mut self, at: Instant) -> Self {
        self.deadline = Some(at);
        self
    }

    /// Scheduling priority (default 0); under brown-out level ≥ 2,
    /// negative-priority requests are shed at admission first.
    pub fn priority(mut self, p: i8) -> Self {
        self.priority = p;
        self
    }

    /// Seeded sampled decode (PR 9): temperature / top-k over
    /// f64-softmaxed logits, one RNG draw per emitted token. The default
    /// (no params) is greedy argmax. A retried request restarts its RNG
    /// stream from the seed along with its prefix, so sampled chains are
    /// as reproducible across faults and shard counts as greedy ones.
    /// Sampling applies on the incremental decode paths; the
    /// `--no-kv-cache` recompute oracle stays argmax.
    pub fn sampling(mut self, params: SamplingParams) -> Self {
        self.sampling = Some(params);
        self
    }

    /// The prompt prefix (callers getting the request back in a
    /// [`SubmitError`] can inspect or resubmit it).
    pub fn tokens(&self) -> &[i32] {
        &self.tokens
    }
}

/// [`Coordinator::submit`] refusal: every shard queue is closed — the
/// coordinator will never serve new work again (total executor loss, or
/// shutdown has begun). Carries the [`Request`] back *untouched* (no
/// metrics recorded, nothing queued) so the caller can stop submitting —
/// load generators use this to avoid minting phantom shed responses — or
/// route it elsewhere.
#[derive(Debug)]
pub struct SubmitError(pub Request);

impl fmt::Display for SubmitError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "coordinator accepts no new work: every shard queue is closed")
    }
}

impl std::error::Error for SubmitError {}

/// An admitted request inside the coordinator: the caller's [`Request`]
/// plus routing metadata (id, response channel, retry accounting).
#[derive(Debug)]
struct QueuedRequest {
    /// Coordinator-assigned id, echoed in the response.
    id: u64,
    /// The prompt prefix.
    tokens: Vec<i32>,
    /// How many tokens to decode (post brown-out clamping).
    max_new_tokens: usize,
    /// Absolute shed deadline: if it passes while the request is queued,
    /// the executor sheds it (empty `tokens`, `shed = true`) instead of
    /// running it.
    deadline: Option<Instant>,
    /// Where the (single) response is delivered.
    respond: Sender<Response>,
    /// Submission time (latency measurement).
    submitted: Instant,
    /// Scheduling priority; under brown-out level ≥ 2 negative-priority
    /// requests are shed at admission before anything else.
    priority: i8,
    /// Seeded sampling params; `None` decodes greedy. Carried through
    /// re-homing so a retried request replays the same RNG stream.
    sampling: Option<SamplingParams>,
    /// Times this request has been re-enqueued after a fault (0 = first
    /// execution). Bounded by [`SupervisorConfig::max_request_attempts`].
    attempts: u32,
}

/// What the caller's channel yields for one [`Request`].
#[derive(Debug, Clone)]
pub struct Response {
    /// The request's coordinator-assigned id.
    pub id: u64,
    /// First generated token (back-compat with next-token serving); 0 when
    /// shed.
    pub next_token: i32,
    /// All generated tokens, in order (empty when shed).
    pub tokens: Vec<i32>,
    /// Submit-to-respond latency.
    pub latency: Duration,
    /// Which shard executed (or shed) the request.
    pub shard: usize,
    /// True when the request was dropped by deadline shedding or admission
    /// control instead of executed.
    pub shed: bool,
    /// Why the request was shed; `None` on every served response.
    pub reason: Option<ShedReason>,
}

/// What the executor thread runs: per-request [`DecodeState`]s in, one
/// generated token per live request per [`step`](BatchExecutor::step).
///
/// Implementors must provide the single-shot [`run`](BatchExecutor::run)
/// (full-prefix next-token, the recompute oracle); everything else has
/// provided defaults built on it. Executors with a fast path override
/// [`begin`](BatchExecutor::begin) (attach a KV cache) and
/// [`step`](BatchExecutor::step) (evaluate only each request's uncached
/// window suffix) — see [`QuantExecutor`] / [`GraphExecutor`].
pub trait BatchExecutor {
    /// Max sequences per executed batch (the AOT graph's B).
    fn batch_capacity(&self) -> usize;
    /// The model's context window (decode states slide at this length).
    fn seq_len(&self) -> usize;
    /// `prefixes` has ≤ batch_capacity entries, each ≤ seq_len tokens.
    fn run(&mut self, prefixes: &[Vec<i32>]) -> Result<Vec<i32>>;
    /// Simulated DVFS transitions for one pass (schedule metadata).
    fn dvfs_transitions(&self) -> usize {
        0
    }

    /// Paged KV block-pool statistics for this shard, when the executor
    /// serves from a shared [`BlockPool`] (attached via `with_kv_pool`).
    /// `None` for executors without a pool; the shard loop publishes a
    /// `Some` snapshot into the shard's metrics gauges after every step.
    fn kv_pool_stats(&self) -> Option<PoolStats> {
        None
    }

    /// Speculative-decode work counters (monotone totals), when this
    /// executor runs a drafter/verifier pipeline
    /// ([`super::spec::SpecExecutor`]). `None` for plain executors; the
    /// shard loop publishes a `Some` snapshot into the shard's metrics
    /// gauges after every step (same pattern as
    /// [`kv_pool_stats`](Self::kv_pool_stats)).
    fn spec_stats(&self) -> Option<SpecDecodeStats> {
        None
    }

    /// Admit one request: build its [`DecodeState`] (window = the
    /// `seq_len` newest prefix tokens). Cache-capable executors override
    /// this to attach a per-request KV cache.
    fn begin(&mut self, prefix: &[i32], max_new: usize) -> Result<DecodeState> {
        Ok(DecodeState::new(prefix, max_new, self.seq_len()))
    }

    /// Advance every state by exactly one token. The default recomputes
    /// each request's own window via [`run`](Self::run) (no KV cache, no
    /// cross-request padding); overrides run the cached incremental path.
    fn step(&mut self, states: &mut [&mut DecodeState]) -> Result<()> {
        self.step_recompute(states)
    }

    /// The full-recompute step (the equivalence oracle): one
    /// [`run`](Self::run) over the live windows, one argmax-token pushed
    /// per state. Cache-capable executors fall back to this under
    /// `--no-kv-cache` and for states without a cache.
    fn step_recompute(&mut self, states: &mut [&mut DecodeState]) -> Result<()> {
        if states.is_empty() {
            return Ok(());
        }
        let windows: Vec<Vec<i32>> = states.iter().map(|s| s.window().to_vec()).collect();
        let next = self.run(&windows)?;
        anyhow::ensure!(next.len() == states.len(), "executor returned wrong batch size");
        for (s, &tok) in states.iter_mut().zip(&next) {
            s.push_token(tok);
        }
        Ok(())
    }

    /// Autoregressive decode over a fixed request set: [`begin`] every
    /// prefix, then [`step`] the unfinished states until each request
    /// `i` has `max_new[i]` generated tokens. Sequences at the model's
    /// context window slide (drop-front) so every generated token
    /// conditions on the `seq_len` most recent tokens; finished sequences
    /// drop out of later steps. Returns the generated tokens per request.
    /// (The serving shard loop uses [`begin`]/[`step`] directly so
    /// requests can also *join* mid-flight — continuous batching.)
    ///
    /// [`begin`]: BatchExecutor::begin
    /// [`step`]: BatchExecutor::step
    fn generate(&mut self, prefixes: &[Vec<i32>], max_new: &[usize]) -> Result<Vec<Vec<i32>>> {
        anyhow::ensure!(prefixes.len() == max_new.len(), "prefixes/max_new length mismatch");
        let mut states = Vec::with_capacity(prefixes.len());
        for (p, &m) in prefixes.iter().zip(max_new) {
            states.push(self.begin(p, m)?);
        }
        loop {
            let mut active: Vec<&mut DecodeState> =
                states.iter_mut().filter(|s| !s.done()).collect();
            if active.is_empty() {
                break;
            }
            let before: usize = active.iter().map(|s| s.generated().len()).sum();
            self.step(&mut active)?;
            let after: usize = active.iter().map(|s| s.generated().len()).sum();
            // A step that generates nothing would loop forever — make a
            // broken executor a hard error instead.
            anyhow::ensure!(after > before, "executor step made no decode progress");
        }
        Ok(states.into_iter().map(DecodeState::into_generated).collect())
    }
}

/// Production executor: fwd graph + (quantized) parameter buffers, on
/// whichever runtime backend is active (sim or PJRT).
///
/// On backends whose fwd graphs support incremental decode (the sim
/// interpreter), [`BatchExecutor::step`] routes each live request through
/// `Executable::run_decode_step` with the request's own KV cache —
/// evaluating only the uncached window suffix. PJRT (fixed-shape graphs)
/// and [`GraphExecutor::with_kv_cache`]`(false)` fall back to the
/// full-recompute oracle path.
pub struct GraphExecutor {
    rt: Runtime,
    exe: crate::runtime::Executable,
    /// Parameters resident on device across batches (§Perf L3).
    params: Vec<Buffer>,
    batch: usize,
    seq: usize,
    vocab: usize,
    schedule: Schedule,
    /// Sim backend accepts any leading batch dim, so partial batches pad
    /// only to their own size; PJRT compiled a static (B, S).
    dynamic_batch: bool,
    /// KV-cached decode enabled (`--no-kv-cache` clears it).
    use_kv: bool,
    /// `(n_layers, d_model)` for sizing per-request KV caches; `None`
    /// when the model config is unavailable (decode then recomputes).
    kv_dims: Option<(usize, usize)>,
    /// Shared paged block pool for this shard (PR 8). When attached,
    /// `begin` carves per-request caches from it (bounded memory +
    /// shared-prefix reuse); otherwise each request gets a private
    /// unbounded pool.
    kv_pool: Option<Arc<BlockPool>>,
}

impl GraphExecutor {
    /// Build inside the executor thread. `replace` substitutes quantized
    /// linear weights; `schedule` is this executor's DVFS class schedule
    /// (a whole-model schedule, or one shard of [`Schedule::shard`]).
    pub fn new(
        rt: Runtime,
        model: &ModelArtifacts,
        replace: &BTreeMap<String, Matrix>,
        schedule: Schedule,
    ) -> Result<Self> {
        let exe = rt.load(&model.graph_path("fwd_fp"))?;
        let params = rt.upload_all(&model.param_literals(replace)?)?;
        let dynamic_batch = rt.dynamic_batch();
        // Cache dimensions come from the model spec; a model without a
        // readable spec still serves, but on the recompute path — say so
        // instead of silently degrading to O(S²)-per-token decode.
        let kv_dims = match ModelSpec::load(&model.dir) {
            Ok(s) => Some((s.n_layers, s.d_model)),
            Err(e) => {
                eprintln!(
                    "[executor] KV-cached decode disabled for {}: cannot read model spec: {e:#}",
                    model.name
                );
                None
            }
        };
        Ok(Self {
            rt,
            exe,
            params,
            batch: model.eval_batch,
            seq: model.seq_len,
            vocab: model.vocab,
            schedule,
            dynamic_batch,
            use_kv: true,
            kv_dims,
            kv_pool: None,
        })
    }

    /// Toggle KV-cached incremental decode (on by default where the
    /// backend supports it); off = every step recomputes the full window
    /// (the `--no-kv-cache` debugging oracle).
    pub fn with_kv_cache(mut self, on: bool) -> Self {
        self.use_kv = on;
        self
    }

    /// Serve per-request caches from a shared paged [`BlockPool`]. The
    /// pool must be shaped for this model (`n_layers`, `d_model`); a
    /// mismatched pool surfaces as an append-shape error on the first
    /// decode step, not silence. Create the pool *outside* the executor
    /// factory so its shared-prefix registry survives shard respawns.
    pub fn with_kv_pool(mut self, pool: Arc<BlockPool>) -> Self {
        self.kv_pool = Some(pool);
        self
    }
}

/// Native quantized executor (PR 4): decode runs directly on the packed
/// codebook tiles of a [`PackedModel`] — integer W4A8 tile kernels +
/// fused SpMV — so no dense f32 weight matrix is ever materialized for a
/// quantized layer. Always dynamic-batch (the packed forward reads `b` from its
/// inputs), so partial batches only pay for the rows they carry.
///
/// PR 5: [`BatchExecutor::step`] runs KV-cached incremental decode
/// ([`PackedModel::forward_incremental`]) — each live request evaluates
/// only its uncached window suffix, bit-identical to the full-prefix
/// recompute (pinned by `tests/decode_equiv.rs`).
/// [`QuantExecutor::with_kv_cache`]`(false)` restores the oracle path.
pub struct QuantExecutor {
    model: Arc<PackedModel>,
    batch: usize,
    schedule: Schedule,
    use_kv: bool,
    /// Shared paged block pool for this shard (PR 8); see
    /// [`GraphExecutor::with_kv_pool`].
    kv_pool: Option<Arc<BlockPool>>,
    work_positions: u64,
}

impl QuantExecutor {
    /// Executor over a shared packed model, using the model's own
    /// whole-model DVFS schedule.
    pub fn new(model: Arc<PackedModel>, batch: usize) -> Self {
        let schedule = model.schedule.clone();
        Self::with_schedule(model, batch, schedule)
    }

    /// Executor with an explicit schedule slice (one shard of
    /// [`Schedule::shard`] under sharded serving).
    pub fn with_schedule(model: Arc<PackedModel>, batch: usize, schedule: Schedule) -> Self {
        Self { model, batch: batch.max(1), schedule, use_kv: true, kv_pool: None, work_positions: 0 }
    }

    /// Toggle KV-cached incremental decode (on by default); off = every
    /// step recomputes the full window (the `--no-kv-cache` oracle).
    pub fn with_kv_cache(mut self, on: bool) -> Self {
        self.use_kv = on;
        self
    }

    /// Serve per-request caches from a shared paged [`BlockPool`]; see
    /// [`GraphExecutor::with_kv_pool`] for shaping and lifetime rules.
    pub fn with_kv_pool(mut self, pool: Arc<BlockPool>) -> Self {
        self.kv_pool = Some(pool);
        self
    }

    /// Token positions evaluated through the layer stack so far — the
    /// MAC-work proxy (each position pays the same per-layer GEMMs; the
    /// padded pre-PR-5 decode paid `batch × longest-prefix` positions per
    /// step, the continuous-batching path pays exactly the uncached
    /// suffix). `tests/decode_equiv.rs` pins ragged-batch work to within
    /// 1.1× of the per-request ideal with this counter.
    pub fn work_positions(&self) -> u64 {
        self.work_positions
    }
}

impl BatchExecutor for QuantExecutor {
    fn batch_capacity(&self) -> usize {
        self.batch
    }

    fn seq_len(&self) -> usize {
        self.model.spec.seq_len
    }

    fn run(&mut self, prefixes: &[Vec<i32>]) -> Result<Vec<i32>> {
        anyhow::ensure!(prefixes.len() <= self.batch, "over-full batch");
        anyhow::ensure!(!prefixes.is_empty(), "empty batch");
        let b = prefixes.len();
        // Right-pad only to the batch's longest live prefix (capped at the
        // context window) — the packed forward accepts any s ≤ seq_len,
        // and causal attention + from-zero positions make every live
        // row's logits bit-identical to the full-S pass, so short decode
        // batches don't pay for dead positions. Prefixes beyond the
        // window keep their newest tokens (same contract as
        // GraphExecutor::run).
        let cap = self.model.spec.seq_len;
        let s = prefixes.iter().map(|p| p.len().min(cap)).max().unwrap_or(1).max(1);
        let mut tokens = vec![0i32; b * s];
        for (i, p) in prefixes.iter().enumerate() {
            let n = p.len().min(s);
            tokens[i * s..i * s + n].copy_from_slice(&p[p.len() - n..]);
        }
        self.work_positions += (b * s) as u64;
        let logits = self.model.forward(&tokens, b, s)?;
        let vocab = self.model.spec.vocab;
        prefixes
            .iter()
            .enumerate()
            .map(|(i, p)| {
                let pos = p.len().clamp(1, s) - 1;
                let row = logits.row(i * s + pos);
                anyhow::ensure!(row.len() == vocab, "logit row width mismatch");
                Ok(argmax_slice(row) as i32)
            })
            .collect()
    }

    fn dvfs_transitions(&self) -> usize {
        self.schedule.transitions()
    }

    /// KV states by default; plain recompute states under `--no-kv-cache`.
    /// With a shard pool attached, the request's cache is carved from the
    /// pool — block acquisition is deferred to the first append, but
    /// shared-prefix seeding happens here (the pool may hand back a chain
    /// of frozen blocks covering the window's common header).
    fn begin(&mut self, prefix: &[i32], max_new: usize) -> Result<DecodeState> {
        let cap = self.model.spec.seq_len;
        Ok(if self.use_kv {
            let cache = match &self.kv_pool {
                Some(pool) => pool.new_cache(&prefix[prefix.len().saturating_sub(cap)..]),
                None => self.model.new_cache(),
            };
            DecodeState::with_cache(prefix, max_new, cap, cache)
        } else {
            DecodeState::new(prefix, max_new, cap)
        })
    }

    fn kv_pool_stats(&self) -> Option<PoolStats> {
        self.kv_pool.as_ref().map(|p| p.stats())
    }

    /// Incremental decode: each live request evaluates only its uncached
    /// window suffix (one token per step after prefill; the whole window
    /// again after a slide cleared the cache) — no cross-request padding.
    /// Requests are independent (each owns its cache; the packed model is
    /// shared immutably), so the live set fans out over the worker pool —
    /// single-token inner GEMMs sit below the kernels' parallel
    /// threshold, so threads go to requests, not rows.
    fn step(&mut self, states: &mut [&mut DecodeState]) -> Result<()> {
        if !self.use_kv || states.iter().any(|s| !s.has_cache()) {
            return self.step_recompute(states);
        }
        // Work accounting up front (the fan-out below cannot touch self):
        // the uncached suffix per state, or the 1-row scratch pass for an
        // empty window.
        for s in states.iter() {
            let w = s.window().len();
            self.work_positions += w.saturating_sub(s.cached_rows()).max(1) as u64;
        }
        let model: &PackedModel = &self.model;
        let first_err = Mutex::new(None);
        parallel::par_chunks_mut(states, 1, |_, chunk| {
            let s = &mut *chunk[0];
            if let Err(e) = step_one_packed(model, s) {
                // First error wins; poisoning is absorbed (a panicked
                // sibling worker must not turn a reportable decode error
                // into a shard-killing panic here).
                let mut slot = first_err.lock().unwrap_or_else(|p| p.into_inner());
                if slot.is_none() {
                    *slot = Some(e);
                }
            }
        });
        match first_err.into_inner().unwrap_or_else(|p| p.into_inner()) {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }
}

/// One KV-cached decode step for one request on the packed model:
/// evaluate the uncached window suffix through
/// [`PackedModel::forward_incremental`], select from the last logits row
/// (seeded sampler when the request carries one, argmax otherwise), and
/// record the token. Empty windows mirror `run()`'s all-padding row
/// (token 0 at position 0) via a 1-token scratch pass — bit-identical to
/// the padded batch by row-locality — without touching the request's
/// cache (the window gains its first real token from the push).
fn step_one_packed(model: &PackedModel, s: &mut DecodeState) -> Result<()> {
    let next = if s.window().is_empty() {
        let logits = model.forward(&[0], 1, 1)?;
        select_token(s, logits.row(0))
    } else {
        let (new, cached) = s.uncached_suffix()?;
        let Some(cache) = s.cache_mut() else {
            anyhow::bail!("decode state lost its KV cache mid-step");
        };
        let logits = model.forward_incremental(&new, cached, cache)?;
        anyhow::ensure!(logits.cols == model.spec.vocab, "logit row width mismatch");
        select_token(s, logits.row(logits.rows - 1))
    };
    s.push_token(next);
    Ok(())
}

/// Select the next token from one row of logits: the request's seeded
/// sampler when present ([`Request::sampling`]), argmax otherwise.
/// Exactly one RNG draw per emitted token when sampling — the invariant
/// that keeps speculative and verifier-only sampled chains identical
/// (see `runtime::sample`).
pub(crate) fn select_token(s: &mut DecodeState, row: &[f32]) -> i32 {
    match s.sampler_mut() {
        Some(smp) => smp.select(row) as i32,
        None => argmax_slice(row) as i32,
    }
}

impl BatchExecutor for GraphExecutor {
    fn batch_capacity(&self) -> usize {
        self.batch
    }

    fn seq_len(&self) -> usize {
        self.seq
    }

    fn run(&mut self, prefixes: &[Vec<i32>]) -> Result<Vec<i32>> {
        anyhow::ensure!(prefixes.len() <= self.batch, "over-full batch");
        anyhow::ensure!(!prefixes.is_empty(), "empty batch");
        // Pad to the static (B, S) shape; causality makes right-padding
        // safe. The sim backend reads B from the literal, so partial
        // batches only pay for the rows they actually carry. Prefixes
        // longer than the context window keep their LAST seq tokens — the
        // newest context is what the next token must condition on.
        let b = if self.dynamic_batch { prefixes.len() } else { self.batch };
        let mut tokens = vec![0i32; b * self.seq];
        for (i, p) in prefixes.iter().enumerate() {
            let n = p.len().min(self.seq);
            tokens[i * self.seq..i * self.seq + n].copy_from_slice(&p[p.len() - n..]);
        }
        let tok_buf = self.rt.upload(&literal_i32(&tokens, &[b, self.seq])?)?;
        let mut inputs: Vec<&Buffer> = self.params.iter().collect();
        inputs.push(&tok_buf);
        let logits = self.exe.run_b1(&inputs)?;
        // logits: (b, S, vocab); read the argmax at each prefix's last pos.
        prefixes
            .iter()
            .enumerate()
            .map(|(i, p)| {
                // Empty prefixes read position 0 (all-padding row) instead
                // of underflowing.
                let pos = p.len().clamp(1, self.seq) - 1;
                logits.argmax_span((i * self.seq + pos) * self.vocab, self.vocab)
            })
            .collect()
    }

    fn dvfs_transitions(&self) -> usize {
        self.schedule.transitions()
    }

    /// KV states when the loaded graph supports incremental decode (sim
    /// backend); plain recompute states otherwise (PJRT, `--no-kv-cache`).
    /// With a shard pool attached, caches come from the pool (bounded
    /// blocks + shared-prefix seeding) instead of private allocations.
    fn begin(&mut self, prefix: &[i32], max_new: usize) -> Result<DecodeState> {
        Ok(match self.kv_dims {
            Some((layers, d)) if self.use_kv && self.exe.supports_incremental_decode() => {
                let cache = match &self.kv_pool {
                    Some(pool) => {
                        pool.new_cache(&prefix[prefix.len().saturating_sub(self.seq)..])
                    }
                    None => KvCache::new(layers, d),
                };
                DecodeState::with_cache(prefix, max_new, self.seq, cache)
            }
            _ => DecodeState::new(prefix, max_new, self.seq),
        })
    }

    fn kv_pool_stats(&self) -> Option<PoolStats> {
        self.kv_pool.as_ref().map(|p| p.stats())
    }

    /// Incremental decode through `Executable::run_decode_step`: each
    /// live request evaluates only its uncached window suffix against its
    /// resident parameter buffers. Serial over the live set — backend
    /// executables are not required to be thread-safe (PJRT handles are
    /// pinned to their thread), unlike the packed executor's fan-out.
    fn step(&mut self, states: &mut [&mut DecodeState]) -> Result<()> {
        if !self.use_kv
            || !self.exe.supports_incremental_decode()
            || states.iter().any(|s| !s.has_cache())
        {
            return self.step_recompute(states);
        }
        let (layers, d) = self.kv_dims.unwrap_or((0, 0));
        let params: Vec<&Buffer> = self.params.iter().collect();
        for s in states.iter_mut() {
            let (logits, pos) = if s.window().is_empty() {
                // Degenerate empty prefix: mirror run()'s all-padding row
                // (token 0 at position 0) against a scratch cache.
                let mut scratch = KvCache::new(layers, d);
                (self.exe.run_decode_step(&params, &[0], 0, &mut scratch)?, 0)
            } else {
                let (new, cached) = s.uncached_suffix()?;
                let n = new.len();
                let Some(cache) = s.cache_mut() else {
                    anyhow::bail!("decode state lost its KV cache mid-step");
                };
                (self.exe.run_decode_step(&params, &new, cached, cache)?, n - 1)
            };
            let next = if s.sampler_mut().is_some() {
                let data = logits.as_f32()?;
                let base = pos * self.vocab;
                anyhow::ensure!(base + self.vocab <= data.len(), "logit row out of range");
                select_token(s, &data[base..base + self.vocab])
            } else {
                logits.argmax_span(pos * self.vocab, self.vocab)?
            };
            s.push_token(next);
        }
        Ok(())
    }
}

/// Default cap on consecutive fruitless respawns before a shard is
/// declared permanently dead (the supervisor's restart budget).
pub const MAX_SHARD_RESTARTS: u32 = 3;
/// Default cap on per-request re-enqueues after faults.
pub const MAX_REQUEST_ATTEMPTS: u32 = 3;
/// Default global retry budget: total re-enqueues across all shards for
/// the coordinator's lifetime (a retry-storm circuit breaker).
pub const RETRY_BUDGET: u64 = 10_000;

/// Supervisor policy: restart/retry budgets, backoff shape, and the
/// brown-out degradation thresholds. Lives in [`CoordinatorConfig`].
#[derive(Debug, Clone)]
pub struct SupervisorConfig {
    /// Consecutive fruitless deaths (no response served since the last
    /// respawn) before a shard is permanently dead ([`MAX_SHARD_RESTARTS`]).
    pub max_shard_restarts: u32,
    /// Re-enqueues allowed per request before it is shed with
    /// [`ShedReason::RetryExhausted`] ([`MAX_REQUEST_ATTEMPTS`]).
    pub max_request_attempts: u32,
    /// Global retry token pool shared by every shard ([`RETRY_BUDGET`]);
    /// once drained, faulted requests are shed instead of re-enqueued.
    pub retry_budget: u64,
    /// First respawn backoff; doubles per consecutive death.
    pub backoff_base: Duration,
    /// Backoff ceiling (exponential growth is clamped here).
    pub backoff_cap: Duration,
    /// Overload events (admission rejections, shard deaths) that raise
    /// the brown-out level by one; successful admissions decay pressure.
    pub brownout_pressure: u32,
    /// Maximum brown-out level. Level `L ≥ 1` clamps `max_new_tokens` to
    /// `max_new >> L`; level ≥ 2 sheds negative-priority requests at
    /// admission. `0` disables brown-out entirely.
    pub brownout_max_level: u32,
}

impl Default for SupervisorConfig {
    fn default() -> Self {
        Self {
            max_shard_restarts: MAX_SHARD_RESTARTS,
            max_request_attempts: MAX_REQUEST_ATTEMPTS,
            retry_budget: RETRY_BUDGET,
            backoff_base: Duration::from_millis(5),
            backoff_cap: Duration::from_millis(80),
            brownout_pressure: 8,
            brownout_max_level: 3,
        }
    }
}

/// Coordinator-level brown-out state: an overload-pressure accumulator
/// with hysteresis. Raising events are admission rejections and shard
/// deaths; successful admissions bleed pressure off. Level transitions
/// (both directions) are counted in `Metrics::brownout_steps`.
struct Brownout {
    /// `(level, pressure)` under one small lock (events only — not on the
    /// decode hot path).
    state: Mutex<(u32, u32)>,
    pressure_high: u32,
    max_level: u32,
}

impl Brownout {
    fn new(cfg: &SupervisorConfig) -> Self {
        Self {
            state: Mutex::new((0, 0)),
            pressure_high: cfg.brownout_pressure.max(1),
            max_level: cfg.brownout_max_level,
        }
    }

    /// Current degradation level (0 = healthy).
    fn level(&self) -> u32 {
        self.state.lock().unwrap_or_else(|e| e.into_inner()).0
    }

    /// One overload event; may step the level up.
    fn overload(&self, global: &Metrics) {
        let mut st = self.state.lock().unwrap_or_else(|e| e.into_inner());
        st.1 = st.1.saturating_add(1);
        if st.1 >= self.pressure_high && st.0 < self.max_level {
            st.0 += 1;
            st.1 = 0;
            global.brownout_steps.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// One healthy-admission event; may step the level down (with
    /// half-threshold hysteresis so the level doesn't flap).
    fn relief(&self, global: &Metrics) {
        let mut st = self.state.lock().unwrap_or_else(|e| e.into_inner());
        if st.1 > 0 {
            st.1 -= 1;
        } else if st.0 > 0 {
            st.0 -= 1;
            st.1 = self.pressure_high / 2;
            global.brownout_steps.fetch_add(1, Ordering::Relaxed);
        }
    }
}

/// Coordinator-wide configuration: per-shard batching plus routing,
/// admission-control, and supervisor/recovery knobs.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// Per-shard batch-forming knobs.
    pub batcher: BatcherConfig,
    /// Executor shards (threads). Each owns its own queue + executor.
    pub shards: usize,
    /// Per-shard queue bound for admission control; 0 = unbounded.
    pub queue_cap: usize,
    /// Deadline applied to requests submitted without an explicit one.
    pub default_deadline: Option<Duration>,
    /// Shard-supervisor restart/retry/brown-out policy.
    pub supervisor: SupervisorConfig,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        Self {
            batcher: BatcherConfig::default(),
            shards: 1,
            queue_cap: 0,
            default_deadline: None,
            supervisor: SupervisorConfig::default(),
        }
    }
}

impl CoordinatorConfig {
    /// Default config with `shards` executor threads.
    pub fn sharded(shards: usize) -> Self {
        Self { shards: shards.max(1), ..Self::default() }
    }
}

/// One shard's router-visible state: its bounded queue, liveness flag and
/// per-shard metrics. Shared (`Arc<Vec<ShardSlot>>`) between the router
/// and every supervisor thread, so a dying shard can re-home its orphaned
/// requests onto the survivors' queues.
struct ShardSlot {
    /// Bounded request queue (admission control lives in the queue: a
    /// `push` atomically checks cap + closed under one lock). Stays open
    /// across respawns — only shutdown or permanent death closes it.
    queue: Arc<RequestQueue<QueuedRequest>>,
    /// Set while the shard's executor is down (dead or between respawns):
    /// the router prefers live shards and only queues here as a last
    /// resort (the backlog is drained by the respawn, or re-homed at
    /// permanent death).
    dead: AtomicBool,
    metrics: Arc<Metrics>,
}

/// The running coordinator.
pub struct Coordinator {
    slots: Arc<Vec<ShardSlot>>,
    handles: Vec<Option<JoinHandle<()>>>,
    cfg: CoordinatorConfig,
    rr: AtomicUsize,
    next_id: AtomicU64,
    brownout: Arc<Brownout>,
    /// Aggregate metrics across all shards (live counters; per-shard views
    /// via [`Coordinator::shard_metrics`]).
    pub metrics: Arc<Metrics>,
}

impl Coordinator {
    /// The one constructor (PR 8 deleted the single-shard special case):
    /// start `cfg.shards` executor threads. `make_executor(shard)` runs on
    /// each shard's own thread (PJRT handles never cross threads) — and
    /// runs *again* whenever that shard's supervisor respawns a dead
    /// executor, so it must hand out a fresh executor per call. Build
    /// anything that must survive respawns (e.g. a shard's KV
    /// [`BlockPool`]) *outside* the closure and move clones in.
    pub fn start<F>(cfg: CoordinatorConfig, make_executor: F) -> Self
    where
        F: Fn(usize) -> Result<Box<dyn BatchExecutor>> + Send + Sync + 'static,
    {
        let n = cfg.shards.max(1);
        let f = Arc::new(make_executor);
        let factories: Vec<ShardFactory> = (0..n)
            .map(|s| {
                let f = f.clone();
                Box::new(move || f(s)) as ShardFactory
            })
            .collect();
        Self::start_with(cfg, factories)
    }

    fn start_with(cfg: CoordinatorConfig, factories: Vec<ShardFactory>) -> Self {
        let metrics = Arc::new(Metrics::default());
        let brownout = Arc::new(Brownout::new(&cfg.supervisor));
        let retry_tokens = Arc::new(Mutex::new(cfg.supervisor.retry_budget));
        let slots: Arc<Vec<ShardSlot>> = Arc::new(
            (0..factories.len())
                .map(|_| ShardSlot {
                    queue: Arc::new(RequestQueue::bounded(cfg.queue_cap)),
                    dead: AtomicBool::new(false),
                    metrics: Arc::new(Metrics::default()),
                })
                .collect(),
        );
        let handles = factories
            .into_iter()
            .enumerate()
            .map(|(s, f)| {
                let ctx = ShardCtx {
                    shard_id: s,
                    sup: cfg.supervisor.clone(),
                    slots: slots.clone(),
                    retry_tokens: retry_tokens.clone(),
                    brownout: brownout.clone(),
                    global: metrics.clone(),
                };
                Some(spawn_shard(ctx, f, cfg.batcher.clone()))
            })
            .collect();
        Self {
            slots,
            handles,
            cfg,
            rr: AtomicUsize::new(0),
            next_id: AtomicU64::new(0),
            brownout,
            metrics,
        }
    }

    /// Number of executor shards (threads) this coordinator runs.
    pub fn n_shards(&self) -> usize {
        self.slots.len()
    }

    /// Per-shard metrics views (index = shard id).
    pub fn shard_metrics(&self) -> Vec<Arc<Metrics>> {
        self.slots.iter().map(|s| s.metrics.clone()).collect()
    }

    /// Current brown-out degradation level (0 = healthy; see
    /// [`SupervisorConfig`]).
    pub fn brownout_level(&self) -> u32 {
        self.brownout.level()
    }

    /// Aggregate snapshot: per-shard serving metrics merged (percentiles
    /// over the union of latency samples) plus the coordinator-side
    /// counters (arrivals, admission rejections, brown-out transitions,
    /// per-reason shed counts) that the global view records
    /// authoritatively.
    pub fn merged_snapshot(&self) -> super::metrics::MetricsSnapshot {
        let mut s = Metrics::merged(&self.shard_metrics());
        let g = self.metrics.snapshot();
        s.requests = g.requests;
        s.rejected = g.rejected;
        s.brownout_steps = g.brownout_steps;
        s.shed_reasons = g.shed_reasons;
        s
    }

    /// Infallible submit: a request the coordinator cannot accept still
    /// answers on the returned channel with a shed response — the thin
    /// wrapper over [`Coordinator::submit`] for callers that want one
    /// channel per request, no error handling.
    pub fn submit_or_shed(&self, req: Request) -> Receiver<Response> {
        match self.submit(req) {
            Ok(rx) => rx,
            Err(_) => {
                // Every queue is closed (total executor loss or shutdown):
                // account the arrival and answer with a terminal shed.
                let (rtx, rrx) = channel();
                let id = self.next_id.fetch_add(1, Ordering::Relaxed);
                self.metrics.requests.fetch_add(1, Ordering::Relaxed);
                self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                self.metrics
                    .shed_reason_counter(ShedReason::ShardDeath)
                    .fetch_add(1, Ordering::Relaxed);
                let _ = rtx.send(Response {
                    id,
                    next_token: 0,
                    tokens: Vec::new(),
                    latency: Duration::ZERO,
                    shard: usize::MAX,
                    shed: true,
                    reason: Some(ShedReason::ShardDeath),
                });
                rrx
            }
        }
    }

    /// Fallible submit: `Err` hands the [`Request`] back *untouched* (see
    /// [`SubmitError`]) when every shard queue is closed — the coordinator
    /// will never serve new work again (total executor loss, or shutdown
    /// has begun).
    ///
    /// `Ok` means the request was admitted *or* terminally answered on the
    /// returned channel (admission-control rejection, brown-out shed) —
    /// exactly one response either way.
    pub fn submit(&self, req: Request) -> Result<Receiver<Response>, SubmitError> {
        let (rtx, rrx) = channel();
        let level = self.brownout.level();
        // Brown-out level ≥ 2: negative-priority work is shed at admission
        // before it can displace foreground requests.
        if level >= 2 && req.priority < 0 {
            let id = self.next_id.fetch_add(1, Ordering::Relaxed);
            self.metrics.requests.fetch_add(1, Ordering::Relaxed);
            self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
            self.metrics
                .shed_reason_counter(ShedReason::Brownout)
                .fetch_add(1, Ordering::Relaxed);
            let _ = rtx.send(Response {
                id,
                next_token: 0,
                tokens: Vec::new(),
                latency: Duration::ZERO,
                shard: usize::MAX,
                shed: true,
                reason: Some(ShedReason::Brownout),
            });
            return Ok(rrx);
        }
        // Brown-out level ≥ 1: clamp decode budgets (halved per level) so
        // the backlog drains sooner; the clamp never goes below one token.
        let requested_new = req.max_new.max(1);
        let max_new = if level > 0 { (requested_new >> level.min(16)).max(1) } else { requested_new };
        let caller_deadline = req.deadline;
        let deadline =
            caller_deadline.or_else(|| self.cfg.default_deadline.map(|d| Instant::now() + d));
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let priority = req.priority;
        let mut req = QueuedRequest {
            id,
            tokens: req.tokens,
            max_new_tokens: max_new,
            deadline,
            respond: rtx,
            submitted: Instant::now(),
            priority,
            sampling: req.sampling,
            attempts: 0,
        };

        // Route: start at the round-robin cursor, prefer the least-loaded
        // shard (ties keep the round-robin order). Depths are snapshotted
        // once up front: re-reading live queue lengths per comparison could
        // present the sort with an inconsistent order (which std's sort
        // detects by panicking).
        let n = self.slots.len();
        let start = self.rr.fetch_add(1, Ordering::Relaxed);
        let mut order: Vec<(usize, usize)> = (0..n)
            .map(|k| (start + k) % n)
            .map(|s| (self.slots.get(s).map_or(usize::MAX, |sl| sl.queue.len()), s))
            .collect();
        order.sort_by_key(|&(depth, _)| depth); // stable sort: ties keep rr order
        // Pass 0 targets live shards only; pass 1 accepts any open queue —
        // a dead-but-open shard is respawning under its supervisor, which
        // will drain the backlog (or re-home it at permanent death), so
        // queueing there beats rejecting outright.
        let mut any_full = false;
        for pass in 0..2 {
            for &(_, s) in &order {
                let Some(slot) = self.slots.get(s) else { continue };
                if pass == 0 && slot.dead.load(Ordering::Relaxed) {
                    continue;
                }
                // The queue checks capacity and closedness atomically with
                // the enqueue — concurrent submitters can never overshoot
                // the cap (model-checked in tests/loom_coordinator.rs).
                match slot.queue.push(req) {
                    Ok(()) => {
                        self.metrics.requests.fetch_add(1, Ordering::Relaxed);
                        self.brownout.relief(&self.metrics);
                        return Ok(rrx);
                    }
                    Err(PushError::Full(r)) => {
                        if pass == 1 {
                            any_full = true;
                        }
                        req = r;
                    }
                    Err(PushError::Closed(r)) => req = r,
                }
            }
        }
        if !any_full {
            // Every queue is closed: hand the request back (pre-clamp
            // decode budget, the caller's own deadline) so the caller can
            // stop submitting. Nothing was recorded or queued.
            return Err(SubmitError(Request {
                tokens: req.tokens,
                max_new: requested_new,
                deadline: caller_deadline,
                priority: req.priority,
            }));
        }
        // Backpressure: every open queue is at capacity. Terminal
        // admission-control rejection, surfaced as a shed response.
        self.metrics.requests.fetch_add(1, Ordering::Relaxed);
        self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
        self.metrics
            .shed_reason_counter(ShedReason::Admission)
            .fetch_add(1, Ordering::Relaxed);
        self.brownout.overload(&self.metrics);
        let _ = req.respond.send(Response {
            id,
            next_token: 0,
            tokens: Vec::new(),
            latency: req.submitted.elapsed(),
            shard: usize::MAX,
            shed: true,
            reason: Some(ShedReason::Admission),
        });
        Ok(rrx)
    }

    /// Drain and stop every shard. Reports (rather than panics on) shard
    /// threads that died of an uncaught panic — their queued clients were
    /// already shed by the shard's own unwind fences.
    pub fn shutdown(mut self) -> Result<()> {
        for s in self.slots.iter() {
            s.queue.close();
        }
        let mut crashed = 0usize;
        for h in &mut self.handles {
            if let Some(h) = h.take() {
                if h.join().is_err() {
                    crashed += 1;
                }
            }
        }
        anyhow::ensure!(crashed == 0, "{crashed} shard thread(s) panicked outside the unwind fence");
        Ok(())
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        for s in self.slots.iter() {
            s.queue.close();
        }
        for h in &mut self.handles {
            if let Some(h) = h.take() {
                let _ = h.join();
            }
        }
    }
}

/// Executor factory: runs on the shard's own thread, once per executor
/// *generation* — the supervisor calls it again after each death, so it
/// must hand out a fresh executor per call (or fail, which counts as a
/// fruitless restart).
type ShardFactory = Box<dyn FnMut() -> Result<Box<dyn BatchExecutor>> + Send>;

/// One in-flight request on a shard: submission metadata + decode state.
struct Live {
    req: QueuedRequest,
    state: DecodeState,
}

/// Everything a shard's supervisor and decode loop need to cooperate with
/// the rest of the coordinator: identity, recovery policy, the shared
/// slot table (for re-homing orphans), the global retry-token pool,
/// brown-out state and the global metrics view.
struct ShardCtx {
    shard_id: usize,
    sup: SupervisorConfig,
    slots: Arc<Vec<ShardSlot>>,
    retry_tokens: Arc<Mutex<u64>>,
    brownout: Arc<Brownout>,
    global: Arc<Metrics>,
}

/// Why one executor generation ended.
enum GenExit {
    /// Queue closed and drained: orderly shutdown, the shard is done.
    Clean,
    /// The executor died (panic or injected fault). `orphans` is the live
    /// set (plus any request caught mid-admission) to re-home; `served_any`
    /// reports whether this generation completed at least one response
    /// (which resets the supervisor's consecutive-death counter).
    Died { orphans: Vec<QueuedRequest>, served_any: bool },
}

fn orphaned(
    live: &mut Vec<Live>,
    extra: Option<QueuedRequest>,
    served_any: bool,
) -> GenExit {
    let mut orphans: Vec<QueuedRequest> = live.drain(..).map(|l| l.req).collect();
    orphans.extend(extra);
    GenExit::Died { orphans, served_any }
}

/// Spawn one shard: a *supervisor* thread that runs executor generations
/// ([`run_generation`]) until shutdown or permanent death. Each death (a
/// panicking executor, a failed construction, or an injected
/// [`crate::util::failpoint`] kill) takes the shard out of rotation,
/// re-homes its orphaned requests ([`redistribute`]), raises brown-out
/// pressure, and — while the consecutive-death count stays within
/// [`SupervisorConfig::max_shard_restarts`] — sleeps a capped exponential
/// backoff with seeded jitter before constructing a fresh executor
/// through the factory. A shard whose deaths exceed the budget closes its
/// queue and re-homes the backlog one final time; with no survivors left,
/// those requests shed with [`ShedReason::ShardDeath`] — never silently
/// dropped.
fn spawn_shard(
    ctx: ShardCtx,
    mut make_executor: ShardFactory,
    batcher_cfg: BatcherConfig,
) -> JoinHandle<()> {
    std::thread::spawn(move || {
        let Some(slot) = ctx.slots.get(ctx.shard_id) else {
            return; // unreachable: the slot table is built from the factory list
        };
        let q = slot.queue.clone();
        let m = slot.metrics.clone();
        // Deterministic per-shard backoff jitter (golden-ratio id spread).
        let mut rng = Rng::seed_from_u64(0x9e37_79b9_7f4a_7c15 ^ ctx.shard_id as u64);
        let mut deaths: u32 = 0;
        let mut constructed_before = false;
        loop {
            let built = catch_unwind(AssertUnwindSafe(|| make_executor()));
            let exec = match built {
                Ok(Ok(e)) => Some(e),
                Ok(Err(e)) => {
                    eprintln!(
                        "[coordinator] shard {}: executor construction failed: {e:#}",
                        ctx.shard_id
                    );
                    None
                }
                Err(p) => {
                    eprintln!(
                        "[coordinator] shard {}: executor construction panicked: {}",
                        ctx.shard_id,
                        panic_msg(&p)
                    );
                    None
                }
            };
            let outcome = match exec {
                Some(exec) => {
                    if constructed_before {
                        for g in [&m, &ctx.global] {
                            g.shard_restarts.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                    constructed_before = true;
                    slot.dead.store(false, Ordering::Relaxed); // back in rotation
                    run_generation(&ctx, &m, &q, exec, &batcher_cfg)
                }
                None => GenExit::Died { orphans: Vec::new(), served_any: false },
            };
            let (orphans, served_any) = match outcome {
                GenExit::Clean => break, // shutdown: queue closed + drained
                GenExit::Died { orphans, served_any } => (orphans, served_any),
            };
            // Out of rotation while down; the queue stays open as a
            // last-resort routing target unless death becomes permanent.
            slot.dead.store(true, Ordering::Relaxed);
            ctx.brownout.overload(&ctx.global);
            if served_any {
                deaths = 0; // the generation did real work: reset the streak
            }
            deaths += 1;
            redistribute(&ctx, &m, orphans);
            if deaths > ctx.sup.max_shard_restarts {
                eprintln!(
                    "[coordinator] shard {}: permanently dead after {deaths} consecutive deaths",
                    ctx.shard_id
                );
                // Close first so no new request can slip in behind the
                // drain, then re-home the backlog one final time.
                q.close();
                let mut backlog = Vec::new();
                while let Some(r) = q.pop() {
                    backlog.push(r);
                }
                redistribute(&ctx, &m, backlog);
                break;
            }
            // Capped exponential backoff + seeded jitter before respawn.
            let exp = deaths.saturating_sub(1).min(16);
            let base = ctx.sup.backoff_base.saturating_mul(1u32 << exp);
            let jitter_us = (ctx.sup.backoff_base.as_micros() as u64).max(1);
            let wait =
                base.min(ctx.sup.backoff_cap) + Duration::from_micros(rng.gen_range_u64(jitter_us));
            std::thread::sleep(wait);
            if q.is_closed() {
                // Shutdown landed while we were down: re-home the backlog
                // (which sheds once every queue is closed) and exit.
                let mut backlog = Vec::new();
                while let Some(r) = q.pop() {
                    backlog.push(r);
                }
                redistribute(&ctx, &m, backlog);
                break;
            }
        }
    })
}

/// One executor generation: the continuous-batching decode loop. The loop
/// keeps a live set of [`DecodeState`]s; every iteration (a) admits
/// queued requests into free slots — blocking via the [`Batcher`] only
/// when idle, non-blocking [`Batcher::try_fill`] between steps so
/// requests *join mid-flight* — (b) advances every live request one token
/// ([`BatchExecutor::step`], KV-cached where supported), and (c) retires
/// finished requests immediately instead of holding them until the
/// longest neighbor drains. `Metrics::batches` counts decode steps.
///
/// Fault semantics (PR 7): *panics* — and injected `shard.loop` /
/// `shard.begin` / `shard.step` failpoint kills — end the generation: the
/// executor's internal state is unknowable, so the live set rides back to
/// the supervisor as orphans for re-homing. Plain *errors* from
/// `begin`/`step` are retryable: the executor is structurally sound, so
/// the affected requests re-home immediately ([`redistribute`]) and the
/// generation keeps serving. Expired requests shed with
/// [`ShedReason::Deadline`]; a client that dropped its receiver never
/// wedges the shard; no panic crosses a lock (no poisoning) or reaches
/// `join`.
fn run_generation(
    ctx: &ShardCtx,
    m: &Arc<Metrics>,
    q: &Arc<RequestQueue<QueuedRequest>>,
    mut exec: Box<dyn BatchExecutor>,
    batcher_cfg: &BatcherConfig,
) -> GenExit {
    let shard_id = ctx.shard_id;
    let cap = exec.batch_capacity().max(1);
    let cfg = BatcherConfig {
        batch_size: batcher_cfg.batch_size.min(cap).max(1),
        ..batcher_cfg.clone()
    };
    let batcher = Batcher::new(cfg, q.clone());
    let mut live: Vec<Live> = Vec::new();
    let mut served_any = false;
    loop {
        // Chaos hook: kill or stall the shard loop between steps.
        match catch_unwind(AssertUnwindSafe(|| failpoint::check(sites::SHARD_LOOP))) {
            Ok(Ok(())) => {}
            Ok(Err(e)) => {
                eprintln!("[coordinator] shard {shard_id}: {e:#}");
                return orphaned(&mut live, None, served_any);
            }
            Err(p) => {
                eprintln!("[coordinator] shard {shard_id}: {}", panic_msg(&p));
                return orphaned(&mut live, None, served_any);
            }
        }
        // ---- admit: block only when idle; top up mid-flight.
        let incoming = if live.is_empty() {
            match batcher.next_batch() {
                Some(b) => b,
                None => break, // queue closed and drained; no work left
            }
        } else {
            batcher.try_fill(cap - live.len())
        };
        let now = Instant::now();
        for req in incoming {
            // Shed-on-deadline: drop requests that expired in queue.
            if matches!(req.deadline, Some(dl) if now > dl) {
                shed_one(shard_id, req, m, &ctx.global, ShedReason::Deadline);
                continue;
            }
            let begun = catch_unwind(AssertUnwindSafe(|| {
                failpoint::check(sites::SHARD_BEGIN)?;
                exec.begin(&req.tokens, req.max_new_tokens)
            }));
            match begun {
                Err(p) => {
                    for g in [m, &ctx.global] {
                        g.exec_errors.fetch_add(1, Ordering::Relaxed);
                    }
                    eprintln!(
                        "[coordinator] shard {shard_id}: executor panicked in begin: {}",
                        panic_msg(&p)
                    );
                    return orphaned(&mut live, Some(req), served_any);
                }
                Ok(Ok(state)) if state.done() => {
                    // Zero-budget request: answer immediately.
                    let latency = req.submitted.elapsed();
                    for g in [m, &ctx.global] {
                        g.record_latency(latency);
                        g.responses.fetch_add(1, Ordering::Relaxed);
                    }
                    served_any = true;
                    let _ = req.respond.send(Response {
                        id: req.id,
                        next_token: 0,
                        tokens: Vec::new(),
                        latency,
                        shard: shard_id,
                        shed: false,
                        reason: None,
                    });
                }
                Ok(Ok(mut state)) => {
                    for g in [m, &ctx.global] {
                        g.batch_tokens.fetch_add(req.tokens.len() as u64, Ordering::Relaxed);
                    }
                    // Attach the request's seeded sampler here (executor-
                    // agnostic, and re-seeded from scratch on every retry
                    // so re-homed sampled chains replay bit-identically).
                    state.set_sampler(req.sampling.map(Sampler::new));
                    live.push(Live { req, state });
                }
                Ok(Err(e)) => {
                    eprintln!("[coordinator] shard {shard_id}: admit failed: {e:#}");
                    for g in [m, &ctx.global] {
                        g.exec_errors.fetch_add(1, Ordering::Relaxed);
                    }
                    if e.downcast_ref::<PoolExhausted>().is_some() {
                        // KV block pool dry: this is load, not a fault.
                        // Retrying elsewhere would just drain the retry
                        // budget against a full pool — shed as brown-out
                        // backpressure and raise the pressure so decode
                        // budgets clamp until blocks free up.
                        ctx.brownout.overload(&ctx.global);
                        shed_one(shard_id, req, m, &ctx.global, ShedReason::Brownout);
                        // Publish the refusal immediately: a shard that
                        // only ever sheds at begin would otherwise never
                        // reach the per-step gauge store below.
                        if let Some(ps) = exec.kv_pool_stats() {
                            m.store_kv_pool(&ps);
                        }
                    } else {
                        // Retryable: the executor survived and the request
                        // never started — re-home it instead of shedding.
                        redistribute(ctx, m, vec![req]);
                    }
                }
            }
        }
        if live.is_empty() {
            continue;
        }

        // ---- one decode step across the whole live set.
        let before: usize = live.iter().map(|l| l.state.generated().len()).sum();
        let step_res = {
            let mut active: Vec<&mut DecodeState> =
                live.iter_mut().map(|l| &mut l.state).collect();
            catch_unwind(AssertUnwindSafe(|| {
                failpoint::check(sites::SHARD_STEP)?;
                exec.step(&mut active)
            }))
        };
        let step_res = match step_res {
            Err(p) => {
                // Executor state is unknowable after a panic: this
                // generation is done. The supervisor re-homes the live set.
                for g in [m, &ctx.global] {
                    g.exec_errors.fetch_add(1, Ordering::Relaxed);
                }
                eprintln!(
                    "[coordinator] shard {shard_id}: executor panicked mid-step: {}",
                    panic_msg(&p)
                );
                return orphaned(&mut live, None, served_any);
            }
            Ok(r) => r,
        };
        // A "successful" step that generated nothing would spin this
        // loop forever — treat it as an executor fault.
        let after: usize = live.iter().map(|l| l.state.generated().len()).sum();
        let step_res = step_res.and_then(|()| {
            anyhow::ensure!(after > before, "executor step made no decode progress");
            Ok(())
        });
        if let Err(e) = step_res {
            eprintln!("[coordinator] shard {shard_id}: decode step failed: {e:#}");
            for g in [m, &ctx.global] {
                g.exec_errors.fetch_add(1, Ordering::Relaxed);
            }
            let pool_pressure = e.downcast_ref::<PoolExhausted>().is_some();
            if pool_pressure {
                // Pool pressure mid-decode: raise brown-out so admission
                // clamps budgets. The re-home below also *releases* every
                // live cache (dropping the DecodeStates frees their
                // blocks), so the retried requests see a drained pool.
                ctx.brownout.overload(&ctx.global);
                if let Some(ps) = exec.kv_pool_stats() {
                    m.store_kv_pool(&ps);
                }
            }
            // Retryable fault: re-home the live set (each request restarts
            // decode from its original prefix, so greedy chains stay
            // bit-identical) and keep this generation serving. Requests
            // whose budget runs out under sustained pool pressure shed as
            // Brownout — backpressure, not a shard fault.
            let orphans: Vec<QueuedRequest> = live.drain(..).map(|l| l.req).collect();
            let exhaust = if pool_pressure {
                ShedReason::Brownout
            } else {
                ShedReason::RetryExhausted
            };
            redistribute_with(ctx, m, orphans, exhaust);
            continue;
        }
        // Tokens actually emitted this step: a speculative executor can
        // emit several per request per step, and every one must count.
        // The schedule-pass counter stays once-per-`step` call — one
        // verifier pass per step, never per drafted token (PR 9 fix,
        // pinned next to the PR 5 counter test).
        let stepped = (after - before) as u64;
        let transitions = exec.dvfs_transitions() as u64;
        for g in [m, &ctx.global] {
            g.batches.fetch_add(1, Ordering::Relaxed);
            g.generated_tokens.fetch_add(stepped, Ordering::Relaxed);
            g.dvfs_transitions.fetch_add(transitions, Ordering::Relaxed);
        }
        // Publish the shard's KV pool occupancy/sharing gauges (if any)
        // while they're fresh — metrics readers see per-step granularity.
        if let Some(ps) = exec.kv_pool_stats() {
            m.store_kv_pool(&ps);
        }
        // Same for speculative drafter/verifier work accounting.
        if let Some(ss) = exec.spec_stats() {
            m.store_spec(&ss);
        }

        // ---- retire finished requests immediately.
        let mut i = 0;
        while i < live.len() {
            if !live[i].state.done() {
                i += 1;
                continue;
            }
            let Live { req, state } = live.swap_remove(i);
            let latency = req.submitted.elapsed();
            for g in [m, &ctx.global] {
                g.record_latency(latency);
                g.responses.fetch_add(1, Ordering::Relaxed);
            }
            served_any = true;
            let toks = state.into_generated();
            // Receiver may have gone away (client disconnect); that
            // must never unwind or stall the shard.
            let _ = req.respond.send(Response {
                id: req.id,
                next_token: toks.first().copied().unwrap_or(0),
                tokens: toks,
                latency,
                shard: shard_id,
                shed: false,
                reason: None,
            });
        }
    }
    GenExit::Clean
}

/// Take one token from the global retry pool, or report exhaustion. A
/// mutex-guarded counter (the shim atomics carry no compare-exchange, and
/// this sits far off the decode hot path).
fn take_retry_token(tokens: &Mutex<u64>) -> bool {
    let mut t = tokens.lock().unwrap_or_else(|e| e.into_inner());
    if *t == 0 {
        return false;
    }
    *t -= 1;
    true
}

/// Try to place a re-homed request: pass 0 offers it to live shards
/// (least-loaded first), pass 1 to any open queue (a dead-but-open shard
/// is respawning and will drain — or re-home — its backlog). Returns the
/// request when every queue refused it.
fn try_place(slots: &[ShardSlot], mut req: QueuedRequest) -> Option<QueuedRequest> {
    let mut order: Vec<(usize, usize)> =
        slots.iter().enumerate().map(|(s, sl)| (sl.queue.len(), s)).collect();
    order.sort_by_key(|&(depth, _)| depth);
    for pass in 0..2 {
        for &(_, s) in &order {
            let Some(slot) = slots.get(s) else { continue };
            if pass == 0 && slot.dead.load(Ordering::Relaxed) {
                continue;
            }
            match slot.queue.push(req) {
                Ok(()) => return None,
                Err(e) => req = e.into_inner(),
            }
        }
    }
    Some(req)
}

/// Re-home requests that lost their executor. Retries are bounded twice
/// over: a request past [`SupervisorConfig::max_request_attempts`] — or
/// arriving after the global [`SupervisorConfig::retry_budget`] pool has
/// drained — sheds with [`ShedReason::RetryExhausted`]. Expired requests
/// shed with [`ShedReason::Deadline`] without consuming retry budget, and
/// a request no queue will take (total executor loss) sheds with
/// [`ShedReason::ShardDeath`]. Every path answers the client exactly once
/// — re-homed requests restart decode from their original prefix, so a
/// retried greedy chain is bit-identical to an unfaulted one.
fn redistribute(ctx: &ShardCtx, m: &Arc<Metrics>, orphans: Vec<QueuedRequest>) {
    redistribute_with(ctx, m, orphans, ShedReason::RetryExhausted)
}

/// [`redistribute`] with an explicit reason for budget-exhausted sheds.
/// Fault paths keep [`ShedReason::RetryExhausted`]; the KV pool-pressure
/// path passes [`ShedReason::Brownout`] so a request that keeps losing
/// the block race reads as backpressure ("retry later"), not as a fault
/// that consumed the recovery budget.
fn redistribute_with(
    ctx: &ShardCtx,
    m: &Arc<Metrics>,
    orphans: Vec<QueuedRequest>,
    exhaust_reason: ShedReason,
) {
    for mut req in orphans {
        if matches!(req.deadline, Some(dl) if Instant::now() > dl) {
            shed_one(ctx.shard_id, req, m, &ctx.global, ShedReason::Deadline);
            continue;
        }
        req.attempts += 1;
        if req.attempts > ctx.sup.max_request_attempts || !take_retry_token(&ctx.retry_tokens) {
            shed_one(ctx.shard_id, req, m, &ctx.global, exhaust_reason);
            continue;
        }
        for g in [m, &ctx.global] {
            g.retries.fetch_add(1, Ordering::Relaxed);
        }
        if let Some(req) = try_place(&ctx.slots, req) {
            shed_one(ctx.shard_id, req, m, &ctx.global, ShedReason::ShardDeath);
        }
    }
}

/// Best-effort description of a caught panic payload (for shard-death
/// logging; `&str` and `String` payloads cover `panic!`/`expect`).
fn panic_msg(p: &(dyn std::any::Any + Send)) -> &str {
    p.downcast_ref::<&'static str>()
        .copied()
        .or_else(|| p.downcast_ref::<String>().map(String::as_str))
        .unwrap_or("<non-string panic payload>")
}

/// Terminal shed: count it (with its reason) on both the shard and global
/// metrics, and answer the client's channel exactly once.
fn shed_one(
    shard_id: usize,
    req: QueuedRequest,
    m: &Metrics,
    global: &Metrics,
    reason: ShedReason,
) {
    for g in [m, global] {
        g.shed.fetch_add(1, Ordering::Relaxed);
        g.shed_reason_counter(reason).fetch_add(1, Ordering::Relaxed);
    }
    let _ = req.respond.send(Response {
        id: req.id,
        next_token: 0,
        tokens: Vec::new(),
        latency: req.submitted.elapsed(),
        shard: shard_id,
        shed: true,
        reason: Some(reason),
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;
    use std::time::Duration;

    /// Deterministic fake: next token = sum of prefix mod 97.
    struct Echo {
        cap: usize,
    }

    impl BatchExecutor for Echo {
        fn batch_capacity(&self) -> usize {
            self.cap
        }
        fn seq_len(&self) -> usize {
            16
        }
        fn run(&mut self, prefixes: &[Vec<i32>]) -> Result<Vec<i32>> {
            Ok(prefixes.iter().map(|p| p.iter().sum::<i32>() % 97).collect())
        }
        fn dvfs_transitions(&self) -> usize {
            2
        }
    }

    fn start_shards(n: usize, batch: usize) -> Coordinator {
        Coordinator::start(
            CoordinatorConfig {
                batcher: BatcherConfig { batch_size: batch, timeout: Duration::from_millis(2) },
                shards: n,
                ..CoordinatorConfig::default()
            },
            move |_shard| Ok(Box::new(Echo { cap: batch }) as Box<dyn BatchExecutor>),
        )
    }

    fn start(batch: usize) -> Coordinator {
        start_shards(1, batch)
    }

    /// Next-token submit shorthand (the pre-PR-8 `submit(tokens)` shape).
    fn submit1(c: &Coordinator, tokens: Vec<i32>) -> Receiver<Response> {
        c.submit_or_shed(Request::new(tokens))
    }

    #[test]
    fn every_request_answered_exactly_once() {
        let c = start(4);
        let mut rxs = Vec::new();
        let mut want = Vec::new();
        let mut rng = Rng::seed_from_u64(1);
        for i in 0..97 {
            let tokens: Vec<i32> =
                (0..1 + rng.gen_usize(10)).map(|_| rng.gen_usize(50) as i32).collect();
            want.push((i as u64, tokens.iter().sum::<i32>() % 97));
            rxs.push(submit1(&c, tokens));
        }
        for (rx, (id, tok)) in rxs.into_iter().zip(want) {
            let resp = rx.recv_timeout(Duration::from_secs(5)).unwrap();
            assert_eq!(resp.id, id);
            assert_eq!(resp.next_token, tok);
            assert!(!resp.shed);
            // one response only
            assert!(rx.recv_timeout(Duration::from_millis(1)).is_err());
        }
        let m = &c.metrics;
        assert_eq!(m.requests.load(Ordering::Relaxed), 97);
        assert_eq!(m.responses.load(Ordering::Relaxed), 97);
        c.shutdown().unwrap();
    }

    #[test]
    fn batching_actually_batches() {
        let c = start(8);
        let rxs: Vec<_> = (0..64).map(|i| submit1(&c, vec![i])).collect();
        for rx in rxs {
            rx.recv_timeout(Duration::from_secs(5)).unwrap();
        }
        let batches = c.metrics.batches.load(Ordering::Relaxed);
        assert!(batches < 64, "no batching happened: {batches}");
        assert!(c.metrics.mean_batch_occupancy() > 1.1);
        c.shutdown().unwrap();
    }

    #[test]
    fn dvfs_transitions_accounted_per_batch() {
        let c = start(4);
        let rxs: Vec<_> = (0..8).map(|i| submit1(&c, vec![i])).collect();
        for rx in rxs {
            rx.recv().unwrap();
        }
        let b = c.metrics.batches.load(Ordering::Relaxed);
        assert_eq!(c.metrics.dvfs_transitions.load(Ordering::Relaxed), 2 * b);
        c.shutdown().unwrap();
    }

    #[test]
    fn dvfs_transitions_accounted_per_decode_step() {
        // Multi-token decode pins the PR 5 semantics: one schedule pass
        // per decode STEP (3 steps → 3× the per-pass transitions), not
        // one per admitted batch.
        let c = start(4);
        let rx = c.submit_or_shed(Request::new(vec![1, 2]).max_new(3));
        rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(c.metrics.batches.load(Ordering::Relaxed), 3);
        assert_eq!(c.metrics.dvfs_transitions.load(Ordering::Relaxed), 6);
        c.shutdown().unwrap();
    }

    /// Fake speculative executor: every step emits `burst` tokens per
    /// live request (Echo's chain rule), the way `SpecExecutor` retires
    /// several accepted tokens in one verifier pass.
    struct Burst {
        cap: usize,
        burst: usize,
    }

    impl BatchExecutor for Burst {
        fn batch_capacity(&self) -> usize {
            self.cap
        }
        fn seq_len(&self) -> usize {
            16
        }
        fn run(&mut self, prefixes: &[Vec<i32>]) -> Result<Vec<i32>> {
            Ok(prefixes.iter().map(|p| p.iter().sum::<i32>() % 97).collect())
        }
        fn dvfs_transitions(&self) -> usize {
            2
        }
        fn step(&mut self, states: &mut [&mut DecodeState]) -> Result<()> {
            for s in states.iter_mut() {
                let burst = self.burst.min(s.max_new().saturating_sub(s.generated().len())).max(1);
                for _ in 0..burst {
                    let t = s.window().iter().sum::<i32>() % 97;
                    s.push_token(t);
                }
            }
            Ok(())
        }
    }

    #[test]
    fn dvfs_transitions_accounted_per_verifier_step_not_per_token() {
        // PR 9 regression: a speculative step retires several tokens in
        // ONE schedule pass. The coordinator must count one pass per
        // executor step (9 tokens / 3 per step → 3 steps → 3×2
        // transitions) and generated_tokens from the real token delta —
        // never one pass (or one token) per drafted token.
        let c = Coordinator::start(
            CoordinatorConfig {
                batcher: BatcherConfig { batch_size: 4, timeout: Duration::from_millis(2) },
                shards: 1,
                ..CoordinatorConfig::default()
            },
            move |_shard| Ok(Box::new(Burst { cap: 4, burst: 3 }) as Box<dyn BatchExecutor>),
        );
        let rx = c.submit_or_shed(Request::new(vec![1, 2]).max_new(9));
        let resp = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(resp.tokens.len(), 9);
        assert_eq!(c.metrics.batches.load(Ordering::Relaxed), 3);
        assert_eq!(c.metrics.dvfs_transitions.load(Ordering::Relaxed), 6);
        assert_eq!(c.metrics.generated_tokens.load(Ordering::Relaxed), 9);
        c.shutdown().unwrap();
    }

    #[test]
    fn shutdown_drains_cleanly() {
        let c = start(2);
        let rx = submit1(&c, vec![1, 2, 3]);
        c.shutdown().unwrap();
        assert_eq!(rx.recv().unwrap().next_token, 6);
    }

    // ------------------------------------------------- sharded serving

    #[test]
    fn sharded_answers_every_request_and_spreads_load() {
        let c = start_shards(4, 4);
        assert_eq!(c.n_shards(), 4);
        let mut rxs = Vec::new();
        let mut want = Vec::new();
        for i in 0..200i32 {
            want.push((i % 50) % 97);
            rxs.push(submit1(&c, vec![i % 50]));
        }
        for (rx, want) in rxs.into_iter().zip(want) {
            let r = rx.recv_timeout(Duration::from_secs(5)).unwrap();
            assert_eq!(r.next_token, want);
            assert!(r.shard < 4);
        }
        // Router spread work across shards: no shard did everything.
        let busy: Vec<u64> = c
            .shard_metrics()
            .iter()
            .map(|m| m.responses.load(Ordering::Relaxed))
            .collect();
        assert_eq!(busy.iter().sum::<u64>(), 200);
        assert!(busy.iter().filter(|&&b| b > 0).count() >= 2, "one shard took all: {busy:?}");
        c.shutdown().unwrap();
    }

    #[test]
    fn generate_decodes_multiple_tokens() {
        // Echo's next token is (sum of prefix) % 97, so the decode chain is
        // deterministic and checkable in plain code.
        let c = start_shards(2, 4);
        let prefix = vec![3, 5];
        let rx = c.submit_or_shed(Request::new(prefix.clone()).max_new(4));
        let r = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        let mut seq = prefix;
        let mut want = Vec::new();
        for _ in 0..4 {
            let t = seq.iter().sum::<i32>() % 97;
            want.push(t);
            seq.push(t);
        }
        assert_eq!(r.tokens, want);
        assert_eq!(r.next_token, want[0]);
        assert_eq!(c.metrics.generated_tokens.load(Ordering::Relaxed), 4);
        c.shutdown().unwrap();
    }

    #[test]
    fn generate_slides_context_at_seq_cap() {
        // seq_len = 16; a 16-token prefix forces the slide path.
        let c = start(2);
        let rx = c.submit_or_shed(Request::new(vec![1; 16]).max_new(3));
        let r = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(r.tokens.len(), 3);
        c.shutdown().unwrap();
    }

    /// Echo's decode chain under the sliding window, mirrored in plain code.
    fn echo_chain(prefix: &[i32], cap: usize, steps: usize) -> Vec<i32> {
        let mut seq: Vec<i32> = prefix[prefix.len().saturating_sub(cap)..].to_vec();
        let mut want = Vec::new();
        for _ in 0..steps {
            let t = seq.iter().sum::<i32>() % 97;
            want.push(t);
            if seq.len() >= cap {
                seq.remove(0);
            }
            seq.push(t);
        }
        want
    }

    #[test]
    fn generate_conditions_on_newest_context_for_long_prefixes() {
        // A 40-token prefix against seq_len = 16: decode must condition on
        // the LAST 16 tokens, not the first.
        let c = start(4);
        let prefix: Vec<i32> = (0..40).collect();
        let rx = c.submit_or_shed(Request::new(prefix.clone()).max_new(3));
        let r = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(r.tokens, echo_chain(&prefix, 16, 3));
        c.shutdown().unwrap();
    }

    #[test]
    fn mixed_decode_lengths_in_one_batch() {
        // Different max_new in one batch: short requests finish early (and
        // drop out of later forward passes), long ones keep decoding.
        let c = start(4);
        let rx1 = c.submit_or_shed(Request::new(vec![1]));
        let rx2 = c.submit_or_shed(Request::new(vec![2]).max_new(5));
        let r1 = rx1.recv_timeout(Duration::from_secs(5)).unwrap();
        let r2 = rx2.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(r1.tokens, echo_chain(&[1], 16, 1));
        assert_eq!(r2.tokens, echo_chain(&[2], 16, 5));
        c.shutdown().unwrap();
    }

    #[test]
    fn dead_shard_is_skipped_and_healthy_shards_serve() {
        let c = Coordinator::start(
            CoordinatorConfig {
                batcher: BatcherConfig { batch_size: 2, timeout: Duration::from_millis(1) },
                shards: 2,
                ..CoordinatorConfig::default()
            },
            move |shard| {
                if shard == 0 {
                    anyhow::bail!("shard 0 never comes up");
                }
                Ok(Box::new(Echo { cap: 2 }) as Box<dyn BatchExecutor>)
            },
        );
        // Let shard 0 mark itself out of rotation; afterwards everything
        // must be served by shard 1 rather than shed by the dead shard.
        std::thread::sleep(Duration::from_millis(200));
        let rxs: Vec<_> = (0..20).map(|i| submit1(&c, vec![i])).collect();
        for (i, rx) in rxs.into_iter().enumerate() {
            let r = rx.recv_timeout(Duration::from_secs(5)).unwrap();
            assert!(!r.shed, "request {i} shed despite a healthy shard");
            assert_eq!(r.shard, 1);
        }
        c.shutdown().unwrap();
    }

    #[test]
    fn expired_deadline_is_shed_not_run() {
        // Deadline already in the past: the shard must shed, not execute.
        let c = start(4);
        let req = Request::new(vec![1, 2, 3]).deadline_at(Instant::now() - Duration::from_millis(1));
        let r = c.submit_or_shed(req).recv_timeout(Duration::from_secs(5)).unwrap();
        assert!(r.shed);
        assert!(r.tokens.is_empty());
        assert_eq!(r.reason, Some(ShedReason::Deadline));
        assert_eq!(c.metrics.shed.load(Ordering::Relaxed), 1);
        assert_eq!(c.metrics.responses.load(Ordering::Relaxed), 0);
        assert_eq!(c.merged_snapshot().shed_for(ShedReason::Deadline), 1);
        c.shutdown().unwrap();
    }

    /// Executor that blocks until released — lets tests fill queues
    /// deterministically.
    struct Gate {
        release: Receiver<()>,
    }

    impl BatchExecutor for Gate {
        fn batch_capacity(&self) -> usize {
            1
        }
        fn seq_len(&self) -> usize {
            16
        }
        fn run(&mut self, prefixes: &[Vec<i32>]) -> Result<Vec<i32>> {
            let _ = self.release.recv();
            Ok(vec![0; prefixes.len()])
        }
    }

    #[test]
    fn full_queues_reject_with_backpressure() {
        let (gate_tx, gate_rx) = channel::<()>();
        let gate_rx = Mutex::new(Some(gate_rx));
        let c = Coordinator::start(
            CoordinatorConfig {
                batcher: BatcherConfig { batch_size: 1, timeout: Duration::from_millis(1) },
                shards: 1,
                queue_cap: 2,
                ..CoordinatorConfig::default()
            },
            move |_s| {
                let rx = gate_rx.lock().unwrap().take().expect("single shard");
                Ok(Box::new(Gate { release: rx }) as Box<dyn BatchExecutor>)
            },
        );
        // First request occupies the executor; then fill the queue beyond
        // the cap. Depth only decrements when the batcher pulls, so after
        // cap is reached submissions must come back shed immediately.
        let mut rxs = Vec::new();
        for i in 0..8i32 {
            rxs.push(submit1(&c, vec![i]));
            // Give the shard a beat to pull the first request into a batch.
            if i == 0 {
                std::thread::sleep(Duration::from_millis(20));
            }
        }
        let rejected = c.metrics.rejected.load(Ordering::Relaxed);
        assert!(rejected >= 1, "queue_cap=2 never rejected under an 8-deep burst");
        // Release the gate for every possible run call, then drain.
        for _ in 0..16 {
            let _ = gate_tx.send(());
        }
        let mut shed = 0;
        let mut ok = 0;
        for rx in rxs {
            let r = rx.recv_timeout(Duration::from_secs(5)).unwrap();
            if r.shed {
                shed += 1;
            } else {
                ok += 1;
            }
        }
        assert_eq!(shed as u64, rejected);
        assert!(ok >= 2); // executor slot + queued requests under the cap
        c.shutdown().unwrap();
    }

    #[test]
    fn dropped_receiver_does_not_wedge_the_shard() {
        let c = start(2);
        // Client gives up immediately: drop the receiver before the shard
        // responds.
        drop(submit1(&c, vec![1, 2]));
        // The shard must still be alive and serving.
        let rx = submit1(&c, vec![4, 4]);
        assert_eq!(rx.recv_timeout(Duration::from_secs(5)).unwrap().next_token, 8);
        c.shutdown().unwrap();
    }

    /// Executor whose first run() fails — the shard must retry the batch
    /// (PR 7) and keep serving rather than kill the thread.
    struct Faulty {
        fail_first: u32,
    }

    impl BatchExecutor for Faulty {
        fn batch_capacity(&self) -> usize {
            4
        }
        fn seq_len(&self) -> usize {
            16
        }
        fn run(&mut self, prefixes: &[Vec<i32>]) -> Result<Vec<i32>> {
            if self.fail_first > 0 {
                self.fail_first -= 1;
                anyhow::bail!("injected executor fault");
            }
            Ok(prefixes.iter().map(|p| p.len() as i32).collect())
        }
    }

    #[test]
    fn executor_error_retries_request_and_shard_survives() {
        let c = Coordinator::start(
            CoordinatorConfig {
                batcher: BatcherConfig { batch_size: 1, timeout: Duration::from_millis(1) },
                ..CoordinatorConfig::default()
            },
            |_s| Ok(Box::new(Faulty { fail_first: 1 }) as Box<dyn BatchExecutor>),
        );
        // A non-panic step error is retryable: the request re-homes (here
        // back onto the same, still-healthy shard) and then serves.
        let r1 = submit1(&c, vec![1, 2, 3]).recv_timeout(Duration::from_secs(5)).unwrap();
        assert!(!r1.shed, "retryable executor error must not shed");
        assert_eq!(r1.next_token, 3);
        assert_eq!(c.metrics.exec_errors.load(Ordering::Relaxed), 1);
        assert_eq!(c.metrics.retries.load(Ordering::Relaxed), 1);
        let r2 = submit1(&c, vec![1, 2, 3]).recv_timeout(Duration::from_secs(5)).unwrap();
        assert!(!r2.shed);
        assert_eq!(r2.next_token, 3);
        c.shutdown().unwrap();
    }

    /// Echo that reports every run()'s batch size and then blocks until
    /// released — makes the continuous-batching join observable and
    /// deterministic.
    struct StepGate {
        release: Receiver<()>,
        sizes: Sender<usize>,
    }

    impl BatchExecutor for StepGate {
        fn batch_capacity(&self) -> usize {
            4
        }
        fn seq_len(&self) -> usize {
            16
        }
        fn run(&mut self, prefixes: &[Vec<i32>]) -> Result<Vec<i32>> {
            let _ = self.sizes.send(prefixes.len());
            let _ = self.release.recv();
            Ok(prefixes.iter().map(|p| p.iter().sum::<i32>() % 97).collect())
        }
    }

    #[test]
    fn requests_join_the_live_decode_set_mid_flight() {
        // Continuous batching: a request submitted while another is
        // mid-decode joins at the next step boundary instead of waiting
        // for the whole batch to drain (the pre-PR-5 behavior).
        let (rel_tx, rel_rx) = channel::<()>();
        let (size_tx, size_rx) = channel::<usize>();
        let slots = Mutex::new(Some((rel_rx, size_tx)));
        let c = Coordinator::start(
            CoordinatorConfig {
                batcher: BatcherConfig { batch_size: 4, timeout: Duration::from_millis(1) },
                ..CoordinatorConfig::default()
            },
            move |_s| {
                let (release, sizes) = slots.lock().unwrap().take().expect("single shard");
                Ok(Box::new(StepGate { release, sizes }) as Box<dyn BatchExecutor>)
            },
        );
        let rx1 = c.submit_or_shed(Request::new(vec![3, 5]).max_new(3));
        // Step 1 begins with request 1 alone.
        assert_eq!(size_rx.recv_timeout(Duration::from_secs(5)).unwrap(), 1);
        // Submit request 2 while step 1 is still executing.
        let rx2 = c.submit_or_shed(Request::new(vec![7]));
        rel_tx.send(()).unwrap(); // finish step 1
        // Step 2 must include BOTH requests: the join happened mid-flight.
        assert_eq!(size_rx.recv_timeout(Duration::from_secs(5)).unwrap(), 2);
        rel_tx.send(()).unwrap(); // finish step 2; request 2 retires
        // Step 3: request 2 retired immediately, request 1 decodes on.
        assert_eq!(size_rx.recv_timeout(Duration::from_secs(5)).unwrap(), 1);
        rel_tx.send(()).unwrap();
        let r1 = rx1.recv_timeout(Duration::from_secs(5)).unwrap();
        let r2 = rx2.recv_timeout(Duration::from_secs(5)).unwrap();
        // Chains are per-request windows — the join never cross-pollutes.
        assert_eq!(r1.tokens, echo_chain(&[3, 5], 16, 3));
        assert_eq!(r2.tokens, echo_chain(&[7], 16, 1));
        // 3 decode steps total, not 4 (= the serialized alternative).
        assert_eq!(c.metrics.batches.load(Ordering::Relaxed), 3);
        assert_eq!(c.metrics.generated_tokens.load(Ordering::Relaxed), 4);
        c.shutdown().unwrap();
    }

    /// Executor whose step "succeeds" without generating — the shard and
    /// generate() must fail it rather than spin forever.
    struct Stuck;

    impl BatchExecutor for Stuck {
        fn batch_capacity(&self) -> usize {
            2
        }
        fn seq_len(&self) -> usize {
            8
        }
        fn run(&mut self, prefixes: &[Vec<i32>]) -> Result<Vec<i32>> {
            Ok(vec![0; prefixes.len()])
        }
        fn step(&mut self, _states: &mut [&mut DecodeState]) -> Result<()> {
            Ok(()) // generates nothing
        }
    }

    #[test]
    fn zero_progress_step_is_an_error_not_a_livelock() {
        let mut e = Stuck;
        assert!(e.generate(&[vec![1]], &[2]).is_err());
        // Through the coordinator: the request is shed, the shard lives.
        let c = Coordinator::start(
            CoordinatorConfig {
                batcher: BatcherConfig { batch_size: 2, timeout: Duration::from_millis(1) },
                ..CoordinatorConfig::default()
            },
            |_s| Ok(Box::new(Stuck) as Box<dyn BatchExecutor>),
        );
        let r = submit1(&c, vec![1, 2]).recv_timeout(Duration::from_secs(5)).unwrap();
        assert!(r.shed);
        // Each zero-progress fault is retried until the per-request budget
        // drains, then the request sheds as retry-exhausted.
        assert_eq!(r.reason, Some(ShedReason::RetryExhausted));
        assert_eq!(c.metrics.retries.load(Ordering::Relaxed), MAX_REQUEST_ATTEMPTS as u64);
        assert!(c.metrics.exec_errors.load(Ordering::Relaxed) >= 1);
        c.shutdown().unwrap();
    }

    #[test]
    fn submit_after_total_executor_loss_sheds_instead_of_panicking() {
        // Executor construction fails: the shard drains with shed
        // responses and later submissions still answer.
        let c = Coordinator::start(CoordinatorConfig::default(), |_s| {
            anyhow::bail!("no executor today")
        });
        let r = submit1(&c, vec![1]).recv_timeout(Duration::from_secs(5)).unwrap();
        assert!(r.shed);
        c.shutdown().unwrap();
    }

    // ------------------------------------------------- panic safety (PR 6)

    /// Executor that panics on its `fail_on`-th step — exercises the
    /// unwind fence around `BatchExecutor::step`.
    struct Bomb {
        steps: u32,
        fail_on: u32,
    }

    impl BatchExecutor for Bomb {
        fn batch_capacity(&self) -> usize {
            4
        }
        fn seq_len(&self) -> usize {
            16
        }
        fn run(&mut self, prefixes: &[Vec<i32>]) -> Result<Vec<i32>> {
            self.steps += 1;
            if self.steps >= self.fail_on {
                panic!("injected executor panic");
            }
            Ok(prefixes.iter().map(|p| p.iter().sum::<i32>() % 97).collect())
        }
    }

    #[test]
    fn panicking_step_sheds_requests_instead_of_hanging_clients() {
        // Single shard whose executor panics mid-step: every in-flight and
        // queued request must come back as a shed response — no client
        // hangs, and shutdown returns Ok (the panic never crossed the
        // unwind fence to the thread boundary).
        let c = Coordinator::start(
            CoordinatorConfig {
                batcher: BatcherConfig { batch_size: 4, timeout: Duration::from_millis(20) },
                ..CoordinatorConfig::default()
            },
            |_s| Ok(Box::new(Bomb { steps: 0, fail_on: 1 }) as Box<dyn BatchExecutor>),
        );
        let rxs: Vec<_> = (0..6).map(|i| submit1(&c, vec![i])).collect();
        for rx in rxs {
            let r = rx.recv_timeout(Duration::from_secs(5)).unwrap();
            assert!(r.shed, "request served by a panicked executor");
        }
        assert!(c.metrics.exec_errors.load(Ordering::Relaxed) >= 1);
        // Later submissions find no live shard and shed immediately.
        let r = submit1(&c, vec![9]).recv_timeout(Duration::from_secs(5)).unwrap();
        assert!(r.shed);
        c.shutdown().unwrap();
    }

    #[test]
    fn panicked_shard_dies_alone_and_healthy_shards_keep_serving() {
        // Shard-death tolerance: one shard's executor panics (which in a
        // lock-per-shard design could poison shard-held state); the router
        // must keep serving on the survivor. Submissions race the death,
        // so each request either sheds (hit the dying shard) or serves
        // (hit the healthy one) — but never hangs, and the healthy shard
        // answers everything routed to it after the death lands.
        let c = Coordinator::start(
            CoordinatorConfig {
                batcher: BatcherConfig { batch_size: 2, timeout: Duration::from_millis(1) },
                shards: 2,
                ..CoordinatorConfig::default()
            },
            |shard| {
                Ok(if shard == 0 {
                    Box::new(Bomb { steps: 0, fail_on: 1 }) as Box<dyn BatchExecutor>
                } else {
                    Box::new(Echo { cap: 2 }) as Box<dyn BatchExecutor>
                })
            },
        );
        // Trip the bomb, then give the death time to land.
        let first: Vec<_> = (0..4).map(|i| submit1(&c, vec![i])).collect();
        for rx in first {
            rx.recv_timeout(Duration::from_secs(5)).unwrap();
        }
        std::thread::sleep(Duration::from_millis(100));
        for i in 0..20i32 {
            let r = submit1(&c, vec![i]).recv_timeout(Duration::from_secs(5)).unwrap();
            assert!(!r.shed, "request {i} shed despite a healthy shard");
            assert_eq!(r.shard, 1);
            assert_eq!(r.next_token, i % 97);
        }
        c.shutdown().unwrap();
    }

    #[test]
    fn panicking_construction_sheds_queued_requests() {
        let c = Coordinator::start(CoordinatorConfig::default(), |_s| {
            panic!("injected constructor panic")
        });
        let r = submit1(&c, vec![1]).recv_timeout(Duration::from_secs(5)).unwrap();
        assert!(r.shed);
        c.shutdown().unwrap();
    }

    // ------------------------------------------- supervised recovery (PR 7)

    #[test]
    fn shard_respawns_after_panic_and_retried_decode_is_bit_identical() {
        // Respawnable factory: the supervisor must bring the shard back,
        // and the orphaned request must re-run from its original prefix —
        // bit-identical to an unfaulted run.
        let first = Arc::new(AtomicBool::new(true));
        let c = Coordinator::start(
            CoordinatorConfig {
                batcher: BatcherConfig { batch_size: 2, timeout: Duration::from_millis(1) },
                shards: 1,
                ..CoordinatorConfig::default()
            },
            move |_s| {
                Ok(if first.swap(false, Ordering::Relaxed) {
                    Box::new(Bomb { steps: 0, fail_on: 1 }) as Box<dyn BatchExecutor>
                } else {
                    Box::new(Echo { cap: 2 }) as Box<dyn BatchExecutor>
                })
            },
        );
        let r = c
            .submit_or_shed(Request::new(vec![3, 5]).max_new(3))
            .recv_timeout(Duration::from_secs(5))
            .unwrap();
        assert!(!r.shed, "orphan of a respawned shard must serve, not shed");
        assert_eq!(r.tokens, echo_chain(&[3, 5], 16, 3));
        assert_eq!(c.metrics.shard_restarts.load(Ordering::Relaxed), 1);
        assert_eq!(c.metrics.retries.load(Ordering::Relaxed), 1);
        c.shutdown().unwrap();
    }

    #[test]
    fn brownout_levels_step_up_and_down_with_hysteresis() {
        let sup = SupervisorConfig {
            brownout_pressure: 2,
            brownout_max_level: 2,
            ..SupervisorConfig::default()
        };
        let b = Brownout::new(&sup);
        let g = Metrics::default();
        assert_eq!(b.level(), 0);
        b.overload(&g);
        assert_eq!(b.level(), 0);
        b.overload(&g);
        assert_eq!(b.level(), 1);
        b.overload(&g);
        b.overload(&g);
        assert_eq!(b.level(), 2);
        // max_level clamps further overload.
        b.overload(&g);
        b.overload(&g);
        assert_eq!(b.level(), 2);
        // Relief decays pressure first (hysteresis), then the level.
        let mut reliefs = 0;
        while b.level() > 0 {
            b.relief(&g);
            reliefs += 1;
            assert!(reliefs < 100, "level never decayed");
        }
        // Two up-steps and two down-steps, each counted.
        assert_eq!(g.brownout_steps.load(Ordering::Relaxed), 4);
    }

    #[test]
    fn brownout_sheds_negative_priority_and_clamps_decode_budget() {
        let c = Coordinator::start(
            CoordinatorConfig {
                batcher: BatcherConfig { batch_size: 2, timeout: Duration::from_millis(1) },
                shards: 2,
                supervisor: SupervisorConfig {
                    brownout_pressure: 1,
                    backoff_base: Duration::from_millis(1),
                    backoff_cap: Duration::from_millis(2),
                    ..SupervisorConfig::default()
                },
                ..CoordinatorConfig::default()
            },
            move |shard| {
                if shard == 0 {
                    anyhow::bail!("shard 0 stays down");
                }
                Ok(Box::new(Echo { cap: 2 }) as Box<dyn BatchExecutor>)
            },
        );
        // Shard 0's fruitless restarts each raise brown-out pressure; with
        // pressure_high = 1 the level pins at its max (3).
        let deadline = Instant::now() + Duration::from_secs(5);
        while c.brownout_level() < 3 {
            assert!(Instant::now() < deadline, "brown-out never engaged");
            std::thread::sleep(Duration::from_millis(5));
        }
        // Level ≥ 2: negative-priority work sheds at admission...
        let r = c
            .submit_or_shed(Request::new(vec![1]).max_new(4).priority(-1))
            .recv_timeout(Duration::from_secs(5))
            .unwrap();
        assert!(r.shed);
        assert_eq!(r.reason, Some(ShedReason::Brownout));
        // ...and level 3 clamps an 8-token decode budget to one token.
        let r = c
            .submit_or_shed(Request::new(vec![2]).max_new(8))
            .recv_timeout(Duration::from_secs(5))
            .unwrap();
        assert!(!r.shed);
        assert_eq!(r.tokens.len(), 1);
        let snap = c.merged_snapshot();
        assert!(snap.brownout_steps >= 3, "level 3 needs ≥ 3 counted up-steps");
        assert_eq!(snap.shed_for(ShedReason::Brownout), 1);
        c.shutdown().unwrap();
    }

    #[test]
    fn metrics_conserve_requests_under_churn() {
        // requests == responses + shed + rejected at quiesce, and the
        // per-reason counters sum to shed + rejected — even with a shard
        // dying and respawning under load.
        let c = Coordinator::start(
            CoordinatorConfig {
                batcher: BatcherConfig { batch_size: 2, timeout: Duration::from_millis(1) },
                shards: 2,
                ..CoordinatorConfig::default()
            },
            |shard| {
                Ok(if shard == 0 {
                    Box::new(Bomb { steps: 0, fail_on: 3 }) as Box<dyn BatchExecutor>
                } else {
                    Box::new(Echo { cap: 2 }) as Box<dyn BatchExecutor>
                })
            },
        );
        let rxs: Vec<_> = (0..50).map(|i| submit1(&c, vec![i])).collect();
        for rx in rxs {
            rx.recv_timeout(Duration::from_secs(5)).unwrap();
        }
        let snap = c.merged_snapshot();
        assert_eq!(snap.requests, snap.responses + snap.shed + snap.rejected);
        assert_eq!(snap.shed_reason_total(), snap.shed + snap.rejected);
        c.shutdown().unwrap();
    }
}
