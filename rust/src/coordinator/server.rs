//! The coordinator: router → per-shard batcher → executor threads.
//!
//! PR 3 scales the serving path from one executor to **N sharded executor
//! threads**. Each shard owns a bounded request queue, a [`Batcher`], and a
//! [`BatchExecutor`] constructed *inside* the shard thread via a factory
//! closure (PJRT handles are not `Send`). The router round-robins across
//! shards but steals toward the least-loaded queue; admission control
//! rejects new work when every queue is at capacity, and requests whose
//! deadline expired while queued are shed before execution instead of
//! burning executor time.
//!
//! The executor is abstracted behind [`BatchExecutor`] so the
//! routing/batching/shedding invariants are testable without a model; the
//! production executor ([`GraphExecutor`]) owns the loaded `fwd` graph and
//! the quantized parameter buffers on whichever runtime backend is active.
//! Full autoregressive decode is a provided method
//! ([`BatchExecutor::generate`]): run the forward pass, take the argmax
//! next token per sequence, re-feed it, repeat — reusing the padded-batch
//! plumbing of [`BatchExecutor::run`].

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use super::batch::{Batcher, BatcherConfig};
use super::metrics::Metrics;
use crate::dvfs::Schedule;
use crate::quant::Matrix;
use crate::runtime::{literal_i32, Buffer, ModelArtifacts, PackedModel, Runtime};

/// One inference request: a token prefix plus decode/deadline metadata.
/// The response carries the autoregressively generated tokens.
#[derive(Debug)]
pub struct Request {
    pub id: u64,
    pub tokens: Vec<i32>,
    /// How many tokens to decode (1 = classic next-token serving).
    pub max_new_tokens: usize,
    /// Absolute shed deadline: if it passes while the request is queued,
    /// the executor sheds it (empty `tokens`, `shed = true`) instead of
    /// running it.
    pub deadline: Option<Instant>,
    pub respond: Sender<Response>,
    pub submitted: Instant,
}

#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    /// First generated token (back-compat with next-token serving); 0 when
    /// shed.
    pub next_token: i32,
    /// All generated tokens, in order (empty when shed).
    pub tokens: Vec<i32>,
    pub latency: Duration,
    /// Which shard executed (or shed) the request.
    pub shard: usize,
    /// True when the request was dropped by deadline shedding or admission
    /// control instead of executed.
    pub shed: bool,
}

/// What the executor thread runs per batch: padded token matrix in, one
/// next-token per request out.
pub trait BatchExecutor {
    /// Max sequences per executed batch (the AOT graph's B).
    fn batch_capacity(&self) -> usize;
    fn seq_len(&self) -> usize;
    /// `prefixes` has ≤ batch_capacity entries, each ≤ seq_len tokens.
    fn run(&mut self, prefixes: &[Vec<i32>]) -> Result<Vec<i32>>;
    /// Simulated DVFS transitions for one pass (schedule metadata).
    fn dvfs_transitions(&self) -> usize {
        0
    }

    /// Autoregressive decode: repeatedly [`run`](Self::run) the batch,
    /// append each sequence's argmax token, and re-feed it, until sequence
    /// `i` has `max_new[i]` generated tokens. Sequences at the model's
    /// context window slide (drop-front) so every generated token
    /// conditions on the `seq_len` most recent tokens. Finished sequences
    /// drop out of later forward passes. Returns the generated tokens per
    /// sequence.
    fn generate(&mut self, prefixes: &[Vec<i32>], max_new: &[usize]) -> Result<Vec<Vec<i32>>> {
        anyhow::ensure!(prefixes.len() == max_new.len(), "prefixes/max_new length mismatch");
        let cap = self.seq_len();
        let mut seqs: Vec<Vec<i32>> = prefixes
            .iter()
            .map(|p| p[p.len().saturating_sub(cap)..].to_vec())
            .collect();
        let mut out: Vec<Vec<i32>> = prefixes.iter().map(|_| Vec::new()).collect();
        let steps = max_new.iter().copied().max().unwrap_or(0);
        for _ in 0..steps {
            let active: Vec<usize> =
                (0..seqs.len()).filter(|&i| out[i].len() < max_new[i]).collect();
            if active.is_empty() {
                break;
            }
            // Finished sequences are compacted out so they stop paying for
            // forward passes; the full-batch common case avoids the copy.
            let next = if active.len() == seqs.len() {
                self.run(&seqs)?
            } else {
                let batch: Vec<Vec<i32>> = active.iter().map(|&i| seqs[i].clone()).collect();
                self.run(&batch)?
            };
            anyhow::ensure!(next.len() == active.len(), "executor returned wrong batch size");
            for (&i, &tok) in active.iter().zip(&next) {
                out[i].push(tok);
                if seqs[i].len() >= cap {
                    seqs[i].remove(0); // slide the context window
                }
                seqs[i].push(tok);
            }
        }
        Ok(out)
    }
}

/// Production executor: fwd graph + (quantized) parameter buffers, on
/// whichever runtime backend is active (sim or PJRT).
pub struct GraphExecutor {
    rt: Runtime,
    exe: crate::runtime::Executable,
    /// Parameters resident on device across batches (§Perf L3).
    params: Vec<Buffer>,
    batch: usize,
    seq: usize,
    vocab: usize,
    schedule: Schedule,
    /// Sim backend accepts any leading batch dim, so partial batches pad
    /// only to their own size; PJRT compiled a static (B, S).
    dynamic_batch: bool,
}

impl GraphExecutor {
    /// Build inside the executor thread. `replace` substitutes quantized
    /// linear weights; `schedule` is this executor's DVFS class schedule
    /// (a whole-model schedule, or one shard of [`Schedule::shard`]).
    pub fn new(
        rt: Runtime,
        model: &ModelArtifacts,
        replace: &BTreeMap<String, Matrix>,
        schedule: Schedule,
    ) -> Result<Self> {
        let exe = rt.load(&model.graph_path("fwd_fp"))?;
        let params = rt.upload_all(&model.param_literals(replace)?)?;
        let dynamic_batch = rt.dynamic_batch();
        Ok(Self {
            rt,
            exe,
            params,
            batch: model.eval_batch,
            seq: model.seq_len,
            vocab: model.vocab,
            schedule,
            dynamic_batch,
        })
    }
}

/// Native quantized executor (PR 4): decode runs directly on the packed
/// codebook tiles of a [`PackedModel`] — LUT matmul kernels + fused SpMV —
/// so no dense f32 weight matrix is ever materialized for a quantized
/// layer. Always dynamic-batch (the packed forward reads `b` from its
/// inputs), so partial batches only pay for the rows they carry.
pub struct QuantExecutor {
    model: Arc<PackedModel>,
    batch: usize,
    schedule: Schedule,
}

impl QuantExecutor {
    /// Executor over a shared packed model, using the model's own
    /// whole-model DVFS schedule.
    pub fn new(model: Arc<PackedModel>, batch: usize) -> Self {
        let schedule = model.schedule.clone();
        Self::with_schedule(model, batch, schedule)
    }

    /// Executor with an explicit schedule slice (one shard of
    /// [`Schedule::shard`] under sharded serving).
    pub fn with_schedule(model: Arc<PackedModel>, batch: usize, schedule: Schedule) -> Self {
        Self { model, batch: batch.max(1), schedule }
    }
}

impl BatchExecutor for QuantExecutor {
    fn batch_capacity(&self) -> usize {
        self.batch
    }

    fn seq_len(&self) -> usize {
        self.model.spec.seq_len
    }

    fn run(&mut self, prefixes: &[Vec<i32>]) -> Result<Vec<i32>> {
        anyhow::ensure!(prefixes.len() <= self.batch, "over-full batch");
        anyhow::ensure!(!prefixes.is_empty(), "empty batch");
        let b = prefixes.len();
        // Right-pad only to the batch's longest live prefix (capped at the
        // context window) — the packed forward accepts any s ≤ seq_len,
        // and causal attention + from-zero positions make every live
        // row's logits bit-identical to the full-S pass, so short decode
        // batches don't pay for dead positions. Prefixes beyond the
        // window keep their newest tokens (same contract as
        // GraphExecutor::run).
        let cap = self.model.spec.seq_len;
        let s = prefixes.iter().map(|p| p.len().min(cap)).max().unwrap_or(1).max(1);
        let mut tokens = vec![0i32; b * s];
        for (i, p) in prefixes.iter().enumerate() {
            let n = p.len().min(s);
            tokens[i * s..i * s + n].copy_from_slice(&p[p.len() - n..]);
        }
        let logits = self.model.forward(&tokens, b, s)?;
        let vocab = self.model.spec.vocab;
        prefixes
            .iter()
            .enumerate()
            .map(|(i, p)| {
                let pos = p.len().clamp(1, s) - 1;
                let row = logits.row(i * s + pos);
                anyhow::ensure!(row.len() == vocab, "logit row width mismatch");
                Ok(crate::runtime::argmax_slice(row) as i32)
            })
            .collect()
    }

    fn dvfs_transitions(&self) -> usize {
        self.schedule.transitions()
    }
}

impl BatchExecutor for GraphExecutor {
    fn batch_capacity(&self) -> usize {
        self.batch
    }

    fn seq_len(&self) -> usize {
        self.seq
    }

    fn run(&mut self, prefixes: &[Vec<i32>]) -> Result<Vec<i32>> {
        anyhow::ensure!(prefixes.len() <= self.batch, "over-full batch");
        anyhow::ensure!(!prefixes.is_empty(), "empty batch");
        // Pad to the static (B, S) shape; causality makes right-padding
        // safe. The sim backend reads B from the literal, so partial
        // batches only pay for the rows they actually carry. Prefixes
        // longer than the context window keep their LAST seq tokens — the
        // newest context is what the next token must condition on.
        let b = if self.dynamic_batch { prefixes.len() } else { self.batch };
        let mut tokens = vec![0i32; b * self.seq];
        for (i, p) in prefixes.iter().enumerate() {
            let n = p.len().min(self.seq);
            tokens[i * self.seq..i * self.seq + n].copy_from_slice(&p[p.len() - n..]);
        }
        let tok_buf = self.rt.upload(&literal_i32(&tokens, &[b, self.seq])?)?;
        let mut inputs: Vec<&Buffer> = self.params.iter().collect();
        inputs.push(&tok_buf);
        let logits = self.exe.run_b1(&inputs)?;
        // logits: (b, S, vocab); read the argmax at each prefix's last pos.
        prefixes
            .iter()
            .enumerate()
            .map(|(i, p)| {
                // Empty prefixes read position 0 (all-padding row) instead
                // of underflowing.
                let pos = p.len().clamp(1, self.seq) - 1;
                logits.argmax_span((i * self.seq + pos) * self.vocab, self.vocab)
            })
            .collect()
    }

    fn dvfs_transitions(&self) -> usize {
        self.schedule.transitions()
    }
}

/// Coordinator-wide configuration: per-shard batching plus routing and
/// admission-control knobs.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    pub batcher: BatcherConfig,
    /// Executor shards (threads). Each owns its own queue + executor.
    pub shards: usize,
    /// Per-shard queue bound for admission control; 0 = unbounded.
    pub queue_cap: usize,
    /// Deadline applied to requests submitted without an explicit one.
    pub default_deadline: Option<Duration>,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        Self {
            batcher: BatcherConfig::default(),
            shards: 1,
            queue_cap: 0,
            default_deadline: None,
        }
    }
}

impl CoordinatorConfig {
    pub fn sharded(shards: usize) -> Self {
        Self { shards: shards.max(1), ..Self::default() }
    }
}

/// Everything `submit_spec` needs to route one request.
#[derive(Debug, Clone)]
pub struct SubmitSpec {
    pub tokens: Vec<i32>,
    pub max_new_tokens: usize,
    pub deadline: Option<Instant>,
}

impl SubmitSpec {
    pub fn next_token(tokens: Vec<i32>) -> Self {
        Self { tokens, max_new_tokens: 1, deadline: None }
    }

    pub fn generate(tokens: Vec<i32>, max_new_tokens: usize) -> Self {
        Self { tokens, max_new_tokens: max_new_tokens.max(1), deadline: None }
    }

    pub fn with_deadline(mut self, d: Duration) -> Self {
        self.deadline = Some(Instant::now() + d);
        self
    }
}

struct Shard {
    tx: Option<Sender<Request>>,
    handle: Option<JoinHandle<()>>,
    /// Requests queued (sent, not yet pulled into a batch).
    depth: Arc<AtomicUsize>,
    /// Set by the shard thread when its executor failed to construct: the
    /// router must skip it (its instant drain-and-shed would otherwise
    /// keep its queue depth near zero and attract all least-loaded
    /// routing, starving healthy shards).
    dead: Arc<std::sync::atomic::AtomicBool>,
    metrics: Arc<Metrics>,
}

/// The running coordinator.
pub struct Coordinator {
    shards: Vec<Shard>,
    cfg: CoordinatorConfig,
    rr: AtomicUsize,
    next_id: AtomicU64,
    /// Aggregate metrics across all shards (live counters; per-shard views
    /// via [`Coordinator::shard_metrics`]).
    pub metrics: Arc<Metrics>,
}

impl Coordinator {
    /// Single-shard back-compat constructor: one executor thread, unbounded
    /// queue, no default deadline.
    pub fn start<F>(cfg: BatcherConfig, make_executor: F) -> Self
    where
        F: FnOnce() -> Result<Box<dyn BatchExecutor>> + Send + 'static,
    {
        let coord_cfg = CoordinatorConfig { batcher: cfg, ..CoordinatorConfig::default() };
        Self::start_with(coord_cfg, vec![Box::new(make_executor) as ShardFactory])
    }

    /// Start `cfg.shards` executor threads. `make_executor(shard)` runs on
    /// each shard's own thread (PJRT handles never cross threads).
    pub fn start_sharded<F>(cfg: CoordinatorConfig, make_executor: F) -> Self
    where
        F: Fn(usize) -> Result<Box<dyn BatchExecutor>> + Send + Sync + 'static,
    {
        let n = cfg.shards.max(1);
        let f = Arc::new(make_executor);
        let factories: Vec<ShardFactory> = (0..n)
            .map(|s| {
                let f = f.clone();
                Box::new(move || f(s)) as ShardFactory
            })
            .collect();
        Self::start_with(cfg, factories)
    }

    fn start_with(cfg: CoordinatorConfig, factories: Vec<ShardFactory>) -> Self {
        let metrics = Arc::new(Metrics::default());
        let shards: Vec<Shard> = factories
            .into_iter()
            .enumerate()
            .map(|(s, f)| spawn_shard(s, f, cfg.batcher.clone(), metrics.clone()))
            .collect();
        Self {
            shards,
            cfg,
            rr: AtomicUsize::new(0),
            next_id: AtomicU64::new(0),
            metrics,
        }
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// Per-shard metrics views (index = shard id).
    pub fn shard_metrics(&self) -> Vec<Arc<Metrics>> {
        self.shards.iter().map(|s| s.metrics.clone()).collect()
    }

    /// Aggregate snapshot: per-shard serving metrics merged (percentiles
    /// over the union of latency samples) plus the submission-side
    /// counters (arrivals, admission rejections) that only the
    /// coordinator's global view records.
    pub fn merged_snapshot(&self) -> super::metrics::MetricsSnapshot {
        let mut s = Metrics::merged(&self.shard_metrics());
        let g = self.metrics.snapshot();
        s.requests = g.requests;
        s.rejected = g.rejected;
        s
    }

    /// Submit a next-token request (back-compat). Never panics: when the
    /// request cannot be accepted (all queues full or all executors gone),
    /// the returned channel yields a `shed` response instead.
    pub fn submit(&self, tokens: Vec<i32>) -> Receiver<Response> {
        self.submit_spec(SubmitSpec::next_token(tokens))
    }

    /// Submit with full control over decode length and deadline.
    pub fn submit_spec(&self, spec: SubmitSpec) -> Receiver<Response> {
        let (rtx, rrx) = channel();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.metrics.requests.fetch_add(1, Ordering::Relaxed);
        let deadline = spec
            .deadline
            .or_else(|| self.cfg.default_deadline.map(|d| Instant::now() + d));
        let mut req = Request {
            id,
            tokens: spec.tokens,
            max_new_tokens: spec.max_new_tokens.max(1),
            deadline,
            respond: rtx,
            submitted: Instant::now(),
        };

        // Route: start at the round-robin cursor, prefer the least-loaded
        // shard (ties keep the round-robin order), skip shards over the
        // queue bound or with a dead executor.
        let n = self.shards.len();
        let start = self.rr.fetch_add(1, Ordering::Relaxed);
        let mut order: Vec<usize> = (0..n).map(|k| (start + k) % n).collect();
        // Snapshot each depth exactly once: re-reading the live atomics per
        // comparison could present the sort with an inconsistent order
        // (which std's sort detects by panicking).
        order.sort_by_cached_key(|&s| self.shards[s].depth.load(Ordering::Relaxed));
        for &s in &order {
            let shard = &self.shards[s];
            if shard.dead.load(Ordering::Relaxed) {
                continue;
            }
            let Some(tx) = shard.tx.as_ref() else { continue };
            // Reserve the queue slot before sending (a check-then-add gap
            // would let concurrent submitters overshoot the cap).
            let prev = shard.depth.fetch_add(1, Ordering::Relaxed);
            if self.cfg.queue_cap > 0 && prev >= self.cfg.queue_cap {
                shard.depth.fetch_sub(1, Ordering::Relaxed);
                continue;
            }
            match tx.send(req) {
                Ok(()) => return rrx,
                Err(std::sync::mpsc::SendError(r)) => {
                    // Executor thread died; try the next shard.
                    shard.depth.fetch_sub(1, Ordering::Relaxed);
                    req = r;
                }
            }
        }

        // Rejected: every queue is full (backpressure) or every executor is
        // gone. Answer on the caller's channel rather than panicking.
        self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
        let _ = req.respond.send(Response {
            id,
            next_token: 0,
            tokens: Vec::new(),
            latency: req.submitted.elapsed(),
            shard: usize::MAX,
            shed: true,
        });
        rrx
    }

    /// Drain and stop every shard.
    pub fn shutdown(mut self) -> Result<()> {
        for s in &mut self.shards {
            drop(s.tx.take());
        }
        for s in &mut self.shards {
            if let Some(h) = s.handle.take() {
                h.join().expect("shard thread panicked");
            }
        }
        Ok(())
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        for s in &mut self.shards {
            drop(s.tx.take());
        }
        for s in &mut self.shards {
            if let Some(h) = s.handle.take() {
                let _ = h.join();
            }
        }
    }
}

type ShardFactory = Box<dyn FnOnce() -> Result<Box<dyn BatchExecutor>> + Send>;

/// Spawn one shard: queue + batcher + executor loop. The loop never
/// propagates per-batch errors out of the thread — a failed batch or a
/// client that dropped its receiver is logged and the shard keeps serving
/// (the seed implementation `?`-ed out and wedged every queued client).
fn spawn_shard(
    shard_id: usize,
    make_executor: ShardFactory,
    batcher_cfg: BatcherConfig,
    global: Arc<Metrics>,
) -> Shard {
    let (tx, rx): (Sender<Request>, Receiver<Request>) = channel();
    let metrics = Arc::new(Metrics::default());
    let m = metrics.clone();
    let depth = Arc::new(AtomicUsize::new(0));
    let d = depth.clone();
    let dead = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let dead_flag = dead.clone();
    let handle = std::thread::spawn(move || {
        let mut exec = match make_executor() {
            Ok(e) => e,
            Err(e) => {
                eprintln!("[coordinator] shard {shard_id}: executor construction failed: {e:#}");
                // Take the shard out of rotation, then drain anything that
                // raced in so those clients get shed responses instead of
                // hanging.
                dead_flag.store(true, Ordering::Relaxed);
                while let Ok(req) = rx.recv() {
                    d.fetch_sub(1, Ordering::Relaxed);
                    shed_one(shard_id, req, &m, &global);
                }
                return;
            }
        };
        let cfg = BatcherConfig {
            batch_size: batcher_cfg.batch_size.min(exec.batch_capacity()).max(1),
            ..batcher_cfg
        };
        let batcher = Batcher::new(cfg, rx);
        while let Some(batch) = batcher.next_batch() {
            d.fetch_sub(batch.len(), Ordering::Relaxed);
            // Shed-on-deadline: drop requests that expired while queued.
            let now = Instant::now();
            let (live, expired): (Vec<Request>, Vec<Request>) =
                batch.into_iter().partition(|r| match r.deadline {
                    Some(dl) => now <= dl,
                    None => true,
                });
            for req in expired {
                shed_one(shard_id, req, &m, &global);
            }
            if live.is_empty() {
                continue;
            }

            let prefixes: Vec<Vec<i32>> = live.iter().map(|r| r.tokens.clone()).collect();
            let max_new: Vec<usize> = live.iter().map(|r| r.max_new_tokens).collect();
            let generated = match exec.generate(&prefixes, &max_new) {
                Ok(g) => g,
                Err(e) => {
                    eprintln!("[coordinator] shard {shard_id}: batch failed: {e:#}");
                    for g in [&m, &global] {
                        g.exec_errors.fetch_add(1, Ordering::Relaxed);
                    }
                    for req in live {
                        shed_one(shard_id, req, &m, &global);
                    }
                    continue;
                }
            };

            let n_tokens: u64 = generated.iter().map(|g| g.len() as u64).sum();
            let batch_tokens: u64 = prefixes.iter().map(|p| p.len() as u64).sum();
            let transitions = exec.dvfs_transitions() as u64;
            for g in [&m, &global] {
                g.batches.fetch_add(1, Ordering::Relaxed);
                g.batch_tokens.fetch_add(batch_tokens, Ordering::Relaxed);
                g.generated_tokens.fetch_add(n_tokens, Ordering::Relaxed);
                g.dvfs_transitions.fetch_add(transitions, Ordering::Relaxed);
            }
            for (req, toks) in live.into_iter().zip(generated) {
                let latency = req.submitted.elapsed();
                for g in [&m, &global] {
                    g.record_latency(latency);
                    g.responses.fetch_add(1, Ordering::Relaxed);
                }
                // Receiver may have gone away (client disconnect); that
                // must never unwind or stall the shard.
                let _ = req.respond.send(Response {
                    id: req.id,
                    next_token: toks.first().copied().unwrap_or(0),
                    tokens: toks,
                    latency,
                    shard: shard_id,
                    shed: false,
                });
            }
        }
    });
    Shard { tx: Some(tx), handle: Some(handle), depth, dead, metrics }
}

fn shed_one(shard_id: usize, req: Request, m: &Metrics, global: &Metrics) {
    for g in [m, global] {
        g.shed.fetch_add(1, Ordering::Relaxed);
    }
    let _ = req.respond.send(Response {
        id: req.id,
        next_token: 0,
        tokens: Vec::new(),
        latency: req.submitted.elapsed(),
        shard: shard_id,
        shed: true,
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;
    use std::time::Duration;

    /// Deterministic fake: next token = sum of prefix mod 97.
    struct Echo {
        cap: usize,
    }

    impl BatchExecutor for Echo {
        fn batch_capacity(&self) -> usize {
            self.cap
        }
        fn seq_len(&self) -> usize {
            16
        }
        fn run(&mut self, prefixes: &[Vec<i32>]) -> Result<Vec<i32>> {
            Ok(prefixes.iter().map(|p| p.iter().sum::<i32>() % 97).collect())
        }
        fn dvfs_transitions(&self) -> usize {
            2
        }
    }

    fn start(batch: usize) -> Coordinator {
        Coordinator::start(
            BatcherConfig { batch_size: batch, timeout: Duration::from_millis(2) },
            move || Ok(Box::new(Echo { cap: batch }) as Box<dyn BatchExecutor>),
        )
    }

    fn start_shards(n: usize, batch: usize) -> Coordinator {
        Coordinator::start_sharded(
            CoordinatorConfig {
                batcher: BatcherConfig { batch_size: batch, timeout: Duration::from_millis(2) },
                shards: n,
                ..CoordinatorConfig::default()
            },
            move |_shard| Ok(Box::new(Echo { cap: batch }) as Box<dyn BatchExecutor>),
        )
    }

    #[test]
    fn every_request_answered_exactly_once() {
        let c = start(4);
        let mut rxs = Vec::new();
        let mut want = Vec::new();
        let mut rng = Rng::seed_from_u64(1);
        for i in 0..97 {
            let tokens: Vec<i32> =
                (0..1 + rng.gen_usize(10)).map(|_| rng.gen_usize(50) as i32).collect();
            want.push((i as u64, tokens.iter().sum::<i32>() % 97));
            rxs.push(c.submit(tokens));
        }
        for (rx, (id, tok)) in rxs.into_iter().zip(want) {
            let resp = rx.recv_timeout(Duration::from_secs(5)).unwrap();
            assert_eq!(resp.id, id);
            assert_eq!(resp.next_token, tok);
            assert!(!resp.shed);
            // one response only
            assert!(rx.recv_timeout(Duration::from_millis(1)).is_err());
        }
        let m = &c.metrics;
        assert_eq!(m.requests.load(Ordering::Relaxed), 97);
        assert_eq!(m.responses.load(Ordering::Relaxed), 97);
        c.shutdown().unwrap();
    }

    #[test]
    fn batching_actually_batches() {
        let c = start(8);
        let rxs: Vec<_> = (0..64).map(|i| c.submit(vec![i])).collect();
        for rx in rxs {
            rx.recv_timeout(Duration::from_secs(5)).unwrap();
        }
        let batches = c.metrics.batches.load(Ordering::Relaxed);
        assert!(batches < 64, "no batching happened: {batches}");
        assert!(c.metrics.mean_batch_occupancy() > 1.1);
        c.shutdown().unwrap();
    }

    #[test]
    fn dvfs_transitions_accounted_per_batch() {
        let c = start(4);
        let rxs: Vec<_> = (0..8).map(|i| c.submit(vec![i])).collect();
        for rx in rxs {
            rx.recv().unwrap();
        }
        let b = c.metrics.batches.load(Ordering::Relaxed);
        assert_eq!(c.metrics.dvfs_transitions.load(Ordering::Relaxed), 2 * b);
        c.shutdown().unwrap();
    }

    #[test]
    fn shutdown_drains_cleanly() {
        let c = start(2);
        let rx = c.submit(vec![1, 2, 3]);
        c.shutdown().unwrap();
        assert_eq!(rx.recv().unwrap().next_token, 6);
    }

    // ------------------------------------------------- sharded serving

    #[test]
    fn sharded_answers_every_request_and_spreads_load() {
        let c = start_shards(4, 4);
        assert_eq!(c.n_shards(), 4);
        let mut rxs = Vec::new();
        let mut want = Vec::new();
        for i in 0..200i32 {
            want.push((i % 50) % 97);
            rxs.push(c.submit(vec![i % 50]));
        }
        for (rx, want) in rxs.into_iter().zip(want) {
            let r = rx.recv_timeout(Duration::from_secs(5)).unwrap();
            assert_eq!(r.next_token, want);
            assert!(r.shard < 4);
        }
        // Router spread work across shards: no shard did everything.
        let busy: Vec<u64> = c
            .shard_metrics()
            .iter()
            .map(|m| m.responses.load(Ordering::Relaxed))
            .collect();
        assert_eq!(busy.iter().sum::<u64>(), 200);
        assert!(busy.iter().filter(|&&b| b > 0).count() >= 2, "one shard took all: {busy:?}");
        c.shutdown().unwrap();
    }

    #[test]
    fn generate_decodes_multiple_tokens() {
        // Echo's next token is (sum of prefix) % 97, so the decode chain is
        // deterministic and checkable in plain code.
        let c = start_shards(2, 4);
        let prefix = vec![3, 5];
        let rx = c.submit_spec(SubmitSpec::generate(prefix.clone(), 4));
        let r = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        let mut seq = prefix;
        let mut want = Vec::new();
        for _ in 0..4 {
            let t = seq.iter().sum::<i32>() % 97;
            want.push(t);
            seq.push(t);
        }
        assert_eq!(r.tokens, want);
        assert_eq!(r.next_token, want[0]);
        assert_eq!(c.metrics.generated_tokens.load(Ordering::Relaxed), 4);
        c.shutdown().unwrap();
    }

    #[test]
    fn generate_slides_context_at_seq_cap() {
        // seq_len = 16; a 16-token prefix forces the slide path.
        let c = start(2);
        let rx = c.submit_spec(SubmitSpec::generate(vec![1; 16], 3));
        let r = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(r.tokens.len(), 3);
        c.shutdown().unwrap();
    }

    /// Echo's decode chain under the sliding window, mirrored in plain code.
    fn echo_chain(prefix: &[i32], cap: usize, steps: usize) -> Vec<i32> {
        let mut seq: Vec<i32> = prefix[prefix.len().saturating_sub(cap)..].to_vec();
        let mut want = Vec::new();
        for _ in 0..steps {
            let t = seq.iter().sum::<i32>() % 97;
            want.push(t);
            if seq.len() >= cap {
                seq.remove(0);
            }
            seq.push(t);
        }
        want
    }

    #[test]
    fn generate_conditions_on_newest_context_for_long_prefixes() {
        // A 40-token prefix against seq_len = 16: decode must condition on
        // the LAST 16 tokens, not the first.
        let c = start(4);
        let prefix: Vec<i32> = (0..40).collect();
        let rx = c.submit_spec(SubmitSpec::generate(prefix.clone(), 3));
        let r = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(r.tokens, echo_chain(&prefix, 16, 3));
        c.shutdown().unwrap();
    }

    #[test]
    fn mixed_decode_lengths_in_one_batch() {
        // Different max_new in one batch: short requests finish early (and
        // drop out of later forward passes), long ones keep decoding.
        let c = start(4);
        let rx1 = c.submit_spec(SubmitSpec::generate(vec![1], 1));
        let rx2 = c.submit_spec(SubmitSpec::generate(vec![2], 5));
        let r1 = rx1.recv_timeout(Duration::from_secs(5)).unwrap();
        let r2 = rx2.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(r1.tokens, echo_chain(&[1], 16, 1));
        assert_eq!(r2.tokens, echo_chain(&[2], 16, 5));
        c.shutdown().unwrap();
    }

    #[test]
    fn dead_shard_is_skipped_and_healthy_shards_serve() {
        let c = Coordinator::start_sharded(
            CoordinatorConfig {
                batcher: BatcherConfig { batch_size: 2, timeout: Duration::from_millis(1) },
                shards: 2,
                ..CoordinatorConfig::default()
            },
            move |shard| {
                if shard == 0 {
                    anyhow::bail!("shard 0 never comes up");
                }
                Ok(Box::new(Echo { cap: 2 }) as Box<dyn BatchExecutor>)
            },
        );
        // Let shard 0 mark itself out of rotation; afterwards everything
        // must be served by shard 1 rather than shed by the dead shard.
        std::thread::sleep(Duration::from_millis(200));
        let rxs: Vec<_> = (0..20).map(|i| c.submit(vec![i])).collect();
        for (i, rx) in rxs.into_iter().enumerate() {
            let r = rx.recv_timeout(Duration::from_secs(5)).unwrap();
            assert!(!r.shed, "request {i} shed despite a healthy shard");
            assert_eq!(r.shard, 1);
        }
        c.shutdown().unwrap();
    }

    #[test]
    fn expired_deadline_is_shed_not_run() {
        // Deadline already in the past: the shard must shed, not execute.
        let c = start(4);
        let spec = SubmitSpec {
            tokens: vec![1, 2, 3],
            max_new_tokens: 1,
            deadline: Some(Instant::now() - Duration::from_millis(1)),
        };
        let r = c.submit_spec(spec).recv_timeout(Duration::from_secs(5)).unwrap();
        assert!(r.shed);
        assert!(r.tokens.is_empty());
        assert_eq!(c.metrics.shed.load(Ordering::Relaxed), 1);
        assert_eq!(c.metrics.responses.load(Ordering::Relaxed), 0);
        c.shutdown().unwrap();
    }

    /// Executor that blocks until released — lets tests fill queues
    /// deterministically.
    struct Gate {
        release: Receiver<()>,
    }

    impl BatchExecutor for Gate {
        fn batch_capacity(&self) -> usize {
            1
        }
        fn seq_len(&self) -> usize {
            16
        }
        fn run(&mut self, prefixes: &[Vec<i32>]) -> Result<Vec<i32>> {
            let _ = self.release.recv();
            Ok(vec![0; prefixes.len()])
        }
    }

    #[test]
    fn full_queues_reject_with_backpressure() {
        let (gate_tx, gate_rx) = channel::<()>();
        let gate_rx = std::sync::Mutex::new(Some(gate_rx));
        let c = Coordinator::start_sharded(
            CoordinatorConfig {
                batcher: BatcherConfig { batch_size: 1, timeout: Duration::from_millis(1) },
                shards: 1,
                queue_cap: 2,
                ..CoordinatorConfig::default()
            },
            move |_s| {
                let rx = gate_rx.lock().unwrap().take().expect("single shard");
                Ok(Box::new(Gate { release: rx }) as Box<dyn BatchExecutor>)
            },
        );
        // First request occupies the executor; then fill the queue beyond
        // the cap. Depth only decrements when the batcher pulls, so after
        // cap is reached submissions must come back shed immediately.
        let mut rxs = Vec::new();
        for i in 0..8i32 {
            rxs.push(c.submit(vec![i]));
            // Give the shard a beat to pull the first request into a batch.
            if i == 0 {
                std::thread::sleep(Duration::from_millis(20));
            }
        }
        let rejected = c.metrics.rejected.load(Ordering::Relaxed);
        assert!(rejected >= 1, "queue_cap=2 never rejected under an 8-deep burst");
        // Release the gate for every possible run call, then drain.
        for _ in 0..16 {
            let _ = gate_tx.send(());
        }
        let mut shed = 0;
        let mut ok = 0;
        for rx in rxs {
            let r = rx.recv_timeout(Duration::from_secs(5)).unwrap();
            if r.shed {
                shed += 1;
            } else {
                ok += 1;
            }
        }
        assert_eq!(shed as u64, rejected);
        assert!(ok >= 2); // executor slot + queued requests under the cap
        c.shutdown().unwrap();
    }

    #[test]
    fn dropped_receiver_does_not_wedge_the_shard() {
        let c = start(2);
        // Client gives up immediately: drop the receiver before the shard
        // responds.
        drop(c.submit(vec![1, 2]));
        // The shard must still be alive and serving.
        let rx = c.submit(vec![4, 4]);
        assert_eq!(rx.recv_timeout(Duration::from_secs(5)).unwrap().next_token, 8);
        c.shutdown().unwrap();
    }

    /// Executor whose first run() fails — the shard must shed the batch
    /// and keep serving rather than kill the thread.
    struct Faulty {
        fail_first: u32,
    }

    impl BatchExecutor for Faulty {
        fn batch_capacity(&self) -> usize {
            4
        }
        fn seq_len(&self) -> usize {
            16
        }
        fn run(&mut self, prefixes: &[Vec<i32>]) -> Result<Vec<i32>> {
            if self.fail_first > 0 {
                self.fail_first -= 1;
                anyhow::bail!("injected executor fault");
            }
            Ok(prefixes.iter().map(|p| p.len() as i32).collect())
        }
    }

    #[test]
    fn executor_error_sheds_batch_and_shard_survives() {
        let c = Coordinator::start(
            BatcherConfig { batch_size: 1, timeout: Duration::from_millis(1) },
            || Ok(Box::new(Faulty { fail_first: 1 }) as Box<dyn BatchExecutor>),
        );
        let r1 = c.submit(vec![1, 2, 3]).recv_timeout(Duration::from_secs(5)).unwrap();
        assert!(r1.shed, "failed batch must shed its requests");
        let r2 = c.submit(vec![1, 2, 3]).recv_timeout(Duration::from_secs(5)).unwrap();
        assert!(!r2.shed);
        assert_eq!(r2.next_token, 3);
        assert_eq!(c.metrics.exec_errors.load(Ordering::Relaxed), 1);
        c.shutdown().unwrap();
    }

    #[test]
    fn submit_after_total_executor_loss_sheds_instead_of_panicking() {
        // Executor construction fails: the shard drains with shed
        // responses and later submissions still answer.
        let c = Coordinator::start(BatcherConfig::default(), || {
            anyhow::bail!("no executor today")
        });
        let r = c.submit(vec![1]).recv_timeout(Duration::from_secs(5)).unwrap();
        assert!(r.shed);
        c.shutdown().unwrap();
    }
}
