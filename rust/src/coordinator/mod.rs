//! L3 serving coordinator: request router → dynamic batcher → executor.
//!
//! The offline build has no tokio, so the coordinator is built directly on
//! std threads + channels (arguably closer to the deterministic lockstep
//! the paper's systolic target wants anyway). Python never appears here:
//! the executor thread owns the graph executable loaded from `artifacts/`
//! through the runtime backend (sim by default, PJRT with `--features
//! xla`).
//!
//! DVFS-awareness (§III-C3): each quantized model carries a
//! [`crate::dvfs::Schedule`]; the executor executes whole batches and
//! accounts the simulated per-class residency + transition overhead into
//! the metrics, mirroring how the systolic array would clock the pass.

pub mod batch;
pub mod metrics;
pub mod server;

pub use batch::{Batcher, BatcherConfig};
pub use metrics::Metrics;
pub use server::{BatchExecutor, Coordinator, Request, Response};
