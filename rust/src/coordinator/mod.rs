//! L3 serving coordinator: router → sharded batchers → executor threads.
//!
//! The offline build has no tokio, so the coordinator is built directly on
//! std threads + channels (arguably closer to the deterministic lockstep
//! the paper's systolic target wants anyway). Python never appears here:
//! each executor thread owns a graph executable loaded from `artifacts/`
//! through the runtime backend (sim by default, PJRT with `--features
//! xla`).
//!
//! Architecture (PR 3 sharding + PR 5 continuous batching):
//!
//! ```text
//!     submit(Request) / submit_or_shed(Request)
//!                    │ round-robin + least-loaded stealing,
//!                    │ bounded queues (admission control)
//!        ┌───────────┼───────────┐
//!     shard 0     shard 1  …  shard N-1        (threads)
//!     Batcher     Batcher     Batcher          (blocking when idle,
//!        │           │           │              try_fill between steps)
//!     live set    live set    live set         (per-request DecodeState
//!        │           │           │              + KV cache; join/retire
//!     Executor    Executor    Executor          mid-flight, one token
//!        │           │           │              per request per step)
//!        └───────────┴───────────┘
//!          per-shard Metrics  →  Metrics::merged (p50/p95/p99, tok/s)
//! ```
//!
//! Decode is **KV-cached and continuously batched** (PR 5): each shard
//! steps a set of heterogeneous-length requests one token at a time,
//! admitting queued requests into free slots at every step boundary and
//! retiring finished ones immediately — no request ever pads to its
//! neighbor's prefix length, and no request waits for the current batch
//! to drain before starting. The cached path is pinned bit-identical to
//! full-prefix recompute by `tests/decode_equiv.rs`. Since PR 8 each
//! shard's per-request caches are carved from a shared paged
//! [`BlockPool`](crate::runtime::BlockPool) (fixed-size blocks, frozen
//! shared prefixes, bounded memory — exhaustion sheds as brown-out
//! backpressure instead of panicking).
//!
//! Shards are **supervised** (PR 7): each shard thread restarts its
//! executor after a death (capped exponential backoff + jitter), re-homes
//! orphaned requests onto survivors under per-request and global retry
//! budgets, and degrades gracefully under sustained overload (brown-out:
//! decode-budget clamping, then priority shedding). Every shed carries a
//! [`ShedReason`](metrics::ShedReason); the fault-injection subsystem
//! behind it lives in [`crate::util::failpoint`] and the whole layer is
//! pinned by `tests/chaos.rs`. See DESIGN.md §Fault model & recovery.
//!
//! DVFS-awareness (§III-C3): each quantized model carries a
//! [`crate::dvfs::Schedule`]; [`Schedule::shard`](crate::dvfs::Schedule::shard)
//! splits it so every executor accounts its own per-class residency +
//! transition overhead into the metrics, mirroring how each slice of the
//! systolic array would clock its pass.

pub mod batch;
pub mod loadgen;
pub mod metrics;
pub mod queue;
pub mod server;
pub mod spec;

pub use batch::{Batcher, BatcherConfig};
pub use queue::{Pop, PushError, RequestQueue};
pub use loadgen::{LoadgenConfig, LoadgenReport, SyntheticExecutor};
pub use metrics::{Metrics, MetricsSnapshot, ShedReason, SpecDecodeStats};
pub use spec::{SpecConfig, SpecDrafter, SpecExecutor, SpecVerifier};
pub use server::{
    BatchExecutor, Coordinator, CoordinatorConfig, QuantExecutor, Request, Response, SubmitError,
    SupervisorConfig,
};
