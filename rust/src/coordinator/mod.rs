//! L3 serving coordinator: router → sharded batchers → executor threads.
//!
//! The offline build has no tokio, so the coordinator is built directly on
//! std threads + channels (arguably closer to the deterministic lockstep
//! the paper's systolic target wants anyway). Python never appears here:
//! each executor thread owns a graph executable loaded from `artifacts/`
//! through the runtime backend (sim by default, PJRT with `--features
//! xla`).
//!
//! Architecture (PR 3):
//!
//! ```text
//!            submit / submit_spec
//!                    │ round-robin + least-loaded stealing,
//!                    │ bounded queues (admission control)
//!        ┌───────────┼───────────┐
//!     shard 0     shard 1  …  shard N-1        (threads)
//!     Batcher     Batcher     Batcher          (dynamic batching)
//!     Executor    Executor    Executor         (GraphExecutor / fake)
//!        │           │           │   deadline shed, decode loop
//!        └───────────┴───────────┘
//!          per-shard Metrics  →  Metrics::merged (p50/p95/p99, tok/s)
//! ```
//!
//! DVFS-awareness (§III-C3): each quantized model carries a
//! [`crate::dvfs::Schedule`]; [`Schedule::shard`](crate::dvfs::Schedule::shard)
//! splits it so every executor accounts its own per-class residency +
//! transition overhead into the metrics, mirroring how each slice of the
//! systolic array would clock its pass.

pub mod batch;
pub mod loadgen;
pub mod metrics;
pub mod server;

pub use batch::{Batcher, BatcherConfig};
pub use loadgen::{LoadgenConfig, LoadgenReport, SyntheticExecutor};
pub use metrics::{Metrics, MetricsSnapshot};
pub use server::{
    BatchExecutor, Coordinator, CoordinatorConfig, QuantExecutor, Request, Response, SubmitSpec,
};
