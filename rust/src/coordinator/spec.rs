//! Speculative decoding on the variant ladder (PR 9).
//!
//! A cheap **drafter** (a natively packed HALO variant) proposes up to
//! `k` tokens ahead through its own incremental KV-cached chain; the
//! **verifier** (the served packed variant, or the dense rung of the
//! ladder) scores the whole proposal in *one* batched
//! `forward_incremental` pass, accepts the longest agreeing prefix plus
//! one bonus token, and rolls both block tables back to the accept point
//! — truncation ([`KvCache::truncate_to`]), never re-prefill.
//!
//! **Exactness.** Acceptance compares the verifier's own selections
//! (seeded sampler or argmax, via the shared
//! [`select_token`](super::server::select_token)) against the greedy
//! drafts, so the emitted chain is *bit-identical* to a verifier-only
//! decode regardless of drafter quality: drafter numerics only move the
//! acceptance rate, never a token. Two structural invariants carry the
//! proof (pinned across the whole pairing matrix by
//! `tests/decode_equiv.rs`):
//!
//! - **No slide before verification.** The draft length is clamped to
//!   the context headroom (`k_eff ≤ seq_len − window`), so every drafted
//!   row is appended and verified before any window slide can occur; the
//!   only push that may slide is the final emitted token — exactly the
//!   push that slides at the same point in a verifier-only chain.
//! - **Position conservation.** [`KvCache::truncate_to`] rewinds the
//!   monotone committed-position count by the rejected rows, so the
//!   surviving rows (and every later append) sit at the same ring
//!   positions a verifier-only chain would give them.
//!
//! **Speedup.** The verifier amortizes its per-pass fixed costs
//! (interpreter walk, cache bookkeeping, per-call activation
//! quantization) over `k_eff + 1` emitted tokens. Since the integer
//! W4A8 rewrite the drafter runs **natively packed**
//! ([`SpecDrafter::Packed`]) — packed decode now beats dense wall-clock
//! (`benches/l4_quant_exec.rs` gates `quant_vs_dense_throughput` ≥ 1.0)
//! so expanding the drafter back to dense
//! ([`PackedModel::expand_params`]) would slow drafting down. With
//! drafter and verifier on the same kernels the self-pair win is
//! bounded by per-pass amortization (≈ `(k+1)/k` at full acceptance);
//! the headroom beyond that needs a smaller-capacity drafter model (see
//! ROADMAP). `benches/l7_spec.rs` measures and gates
//! `spec_decode_speedup` in CI.
//!
//! The executor composes with the whole serving stack: it is a
//! [`BatchExecutor`], so continuous batching, brown-out, re-homing and
//! shared-prefix seeding apply unchanged, and the drafter's state rides
//! the request's [`DecodeState`] aux slot through retire / re-home /
//! drop (the same RAII path that releases the verifier's blocks).

use std::any::Any;

use anyhow::{Context, Result};

use super::metrics::SpecDecodeStats;
use super::server::{select_token, BatchExecutor};
use crate::dvfs::Schedule;
use crate::quant::{Matrix, Variant};
use crate::runtime::sim::{self, DenseParams, ModelSpec};
use crate::runtime::{argmax_slice, BlockPool, DecodeState, KvCache, PackedModel, PoolStats};
use crate::util::sync::Arc;

/// Parsed `--spec drafter=halo-perf,k=4` serving configuration.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SpecConfig {
    /// Which HALO variant drafts (packed at load, drafting natively on
    /// the integer kernels).
    pub drafter: Variant,
    /// Maximum tokens drafted per speculative round (clamped at runtime
    /// by the context headroom and the request's remaining budget).
    pub k: usize,
}

impl Default for SpecConfig {
    fn default() -> Self {
        Self { drafter: Variant::PerfOpt, k: 4 }
    }
}

impl SpecConfig {
    /// Parse a `key=value` list: `drafter=halo-perf,k=4`. Drafter names
    /// accept an optional `halo-` prefix over [`Variant::parse`]'s
    /// spellings; omitted keys keep the defaults (`halo-perf`, `k=4`).
    pub fn parse(s: &str) -> Result<Self> {
        let mut cfg = Self::default();
        for part in s.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let (key, val) = part
                .split_once('=')
                .with_context(|| format!("--spec expects key=value pairs, got {part:?}"))?;
            match key.trim() {
                "drafter" => {
                    let name = val.trim();
                    cfg.drafter = Variant::parse(name.strip_prefix("halo-").unwrap_or(name))
                        .with_context(|| {
                            format!(
                                "unknown drafter variant {name:?} \
                                 (use halo-perf, halo-bal, or halo-acc)"
                            )
                        })?;
                }
                "k" => {
                    cfg.k = val
                        .trim()
                        .parse::<usize>()
                        .ok()
                        .filter(|k| (1..=64).contains(k))
                        .with_context(|| format!("draft length k must be 1..=64, got {val:?}"))?;
                }
                other => anyhow::bail!("unknown --spec key {other:?} (expected drafter or k)"),
            }
        }
        Ok(cfg)
    }
}

/// The scoring model of a speculative pair: the rung of the ladder whose
/// chain the pipeline must reproduce bit for bit.
pub enum SpecVerifier {
    /// A packed HALO variant, scoring natively on its codebook tiles.
    Packed(Arc<PackedModel>),
    /// The dense f32 rung (the strongest verifier on the ladder).
    Dense {
        /// Model hyper-parameters (must pair with the drafter's).
        spec: ModelSpec,
        /// Owned dense parameter store driving the shared interpreter.
        params: Arc<DenseParams>,
    },
}

/// The proposing model of a speculative pair. Drafts are always greedy
/// and never touch the request's sampler RNG, so the drafter choice
/// moves the acceptance rate and the wall-clock — never a token.
pub enum SpecDrafter {
    /// A packed HALO variant drafting natively on the integer W4A8
    /// kernels — the default since packed decode beats dense.
    Packed(Arc<PackedModel>),
    /// An owned dense store (tests; ladder experiments pairing dense
    /// numerics against a packed verifier).
    Dense {
        /// Model hyper-parameters (must pair with the verifier's).
        spec: ModelSpec,
        /// Owned dense parameter store driving the shared interpreter.
        params: Arc<DenseParams>,
    },
}

impl SpecDrafter {
    /// The drafter's model hyper-parameters.
    pub fn spec(&self) -> &ModelSpec {
        match self {
            SpecDrafter::Packed(m) => &m.spec,
            SpecDrafter::Dense { spec, .. } => spec,
        }
    }

    fn forward_incremental(
        &self,
        tokens: &[i32],
        pos0: usize,
        cache: &mut KvCache,
    ) -> Result<Matrix> {
        match self {
            SpecDrafter::Packed(m) => m.forward_incremental(tokens, pos0, cache),
            SpecDrafter::Dense { spec, params } => {
                sim::forward_incremental(spec, params.as_ref(), tokens, pos0, cache, false)
            }
        }
    }
}

impl SpecVerifier {
    /// The verifier's model hyper-parameters.
    pub fn spec(&self) -> &ModelSpec {
        match self {
            SpecVerifier::Packed(m) => &m.spec,
            SpecVerifier::Dense { spec, .. } => spec,
        }
    }

    fn forward_full(&self, tokens: &[i32], b: usize, s: usize) -> Result<Matrix> {
        match self {
            SpecVerifier::Packed(m) => m.forward(tokens, b, s),
            SpecVerifier::Dense { spec, params } => {
                sim::forward_logits(spec, params.as_ref(), tokens, b, s)
            }
        }
    }

    fn forward_incremental(
        &self,
        tokens: &[i32],
        pos0: usize,
        cache: &mut KvCache,
    ) -> Result<Matrix> {
        match self {
            SpecVerifier::Packed(m) => m.forward_incremental(tokens, pos0, cache),
            SpecVerifier::Dense { spec, params } => {
                sim::forward_incremental(spec, params.as_ref(), tokens, pos0, cache, false)
            }
        }
    }
}

/// Speculative drafter/verifier pipeline as a serving [`BatchExecutor`]:
/// one [`step`](BatchExecutor::step) runs one speculative round per live
/// request (draft up to `k`, verify in one batched pass, emit the
/// accepted prefix + bonus), so a step may retire several tokens while
/// the coordinator still accounts one schedule pass per step.
pub struct SpecExecutor {
    drafter: SpecDrafter,
    verifier: SpecVerifier,
    k: usize,
    batch: usize,
    schedule: Option<Schedule>,
    verifier_pool: Option<Arc<BlockPool>>,
    drafter_pool: Option<Arc<BlockPool>>,
    stats: SpecDecodeStats,
}

impl SpecExecutor {
    /// Pair a drafter with a verifier. The two must agree on vocabulary
    /// and context window — the drafter proposes token ids the verifier
    /// scores, over the same window trajectory.
    pub fn new(
        drafter: SpecDrafter,
        verifier: SpecVerifier,
        k: usize,
        batch: usize,
    ) -> Result<Self> {
        let ds = drafter.spec();
        let vs = verifier.spec();
        anyhow::ensure!(
            ds.vocab == vs.vocab && ds.seq_len == vs.seq_len,
            "drafter (vocab {}, seq {}) does not pair with the verifier (vocab {}, seq {})",
            ds.vocab,
            ds.seq_len,
            vs.vocab,
            vs.seq_len
        );
        anyhow::ensure!(k >= 1, "draft length k must be ≥ 1");
        let schedule = match &verifier {
            SpecVerifier::Packed(m) => Some(m.schedule.clone()),
            SpecVerifier::Dense { .. } => None,
        };
        Ok(Self {
            drafter,
            verifier,
            k,
            batch: batch.max(1),
            schedule,
            verifier_pool: None,
            drafter_pool: None,
            stats: SpecDecodeStats::default(),
        })
    }

    /// Pair a packed drafter variant with a verifier. Since the integer
    /// W4A8 rewrite the drafter decodes **natively** on its packed tiles
    /// — genuinely faster than a dense expansion, and still proposing
    /// exactly the variant's tokens.
    pub fn from_packed(
        drafter: Arc<PackedModel>,
        verifier: SpecVerifier,
        k: usize,
        batch: usize,
    ) -> Result<Self> {
        Self::new(SpecDrafter::Packed(drafter), verifier, k, batch)
    }

    /// Account DVFS transitions against an explicit schedule slice (one
    /// shard of `Schedule::shard`), instead of the packed verifier's
    /// whole-model schedule (dense verifiers default to none).
    pub fn with_schedule(mut self, schedule: Schedule) -> Self {
        self.schedule = Some(schedule);
        self
    }

    /// Serve the verifier's and drafter's per-request caches from shared
    /// paged pools. Two pools, not one: each side's shared-prefix
    /// registry must only ever seed caches with its *own* K/V numerics.
    pub fn with_kv_pools(mut self, verifier: Arc<BlockPool>, drafter: Arc<BlockPool>) -> Self {
        self.verifier_pool = Some(verifier);
        self.drafter_pool = Some(drafter);
        self
    }

    /// Monotone work counters for this executor's lifetime (the shard
    /// loop publishes them into the metrics gauges after every step).
    pub fn stats(&self) -> SpecDecodeStats {
        self.stats
    }

    fn seq_cap(&self) -> usize {
        self.verifier.spec().seq_len
    }

    fn vocab(&self) -> usize {
        self.verifier.spec().vocab
    }

    /// Detach the drafter's companion state from the request, or build a
    /// fresh one (first step after a fallback path, or a desynced
    /// drafter — the drafter is an accelerator, so any doubt about its
    /// window means rebuild-and-reprefill, never a wrong proposal
    /// surviving into the verify pass with a corrupt cache).
    fn take_draft(&self, s: &mut DecodeState) -> DecodeState {
        if let Some(aux) = s.take_aux() {
            if let Ok(d) = aux.downcast::<DecodeState>() {
                if d.window() == s.window() {
                    return *d;
                }
            }
        }
        let ds = self.drafter.spec();
        let cache = match &self.drafter_pool {
            Some(pool) => pool.new_cache(s.window()),
            None => KvCache::new(ds.n_layers, ds.d_model),
        };
        DecodeState::with_cache(s.window(), s.max_new(), self.seq_cap(), cache)
    }

    /// One speculative round for one request; the drafter state is
    /// detached first so the borrows stay disjoint, and re-parked on the
    /// aux slot even when the round errors (its blocks release through
    /// the request's own retire/drop path either way).
    fn step_one(&mut self, s: &mut DecodeState) -> Result<()> {
        let mut draft = self.take_draft(s);
        let out = self.speculate(s, &mut draft);
        s.set_aux(Box::new(draft) as Box<dyn Any + Send>);
        out
    }

    fn speculate(&mut self, s: &mut DecodeState, d: &mut DecodeState) -> Result<()> {
        let remaining = s.max_new().saturating_sub(s.generated().len());
        if remaining == 0 {
            return Ok(());
        }
        let cap = self.seq_cap();

        // Degenerate empty window: mirror the plain executors'
        // all-padding row (token 0 at position 0) without touching any
        // cache.
        if s.window().is_empty() {
            let logits = self.verifier.forward_full(&[0], 1, 1)?;
            self.stats.verify_rounds += 1;
            self.stats.verify_positions += 1;
            anyhow::ensure!(logits.cols == self.vocab(), "logit row width mismatch");
            let t = select_token(s, logits.row(0));
            s.push_token(t);
            d.push_token(t);
            return Ok(());
        }

        // Re-open a fully-caught-up verifier cache (nothing uncached to
        // anchor the verify pass on): re-evaluate the newest window
        // token. Defensive — every normal round leaves the last emitted
        // token uncached.
        if s.cached_rows() >= s.window().len() {
            let w = s.window().len();
            match s.cache_mut() {
                Some(c) => c.truncate_to(w - 1)?,
                None => anyhow::bail!("speculative state lost its KV cache mid-step"),
            }
        }
        let (new, cached) = s.uncached_suffix()?;
        let u = new.len();

        // Draft budget: stay inside the context headroom so no slide can
        // happen before every drafted row is verified (the exactness
        // invariant — see the module docs), and never draft past the
        // request's remaining decode budget.
        let w_len = cached + u;
        let k_eff = self.k.min(cap - w_len).min(remaining.saturating_sub(1));

        // Drafter proposals: greedy argmax on the drafter's own
        // incremental chain. The drafter never touches the request's
        // sampler RNG, so sampled chains draw the same stream as a
        // verifier-only decode.
        let mut drafts: Vec<i32> = Vec::with_capacity(k_eff);
        if k_eff > 0 {
            if d.cached_rows() >= d.window().len() {
                let w = d.window().len();
                match d.cache_mut() {
                    Some(c) => c.truncate_to(w - 1)?,
                    None => anyhow::bail!("drafter state lost its KV cache mid-step"),
                }
            }
            for _ in 0..k_eff {
                // First iteration catches up everything the drafter has
                // not seen yet (previously emitted tokens); later ones
                // evaluate exactly the proposal just pushed.
                let (dnew, dcached) = d.uncached_suffix()?;
                anyhow::ensure!(!dnew.is_empty(), "drafter chain has nothing to evaluate");
                let Some(dcache) = d.cache_mut() else {
                    anyhow::bail!("drafter state lost its KV cache mid-step");
                };
                let logits = self.drafter.forward_incremental(&dnew, dcached, dcache)?;
                self.stats.draft_positions += dnew.len() as u64;
                let g = argmax_slice(logits.row(dnew.len() - 1)) as i32;
                drafts.push(g);
                d.push_token(g);
            }
        }

        // One batched verifier pass over the uncached suffix + every
        // draft: u + k_eff rows, of which the last k_eff + 1 logits rows
        // score the emitted positions.
        let mut vtokens = new;
        vtokens.extend_from_slice(&drafts);
        let n_rows = vtokens.len();
        let logits = {
            let Some(cache) = s.cache_mut() else {
                anyhow::bail!("speculative state lost its KV cache mid-step");
            };
            self.verifier.forward_incremental(&vtokens, cached, cache)?
        };
        self.stats.verify_rounds += 1;
        self.stats.verify_positions += n_rows as u64;
        self.stats.drafted_tokens += k_eff as u64;
        anyhow::ensure!(logits.cols == self.vocab(), "logit row width mismatch");
        anyhow::ensure!(logits.rows == n_rows, "verifier returned {} rows for {n_rows}", logits.rows);

        // Longest agreeing prefix + one bonus token. Each emitted token
        // is selected exactly as a verifier-only chain would select it
        // (same logits row, same single RNG draw when sampling).
        let mut emitted: Vec<i32> = Vec::new();
        let mut keep = 0usize;
        for i in 0..=k_eff {
            let t = select_token(s, logits.row(u - 1 + i));
            emitted.push(t);
            let accepted = drafts.get(i) == Some(&t);
            if accepted {
                keep += 1;
            }
            if !accepted || emitted.len() >= remaining {
                break;
            }
        }
        self.stats.accepted_tokens += keep as u64;

        // Roll the verifier's block table back to the accept point
        // (truncate, never re-prefill), drop the drafter's rejected
        // proposals, then record the emitted tokens on both chains (the
        // drafter already holds its accepted proposals).
        match s.cache_mut() {
            Some(c) => c.truncate_to(w_len + keep)?,
            None => anyhow::bail!("speculative state lost its KV cache mid-step"),
        }
        d.rollback(k_eff - keep)?;
        for (i, &t) in emitted.iter().enumerate() {
            s.push_token(t);
            if i >= keep {
                d.push_token(t);
            }
        }
        Ok(())
    }
}

impl BatchExecutor for SpecExecutor {
    fn batch_capacity(&self) -> usize {
        self.batch
    }

    fn seq_len(&self) -> usize {
        self.seq_cap()
    }

    /// Verifier-only full-prefix recompute — the equivalence oracle the
    /// speculative chain must match (same contract as `QuantExecutor`).
    fn run(&mut self, prefixes: &[Vec<i32>]) -> Result<Vec<i32>> {
        anyhow::ensure!(prefixes.len() <= self.batch, "over-full batch");
        anyhow::ensure!(!prefixes.is_empty(), "empty batch");
        let b = prefixes.len();
        let cap = self.seq_cap();
        let s = prefixes.iter().map(|p| p.len().min(cap)).max().unwrap_or(1).max(1);
        let mut tokens = vec![0i32; b * s];
        for (i, p) in prefixes.iter().enumerate() {
            let n = p.len().min(s);
            tokens[i * s..i * s + n].copy_from_slice(&p[p.len() - n..]);
        }
        let logits = self.verifier.forward_full(&tokens, b, s)?;
        let vocab = self.vocab();
        prefixes
            .iter()
            .enumerate()
            .map(|(i, p)| {
                let pos = p.len().clamp(1, s) - 1;
                let row = logits.row(i * s + pos);
                anyhow::ensure!(row.len() == vocab, "logit row width mismatch");
                Ok(argmax_slice(row) as i32)
            })
            .collect()
    }

    fn dvfs_transitions(&self) -> usize {
        self.schedule.as_ref().map_or(0, Schedule::transitions)
    }

    fn kv_pool_stats(&self) -> Option<PoolStats> {
        self.verifier_pool.as_ref().map(|p| p.stats())
    }

    fn spec_stats(&self) -> Option<SpecDecodeStats> {
        Some(self.stats)
    }

    /// Verifier cache (pool-seeded when pooled) on the request's state;
    /// the drafter's own cache + window chain parks on the aux slot, so
    /// re-homing rebuilds both from the original prefix (bit-identical
    /// restart) and retiring releases both block chains.
    fn begin(&mut self, prefix: &[i32], max_new: usize) -> Result<DecodeState> {
        let cap = self.seq_cap();
        let tail = &prefix[prefix.len().saturating_sub(cap)..];
        let vs = self.verifier.spec();
        let vcache = match &self.verifier_pool {
            Some(pool) => pool.new_cache(tail),
            None => KvCache::new(vs.n_layers, vs.d_model),
        };
        let mut state = DecodeState::with_cache(prefix, max_new, cap, vcache);
        let ds = self.drafter.spec();
        let dcache = match &self.drafter_pool {
            Some(pool) => pool.new_cache(tail),
            None => KvCache::new(ds.n_layers, ds.d_model),
        };
        let draft = DecodeState::with_cache(prefix, max_new, cap, dcache);
        state.set_aux(Box::new(draft) as Box<dyn Any + Send>);
        Ok(state)
    }

    /// One speculative round per live request, serially — each round is
    /// itself a batched verifier pass, so the win comes from depth, not
    /// from fanning rounds out.
    fn step(&mut self, states: &mut [&mut DecodeState]) -> Result<()> {
        if states.iter().any(|s| !s.has_cache()) {
            return self.step_recompute(states);
        }
        for s in states.iter_mut() {
            self.step_one(s)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{Sampler, SamplingParams};
    use crate::util::Rng;

    fn tiny_spec() -> ModelSpec {
        ModelSpec::synthetic(13, 8, 2, 2, 16, 24)
    }

    fn dense_model(spec: &ModelSpec, seed: u64) -> DenseParams {
        let mut rng = Rng::seed_from_u64(seed);
        let owned: Vec<(String, Vec<usize>, Vec<f32>)> = spec
            .names
            .iter()
            .zip(&spec.shapes)
            .map(|(name, shape)| {
                let n: usize = shape.iter().product();
                let data: Vec<f32> = if name.ends_with(".scale") || name == "ln_f.scale" {
                    vec![1.0; n]
                } else {
                    let s = 1.0 / (shape[0] as f32).sqrt();
                    (0..n).map(|_| rng.gen_normal() as f32 * s).collect()
                };
                (name.clone(), shape.clone(), data)
            })
            .collect();
        DenseParams::from_params(
            spec,
            owned.iter().map(|(n, s, d)| (n.as_str(), s.as_slice(), d.as_slice())),
        )
        .unwrap()
    }

    /// Verifier-only incremental chain: the oracle every speculative
    /// configuration must reproduce bit for bit.
    fn verifier_only(spec: &ModelSpec, p: &DenseParams, prefix: &[i32], max_new: usize) -> Vec<i32> {
        let mut s = DecodeState::with_cache(
            prefix,
            max_new,
            spec.seq_len,
            KvCache::new(spec.n_layers, spec.d_model),
        );
        while !s.done() {
            let (new, cached) = s.uncached_suffix().unwrap();
            let logits =
                sim::forward_incremental(spec, p, &new, cached, s.cache_mut().unwrap(), false)
                    .unwrap();
            let t = select_token(&mut s, logits.row(new.len() - 1));
            s.push_token(t);
        }
        s.into_generated()
    }

    fn spec_exec(drafter_seed: u64, verifier_seed: u64, k: usize) -> (ModelSpec, DenseParams, SpecExecutor) {
        let spec = tiny_spec();
        let verifier = dense_model(&spec, verifier_seed);
        let drafter = dense_model(&spec, drafter_seed);
        let oracle = dense_model(&spec, verifier_seed);
        let ex = SpecExecutor::new(
            SpecDrafter::Dense { spec: spec.clone(), params: Arc::new(drafter) },
            SpecVerifier::Dense { spec: spec.clone(), params: Arc::new(verifier) },
            k,
            4,
        )
        .unwrap();
        (spec, oracle, ex)
    }

    #[test]
    fn parse_accepts_ladder_names_and_rejects_junk() {
        assert_eq!(SpecConfig::parse("").unwrap(), SpecConfig::default());
        let c = SpecConfig::parse("drafter=halo-bal,k=8").unwrap();
        assert_eq!(c.drafter, Variant::Bal);
        assert_eq!(c.k, 8);
        assert_eq!(SpecConfig::parse("drafter=acc").unwrap().drafter, Variant::AccOpt);
        assert_eq!(SpecConfig::parse("k=1").unwrap().k, 1);
        assert!(SpecConfig::parse("drafter=dense").is_err(), "dense cannot draft for itself");
        assert!(SpecConfig::parse("k=0").is_err());
        assert!(SpecConfig::parse("k=65").is_err());
        assert!(SpecConfig::parse("k=four").is_err());
        assert!(SpecConfig::parse("draft=halo-perf").is_err());
        assert!(SpecConfig::parse("halo-perf").is_err(), "missing key=value shape");
    }

    #[test]
    fn self_drafting_accepts_everything_and_matches_the_oracle() {
        // Drafter == verifier numerics: every greedy draft agrees, so
        // acceptance is exactly 1 and each round retires k_eff + 1 tokens.
        let (spec, oracle, mut ex) = spec_exec(11, 11, 4);
        let prefix = vec![3, 1, 4, 1, 5];
        let out = ex.generate(&[prefix.clone()], &[12]).unwrap();
        assert_eq!(out[0], verifier_only(&spec, &oracle, &prefix, 12));
        let st = ex.stats();
        assert!(st.drafted_tokens > 0);
        assert_eq!(st.accepted_tokens, st.drafted_tokens, "identical pair must accept all");
        assert!(
            st.verify_rounds < 12,
            "{} rounds for 12 tokens is no speculation at all",
            st.verify_rounds
        );
    }

    #[test]
    fn weak_drafter_changes_rounds_not_tokens() {
        // A drafter with different numerics may be rejected at any
        // position — the emitted chain must not move by a single bit.
        let (spec, oracle, mut ex) = spec_exec(99, 11, 4);
        let prefix = vec![7, 2, 9];
        let out = ex.generate(&[prefix.clone()], &[16]).unwrap();
        assert_eq!(out[0], verifier_only(&spec, &oracle, &prefix, 16));
        let st = ex.stats();
        assert!(st.accepted_tokens <= st.drafted_tokens);
        assert!(st.verify_rounds >= 1);
    }

    #[test]
    fn chains_that_slide_the_window_stay_exact() {
        // prefix + max_new well past seq_len = 24: rollbacks interleave
        // with context slides (the headroom clamp shrinks k_eff to 0 at
        // the cap) and the chain still matches verifier-only decode.
        let (spec, oracle, mut ex) = spec_exec(11, 11, 16);
        let prefix: Vec<i32> = (0..20).map(|i| (i * 5) % 13).collect();
        let out = ex.generate(&[prefix.clone()], &[24]).unwrap();
        assert_eq!(out[0], verifier_only(&spec, &oracle, &prefix, 24));
    }

    #[test]
    fn sampled_speculation_draws_the_verifier_only_stream() {
        let (spec, oracle, mut ex) = spec_exec(11, 11, 4);
        let prefix = vec![1, 2, 3];
        let params = SamplingParams::new(0xC0FFEE).temperature(0.8).top_k(6);
        let mut st = ex.begin(&prefix, 10).unwrap();
        st.set_sampler(Some(Sampler::new(params)));
        while !st.done() {
            let mut act = vec![&mut st];
            ex.step(&mut act).unwrap();
        }
        // Oracle: verifier-only chain drawing from the same seeded stream.
        let mut o = DecodeState::with_cache(
            &prefix,
            10,
            spec.seq_len,
            KvCache::new(spec.n_layers, spec.d_model),
        );
        o.set_sampler(Some(Sampler::new(params)));
        while !o.done() {
            let (new, cached) = o.uncached_suffix().unwrap();
            let logits =
                sim::forward_incremental(&spec, &oracle, &new, cached, o.cache_mut().unwrap(), false)
                    .unwrap();
            let t = select_token(&mut o, logits.row(new.len() - 1));
            o.push_token(t);
        }
        assert_eq!(st.into_generated(), o.into_generated());
    }

    #[test]
    fn mismatched_pairing_is_refused() {
        let spec = tiny_spec();
        let other = ModelSpec::synthetic(17, 8, 2, 2, 16, 24); // different vocab
        let drafter = dense_model(&other, 1);
        let verifier = dense_model(&spec, 2);
        assert!(SpecExecutor::new(
            SpecDrafter::Dense { spec: other, params: Arc::new(drafter) },
            SpecVerifier::Dense { spec: spec.clone(), params: Arc::new(verifier) },
            4,
            2,
        )
        .is_err());
    }
}
