//! Synthetic load generator for the sharded serving path.
//!
//! Drives a [`Coordinator`] with a deterministic open-loop arrival process
//! (Poisson-free fixed-rate pacing keeps runs reproducible) against a
//! CPU-bound [`SyntheticExecutor`] — no artifacts required, so the same
//! harness runs in CI smoke mode, `benches/l2_serving.rs`, and
//! `halo loadgen`. Per-shard compute is deliberately single-threaded
//! (naive kernels) so throughput scaling across shards measures the
//! router/batcher architecture, not the matmul thread pool.

use std::time::{Duration, Instant};

use anyhow::Result;

use super::batch::BatcherConfig;
use super::metrics::{MetricsSnapshot, ShedReason};
use super::server::{BatchExecutor, Coordinator, CoordinatorConfig, Request};
use crate::quant::Matrix;
use crate::runtime::kernels::naive;
use crate::util::failpoint::{self, sites, FailPlan, Fault};
use crate::util::{Json, Rng};

/// Fake model: deterministic next-token function plus a fixed dose of
/// single-threaded GEMM work per sequence per step, so batches cost real
/// CPU and shard scaling is measurable.
pub struct SyntheticExecutor {
    batch: usize,
    seq: usize,
    a: Matrix,
    b: Matrix,
}

impl SyntheticExecutor {
    /// `work_dim` is the side of the per-sequence busywork matmul
    /// (`work_dim³` MACs per sequence per decode step).
    pub fn new(batch: usize, seq: usize, work_dim: usize, seed: u64) -> Self {
        let mut rng = Rng::seed_from_u64(seed);
        let d = work_dim.max(1);
        Self {
            batch,
            seq,
            a: Matrix::random_normal(d, d, 1.0, &mut rng),
            b: Matrix::random_normal(d, d, 1.0, &mut rng),
        }
    }

    /// The deterministic "model": next token from the prefix alone.
    pub fn next_token(prefix: &[i32]) -> i32 {
        let mut h = 7i64;
        for &t in prefix {
            h = (h.wrapping_mul(31) + t as i64).rem_euclid(65_521);
        }
        (h % 251) as i32
    }
}

impl BatchExecutor for SyntheticExecutor {
    fn batch_capacity(&self) -> usize {
        self.batch
    }

    fn seq_len(&self) -> usize {
        self.seq
    }

    fn run(&mut self, prefixes: &[Vec<i32>]) -> Result<Vec<i32>> {
        let mut out = Vec::with_capacity(prefixes.len());
        for p in prefixes {
            // Single-threaded busywork stands in for the forward pass.
            std::hint::black_box(naive::matmul(&self.a, &self.b));
            out.push(Self::next_token(p));
        }
        Ok(out)
    }
}

/// One loadgen run's knobs.
#[derive(Debug, Clone)]
pub struct LoadgenConfig {
    /// Executor shards (threads).
    pub shards: usize,
    /// Max live requests per shard decode step.
    pub batch_size: usize,
    /// Batch-forming window after the first pending request.
    pub batch_timeout: Duration,
    /// Per-shard queue bound; 0 = unbounded.
    pub queue_cap: usize,
    /// Shed deadline per request; None = no deadline.
    pub deadline: Option<Duration>,
    /// Total requests to fire.
    pub requests: usize,
    /// Open-loop arrival rate (requests/second); 0 = as fast as possible.
    pub rps: f64,
    /// Decode length per request.
    pub max_new_tokens: usize,
    /// Prefix length per request.
    pub prefix_len: usize,
    /// Busywork matmul side per sequence per step.
    pub work_dim: usize,
    /// RNG seed for prefixes and pacing.
    pub seed: u64,
    /// When set, install a seeded chaos failpoint schedule for the run
    /// (`halo loadgen --chaos-seed`): shard kills, transient admit errors
    /// and queue-push delays, all reproducible from this seed.
    pub chaos_seed: Option<u64>,
    /// Per-hit shard-kill probability for the chaos schedule (the other
    /// fault classes fire at fractions of it); ignored without
    /// `chaos_seed`.
    pub kill_prob: f64,
}

impl Default for LoadgenConfig {
    fn default() -> Self {
        Self {
            shards: 1,
            batch_size: 8,
            batch_timeout: Duration::from_millis(2),
            queue_cap: 0,
            deadline: None,
            requests: 256,
            rps: 0.0,
            max_new_tokens: 4,
            prefix_len: 12,
            work_dim: 48,
            seed: 0x10AD,
            chaos_seed: None,
            kill_prob: 0.02,
        }
    }
}

/// What one run measured.
#[derive(Debug, Clone)]
pub struct LoadgenReport {
    /// Shards the run was configured with.
    pub cfg_shards: usize,
    /// Wall-clock time from first submit to last response.
    pub wall: Duration,
    /// Aggregate across shards (percentiles over the union of samples).
    pub merged: MetricsSnapshot,
    /// Per-shard snapshots (index = shard id).
    pub per_shard: Vec<MetricsSnapshot>,
    /// Responses whose decoded tokens matched the deterministic model.
    pub verified_ok: usize,
    /// Responses shed (deadline, admission, or executor failure).
    pub shed: usize,
    /// Requests actually submitted (`< cfg.requests` iff `stopped_early`).
    pub submitted: usize,
    /// True when the coordinator reported total executor loss
    /// ([`Coordinator::submit`] handed the request back) and the
    /// generator stopped submitting — the remaining arrivals were never
    /// sent, so they are *not* counted as shed (no phantom sheds).
    pub stopped_early: bool,
    /// Client-observed shed counts by [`ShedReason`], indexed in
    /// [`ShedReason::ALL`] order (tallied from `Response::reason`).
    pub shed_by_reason: [u64; 5],
}

impl LoadgenReport {
    /// Served responses per wall-clock second.
    pub fn throughput_rps(&self) -> f64 {
        self.merged.responses as f64 / self.wall.as_secs_f64().max(1e-12)
    }

    /// Full machine-readable report (the `--json` output).
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("shards", self.cfg_shards)
            .set("submitted", self.submitted)
            .set("stopped_early", self.stopped_early)
            .set("verified_ok", self.verified_ok)
            .set("shed_total", self.shed)
            .set("throughput_rps", self.throughput_rps())
            .set("metrics", self.merged.to_json(Some(self.wall)));
        let mut reasons = Json::obj();
        for (r, &n) in ShedReason::ALL.iter().zip(&self.shed_by_reason) {
            reasons.set(r.name(), n as f64);
        }
        j.set("shed_reasons", reasons);
        let shards: Vec<Json> =
            self.per_shard.iter().map(|s| s.to_json(Some(self.wall))).collect();
        j.set("per_shard", Json::Arr(shards));
        j
    }

    /// One-line human summary (the `halo loadgen` console output).
    pub fn summary(&self) -> String {
        let early = if self.stopped_early {
            format!(" STOPPED-EARLY(submitted={})", self.submitted)
        } else {
            String::new()
        };
        format!(
            "shards={} wall={:.3}s throughput={:.0} req/s tokens/s={:.0} ok={} shed={}{} | {}",
            self.cfg_shards,
            self.wall.as_secs_f64(),
            self.throughput_rps(),
            self.merged.tokens_per_sec(self.wall),
            self.verified_ok,
            self.shed,
            early,
            self.merged.summary()
        )
    }
}

/// Run one synthetic serving experiment: start `cfg.shards` executors,
/// fire `cfg.requests` paced arrivals, wait for every response, and
/// aggregate per-shard metrics. Responses are verified against the
/// deterministic synthetic decode chain.
pub fn run(cfg: &LoadgenConfig) -> Result<LoadgenReport> {
    let seq = 64usize.max(cfg.prefix_len + cfg.max_new_tokens);
    let (batch, work, seed) = (cfg.batch_size, cfg.work_dim, cfg.seed);
    let verify = |prefix: &[i32], tokens: &[i32], max_new: usize| {
        // Re-derive the expected decode chain end to end.
        let mut seq = prefix.to_vec();
        if tokens.len() != max_new {
            return false;
        }
        for &tok in tokens {
            if tok != SyntheticExecutor::next_token(&seq) {
                return false;
            }
            seq.push(tok);
        }
        true
    };
    run_with(cfg, 250, &verify, move |shard| {
        Ok(Box::new(SyntheticExecutor::new(batch, seq, work, seed ^ shard as u64))
            as Box<dyn BatchExecutor>)
    })
}

/// Drive the coordinator with paced arrivals against caller-supplied
/// executors — the `halo loadgen --quant` path, where each shard serves a
/// real quantized model. `vocab` bounds the sampled prefix tokens;
/// `verify(prefix, generated, max_new)` judges each served response.
pub fn run_with<F>(
    cfg: &LoadgenConfig,
    vocab: usize,
    verify: &dyn Fn(&[i32], &[i32], usize) -> bool,
    make_executor: F,
) -> Result<LoadgenReport>
where
    F: Fn(usize) -> Result<Box<dyn BatchExecutor>> + Send + Sync + 'static,
{
    // Chaos mode: a seeded schedule of shard kills, transient admit
    // errors and enqueue delays. The guard clears the process-global
    // registry when the run ends (even on error).
    let _chaos = cfg.chaos_seed.map(|seed| {
        let p = cfg.kill_prob.clamp(0.0, 1.0);
        failpoint::install_guarded(
            vec![
                FailPlan::always(sites::SHARD_STEP, Fault::Panic).with_prob(p),
                FailPlan::always(sites::SHARD_BEGIN, Fault::Error).with_prob(p / 2.0),
                FailPlan::always(sites::QUEUE_PUSH, Fault::Delay(Duration::from_millis(1)))
                    .with_prob(p / 4.0),
            ],
            seed,
        )
    });
    let coord_cfg = CoordinatorConfig {
        batcher: BatcherConfig { batch_size: cfg.batch_size, timeout: cfg.batch_timeout },
        shards: cfg.shards,
        queue_cap: cfg.queue_cap,
        default_deadline: cfg.deadline,
        ..CoordinatorConfig::default()
    };
    let coord = Coordinator::start(coord_cfg, make_executor);

    let mut rng = Rng::seed_from_u64(cfg.seed);
    let prefixes: Vec<Vec<i32>> = (0..cfg.requests)
        .map(|_| {
            (0..cfg.prefix_len.max(1)).map(|_| rng.gen_usize(vocab.max(1)) as i32).collect()
        })
        .collect();

    let t0 = Instant::now();
    let mut rxs = Vec::with_capacity(cfg.requests);
    let mut stopped_early = false;
    for (i, p) in prefixes.iter().enumerate() {
        if cfg.rps > 0.0 {
            let due = t0 + Duration::from_secs_f64(i as f64 / cfg.rps);
            let now = Instant::now();
            if due > now {
                std::thread::sleep(due - now);
            }
        }
        // Fallible submit: `Err` means every shard queue is closed (total
        // executor loss) — stop generating load and report a partial run
        // instead of minting phantom shed responses for arrivals that
        // were never actually sent.
        match coord.submit(Request::new(p.clone()).max_new(cfg.max_new_tokens)) {
            Ok(rx) => rxs.push(rx),
            Err(_) => {
                stopped_early = true;
                break;
            }
        }
    }
    let submitted = rxs.len();

    // Collect every response before verifying, so the measured wall clock
    // covers serving only — client-side chain re-derivation (which the
    // quantized path does against the real model) stays off the clock.
    let mut responses = Vec::with_capacity(submitted);
    for rx in rxs {
        responses.push(rx.recv_timeout(Duration::from_secs(120))?);
    }
    let wall = t0.elapsed();

    let mut verified_ok = 0usize;
    let mut shed = 0usize;
    let mut shed_by_reason = [0u64; 5];
    for (resp, p) in responses.iter().zip(&prefixes) {
        if resp.shed {
            shed += 1;
            if let Some(reason) = resp.reason {
                for (slot, r) in shed_by_reason.iter_mut().zip(ShedReason::ALL) {
                    if r == reason {
                        *slot += 1;
                    }
                }
            }
        } else if verify(p.as_slice(), &resp.tokens, cfg.max_new_tokens) {
            verified_ok += 1;
        }
    }

    let per: Vec<MetricsSnapshot> =
        coord.shard_metrics().iter().map(|m| m.snapshot()).collect();
    let merged = coord.merged_snapshot();
    let report = LoadgenReport {
        cfg_shards: cfg.shards,
        wall,
        merged,
        per_shard: per,
        verified_ok,
        shed,
        submitted,
        stopped_early,
        shed_by_reason,
    };
    coord.shutdown()?;
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_decode_verifies_end_to_end() {
        let cfg = LoadgenConfig {
            requests: 24,
            shards: 2,
            work_dim: 8,
            max_new_tokens: 3,
            ..LoadgenConfig::default()
        };
        let r = run(&cfg).unwrap();
        assert_eq!(r.verified_ok, 24, "decode chains must match the deterministic model");
        assert_eq!(r.shed, 0);
        assert_eq!(r.merged.responses, 24);
        assert_eq!(r.merged.generated_tokens, 24 * 3);
        assert_eq!(r.per_shard.len(), 2);
        let j = r.to_json();
        assert_eq!(j.req("verified_ok").unwrap().as_usize().unwrap(), 24);
    }

    #[test]
    fn paced_arrivals_and_queue_caps_still_answer_every_request() {
        // Bounded queues + a real deadline: every request must come back
        // exactly once, as either a served or a shed response.
        let cfg = LoadgenConfig {
            requests: 40,
            shards: 2,
            queue_cap: 4,
            rps: 2000.0,
            work_dim: 8,
            max_new_tokens: 2,
            deadline: Some(Duration::from_secs(30)),
            ..LoadgenConfig::default()
        };
        let r = run(&cfg).unwrap();
        assert_eq!(r.verified_ok + r.shed, 40);
        assert_eq!(r.submitted, 40);
        assert!(!r.stopped_early);
    }

    #[test]
    fn total_executor_loss_stops_the_generator_without_phantom_sheds() {
        // Every shard factory fails: the supervisor retires the shard
        // permanently (closing its queue) within a few backoff periods.
        // Once submit reports the closure, the generator must stop —
        // arrivals never sent are not counted anywhere.
        let cfg = LoadgenConfig {
            requests: 50,
            shards: 1,
            rps: 200.0, // 5 ms apart: the close lands mid-run
            max_new_tokens: 1,
            ..LoadgenConfig::default()
        };
        let verify = |_: &[i32], _: &[i32], _: usize| true;
        let r = run_with(&cfg, 50, &verify, |_shard| {
            anyhow::bail!("executor never comes up")
        })
        .unwrap();
        assert!(r.stopped_early, "generator kept submitting into closed queues");
        assert!(r.submitted < 50, "all 50 submitted despite total executor loss");
        assert_eq!(r.verified_ok, 0);
        assert_eq!(r.shed, r.submitted, "every submitted request must shed");
        // Client-observed reasons cover every shed, and the coordinator's
        // own arrival count matches what was actually submitted.
        assert_eq!(r.shed_by_reason.iter().sum::<u64>(), r.shed as u64);
        assert_eq!(r.merged.requests, r.submitted as u64);
        let j = r.to_json();
        assert_eq!(j.req("submitted").unwrap().as_usize().unwrap(), r.submitted);
        assert!(j.req("shed_reasons").unwrap().req("shard_death").is_ok());
    }
}
