//! Wallace/CSA reduction tree: compress N addend rows into two.
//!
//! Rows are `WIDTH`-bit columns of gate nodes (LSB-first); missing bits are
//! structural constants folded away by the builder. Each reduction level
//! applies full adders to triples and half adders to pairs per column —
//! classic Wallace reduction, so the tree depth (and thus the sensitizable
//! path length) shrinks when Booth rows are constant-zero for a given
//! weight.

use super::gate::{NetBuilder, NodeId};

/// Reduce `rows` (each a WIDTH-long bit vector) to exactly two rows.
pub fn reduce(nb: &mut NetBuilder, rows: Vec<Vec<NodeId>>, width: usize) -> (Vec<NodeId>, Vec<NodeId>) {
    assert!(rows.iter().all(|r| r.len() == width));
    // Column-major working set.
    let mut cols: Vec<Vec<NodeId>> = vec![Vec::new(); width];
    let zero = nb.constant(false);
    for row in &rows {
        for (k, &b) in row.iter().enumerate() {
            cols[k].push(b);
        }
    }

    while cols.iter().any(|c| c.len() > 2) {
        let mut next: Vec<Vec<NodeId>> = vec![Vec::new(); width];
        for k in 0..width {
            let col = std::mem::take(&mut cols[k]);
            let mut it = col.into_iter().peekable();
            let mut pending: Vec<NodeId> = Vec::new();
            while it.peek().is_some() {
                pending.clear();
                for _ in 0..3 {
                    if let Some(b) = it.next() {
                        pending.push(b);
                    }
                }
                match pending.len() {
                    3 => {
                        let (s, c) = nb.full_adder(pending[0], pending[1], pending[2]);
                        next[k].push(s);
                        if k + 1 < width {
                            next[k + 1].push(c);
                        }
                    }
                    2 => {
                        let (s, c) = nb.half_adder(pending[0], pending[1]);
                        next[k].push(s);
                        if k + 1 < width {
                            next[k + 1].push(c);
                        }
                    }
                    1 => next[k].push(pending[0]),
                    _ => unreachable!(),
                }
            }
        }
        cols = next;
    }

    let mut r0 = Vec::with_capacity(width);
    let mut r1 = Vec::with_capacity(width);
    for col in cols {
        let mut it = col.into_iter();
        r0.push(it.next().unwrap_or(zero));
        r1.push(it.next().unwrap_or(zero));
    }
    (r0, r1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mac::gate::Netlist;

    /// Reduce a set of constant rows and check sum == carry-save sum.
    fn check(rows_vals: &[u32], width: usize) {
        let mut nb = NetBuilder::new();
        let rows: Vec<Vec<NodeId>> = rows_vals
            .iter()
            .map(|&v| (0..width).map(|k| nb.constant((v >> k) & 1 != 0)).collect())
            .collect();
        let (r0, r1) = reduce(&mut nb, rows, width);
        let outs: Vec<NodeId> = r0.iter().chain(r1.iter()).copied().collect();
        let net: Netlist = nb.finish(outs);
        let mut vals = vec![false; net.len()];
        net.eval_into(&mut vals);
        let bits = net.read_outputs(&vals);
        let s0 = bits & ((1u64 << width) - 1);
        let s1 = (bits >> width) & ((1u64 << width) - 1);
        let want: u64 = rows_vals.iter().map(|&v| v as u64).sum::<u64>() & ((1u64 << width) - 1);
        assert_eq!((s0 + s1) & ((1u64 << width) - 1), want, "rows={rows_vals:?}");
    }

    #[test]
    fn reduces_to_correct_carry_save_sum() {
        check(&[0b1011, 0b0110, 0b1110], 6);
        check(&[1, 2, 3, 4, 5, 6], 8);
        check(&[0xff, 0xff, 0xff, 0xff, 0xff], 10);
        check(&[0, 0, 0], 4);
    }

    #[test]
    fn tree_shrinks_with_fewer_rows() {
        // Structural property behind the paper's effect: fewer live rows →
        // fewer gates (and shallower tree).
        let size = |n_rows: usize| {
            let mut nb = NetBuilder::new();
            let rows: Vec<Vec<NodeId>> =
                (0..n_rows).map(|_| (0..16).map(|_| nb.input()).collect()).collect();
            let (r0, r1) = reduce(&mut nb, rows, 16);
            nb.finish(r0.into_iter().chain(r1).collect()).len()
        };
        assert!(size(2) < size(4));
        assert!(size(4) < size(6));
    }
}
