//! Per-weight static timing analysis with constant propagation.
//!
//! PrimeTime-style "case analysis": the weight port is tied to a constant
//! (the value the PE will hold for a whole tile), constants propagate, and
//! any gate whose output is logically constant no longer launches timing
//! paths. The remaining longest path from a variable input (activation or
//! accumulator) to an output bit is the weight's critical-path delay — the
//! quantity behind the paper's Fig. 4.
//!
//! Gates keep their silicon delay even when an input is constant (the
//! circuit is fixed; only *constant-output* gates stop propagating events).

use super::gate::{Gate, Netlist};
use super::mac8::MacPorts;

/// Constant-propagated knowledge about each node.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Known {
    Const(bool),
    Var,
}

/// Propagate a fixed weight value through the netlist.
/// Returns per-node [`Known`] (activations/accumulator stay variable).
pub fn propagate_weight(net: &Netlist, ports: &MacPorts, w: i8) -> Vec<Known> {
    let mut known = vec![Known::Var; net.len()];
    // Mark weight bits.
    let mut is_w_input = vec![false; net.len()];
    for (i, &n) in ports.w.iter().enumerate() {
        is_w_input[n as usize] = true;
        known[n as usize] = Known::Const((w as u8 >> i) & 1 != 0);
    }
    for (i, g) in net.gates.iter().enumerate() {
        if is_w_input[i] {
            continue;
        }
        known[i] = match *g {
            Gate::Input => Known::Var,
            Gate::Const(c) => Known::Const(c),
            Gate::Not(a) => match known[a as usize] {
                Known::Const(v) => Known::Const(!v),
                Known::Var => Known::Var,
            },
            Gate::And(a, b) => match (known[a as usize], known[b as usize]) {
                (Known::Const(false), _) | (_, Known::Const(false)) => Known::Const(false),
                (Known::Const(true), Known::Const(true)) => Known::Const(true),
                _ => Known::Var,
            },
            Gate::Or(a, b) => match (known[a as usize], known[b as usize]) {
                (Known::Const(true), _) | (_, Known::Const(true)) => Known::Const(true),
                (Known::Const(false), Known::Const(false)) => Known::Const(false),
                _ => Known::Var,
            },
            Gate::Xor(a, b) => match (known[a as usize], known[b as usize]) {
                (Known::Const(x), Known::Const(y)) => Known::Const(x ^ y),
                _ => Known::Var,
            },
        };
    }
    known
}

/// Longest sensitizable path (in pre-calibration delay units) for a fixed
/// weight: max arrival time over all output bits, where constant nodes
/// launch no events.
pub fn weight_delay(net: &Netlist, ports: &MacPorts, w: i8) -> u32 {
    let known = propagate_weight(net, ports, w);
    let mut arrival: Vec<Option<u32>> = vec![None; net.len()];
    for (i, g) in net.gates.iter().enumerate() {
        if matches!(known[i], Known::Const(_)) {
            continue; // constant: no timing event
        }
        arrival[i] = match g {
            Gate::Input => Some(0),
            Gate::Const(_) => None,
            _ => {
                let latest = g
                    .inputs()
                    .filter_map(|j| arrival[j as usize])
                    .max();
                // A variable gate must have at least one variable input.
                latest.map(|t| t + g.delay())
            }
        };
    }
    net.outputs
        .iter()
        .filter_map(|&o| arrival[o as usize])
        .max()
        .unwrap_or(0)
}

/// Count of gates still switching (non-constant) under a fixed weight —
/// the structural proxy for dynamic power (refined by `dynsim` toggles).
pub fn live_gates(net: &Netlist, ports: &MacPorts, w: i8) -> usize {
    propagate_weight(net, ports, w)
        .iter()
        .filter(|k| matches!(k, Known::Var))
        .count()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mac::booth::nonzero_digits;
    use crate::mac::mac8;

    #[test]
    fn zero_weight_is_fastest() {
        let (net, ports) = mac8::build();
        let d0 = weight_delay(&net, &ports, 0);
        for w in [1i8, 64, -127, 85, 127] {
            assert!(d0 <= weight_delay(&net, &ports, w), "w={w}");
        }
    }

    #[test]
    fn fewer_booth_digits_is_never_slower_much() {
        // Aggregate trend (paper Fig. 4): average delay grows with the
        // number of non-zero Booth digits.
        let (net, ports) = mac8::build();
        let mut by_digits = vec![(0u64, 0u64); 5];
        for w in i8::MIN..=i8::MAX {
            let d = weight_delay(&net, &ports, w) as u64;
            let n = nonzero_digits(w);
            by_digits[n].0 += d;
            by_digits[n].1 += 1;
        }
        let avg: Vec<f64> = by_digits
            .iter()
            .map(|&(s, c)| if c == 0 { 0.0 } else { s as f64 / c as f64 })
            .collect();
        assert!(avg[1] < avg[2] && avg[2] < avg[4], "avg by digits: {avg:?}");
    }

    #[test]
    fn weight_64_faster_than_minus_127() {
        // The paper's Fig. 3 pair: w=64 reaches 3.7 GHz, w=-127 only 1.9.
        let (net, ports) = mac8::build();
        assert!(
            weight_delay(&net, &ports, 64) < weight_delay(&net, &ports, -127),
            "64 should be faster than -127"
        );
    }

    #[test]
    fn propagation_agrees_with_eval() {
        // Any node marked Const must evaluate to that constant for every
        // activation/accumulator assignment (spot-checked).
        let (net, ports) = mac8::build();
        let w = -37i8;
        let known = propagate_weight(&net, &ports, w);
        for (a, acc) in [(0i8, 0i32), (127, -1), (-128, 0x3fffff), (55, -12345)] {
            let mut vals = vec![false; net.len()];
            mac8::set_inputs(&ports, &mut vals, w, a, acc);
            net.eval_into(&mut vals);
            for (i, k) in known.iter().enumerate() {
                if let Known::Const(c) = k {
                    assert_eq!(vals[i], *c, "node {i} a={a} acc={acc}");
                }
            }
        }
    }

    #[test]
    fn live_gates_fewer_for_simple_weights() {
        let (net, ports) = mac8::build();
        assert!(live_gates(&net, &ports, 0) < live_gates(&net, &ports, -127));
        assert!(live_gates(&net, &ports, 64) < live_gates(&net, &ports, 85));
    }
}
