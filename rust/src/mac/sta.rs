//! Per-weight static timing analysis with constant propagation.
//!
//! PrimeTime-style "case analysis": the weight port is tied to a constant
//! (the value the PE will hold for a whole tile), constants propagate, and
//! any gate whose output is logically constant no longer launches timing
//! paths. The remaining longest path from a variable input (activation or
//! accumulator) to an output bit is the weight's critical-path delay — the
//! quantity behind the paper's Fig. 4.
//!
//! Gates keep their silicon delay even when an input is constant (the
//! circuit is fixed; only *constant-output* gates stop propagating events).

use super::gate::{Gate, Netlist};
use super::mac8::MacPorts;

/// Constant-propagated knowledge about each node.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Known {
    Const(bool),
    Var,
}

/// Propagate a fixed weight value through the netlist.
/// Returns per-node [`Known`] (activations/accumulator stay variable).
pub fn propagate_weight(net: &Netlist, ports: &MacPorts, w: i8) -> Vec<Known> {
    let mut known = Vec::new();
    propagate_weight_into(net, ports, w, &mut known);
    known
}

/// [`propagate_weight`] into a caller-owned buffer — the profile loop calls
/// this 256 times, so the scratch is reused instead of reallocated.
pub fn propagate_weight_into(net: &Netlist, ports: &MacPorts, w: i8, known: &mut Vec<Known>) {
    known.clear();
    known.resize(net.len(), Known::Var);
    // Pin weight bits; every other input stays Var (the `Gate::Input` arm
    // below keeps whatever is already in the buffer).
    for (i, &n) in ports.w.iter().enumerate() {
        known[n as usize] = Known::Const((w as u8 >> i) & 1 != 0);
    }
    for (i, g) in net.gates.iter().enumerate() {
        let ki = match *g {
            Gate::Input => known[i],
            Gate::Const(c) => Known::Const(c),
            Gate::Not(a) => match known[a as usize] {
                Known::Const(v) => Known::Const(!v),
                Known::Var => Known::Var,
            },
            Gate::And(a, b) => match (known[a as usize], known[b as usize]) {
                (Known::Const(false), _) | (_, Known::Const(false)) => Known::Const(false),
                (Known::Const(true), Known::Const(true)) => Known::Const(true),
                _ => Known::Var,
            },
            Gate::Or(a, b) => match (known[a as usize], known[b as usize]) {
                (Known::Const(true), _) | (_, Known::Const(true)) => Known::Const(true),
                (Known::Const(false), Known::Const(false)) => Known::Const(false),
                _ => Known::Var,
            },
            Gate::Xor(a, b) => match (known[a as usize], known[b as usize]) {
                (Known::Const(x), Known::Const(y)) => Known::Const(x ^ y),
                _ => Known::Var,
            },
        };
        known[i] = ki;
    }
}

/// Longest sensitizable path (in pre-calibration delay units) for a fixed
/// weight: max arrival time over all output bits, where constant nodes
/// launch no events.
pub fn weight_delay(net: &Netlist, ports: &MacPorts, w: i8) -> u32 {
    let mut known = Vec::new();
    let mut arrival = Vec::new();
    weight_delay_into(net, ports, w, &mut known, &mut arrival)
}

/// [`weight_delay`] with caller-owned scratch buffers (profile hot path).
pub fn weight_delay_into(
    net: &Netlist,
    ports: &MacPorts,
    w: i8,
    known: &mut Vec<Known>,
    arrival: &mut Vec<Option<u32>>,
) -> u32 {
    propagate_weight_into(net, ports, w, known);
    arrival.clear();
    arrival.resize(net.len(), None);
    for (i, g) in net.gates.iter().enumerate() {
        if matches!(known[i], Known::Const(_)) {
            continue; // constant: no timing event
        }
        let at = match g {
            Gate::Input => Some(0),
            Gate::Const(_) => None,
            _ => {
                let latest = g
                    .inputs()
                    .filter_map(|j| arrival[j as usize])
                    .max();
                // A variable gate must have at least one variable input.
                latest.map(|t| t + g.delay())
            }
        };
        arrival[i] = at;
    }
    net.outputs
        .iter()
        .filter_map(|&o| arrival[o as usize])
        .max()
        .unwrap_or(0)
}

/// STA bound for all 256 int8 weight values (indexed by `w as u8`):
/// chunked over the worker pool with per-chunk scratch reuse — the
/// profile's companion pass to the dynamic simulation.
pub fn weight_delays_all(net: &Netlist, ports: &MacPorts) -> Vec<u32> {
    const CHUNK: usize = 32;
    let chunks = crate::util::parallel::par_map(256 / CHUNK, |c| {
        let mut known = Vec::new();
        let mut arrival = Vec::new();
        let mut out = [0u32; CHUNK];
        for (k, slot) in out.iter_mut().enumerate() {
            let w = (c * CHUNK + k) as u8 as i8;
            *slot = weight_delay_into(net, ports, w, &mut known, &mut arrival);
        }
        out
    });
    chunks.into_iter().flatten().collect()
}

/// Count of gates still switching (non-constant) under a fixed weight —
/// the structural proxy for dynamic power (refined by `dynsim` toggles).
pub fn live_gates(net: &Netlist, ports: &MacPorts, w: i8) -> usize {
    propagate_weight(net, ports, w)
        .iter()
        .filter(|k| matches!(k, Known::Var))
        .count()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mac::booth::nonzero_digits;
    use crate::mac::mac8;

    #[test]
    fn zero_weight_is_fastest() {
        let (net, ports) = mac8::build();
        let d0 = weight_delay(&net, &ports, 0);
        for w in [1i8, 64, -127, 85, 127] {
            assert!(d0 <= weight_delay(&net, &ports, w), "w={w}");
        }
    }

    #[test]
    fn fewer_booth_digits_is_never_slower_much() {
        // Aggregate trend (paper Fig. 4): average delay grows with the
        // number of non-zero Booth digits.
        let (net, ports) = mac8::build();
        let mut by_digits = vec![(0u64, 0u64); 5];
        for w in i8::MIN..=i8::MAX {
            let d = weight_delay(&net, &ports, w) as u64;
            let n = nonzero_digits(w);
            by_digits[n].0 += d;
            by_digits[n].1 += 1;
        }
        let avg: Vec<f64> = by_digits
            .iter()
            .map(|&(s, c)| if c == 0 { 0.0 } else { s as f64 / c as f64 })
            .collect();
        assert!(avg[1] < avg[2] && avg[2] < avg[4], "avg by digits: {avg:?}");
    }

    #[test]
    fn weight_64_faster_than_minus_127() {
        // The paper's Fig. 3 pair: w=64 reaches 3.7 GHz, w=-127 only 1.9.
        let (net, ports) = mac8::build();
        assert!(
            weight_delay(&net, &ports, 64) < weight_delay(&net, &ports, -127),
            "64 should be faster than -127"
        );
    }

    #[test]
    fn propagation_agrees_with_eval() {
        // Any node marked Const must evaluate to that constant for every
        // activation/accumulator assignment (spot-checked).
        let (net, ports) = mac8::build();
        let w = -37i8;
        let known = propagate_weight(&net, &ports, w);
        for (a, acc) in [(0i8, 0i32), (127, -1), (-128, 0x3fffff), (55, -12345)] {
            let mut vals = vec![false; net.len()];
            mac8::set_inputs(&ports, &mut vals, w, a, acc);
            net.eval_into(&mut vals);
            for (i, k) in known.iter().enumerate() {
                if let Known::Const(c) = k {
                    assert_eq!(vals[i], *c, "node {i} a={a} acc={acc}");
                }
            }
        }
    }

    #[test]
    fn batch_delays_match_single_queries() {
        let (net, ports) = mac8::build();
        let all = weight_delays_all(&net, &ports);
        assert_eq!(all.len(), 256);
        for &w in &[0i8, 1, -1, 64, -127, 85, 127, -128] {
            assert_eq!(all[w as u8 as usize], weight_delay(&net, &ports, w), "w={w}");
        }
    }

    #[test]
    fn live_gates_fewer_for_simple_weights() {
        let (net, ports) = mac8::build();
        assert!(live_gates(&net, &ports, 0) < live_gates(&net, &ports, -127));
        assert!(live_gates(&net, &ports, 64) < live_gates(&net, &ports, 85));
    }
}
