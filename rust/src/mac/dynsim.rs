//! Event/transition-level dynamic timing + switching-activity simulation.
//!
//! For a fixed weight, applies an (activation, accumulator) input transition
//! and computes (a) the settle time — when the last output reaches its final
//! value — and (b) the toggle count — how many gate outputs changed. The
//! settle-time histogram over many transitions is the paper's Fig. 3; mean
//! toggles drive the Fig. 5 power model.
//!
//! Approximation: zero-delay glitches are not modeled (a gate whose stable
//! value is unchanged contributes no event). This underestimates switching
//! power uniformly but preserves the per-weight ordering, which is what the
//! quantizer consumes.
//!
//! Two engines share those semantics:
//!
//! - [`DynSim`] — the scalar reference: one transition per netlist pass,
//!   allocation-free (double-buffered value vectors).
//! - [`DynSim64`] — the bit-sliced hot path: lane `l` of every `u64` node
//!   word carries an independent input state, so one topological pass
//!   evaluates 64 transitions. Toggle counts accumulate in vertical
//!   (bit-transposed) counters; per-lane settle times are only written —
//!   and only read — for `(node, lane)` pairs whose value actually
//!   changed, keeping settle bookkeeping proportional to real switching
//!   activity instead of lanes × gates.
//!
//! The sampling entry points ([`weight_stats`], [`settle_histogram`]) run
//! bit-sliced; [`weight_stats_scalar`] keeps the scalar path alive as the
//! equivalence oracle (both produce identical per-transition results from
//! the same RNG stream — see the tests below and `tests/hotpaths.rs`).

use crate::util::Rng;

use super::gate::{Gate, Netlist};
use super::mac8::{self, MacPorts};

/// Result of one input transition.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct Transition {
    /// Settle time in pre-calibration delay units.
    pub settle: u32,
    /// Number of gate outputs that changed value.
    pub toggles: u32,
}

/// Reusable scalar simulator state for one netlist + fixed weight.
pub struct DynSim<'a> {
    net: &'a Netlist,
    ports: &'a MacPorts,
    w: i8,
    vals: Vec<bool>,
    /// previous stable state (double buffer — no per-step allocation)
    prev: Vec<bool>,
    /// scratch: settle time per node for the current transition
    settle: Vec<u32>,
}

impl<'a> DynSim<'a> {
    /// Simulator settled at the initial state `(a0, acc0)` for weight `w`.
    pub fn new(net: &'a Netlist, ports: &'a MacPorts, w: i8, a0: i8, acc0: i32) -> Self {
        let mut vals = vec![false; net.len()];
        mac8::set_inputs(ports, &mut vals, w, a0, acc0);
        net.eval_into(&mut vals);
        let prev = vals.clone();
        Self { net, ports, w, vals, prev, settle: vec![0; net.len()] }
    }

    /// Current stable node values (outputs readable via
    /// [`Netlist::read_outputs`]).
    pub fn values(&self) -> &[bool] {
        &self.vals
    }

    /// Apply a transition to new (a, acc); weight stays constant.
    pub fn step(&mut self, a: i8, acc: i32) -> Transition {
        // Swap buffers: `prev` becomes the old stable state, `vals` is
        // rebuilt in place from it (no allocation).
        std::mem::swap(&mut self.vals, &mut self.prev);
        self.vals.copy_from_slice(&self.prev);
        mac8::set_inputs(self.ports, &mut self.vals, self.w, a, acc);

        let old = &self.prev;
        let new = &mut self.vals;
        let settle = &mut self.settle;
        let mut toggles = 0u32;
        for (i, g) in self.net.gates.iter().enumerate() {
            let v = match *g {
                Gate::Input => new[i],
                Gate::Const(c) => c,
                Gate::Not(x) => !new[x as usize],
                Gate::And(x, y) => new[x as usize] && new[y as usize],
                Gate::Or(x, y) => new[x as usize] || new[y as usize],
                Gate::Xor(x, y) => new[x as usize] ^ new[y as usize],
            };
            new[i] = v;
            if v != old[i] {
                toggles += 1;
                let latest = g
                    .inputs()
                    .filter(|&j| new[j as usize] != old[j as usize])
                    .map(|j| settle[j as usize])
                    .max()
                    .unwrap_or(0);
                settle[i] = latest + g.delay();
            } else {
                settle[i] = 0;
            }
        }

        let out_settle = self
            .net
            .outputs
            .iter()
            .map(|&o| settle[o as usize])
            .max()
            .unwrap_or(0);
        Transition { settle: out_settle, toggles }
    }
}

/// 64-lane bit-sliced transition simulator for one netlist + fixed weight.
///
/// Each `(a, acc)` pair fully determines the circuit state (the netlist is
/// combinational), so an arbitrary transition *chain* can be packed into
/// lanes: pass `states[t..t+n]` as `from` and `states[t+1..t+1+n]` as `to`
/// and lane `l` reproduces scalar step `t + l` exactly.
pub struct DynSim64<'a> {
    net: &'a Netlist,
    ports: &'a MacPorts,
    w: i8,
    old: Vec<u64>,
    new: Vec<u64>,
    /// per-node toggle mask of the current batch (old ^ new)
    diff: Vec<u64>,
    /// settle[node * 64 + lane]; valid only where `diff[node]` has the
    /// lane bit set (reads are guarded, so stale entries are never seen)
    settle: Vec<u32>,
}

impl<'a> DynSim64<'a> {
    /// Bit-sliced simulator for weight `w` (states are supplied per batch).
    pub fn new(net: &'a Netlist, ports: &'a MacPorts, w: i8) -> Self {
        assert!(net.len() < (1 << 16), "toggle counters assume < 65536 gates");
        Self {
            net,
            ports,
            w,
            old: vec![0; net.len()],
            new: vec![0; net.len()],
            diff: vec![0; net.len()],
            settle: vec![0; net.len() * 64],
        }
    }

    /// Simulate one batch of transitions: lane `l` goes from input state
    /// `from[l]` to `to[l]`. Writes one [`Transition`] per lane into `out`
    /// (`from`, `to` and `out` must have equal length ≤ 64).
    pub fn run_batch(&mut self, from: &[(i8, i32)], to: &[(i8, i32)], out: &mut [Transition]) {
        let lanes = from.len();
        assert!(lanes == to.len() && lanes == out.len() && lanes <= 64);
        if lanes == 0 {
            return;
        }
        mac8::set_inputs64(self.ports, &mut self.old, self.w, from);
        self.net.eval64_into(&mut self.old);
        mac8::set_inputs64(self.ports, &mut self.new, self.w, to);

        // Fused pass: evaluate the new state, diff against the old one,
        // count toggles and propagate settle times — all 64 lanes at once.
        let new = &mut self.new;
        let old = &self.old;
        let diff = &mut self.diff;
        let settle = &mut self.settle;
        // Vertical per-lane toggle counters: plane `p` holds bit `p` of
        // every lane's running count (16 planes cover the gate-count bound
        // asserted in `new`).
        let mut planes = [0u64; 16];
        for (i, g) in self.net.gates.iter().enumerate() {
            let v = match *g {
                Gate::Input => new[i],
                Gate::Const(c) => {
                    if c {
                        u64::MAX
                    } else {
                        0
                    }
                }
                Gate::Not(x) => !new[x as usize],
                Gate::And(x, y) => new[x as usize] & new[y as usize],
                Gate::Or(x, y) => new[x as usize] | new[y as usize],
                Gate::Xor(x, y) => new[x as usize] ^ new[y as usize],
            };
            new[i] = v;
            let d = v ^ old[i];
            diff[i] = d;
            if d == 0 {
                continue;
            }
            // toggle_count[lane] += 1 for every set lane bit: ripple-carry
            // add of `d` into the bit-transposed counters.
            let mut carry = d;
            for p in planes.iter_mut() {
                let t = *p & carry;
                *p ^= carry;
                carry = t;
                if carry == 0 {
                    break;
                }
            }
            // Same settle recurrence as `DynSim::step`, applied only to
            // the lanes that actually toggled.
            let delay = g.delay();
            let mut m = d;
            while m != 0 {
                let l = m.trailing_zeros() as usize;
                m &= m - 1;
                let mut latest = 0u32;
                for j in g.inputs() {
                    let j = j as usize;
                    if (diff[j] >> l) & 1 != 0 {
                        latest = latest.max(settle[j * 64 + l]);
                    }
                }
                settle[i * 64 + l] = latest + delay;
            }
        }

        for (l, t) in out.iter_mut().enumerate() {
            let mut s = 0u32;
            for &o in &self.net.outputs {
                let o = o as usize;
                if (diff[o] >> l) & 1 != 0 {
                    s = s.max(settle[o * 64 + l]);
                }
            }
            let mut toggles = 0u32;
            for (p, &plane) in planes.iter().enumerate() {
                toggles |= (((plane >> l) & 1) as u32) << p;
            }
            *t = Transition { settle: s, toggles };
        }
    }
}

/// Per-weight transition statistics over `samples` random transitions.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct WeightStats {
    /// Worst observed settle time (pre-calibration delay units).
    pub max_settle: u32,
    /// Mean settle time over the sampled transitions.
    pub mean_settle: f64,
    /// Mean gate-output toggle count per transition.
    pub mean_toggles: f64,
}

/// The shared input-state stream: the exact RNG call sequence of the seed
/// scalar implementation (initial state, then one `(a, acc)` per sample),
/// so scalar and bit-sliced engines replay identical transitions.
fn sample_states(rng: &mut Rng, samples: usize, random_acc0: bool) -> Vec<(i8, i32)> {
    let mut states = Vec::with_capacity(samples + 1);
    let a0 = rng.gen_i8();
    let acc0 = if random_acc0 {
        rng.gen_range_i64(-0x400000, 0x400000) as i32
    } else {
        0
    };
    states.push((a0, acc0));
    for _ in 0..samples {
        states.push((rng.gen_i8(), rng.gen_range_i64(-0x400000, 0x400000) as i32));
    }
    states
}

/// Sample random (a, acc) transitions for a fixed weight — bit-sliced:
/// 64 transitions per pair of netlist passes. Produces results identical
/// to [`weight_stats_scalar`].
pub fn weight_stats(
    net: &Netlist,
    ports: &MacPorts,
    w: i8,
    samples: usize,
    seed: u64,
) -> WeightStats {
    let mut rng = Rng::seed_from_u64(seed ^ ((w as u8 as u64) << 32));
    let states = sample_states(&mut rng, samples, true);

    let mut sim = DynSim64::new(net, ports, w);
    let mut batch = [Transition::default(); 64];
    let mut max_settle = 0u32;
    let (mut sum_settle, mut sum_toggles) = (0u64, 0u64);
    let mut t = 0usize;
    while t < samples {
        let n = (samples - t).min(64);
        sim.run_batch(&states[t..t + n], &states[t + 1..t + 1 + n], &mut batch[..n]);
        for tr in &batch[..n] {
            max_settle = max_settle.max(tr.settle);
            sum_settle += tr.settle as u64;
            sum_toggles += tr.toggles as u64;
        }
        t += n;
    }
    WeightStats {
        max_settle,
        mean_settle: sum_settle as f64 / samples as f64,
        mean_toggles: sum_toggles as f64 / samples as f64,
    }
}

/// The seed scalar implementation of [`weight_stats`] — kept as the
/// equivalence oracle and the pre-PR baseline for `benches/l1_hotpaths.rs`.
pub fn weight_stats_scalar(
    net: &Netlist,
    ports: &MacPorts,
    w: i8,
    samples: usize,
    seed: u64,
) -> WeightStats {
    let mut rng = Rng::seed_from_u64(seed ^ ((w as u8 as u64) << 32));
    let mut sim = DynSim::new(
        net,
        ports,
        w,
        rng.gen_i8(),
        rng.gen_range_i64(-0x400000, 0x400000) as i32,
    );
    let mut max_settle = 0u32;
    let (mut sum_settle, mut sum_toggles) = (0u64, 0u64);
    for _ in 0..samples {
        let t = sim.step(rng.gen_i8(), rng.gen_range_i64(-0x400000, 0x400000) as i32);
        max_settle = max_settle.max(t.settle);
        sum_settle += t.settle as u64;
        sum_toggles += t.toggles as u64;
    }
    WeightStats {
        max_settle,
        mean_settle: sum_settle as f64 / samples as f64,
        mean_toggles: sum_toggles as f64 / samples as f64,
    }
}

/// Settle-time histogram for Fig. 3: (settle units → count). Bit-sliced;
/// replays the seed implementation's exact transition stream (initial
/// accumulator pinned to 0).
pub fn settle_histogram(
    net: &Netlist,
    ports: &MacPorts,
    w: i8,
    samples: usize,
    seed: u64,
) -> Vec<(u32, u32)> {
    let mut rng = Rng::seed_from_u64(seed ^ ((w as u8 as u64) << 32));
    let states = sample_states(&mut rng, samples, false);

    let mut sim = DynSim64::new(net, ports, w);
    let mut batch = [Transition::default(); 64];
    let mut counts = std::collections::BTreeMap::new();
    let mut t = 0usize;
    while t < samples {
        let n = (samples - t).min(64);
        sim.run_batch(&states[t..t + n], &states[t + 1..t + 1 + n], &mut batch[..n]);
        for tr in &batch[..n] {
            *counts.entry(tr.settle).or_insert(0u32) += 1;
        }
        t += n;
    }
    counts.into_iter().collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mac::mac8;
    use crate::mac::sta;

    #[test]
    fn functional_values_stay_correct_across_steps() {
        let (net, ports) = mac8::build();
        let w = -45i8;
        let mut sim = DynSim::new(&net, &ports, w, 3, 100);
        for (a, acc) in [(7i8, -5i32), (-128, 0), (127, 0x1234), (0, -1)] {
            sim.step(a, acc);
            assert_eq!(net.read_outputs(sim.values()) as u32, mac8::mac_ref(w, a, acc));
        }
    }

    #[test]
    fn settle_bounded_by_sta() {
        // Dynamic settle can never exceed the constant-prop STA bound.
        let (net, ports) = mac8::build();
        for &w in &[0i8, 1, 64, -127, 85, -86, 37] {
            let bound = sta::weight_delay(&net, &ports, w);
            let st = weight_stats(&net, &ports, w, 300, 42);
            assert!(
                st.max_settle <= bound,
                "w={w}: dyn {} > sta {bound}",
                st.max_settle
            );
        }
    }

    #[test]
    fn identical_inputs_no_toggles() {
        let (net, ports) = mac8::build();
        let mut sim = DynSim::new(&net, &ports, 23, 17, 99);
        sim.step(5, -3);
        let t = sim.step(5, -3);
        assert_eq!(t.toggles, 0);
        assert_eq!(t.settle, 0);
    }

    #[test]
    fn bitsliced_matches_scalar_per_transition() {
        // Lane l of a batch must reproduce scalar step t + l exactly.
        let (net, ports) = mac8::build();
        let mut rng = crate::util::Rng::seed_from_u64(0x5EED);
        for &w in &[0i8, 64, -127, 37] {
            let states: Vec<(i8, i32)> = (0..100)
                .map(|_| (rng.gen_i8(), rng.gen_range_i64(-0x400000, 0x400000) as i32))
                .collect();
            let mut scalar = DynSim::new(&net, &ports, w, states[0].0, states[0].1);
            let want: Vec<Transition> =
                states[1..].iter().map(|&(a, acc)| scalar.step(a, acc)).collect();

            let mut sim = DynSim64::new(&net, &ports, w);
            let mut got = vec![Transition::default(); states.len() - 1];
            let samples = states.len() - 1;
            let mut t = 0usize;
            while t < samples {
                let n = (samples - t).min(64);
                sim.run_batch(&states[t..t + n], &states[t + 1..t + 1 + n], &mut got[t..t + n]);
                t += n;
            }
            assert_eq!(got, want, "w={w}");
        }
    }

    #[test]
    fn bitsliced_weight_stats_match_scalar() {
        let (net, ports) = mac8::build();
        for &w in &[0i8, 1, 64, -127, 85] {
            for &samples in &[1usize, 63, 64, 65, 130] {
                let a = weight_stats(&net, &ports, w, samples, 7);
                let b = weight_stats_scalar(&net, &ports, w, samples, 7);
                assert_eq!(a, b, "w={w} samples={samples}");
            }
        }
    }

    #[test]
    fn fast_weight_lower_power() {
        let (net, ports) = mac8::build();
        let fast = weight_stats(&net, &ports, 64, 400, 7);
        let slow = weight_stats(&net, &ports, -127, 400, 7);
        assert!(fast.mean_toggles < slow.mean_toggles,
            "64:{} -127:{}", fast.mean_toggles, slow.mean_toggles);
    }

    #[test]
    fn histogram_counts_sum_to_samples() {
        let (net, ports) = mac8::build();
        let h = settle_histogram(&net, &ports, 64, 200, 1);
        assert_eq!(h.iter().map(|&(_, c)| c).sum::<u32>(), 200);
    }
}
