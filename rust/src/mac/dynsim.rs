//! Event/transition-level dynamic timing + switching-activity simulation.
//!
//! For a fixed weight, applies an (activation, accumulator) input transition
//! and computes (a) the settle time — when the last output reaches its final
//! value — and (b) the toggle count — how many gate outputs changed. The
//! settle-time histogram over many transitions is the paper's Fig. 3; mean
//! toggles drive the Fig. 5 power model.
//!
//! Approximation: zero-delay glitches are not modeled (a gate whose stable
//! value is unchanged contributes no event). This underestimates switching
//! power uniformly but preserves the per-weight ordering, which is what the
//! quantizer consumes.

use crate::util::Rng;

use super::gate::{Gate, Netlist};
use super::mac8::{self, MacPorts};

/// Result of one input transition.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Transition {
    /// Settle time in pre-calibration delay units.
    pub settle: u32,
    /// Number of gate outputs that changed value.
    pub toggles: u32,
}

/// Reusable simulator state for one netlist + fixed weight.
pub struct DynSim<'a> {
    net: &'a Netlist,
    ports: &'a MacPorts,
    w: i8,
    vals: Vec<bool>,
    /// scratch: settle time per node for the current transition
    settle: Vec<u32>,
}

impl<'a> DynSim<'a> {
    pub fn new(net: &'a Netlist, ports: &'a MacPorts, w: i8, a0: i8, acc0: i32) -> Self {
        let mut vals = vec![false; net.len()];
        mac8::set_inputs(ports, &mut vals, w, a0, acc0);
        net.eval_into(&mut vals);
        Self { net, ports, w, vals, settle: vec![0; net.len()] }
    }

    /// Apply a transition to new (a, acc); weight stays constant.
    pub fn step(&mut self, a: i8, acc: i32) -> Transition {
        let old = std::mem::take(&mut self.vals);
        let mut new = old.clone();
        mac8::set_inputs(self.ports, &mut new, self.w, a, acc);

        let settle = &mut self.settle;
        let mut toggles = 0u32;
        for (i, g) in self.net.gates.iter().enumerate() {
            let v = match *g {
                Gate::Input => new[i],
                Gate::Const(c) => c,
                Gate::Not(x) => !new[x as usize],
                Gate::And(x, y) => new[x as usize] && new[y as usize],
                Gate::Or(x, y) => new[x as usize] || new[y as usize],
                Gate::Xor(x, y) => new[x as usize] ^ new[y as usize],
            };
            new[i] = v;
            if v != old[i] {
                toggles += 1;
                let latest = g
                    .inputs()
                    .filter(|&j| new[j as usize] != old[j as usize])
                    .map(|j| settle[j as usize])
                    .max()
                    .unwrap_or(0);
                settle[i] = latest + g.delay();
            } else {
                settle[i] = 0;
            }
        }

        let out_settle = self
            .net
            .outputs
            .iter()
            .map(|&o| settle[o as usize])
            .max()
            .unwrap_or(0);
        self.vals = new;
        Transition { settle: out_settle, toggles }
    }
}

/// Per-weight transition statistics over `samples` random transitions.
#[derive(Debug, Clone, Copy, Default)]
pub struct WeightStats {
    pub max_settle: u32,
    pub mean_settle: f64,
    pub mean_toggles: f64,
}

/// Sample random (a, acc) transitions for a fixed weight.
pub fn weight_stats(
    net: &Netlist,
    ports: &MacPorts,
    w: i8,
    samples: usize,
    seed: u64,
) -> WeightStats {
    let mut rng = Rng::seed_from_u64(seed ^ ((w as u8 as u64) << 32));
    let mut sim = DynSim::new(net, ports, w, rng.gen_i8(), rng.gen_range_i64(-0x400000, 0x400000) as i32);
    let mut max_settle = 0u32;
    let (mut sum_settle, mut sum_toggles) = (0u64, 0u64);
    for _ in 0..samples {
        let t = sim.step(rng.gen_i8(), rng.gen_range_i64(-0x400000, 0x400000) as i32);
        max_settle = max_settle.max(t.settle);
        sum_settle += t.settle as u64;
        sum_toggles += t.toggles as u64;
    }
    WeightStats {
        max_settle,
        mean_settle: sum_settle as f64 / samples as f64,
        mean_toggles: sum_toggles as f64 / samples as f64,
    }
}

/// Settle-time histogram for Fig. 3: (settle units → count).
pub fn settle_histogram(
    net: &Netlist,
    ports: &MacPorts,
    w: i8,
    samples: usize,
    seed: u64,
) -> Vec<(u32, u32)> {
    let mut rng = Rng::seed_from_u64(seed ^ ((w as u8 as u64) << 32));
    let mut sim = DynSim::new(net, ports, w, rng.gen_i8(), 0);
    let mut counts = std::collections::BTreeMap::new();
    for _ in 0..samples {
        let t = sim.step(rng.gen_i8(), rng.gen_range_i64(-0x400000, 0x400000) as i32);
        *counts.entry(t.settle).or_insert(0u32) += 1;
    }
    counts.into_iter().collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mac::mac8;
    use crate::mac::sta;

    #[test]
    fn functional_values_stay_correct_across_steps() {
        let (net, ports) = mac8::build();
        let w = -45i8;
        let mut sim = DynSim::new(&net, &ports, w, 3, 100);
        for (a, acc) in [(7i8, -5i32), (-128, 0), (127, 0x1234), (0, -1)] {
            sim.step(a, acc);
            assert_eq!(net.read_outputs(&sim.vals) as u32, mac8::mac_ref(w, a, acc));
        }
    }

    #[test]
    fn settle_bounded_by_sta() {
        // Dynamic settle can never exceed the constant-prop STA bound.
        let (net, ports) = mac8::build();
        for &w in &[0i8, 1, 64, -127, 85, -86, 37] {
            let bound = sta::weight_delay(&net, &ports, w);
            let st = weight_stats(&net, &ports, w, 300, 42);
            assert!(
                st.max_settle <= bound,
                "w={w}: dyn {} > sta {bound}",
                st.max_settle
            );
        }
    }

    #[test]
    fn identical_inputs_no_toggles() {
        let (net, ports) = mac8::build();
        let mut sim = DynSim::new(&net, &ports, 23, 17, 99);
        sim.step(5, -3);
        let t = sim.step(5, -3);
        assert_eq!(t.toggles, 0);
        assert_eq!(t.settle, 0);
    }

    #[test]
    fn fast_weight_lower_power(){
        let (net, ports) = mac8::build();
        let fast = weight_stats(&net, &ports, 64, 400, 7);
        let slow = weight_stats(&net, &ports, -127, 400, 7);
        assert!(fast.mean_toggles < slow.mean_toggles,
            "64:{} -127:{}", fast.mean_toggles, slow.mean_toggles);
    }

    #[test]
    fn histogram_counts_sum_to_samples() {
        let (net, ports) = mac8::build();
        let h = settle_histogram(&net, &ports, 64, 200, 1);
        assert_eq!(h.iter().map(|&(_, c)| c).sum::<u32>(), 200);
    }
}
