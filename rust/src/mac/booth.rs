//! Radix-4 (modified) Booth encoding and partial-product generation.
//!
//! The weight is the Booth-encoded multiplier — this is where the paper's
//! core circuit effect lives (§II): weight bit patterns with few non-zero
//! Booth digits produce constant-zero partial-product rows, killing the
//! signal paths through them and shortening the sensitizable critical path.

use super::gate::{NetBuilder, NodeId};

/// One Booth digit's control signals (digit ∈ {-2,-1,0,+1,+2}).
#[derive(Debug, Clone, Copy)]
pub struct BoothDigit {
    /// |digit| == 1
    pub one: NodeId,
    /// |digit| == 2
    pub two: NodeId,
    /// digit < 0 (drives row inversion + the +1 LSB correction)
    pub neg: NodeId,
}

/// Encode an 8-bit weight (LSB-first node list) into 4 radix-4 Booth digits.
///
/// Digit i examines bits (w[2i+1], w[2i], w[2i-1]) with w[-1] = 0:
///   one = w[2i] ^ w[2i-1]
///   two = (w[2i+1] & !w[2i] & !w[2i-1]) | (!w[2i+1] & w[2i] & w[2i-1])
///   neg = w[2i+1] & !(w[2i] & w[2i-1])
pub fn encode(nb: &mut NetBuilder, w: &[NodeId]) -> Vec<BoothDigit> {
    assert_eq!(w.len(), 8);
    let zero = nb.constant(false);
    (0..4)
        .map(|i| {
            let lo = if i == 0 { zero } else { w[2 * i - 1] };
            let mid = w[2 * i];
            let hi = w[2 * i + 1];
            let one = nb.xor(mid, lo);
            let nmid = nb.not(mid);
            let nlo = nb.not(lo);
            let nhi = nb.not(hi);
            let t1 = nb.and3(hi, nmid, nlo);
            let t2 = nb.and3(nhi, mid, lo);
            let two = nb.or(t1, t2);
            let both = nb.and(mid, lo);
            let nboth = nb.not(both);
            let neg = nb.and(hi, nboth);
            BoothDigit { one, two, neg }
        })
        .collect()
}

/// Build the 9-bit partial-product row for one Booth digit over a signed
/// 8-bit activation `a` (LSB-first).
///
/// Row bit j (j = 0..=8) in invert-if-negative form:
///   pp_j = neg ^ ((one & a_j) | (two & a_{j-1}))
/// with a_{-1} = 0 and a_8 = a_7 (sign extension for the ×2 shift).
/// The missing `+neg` LSB correction is returned separately by the caller's
/// reduction tree (standard Booth two's-complement completion).
pub fn partial_product(nb: &mut NetBuilder, d: BoothDigit, a: &[NodeId]) -> Vec<NodeId> {
    assert_eq!(a.len(), 8);
    let zero = nb.constant(false);
    (0..=8)
        .map(|j| {
            let aj = if j < 8 { a[j] } else { a[7] };
            let ajm1 = if j == 0 { zero } else { a[j - 1] };
            let t1 = nb.and(d.one, aj);
            let t2 = nb.and(d.two, ajm1);
            let m = nb.or(t1, t2);
            nb.xor(d.neg, m)
        })
        .collect()
}

/// Software Booth digits for an 8-bit weight (reference/testing).
pub fn digits_of(w: i8) -> [i32; 4] {
    let wu = w as u8 as u32;
    let mut out = [0i32; 4];
    for (i, o) in out.iter_mut().enumerate() {
        let lo = if i == 0 { 0 } else { (wu >> (2 * i - 1)) & 1 };
        let mid = (wu >> (2 * i)) & 1;
        let hi = (wu >> (2 * i + 1)) & 1;
        *o = (mid + lo) as i32 - 2 * hi as i32;
    }
    out
}

/// Number of non-zero Booth digits — the structural predictor of the
/// per-weight critical path (paper Fig. 4 peaks).
pub fn nonzero_digits(w: i8) -> usize {
    digits_of(w).iter().filter(|&&d| d != 0).count()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn digits_reconstruct_weight() {
        for w in i8::MIN..=i8::MAX {
            let d = digits_of(w);
            let v: i32 = d.iter().enumerate().map(|(i, &di)| di << (2 * i)).sum();
            assert_eq!(v, w as i32, "w={w} digits={d:?}");
        }
    }

    #[test]
    fn digit_range_is_radix4() {
        for w in i8::MIN..=i8::MAX {
            for d in digits_of(w) {
                assert!((-2..=2).contains(&d));
            }
        }
    }

    #[test]
    fn single_digit_weights() {
        // Radix-4 Booth single-digit values: +4^k (digit +1), every negative
        // power of two (-4^k as -1, -2·4^k as -2). Positive 2·4^k values
        // like +2, +8 encode as (-2·4^k) + (+1·4^{k+1}) — two digits.
        assert_eq!(nonzero_digits(0), 0);
        for w in [1i8, 4, 16, 64, -1, -2, -4, -8, -16, -32, -64, -128] {
            assert_eq!(nonzero_digits(w), 1, "w={w}");
        }
        for w in [2i8, 8, 32] {
            assert_eq!(nonzero_digits(w), 2, "w={w}");
        }
        assert!(nonzero_digits(-127) >= 2);
        assert!(nonzero_digits(85) >= 3);
    }
}
