//! Gate-level netlist substrate for the MAC circuit model.
//!
//! The paper's §II analysis runs Synopsys PrimeTime on the DesignWare
//! `DW02_MAC`; we rebuild the equivalent circuit from 2-input gates so the
//! same analyses (per-weight STA, transition simulation, toggle counting)
//! can run anywhere. Gate delays are rough 22 nm-class relative numbers;
//! absolute calibration happens in [`crate::mac::profile`].

/// Gate delay in picoseconds (pre-calibration units).
pub type Delay = u32;

/// Inverter delay.
pub const D_NOT: Delay = 8;
/// 2-input AND delay.
pub const D_AND: Delay = 15;
/// 2-input OR delay.
pub const D_OR: Delay = 15;
/// 2-input XOR delay (slowest primitive — dominates adder paths).
pub const D_XOR: Delay = 22;

/// Node index into [`Netlist::gates`].
pub type NodeId = u32;

/// A combinational node. Inputs always precede the gate in the vector, so
/// the vector order is a topological order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Gate {
    /// External input bit (activation, weight, accumulator).
    Input,
    /// Constant 0/1.
    Const(bool),
    /// Inverter.
    Not(NodeId),
    /// 2-input AND.
    And(NodeId, NodeId),
    /// 2-input OR.
    Or(NodeId, NodeId),
    /// 2-input XOR.
    Xor(NodeId, NodeId),
}

impl Gate {
    /// Propagation delay of this gate type.
    pub fn delay(&self) -> Delay {
        match self {
            Gate::Input | Gate::Const(_) => 0,
            Gate::Not(_) => D_NOT,
            Gate::And(..) => D_AND,
            Gate::Or(..) => D_OR,
            Gate::Xor(..) => D_XOR,
        }
    }

    /// The gate's fan-in nodes (0, 1 or 2 of them).
    pub fn inputs(&self) -> impl Iterator<Item = NodeId> {
        let (a, b) = match *self {
            Gate::Input | Gate::Const(_) => (None, None),
            Gate::Not(x) => (Some(x), None),
            Gate::And(x, y) | Gate::Or(x, y) | Gate::Xor(x, y) => (Some(x), Some(y)),
        };
        a.into_iter().chain(b)
    }
}

/// A combinational netlist in topological order, with named input groups and
/// an ordered list of output nodes.
#[derive(Debug, Clone, Default)]
pub struct Netlist {
    /// All nodes, inputs-before-users (a topological order).
    pub gates: Vec<Gate>,
    /// Output nodes, LSB first.
    pub outputs: Vec<NodeId>,
}

impl Netlist {
    /// Total node count (inputs + constants + gates).
    pub fn len(&self) -> usize {
        self.gates.len()
    }

    /// Whether the netlist has no nodes at all.
    pub fn is_empty(&self) -> bool {
        self.gates.is_empty()
    }

    /// Evaluate the netlist for a full input assignment.
    ///
    /// `values` must be pre-sized to `gates.len()` with input nodes already
    /// set; all other entries are overwritten in topological order.
    pub fn eval_into(&self, values: &mut [bool]) {
        debug_assert_eq!(values.len(), self.gates.len());
        for (i, g) in self.gates.iter().enumerate() {
            let v = match *g {
                Gate::Input => values[i],
                Gate::Const(c) => c,
                Gate::Not(a) => !values[a as usize],
                Gate::And(a, b) => values[a as usize] && values[b as usize],
                Gate::Or(a, b) => values[a as usize] || values[b as usize],
                Gate::Xor(a, b) => values[a as usize] ^ values[b as usize],
            };
            values[i] = v;
        }
    }

    /// Read the output bits from an evaluated value vector.
    pub fn read_outputs(&self, values: &[bool]) -> u64 {
        let mut out = 0u64;
        for (k, &o) in self.outputs.iter().enumerate() {
            out |= (values[o as usize] as u64) << k;
        }
        out
    }

    /// Bit-sliced evaluation: 64 independent input assignments at once,
    /// one per bit lane of every `u64` word (classic bit-parallel logic
    /// simulation — each gate becomes one bitwise op over all lanes).
    ///
    /// `values` must be pre-sized to `gates.len()` with the input-node
    /// words already set (lane `l` of word `i` = input `i` of assignment
    /// `l`); all other entries are overwritten in topological order.
    pub fn eval64_into(&self, values: &mut [u64]) {
        debug_assert_eq!(values.len(), self.gates.len());
        for (i, g) in self.gates.iter().enumerate() {
            let v = match *g {
                Gate::Input => values[i],
                Gate::Const(c) => {
                    if c {
                        u64::MAX
                    } else {
                        0
                    }
                }
                Gate::Not(a) => !values[a as usize],
                Gate::And(a, b) => values[a as usize] & values[b as usize],
                Gate::Or(a, b) => values[a as usize] | values[b as usize],
                Gate::Xor(a, b) => values[a as usize] ^ values[b as usize],
            };
            values[i] = v;
        }
    }

    /// Read one lane's output bits from a 64-lane evaluated value vector.
    pub fn read_outputs_lane(&self, values: &[u64], lane: usize) -> u64 {
        debug_assert!(lane < 64);
        let mut out = 0u64;
        for (k, &o) in self.outputs.iter().enumerate() {
            out |= ((values[o as usize] >> lane) & 1) << k;
        }
        out
    }

    /// Structural FNV-1a hash over gates + outputs — the cache key for
    /// artifacts derived from this netlist (e.g. the on-disk MAC profile).
    pub fn structural_hash(&self) -> u64 {
        const OFFSET: u64 = 0xcbf29ce484222325;
        const PRIME: u64 = 0x100000001b3;
        let mut h = OFFSET;
        let mut mix = |x: u64| {
            for shift in [0u32, 16, 32, 48] {
                h ^= (x >> shift) & 0xffff;
                h = h.wrapping_mul(PRIME);
            }
        };
        for g in &self.gates {
            let (tag, a, b) = match *g {
                Gate::Input => (1u64, 0u64, 0u64),
                Gate::Const(c) => (2, c as u64, 0),
                Gate::Not(x) => (3, x as u64, 0),
                Gate::And(x, y) => (4, x as u64, y as u64),
                Gate::Or(x, y) => (5, x as u64, y as u64),
                Gate::Xor(x, y) => (6, x as u64, y as u64),
            };
            mix(tag);
            mix(a);
            mix(b);
        }
        mix(0xffff_ffff);
        for &o in &self.outputs {
            mix(o as u64);
        }
        h
    }
}

/// Builder with tiny peephole constant folding — keeps the netlist close to
/// what synthesis would emit for a fixed structure (folding only touches
/// structurally-constant nodes, e.g. sign-extension zeros, never
/// weight-dependent ones; weight constants are handled later by STA
/// constant propagation).
#[derive(Debug, Default)]
pub struct NetBuilder {
    /// Nodes emitted so far, in creation (= topological) order.
    pub gates: Vec<Gate>,
}

impl NetBuilder {
    /// Fresh empty builder.
    pub fn new() -> Self {
        Self::default()
    }

    fn push(&mut self, g: Gate) -> NodeId {
        let id = self.gates.len() as NodeId;
        self.gates.push(g);
        id
    }

    /// New external input node.
    pub fn input(&mut self) -> NodeId {
        self.push(Gate::Input)
    }

    /// `n` new input nodes, LSB first.
    pub fn inputs(&mut self, n: usize) -> Vec<NodeId> {
        (0..n).map(|_| self.input()).collect()
    }

    /// Constant 0/1 node.
    pub fn constant(&mut self, v: bool) -> NodeId {
        self.push(Gate::Const(v))
    }

    fn const_of(&self, id: NodeId) -> Option<bool> {
        match self.gates[id as usize] {
            Gate::Const(c) => Some(c),
            _ => None,
        }
    }

    /// NOT gate (folds constant operands).
    pub fn not(&mut self, a: NodeId) -> NodeId {
        match self.const_of(a) {
            Some(c) => self.constant(!c),
            None => self.push(Gate::Not(a)),
        }
    }

    /// AND gate (folds constant operands).
    pub fn and(&mut self, a: NodeId, b: NodeId) -> NodeId {
        match (self.const_of(a), self.const_of(b)) {
            (Some(false), _) | (_, Some(false)) => self.constant(false),
            (Some(true), _) => b,
            (_, Some(true)) => a,
            _ => self.push(Gate::And(a, b)),
        }
    }

    /// OR gate (folds constant operands).
    pub fn or(&mut self, a: NodeId, b: NodeId) -> NodeId {
        match (self.const_of(a), self.const_of(b)) {
            (Some(true), _) | (_, Some(true)) => self.constant(true),
            (Some(false), _) => b,
            (_, Some(false)) => a,
            _ => self.push(Gate::Or(a, b)),
        }
    }

    /// XOR gate (folds constant operands; XOR-with-1 becomes NOT).
    pub fn xor(&mut self, a: NodeId, b: NodeId) -> NodeId {
        match (self.const_of(a), self.const_of(b)) {
            (Some(false), _) => b,
            (_, Some(false)) => a,
            (Some(true), _) => self.not(b),
            (_, Some(true)) => self.not(a),
            _ => self.push(Gate::Xor(a, b)),
        }
    }

    /// 3-input AND as two 2-input gates.
    pub fn and3(&mut self, a: NodeId, b: NodeId, c: NodeId) -> NodeId {
        let ab = self.and(a, b);
        self.and(ab, c)
    }

    /// 3-input OR as two 2-input gates.
    pub fn or3(&mut self, a: NodeId, b: NodeId, c: NodeId) -> NodeId {
        let ab = self.or(a, b);
        self.or(ab, c)
    }

    /// 2:1 mux as gates: sel ? a : b.
    pub fn mux(&mut self, sel: NodeId, a: NodeId, b: NodeId) -> NodeId {
        let ns = self.not(sel);
        let ta = self.and(sel, a);
        let tb = self.and(ns, b);
        self.or(ta, tb)
    }

    /// Full adder; returns (sum, carry).
    pub fn full_adder(&mut self, a: NodeId, b: NodeId, c: NodeId) -> (NodeId, NodeId) {
        let axb = self.xor(a, b);
        let sum = self.xor(axb, c);
        let ab = self.and(a, b);
        let cx = self.and(axb, c);
        let carry = self.or(ab, cx);
        (sum, carry)
    }

    /// Half adder; returns (sum, carry).
    pub fn half_adder(&mut self, a: NodeId, b: NodeId) -> (NodeId, NodeId) {
        (self.xor(a, b), self.and(a, b))
    }

    /// Seal the builder into a [`Netlist`] with the given output nodes.
    pub fn finish(self, outputs: Vec<NodeId>) -> Netlist {
        Netlist { gates: self.gates, outputs }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_adder_truth_table() {
        for bits in 0..8u32 {
            let (a, b, c) = (bits & 1 != 0, bits & 2 != 0, bits & 4 != 0);
            let mut nb = NetBuilder::new();
            let (ia, ib, ic) = (nb.input(), nb.input(), nb.input());
            let (s, cy) = nb.full_adder(ia, ib, ic);
            let net = nb.finish(vec![s, cy]);
            let mut vals = vec![false; net.len()];
            vals[ia as usize] = a;
            vals[ib as usize] = b;
            vals[ic as usize] = c;
            net.eval_into(&mut vals);
            let got = net.read_outputs(&vals);
            let want = (a as u64) + (b as u64) + (c as u64);
            assert_eq!(got, want, "a={a} b={b} c={c}");
        }
    }

    #[test]
    fn mux_select() {
        for (sel, a, b) in [(false, true, false), (true, true, false)] {
            let mut nb = NetBuilder::new();
            let (is, ia, ib) = (nb.input(), nb.input(), nb.input());
            let m = nb.mux(is, ia, ib);
            let net = nb.finish(vec![m]);
            let mut vals = vec![false; net.len()];
            vals[is as usize] = sel;
            vals[ia as usize] = a;
            vals[ib as usize] = b;
            net.eval_into(&mut vals);
            assert_eq!(net.read_outputs(&vals) != 0, if sel { a } else { b });
        }
    }

    #[test]
    fn eval64_matches_scalar_full_adder() {
        // All 8 input combinations live in 8 lanes of one bit-sliced pass.
        let mut nb = NetBuilder::new();
        let (ia, ib, ic) = (nb.input(), nb.input(), nb.input());
        let (s, cy) = nb.full_adder(ia, ib, ic);
        let net = nb.finish(vec![s, cy]);

        let mut words = vec![0u64; net.len()];
        for lane in 0..8u64 {
            words[ia as usize] |= (lane & 1) << lane;
            words[ib as usize] |= ((lane >> 1) & 1) << lane;
            words[ic as usize] |= ((lane >> 2) & 1) << lane;
        }
        net.eval64_into(&mut words);
        for lane in 0..8usize {
            let want = (lane & 1) as u64 + ((lane >> 1) & 1) as u64 + ((lane >> 2) & 1) as u64;
            assert_eq!(net.read_outputs_lane(&words, lane), want, "lane {lane}");
        }
    }

    #[test]
    fn structural_hash_distinguishes_netlists() {
        let build = |flip: bool| {
            let mut nb = NetBuilder::new();
            let a = nb.input();
            let b = nb.input();
            let g = if flip { nb.and(a, b) } else { nb.or(a, b) };
            nb.finish(vec![g])
        };
        let h1 = build(false).structural_hash();
        let h2 = build(true).structural_hash();
        let h1b = build(false).structural_hash();
        assert_eq!(h1, h1b, "hash must be deterministic");
        assert_ne!(h1, h2, "different gates must hash differently");
    }

    #[test]
    fn constant_folding() {
        let mut nb = NetBuilder::new();
        let a = nb.input();
        let zero = nb.constant(false);
        let one = nb.constant(true);
        let az = nb.and(a, zero);
        assert!(matches!(nb.gates[az as usize], Gate::Const(false)));
        assert_eq!(nb.and(a, one), a);
        assert_eq!(nb.or(a, zero), a);
        assert_eq!(nb.xor(a, zero), a);
        // xor with 1 becomes NOT
        let n = nb.xor(a, one);
        assert!(matches!(nb.gates[n as usize], Gate::Not(x) if x == a));
    }
}
