//! Gate-level MAC circuit model (paper §II: timing and energy analysis).
//!
//! Substitutes for the paper's Synopsys DW02_MAC + PrimeTime flow
//! (DESIGN.md §Substitutions): a radix-4 Booth × Wallace-tree × Kogge–Stone
//! 8-bit MAC built from 2-input gates, with per-weight case-analysis STA,
//! transition-level dynamic timing, and switching-activity power. The
//! derived [`profile::MacProfile`] feeds the quantizer ([`crate::quant`]),
//! the DVFS ladder ([`crate::dvfs`]) and both simulators.

pub mod adder;
pub mod booth;
pub mod dynsim;
pub mod gate;
pub mod mac8;
pub mod power;
pub mod profile;
pub mod sta;
pub mod wallace;

pub use profile::MacProfile;
