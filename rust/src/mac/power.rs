//! MAC power model: switching activity → dynamic power, plus leakage.
//!
//! Standard CMOS decomposition (paper Fig. 5 + §IV energy figures):
//!
//!   P_dyn  = E_toggle · N_toggles · f · (V / V_NOM)²
//!   P_stat = P_LEAK · (V / V_NOM)
//!
//! `N_toggles` comes from [`crate::mac::dynsim`]; E_toggle is a 22 nm-class
//! per-gate switching energy. Absolute numbers are calibration; the paper's
//! effect is the per-weight *ordering* (fast Booth-sparse weights toggle
//! fewer gates → less power), which carries through any positive E_toggle.

/// Nominal supply voltage (V) — Table I systolic base level.
pub const V_NOM: f64 = 1.0;

/// Energy per gate toggle at V_NOM, femtojoules (22 nm-class standard cell
/// with local wire load).
pub const E_TOGGLE_FJ: f64 = 1.1;

/// Per-MAC leakage power at V_NOM, microwatts.
pub const P_LEAK_UW: f64 = 2.0;

/// Dynamic energy of one MAC operation (pJ) given its mean toggle count.
pub fn dynamic_energy_pj(mean_toggles: f64, v: f64) -> f64 {
    mean_toggles * E_TOGGLE_FJ * 1e-3 * (v / V_NOM) * (v / V_NOM)
}

/// Dynamic power (mW) of one MAC at frequency `f_ghz`, voltage `v`.
pub fn dynamic_power_mw(mean_toggles: f64, f_ghz: f64, v: f64) -> f64 {
    // pJ * GHz = mW
    dynamic_energy_pj(mean_toggles, v) * f_ghz
}

/// Leakage power (mW) at voltage `v`.
pub fn leakage_power_mw(v: f64) -> f64 {
    P_LEAK_UW * 1e-3 * (v / V_NOM)
}

/// Total per-MAC power (mW).
pub fn total_power_mw(mean_toggles: f64, f_ghz: f64, v: f64) -> f64 {
    dynamic_power_mw(mean_toggles, f_ghz, v) + leakage_power_mw(v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scales_quadratically_with_voltage() {
        let p1 = dynamic_power_mw(100.0, 2.0, 1.0);
        let p2 = dynamic_power_mw(100.0, 2.0, 1.2);
        assert!((p2 / p1 - 1.44).abs() < 1e-9);
    }

    #[test]
    fn scales_linearly_with_frequency_and_activity() {
        assert!(
            (dynamic_power_mw(100.0, 3.0, 1.0) / dynamic_power_mw(100.0, 1.0, 1.0) - 3.0).abs()
                < 1e-9
        );
        assert!(
            (dynamic_power_mw(200.0, 1.0, 1.0) / dynamic_power_mw(100.0, 1.0, 1.0) - 2.0).abs()
                < 1e-9
        );
    }

    #[test]
    fn energy_per_op_independent_of_frequency() {
        // Energy/op depends on V and activity only — the reason HALO's
        // overclocked fast tiles still save energy (shorter runtime at the
        // same per-op energy).
        assert_eq!(dynamic_energy_pj(50.0, 1.1), dynamic_energy_pj(50.0, 1.1));
        assert!(dynamic_energy_pj(50.0, 1.2) > dynamic_energy_pj(50.0, 1.0));
    }
}
