//! The assembled 8-bit Booth–Wallace MAC: `y_n = w · a + y_{n-1}`.
//!
//! Mirrors the DesignWare `DW02_MAC` the paper analyzes (§II): signed 8×8
//! multiply via radix-4 Booth partial products, Wallace reduction of the
//! four PP rows + Booth corrections + the 24-bit accumulator input, and a
//! Kogge–Stone final CPA. ~1 k gates.

use super::adder::kogge_stone;
use super::booth;
use super::gate::{NetBuilder, Netlist, NodeId};
use super::wallace;

/// Accumulator width (bits). 8×8 products need 16; headroom for 256-long
/// dot-product chains pushes the register to 24 bits, as in TPU-class PEs.
pub const ACC_BITS: usize = 24;

/// Input node ids of the assembled MAC, grouped by port.
#[derive(Debug, Clone)]
pub struct MacPorts {
    /// Weight bits, LSB first (8).
    pub w: Vec<NodeId>,
    /// Activation bits, LSB first (8).
    pub a: Vec<NodeId>,
    /// Accumulator-in bits, LSB first ([`ACC_BITS`]).
    pub acc: Vec<NodeId>,
}

/// Build the MAC netlist. Outputs are the ACC_BITS sum bits (LSB-first).
pub fn build() -> (Netlist, MacPorts) {
    let mut nb = NetBuilder::new();
    let w = nb.inputs(8);
    let a = nb.inputs(8);
    let acc = nb.inputs(ACC_BITS);

    let digits = booth::encode(&mut nb, &w);

    let zero = nb.constant(false);
    let mut rows: Vec<Vec<NodeId>> = Vec::new();

    // Four shifted, sign-extended partial-product rows.
    for (i, &d) in digits.iter().enumerate() {
        let pp = booth::partial_product(&mut nb, d, &a);
        let shift = 2 * i;
        let mut row = vec![zero; ACC_BITS];
        for (j, &bit) in pp.iter().enumerate() {
            row[shift + j] = bit;
        }
        // Sign-extend: ~(sext M) == sext(~M), so extending pp[8] upward is
        // correct for both positive and inverted rows.
        for k in (shift + 9)..ACC_BITS {
            row[k] = pp[8];
        }
        rows.push(row);
    }

    // Booth +neg corrections, packed into one sparse row (positions 0,2,4,6).
    let mut corr = vec![zero; ACC_BITS];
    for (i, &d) in digits.iter().enumerate() {
        corr[2 * i] = d.neg;
    }
    rows.push(corr);

    // Accumulator input is just another addend row.
    rows.push(acc.clone());

    let (r0, r1) = wallace::reduce(&mut nb, rows, ACC_BITS);
    let sum = kogge_stone(&mut nb, &r0, &r1);

    (nb.finish(sum), MacPorts { w, a, acc })
}

/// Software reference: (w·a + acc) mod 2^ACC_BITS.
pub fn mac_ref(w: i8, a: i8, acc: i32) -> u32 {
    let full = (w as i32) * (a as i32) + acc;
    (full as u32) & ((1u32 << ACC_BITS) - 1)
}

/// Assign the three ports into a value vector sized for the netlist.
pub fn set_inputs(ports: &MacPorts, vals: &mut [bool], w: i8, a: i8, acc: i32) {
    for (i, &n) in ports.w.iter().enumerate() {
        vals[n as usize] = (w as u8 >> i) & 1 != 0;
    }
    for (i, &n) in ports.a.iter().enumerate() {
        vals[n as usize] = (a as u8 >> i) & 1 != 0;
    }
    for (i, &n) in ports.acc.iter().enumerate() {
        vals[n as usize] = (acc as u32 >> i) & 1 != 0;
    }
}

/// Assign the three ports across 64 bit-sliced lanes: lane `l` of every
/// input word carries `xs[l]`'s bits (`xs[l] = (a, acc)`); the weight is
/// broadcast to all lanes. Lanes ≥ `xs.len()` are zero-filled — callers
/// must ignore their outputs.
pub fn set_inputs64(ports: &MacPorts, vals: &mut [u64], w: i8, xs: &[(i8, i32)]) {
    debug_assert!(xs.len() <= 64);
    for (i, &n) in ports.w.iter().enumerate() {
        vals[n as usize] = if (w as u8 >> i) & 1 != 0 { u64::MAX } else { 0 };
    }
    for (i, &n) in ports.a.iter().enumerate() {
        let mut word = 0u64;
        for (l, &(a, _)) in xs.iter().enumerate() {
            word |= (((a as u8 >> i) & 1) as u64) << l;
        }
        vals[n as usize] = word;
    }
    for (i, &n) in ports.acc.iter().enumerate() {
        let mut word = 0u64;
        for (l, &(_, acc)) in xs.iter().enumerate() {
            word |= (((acc as u32 >> i) & 1) as u64) << l;
        }
        vals[n as usize] = word;
    }
}

/// Evaluate the netlist functionally (testing / dynamic sim setup).
pub fn eval(net: &Netlist, ports: &MacPorts, w: i8, a: i8, acc: i32) -> u32 {
    let mut vals = vec![false; net.len()];
    set_inputs(ports, &mut vals, w, a, acc);
    net.eval_into(&mut vals);
    net.read_outputs(&vals) as u32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_reference_on_corners() {
        let (net, ports) = build();
        for &w in &[0i8, 1, -1, 2, 64, 127, -127, -128, 85, -86] {
            for &a in &[0i8, 1, -1, 127, -128, 77, -3] {
                for &acc in &[0i32, 1, -1, 0x7fffff, -0x800000, 12345, -54321] {
                    assert_eq!(
                        eval(&net, &ports, w, a, acc),
                        mac_ref(w, a, acc),
                        "w={w} a={a} acc={acc}"
                    );
                }
            }
        }
    }

    #[test]
    fn matches_reference_randomized() {
        let (net, ports) = build();
        let mut state = 0xdeadbeefu64;
        let mut next = || {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            state
        };
        for _ in 0..2000 {
            let r = next();
            let w = (r >> 8) as u8 as i8;
            let a = (r >> 16) as u8 as i8;
            let acc = ((r >> 24) as u32 & 0xffffff) as i32 - 0x800000;
            assert_eq!(eval(&net, &ports, w, a, acc), mac_ref(w, a, acc), "w={w} a={a} acc={acc}");
        }
    }

    #[test]
    fn exhaustive_multiply_no_acc() {
        let (net, ports) = build();
        for w in i8::MIN..=i8::MAX {
            // all activations for a few weights would be 64k evals; stride a.
            for a in (i16::from(i8::MIN)..=i16::from(i8::MAX)).step_by(7) {
                let a = a as i8;
                assert_eq!(eval(&net, &ports, w, a, 0), mac_ref(w, a, 0), "w={w} a={a}");
            }
        }
    }

    #[test]
    fn bitsliced_eval_matches_reference() {
        // 64 random MACs in one bit-parallel pass.
        let (net, ports) = build();
        let mut rng = crate::util::Rng::seed_from_u64(0xB17);
        let xs: Vec<(i8, i32)> = (0..64)
            .map(|_| (rng.gen_i8(), rng.gen_range_i64(-0x800000, 0x800000) as i32))
            .collect();
        for &w in &[0i8, 1, 64, -127, 85, -86] {
            let mut words = vec![0u64; net.len()];
            set_inputs64(&ports, &mut words, w, &xs);
            net.eval64_into(&mut words);
            for (l, &(a, acc)) in xs.iter().enumerate() {
                assert_eq!(
                    net.read_outputs_lane(&words, l) as u32,
                    mac_ref(w, a, acc),
                    "w={w} lane={l} a={a} acc={acc}"
                );
            }
        }
    }

    #[test]
    fn netlist_size_sane() {
        let (net, _) = build();
        assert!(net.len() > 400 && net.len() < 3000, "gates={}", net.len());
        assert_eq!(net.outputs.len(), ACC_BITS);
    }
}
