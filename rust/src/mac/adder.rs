//! Final carry-propagate adder: Kogge–Stone parallel prefix.
//!
//! Synthesis of DW02_MAC maps the final CPA onto a log-depth prefix adder;
//! a ripple adder would flatten the per-weight delay variation the paper
//! exploits (its linear carry chain would dominate every path), so the
//! prefix structure matters for fidelity, not just speed.

use super::gate::{NetBuilder, NodeId};

/// `width`-bit Kogge–Stone adder (no carry-in); returns sum bits LSB-first.
/// Carry-out is discarded (two's-complement wrap, matching the accumulator
/// register width).
pub fn kogge_stone(nb: &mut NetBuilder, a: &[NodeId], b: &[NodeId]) -> Vec<NodeId> {
    assert_eq!(a.len(), b.len());
    let w = a.len();
    // Bit-level generate/propagate.
    let mut g: Vec<NodeId> = (0..w).map(|i| nb.and(a[i], b[i])).collect();
    let mut p: Vec<NodeId> = (0..w).map(|i| nb.xor(a[i], b[i])).collect();
    let p0 = p.clone(); // save half-sum bits

    let mut dist = 1;
    while dist < w {
        let mut g2 = g.clone();
        let mut p2 = p.clone();
        for i in dist..w {
            // G' = G | (P & G_{i-dist}); P' = P & P_{i-dist}
            let t = nb.and(p[i], g[i - dist]);
            g2[i] = nb.or(g[i], t);
            p2[i] = nb.and(p[i], p[i - dist]);
        }
        g = g2;
        p = p2;
        dist <<= 1;
    }

    // sum_i = p0_i ^ carry_{i-1}, carry_{i-1} = G_{i-1} (prefix over [0, i-1])
    let mut sum = Vec::with_capacity(w);
    sum.push(p0[0]);
    for i in 1..w {
        sum.push(nb.xor(p0[i], g[i - 1]));
    }
    sum
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mac::gate::Netlist;

    fn add(width: usize, x: u64, y: u64) -> u64 {
        let mut nb = NetBuilder::new();
        let a: Vec<NodeId> = nb.inputs(width);
        let b: Vec<NodeId> = nb.inputs(width);
        let s = kogge_stone(&mut nb, &a, &b);
        let net: Netlist = nb.finish(s);
        let mut vals = vec![false; net.len()];
        for i in 0..width {
            vals[a[i] as usize] = (x >> i) & 1 != 0;
            vals[b[i] as usize] = (y >> i) & 1 != 0;
        }
        net.eval_into(&mut vals);
        net.read_outputs(&vals)
    }

    #[test]
    fn adds_exhaustive_6bit() {
        for x in 0..64u64 {
            for y in 0..64u64 {
                assert_eq!(add(6, x, y), (x + y) & 63, "x={x} y={y}");
            }
        }
    }

    #[test]
    fn adds_random_24bit() {
        let mut state = 0x12345678u64;
        for _ in 0..200 {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1);
            let x = state >> 20 & 0xffffff;
            let y = state >> 40 & 0xffffff;
            assert_eq!(add(24, x, y), (x + y) & 0xffffff);
        }
    }

    #[test]
    fn depth_is_logarithmic() {
        // Count the longest topological chain; must be O(log w), not O(w).
        let mut nb = NetBuilder::new();
        let a = nb.inputs(24);
        let b = nb.inputs(24);
        let s = kogge_stone(&mut nb, &a, &b);
        let net = nb.finish(s);
        let mut depth = vec![0u32; net.len()];
        for (i, g) in net.gates.iter().enumerate() {
            depth[i] = g.inputs().map(|j| depth[j as usize] + 1).max().unwrap_or(0);
        }
        let max = net.outputs.iter().map(|&o| depth[o as usize]).max().unwrap();
        assert!(max <= 14, "depth {max} too deep for Kogge-Stone");
    }
}
