//! The per-weight MAC profile: the bridge from circuit analysis to the
//! quantizer and the simulators.
//!
//! For every int8 weight value this records the STA critical-path delay
//! (calibrated to picoseconds), the achievable frequency (Fig. 4), and the
//! mean switching activity / dynamic energy (Fig. 5). From the ranking it
//! derives the two codebooks the paper uses: the 9 fastest values
//! (low-sensitivity tiles, ~3.7 GHz) and the 16 fastest (high-sensitivity
//! tiles, ~2.4 GHz).
//!
//! Calibration pins the full-range worst case to the Table I base level
//! (1.9 GHz): one ps-per-unit factor, everything else is derived.

use std::path::{Path, PathBuf};
use std::sync::OnceLock;

use crate::util::{parallel, Json};

use super::{dynsim, mac8, sta};

/// Table I systolic base frequency: the clock a fully general int8 weight
/// (outliers/salient, RTN W8) must meet.
pub const BASE_FREQ_GHZ: f64 = 1.9;

/// Fast (low-sensitivity) codebook size from the paper (§III-C2).
pub const FAST_SET: usize = 9;
/// Medium (high-sensitivity) codebook size from the paper (§III-C2).
pub const MED_SET: usize = 16;

/// Default number of sampled transitions per weight for timing/power stats.
/// The paper sweeps all activation transitions; we sample (documented in
/// DESIGN.md) — 2048 transitions bounds the max-settle estimate tightly
/// (the settle distribution has a short upper tail, see Fig 3 histograms).
pub const DEFAULT_SAMPLES: usize = 2048;

fn widx(w: i8) -> usize {
    w as u8 as usize
}

/// Per-weight timing/power profile of the 8-bit Booth–Wallace MAC.
///
/// `delay_ps` is the paper's quantity (Figs 3–4): the **maximum settle time
/// across activation/accumulator transitions** with the weight held
/// constant — dynamic path sensitization, which is what bounds the clock of
/// a weight-stationary PE. `sta_delay_ps` is the topological
/// constant-propagation bound (always ≥ the dynamic value); it is kept for
/// validation and as the conservative margin the DVFS unit would sign off.
#[derive(Debug, Clone)]
pub struct MacProfile {
    /// Calibrated max-transition (dynamic) critical-path delay (ps),
    /// indexed by `w as u8`.
    pub delay_ps: Vec<f64>,
    /// Topological STA bound per weight (ps), same calibration.
    pub sta_delay_ps: Vec<f64>,
    /// Achievable frequency (GHz) = 1000 / delay_ps.
    pub freq_ghz: Vec<f64>,
    /// Mean gate toggles per MAC operation.
    pub mean_toggles: Vec<f64>,
    /// Dynamic energy per MAC op at V_NOM (pJ).
    pub energy_pj: Vec<f64>,
    /// The 9 lowest-delay weight values (low-sensitivity codebook).
    pub codebook_fast: Vec<i8>,
    /// The 16 lowest-delay weight values (high-sensitivity codebook).
    pub codebook_med: Vec<i8>,
    /// Achievable frequency of the fast (9-value) class (GHz).
    pub f_fast_ghz: f64,
    /// Achievable frequency of the medium (16-value) class (GHz).
    pub f_med_ghz: f64,
    /// = BASE_FREQ_GHZ by calibration.
    pub f_base_ghz: f64,
    /// ps per pre-calibration delay unit.
    pub ps_per_unit: f64,
    /// Transitions sampled per weight.
    pub samples: usize,
}

impl MacProfile {
    /// Build the profile: dynamic max-settle + toggle stats over sampled
    /// transitions for all 256 weights, plus the STA bound per weight.
    pub fn compute(samples: usize, seed: u64) -> Self {
        let (net, ports) = mac8::build();

        // Dynamic stats: one independent RNG stream per weight value,
        // fanned out over the worker pool (each item is a full bit-sliced
        // transition simulation — the crate's heaviest computation).
        let stats = parallel::par_map(256, |i| {
            dynsim::weight_stats(&net, &ports, i as u8 as i8, samples, seed)
        });
        let sta_units: Vec<u32> = sta::weight_delays_all(&net, &ports);

        // `stats[i]` is the weight whose bit pattern is `i` (== widx).
        let delay_units: Vec<u32> = stats.iter().map(|s| s.max_settle).collect();
        let mean_toggles: Vec<f64> = stats.iter().map(|s| s.mean_toggles).collect();

        let worst = *delay_units.iter().max().expect("non-empty") as f64;
        let ps_per_unit = (1000.0 / BASE_FREQ_GHZ) / worst;

        let delay_ps: Vec<f64> =
            delay_units.iter().map(|&d| d as f64 * ps_per_unit).collect();
        let sta_delay_ps: Vec<f64> =
            sta_units.iter().map(|&d| d as f64 * ps_per_unit).collect();
        let freq_ghz: Vec<f64> = delay_ps
            .iter()
            .map(|&d| if d > 0.0 { 1000.0 / d } else { f64::INFINITY })
            .collect();
        let energy_pj: Vec<f64> = mean_toggles
            .iter()
            .map(|&t| super::power::dynamic_energy_pj(t, super::power::V_NOM))
            .collect();

        // Rank all weights by (delay, |w|, w) — deterministic; ties broken
        // toward small magnitudes purely for reproducibility.
        let mut order: Vec<i8> = (i8::MIN..=i8::MAX).collect();
        order.sort_by_key(|&w| (delay_units[widx(w)], (w as i32).abs(), w));

        let codebook_fast: Vec<i8> = Self::pick_codebook(&order, FAST_SET);
        let codebook_med: Vec<i8> = Self::pick_codebook(&order, MED_SET);

        let class_freq = |cb: &[i8]| {
            cb.iter()
                .map(|&w| freq_ghz[widx(w)])
                .fold(f64::INFINITY, f64::min)
        };
        let f_fast_ghz = class_freq(&codebook_fast);
        let f_med_ghz = class_freq(&codebook_med);

        Self {
            delay_ps,
            sta_delay_ps,
            freq_ghz,
            mean_toggles,
            energy_pj,
            codebook_fast,
            codebook_med,
            f_fast_ghz,
            f_med_ghz,
            f_base_ghz: BASE_FREQ_GHZ,
            ps_per_unit,
            samples,
        }
    }

    /// Select a `size`-value codebook from the delay ranking.
    ///
    /// Greedy with a usability constraint: always include 0, keep the set
    /// sign-balanced (the paper's sets are symmetric — weight distributions
    /// are zero-centered), and otherwise take the fastest remaining values.
    fn pick_codebook(order: &[i8], size: usize) -> Vec<i8> {
        let mut cb: Vec<i8> = Vec::with_capacity(size);
        cb.push(0);
        let mut pos = 0usize; // count of positive entries
        let mut neg = 0usize;
        let half = size / 2; // e.g. 4 for 9, 7..8 for 16
        for &w in order.iter() {
            if cb.len() >= size {
                break;
            }
            if w == 0 || cb.contains(&w) {
                continue;
            }
            if w > 0 && pos >= size - 1 - half {
                continue;
            }
            if w < 0 && neg >= size - 1 - half {
                continue;
            }
            cb.push(w);
            if w > 0 {
                pos += 1;
            } else {
                neg += 1;
            }
        }
        // Fallback: if balance constraints starved the set, fill fastest.
        for &w in order.iter() {
            if cb.len() >= size {
                break;
            }
            if !cb.contains(&w) {
                cb.push(w);
            }
        }
        cb.sort_unstable();
        cb
    }

    /// Worst-case delay (ps) over an arbitrary set of int8 weight values.
    pub fn set_delay_ps(&self, set: &[i8]) -> f64 {
        set.iter().map(|&w| self.delay_ps[widx(w)]).fold(0.0, f64::max)
    }

    /// Achievable frequency (GHz) for a set of weight values.
    pub fn set_freq_ghz(&self, set: &[i8]) -> f64 {
        1000.0 / self.set_delay_ps(set).max(1e-9)
    }

    /// Calibrated dynamic critical-path delay (ps) of weight `w`.
    pub fn delay_of(&self, w: i8) -> f64 {
        self.delay_ps[widx(w)]
    }

    /// Achievable clock (GHz) of weight `w`.
    pub fn freq_of(&self, w: i8) -> f64 {
        self.freq_ghz[widx(w)]
    }

    /// Mean gate toggles per MAC op with weight `w`.
    pub fn toggles_of(&self, w: i8) -> f64 {
        self.mean_toggles[widx(w)]
    }

    /// Dynamic energy per MAC op (pJ) with weight `w`.
    pub fn energy_of(&self, w: i8) -> f64 {
        self.energy_pj[widx(w)]
    }

    /// Mean dynamic energy per MAC over a codebook (pJ) — tile energy proxy.
    pub fn mean_energy_pj(&self, set: &[i8]) -> f64 {
        if set.is_empty() {
            return 0.0;
        }
        set.iter().map(|&w| self.energy_of(w)).sum::<f64>() / set.len() as f64
    }

    /// Mean dynamic energy over the full int8 range (uniform-quant tiles).
    pub fn full_range_energy_pj(&self) -> f64 {
        self.energy_pj.iter().sum::<f64>() / 256.0
    }

    /// Serialize for the on-disk cache / Python-side consumers.
    pub fn to_json(&self) -> Json {
        let f64s = |v: &[f64]| Json::Arr(v.iter().map(|&x| Json::Num(x)).collect());
        let i8s = |v: &[i8]| Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect());
        let mut o = Json::obj();
        o.set("delay_ps", f64s(&self.delay_ps))
            .set("sta_delay_ps", f64s(&self.sta_delay_ps))
            .set("freq_ghz", f64s(&self.freq_ghz))
            .set("mean_toggles", f64s(&self.mean_toggles))
            .set("energy_pj", f64s(&self.energy_pj))
            .set("codebook_fast", i8s(&self.codebook_fast))
            .set("codebook_med", i8s(&self.codebook_med))
            .set("f_fast_ghz", self.f_fast_ghz)
            .set("f_med_ghz", self.f_med_ghz)
            .set("f_base_ghz", self.f_base_ghz)
            .set("ps_per_unit", self.ps_per_unit)
            .set("samples", self.samples);
        o
    }

    /// Deserialize a profile produced by [`to_json`](Self::to_json).
    pub fn from_json(j: &Json) -> crate::Result<Self> {
        let f64s = |k: &str| -> crate::Result<Vec<f64>> {
            j.req(k)?.as_arr()?.iter().map(|x| x.as_f64()).collect()
        };
        let i8s = |k: &str| -> crate::Result<Vec<i8>> {
            Ok(f64s(k)?.into_iter().map(|x| x as i8).collect())
        };
        Ok(Self {
            delay_ps: f64s("delay_ps")?,
            sta_delay_ps: f64s("sta_delay_ps")?,
            freq_ghz: f64s("freq_ghz")?,
            mean_toggles: f64s("mean_toggles")?,
            energy_pj: f64s("energy_pj")?,
            codebook_fast: i8s("codebook_fast")?,
            codebook_med: i8s("codebook_med")?,
            f_fast_ghz: j.req("f_fast_ghz")?.as_f64()?,
            f_med_ghz: j.req("f_med_ghz")?.as_f64()?,
            f_base_ghz: j.req("f_base_ghz")?.as_f64()?,
            ps_per_unit: j.req("ps_per_unit")?.as_f64()?,
            samples: j.req("samples")?.as_usize()?,
        })
    }

    /// Write the profile to `path` atomically (write-then-rename).
    pub fn save(&self, path: &Path) -> crate::Result<()> {
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        // Write-then-rename: concurrent test binaries may race on the same
        // cache key, and a torn file must never be loadable.
        let tmp = path.with_extension(format!("tmp.{}", std::process::id()));
        std::fs::write(&tmp, self.to_json().to_string_pretty())?;
        std::fs::rename(&tmp, path)?;
        Ok(())
    }

    /// Read a profile saved by [`save`](Self::save).
    pub fn load(path: &Path) -> crate::Result<Self> {
        Self::from_json(&Json::parse(&std::fs::read_to_string(path)?)?)
    }

    /// Sanity of a deserialized profile (guards against stale/corrupt
    /// cache files written by older code).
    fn valid_for(&self, samples: usize) -> bool {
        self.samples == samples
            && self.delay_ps.len() == 256
            && self.sta_delay_ps.len() == 256
            && self.freq_ghz.len() == 256
            && self.mean_toggles.len() == 256
            && self.energy_pj.len() == 256
            && self.codebook_fast.len() == FAST_SET
            && self.codebook_med.len() == MED_SET
    }

    /// Directory for on-disk profile caches: `$HALO_PROFILE_DIR`, else
    /// `artifacts/` (the tree `make artifacts` populates).
    pub fn cache_dir() -> PathBuf {
        std::env::var_os("HALO_PROFILE_DIR")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }

    /// Cache file inside `dir`, keyed so any input change invalidates:
    /// netlist structural hash + samples + seed.
    pub fn cache_path_in(dir: &Path, samples: usize, seed: u64) -> PathBuf {
        // The netlist is a fixed structure; hash it once per process so
        // cache-hit lookups don't rebuild the circuit.
        static NET_HASH: OnceLock<u64> = OnceLock::new();
        let hash = *NET_HASH.get_or_init(|| mac8::build().0.structural_hash());
        dir.join(format!("mac_profile_{hash:016x}_s{samples}_r{seed:x}.json"))
    }

    /// Load through the on-disk cache, computing + saving on a miss.
    /// Logs one line per lookup so test/CLI wall-clock wins are visible.
    pub fn cached_or_compute_in(dir: &Path, samples: usize, seed: u64) -> MacProfile {
        let path = Self::cache_path_in(dir, samples, seed);
        match Self::load(&path) {
            Ok(p) if p.valid_for(samples) => {
                eprintln!("[mac] profile cache hit: {}", path.display());
                return p;
            }
            Ok(_) => eprintln!("[mac] profile cache stale, recomputing: {}", path.display()),
            Err(_) => eprintln!(
                "[mac] profile cache miss ({} transitions/weight × 256 weights): {}",
                samples,
                path.display()
            ),
        }
        let p = Self::compute(samples, seed);
        if let Err(e) = p.save(&path) {
            eprintln!("[mac] profile cache write failed ({e}); continuing uncached");
        }
        p
    }

    /// [`cached_or_compute_in`](Self::cached_or_compute_in) in the default
    /// [`cache_dir`](Self::cache_dir).
    pub fn cached_or_compute(samples: usize, seed: u64) -> MacProfile {
        Self::cached_or_compute_in(&Self::cache_dir(), samples, seed)
    }

    /// Process-wide cached profile: the `OnceLock` memoizes within the
    /// process, the disk cache across processes (so repeat test/bench/CLI
    /// runs skip circuit simulation entirely).
    pub fn cached() -> &'static MacProfile {
        static CACHE: OnceLock<MacProfile> = OnceLock::new();
        CACHE.get_or_init(|| MacProfile::cached_or_compute(DEFAULT_SAMPLES, 0x4A10))
    }
}

/// Fig. 3 data: settle-time histogram (ps → count) for one weight value.
pub fn delay_histogram_ps(w: i8, samples: usize, seed: u64) -> Vec<(f64, u32)> {
    let (net, ports) = mac8::build();
    let prof = MacProfile::cached();
    dynsim::settle_histogram(&net, &ports, w, samples, seed)
        .into_iter()
        .map(|(u, c)| (u as f64 * prof.ps_per_unit, c))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn prof() -> &'static MacProfile {
        MacProfile::cached()
    }

    #[test]
    fn calibration_pins_worst_case_to_base_freq() {
        let p = prof();
        let worst = p.delay_ps.iter().cloned().fold(0.0, f64::max);
        assert!((worst - 1000.0 / BASE_FREQ_GHZ).abs() < 1e-6);
        let fmin = p.freq_ghz.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!((fmin - BASE_FREQ_GHZ).abs() < 1e-6);
    }

    #[test]
    fn codebook_sizes_match_paper() {
        let p = prof();
        assert_eq!(p.codebook_fast.len(), FAST_SET);
        assert_eq!(p.codebook_med.len(), MED_SET);
        assert!(p.codebook_fast.contains(&0));
    }

    #[test]
    fn class_frequencies_ordered() {
        // fast class > med class > base — the DVFS ladder shape of Table I.
        let p = prof();
        assert!(p.f_fast_ghz > p.f_med_ghz, "{} vs {}", p.f_fast_ghz, p.f_med_ghz);
        assert!(p.f_med_ghz > p.f_base_ghz, "{} vs {}", p.f_med_ghz, p.f_base_ghz);
    }

    #[test]
    fn fast_codebook_is_booth_sparse() {
        // The fast set is dominated by Booth-sparse values: strictly fewer
        // mean non-zero digits than the full range (2.99 on average), and
        // no member uses more than 2 digits.
        let p = prof();
        let mean_digits = |ws: &[i8]| {
            ws.iter().map(|&w| crate::mac::booth::nonzero_digits(w)).sum::<usize>() as f64
                / ws.len() as f64
        };
        let all: Vec<i8> = (i8::MIN..=i8::MAX).collect();
        assert!(mean_digits(&p.codebook_fast) < mean_digits(&all) - 0.5);
    }

    #[test]
    fn fast_codebook_subset_of_medium() {
        // The quantizer's shared 16-entry codebook table relies on this.
        let p = prof();
        for w in &p.codebook_fast {
            assert!(p.codebook_med.contains(w), "{w} not in medium codebook");
        }
    }

    #[test]
    fn codebook_energy_below_full_range() {
        // Fig. 4/5 correlation: fast weights also switch less.
        let p = prof();
        assert!(p.mean_energy_pj(&p.codebook_fast) < p.full_range_energy_pj());
        assert!(p.mean_energy_pj(&p.codebook_med) <= p.full_range_energy_pj());
    }

    #[test]
    fn disk_cache_roundtrip_and_keying() {
        let dir = std::env::temp_dir().join(format!("halo_profile_cache_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let a = MacProfile::cached_or_compute_in(&dir, 16, 7); // miss → compute + save
        assert!(MacProfile::cache_path_in(&dir, 16, 7).exists());
        let b = MacProfile::cached_or_compute_in(&dir, 16, 7); // hit → load
        assert_eq!(a.delay_ps, b.delay_ps);
        assert_eq!(a.codebook_med, b.codebook_med);
        assert_eq!(a.samples, b.samples);
        // Different samples/seed key different files (no false sharing).
        let p16 = MacProfile::cache_path_in(&dir, 16, 7);
        assert_ne!(p16, MacProfile::cache_path_in(&dir, 17, 7));
        assert_ne!(p16, MacProfile::cache_path_in(&dir, 16, 8));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn parallel_compute_matches_serial() {
        // Thread count must never change the profile (per-weight RNG
        // streams are independent of scheduling).
        let _guard = crate::util::parallel::THREAD_CAP_TEST_LOCK.lock().unwrap();
        let par = MacProfile::compute(24, 3);
        crate::util::parallel::set_max_threads(1);
        let ser = MacProfile::compute(24, 3);
        crate::util::parallel::set_max_threads(0);
        assert_eq!(par.delay_ps, ser.delay_ps);
        assert_eq!(par.mean_toggles, ser.mean_toggles);
        assert_eq!(par.sta_delay_ps, ser.sta_delay_ps);
        assert_eq!(par.codebook_fast, ser.codebook_fast);
    }

    #[test]
    fn save_load_roundtrip() {
        let p = MacProfile::compute(32, 1);
        let path = std::env::temp_dir().join("halo_mac_profile_test.json");
        p.save(&path).unwrap();
        let q = MacProfile::load(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(p.codebook_fast, q.codebook_fast);
        assert_eq!(p.delay_ps, q.delay_ps);
    }
}
