//! # HALO — Hardware-Aware Quantization with Low Critical-Path-Delay Weights
//!
//! Full-system reproduction of *HALO* (Juneja et al., AAAI 2026): a
//! hardware-aware post-training-quantization framework that selects weight
//! values with short MAC critical paths so tiles can be clocked faster, and
//! co-optimizes the quantization with a DVFS schedule.
//!
//! The crate is Layer 3 of a three-layer Rust + JAX + Pallas stack
//! (see `DESIGN.md`):
//!
//! - [`mac`] — gate-level Booth–Wallace MAC circuit model: per-weight static
//!   timing analysis and switching-activity power (paper §II, Figs 3–5).
//! - [`quant`] — the HALO quantization framework (Algorithm 1) and all the
//!   paper's baselines (RTN, SmoothQuant, GPTQ, ZeroQuant).
//! - [`dvfs`] — DVFS levels (Table I), tile→frequency-class assignment and
//!   transition scheduling (§III-C).
//! - [`systolic`] — cycle-level weight-stationary systolic-array simulator
//!   with per-tile clocking and energy decomposition (Figs 8–11).
//! - [`gpu`] — analytic RTX-2080-Ti-class GPU model (Figs 12–13).
//! - [`workload`] — LLM GEMM traces (LLaMA2 / OPT shapes) + synthetic data.
//! - [`runtime`] — pluggable execution backend over the AOT artifacts: a
//!   pure-Rust dense-f32 interpreter ([`runtime::sim`], the default) and a
//!   PJRT/XLA client behind the `xla` cargo feature.
//! - [`model`] — perplexity evaluation + Fisher calibration over artifacts.
//! - [`coordinator`] — std-thread serving loop (router → bounded request
//!   queue → dynamic batcher → executor thread; no tokio in the offline
//!   build), panic-fenced and model-checked (`tests/loom_coordinator.rs`).
//! - [`util`] — in-crate substitutes for unavailable crates, including the
//!   [`util::sync`] shim with its built-in systematic concurrency tester.
//! - [`experiments`] — one generator per paper table/figure.

// Style lints the hand-rolled numeric code intentionally trips: explicit
// index loops are the clearest (and best-vectorizing) form for the blocked
// linear-algebra kernels and the netlist/array simulators.
#![allow(clippy::needless_range_loop, clippy::manual_memcpy, clippy::too_many_arguments)]
// Public items must carry rustdoc. Coverage is landing module-by-module:
// `quant/`, `dvfs/`, `systolic/`, `coordinator/`, `runtime/`, `util/` and
// `mac/` are fully documented and enforced (CI builds docs with
// RUSTDOCFLAGS="-D warnings"); the modules below carry an explicit allow
// until their pass lands (tracked in ROADMAP.md, regression-gated by
// `halo-lint`'s missing-docs inventory).
#![warn(missing_docs)]
// The crate is safe Rust except one audited `&[i8]` → `&[u8]` cast in the
// PJRT literal bridge (`runtime/xla.rs`), which carries a scoped allow +
// SAFETY comment. `halo-lint` additionally requires a SAFETY comment on
// every unsafe block.
#![deny(unsafe_code)]

pub mod coordinator;
pub mod dvfs;
#[allow(missing_docs)]
pub mod experiments;
#[allow(missing_docs)]
pub mod gpu;
pub mod mac;
#[allow(missing_docs)]
pub mod model;
pub mod quant;
pub mod runtime;
pub mod systolic;
pub mod util;
#[allow(missing_docs)]
pub mod workload;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
