//! SmoothQuant (Xiao et al., ICML'23): migrate activation outlier
//! difficulty into the weights via per-input-channel smoothing
//! s_j = max|X_j|^α / max|W_j|^(1-α), W' = diag(s) · W, X' = X · diag(s)⁻¹.
//!
//! Offline substitution (DESIGN.md §Substitutions): real per-channel
//! activation maxima are not observable from the AOT artifacts, so we use
//! the standard synthetic LLM activation model — lognormal channel scales
//! with a small number of strong outlier channels (the exact phenomenon
//! SmoothQuant targets; cf. its Fig. 1). The activation statistics are
//! seeded per layer, so results are reproducible.

use crate::mac::MacProfile;
use crate::util::Rng;

use super::super::tensor::{Matrix, TileGrid};
use super::super::uniform::per_channel;
use super::super::{tile_hw_stats, LayerCtx, QuantResult, Quantizer};

/// Synthetic per-input-channel activation absolute maxima.
pub fn synthetic_act_absmax(k: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::seed_from_u64(seed ^ 0x5307);
    (0..k)
        .map(|_| {
            let base = (rng.gen_normal() * 0.6).exp() as f32; // lognormal σ=0.6
            // ~2% outlier channels with 10-60x magnitude (LLM phenomenon).
            if rng.gen_f64() < 0.02 {
                base * (10.0 + 50.0 * rng.gen_f64() as f32)
            } else {
                base
            }
        })
        .collect()
}

/// SmoothQuant WxA8 with the synthetic activation-statistics substitution.
pub struct SmoothQuant<'p> {
    /// Weight bit-width.
    pub bits: u32,
    /// Migration strength α (reference default 0.5).
    pub alpha: f32,
    /// MAC circuit profile for the per-tile timing/energy stats.
    pub profile: &'p MacProfile,
    /// Tile edge for the hardware-stats grid.
    pub tile: usize,
}

impl<'p> SmoothQuant<'p> {
    /// SmoothQuant at `bits` with the reference α = 0.5.
    pub fn new(bits: u32, profile: &'p MacProfile, tile: usize) -> Self {
        Self { bits, alpha: 0.5, profile, tile }
    }
}

impl<'p> Quantizer for SmoothQuant<'p> {
    fn name(&self) -> String {
        format!("smoothquant-w{}", self.bits)
    }

    fn quantize(&self, w: &Matrix, ctx: &LayerCtx) -> QuantResult {
        let act_max = synthetic_act_absmax(w.rows, ctx.seed);
        let w_rowmax = w.row_absmax();

        // s_j = act^α / w^(1-α); clamp for stability like the reference impl.
        let s: Vec<f32> = act_max
            .iter()
            .zip(&w_rowmax)
            .map(|(&a, &wm)| {
                let s = a.max(1e-5).powf(self.alpha) / wm.max(1e-5).powf(1.0 - self.alpha);
                s.clamp(1e-4, 1e4)
            })
            .collect();

        // Quantize the smoothed weights, then fold the smoothing back so the
        // dequantized matrix lives in the original activation basis (our
        // eval graphs quantize activations per-token dynamically, which
        // absorbs the X' = X / s side).
        let smoothed = Matrix::from_fn(w.rows, w.cols, |r, c| w.get(r, c) * s[r]);
        let (deq_s, img) = per_channel(&smoothed, self.bits);
        let dequant = Matrix::from_fn(w.rows, w.cols, |r, c| deq_s.get(r, c) / s[r]);

        let grid = TileGrid::new(w.rows, w.cols, self.tile);
        let (tile_freq_ghz, tile_energy_pj) = tile_hw_stats(&img, &grid, self.profile);
        QuantResult {
            method: self.name(),
            dequant,
            grid,
            tile_freq_ghz,
            tile_energy_pj,
            bits_eff: self.bits as f64,
            sparse_nnz: 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::check_invariants;
    use super::*;
    use crate::util::Rng;

    #[test]
    fn outlier_channels_present_in_synthetic_stats() {
        let a = synthetic_act_absmax(2000, 1);
        let mean = a.iter().sum::<f32>() / a.len() as f32;
        let n_out = a.iter().filter(|&&x| x > 8.0 * mean).count();
        assert!(n_out > 5, "outlier channels: {n_out}");
    }

    #[test]
    fn smoothing_helps_when_weight_rows_match_act_outliers() {
        // Construct weights whose rows scale inversely with activation
        // magnitude (the compensating structure real LLMs exhibit); then
        // smoothing must reduce W4 error vs plain RTN *in the
        // activation-weighted metric* that matters: sum_j act_j^2 * err_j^2.
        let mut rng = Rng::seed_from_u64(60);
        let k = 128;
        let ctx = LayerCtx { name: "t", grad: None, seed: 0 };
        let act = synthetic_act_absmax(k, ctx.seed);
        let w = Matrix::from_fn(k, 64, |r, _| {
            (rng.gen_normal() as f32 * 0.02) / act[r].max(0.2)
        });
        let p = MacProfile::cached();
        let sq = SmoothQuant::new(4, p, 32).quantize(&w, &ctx);
        let rtn = super::super::rtn::Rtn::new(4, p, 32).quantize(&w, &ctx);
        let weighted = |deq: &Matrix| -> f64 {
            let mut s = 0.0;
            for r in 0..k {
                for c in 0..64 {
                    let e = (deq.get(r, c) - w.get(r, c)) as f64 * act[r] as f64;
                    s += e * e;
                }
            }
            s
        };
        assert!(weighted(&sq.dequant) <= weighted(&rtn.dequant) * 1.05);
    }

    #[test]
    fn invariants_all_bit_widths() {
        let mut rng = Rng::seed_from_u64(61);
        let w = Matrix::random_normal(64, 64, 0.02, &mut rng);
        let p = MacProfile::cached();
        for bits in [8, 4, 3] {
            check_invariants(&SmoothQuant::new(bits, p, 32), &w, &LayerCtx::new("t"));
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let mut rng = Rng::seed_from_u64(62);
        let w = Matrix::random_normal(32, 32, 0.02, &mut rng);
        let p = MacProfile::cached();
        let ctx = LayerCtx { name: "t", grad: None, seed: 7 };
        let a = SmoothQuant::new(4, p, 32).quantize(&w, &ctx);
        let b = SmoothQuant::new(4, p, 32).quantize(&w, &ctx);
        assert_eq!(a.dequant, b.dequant);
    }
}
