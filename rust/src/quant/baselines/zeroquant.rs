//! ZeroQuant (Yao et al., NeurIPS'22) — the two variants the paper
//! compares against (numbers in its Table II come from the LoRC follow-up):
//!
//! - **ZQ-Local**: fine-grained quantization on 128×128 tiles with per-tile
//!   scale and zero-point, compensation ratio 1.0.
//! - **ZQ-Global**: fuses groups of 64 input channels into one quantization
//!   group and applies a global compensation factor of 0.8 per tile
//!   (cheaper calibration, coarser scales).

use crate::mac::MacProfile;

use super::super::tensor::{Matrix, TileGrid};
use super::super::uniform::pe_image;
use super::super::{tile_hw_stats, LayerCtx, QuantResult, Quantizer};

/// Asymmetric (scale + zero-point) quantization of one value.
#[inline]
fn q_asym(v: f32, lo: f32, hi: f32, bits: u32) -> (i32, f32, f32) {
    let levels = ((1u32 << bits) - 1) as f32;
    let range = (hi - lo).max(1e-12);
    let s = range / levels;
    let z = (-lo / s).round();
    let qv = ((v / s) + z).round().clamp(0.0, levels) as i32;
    (qv, s, z)
}

#[inline]
fn deq_asym(qv: i32, s: f32, z: f32) -> f32 {
    (qv as f32 - z) * s
}

/// Signed PE image of an asymmetric b-bit code (shift to signed, then
/// MSB-align onto the int8 datapath).
#[inline]
fn pe_image_asym(qv: i32, bits: u32) -> i8 {
    pe_image(qv - (1 << (bits - 1)), bits)
}

/// ZeroQuant-Local: per-tile asymmetric quantization, compensation 1.0.
pub struct ZqLocal<'p> {
    /// Weight bit-width.
    pub bits: u32,
    /// MAC circuit profile for the per-tile timing/energy stats.
    pub profile: &'p MacProfile,
    /// Tile edge (quantization groups AND hardware-stats grid).
    pub tile: usize,
    /// Post-dequant compensation factor (Local: 1.0).
    pub compensation: f32,
}

impl<'p> ZqLocal<'p> {
    /// ZQ-Local at `bits` over `tile × tile` quantization groups.
    pub fn new(bits: u32, profile: &'p MacProfile, tile: usize) -> Self {
        Self { bits, profile, tile, compensation: 1.0 }
    }
}

impl<'p> Quantizer for ZqLocal<'p> {
    fn name(&self) -> String {
        format!("zq-local-w{}", self.bits)
    }

    fn quantize(&self, w: &Matrix, _ctx: &LayerCtx) -> QuantResult {
        let grid = TileGrid::new(w.rows, w.cols, self.tile);
        let mut dequant = Matrix::zeros(w.rows, w.cols);
        let mut img = vec![0i8; w.numel()];
        for t in 0..grid.n_tiles() {
            let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
            grid.for_each(t, |r, c| {
                let v = w.get(r, c);
                lo = lo.min(v);
                hi = hi.max(v);
            });
            grid.for_each(t, |r, c| {
                let (qv, s, z) = q_asym(w.get(r, c), lo, hi, self.bits);
                dequant.set(r, c, deq_asym(qv, s, z) * self.compensation);
                img[r * w.cols + c] = pe_image_asym(qv, self.bits);
            });
        }
        let (tile_freq_ghz, tile_energy_pj) = tile_hw_stats(&img, &grid, self.profile);
        QuantResult {
            method: self.name(),
            dequant,
            grid,
            tile_freq_ghz,
            tile_energy_pj,
            bits_eff: self.bits as f64,
            sparse_nnz: 0,
        }
    }
}

/// ZeroQuant-Global: fused input-channel groups, compensation 0.8.
pub struct ZqGlobal<'p> {
    /// Weight bit-width.
    pub bits: u32,
    /// MAC circuit profile for the per-tile timing/energy stats.
    pub profile: &'p MacProfile,
    /// Tile edge for the hardware-stats grid.
    pub tile: usize,
    /// Input channels fused into one quantization group.
    pub group_channels: usize,
    /// Global compensation factor (LoRC's 0.8).
    pub compensation: f32,
}

impl<'p> ZqGlobal<'p> {
    /// ZQ-Global at `bits` with 64-channel groups and 0.8 compensation.
    pub fn new(bits: u32, profile: &'p MacProfile, tile: usize) -> Self {
        Self { bits, profile, tile, group_channels: 64, compensation: 0.8 }
    }
}

impl<'p> Quantizer for ZqGlobal<'p> {
    fn name(&self) -> String {
        format!("zq-global-w{}", self.bits)
    }

    fn quantize(&self, w: &Matrix, _ctx: &LayerCtx) -> QuantResult {
        // Fuse blocks of `group_channels` input rows: one (lo, hi) per group.
        let mut dequant = Matrix::zeros(w.rows, w.cols);
        let mut img = vec![0i8; w.numel()];
        let g = self.group_channels;
        let mut r0 = 0usize;
        while r0 < w.rows {
            let r1 = (r0 + g).min(w.rows);
            let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
            for r in r0..r1 {
                for &v in w.row(r) {
                    lo = lo.min(v);
                    hi = hi.max(v);
                }
            }
            for r in r0..r1 {
                for c in 0..w.cols {
                    let (qv, s, z) = q_asym(w.get(r, c), lo, hi, self.bits);
                    // Global compensation: shrink toward zero to offset the
                    // coarse-grid clipping bias (LoRC's 0.8 factor), blended
                    // with the raw dequant.
                    let d = deq_asym(qv, s, z);
                    let comp = self.compensation + (1.0 - self.compensation) * 0.5;
                    dequant.set(r, c, d * comp);
                    img[r * w.cols + c] = pe_image_asym(qv, self.bits);
                }
            }
            r0 = r1;
        }
        let grid = TileGrid::new(w.rows, w.cols, self.tile);
        let (tile_freq_ghz, tile_energy_pj) = tile_hw_stats(&img, &grid, self.profile);
        QuantResult {
            method: self.name(),
            dequant,
            grid,
            tile_freq_ghz,
            tile_energy_pj,
            bits_eff: self.bits as f64,
            sparse_nnz: 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::check_invariants;
    use super::super::rtn::Rtn;
    use super::*;
    use crate::util::Rng;

    fn w(seed: u64) -> Matrix {
        let mut rng = Rng::seed_from_u64(seed);
        Matrix::random_normal(128, 64, 0.02, &mut rng)
    }

    #[test]
    fn asym_roundtrip_exact_on_grid() {
        let (qv, s, z) = q_asym(0.5, -1.0, 1.0, 4);
        let d = deq_asym(qv, s, z);
        assert!((d - 0.5).abs() <= s / 2.0 + 1e-6);
        // Extremes map to grid ends.
        assert_eq!(q_asym(-1.0, -1.0, 1.0, 4).0, 0);
        assert_eq!(q_asym(1.0, -1.0, 1.0, 4).0, 15);
    }

    #[test]
    fn local_beats_rtn_on_tile_banded_magnitudes() {
        // ZeroQuant's fine-granularity claim: when magnitude structure is
        // tile-local (every tile roughly homogeneous, every *column*
        // passing through a high-magnitude band somewhere), per-tile scales
        // adapt and per-output-channel RTN scales cannot.
        let mut rng = Rng::seed_from_u64(80);
        let m = Matrix::from_fn(128, 128, |r, c| {
            let band = (r / 32 + c / 32) % 4;
            rng.gen_normal() as f32 * 0.01 * (2.0f32).powi(band as i32 * 3)
        });
        let p = MacProfile::cached();
        let ctx = LayerCtx::new("t");
        let zq = ZqLocal::new(4, p, 32).quantize(&m, &ctx);
        let rtn = Rtn::new(4, p, 32).quantize(&m, &ctx);
        assert!(
            zq.dequant.mse(&m) < rtn.dequant.mse(&m),
            "zq {} rtn {}",
            zq.dequant.mse(&m),
            rtn.dequant.mse(&m)
        );
    }

    #[test]
    fn global_coarser_than_local() {
        let m = w(81);
        let p = MacProfile::cached();
        let ctx = LayerCtx::new("t");
        let local = ZqLocal::new(4, p, 32).quantize(&m, &ctx);
        let global = ZqGlobal::new(4, p, 32).quantize(&m, &ctx);
        assert!(local.dequant.mse(&m) <= global.dequant.mse(&m));
    }

    #[test]
    fn invariants_both_variants() {
        let m = w(82);
        let p = MacProfile::cached();
        check_invariants(&ZqLocal::new(4, p, 32), &m, &LayerCtx::new("t"));
        check_invariants(&ZqGlobal::new(4, p, 32), &m, &LayerCtx::new("t"));
    }
}
