//! GPTQ (Frantar et al., 2023): Hessian-guided one-shot weight
//! quantization. Quantizes weight columns (input channels) in order,
//! propagating each column's rounding error into the not-yet-quantized
//! columns via the inverse-Hessian Cholesky factor — the OBQ update
//!
//!   W[:, j:] -= err_j · Hinv[j, j:] / Hinv[j, j]
//!
//! Hessian H = E[x xᵀ] over the calibration set (paper: 128 samples).
//! Offline substitution (DESIGN.md): calibration activations are synthetic
//! — unit-variance channels scaled by the same lognormal-with-outliers
//! activation model SmoothQuant uses, so H = diag(act)² + low-rank noise.
//! That preserves what GPTQ exploits: ill-conditioned, outlier-dominated
//! input covariance.

use crate::mac::MacProfile;
use crate::util::Rng;

use super::super::tensor::{inverse_cholesky_upper, Matrix, TileGrid};
use super::super::uniform::{pe_image, q, qmax};
use super::super::{tile_hw_stats, LayerCtx, QuantResult, Quantizer};
use super::smoothquant::synthetic_act_absmax;

/// Synthetic calibration Hessian H = (1/n) Σ x xᵀ with `n_samples` draws.
pub fn synthetic_hessian(k: usize, seed: u64, n_samples: usize) -> Vec<f64> {
    let act = synthetic_act_absmax(k, seed);
    let mut rng = Rng::seed_from_u64(seed ^ 0x6970);
    let mut h = vec![0.0f64; k * k];
    // Low-rank structured samples: x = act ⊙ (z + ρ·u·g) — correlated noise.
    let u: Vec<f64> = (0..k).map(|_| rng.gen_normal()).collect();
    for _ in 0..n_samples {
        let g = rng.gen_normal();
        let x: Vec<f64> = (0..k)
            .map(|j| act[j] as f64 * (rng.gen_normal() + 0.5 * u[j] * g))
            .collect();
        for i in 0..k {
            let xi = x[i] / n_samples as f64;
            for j in 0..k {
                h[i * k + j] += xi * x[j];
            }
        }
    }
    h
}

/// GPTQ with the synthetic-Hessian calibration substitution.
pub struct Gptq<'p> {
    /// Weight bit-width.
    pub bits: u32,
    /// MAC circuit profile for the per-tile timing/energy stats.
    pub profile: &'p MacProfile,
    /// Tile edge for the hardware-stats grid.
    pub tile: usize,
    /// Relative dampening λ = percdamp · mean(diag H) (reference: 0.01).
    pub percdamp: f64,
    /// Synthetic calibration samples for the Hessian (paper: 128).
    pub n_calib: usize,
}

impl<'p> Gptq<'p> {
    /// GPTQ at `bits` with the reference dampening and calibration size.
    pub fn new(bits: u32, profile: &'p MacProfile, tile: usize) -> Self {
        Self { bits, profile, tile, percdamp: 0.01, n_calib: 128 }
    }

    /// Core GPTQ: quantize `w` (K×N, column j = input channel j is row j
    /// here — we quantize along rows of Wᵀ). Our W is (in, out), so GPTQ's
    /// "columns" are our *rows*; error propagates down remaining rows.
    fn run(&self, w: &Matrix, hinv_u: &[f64], scales: &[f32]) -> (Matrix, Vec<i8>) {
        let (k, n) = (w.rows, w.cols);
        let mut work = w.clone(); // rows get updated as we go
        let mut deq = Matrix::zeros(k, n);
        let mut img = vec![0i8; k * n];
        for j in 0..k {
            let d = hinv_u[j * k + j];
            for c in 0..n {
                let v = work.get(j, c);
                let s = scales[c];
                let qv = q(v, s, self.bits);
                let dq = qv as f32 * s;
                deq.set(j, c, dq);
                img[j * n + c] = pe_image(qv, self.bits);
                let err = ((v - dq) as f64) / d;
                // Propagate into remaining rows via Hinv upper row j.
                for jj in (j + 1)..k {
                    let u = hinv_u[j * k + jj];
                    if u != 0.0 {
                        let nv = work.get(jj, c) as f64 - err * u;
                        work.set(jj, c, nv as f32);
                    }
                }
            }
        }
        (deq, img)
    }
}

impl<'p> Quantizer for Gptq<'p> {
    fn name(&self) -> String {
        format!("gptq-w{}", self.bits)
    }

    fn quantize(&self, w: &Matrix, ctx: &LayerCtx) -> QuantResult {
        let k = w.rows;
        let mut h = synthetic_hessian(k, ctx.seed, self.n_calib);
        // Dampen: H += λ I.
        let mean_diag = (0..k).map(|i| h[i * k + i]).sum::<f64>() / k as f64;
        let lambda = self.percdamp * mean_diag.max(1e-12);
        for i in 0..k {
            h[i * k + i] += lambda;
        }
        let hinv_u = inverse_cholesky_upper(&h, k);

        // Per-output-channel scales from the *original* weights.
        let m = qmax(self.bits) as f32;
        let scales: Vec<f32> = w.col_absmax().iter().map(|&a| a / m).collect();

        let (dequant, img) = self.run(w, &hinv_u, &scales);
        let grid = TileGrid::new(w.rows, w.cols, self.tile);
        let (tile_freq_ghz, tile_energy_pj) = tile_hw_stats(&img, &grid, self.profile);
        QuantResult {
            method: self.name(),
            dequant,
            grid,
            tile_freq_ghz,
            tile_energy_pj,
            bits_eff: self.bits as f64,
            sparse_nnz: 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::check_invariants;
    use super::super::rtn::Rtn;
    use super::*;
    use crate::util::Rng;

    #[test]
    fn hessian_is_symmetric_positive() {
        let k = 16;
        let h = synthetic_hessian(k, 3, 64);
        for i in 0..k {
            assert!(h[i * k + i] > 0.0);
            for j in 0..k {
                assert!((h[i * k + j] - h[j * k + i]).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn gptq_beats_rtn_in_hessian_metric() {
        // GPTQ minimizes tr((W-Ŵ)ᵀ H (W-Ŵ)); it must beat RTN there.
        let mut rng = Rng::seed_from_u64(70);
        let k = 48;
        let w = Matrix::random_normal(k, 32, 0.02, &mut rng);
        let ctx = LayerCtx { name: "t", grad: None, seed: 9 };
        let p = MacProfile::cached();
        let h = synthetic_hessian(k, ctx.seed, 128);

        let hess_err = |deq: &Matrix| -> f64 {
            let mut total = 0.0;
            for c in 0..w.cols {
                // eᵀ H e per output column
                let e: Vec<f64> =
                    (0..k).map(|r| (deq.get(r, c) - w.get(r, c)) as f64).collect();
                for i in 0..k {
                    for j in 0..k {
                        total += e[i] * h[i * k + j] * e[j];
                    }
                }
            }
            total
        };

        let gptq = Gptq::new(4, p, 16).quantize(&w, &ctx);
        let rtn = Rtn::new(4, p, 16).quantize(&w, &ctx);
        let (eg, er) = (hess_err(&gptq.dequant), hess_err(&rtn.dequant));
        assert!(eg < er, "gptq {eg} !< rtn {er}");
    }

    #[test]
    fn invariants() {
        let mut rng = Rng::seed_from_u64(71);
        let w = Matrix::random_normal(64, 48, 0.02, &mut rng);
        check_invariants(
            &Gptq::new(4, MacProfile::cached(), 32),
            &w,
            &LayerCtx::new("t"),
        );
    }

    #[test]
    fn deterministic() {
        let mut rng = Rng::seed_from_u64(72);
        let w = Matrix::random_normal(32, 16, 0.02, &mut rng);
        let p = MacProfile::cached();
        let ctx = LayerCtx { name: "t", grad: None, seed: 4 };
        let a = Gptq::new(4, p, 16).quantize(&w, &ctx);
        let b = Gptq::new(4, p, 16).quantize(&w, &ctx);
        assert_eq!(a.dequant, b.dequant);
    }
}
