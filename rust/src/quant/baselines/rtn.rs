//! Round-To-Nearest (RTN) WxA8 and the FP16 identity baseline.
//!
//! RTN is the paper's simplest baseline: symmetric per-output-channel
//! uniform quantization, no calibration. It collapses at W3 (Table II shows
//! perplexities in the thousands) because a 7-level grid cannot cover
//! normal-tailed weights with a per-channel scale.

use crate::mac::MacProfile;

use super::super::tensor::{Matrix, TileGrid};
use super::super::uniform::per_channel;
use super::super::{tile_hw_stats, LayerCtx, QuantResult, Quantizer};

/// Round-To-Nearest WxA8: per-output-channel symmetric uniform grids.
pub struct Rtn<'p> {
    /// Weight bit-width (8 / 4 / 3 in the paper's sweeps).
    pub bits: u32,
    /// MAC circuit profile for the per-tile timing/energy stats.
    pub profile: &'p MacProfile,
    /// Tile edge for the hardware-stats grid.
    pub tile: usize,
}

impl<'p> Rtn<'p> {
    /// RTN at `bits` with hardware stats over `tile × tile` tiles.
    pub fn new(bits: u32, profile: &'p MacProfile, tile: usize) -> Self {
        Self { bits, profile, tile }
    }
}

impl<'p> Quantizer for Rtn<'p> {
    fn name(&self) -> String {
        format!("rtn-w{}", self.bits)
    }

    fn quantize(&self, w: &Matrix, _ctx: &LayerCtx) -> QuantResult {
        let (dequant, img) = per_channel(w, self.bits);
        let grid = TileGrid::new(w.rows, w.cols, self.tile);
        let (tile_freq_ghz, tile_energy_pj) = tile_hw_stats(&img, &grid, self.profile);
        QuantResult {
            method: self.name(),
            dequant,
            grid,
            tile_freq_ghz,
            tile_energy_pj,
            bits_eff: self.bits as f64,
            sparse_nnz: 0,
        }
    }
}

/// FP16 "Ideal" row: identity weights, 16-bit storage/energy accounting.
/// The FP16 datapath runs at the base clock and a wide-MAC energy penalty
/// (handled by the simulators via `bits_eff = 16`).
pub struct Fp16<'p> {
    /// MAC circuit profile (base-clock/energy accounting).
    pub profile: &'p MacProfile,
    /// Tile edge for the hardware-stats grid.
    pub tile: usize,
}

impl<'p> Fp16<'p> {
    /// FP16 identity with hardware stats over `tile × tile` tiles.
    pub fn new(profile: &'p MacProfile, tile: usize) -> Self {
        Self { profile, tile }
    }
}

impl<'p> Quantizer for Fp16<'p> {
    fn name(&self) -> String {
        "fp16".into()
    }

    fn quantize(&self, w: &Matrix, _ctx: &LayerCtx) -> QuantResult {
        let grid = TileGrid::new(w.rows, w.cols, self.tile);
        let n = grid.n_tiles();
        QuantResult {
            method: self.name(),
            dequant: w.clone(),
            grid,
            tile_freq_ghz: vec![self.profile.f_base_ghz; n],
            // FP16 MACs switch ~2x the gates of the worst int8 case.
            tile_energy_pj: vec![self.profile.full_range_energy_pj() * 2.0; n],
            bits_eff: 16.0,
            sparse_nnz: 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::check_invariants;
    use super::*;
    use crate::util::Rng;

    fn w(seed: u64) -> Matrix {
        let mut rng = Rng::seed_from_u64(seed);
        Matrix::random_normal(96, 64, 0.02, &mut rng)
    }

    #[test]
    fn rtn_bits_control_error() {
        let w = w(50);
        let ctx = LayerCtx::new("t");
        let p = MacProfile::cached();
        let e8 = check_invariants(&Rtn::new(8, p, 32), &w, &ctx).dequant.mse(&w);
        let e4 = check_invariants(&Rtn::new(4, p, 32), &w, &ctx).dequant.mse(&w);
        let e3 = check_invariants(&Rtn::new(3, p, 32), &w, &ctx).dequant.mse(&w);
        assert!(e8 < e4 && e4 < e3);
    }

    #[test]
    fn rtn_tiles_land_at_base_class() {
        // Uniform grids contain slow weight values -> tiles cannot beat the
        // medium class, and W8 tiles sit essentially at base.
        let w = w(51);
        let p = MacProfile::cached();
        let res = Rtn::new(8, p, 32).quantize(&w, &LayerCtx::new("t"));
        let avg: f64 =
            res.tile_freq_ghz.iter().sum::<f64>() / res.tile_freq_ghz.len() as f64;
        assert!(avg < p.f_med_ghz, "avg={avg}");
    }

    #[test]
    fn fp16_identity() {
        let w = w(52);
        let p = MacProfile::cached();
        let res = Fp16::new(p, 32).quantize(&w, &LayerCtx::new("t"));
        assert_eq!(res.dequant, w);
        assert_eq!(res.bits_eff, 16.0);
    }
}
