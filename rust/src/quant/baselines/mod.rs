//! Baseline quantizers the paper evaluates against (Table II, Figs 8–13):
//! RTN, SmoothQuant, GPTQ, ZeroQuant-Local/Global, plus the FP16 identity.

pub mod gptq;
pub mod rtn;
pub mod smoothquant;
pub mod zeroquant;

pub use gptq::Gptq;
pub use rtn::{Fp16, Rtn};
pub use smoothquant::SmoothQuant;
pub use zeroquant::{ZqGlobal, ZqLocal};

use super::Quantizer;
#[cfg(test)]
use super::{LayerCtx, QuantResult};

/// All baselines + HALO variants by canonical name, for the CLI/harness.
pub fn by_name<'p>(
    name: &str,
    profile: &'p crate::mac::MacProfile,
    tile: usize,
) -> Option<Box<dyn Quantizer + 'p>> {
    use super::halo::{HaloConfig, HaloQuantizer, Variant};
    let q: Box<dyn Quantizer + 'p> = match name {
        "fp16" => Box::new(Fp16::new(profile, tile)),
        "rtn-w8" | "w8a8" => Box::new(Rtn::new(8, profile, tile)),
        "rtn-w4" | "w4a8" => Box::new(Rtn::new(4, profile, tile)),
        "rtn-w3" | "w3a8" => Box::new(Rtn::new(3, profile, tile)),
        "smoothquant-w8" | "sq-w8" => Box::new(SmoothQuant::new(8, profile, tile)),
        "smoothquant-w4" | "sq-w4" => Box::new(SmoothQuant::new(4, profile, tile)),
        "smoothquant-w3" | "sq-w3" => Box::new(SmoothQuant::new(3, profile, tile)),
        "gptq" | "gptq-w4" => Box::new(Gptq::new(4, profile, tile)),
        "zq-local" => Box::new(ZqLocal::new(4, profile, tile)),
        "zq-global" => Box::new(ZqGlobal::new(4, profile, tile)),
        "halo-perf" => Box::new(HaloQuantizer::new(
            HaloConfig::new(tile, Variant::PerfOpt),
            profile,
        )),
        "halo-acc" => Box::new(HaloQuantizer::new(
            HaloConfig::new(tile, Variant::AccOpt),
            profile,
        )),
        "halo-bal" | "halo" => Box::new(HaloQuantizer::new(
            HaloConfig::new(tile, Variant::Bal),
            profile,
        )),
        _ => return None,
    };
    Some(q)
}

/// Canonical method list for the paper figures.
pub const FIGURE_METHODS: &[&str] = &[
    "fp16", "w8a8", "w4a8", "w3a8", "halo-perf", "halo-acc", "halo-bal",
];

/// Canonical method list for Table II.
pub const TABLE2_METHODS: &[&str] = &[
    "fp16",
    "rtn-w8",
    "rtn-w4",
    "rtn-w3",
    "smoothquant-w8",
    "smoothquant-w4",
    "smoothquant-w3",
    "gptq",
    "zq-local",
    "zq-global",
    "halo-perf",
    "halo-acc",
    "halo-bal",
];

/// Run any quantizer and sanity-check its invariants (shared test helper).
#[cfg(test)]
pub fn check_invariants(q: &dyn Quantizer, w: &super::Matrix, ctx: &LayerCtx) -> QuantResult {
    let res = q.quantize(w, ctx);
    assert_eq!((res.dequant.rows, res.dequant.cols), (w.rows, w.cols));
    assert_eq!(res.tile_freq_ghz.len(), res.grid.n_tiles());
    assert_eq!(res.tile_energy_pj.len(), res.grid.n_tiles());
    assert!(res.bits_eff > 0.0 && res.bits_eff <= 16.0);
    for &f in &res.tile_freq_ghz {
        assert!(f >= crate::mac::profile::BASE_FREQ_GHZ - 1e-9, "freq {f}");
    }
    res
}
