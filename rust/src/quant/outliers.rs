//! Outlier extraction (paper §III-A): the 3σ rule.
//!
//! Values beyond three standard deviations from the mean are removed from
//! the dense matrix (replaced by 0) and routed to the SpMV engine in full
//! precision. Together with the salient weights these are < 0.5 % of all
//! values, so the sparse side is hypersparse.

use super::tensor::Matrix;

/// One extracted weight: (row, col, original value).
pub type Coord = (usize, usize, f32);

/// Extraction output: the cleaned matrix and the extracted coordinates.
#[derive(Debug, Clone)]
pub struct Extracted {
    /// The input with every extracted entry zeroed.
    pub cleaned: Matrix,
    /// Extracted `(row, col, original value)` entries.
    pub coords: Vec<Coord>,
    /// The absolute cut applied (`k · σ`).
    pub sigma_cut: f64,
}

/// Remove values with |w - μ| > kσ (paper uses k = 3).
pub fn extract_outliers(w: &Matrix, k_sigma: f64) -> Extracted {
    let mu = w.mean();
    let sd = w.std();
    let cut = k_sigma * sd;
    let mut cleaned = w.clone();
    let mut coords = Vec::new();
    for r in 0..w.rows {
        for c in 0..w.cols {
            let v = w.get(r, c);
            if (v as f64 - mu).abs() > cut {
                coords.push((r, c, v));
                cleaned.set(r, c, 0.0);
            }
        }
    }
    Extracted { cleaned, coords, sigma_cut: cut }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn gaussian_outlier_fraction_near_theory() {
        // P(|x| > 3σ) ≈ 0.27 % for a normal distribution.
        let mut rng = Rng::seed_from_u64(1);
        let w = Matrix::random_normal(200, 200, 0.02, &mut rng);
        let ex = extract_outliers(&w, 3.0);
        let frac = ex.coords.len() as f64 / w.numel() as f64;
        assert!((0.001..0.006).contains(&frac), "frac={frac}");
    }

    #[test]
    fn extracted_positions_are_zeroed_and_recoverable() {
        let mut rng = Rng::seed_from_u64(2);
        let mut w = Matrix::random_normal(64, 64, 0.02, &mut rng);
        w.set(3, 7, 5.0); // plant an extreme outlier
        let ex = extract_outliers(&w, 3.0);
        assert!(ex.coords.iter().any(|&(r, c, v)| (r, c, v) == (3, 7, 5.0)));
        assert_eq!(ex.cleaned.get(3, 7), 0.0);
        // Reinserting restores the original exactly.
        let mut rec = ex.cleaned.clone();
        for &(r, c, v) in &ex.coords {
            rec.set(r, c, v);
        }
        assert_eq!(rec, w);
    }

    #[test]
    fn no_outliers_in_bounded_matrix() {
        let w = Matrix::from_fn(16, 16, |r, c| ((r + c) % 3) as f32 - 1.0);
        let ex = extract_outliers(&w, 3.0);
        assert!(ex.coords.is_empty());
        assert_eq!(ex.cleaned, w);
    }
}
