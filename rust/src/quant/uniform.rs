//! Symmetric uniform quantization helpers shared by the baselines.
//!
//! All baselines store weights as signed integers on a uniform grid with a
//! scale per channel / tensor / tile; the *int8 image* of the grid (what the
//! PE register holds) is what determines timing via the MAC profile.

use super::tensor::{Matrix, TileGrid};

/// qmax for b-bit symmetric quantization (e.g. 127 for 8, 7 for 4, 3 for 3).
pub fn qmax(bits: u32) -> i32 {
    (1 << (bits - 1)) - 1
}

/// Quantize one value to the b-bit grid with scale `s`; returns the integer.
#[inline]
pub fn q(v: f32, s: f32, bits: u32) -> i32 {
    if s == 0.0 {
        return 0;
    }
    let m = qmax(bits);
    (v / s).round().clamp(-(m as f32) - 1.0, m as f32) as i32
}

/// The int8 value the PE holds for a b-bit integer `qv`: the hardware maps
/// the b-bit grid onto the int8 datapath MSB-aligned (shift left), which is
/// how a W4 value -8..7 appears to the multiplier circuit.
#[inline]
pub fn pe_image(qv: i32, bits: u32) -> i8 {
    (qv << (8 - bits)).clamp(-128, 127) as i8
}

/// Per-output-channel (column) symmetric quantization.
/// Returns (dequantized matrix, int8 PE image of every weight).
pub fn per_channel(w: &Matrix, bits: u32) -> (Matrix, Vec<i8>) {
    let m = qmax(bits) as f32;
    let scales: Vec<f32> = w.col_absmax().iter().map(|&a| a / m).collect();
    let mut deq = Matrix::zeros(w.rows, w.cols);
    let mut img = vec![0i8; w.numel()];
    for r in 0..w.rows {
        for c in 0..w.cols {
            let s = scales[c];
            let qv = q(w.get(r, c), s, bits);
            deq.set(r, c, qv as f32 * s);
            img[r * w.cols + c] = pe_image(qv, bits);
        }
    }
    (deq, img)
}

/// Per-tile symmetric quantization (ZeroQuant-style fine granularity).
pub fn per_tile(w: &Matrix, grid: &TileGrid, bits: u32) -> (Matrix, Vec<i8>, Vec<f32>) {
    let m = qmax(bits) as f32;
    let mut deq = Matrix::zeros(w.rows, w.cols);
    let mut img = vec![0i8; w.numel()];
    let mut scales = Vec::with_capacity(grid.n_tiles());
    for t in 0..grid.n_tiles() {
        let mut amax = 0.0f32;
        grid.for_each(t, |r, c| amax = amax.max(w.get(r, c).abs()));
        let s = amax / m;
        scales.push(s);
        grid.for_each(t, |r, c| {
            let qv = q(w.get(r, c), s, bits);
            deq.set(r, c, qv as f32 * s);
            img[r * w.cols + c] = pe_image(qv, bits);
        });
    }
    (deq, img, scales)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn qmax_values() {
        assert_eq!(qmax(8), 127);
        assert_eq!(qmax(4), 7);
        assert_eq!(qmax(3), 3);
    }

    #[test]
    fn pe_image_msb_aligned() {
        assert_eq!(pe_image(7, 4), 112);
        assert_eq!(pe_image(-8, 4), -128);
        assert_eq!(pe_image(3, 3), 96);
        assert_eq!(pe_image(127, 8), 127);
    }

    #[test]
    fn per_channel_error_bound() {
        // |w - deq(w)| <= scale/2 for every weight.
        let mut rng = Rng::seed_from_u64(11);
        let w = Matrix::random_normal(32, 16, 0.05, &mut rng);
        let (deq, _) = per_channel(&w, 8);
        let scales: Vec<f32> = w.col_absmax().iter().map(|&a| a / 127.0).collect();
        for r in 0..w.rows {
            for c in 0..w.cols {
                let err = (w.get(r, c) - deq.get(r, c)).abs();
                assert!(err <= scales[c] / 2.0 + 1e-7, "err={err} s={}", scales[c]);
            }
        }
    }

    #[test]
    fn lower_bits_higher_error() {
        let mut rng = Rng::seed_from_u64(12);
        let w = Matrix::random_normal(64, 64, 0.05, &mut rng);
        let e8 = w.mse(&per_channel(&w, 8).0);
        let e4 = w.mse(&per_channel(&w, 4).0);
        let e3 = w.mse(&per_channel(&w, 3).0);
        assert!(e8 < e4 && e4 < e3, "{e8} {e4} {e3}");
    }

    #[test]
    fn per_tile_scales_isolate_tiles() {
        // A huge value in one tile must not degrade other tiles.
        let mut rng = Rng::seed_from_u64(13);
        let mut w = Matrix::random_normal(8, 8, 0.05, &mut rng);
        w.set(0, 0, 100.0);
        let grid = TileGrid::new(8, 8, 4);
        let (deq, _, scales) = per_tile(&w, &grid, 4);
        assert_eq!(scales.len(), 4);
        // Tile 3 (bottom-right) unaffected by the outlier in tile 0.
        let mut err = 0.0f32;
        for r in 4..8 {
            for c in 4..8 {
                err = err.max((w.get(r, c) - deq.get(r, c)).abs());
            }
        }
        assert!(err < 0.05 / 7.0, "err={err}");
    }
}
