//! Dense f32 matrix substrate for the quantizers.
//!
//! Row-major `(rows, cols)`; weight matrices follow the L2 convention
//! `y = x @ W` with `W: (in_features, out_features)` — a column of `W` is
//! one output channel. Includes the small dense-linear-algebra kernel set
//! GPTQ needs (symmetric Cholesky, triangular inversion).

use crate::util::Rng;

/// A dense row-major f32 matrix — the substrate every quantizer and the
/// pure-Rust interpreter operate on.
#[derive(Debug, Clone, PartialEq)]
pub struct Matrix {
    /// Row count.
    pub rows: usize,
    /// Column count.
    pub cols: usize,
    /// Row-major storage, `rows * cols` elements.
    pub data: Vec<f32>,
}

impl Matrix {
    /// All-zero matrix of the given shape.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Wrap an existing row-major buffer (length must match the shape).
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols);
        Self { rows, cols, data }
    }

    /// Build element-wise from `f(row, col)`.
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                data.push(f(r, c));
            }
        }
        Self { rows, cols, data }
    }

    /// N(0, std) entries — synthetic weight generator for sims/tests.
    pub fn random_normal(rows: usize, cols: usize, std: f32, rng: &mut Rng) -> Self {
        Self::from_fn(rows, cols, |_, _| rng.gen_normal() as f32 * std)
    }

    /// Element at `(r, c)`.
    #[inline]
    pub fn get(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols + c]
    }

    /// Overwrite the element at `(r, c)`.
    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        self.data[r * self.cols + c] = v;
    }

    /// Row `r` as a slice.
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Row `r` as a mutable slice.
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Total element count (`rows * cols`).
    pub fn numel(&self) -> usize {
        self.data.len()
    }

    /// Largest absolute value (quantization range input).
    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
    }

    /// Mean over all elements (f64 accumulation).
    pub fn mean(&self) -> f64 {
        self.data.iter().map(|&x| x as f64).sum::<f64>() / self.numel().max(1) as f64
    }

    /// Population standard deviation (the 3σ outlier-cut input).
    pub fn std(&self) -> f64 {
        let mu = self.mean();
        let var = self
            .data
            .iter()
            .map(|&x| (x as f64 - mu) * (x as f64 - mu))
            .sum::<f64>()
            / self.numel().max(1) as f64;
        var.sqrt()
    }

    /// Per-column (output-channel) absolute maximum.
    pub fn col_absmax(&self) -> Vec<f32> {
        let mut m = vec![0.0f32; self.cols];
        for r in 0..self.rows {
            for (c, &x) in self.row(r).iter().enumerate() {
                m[c] = m[c].max(x.abs());
            }
        }
        m
    }

    /// Per-row (input-channel) absolute maximum.
    pub fn row_absmax(&self) -> Vec<f32> {
        (0..self.rows)
            .map(|r| self.row(r).iter().fold(0.0f32, |m, &x| m.max(x.abs())))
            .collect()
    }

    /// Mean squared difference — quantization error metric.
    pub fn mse(&self, other: &Matrix) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(&a, &b)| (a as f64 - b as f64) * (a as f64 - b as f64))
            .sum::<f64>()
            / self.numel().max(1) as f64
    }

    /// Dense matmul (small sizes: tests, GPTQ Hessian assembly).
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows);
        let mut out = Matrix::zeros(self.rows, other.cols);
        for r in 0..self.rows {
            for k in 0..self.cols {
                let a = self.get(r, k);
                if a == 0.0 {
                    continue;
                }
                let orow = other.row(k);
                let out_row = out.row_mut(r);
                for (c, &b) in orow.iter().enumerate() {
                    out_row[c] += a * b;
                }
            }
        }
        out
    }
}

/// Tile grid geometry over a matrix (paper: 128×128 default).
///
/// Ragged edges are allowed (`PadMatrix` in Algorithm 1 pads, we clamp tile
/// bounds instead — equivalent because padded weights are zero and zero is
/// in every codebook).
#[derive(Debug, Clone, Copy)]
pub struct TileGrid {
    /// Matrix row count the grid covers.
    pub rows: usize,
    /// Matrix column count.
    pub cols: usize,
    /// Tile edge length (tiles are `tile × tile`, clamped at the edges).
    pub tile: usize,
    /// Tile rows (`ceil(rows / tile)`).
    pub tiles_r: usize,
    /// Tile columns (`ceil(cols / tile)`).
    pub tiles_c: usize,
}

impl TileGrid {
    /// Grid of `tile × tile` tiles over a `(rows, cols)` matrix.
    pub fn new(rows: usize, cols: usize, tile: usize) -> Self {
        assert!(tile > 0);
        Self {
            rows,
            cols,
            tile,
            tiles_r: rows.div_ceil(tile),
            tiles_c: cols.div_ceil(tile),
        }
    }

    /// Total tile count (`tiles_r * tiles_c`).
    pub fn n_tiles(&self) -> usize {
        self.tiles_r * self.tiles_c
    }

    /// (row range, col range) of tile `t` (row-major tile index).
    pub fn bounds(&self, t: usize) -> (std::ops::Range<usize>, std::ops::Range<usize>) {
        let tr = t / self.tiles_c;
        let tc = t % self.tiles_c;
        let r0 = tr * self.tile;
        let c0 = tc * self.tile;
        (
            r0..(r0 + self.tile).min(self.rows),
            c0..(c0 + self.tile).min(self.cols),
        )
    }

    /// Apply `f(r, c)` over every element of tile `t`.
    pub fn for_each(&self, t: usize, mut f: impl FnMut(usize, usize)) {
        let (rr, cc) = self.bounds(t);
        for r in rr {
            for c in cc.clone() {
                f(r, c);
            }
        }
    }

    /// Number of elements in tile `t` (edge tiles may be smaller).
    pub fn tile_numel(&self, t: usize) -> usize {
        let (rr, cc) = self.bounds(t);
        rr.len() * cc.len()
    }
}

// ---- dense linear algebra for GPTQ ----

/// Cholesky decomposition of a symmetric positive-definite matrix (f64):
/// returns lower-triangular L with A = L Lᵀ. Panics if not SPD.
pub fn cholesky(a: &[f64], n: usize) -> Vec<f64> {
    let mut l = vec![0.0f64; n * n];
    for i in 0..n {
        for j in 0..=i {
            let mut s = a[i * n + j];
            for k in 0..j {
                s -= l[i * n + k] * l[j * n + k];
            }
            if i == j {
                assert!(s > 0.0, "matrix not positive definite at {i} (s={s})");
                l[i * n + i] = s.sqrt();
            } else {
                l[i * n + j] = s / l[j * n + j];
            }
        }
    }
    l
}

/// Invert a lower-triangular matrix (forward substitution per column).
pub fn invert_lower(l: &[f64], n: usize) -> Vec<f64> {
    let mut inv = vec![0.0f64; n * n];
    for c in 0..n {
        inv[c * n + c] = 1.0 / l[c * n + c];
        for r in (c + 1)..n {
            let mut s = 0.0;
            for k in c..r {
                s += l[r * n + k] * inv[k * n + c];
            }
            inv[r * n + c] = -s / l[r * n + r];
        }
    }
    inv
}

/// Upper-triangular U with UᵀU = A⁻¹ — exactly what GPTQ's error
/// propagation consumes (`torch.linalg.cholesky(cholesky_inverse(...),
/// upper=True)` in the reference implementation).
///
/// Steps: A = L Lᵀ → A⁻¹ = L⁻ᵀ L⁻¹ (formed explicitly) → lower Cholesky
/// of A⁻¹ → transpose.
pub fn inverse_cholesky_upper(a: &[f64], n: usize) -> Vec<f64> {
    let l = cholesky(a, n);
    let linv = invert_lower(&l, n);
    // A⁻¹[i][j] = Σ_k Linv[k][i] · Linv[k][j]  (k ≥ max(i,j); Linv lower)
    let mut ainv = vec![0.0f64; n * n];
    for i in 0..n {
        for j in 0..=i {
            let mut s = 0.0;
            for k in i..n {
                s += linv[k * n + i] * linv[k * n + j];
            }
            ainv[i * n + j] = s;
            ainv[j * n + i] = s;
        }
    }
    let lm = cholesky(&ainv, n);
    // U = LMᵀ  ⇒  UᵀU = LM LMᵀ = A⁻¹.
    let mut u = vec![0.0f64; n * n];
    for r in 0..n {
        for c in 0..=r {
            u[c * n + r] = lm[r * n + c];
        }
    }
    u
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_small() {
        let a = Matrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let b = Matrix::from_vec(3, 2, vec![7., 8., 9., 10., 11., 12.]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![58., 64., 139., 154.]);
    }

    #[test]
    fn tile_grid_ragged() {
        let g = TileGrid::new(100, 70, 32);
        assert_eq!((g.tiles_r, g.tiles_c), (4, 3));
        // Last tile is 4 x 6.
        let (rr, cc) = g.bounds(g.n_tiles() - 1);
        assert_eq!((rr.len(), cc.len()), (4, 6));
        // All tiles cover the matrix exactly once.
        let mut seen = vec![0u8; 100 * 70];
        for t in 0..g.n_tiles() {
            g.for_each(t, |r, c| seen[r * 70 + c] += 1);
        }
        assert!(seen.iter().all(|&x| x == 1));
    }

    #[test]
    fn cholesky_roundtrip() {
        // A = B Bᵀ + I is SPD.
        let n = 8;
        let mut rng = Rng::seed_from_u64(5);
        let b = Matrix::random_normal(n, n, 1.0, &mut rng);
        let mut a = vec![0.0f64; n * n];
        for i in 0..n {
            for j in 0..n {
                let mut s = if i == j { 1.0 } else { 0.0 };
                for k in 0..n {
                    s += b.get(i, k) as f64 * b.get(j, k) as f64;
                }
                a[i * n + j] = s;
            }
        }
        let l = cholesky(&a, n);
        // L Lᵀ == A
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for k in 0..n {
                    s += l[i * n + k] * l[j * n + k];
                }
                assert!((s - a[i * n + j]).abs() < 1e-9);
            }
        }
        // UᵀU == A⁻¹  (check A · (UᵀU) == I) and U is upper-triangular.
        let u = inverse_cholesky_upper(&a, n);
        for r in 1..n {
            for c in 0..r {
                assert_eq!(u[r * n + c], 0.0, "U not upper at ({r},{c})");
            }
        }
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for k in 0..n {
                    // (A · UᵀU)[i][j]
                    let utu_kj: f64 = (0..n).map(|m| u[m * n + k] * u[m * n + j]).sum();
                    s += a[i * n + k] * utu_kj;
                }
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((s - want).abs() < 1e-8, "({i},{j}): {s}");
            }
        }
    }

    #[test]
    fn stats() {
        let m = Matrix::from_vec(2, 2, vec![1., -3., 2., 0.]);
        assert_eq!(m.max_abs(), 3.0);
        assert_eq!(m.col_absmax(), vec![2.0, 3.0]);
        assert_eq!(m.row_absmax(), vec![3.0, 2.0]);
        assert_eq!(m.mean(), 0.0);
    }
}
