//! Weight-sensitivity analysis (paper §III-A, Eq. 1).
//!
//! The Hessian is approximated by the empirical Fisher information
//! F = 1/|D| Σ g g^T; per-weight saliency uses the diagonal, i.e. the mean
//! squared gradient from the calibration batches. The top `frac` (paper:
//! 0.05 %) of weights by saliency are preserved in full precision next to
//! the 3σ outliers.

use super::outliers::Coord;
use super::tensor::Matrix;

/// Per-weight saliency Λ_W = diag(F) = mean g² (the grad matrix passed in
/// is already averaged over the calibration set by the caller).
pub fn fisher_diag(grad: &Matrix) -> Matrix {
    Matrix::from_fn(grad.rows, grad.cols, |r, c| {
        let g = grad.get(r, c);
        g * g
    })
}

/// Extract the top `frac` of weights by Fisher saliency.
/// Returns the cleaned matrix (salient entries zeroed) and their coords.
pub fn extract_salient(w: &Matrix, grad: &Matrix, frac: f64) -> (Matrix, Vec<Coord>) {
    assert_eq!((w.rows, w.cols), (grad.rows, grad.cols));
    let n_keep = ((w.numel() as f64 * frac).ceil() as usize).min(w.numel());
    if n_keep == 0 {
        return (w.clone(), Vec::new());
    }

    // Threshold = n_keep-th largest g² (selection without full sort).
    let mut scores: Vec<f32> = grad.data.iter().map(|&g| g * g).collect();
    let k = scores.len() - n_keep;
    scores.select_nth_unstable_by(k, |a, b| a.partial_cmp(b).unwrap());
    let threshold = scores[k];

    let mut cleaned = w.clone();
    let mut coords = Vec::with_capacity(n_keep);
    for r in 0..w.rows {
        for c in 0..w.cols {
            let g = grad.get(r, c);
            if g * g >= threshold && coords.len() < n_keep {
                coords.push((r, c, w.get(r, c)));
                cleaned.set(r, c, 0.0);
            }
        }
    }
    (cleaned, coords)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn keeps_exactly_top_fraction() {
        let mut rng = Rng::seed_from_u64(3);
        let w = Matrix::random_normal(100, 100, 0.02, &mut rng);
        let g = Matrix::random_normal(100, 100, 1.0, &mut rng);
        let (_, coords) = extract_salient(&w, &g, 0.0005);
        assert_eq!(coords.len(), 5); // ceil(10000 * 0.0005)
    }

    #[test]
    fn selects_highest_gradient_weights() {
        let w = Matrix::from_fn(4, 4, |r, c| (r * 4 + c) as f32);
        let mut g = Matrix::zeros(4, 4);
        g.set(2, 3, 10.0);
        g.set(0, 0, -20.0); // saliency uses g², sign irrelevant
        let (cleaned, coords) = extract_salient(&w, &g, 2.0 / 16.0);
        let pos: Vec<(usize, usize)> = coords.iter().map(|&(r, c, _)| (r, c)).collect();
        assert!(pos.contains(&(0, 0)) && pos.contains(&(2, 3)));
        assert_eq!(cleaned.get(2, 3), 0.0);
        assert_eq!(cleaned.get(0, 0), 0.0);
    }

    #[test]
    fn zero_fraction_is_noop() {
        let mut rng = Rng::seed_from_u64(4);
        let w = Matrix::random_normal(8, 8, 1.0, &mut rng);
        let g = Matrix::random_normal(8, 8, 1.0, &mut rng);
        let (cleaned, coords) = extract_salient(&w, &g, 0.0);
        assert!(coords.is_empty());
        assert_eq!(cleaned, w);
    }

    #[test]
    fn fisher_diag_is_squared_grad() {
        let g = Matrix::from_vec(1, 3, vec![1.0, -2.0, 3.0]);
        assert_eq!(fisher_diag(&g).data, vec![1.0, 4.0, 9.0]);
    }
}
