//! Tile-based sensitivity analysis and the adaptive-k mapping (paper §III-B).
//!
//! Per-tile sensitivity (Eq. 2) is the mean squared gradient over the tile.
//! The adaptive mapping sorts tiles by sensitivity, accumulates until a
//! target fraction of the layer's total sensitivity (e.g. 95 %) is covered,
//! and classifies the covering tiles as high-sensitivity; the remainder
//! (fraction k of all tiles) is low-sensitivity and can be quantized
//! aggressively onto the fast codebook.

use super::tensor::{Matrix, TileGrid};

/// Per-tile sensitivity Λ_Tk = Σ g² / numel (Eq. 2). Row-major tile order.
pub fn tile_sensitivity(grad: &Matrix, grid: &TileGrid) -> Vec<f64> {
    assert_eq!((grad.rows, grad.cols), (grid.rows, grid.cols));
    (0..grid.n_tiles())
        .map(|t| {
            let mut s = 0.0f64;
            let mut n = 0usize;
            grid.for_each(t, |r, c| {
                let g = grad.get(r, c) as f64;
                s += g * g;
                n += 1;
            });
            s / n.max(1) as f64
        })
        .collect()
}

/// Compute the adaptive threshold k (paper §III-B, "ComputeAdaptiveK"):
/// the fraction of tiles classified *low*-sensitivity after the
/// highest-sensitivity tiles covering `keep_frac` of the cumulative
/// sensitivity are marked high. Defaults to 1.0 (all low) when the layer
/// has no gradient signal.
pub fn adaptive_k(sens: &[f64], keep_frac: f64) -> f64 {
    let total: f64 = sens.iter().sum();
    if total <= 0.0 || sens.is_empty() {
        return 1.0;
    }
    let mut order: Vec<usize> = (0..sens.len()).collect();
    order.sort_by(|&a, &b| sens[b].partial_cmp(&sens[a]).unwrap());
    let mut cum = 0.0;
    for (i, &t) in order.iter().enumerate() {
        cum += sens[t];
        if cum / total >= keep_frac {
            // Tiles 0..=i (sorted) are high-sensitivity.
            let high = i + 1;
            return (sens.len() - high) as f64 / sens.len() as f64;
        }
    }
    1.0
}

/// Boolean masks: `true` = low-sensitivity tile (aggressive quantization).
/// `k` is the fraction of tiles classified low (lowest-sensitivity first).
pub fn low_sensitivity_mask(sens: &[f64], k: f64) -> Vec<bool> {
    let n = sens.len();
    let n_low = ((n as f64) * k).round() as usize;
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| sens[a].partial_cmp(&sens[b]).unwrap());
    let mut mask = vec![false; n];
    for &t in order.iter().take(n_low) {
        mask[t] = true;
    }
    mask
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn sensitivity_matches_manual() {
        let g = Matrix::from_vec(2, 4, vec![1., 1., 2., 2., 1., 1., 2., 2.]);
        let grid = TileGrid::new(2, 4, 2);
        let s = tile_sensitivity(&g, &grid);
        assert_eq!(s, vec![1.0, 4.0]);
    }

    #[test]
    fn adaptive_k_uniform_sensitivity() {
        // Uniform tiles: covering 95% needs 95% of tiles -> k ≈ 0.05.
        let sens = vec![1.0; 100];
        let k = adaptive_k(&sens, 0.95);
        assert!((k - 0.05).abs() < 0.011, "k={k}");
    }

    #[test]
    fn adaptive_k_concentrated_sensitivity() {
        // One dominant tile: k -> (n-1)/n.
        let mut sens = vec![1e-12; 10];
        sens[3] = 1.0;
        let k = adaptive_k(&sens, 0.95);
        assert!((k - 0.9).abs() < 1e-9, "k={k}");
    }

    #[test]
    fn adaptive_k_no_signal_defaults_to_one() {
        assert_eq!(adaptive_k(&[0.0; 5], 0.95), 1.0);
        assert_eq!(adaptive_k(&[], 0.95), 1.0);
    }

    #[test]
    fn mask_marks_lowest_sensitivity_tiles() {
        let sens = vec![5.0, 1.0, 3.0, 0.5];
        let mask = low_sensitivity_mask(&sens, 0.5);
        assert_eq!(mask, vec![false, true, false, true]);
    }

    #[test]
    fn mask_count_matches_k() {
        let mut rng = Rng::seed_from_u64(9);
        let sens: Vec<f64> = (0..64).map(|_| rng.gen_f64()).collect();
        for &k in &[0.0, 0.25, 0.5, 1.0] {
            let mask = low_sensitivity_mask(&sens, k);
            assert_eq!(mask.iter().filter(|&&m| m).count(), (64.0 * k) as usize);
        }
    }

    #[test]
    fn cumulative_coverage_property() {
        // The high-sensitivity set must cover >= keep_frac of total
        // sensitivity for random inputs.
        let mut rng = Rng::seed_from_u64(10);
        for _ in 0..20 {
            let sens: Vec<f64> = (0..50).map(|_| rng.gen_f64().powi(3)).collect();
            let keep = 0.9;
            let k = adaptive_k(&sens, keep);
            let mask = low_sensitivity_mask(&sens, k);
            let total: f64 = sens.iter().sum();
            let high: f64 = sens
                .iter()
                .zip(&mask)
                .filter(|(_, &low)| !low)
                .map(|(&s, _)| s)
                .sum();
            assert!(high / total >= keep - 0.02, "cover={}", high / total);
        }
    }
}
