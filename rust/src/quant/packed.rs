//! The packed execution format: HALO-quantized layers as contiguous
//! codebook-index tiles, ready for native execution.
//!
//! [`super::halo::HaloPayload`] is the *wire* format (whole-matrix index
//! plane + shared table — the operands of the lowered `fwd_halo` graph).
//! [`PackedLayer`] is the *execution* format the pure-Rust engine in
//! [`crate::runtime::qkernels`] consumes: one contiguous `u8` code block
//! per tile (row-major within the tile), the shared 16-entry codebook
//! table, a per-tile scale, and the tile's DVFS class/frequency/energy
//! tags from the MAC circuit model. The hypersparse outlier/salient side
//! matrix rides along untouched so the execution engine can fuse it as an
//! SpMV epilogue instead of scattering it into a dense copy.
//!
//! Nothing here ever materializes a dense f32 weight matrix;
//! [`PackedLayer::dequantize`] exists only as the test/bench oracle.

use crate::dvfs::{classify, FreqClass};
use crate::mac::MacProfile;

use super::halo::HaloPayload;
use super::sparse::SparseMatrix;
use super::tensor::{Matrix, TileGrid};
use super::QuantResult;

/// Number of entries in the shared codebook table (the medium book; the
/// fast book is a subset occupying 9 of the 16 slots).
pub const TABLE_LEN: usize = 16;

/// One quantized tile in execution form: contiguous codebook indices plus
/// the hardware tags the per-tile cycle-cost model reads.
#[derive(Debug, Clone)]
pub struct PackedTile {
    /// Codebook index per element, row-major within the tile, indices in
    /// shared-table space (`0..TABLE_LEN`). Edge tiles are smaller.
    pub codes: Vec<u8>,
    /// Tile height (rows actually covered — edge tiles may be short).
    pub rows: usize,
    /// Tile width (columns actually covered).
    pub cols: usize,
    /// Dequantization scale: `w = table[code] * scale`.
    pub scale: f32,
    /// True ⇒ the tile is codebook-pure over the 9-value fast book.
    pub fast: bool,
    /// DVFS class of the tile (fast/med from the codebook; never base —
    /// HALO tiles are codebook-pure by construction).
    pub class: FreqClass,
    /// Achievable clock of the tile's codebook class (GHz, circuit model).
    pub freq_ghz: f64,
    /// Mean dynamic MAC energy per op over the tile's codebook (pJ, V_NOM).
    pub energy_pj: f64,
}

impl PackedTile {
    /// Multiply-accumulate operations this tile contributes per activation
    /// row.
    pub fn macs(&self) -> usize {
        self.rows * self.cols
    }
}

/// A whole linear layer in packed execution form.
#[derive(Debug, Clone)]
pub struct PackedLayer {
    /// Parameter name (e.g. `layer0.attn.wq`).
    pub name: String,
    /// Tile geometry over the layer's `(rows, cols)` — also the single
    /// source of the layer's dimensions ([`Self::rows`] / [`Self::cols`]).
    pub grid: TileGrid,
    /// The shared 16-entry codebook table (medium book; fast ⊆ med).
    pub table: [f32; TABLE_LEN],
    /// One packed tile per grid cell, row-major tile order.
    pub tiles: Vec<PackedTile>,
    /// Full-precision outlier/salient side matrix (SpMV epilogue operand).
    pub sparse: SparseMatrix,
    /// Modeled stored bits per weight (Table II BW accounting).
    pub bits_eff: f64,
}

impl PackedLayer {
    /// Pack a quantization result + payload into execution form. The
    /// payload's whole-matrix index plane is re-tiled into contiguous
    /// per-tile code blocks; every tile is tagged with its DVFS class from
    /// `profile`.
    pub fn pack(
        name: &str,
        result: &QuantResult,
        payload: &HaloPayload,
        profile: &MacProfile,
    ) -> Self {
        let grid = result.grid;
        let (rows, cols) = (grid.rows, grid.cols);
        debug_assert_eq!(payload.idx.len(), rows * cols);
        let mut table = [0.0f32; TABLE_LEN];
        for (slot, &v) in table.iter_mut().zip(payload.codebook.iter()) {
            *slot = v;
        }
        let mut tiles = Vec::with_capacity(grid.n_tiles());
        for t in 0..grid.n_tiles() {
            let (rr, cc) = grid.bounds(t);
            let (th, tw) = (rr.len(), cc.len());
            let mut codes = Vec::with_capacity(th * tw);
            grid.for_each(t, |r, c| codes.push(payload.idx[r * cols + c]));
            let freq_ghz = result.tile_freq_ghz[t];
            tiles.push(PackedTile {
                codes,
                rows: th,
                cols: tw,
                scale: payload.scales[t],
                fast: payload.tile_fast[t],
                class: classify(freq_ghz, profile),
                freq_ghz,
                energy_pj: result.tile_energy_pj[t],
            });
        }
        Self {
            name: name.to_string(),
            grid,
            table,
            tiles,
            sparse: payload.sparse.clone(),
            bits_eff: result.bits_eff,
        }
    }

    /// Input features (K of `y = x @ W`).
    pub fn rows(&self) -> usize {
        self.grid.rows
    }

    /// Output features (N).
    pub fn cols(&self) -> usize {
        self.grid.cols
    }

    /// DVFS class per tile, row-major tile order (schedule input).
    pub fn classes(&self) -> Vec<FreqClass> {
        self.tiles.iter().map(|t| t.class).collect()
    }

    /// Bytes the packed representation actually touches per pass: one `u8`
    /// code per dense weight, the shared table, a scale per tile, and
    /// `(f32 val, u32 pos)` per live sparse entry (padding excluded — it
    /// is an alignment artifact, not traffic).
    pub fn packed_bytes(&self) -> usize {
        let codes: usize = self.tiles.iter().map(|t| t.codes.len()).sum();
        codes
            + TABLE_LEN * std::mem::size_of::<f32>()
            + self.tiles.len() * std::mem::size_of::<f32>()
            + self.sparse.nnz * 8
    }

    /// Bytes a dense f32 copy of the layer would touch per pass.
    pub fn dense_bytes(&self) -> usize {
        self.rows() * self.cols() * std::mem::size_of::<f32>()
    }

    /// Dense reconstruction — the dequantize-then-dense **oracle** for the
    /// equivalence tests and benchmarks. The serving path never calls this.
    pub fn dequantize(&self) -> Matrix {
        let mut out = Matrix::zeros(self.rows(), self.cols());
        for (t, tile) in self.tiles.iter().enumerate() {
            let mut i = 0usize;
            self.grid.for_each(t, |r, c| {
                out.set(r, c, self.table[tile.codes[i] as usize] * tile.scale);
                i += 1;
            });
        }
        self.sparse.scatter_into(&mut out);
        out
    }

    /// Total multiply-accumulates per activation row (`rows * cols`).
    pub fn macs_per_row(&self) -> usize {
        self.rows() * self.cols()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mac::MacProfile;
    use crate::quant::{HaloConfig, HaloQuantizer, LayerCtx, Variant};
    use crate::util::Rng;

    fn quantize(rows: usize, cols: usize, tile: usize, seed: u64) -> (Matrix, PackedLayer) {
        let profile = MacProfile::cached();
        let mut rng = Rng::seed_from_u64(seed);
        let w = Matrix::random_normal(rows, cols, 0.02, &mut rng);
        let g = Matrix::random_normal(rows, cols, 1.0, &mut rng);
        let q = HaloQuantizer::new(HaloConfig::new(tile, Variant::Bal), profile);
        let (res, pay) = q.quantize_full(&w, &LayerCtx::with_grad("t", &g));
        let packed = PackedLayer::pack("t", &res, &pay, profile);
        (res.dequant, packed)
    }

    #[test]
    fn pack_dequantize_matches_quant_result() {
        for (rows, cols, tile) in [(64, 64, 32), (100, 70, 32), (48, 96, 16)] {
            let (dequant, packed) = quantize(rows, cols, tile, 7);
            let rec = packed.dequantize();
            for (a, b) in rec.data.iter().zip(&dequant.data) {
                assert!((a - b).abs() < 1e-6, "{a} vs {b} ({rows}x{cols} t{tile})");
            }
        }
    }

    #[test]
    fn ragged_tiles_pack_their_true_extent() {
        let (_, packed) = quantize(100, 70, 32, 8);
        let last = packed.tiles.last().unwrap();
        assert_eq!((last.rows, last.cols), (4, 6));
        assert_eq!(last.codes.len(), 24);
        let total: usize = packed.tiles.iter().map(|t| t.codes.len()).sum();
        assert_eq!(total, 100 * 70);
    }

    #[test]
    fn packed_bytes_beat_dense_by_over_3x() {
        let (_, packed) = quantize(128, 128, 32, 9);
        let saving = packed.dense_bytes() as f64 / packed.packed_bytes() as f64;
        assert!(saving > 3.0, "saving {saving}");
    }

    #[test]
    fn tiles_tagged_fast_or_med_never_base() {
        let (_, packed) = quantize(128, 128, 32, 10);
        assert!(packed
            .tiles
            .iter()
            .all(|t| matches!(t.class, FreqClass::Fast | FreqClass::Med)));
        // Class agrees with the fast flag.
        for t in &packed.tiles {
            if t.fast {
                assert_eq!(t.class, FreqClass::Fast);
            }
        }
    }
}
