//! The packed execution format: HALO-quantized layers as contiguous
//! codebook-index tiles, ready for native execution.
//!
//! [`super::halo::HaloPayload`] is the *wire* format (whole-matrix index
//! plane + shared table — the operands of the lowered `fwd_halo` graph).
//! [`PackedLayer`] is the *execution* format the pure-Rust engine in
//! [`crate::runtime::qkernels`] consumes: per tile, a contiguous `u8`
//! code block **and** the same elements pre-expanded through an `i8`
//! integer codebook ([`PackedLayer::qtable`], `table[j] ≈ qtable[j] *
//! qstep`) into a contiguous `i8` panel ([`PackedTile::wq`], row-major
//! within the tile) — the operand the W4A8 integer kernel streams, one
//! byte per weight with no per-call LUT expansion. The shared 16-entry
//! f32 table, a per-tile scale, and the tile's DVFS
//! class/frequency/energy tags from the MAC circuit model ride along,
//! as does the hypersparse outlier/salient side matrix, untouched, so
//! the execution engine can fuse it as an SpMV epilogue instead of
//! scattering it into a dense copy.
//!
//! Nothing here ever materializes a dense f32 weight matrix;
//! [`PackedLayer::dequantize`] exists only as the test/bench oracle.

use crate::dvfs::{classify, FreqClass};
use crate::mac::MacProfile;

use super::halo::HaloPayload;
use super::sparse::SparseMatrix;
use super::tensor::{Matrix, TileGrid};
use super::QuantResult;

/// Number of entries in the shared codebook table (the medium book; the
/// fast book is a subset occupying 9 of the 16 slots).
pub const TABLE_LEN: usize = 16;

/// Hard upper bound on the tile edge length, enforced at pack time.
///
/// This is the integer kernel's overflow *and* exactness budget: one
/// tile contributes at most `MAX_TILE` products of an `i8` panel weight
/// (|w| ≤ 127) and an `i8` activation (|a| ≤ 128), so any per-tile
/// accumulator — and every partial sum on the way there — is bounded by
/// `MAX_TILE · 127 · 128 = 16 646 144 < 2^24`. That keeps the `i32`
/// accumulation far from overflow and, because every partial sum is an
/// integer below 2^24, makes the f32 LUT oracle kernel
/// ([`crate::runtime::qkernels::set_force_lut`]) *bit-identical* to the
/// integer path: f32 represents all such integers exactly.
pub const MAX_TILE: usize = 1024;

/// One quantized tile in execution form: contiguous codebook indices plus
/// the hardware tags the per-tile cycle-cost model reads.
#[derive(Debug, Clone)]
pub struct PackedTile {
    /// Codebook index per element, row-major within the tile, indices in
    /// shared-table space (`0..TABLE_LEN`). Edge tiles are smaller.
    pub codes: Vec<u8>,
    /// The same elements pre-expanded through the layer's integer
    /// codebook ([`PackedLayer::qtable`]) at pack time: one `i8` panel
    /// weight per code, row-major within the tile. This is what the
    /// integer kernel streams — 1 byte per weight, no per-call LUT
    /// expansion. `w ≈ wq * qstep * scale`.
    pub wq: Vec<i8>,
    /// Tile height (rows actually covered — edge tiles may be short).
    pub rows: usize,
    /// Tile width (columns actually covered).
    pub cols: usize,
    /// Dequantization scale: `w = table[code] * scale`.
    pub scale: f32,
    /// True ⇒ the tile is codebook-pure over the 9-value fast book.
    pub fast: bool,
    /// DVFS class of the tile (fast/med from the codebook; never base —
    /// HALO tiles are codebook-pure by construction).
    pub class: FreqClass,
    /// Achievable clock of the tile's codebook class (GHz, circuit model).
    pub freq_ghz: f64,
    /// Mean dynamic MAC energy per op over the tile's codebook (pJ, V_NOM).
    pub energy_pj: f64,
}

impl PackedTile {
    /// Multiply-accumulate operations this tile contributes per activation
    /// row.
    pub fn macs(&self) -> usize {
        self.rows * self.cols
    }
}

/// A whole linear layer in packed execution form.
#[derive(Debug, Clone)]
pub struct PackedLayer {
    /// Parameter name (e.g. `layer0.attn.wq`).
    pub name: String,
    /// Tile geometry over the layer's `(rows, cols)` — also the single
    /// source of the layer's dimensions ([`Self::rows`] / [`Self::cols`]).
    pub grid: TileGrid,
    /// The shared 16-entry codebook table (medium book; fast ⊆ med).
    pub table: [f32; TABLE_LEN],
    /// The codebook re-quantized to `i8` for the integer kernel:
    /// `qtable[j] = round_ties_even(table[j] / qstep)`, so
    /// `table[j] ≈ qtable[j] * qstep` within half a step
    /// (≤ 0.4 % of the book's absmax).
    pub qtable: [i8; TABLE_LEN],
    /// Step of the integer codebook: `absmax(table) / 127`
    /// (1.0 for an all-zero book, keeping `qtable` all zero).
    pub qstep: f32,
    /// One packed tile per grid cell, row-major tile order.
    pub tiles: Vec<PackedTile>,
    /// Full-precision outlier/salient side matrix (SpMV epilogue operand).
    pub sparse: SparseMatrix,
    /// Modeled stored bits per weight (Table II BW accounting).
    pub bits_eff: f64,
}

impl PackedLayer {
    /// Pack a quantization result + payload into execution form. The
    /// payload's whole-matrix index plane is re-tiled into contiguous
    /// per-tile code blocks; every tile is tagged with its DVFS class from
    /// `profile`.
    pub fn pack(
        name: &str,
        result: &QuantResult,
        payload: &HaloPayload,
        profile: &MacProfile,
    ) -> Self {
        let grid = result.grid;
        assert!(
            grid.tile <= MAX_TILE,
            "tile edge {} exceeds MAX_TILE {} (i32 accumulation / f32-exactness budget)",
            grid.tile,
            MAX_TILE
        );
        let (rows, cols) = (grid.rows, grid.cols);
        debug_assert_eq!(payload.idx.len(), rows * cols);
        let mut table = [0.0f32; TABLE_LEN];
        for (slot, &v) in table.iter_mut().zip(payload.codebook.iter()) {
            *slot = v;
        }
        // Integer codebook: symmetric absmax over the table, one i8 per
        // entry. An all-zero book keeps qstep = 1.0 so qtable stays zero.
        let tmax = table.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
        let qstep = if tmax == 0.0 { 1.0 } else { tmax / 127.0 };
        let mut qtable = [0i8; TABLE_LEN];
        for (q, &v) in qtable.iter_mut().zip(table.iter()) {
            *q = (v / qstep).round_ties_even().clamp(-127.0, 127.0) as i8;
        }
        let mut tiles = Vec::with_capacity(grid.n_tiles());
        for t in 0..grid.n_tiles() {
            let (rr, cc) = grid.bounds(t);
            let (th, tw) = (rr.len(), cc.len());
            let mut codes = Vec::with_capacity(th * tw);
            grid.for_each(t, |r, c| codes.push(payload.idx[r * cols + c]));
            let wq: Vec<i8> = codes.iter().map(|&c| qtable[c as usize]).collect();
            let freq_ghz = result.tile_freq_ghz[t];
            tiles.push(PackedTile {
                codes,
                wq,
                rows: th,
                cols: tw,
                scale: payload.scales[t],
                fast: payload.tile_fast[t],
                class: classify(freq_ghz, profile),
                freq_ghz,
                energy_pj: result.tile_energy_pj[t],
            });
        }
        Self {
            name: name.to_string(),
            grid,
            table,
            qtable,
            qstep,
            tiles,
            sparse: payload.sparse.clone(),
            bits_eff: result.bits_eff,
        }
    }

    /// Input features (K of `y = x @ W`).
    pub fn rows(&self) -> usize {
        self.grid.rows
    }

    /// Output features (N).
    pub fn cols(&self) -> usize {
        self.grid.cols
    }

    /// DVFS class per tile, row-major tile order (schedule input).
    pub fn classes(&self) -> Vec<FreqClass> {
        self.tiles.iter().map(|t| t.class).collect()
    }

    /// Bytes the packed representation actually touches per pass: one
    /// `i8` panel weight ([`PackedTile::wq`]) per dense weight, the
    /// shared table, a scale per tile, and `(f32 val, u32 pos)` per live
    /// sparse entry (padding excluded — it is an alignment artifact, not
    /// traffic). The `u8` code plane is resident but idle on the serving
    /// path (the dequantize oracle reads it), so it is not traffic.
    pub fn packed_bytes(&self) -> usize {
        let codes: usize = self.tiles.iter().map(|t| t.wq.len()).sum();
        codes
            + TABLE_LEN * std::mem::size_of::<f32>()
            + self.tiles.len() * std::mem::size_of::<f32>()
            + self.sparse.nnz * 8
    }

    /// Bytes a dense f32 copy of the layer would touch per pass.
    pub fn dense_bytes(&self) -> usize {
        self.rows() * self.cols() * std::mem::size_of::<f32>()
    }

    /// Dense reconstruction — the dequantize-then-dense **oracle** for the
    /// equivalence tests and benchmarks. The serving path never calls this.
    pub fn dequantize(&self) -> Matrix {
        let mut out = Matrix::zeros(self.rows(), self.cols());
        for (t, tile) in self.tiles.iter().enumerate() {
            let mut i = 0usize;
            self.grid.for_each(t, |r, c| {
                out.set(r, c, self.table[tile.codes[i] as usize] * tile.scale);
                i += 1;
            });
        }
        self.sparse.scatter_into(&mut out);
        out
    }

    /// Total multiply-accumulates per activation row (`rows * cols`).
    pub fn macs_per_row(&self) -> usize {
        self.rows() * self.cols()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mac::MacProfile;
    use crate::quant::{HaloConfig, HaloQuantizer, LayerCtx, Variant};
    use crate::util::Rng;

    fn quantize(rows: usize, cols: usize, tile: usize, seed: u64) -> (Matrix, PackedLayer) {
        let profile = MacProfile::cached();
        let mut rng = Rng::seed_from_u64(seed);
        let w = Matrix::random_normal(rows, cols, 0.02, &mut rng);
        let g = Matrix::random_normal(rows, cols, 1.0, &mut rng);
        let q = HaloQuantizer::new(HaloConfig::new(tile, Variant::Bal), profile);
        let (res, pay) = q.quantize_full(&w, &LayerCtx::with_grad("t", &g));
        let packed = PackedLayer::pack("t", &res, &pay, profile);
        (res.dequant, packed)
    }

    #[test]
    fn pack_dequantize_matches_quant_result() {
        for (rows, cols, tile) in [(64, 64, 32), (100, 70, 32), (48, 96, 16)] {
            let (dequant, packed) = quantize(rows, cols, tile, 7);
            let rec = packed.dequantize();
            for (a, b) in rec.data.iter().zip(&dequant.data) {
                assert!((a - b).abs() < 1e-6, "{a} vs {b} ({rows}x{cols} t{tile})");
            }
        }
    }

    #[test]
    fn ragged_tiles_pack_their_true_extent() {
        let (_, packed) = quantize(100, 70, 32, 8);
        let last = packed.tiles.last().unwrap();
        assert_eq!((last.rows, last.cols), (4, 6));
        assert_eq!(last.codes.len(), 24);
        let total: usize = packed.tiles.iter().map(|t| t.codes.len()).sum();
        assert_eq!(total, 100 * 70);
    }

    #[test]
    fn integer_codebook_tracks_f32_table_within_half_a_step() {
        let (_, packed) = quantize(64, 64, 32, 11);
        assert!(packed.qstep > 0.0);
        let tmax = packed.table.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
        assert!((packed.qstep - tmax / 127.0).abs() <= f32::EPSILON * tmax);
        for (j, (&q, &v)) in packed.qtable.iter().zip(packed.table.iter()).enumerate() {
            assert!(
                (q as f32 * packed.qstep - v).abs() <= 0.5 * packed.qstep + 1e-12,
                "qtable[{j}] = {q} off by more than qstep/2 from {v}"
            );
        }
        // The extreme entry hits ±127 exactly — full i8 range in use.
        assert!(packed.qtable.iter().any(|&q| q.unsigned_abs() == 127));
    }

    #[test]
    fn wq_panels_are_codes_expanded_through_qtable() {
        let (_, packed) = quantize(100, 70, 32, 12);
        for tile in &packed.tiles {
            assert_eq!(tile.wq.len(), tile.codes.len());
            for (&w, &c) in tile.wq.iter().zip(tile.codes.iter()) {
                assert_eq!(w, packed.qtable[c as usize]);
            }
        }
    }

    #[test]
    fn packed_bytes_beat_dense_by_over_3x() {
        let (_, packed) = quantize(128, 128, 32, 9);
        let saving = packed.dense_bytes() as f64 / packed.packed_bytes() as f64;
        assert!(saving > 3.0, "saving {saving}");
    }

    #[test]
    fn tiles_tagged_fast_or_med_never_base() {
        let (_, packed) = quantize(128, 128, 32, 10);
        assert!(packed
            .tiles
            .iter()
            .all(|t| matches!(t.class, FreqClass::Fast | FreqClass::Med)));
        // Class agrees with the fast flag.
        for t in &packed.tiles {
            if t.fast {
                assert_eq!(t.class, FreqClass::Fast);
            }
        }
    }
}
