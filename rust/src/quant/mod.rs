//! The HALO quantization framework (Algorithm 1) and baselines.
//!
//! Every quantizer consumes a dense f32 weight matrix (+ optional gradients
//! for sensitivity) and produces a [`QuantResult`]: the dequantized weights
//! (for accuracy evaluation through the PJRT graphs), the per-tile
//! achievable frequency and per-op MAC energy (for the simulators, computed
//! from the circuit-model [`crate::mac::MacProfile`]), and the effective
//! bit-width (Table II's `BW` column).
//!
//! The per-tile frequency is *honest*: whatever int8 values a quantizer
//! actually places in a tile determine the tile's achievable clock via the
//! MAC profile. Uniform baselines (RTN/SmoothQuant/GPTQ/ZeroQuant) span
//! delay-unfriendly values and land at the base class; HALO's tiles are
//! codebook-pure by construction and land at the fast/med classes.

pub mod baselines;
pub mod halo;
pub mod nonuniform;
pub mod outliers;
pub mod packed;
pub mod saliency;
pub mod sparse;
pub mod tensor;
pub mod tiles;
pub mod uniform;

pub use halo::{HaloConfig, HaloQuantizer, Variant};
pub use packed::{PackedLayer, PackedTile};
pub use tensor::{Matrix, TileGrid};

use crate::mac::MacProfile;

/// Per-layer context handed to quantizers.
pub struct LayerCtx<'a> {
    /// Layer/parameter name (reporting + per-layer calibration seeds).
    pub name: &'a str,
    /// Loss gradients w.r.t. this weight matrix (Fisher inputs, Eq. 1).
    pub grad: Option<&'a Matrix>,
    /// Seed for methods that need synthetic calibration data.
    pub seed: u64,
}

impl<'a> LayerCtx<'a> {
    /// Context without gradients (every tile is treated low-sensitivity).
    pub fn new(name: &'a str) -> Self {
        Self { name, grad: None, seed: 0 }
    }

    /// Context with Fisher gradients for saliency + tile sensitivity.
    pub fn with_grad(name: &'a str, grad: &'a Matrix) -> Self {
        Self { name, grad: Some(grad), seed: 0 }
    }
}

/// What every quantizer produces.
#[derive(Debug, Clone)]
pub struct QuantResult {
    /// Canonical method name (e.g. `halo-bal-t128`, `rtn-w8`).
    pub method: String,
    /// Reconstructed dense weights (substituted into the eval graphs).
    pub dequant: Matrix,
    /// Tile geometry the per-tile stats below are indexed by.
    pub grid: TileGrid,
    /// Achievable clock per tile (GHz) from the MAC profile — before
    /// snapping to a DVFS ladder.
    pub tile_freq_ghz: Vec<f64>,
    /// Mean dynamic MAC energy per op per tile (pJ at V_NOM).
    pub tile_energy_pj: Vec<f64>,
    /// Effective stored bits per weight (Table II BW column).
    pub bits_eff: f64,
    /// Non-zeros routed to the SpMV engine (outliers + salient).
    pub sparse_nnz: usize,
}

impl QuantResult {
    /// Weight-memory traffic in bytes for one pass over the layer
    /// (bits_eff per dense weight + 40 bits per sparse entry: f32 value +
    /// position). Drives the DRAM model and the §V DRAM-reduction ablation.
    pub fn weight_bytes(&self) -> f64 {
        let dense = self.dequant.numel() as f64 * self.bits_eff / 8.0;
        let sparse = self.sparse_nnz as f64 * 5.0;
        dense + sparse
    }

    /// Histogram of tiles per achievable-frequency bucket, using the
    /// derived codebook class frequencies as bucket edges.
    pub fn class_counts(&self, profile: &MacProfile) -> (usize, usize, usize) {
        let (mut fast, mut med, mut base) = (0, 0, 0);
        for &f in &self.tile_freq_ghz {
            if f >= profile.f_fast_ghz - 1e-9 {
                fast += 1;
            } else if f >= profile.f_med_ghz - 1e-9 {
                med += 1;
            } else {
                base += 1;
            }
        }
        (fast, med, base)
    }
}

/// Common interface over HALO and all baselines.
pub trait Quantizer {
    /// Canonical method name (Table II row label).
    fn name(&self) -> String;
    /// Quantize one weight matrix under the given layer context.
    fn quantize(&self, w: &Matrix, ctx: &LayerCtx) -> QuantResult;
}

/// Compute per-tile achievable frequency + mean energy from the int8 values
/// a quantizer actually stored (shared by all methods).
pub fn tile_hw_stats(
    q_i8: &[i8],
    grid: &TileGrid,
    profile: &MacProfile,
) -> (Vec<f64>, Vec<f64>) {
    let mut freqs = Vec::with_capacity(grid.n_tiles());
    let mut energies = Vec::with_capacity(grid.n_tiles());
    for t in 0..grid.n_tiles() {
        let mut worst = 0.0f64;
        let mut esum = 0.0f64;
        let mut n = 0usize;
        grid.for_each(t, |r, c| {
            let v = q_i8[r * grid.cols + c];
            worst = worst.max(profile.delay_of(v));
            esum += profile.energy_of(v);
            n += 1;
        });
        freqs.push(if worst > 0.0 { 1000.0 / worst } else { f64::INFINITY });
        energies.push(esum / n.max(1) as f64);
    }
    (freqs, energies)
}
