//! HALO quantization pipeline — the paper's Algorithm 1.
//!
//! 1. Extract salient weights (top 0.05 % by Fisher) and 3σ outliers →
//!    hypersparse full-precision side matrix (SpMV engine).
//! 2. Tile the remainder (default 128×128), compute per-tile sensitivity
//!    (Eq. 2), derive the adaptive threshold k from the cumulative
//!    sensitivity curve.
//! 3. Low-sensitivity tiles → 9-value fast codebook; high-sensitivity
//!    tiles → 16-value medium codebook (both derived from the MAC circuit
//!    model).
//! 4. The [`Variant`] (perf-opt / acc-opt / bal) sets the cumulative
//!    coverage target — the paper's "optimization feedback mechanism
//!    constraining the number of tiles allocated to each DVFS level".

use crate::mac::MacProfile;

use super::nonuniform::{dequantize_tile, quantize_tile, Codebook, TileQuant};
use super::outliers::extract_outliers;
use super::saliency::extract_salient;
use super::sparse::SparseMatrix;
use super::tensor::{Matrix, TileGrid};
use super::tiles::{adaptive_k, low_sensitivity_mask, tile_sensitivity};
use super::{LayerCtx, QuantResult, Quantizer};

/// User-facing design-goal presets (paper Table II rows).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Variant {
    /// Maximize tiles in the fast class (lowest BW, highest clock).
    PerfOpt,
    /// Protect accuracy: most sensitivity mass stays on the 16-value book.
    AccOpt,
    /// The knee-point configuration (paper's recommended default).
    Bal,
}

impl Variant {
    /// Cumulative sensitivity coverage the high-sensitivity class must
    /// retain (paper example: 95 %). Lower coverage → more fast tiles.
    pub fn keep_frac(self) -> f64 {
        match self {
            Variant::PerfOpt => 0.50,
            Variant::AccOpt => 0.98,
            Variant::Bal => 0.90,
        }
    }

    /// Fraction of weights preserved as salient (paper: 0.05 %, acc-opt
    /// doubles it; still ≪ the 0.5 % total sparse budget).
    pub fn salient_frac(self) -> f64 {
        match self {
            Variant::PerfOpt => 0.0003,
            Variant::AccOpt => 0.0010,
            Variant::Bal => 0.0005,
        }
    }

    /// Canonical short name (`perf-opt` / `acc-opt` / `bal`).
    pub fn name(self) -> &'static str {
        match self {
            Variant::PerfOpt => "perf-opt",
            Variant::AccOpt => "acc-opt",
            Variant::Bal => "bal",
        }
    }

    /// Parse a variant from its canonical or short CLI spelling.
    pub fn parse(s: &str) -> Option<Variant> {
        match s {
            "perf-opt" | "perf" => Some(Variant::PerfOpt),
            "acc-opt" | "acc" => Some(Variant::AccOpt),
            "bal" | "balanced" => Some(Variant::Bal),
            _ => None,
        }
    }
}

/// Knobs of one HALO quantization run.
#[derive(Debug, Clone)]
pub struct HaloConfig {
    /// Tile edge length (paper default: 128).
    pub tile: usize,
    /// Design-goal preset (coverage target + salient budget).
    pub variant: Variant,
    /// 3σ outlier cut (paper §III-A).
    pub sigma: f64,
}

impl HaloConfig {
    /// Config with the paper's 3σ outlier cut.
    pub fn new(tile: usize, variant: Variant) -> Self {
        Self { tile, variant, sigma: 3.0 }
    }
}

/// The serving-side payload: exactly the operands of the `fwd_halo` graph /
/// L1 Pallas kernel (idx + codebook + per-tile scales + sparse val/pos).
#[derive(Debug, Clone)]
pub struct HaloPayload {
    /// Codebook index per weight, row-major (K, N). Indices refer to the
    /// tile's class codebook padded into a single 16-entry table.
    pub idx: Vec<u8>,
    /// 16-entry f32 codebook table (fast book occupies the first 9 slots
    /// re-mapped; see `codebook_table`).
    pub codebook: Vec<f32>,
    /// Per-tile scale, row-major tile order.
    pub scales: Vec<f32>,
    /// `true` per tile ⇒ fast (9-value) class.
    pub tile_fast: Vec<bool>,
    /// Full-precision outlier/salient side matrix (SpMV operand).
    pub sparse: SparseMatrix,
}

/// The HALO quantizer (owns a reference profile + config).
pub struct HaloQuantizer<'p> {
    /// Tile size / variant / outlier-cut knobs.
    pub cfg: HaloConfig,
    /// The MAC circuit profile the codebooks derive from.
    pub profile: &'p MacProfile,
}

impl<'p> HaloQuantizer<'p> {
    /// Quantizer over a config + circuit profile.
    pub fn new(cfg: HaloConfig, profile: &'p MacProfile) -> Self {
        Self { cfg, profile }
    }

    /// Full Algorithm 1 on one weight matrix. `grad` drives saliency and
    /// tile sensitivity; without it every tile is low-sensitivity (k = 1).
    pub fn quantize_full(&self, w: &Matrix, ctx: &LayerCtx) -> (QuantResult, HaloPayload) {
        let prof = self.profile;
        let cb_fast = Codebook::new(prof.codebook_fast.clone());
        let cb_med = Codebook::new(prof.codebook_med.clone());
        // Payload indices live in the shared 16-entry table (= medium book);
        // fast-book index i maps to the medium-book position of the same
        // int8 value. The MacProfile construction guarantees fast ⊆ med.
        let fast_to_med: Vec<u8> = cb_fast
            .values
            .iter()
            .map(|v| {
                cb_med
                    .values
                    .iter()
                    .position(|m| m == v)
                    .expect("fast codebook must be a subset of the medium codebook")
                    as u8
            })
            .collect();

        // --- 1. salient + outlier extraction (Alg. 1 lines 1-3) ---
        let (after_salient, mut coords) = match ctx.grad {
            Some(g) => extract_salient(w, g, self.cfg.variant.salient_frac()),
            None => (w.clone(), Vec::new()),
        };
        let ex = extract_outliers(&after_salient, self.cfg.sigma);
        coords.extend(ex.coords.iter().copied());
        let cleaned = ex.cleaned;
        let sparse = SparseMatrix::from_coords(w.rows, w.cols, &coords);

        // --- 2. tile sensitivity + adaptive k (lines 4-6) ---
        let grid = TileGrid::new(w.rows, w.cols, self.cfg.tile);
        let (k, sens) = match ctx.grad {
            Some(g) => {
                let sens = tile_sensitivity(g, &grid);
                (adaptive_k(&sens, self.cfg.variant.keep_frac()), sens)
            }
            None => (1.0, vec![0.0; grid.n_tiles()]),
        };
        let low_mask = low_sensitivity_mask(&sens, k);

        // --- 3. per-tile codebook quantization (lines 7-9) ---
        let mut dequant = Matrix::zeros(w.rows, w.cols);
        let mut idx = vec![0u8; w.numel()];
        let mut scales = Vec::with_capacity(grid.n_tiles());
        let mut tile_freq = Vec::with_capacity(grid.n_tiles());
        let mut tile_energy = Vec::with_capacity(grid.n_tiles());
        for t in 0..grid.n_tiles() {
            let (cb, f_class) = if low_mask[t] {
                (&cb_fast, prof.f_fast_ghz)
            } else {
                (&cb_med, prof.f_med_ghz)
            };
            let tq: TileQuant = quantize_tile(&cleaned, &grid, t, cb);
            dequantize_tile(&mut dequant, &grid, t, cb, &tq);
            // Record flat indices in shared-table space.
            let mut i = 0usize;
            grid.for_each(t, |r, c| {
                idx[r * w.cols + c] = if low_mask[t] {
                    fast_to_med[tq.idx[i] as usize]
                } else {
                    tq.idx[i]
                };
                i += 1;
            });
            scales.push(tq.scale);
            tile_freq.push(f_class);
            tile_energy.push(prof.mean_energy_pj(&cb.values));
        }

        // --- sparse correction back into the dense reconstruction ---
        sparse.scatter_into(&mut dequant);

        // --- effective bit-width (Table II BW) ---
        let n = w.numel() as f64;
        let frac_sparse = sparse.nnz as f64 / n;
        let n_low: usize = (0..grid.n_tiles())
            .filter(|&t| low_mask[t])
            .map(|t| grid.tile_numel(t))
            .sum();
        let frac_low = n_low as f64 / n;
        let frac_high = 1.0 - frac_low - frac_sparse;
        let bits_eff = frac_low * cb_fast.bits()
            + frac_high.max(0.0) * cb_med.bits()
            + frac_sparse * 16.0;

        let result = QuantResult {
            method: format!(
                "halo-{}-t{}",
                self.cfg.variant.name(),
                self.cfg.tile
            ),
            dequant,
            grid,
            tile_freq_ghz: tile_freq,
            tile_energy_pj: tile_energy,
            bits_eff,
            sparse_nnz: sparse.nnz,
        };
        let payload = HaloPayload {
            idx,
            codebook: codebook_table(&cb_fast, &cb_med),
            scales,
            tile_fast: low_mask,
            sparse,
        };
        (result, payload)
    }
}

/// The 16-entry codebook table shipped to the `fwd_halo` graph. Fast tiles
/// index into the fast book's values; since both books share the table we
/// ship the *medium* book (16 entries) and re-map fast indices onto the
/// nearest medium entries at payload build time. To keep fast tiles
/// codebook-pure we instead require (and the MacProfile guarantees) the
/// fast book ⊆ medium book, so fast indices map exactly.
pub fn codebook_table(cb_fast: &Codebook, cb_med: &Codebook) -> Vec<f32> {
    debug_assert!(
        cb_fast.values.iter().all(|v| cb_med.values.contains(v)),
        "fast codebook must be a subset of the medium codebook"
    );
    let mut table: Vec<f32> = cb_med.values.iter().map(|&v| v as f32).collect();
    table.resize(16, 0.0);
    table
}

impl<'p> Quantizer for HaloQuantizer<'p> {
    fn name(&self) -> String {
        format!("halo-{}-t{}", self.cfg.variant.name(), self.cfg.tile)
    }

    fn quantize(&self, w: &Matrix, ctx: &LayerCtx) -> QuantResult {
        self.quantize_full(w, ctx).0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn prof() -> &'static MacProfile {
        MacProfile::cached()
    }

    fn wg(rows: usize, cols: usize, seed: u64) -> (Matrix, Matrix) {
        let mut rng = Rng::seed_from_u64(seed);
        let w = Matrix::random_normal(rows, cols, 0.02, &mut rng);
        // Gradients with structure: first tile row much more sensitive.
        let g = Matrix::from_fn(rows, cols, |r, _| {
            let base = rng.gen_normal() as f32;
            if r < rows / 4 {
                base * 10.0
            } else {
                base * 0.1
            }
        });
        (w, g)
    }

    #[test]
    fn variant_class_populations_ordered() {
        // perf-opt must put >= as many tiles in the fast class as bal,
        // which must put >= as many as acc-opt.
        let (w, g) = wg(128, 128, 40);
        let counts: Vec<usize> = [Variant::PerfOpt, Variant::Bal, Variant::AccOpt]
            .iter()
            .map(|&v| {
                let q = HaloQuantizer::new(HaloConfig::new(32, v), prof());
                let ctx = LayerCtx::with_grad("t", &g);
                let (res, pay) = q.quantize_full(&w, &ctx);
                assert_eq!(res.tile_freq_ghz.len(), 16);
                pay.tile_fast.iter().filter(|&&f| f).count()
            })
            .collect();
        assert!(counts[0] >= counts[1] && counts[1] >= counts[2], "{counts:?}");
        assert!(counts[0] > counts[2], "{counts:?}");
    }

    #[test]
    fn bits_eff_between_3_and_5() {
        let (w, g) = wg(128, 128, 41);
        for v in [Variant::PerfOpt, Variant::Bal, Variant::AccOpt] {
            let q = HaloQuantizer::new(HaloConfig::new(32, v), prof());
            let res = q.quantize(&w, &LayerCtx::with_grad("t", &g));
            assert!(
                res.bits_eff > 3.0 && res.bits_eff < 5.0,
                "{}: {}",
                v.name(),
                res.bits_eff
            );
        }
        // perf-opt uses fewer bits than acc-opt.
        let bits = |v| {
            HaloQuantizer::new(HaloConfig::new(32, v), prof())
                .quantize(&w, &LayerCtx::with_grad("t", &g))
                .bits_eff
        };
        assert!(bits(Variant::PerfOpt) < bits(Variant::AccOpt));
    }

    #[test]
    fn reconstruction_error_reasonable() {
        let (w, g) = wg(64, 64, 42);
        let q = HaloQuantizer::new(HaloConfig::new(32, Variant::Bal), prof());
        let res = q.quantize(&w, &LayerCtx::with_grad("t", &g));
        let rel = res.dequant.mse(&w).sqrt() / w.std();
        assert!(rel < 0.35, "relative RMSE {rel}");
        // acc-opt strictly better than perf-opt on average error.
        let e_acc = HaloQuantizer::new(HaloConfig::new(32, Variant::AccOpt), prof())
            .quantize(&w, &LayerCtx::with_grad("t", &g))
            .dequant
            .mse(&w);
        let e_perf = HaloQuantizer::new(HaloConfig::new(32, Variant::PerfOpt), prof())
            .quantize(&w, &LayerCtx::with_grad("t", &g))
            .dequant
            .mse(&w);
        assert!(e_acc <= e_perf, "{e_acc} vs {e_perf}");
    }

    #[test]
    fn sparse_fraction_under_budget() {
        let (w, g) = wg(128, 128, 43);
        let q = HaloQuantizer::new(HaloConfig::new(64, Variant::Bal), prof());
        let res = q.quantize(&w, &LayerCtx::with_grad("t", &g));
        let frac = res.sparse_nnz as f64 / w.numel() as f64;
        assert!(frac < 0.01, "sparse frac {frac}"); // paper: < 0.5% typical
        assert!(frac > 0.0);
    }

    #[test]
    fn no_grad_all_tiles_fast() {
        let (w, _) = wg(64, 64, 44);
        let q = HaloQuantizer::new(HaloConfig::new(32, Variant::Bal), prof());
        let (res, pay) = q.quantize_full(&w, &LayerCtx::new("t"));
        assert!(pay.tile_fast.iter().all(|&f| f));
        assert!(res
            .tile_freq_ghz
            .iter()
            .all(|&f| (f - prof().f_fast_ghz).abs() < 1e-9));
    }

    #[test]
    fn fast_tiles_run_faster_than_uniform() {
        let (w, g) = wg(64, 64, 45);
        let q = HaloQuantizer::new(HaloConfig::new(32, Variant::Bal), prof());
        let res = q.quantize(&w, &LayerCtx::with_grad("t", &g));
        for &f in &res.tile_freq_ghz {
            assert!(f >= prof().f_med_ghz - 1e-9);
            assert!(f > prof().f_base_ghz);
        }
    }

    #[test]
    fn payload_dequant_consistency() {
        // idx/codebook/scales + sparse must reconstruct exactly the dequant
        // matrix in the QuantResult — the contract with fwd_halo.
        let (w, g) = wg(64, 64, 46);
        let q = HaloQuantizer::new(HaloConfig::new(32, Variant::Bal), prof());
        let (res, pay) = q.quantize_full(&w, &LayerCtx::with_grad("t", &g));
        let grid = res.grid;
        // Decode strictly through the shared 16-entry table, exactly as the
        // fwd_halo graph does.
        let mut rec = Matrix::zeros(64, 64);
        for t in 0..grid.n_tiles() {
            grid.for_each(t, |r, c| {
                let v = pay.codebook[pay.idx[r * 64 + c] as usize] * pay.scales[t];
                rec.set(r, c, v);
            });
        }
        pay.sparse.scatter_into(&mut rec);
        for (a, b) in rec.data.iter().zip(&res.dequant.data) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
    }
}
