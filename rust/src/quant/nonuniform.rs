//! Critical-path-delay-aware non-uniform quantization (paper §III-B).
//!
//! Weights are mapped onto a small codebook of int8 values chosen for their
//! short MAC critical paths (from [`crate::mac::MacProfile`]), with one
//! dequant scale per tile: `deq(w) = codebook[i] · s_tile`. Because every
//! stored value is a codebook member, the tile's achievable clock is the
//! codebook class frequency by construction.

use super::tensor::{Matrix, TileGrid};

/// A codebook = sorted int8 values + their f32 images.
#[derive(Debug, Clone)]
pub struct Codebook {
    /// The member int8 values, sorted ascending, deduplicated.
    pub values: Vec<i8>,
    f: Vec<f32>,
}

impl Codebook {
    /// Build from member values (sorted + deduplicated internally).
    pub fn new(mut values: Vec<i8>) -> Self {
        values.sort_unstable();
        values.dedup();
        let f = values.iter().map(|&v| v as f32).collect();
        Self { values, f }
    }

    /// Number of codebook entries.
    pub fn len(&self) -> usize {
        self.values.len()
    }

    /// True when the codebook has no entries.
    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }

    /// Largest absolute member value (scale-mapping anchor).
    pub fn max_abs(&self) -> f32 {
        self.f.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
    }

    /// Index of the nearest codebook entry to `x` (f32 domain).
    pub fn nearest(&self, x: f32) -> usize {
        // Binary search on the sorted values, then compare neighbours.
        let mut lo = 0usize;
        let mut hi = self.f.len() - 1;
        while hi - lo > 1 {
            let mid = (lo + hi) / 2;
            if self.f[mid] <= x {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        if (x - self.f[lo]).abs() <= (x - self.f[hi]).abs() {
            lo
        } else {
            hi
        }
    }

    /// Storage bits per weight for this codebook (Table II BW accounting).
    pub fn bits(&self) -> f64 {
        (self.len() as f64).log2()
    }
}

/// Result of quantizing one tile set onto a codebook.
#[derive(Debug, Clone)]
pub struct TileQuant {
    /// Codebook index per element of the tile (row-major within tile).
    pub idx: Vec<u8>,
    /// Dequantization scale: `w = codebook[idx] * scale`.
    pub scale: f32,
}

/// Quantize the elements of tile `t` of `w` onto `cb`.
/// Scale maps the tile's absmax onto the codebook's absmax.
pub fn quantize_tile(w: &Matrix, grid: &TileGrid, t: usize, cb: &Codebook) -> TileQuant {
    let mut amax = 0.0f32;
    grid.for_each(t, |r, c| amax = amax.max(w.get(r, c).abs()));
    let scale = if amax > 0.0 { amax / cb.max_abs() } else { 1.0 };
    let mut idx = Vec::with_capacity(grid.tile_numel(t));
    grid.for_each(t, |r, c| {
        idx.push(cb.nearest(w.get(r, c) / scale) as u8);
    });
    TileQuant { idx, scale }
}

/// Write the dequantized values of a quantized tile back into `out`.
pub fn dequantize_tile(
    out: &mut Matrix,
    grid: &TileGrid,
    t: usize,
    cb: &Codebook,
    tq: &TileQuant,
) {
    let mut i = 0usize;
    grid.for_each(t, |r, c| {
        out.set(r, c, cb.values[tq.idx[i] as usize] as f32 * tq.scale);
        i += 1;
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn cb9() -> Codebook {
        Codebook::new(vec![-128, -112, -32, -16, 0, 2, 3, 16, 112])
    }

    #[test]
    fn nearest_exhaustive_against_linear_scan() {
        let cb = cb9();
        let mut rng = Rng::seed_from_u64(20);
        for _ in 0..2000 {
            let x = (rng.gen_f64() * 300.0 - 150.0) as f32;
            let got = cb.nearest(x);
            let want = (0..cb.len())
                .min_by(|&a, &b| {
                    (x - cb.values[a] as f32)
                        .abs()
                        .partial_cmp(&(x - cb.values[b] as f32).abs())
                        .unwrap()
                })
                .unwrap();
            let d_got = (x - cb.values[got] as f32).abs();
            let d_want = (x - cb.values[want] as f32).abs();
            assert!((d_got - d_want).abs() < 1e-6, "x={x}");
        }
    }

    #[test]
    fn codebook_members_quantize_exactly() {
        let cb = cb9();
        let grid = TileGrid::new(3, 3, 3);
        // Tile values are exactly scale * codebook entries.
        let scale = 0.01f32;
        let vals: Vec<f32> = cb.values.iter().map(|&v| v as f32 * scale).collect();
        let w = Matrix::from_vec(3, 3, vals.clone());
        let tq = quantize_tile(&w, &grid, 0, &cb);
        let mut out = Matrix::zeros(3, 3);
        dequantize_tile(&mut out, &grid, 0, &cb, &tq);
        for (a, b) in out.data.iter().zip(&vals) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn error_bounded_by_half_max_gap() {
        let cb = cb9();
        let mut rng = Rng::seed_from_u64(21);
        let w = Matrix::random_normal(16, 16, 0.05, &mut rng);
        let grid = TileGrid::new(16, 16, 16);
        let tq = quantize_tile(&w, &grid, 0, &cb);
        let mut out = Matrix::zeros(16, 16);
        dequantize_tile(&mut out, &grid, 0, &cb, &tq);
        // Max gap between adjacent codebook values (int8 domain) = 80.
        let max_gap = cb
            .values
            .windows(2)
            .map(|p| p[1] as i32 - p[0] as i32)
            .max()
            .unwrap() as f32;
        let bound = tq.scale * max_gap / 2.0 + 1e-6;
        for (a, b) in out.data.iter().zip(&w.data) {
            assert!((a - b).abs() <= bound, "{a} vs {b} bound={bound}");
        }
    }

    #[test]
    fn zero_tile_is_stable() {
        let cb = cb9();
        let w = Matrix::zeros(4, 4);
        let grid = TileGrid::new(4, 4, 4);
        let tq = quantize_tile(&w, &grid, 0, &cb);
        let mut out = Matrix::from_fn(4, 4, |_, _| 9.0);
        dequantize_tile(&mut out, &grid, 0, &cb, &tq);
        assert!(out.data.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn bits_accounting() {
        assert!((cb9().bits() - 9f64.log2()).abs() < 1e-12);
        let cb16 = Codebook::new((0..16).map(|i| (i * 8 - 64) as i8).collect());
        assert!((cb16.bits() - 4.0).abs() < 1e-12);
    }
}
