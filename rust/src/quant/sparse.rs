//! Hypersparse packaging + SpMV for outlier/salient weights (§III-C1).
//!
//! The < 0.5 % extracted weights are stored as `(val, pos)` vectors —
//! value + flattened row-major position — exactly the layout the L1 Pallas
//! SpMV kernel and the `fwd_halo` graph consume (zero-padded to a block
//! multiple). `res[i] = val[i] * b[idx[i]]` per the paper.

use super::outliers::Coord;
use super::tensor::Matrix;

/// Padding granularity — matches `SPARSE_PAD` in python/compile/aot.py.
pub const PAD: usize = 256;

/// The hypersparse outlier/salient side matrix in `(val, pos)` form.
#[derive(Debug, Clone, Default)]
pub struct SparseMatrix {
    /// Logical row count of the dense matrix the entries were lifted from.
    pub rows: usize,
    /// Logical column count.
    pub cols: usize,
    /// Non-zero values, zero-padded to a multiple of [`PAD`].
    pub val: Vec<f32>,
    /// Flattened positions (row * cols + col), one per `val` entry.
    pub pos: Vec<u32>,
    /// Live entries (before padding).
    pub nnz: usize,
}

impl SparseMatrix {
    /// Package extracted `(row, col, value)` coordinates, zero-padding the
    /// `(val, pos)` vectors to a [`PAD`] multiple.
    pub fn from_coords(rows: usize, cols: usize, coords: &[Coord]) -> Self {
        let nnz = coords.len();
        let padded = nnz.div_ceil(PAD).max(1) * PAD;
        let mut val = Vec::with_capacity(padded);
        let mut pos = Vec::with_capacity(padded);
        for &(r, c, v) in coords {
            debug_assert!(r < rows && c < cols);
            val.push(v);
            pos.push((r * cols + c) as u32);
        }
        val.resize(padded, 0.0);
        pos.resize(padded, 0);
        Self { rows, cols, val, pos, nnz }
    }

    /// Pad/trim to exactly `len` entries (to match a lowered graph's shape).
    pub fn with_len(mut self, len: usize) -> Self {
        assert!(self.nnz <= len, "sparse overflow: {} > {len}", self.nnz);
        self.val.resize(len, 0.0);
        self.pos.resize(len, 0);
        self
    }

    /// y = x @ W_sparse for a dense row-major x (m, rows) -> (m, cols).
    /// This is the Rust mirror of the L1 SpMV kernel / ref.py oracle.
    pub fn spmv(&self, x: &Matrix) -> Matrix {
        let mut y = Matrix::zeros(x.rows, self.cols);
        self.spmv_into(x, &mut y);
        y
    }

    /// `y += x @ W_sparse` — the fused-epilogue form the packed execution
    /// engine ([`crate::runtime::qkernels`]) uses: the outlier/salient
    /// contribution lands directly in the matmul output without ever
    /// scattering the sparse weights into a dense copy.
    pub fn spmv_into(&self, x: &Matrix, y: &mut Matrix) {
        assert_eq!(x.cols, self.rows);
        assert_eq!((y.rows, y.cols), (x.rows, self.cols));
        for (i, &v) in self.val.iter().enumerate() {
            if v == 0.0 {
                continue;
            }
            let p = self.pos[i] as usize;
            let (r, c) = (p / self.cols, p % self.cols);
            for m in 0..x.rows {
                let add = x.get(m, r) * v;
                y.set(m, c, y.get(m, c) + add);
            }
        }
    }

    /// Scatter back into a dense matrix (adds to existing values).
    pub fn scatter_into(&self, out: &mut Matrix) {
        assert_eq!((out.rows, out.cols), (self.rows, self.cols));
        for (i, &v) in self.val.iter().enumerate() {
            if v == 0.0 {
                continue;
            }
            let p = self.pos[i] as usize;
            out.data[p] += v;
        }
    }

    /// Dense reconstruction (tests / eval).
    pub fn to_dense(&self) -> Matrix {
        let mut m = Matrix::zeros(self.rows, self.cols);
        self.scatter_into(&mut m);
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn random_coords(rng: &mut Rng, rows: usize, cols: usize, n: usize) -> Vec<Coord> {
        let mut used = std::collections::HashSet::new();
        let mut out = Vec::new();
        while out.len() < n {
            let r = rng.gen_usize(rows);
            let c = rng.gen_usize(cols);
            if used.insert((r, c)) {
                out.push((r, c, rng.gen_normal() as f32));
            }
        }
        out
    }

    #[test]
    fn padding_is_block_multiple() {
        let s = SparseMatrix::from_coords(10, 10, &[(1, 2, 3.0)]);
        assert_eq!(s.val.len(), PAD);
        assert_eq!(s.nnz, 1);
        let s2 = s.with_len(2 * PAD);
        assert_eq!(s2.val.len(), 2 * PAD);
    }

    #[test]
    fn spmv_matches_dense_matmul() {
        let mut rng = Rng::seed_from_u64(30);
        let coords = random_coords(&mut rng, 24, 16, 40);
        let s = SparseMatrix::from_coords(24, 16, &coords);
        let x = Matrix::random_normal(4, 24, 1.0, &mut rng);
        let got = s.spmv(&x);
        let want = x.matmul(&s.to_dense());
        for (a, b) in got.data.iter().zip(&want.data) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn spmv_into_accumulates() {
        let mut rng = Rng::seed_from_u64(31);
        let coords = random_coords(&mut rng, 8, 6, 10);
        let s = SparseMatrix::from_coords(8, 6, &coords);
        let x = Matrix::random_normal(3, 8, 1.0, &mut rng);
        let mut y = Matrix::from_fn(3, 6, |r, c| (r * 6 + c) as f32);
        let base = y.clone();
        s.spmv_into(&x, &mut y);
        let delta = s.spmv(&x);
        for i in 0..y.data.len() {
            assert!((y.data[i] - (base.data[i] + delta.data[i])).abs() < 1e-5);
        }
        // Empty sparse set: epilogue is a no-op.
        let empty = SparseMatrix::from_coords(8, 6, &[]);
        let mut z = base.clone();
        empty.spmv_into(&x, &mut z);
        assert_eq!(z, base);
    }

    #[test]
    fn scatter_roundtrip() {
        let coords = vec![(0usize, 0usize, 1.5f32), (2, 3, -2.5)];
        let s = SparseMatrix::from_coords(4, 4, &coords);
        let d = s.to_dense();
        assert_eq!(d.get(0, 0), 1.5);
        assert_eq!(d.get(2, 3), -2.5);
        assert_eq!(d.data.iter().filter(|&&x| x != 0.0).count(), 2);
    }

    #[test]
    #[should_panic(expected = "sparse overflow")]
    fn with_len_rejects_truncation() {
        let coords: Vec<Coord> = (0..300).map(|i| (i / 20, i % 20, 1.0)).collect();
        SparseMatrix::from_coords(20, 20, &coords).with_len(256);
    }
}
