//! Analytic GPU simulator (Figs 12–13): an RTX-2080-Ti-class device with
//! the Table I GPU DVFS ladder and an AccelWattch-style energy split
//! (constant / static / dynamic).
//!
//! Substitution for AccelSim+AccelWattch (DESIGN.md): a roofline model —
//! per-kernel latency = max(compute at the selected DVFS level, memory) —
//! plus a power-budget-driven DVFS selector. Quantization enters exactly
//! where it does on real GPUs: weight bytes (memory-bound decode) and
//! per-op switching energy (which determines how much frequency headroom
//! the power budget allows — the paper's "concentrating high frequency
//! execution only where necessary").

use crate::dvfs::{FreqClass, Ladder, TRANSITION_S};
use crate::mac::MacProfile;
use crate::workload::{LayerQuant, ModelShapes, Phase};

/// GPU hardware description (RTX 2080 Ti-like).
#[derive(Debug, Clone)]
pub struct GpuConfig {
    pub sms: usize,
    /// int8 MACs per SM per cycle (dp4a lanes).
    pub int8_macs_per_sm: usize,
    /// fp16 MACs per SM per cycle.
    pub fp16_macs_per_sm: usize,
    /// DRAM bandwidth (bytes/s).
    pub dram_bw: f64,
    /// Board power budget (W) — what the DVFS governor enforces.
    pub power_budget_w: f64,
    /// Constant (peripheral) power: fans, VRM, display (W).
    pub constant_w: f64,
    /// Leakage at nominal voltage (W).
    pub static_w: f64,
    /// DRAM access energy (pJ/byte).
    pub dram_pj_per_byte: f64,
    /// Core switching energy per int8 MAC (pJ) for full-range weights at
    /// the nominal voltage — scaled per method by the MAC profile ratio.
    pub mac_pj: f64,
    pub ladder: Ladder,
}

impl Default for GpuConfig {
    fn default() -> Self {
        Self {
            sms: 68,
            int8_macs_per_sm: 256,
            fp16_macs_per_sm: 128,
            dram_bw: 616e9,
            power_budget_w: 250.0,
            constant_w: 55.0,
            static_w: 40.0,
            dram_pj_per_byte: 20.0,
            mac_pj: 0.45,
            ladder: Ladder::paper_gpu(),
        }
    }
}

/// Simulation output for one inference pass on the GPU.
#[derive(Debug, Clone)]
pub struct GpuReport {
    pub method: String,
    pub model: String,
    pub time_s: f64,
    pub compute_s: f64,
    pub mem_s: f64,
    /// DVFS level chosen per class (GHz) — the governor's decision.
    pub class_ghz: [f64; 3],
    pub transitions: usize,
    pub energy_constant: f64,
    pub energy_static: f64,
    pub energy_dynamic: f64,
}

impl GpuReport {
    pub fn energy_total(&self) -> f64 {
        self.energy_constant + self.energy_static + self.energy_dynamic
    }
}

pub struct GpuSim {
    pub cfg: GpuConfig,
}

impl GpuSim {
    pub fn new(cfg: GpuConfig) -> Self {
        Self { cfg }
    }

    /// Pick the highest ladder level whose predicted board power stays
    /// within budget for kernels with the given per-MAC energy (pJ).
    fn select_level(&self, mac_pj: f64, macs_per_cycle: f64) -> (FreqClass, f64, f64) {
        let cfg = &self.cfg;
        let mut chosen = (FreqClass::Base, cfg.ladder.levels[0].ghz, cfg.ladder.levels[0].volts);
        for class in FreqClass::ALL {
            let lvl = cfg.ladder.level(class);
            let v2 = (lvl.volts / 1.0).powi(2);
            let dyn_w = mac_pj * v2 * macs_per_cycle * lvl.ghz * 1e9 * 1e-12;
            let static_w = cfg.static_w * lvl.volts;
            if cfg.constant_w + static_w + dyn_w <= cfg.power_budget_w {
                chosen = (class, lvl.ghz, lvl.volts);
            }
        }
        (chosen.0, chosen.1, chosen.2)
    }

    /// Simulate one inference pass.
    pub fn run(
        &self,
        model: &ModelShapes,
        phase: Phase,
        quants: &[LayerQuant],
        method: &str,
    ) -> GpuReport {
        assert_eq!(quants.len(), model.gemms.len());
        let cfg = &self.cfg;
        let profile = MacProfile::cached();
        let e_full = profile.full_range_energy_pj();

        let mut compute_s = 0.0f64;
        let mut bytes = 0.0f64;
        let mut dyn_j = 0.0f64;
        let mut class_ghz = [0.0f64; 3];
        let mut classes_used = [false; 3];

        for (g, lq) in model.gemms.iter().zip(quants) {
            let layer_macs = (phase.m * g.k * g.n * g.count) as f64;
            let macs_per_cycle = (cfg.sms
                * if lq.is_fp16 { cfg.fp16_macs_per_sm } else { cfg.int8_macs_per_sm })
                as f64;

            for class in FreqClass::ALL {
                let frac = lq.class_frac(class) + if class == FreqClass::Base {
                    lq.sparse_frac
                } else {
                    0.0
                };
                if frac <= 0.0 {
                    continue;
                }
                // Per-op energy of this class's weight values, relative to
                // the full int8 range, scales the GPU's MAC energy.
                let mac_pj = cfg.mac_pj * lq.energy_pj[class as usize] / e_full
                    * if lq.is_fp16 { 2.0 } else { 1.0 };
                let (sel, ghz, volts) = self.select_level(mac_pj, macs_per_cycle);
                classes_used[sel as usize] = true;
                class_ghz[class as usize] = ghz;
                let t = layer_macs * frac / (macs_per_cycle * ghz * 1e9);
                compute_s += t;
                dyn_j += layer_macs * frac * mac_pj * (volts / 1.0).powi(2) * 1e-12;
            }

            bytes += (g.k * g.n * g.count) as f64 * lq.bits_eff / 8.0
                + lq.sparse_frac * (g.k * g.n * g.count) as f64 * 5.0
                + (phase.m * (g.k + g.n) * g.count) as f64
                    * if lq.is_fp16 { 2.0 } else { 1.0 };
        }

        let mem_s = bytes / cfg.dram_bw;
        let transitions = classes_used.iter().filter(|&&u| u).count();
        let time_s = compute_s.max(mem_s) + transitions as f64 * TRANSITION_S;
        dyn_j += bytes * cfg.dram_pj_per_byte * 1e-12;

        GpuReport {
            method: method.to_string(),
            model: model.name.to_string(),
            time_s,
            compute_s,
            mem_s,
            class_ghz,
            transitions,
            energy_constant: cfg.constant_w * time_s,
            energy_static: cfg.static_w * time_s,
            energy_dynamic: dyn_j,
        }
    }

    /// Canonical-method convenience mirror of `Simulator::run_method`.
    pub fn run_method(
        &self,
        model: &ModelShapes,
        phase: Phase,
        method: &str,
        tile: usize,
        seed: u64,
    ) -> GpuReport {
        let quants: Vec<LayerQuant> = model
            .gemms
            .iter()
            .enumerate()
            .map(|(i, g)| {
                let n_tiles = g.k.div_ceil(tile) * g.n.div_ceil(tile);
                LayerQuant::for_method(method, n_tiles, tile, MacProfile::cached(),
                                       seed ^ (i as u64) << 8)
            })
            .collect();
        self.run(model, phase, &quants, method)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run(method: &str) -> GpuReport {
        GpuSim::new(GpuConfig::default()).run_method(
            &ModelShapes::opt_1p3b(),
            Phase::decode(8),
            method,
            128,
            42,
        )
    }

    #[test]
    fn fig12_halo_beats_w8a8() {
        let w8 = run("w8a8").time_s;
        let halo = run("halo-bal").time_s;
        assert!(halo < w8, "halo {halo} vs w8 {w8}");
        // Decode is memory-bound: speedup roughly tracks bits (8 / ~3.6).
        let ratio = w8 / halo;
        assert!((1.3..3.0).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn fig13_energy_shape() {
        // W8A8 lowest energy (paper: "lowest overall energy due to
        // uniformly low precision... but performance stagnation");
        // HALO variants trade a marginal increase for speed; FP16 worst.
        let w8 = run("w8a8");
        let halo = run("halo-bal");
        let fp16 = run("fp16");
        assert!(fp16.energy_total() > halo.energy_total());
        assert!(halo.energy_total() < 1.35 * w8.energy_total());
    }

    #[test]
    fn dvfs_governor_gives_halo_higher_clock() {
        let w8 = run("w8a8");
        let halo = run("halo-perf");
        let max_ghz = |r: &GpuReport| r.class_ghz.iter().cloned().fold(0.0, f64::max);
        assert!(
            max_ghz(&halo) >= max_ghz(&w8),
            "halo {:?} w8 {:?}",
            halo.class_ghz,
            w8.class_ghz
        );
    }

    #[test]
    fn bigger_model_slower() {
        let s = GpuSim::new(GpuConfig::default());
        let small = s
            .run_method(&ModelShapes::opt_1p3b(), Phase::decode(8), "w8a8", 128, 1)
            .time_s;
        let big = s
            .run_method(&ModelShapes::opt_30b(), Phase::decode(8), "w8a8", 128, 1)
            .time_s;
        assert!(big > 10.0 * small);
    }

    #[test]
    fn constant_energy_tracks_time() {
        let r = run("w8a8");
        assert!((r.energy_constant / r.time_s - 55.0).abs() < 1e-9);
    }
}
