//! Fisher calibration (paper Eq. 1): run the `grad` graph over calibration
//! batches and accumulate mean-squared gradients per linear weight — the
//! saliency and tile-sensitivity inputs of Algorithm 1.
//!
//! The grad graph returns `(loss, dW for each linear weight in canonical
//! order)`; averaging g over batches then squaring elementwise downstream
//! (saliency uses g², so we return the RMS gradient matrix).

use std::collections::BTreeMap;

use anyhow::Result;

use crate::quant::Matrix;
use crate::runtime::{artifacts::nll_batches, literal_i32, Buffer, ModelArtifacts, Runtime};

/// Accumulated calibration gradients: name → RMS-gradient matrix.
pub fn calibrate_fisher(
    rt: &Runtime,
    model: &ModelArtifacts,
    calib: &[u16],
    max_batches: usize,
) -> Result<BTreeMap<String, Matrix>> {
    let exe = rt.load(&model.graph_path("grad"))?;
    let (b, s) = (model.eval_batch, model.seq_len);
    // Parameters resident on device across calibration batches (§Perf L3).
    let param_bufs = rt.upload_all(&model.param_literals(&BTreeMap::new())?)?;

    let lin: Vec<_> = model.linear_params().collect();
    let mut acc: Vec<Vec<f64>> = lin.iter().map(|p| vec![0.0; p.data.len()]).collect();

    let batches = nll_batches(calib, b, s);
    let n = batches.len().min(max_batches).max(1);
    for tokens in batches.iter().take(n) {
        let tok_buf = rt.upload(&literal_i32(tokens, &[b, s + 1])?)?;
        let mut inputs: Vec<&Buffer> = param_bufs.iter().collect();
        inputs.push(&tok_buf);
        let outputs = exe.run_b(&inputs)?;
        anyhow::ensure!(
            outputs.len() == lin.len() + 1,
            "grad graph returned {} outputs, expected {}",
            outputs.len(),
            lin.len() + 1
        );
        for (i, out) in outputs.iter().skip(1).enumerate() {
            let g: Vec<f32> = out.to_vec()?;
            anyhow::ensure!(g.len() == acc[i].len(), "grad shape mismatch");
            for (a, &x) in acc[i].iter_mut().zip(&g) {
                *a += (x as f64) * (x as f64);
            }
        }
    }

    let mut out = BTreeMap::new();
    for (p, a) in lin.iter().zip(acc) {
        let rms: Vec<f32> = a.iter().map(|&x| ((x / n as f64).sqrt()) as f32).collect();
        out.insert(
            p.name.clone(),
            Matrix::from_vec(p.shape[0], p.shape[1], rms),
        );
    }
    Ok(out)
}
