//! Perplexity evaluation (Table II): run the `nll_fp` / `nll_a8` graphs
//! with (quantized) parameter literals over a corpus stream, on whichever
//! runtime backend is active (sim or PJRT).

use std::collections::BTreeMap;

use anyhow::Result;

use crate::quant::{LayerCtx, Matrix, Quantizer};
use crate::runtime::{
    artifacts::nll_batches, literal_i32, Buffer, Executable, ModelArtifacts, Runtime,
};

/// Evaluator bound to one model's artifacts.
pub struct Evaluator<'r> {
    pub model: &'r ModelArtifacts,
    rt: &'r Runtime,
    nll_fp: Executable,
    nll_a8: Executable,
}

/// One Table II cell.
#[derive(Debug, Clone)]
pub struct PplResult {
    pub method: String,
    pub corpus: String,
    pub ppl: f64,
    pub nll: f64,
    pub bits_eff: f64,
    pub batches: usize,
}

impl<'r> Evaluator<'r> {
    pub fn new(rt: &'r Runtime, model: &'r ModelArtifacts) -> Result<Self> {
        Ok(Self {
            model,
            rt,
            nll_fp: rt.load(&model.graph_path("nll_fp"))?,
            nll_a8: rt.load(&model.graph_path("nll_a8"))?,
        })
    }

    /// Mean NLL over up to `max_batches` of the stream, with weights
    /// optionally replaced and A8 activation quantization toggled.
    ///
    /// Parameters are uploaded to device buffers once and stay resident
    /// across batches (§Perf L3); only the token batch is re-uploaded.
    pub fn mean_nll(
        &self,
        replace: &BTreeMap<String, Matrix>,
        stream: &[u16],
        a8: bool,
        max_batches: usize,
    ) -> Result<(f64, usize)> {
        let (b, s) = (self.model.eval_batch, self.model.seq_len);
        let param_bufs = self.rt.upload_all(&self.model.param_literals(replace)?)?;

        let exe = if a8 { &self.nll_a8 } else { &self.nll_fp };
        let batches = nll_batches(stream, b, s);
        let n = batches.len().min(max_batches).max(1);
        let mut total = 0.0f64;
        for tokens in batches.iter().take(n) {
            let tok_buf = self.rt.upload(&literal_i32(tokens, &[b, s + 1])?)?;
            let mut inputs: Vec<&Buffer> = param_bufs.iter().collect();
            inputs.push(&tok_buf);
            total += exe.run_scalar_b(&inputs)? as f64;
        }
        Ok((total / n as f64, n))
    }

    /// Evaluate a quantizer end-to-end: quantize every linear weight (with
    /// Fisher gradients when provided), substitute, measure perplexity.
    pub fn eval_quantizer(
        &self,
        q: &dyn Quantizer,
        grads: &BTreeMap<String, Matrix>,
        stream: &[u16],
        corpus: &str,
        max_batches: usize,
        a8: bool,
    ) -> Result<PplResult> {
        let mut replace = BTreeMap::new();
        let mut bits_weighted = 0.0f64;
        let mut total_w = 0.0f64;
        for p in self.model.linear_params() {
            let w = p.as_matrix()?;
            let g = grads.get(&p.name);
            let ctx = match g {
                Some(g) => LayerCtx::with_grad(&p.name, g),
                None => LayerCtx::new(&p.name),
            };
            let res = q.quantize(&w, &ctx);
            bits_weighted += res.bits_eff * w.numel() as f64;
            total_w += w.numel() as f64;
            replace.insert(p.name.clone(), res.dequant);
        }
        let (nll, batches) = self.mean_nll(&replace, stream, a8, max_batches)?;
        Ok(PplResult {
            method: q.name(),
            corpus: corpus.to_string(),
            ppl: nll.exp(),
            nll,
            bits_eff: bits_weighted / total_w.max(1.0),
            batches,
        })
    }

    /// FP16 reference row (no substitution, no activation quantization).
    pub fn eval_fp16(
        &self,
        stream: &[u16],
        corpus: &str,
        max_batches: usize,
    ) -> Result<PplResult> {
        let (nll, batches) = self.mean_nll(&BTreeMap::new(), stream, false, max_batches)?;
        Ok(PplResult {
            method: "fp16".into(),
            corpus: corpus.into(),
            ppl: nll.exp(),
            nll,
            bits_eff: 16.0,
            batches,
        })
    }
}
