//! Model evaluation over the AOT artifacts: perplexity (Table II) and
//! Fisher gradient calibration (Algorithm 1's inputs), all through the
//! pluggable runtime backend (sim by default, PJRT with `--features xla`).

pub mod eval;
pub mod fisher;

pub use eval::Evaluator;
pub use fisher::calibrate_fisher;
