//! Model evaluation over the AOT artifacts: perplexity (Table II) and
//! Fisher gradient calibration (Algorithm 1's inputs), all through PJRT.

pub mod eval;
pub mod fisher;

pub use eval::Evaluator;
pub use fisher::calibrate_fisher;
