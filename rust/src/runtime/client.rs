//! PJRT runtime: load AOT HLO-text artifacts, compile once, execute many.
//!
//! Mirrors /opt/xla-example/load_hlo: HLO *text* is the interchange format
//! (jax ≥ 0.5 serialized protos are rejected by xla_extension 0.5.1; the
//! text parser reassigns instruction ids). Every lowered graph returns a
//! tuple (`return_tuple=True`), so outputs decompose with `to_tuple()`.

use std::path::Path;

use anyhow::{Context, Result};

/// Shared PJRT CPU client.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Upload a literal to a device buffer once; reuse it across many
    /// `Executable::run_b` calls. This keeps large parameter sets resident
    /// (§Perf L3: the literal-input `execute` path re-transfers — and, in
    /// xla_extension 0.5.1, leaks — every argument on every call).
    pub fn upload(&self, lit: &xla::Literal) -> Result<xla::PjRtBuffer> {
        // A null device segfaults the CPU plugin — always pin device 0.
        let devices = self.client.addressable_devices();
        let dev = devices.first().context("no addressable device")?;
        let buf = self.client.buffer_from_host_literal(Some(dev), lit)?;
        // BufferFromHostLiteral is asynchronous and the C wrapper does not
        // await the transfer; the host literal must stay alive (and the
        // buffer ready) before any execute_b. Round-tripping the buffer to
        // a literal forces readiness while `lit` is still borrowed.
        let _ = buf.to_literal_sync()?;
        Ok(buf)
    }

    pub fn upload_all(&self, lits: &[xla::Literal]) -> Result<Vec<xla::PjRtBuffer>> {
        lits.iter().map(|l| self.upload(l)).collect()
    }

    /// Load + compile an HLO text artifact.
    pub fn load(&self, path: &Path) -> Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Executable { exe, name: path.display().to_string() })
    }
}

/// A compiled computation ready for repeated execution.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
}

impl Executable {
    /// Execute with positional literal inputs; returns the flattened output
    /// tuple elements.
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let result = self
            .exe
            .execute::<xla::Literal>(inputs)
            .with_context(|| format!("executing {}", self.name))?[0][0]
            .to_literal_sync()?;
        Ok(result.to_tuple()?)
    }

    /// Execute and return the single scalar f32 output (NLL graphs).
    pub fn run_scalar(&self, inputs: &[xla::Literal]) -> Result<f32> {
        let out = self.run(inputs)?;
        anyhow::ensure!(out.len() == 1, "expected 1 output, got {}", out.len());
        Ok(out[0].get_first_element::<f32>()?)
    }

    /// Execute with pre-uploaded device buffers (the hot path: parameters
    /// stay resident, only small operands are re-uploaded per call).
    pub fn run_b(&self, inputs: &[&xla::PjRtBuffer]) -> Result<Vec<xla::Literal>> {
        let result = self
            .exe
            .execute_b::<&xla::PjRtBuffer>(inputs)
            .with_context(|| format!("executing {}", self.name))?[0][0]
            .to_literal_sync()?;
        Ok(result.to_tuple()?)
    }

    /// Execute and return the single scalar f32 output (NLL graphs).
    pub fn run_scalar_b(&self, inputs: &[&xla::PjRtBuffer]) -> Result<f32> {
        let out = self.run_b(inputs)?;
        anyhow::ensure!(out.len() == 1, "expected 1 output, got {}", out.len());
        Ok(out[0].get_first_element::<f32>()?)
    }
}

/// Build an f32 literal of the given shape.
pub fn literal_f32(data: &[f32], dims: &[usize]) -> Result<xla::Literal> {
    let n: usize = dims.iter().product();
    anyhow::ensure!(n == data.len(), "shape {:?} vs len {}", dims, data.len());
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims_i64)?)
}

/// Build an i32 literal of the given shape.
pub fn literal_i32(data: &[i32], dims: &[usize]) -> Result<xla::Literal> {
    let n: usize = dims.iter().product();
    anyhow::ensure!(n == data.len(), "shape {:?} vs len {}", dims, data.len());
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims_i64)?)
}

/// Build an int8 literal (codebook indices) of the given shape.
pub fn literal_i8(data: &[i8], dims: &[usize]) -> Result<xla::Literal> {
    let n: usize = dims.iter().product();
    anyhow::ensure!(n == data.len(), "shape {:?} vs len {}", dims, data.len());
    let bytes: &[u8] =
        unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len()) };
    Ok(xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::S8,
        dims,
        bytes,
    )?)
}
