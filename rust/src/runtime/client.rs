//! Runtime facade: load AOT artifacts once, execute many — over whichever
//! [`Backend`] is active.
//!
//! Backend selection: the pure-Rust [`super::sim::SimBackend`] by default;
//! the PJRT backend when built with `--features xla`. The `HALO_BACKEND`
//! env var (`sim` / `xla`) overrides either way, so a PJRT build can still
//! run the reference interpreter for differential testing.

use std::path::Path;

use anyhow::{Context, Result};

use super::backend::{Backend, Buffer, ExecutableImpl, Literal};
use super::kvcache::KvCache;
use super::sim::SimBackend;

/// A handle to the active execution backend.
pub struct Runtime {
    backend: Box<dyn Backend>,
}

impl Runtime {
    /// The standard constructor used everywhere: host-CPU execution on the
    /// default backend for this build (see module docs).
    pub fn cpu() -> Result<Self> {
        match std::env::var("HALO_BACKEND") {
            Ok(v) if v == "sim" => Ok(Self::sim()),
            Ok(v) if v == "xla" => Self::pjrt(),
            Ok(other) => anyhow::bail!("unknown HALO_BACKEND `{other}` (expected sim|xla)"),
            Err(_) => Self::default_backend(),
        }
    }

    /// The pure-Rust interpreter backend (always available).
    pub fn sim() -> Self {
        Self { backend: Box::new(SimBackend) }
    }

    /// The PJRT backend (requires the `xla` cargo feature).
    #[cfg(feature = "xla")]
    pub fn pjrt() -> Result<Self> {
        Ok(Self { backend: Box::new(super::xla::PjrtBackend::cpu()?) })
    }

    /// The PJRT backend (requires the `xla` cargo feature).
    #[cfg(not(feature = "xla"))]
    pub fn pjrt() -> Result<Self> {
        anyhow::bail!("built without the `xla` feature; rebuild with `--features xla`")
    }

    #[cfg(feature = "xla")]
    fn default_backend() -> Result<Self> {
        Self::pjrt()
    }

    #[cfg(not(feature = "xla"))]
    fn default_backend() -> Result<Self> {
        Ok(Self::sim())
    }

    /// Human-readable platform name of the active backend.
    pub fn platform(&self) -> String {
        self.backend.platform_name()
    }

    /// Whether this backend's forward graphs can decode incrementally
    /// against a per-request KV cache (see
    /// [`Executable::run_decode_step`]).
    pub fn incremental_decode(&self) -> bool {
        self.backend.supports_incremental_decode()
    }

    /// Whether model graphs on this backend accept any leading batch dim
    /// (see [`Backend::supports_dynamic_batch`]). The serving executor uses
    /// this to pad partial batches only to their own size.
    pub fn dynamic_batch(&self) -> bool {
        self.backend.supports_dynamic_batch()
    }

    /// Upload a literal to a device buffer once; reuse it across many
    /// `Executable::run_b` calls. This keeps large parameter sets resident
    /// (§Perf L3).
    pub fn upload(&self, lit: &Literal) -> Result<Buffer> {
        self.backend.upload(lit)
    }

    /// Upload a batch of literals (parameter sets) to resident buffers.
    pub fn upload_all(&self, lits: &[Literal]) -> Result<Vec<Buffer>> {
        lits.iter().map(|l| self.upload(l)).collect()
    }

    /// Load (and, on PJRT, compile) a graph artifact.
    pub fn load(&self, path: &Path) -> Result<Executable> {
        let imp = self
            .backend
            .load(path)
            .with_context(|| format!("loading {}", path.display()))?;
        Ok(Executable { imp, name: path.display().to_string() })
    }
}

/// A loaded computation ready for repeated execution.
pub struct Executable {
    imp: Box<dyn ExecutableImpl>,
    /// The artifact path this executable was loaded from (error context).
    pub name: String,
}

impl Executable {
    /// Execute with positional literal inputs; returns the flattened output
    /// tuple elements.
    pub fn run(&self, inputs: &[Literal]) -> Result<Vec<Literal>> {
        let refs: Vec<&Literal> = inputs.iter().collect();
        self.imp
            .run(&refs)
            .with_context(|| format!("executing {}", self.name))
    }

    /// Execute and return the single scalar f32 output (NLL graphs).
    pub fn run_scalar(&self, inputs: &[Literal]) -> Result<f32> {
        let out = self.run(inputs)?;
        anyhow::ensure!(out.len() == 1, "expected 1 output, got {}", out.len());
        out[0].get_first_element::<f32>()
    }

    /// Execute with pre-uploaded device buffers (the hot path: parameters
    /// stay resident, only small operands are re-uploaded per call).
    pub fn run_b(&self, inputs: &[&Buffer]) -> Result<Vec<Literal>> {
        self.imp
            .run_buffers(inputs)
            .with_context(|| format!("executing {}", self.name))
    }

    /// Execute and return the single scalar f32 output (NLL graphs).
    pub fn run_scalar_b(&self, inputs: &[&Buffer]) -> Result<f32> {
        let out = self.run_b(inputs)?;
        anyhow::ensure!(out.len() == 1, "expected 1 output, got {}", out.len());
        out[0].get_first_element::<f32>()
    }

    /// Execute with device buffers and return the single output literal
    /// (the `fwd_fp` logits path — avoids the Vec wrapper on the serving
    /// decode loop's per-step call).
    pub fn run_b1(&self, inputs: &[&Buffer]) -> Result<Literal> {
        let mut out = self.run_b(inputs)?;
        anyhow::ensure!(out.len() == 1, "expected 1 output, got {}", out.len());
        Ok(out.pop().expect("len checked above"))
    }

    /// True when this loaded graph supports KV-cached incremental decode
    /// (see [`Executable::run_decode_step`]). Only the sim backend's
    /// `fwd` model graphs do.
    pub fn supports_incremental_decode(&self) -> bool {
        self.imp.supports_incremental_decode()
    }

    /// KV-cached incremental decode step: evaluate only `tokens` (the
    /// window suffix at absolute positions `pos0..`) against — and
    /// appending to — the per-request `cache`. `params` are the resident
    /// parameter buffers in canonical order (no token literal). Returns
    /// the `(tokens.len(), vocab)` logits for the new positions,
    /// bit-identical to the rows of a full-prefix pass.
    pub fn run_decode_step(
        &self,
        params: &[&Buffer],
        tokens: &[i32],
        pos0: usize,
        cache: &mut KvCache,
    ) -> Result<Literal> {
        self.imp
            .run_decode_step(params, tokens, pos0, cache)
            .with_context(|| format!("decode step on {}", self.name))
    }
}

/// Build an f32 literal of the given shape.
pub fn literal_f32(data: &[f32], dims: &[usize]) -> Result<Literal> {
    Literal::f32(data, dims)
}

/// Build an i32 literal of the given shape.
pub fn literal_i32(data: &[i32], dims: &[usize]) -> Result<Literal> {
    Literal::i32(data, dims)
}

/// Build an int8 literal (codebook indices) of the given shape.
pub fn literal_i8(data: &[i8], dims: &[usize]) -> Result<Literal> {
    Literal::i8(data, dims)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_constructors_check_shapes() {
        assert!(literal_f32(&[1.0; 6], &[2, 3]).is_ok());
        assert!(literal_f32(&[1.0; 5], &[2, 3]).is_err());
        assert!(literal_i32(&[1, 2], &[2]).is_ok());
        assert!(literal_i8(&[1, 2, 3], &[4]).is_err());
    }

    #[test]
    fn sim_runtime_always_available() {
        let rt = Runtime::sim();
        assert_eq!(rt.platform(), "sim-cpu");
        let buf = rt.upload(&Literal::scalar_f32(1.0)).unwrap();
        assert_eq!(buf.as_host().unwrap().get_first_element::<f32>().unwrap(), 1.0);
    }

    #[cfg(not(feature = "xla"))]
    #[test]
    fn default_backend_is_sim_offline() {
        // Guard against env overrides leaking in from the harness.
        if std::env::var("HALO_BACKEND").is_err() {
            let rt = Runtime::cpu().unwrap();
            assert_eq!(rt.platform(), "sim-cpu");
        }
    }

    #[test]
    fn sim_backend_reports_dynamic_batch() {
        assert!(Runtime::sim().dynamic_batch());
    }

    #[test]
    fn load_missing_artifact_errors() {
        let rt = Runtime::sim();
        assert!(rt.load(Path::new("/nonexistent/nll_fp.hlo.txt")).is_err());
    }
}
