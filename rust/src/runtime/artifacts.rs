//! Artifact store: the contract with `python/compile/aot.py`.
//!
//! Loads the manifest, per-model param tables + trained weights, corpora
//! token streams, and resolves HLO graph paths. Parameter order in every
//! lowered graph is the canonical order of `config.json`'s table, followed
//! by the token batch — the Rust side never guesses.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::quant::Matrix;
use crate::util::Json;

use super::backend::Literal;

/// One named parameter tensor.
#[derive(Debug, Clone)]
pub struct Param {
    /// Canonical parameter name (e.g. `layer0.attn.wq`).
    pub name: String,
    /// Row-major shape.
    pub shape: Vec<usize>,
    /// Trained weights, flat f32.
    pub data: Vec<f32>,
    /// True for linear (quantizable GEMM) weights.
    pub linear: bool,
}

impl Param {
    /// View a 2-D linear weight as a Matrix (copies).
    pub fn as_matrix(&self) -> Result<Matrix> {
        if self.shape.len() != 2 {
            bail!("{} is not 2-D: {:?}", self.name, self.shape);
        }
        Ok(Matrix::from_vec(self.shape[0], self.shape[1], self.data.clone()))
    }
}

/// A trained model's artifacts.
#[derive(Debug)]
pub struct ModelArtifacts {
    /// Model name (the `models/<name>` directory).
    pub name: String,
    /// The model's artifact directory.
    pub dir: PathBuf,
    /// All parameters in canonical (graph-input) order.
    pub params: Vec<Param>,
    /// Batch size the evaluation graphs were lowered with.
    pub eval_batch: usize,
    /// Context window.
    pub seq_len: usize,
    /// Vocabulary size.
    pub vocab: usize,
}

impl ModelArtifacts {
    /// Load `<root>/models/<name>`: config table + trained weights.
    pub fn load(root: &Path, name: &str) -> Result<Self> {
        let dir = root.join("models").join(name);
        let meta = Json::parse(
            &std::fs::read_to_string(dir.join("config.json"))
                .with_context(|| format!("reading {}/config.json", dir.display()))?,
        )?;
        let flat = read_f32(&dir.join("params.f32.bin"))?;
        let n_params = meta.req("n_params")?.as_usize()?;
        anyhow::ensure!(flat.len() == n_params, "params.f32.bin length mismatch");

        let mut params = Vec::new();
        for e in meta.req("params")?.as_arr()? {
            let offset = e.req("offset")?.as_usize()?;
            let numel = e.req("numel")?.as_usize()?;
            let shape: Vec<usize> = e
                .req("shape")?
                .as_arr()?
                .iter()
                .map(|x| x.as_usize())
                .collect::<Result<_>>()?;
            params.push(Param {
                name: e.req("name")?.as_str()?.to_string(),
                shape,
                data: flat[offset..offset + numel].to_vec(),
                linear: e.req("linear")?.as_bool()?,
            });
        }
        let cfg = meta.req("config")?;
        Ok(Self {
            name: name.to_string(),
            dir,
            params,
            eval_batch: meta.req("eval_batch")?.as_usize()?,
            seq_len: cfg.req("seq_len")?.as_usize()?,
            vocab: cfg.req("vocab")?.as_usize()?,
        })
    }

    /// Path of a lowered graph artifact (`fwd_fp`, `nll_a8`, …).
    pub fn graph_path(&self, graph: &str) -> PathBuf {
        self.dir.join(format!("{graph}.hlo.txt"))
    }

    /// Look up one parameter by name.
    pub fn param(&self, name: &str) -> Option<&Param> {
        self.params.iter().find(|p| p.name == name)
    }

    /// The linear (quantizable) weights, in canonical order.
    pub fn linear_params(&self) -> impl Iterator<Item = &Param> {
        self.params.iter().filter(|p| p.linear)
    }

    /// Total scalar weight count across all parameters.
    pub fn n_weights(&self) -> usize {
        self.params.iter().map(|p| p.data.len()).sum()
    }

    /// Literals for all params in canonical order, with the linear weights
    /// optionally substituted by (de)quantized replacements.
    pub fn param_literals(
        &self,
        replace: &BTreeMap<String, Matrix>,
    ) -> Result<Vec<Literal>> {
        self.params
            .iter()
            .map(|p| {
                if let Some(m) = replace.get(&p.name) {
                    anyhow::ensure!(
                        m.rows == p.shape[0] && m.cols == p.shape[1],
                        "shape mismatch for {}",
                        p.name
                    );
                    Literal::f32(&m.data, &p.shape)
                } else {
                    Literal::f32(&p.data, &p.shape)
                }
            })
            .collect()
    }
}

/// The artifact root (manifest + corpora + models).
#[derive(Debug)]
pub struct Store {
    /// The artifact root directory.
    pub root: PathBuf,
    /// The parsed `manifest.json`.
    pub manifest: Json,
}

impl Store {
    /// Open an artifact root (requires its `manifest.json`).
    pub fn open(root: impl Into<PathBuf>) -> Result<Self> {
        let root = root.into();
        let manifest = Json::parse(
            &std::fs::read_to_string(root.join("manifest.json")).with_context(|| {
                format!(
                    "no artifacts at {} — run `make artifacts` first",
                    root.display()
                )
            })?,
        )?;
        Ok(Self { root, manifest })
    }

    /// Default location relative to the repo root, overridable by env.
    pub fn open_default() -> Result<Self> {
        let root = std::env::var("HALO_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
        Self::open(root)
    }

    /// Names of every trained model in the manifest.
    pub fn model_names(&self) -> Result<Vec<String>> {
        Ok(self
            .manifest
            .req("models")?
            .as_obj()?
            .keys()
            .cloned()
            .collect())
    }

    /// Load one model's artifacts by name.
    pub fn model(&self, name: &str) -> Result<ModelArtifacts> {
        ModelArtifacts::load(&self.root, name)
    }

    /// Evaluation token stream for a corpus ("wikisyn" / "c4syn").
    pub fn corpus_eval(&self, corpus: &str) -> Result<Vec<u16>> {
        read_u16(&self.root.join("corpora").join(format!("{corpus}_eval.u16.bin")))
    }

    /// Calibration token stream (Fisher gradients, quantizer inputs).
    pub fn corpus_calib(&self) -> Result<Vec<u16>> {
        read_u16(&self.root.join("corpora").join("calib.u16.bin"))
    }

    /// Path of a standalone lowered kernel (`halo_matmul`, `spmv`).
    pub fn kernel_path(&self, name: &str) -> PathBuf {
        self.root.join("kernels").join(format!("{name}.hlo.txt"))
    }
}

fn read_f32(path: &Path) -> Result<Vec<f32>> {
    let bytes = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    anyhow::ensure!(bytes.len() % 4 == 0, "misaligned f32 file");
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

fn read_u16(path: &Path) -> Result<Vec<u16>> {
    let bytes = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    anyhow::ensure!(bytes.len() % 2 == 0, "misaligned u16 file");
    Ok(bytes
        .chunks_exact(2)
        .map(|c| u16::from_le_bytes([c[0], c[1]]))
        .collect())
}

/// Batch a token stream into (batch, seq+1) i32 batches for the NLL graphs.
pub fn nll_batches(stream: &[u16], batch: usize, seq: usize) -> Vec<Vec<i32>> {
    let per = batch * (seq + 1);
    stream
        .chunks_exact(per)
        .map(|c| c.iter().map(|&t| t as i32).collect())
        .collect()
}
